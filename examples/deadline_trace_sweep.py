"""Deadline-aware scheduling under a week-long grid-carbon forecast —
the two scenario families the PR-1 periodic engine rejected outright,
now one `Campaign.sweep` call away via the trace-grid scan engine.

A fleet of deadline pace-keepers is swept against a non-periodic 7-day
carbon-intensity trace (diurnal swing + weekday drift): each schedule
coasts while ahead of its linear pace and ramps up when behind, so the
runtime/CO2e trade maps the cost of every deadline directly.

    PYTHONPATH=src python examples/deadline_trace_sweep.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.carina as carina


def week_trace() -> carina.TraceSignal:
    """7 days of hourly kg-CO2e/kWh: Midwest-style diurnal swing, a slow
    weekday drift, deterministic noise.  Nothing repeats with period 24,
    so the periodic engine cannot represent it."""
    h = np.arange(7 * 24)
    rng = np.random.RandomState(7)
    vals = carina.DTE_FACTOR * (1.0
                                + 0.30 * np.sin(2 * np.pi * h / 24.0)
                                + 0.08 * np.sin(2 * np.pi * h / 168.0)
                                + 0.05 * rng.randn(h.size))
    return carina.as_trace(vals, name="week-forecast")


def main():
    campaign = carina.Campaign(carina.OEM_CASE_1)
    trace = week_trace()

    deadlines = list(range(185, 271, 5))
    schedules = [carina.deadline_schedule(float(dl)) for dl in deadlines]
    t0 = time.perf_counter()
    swept = campaign.sweep(schedules, carbon_trace=trace)
    dt = (time.perf_counter() - t0) * 1e3
    base = campaign.baseline()

    print(f"=== {len(schedules)} deadline pace-keepers x 7-day carbon "
          f"trace in {dt:.0f} ms (trace-grid scan engine)")
    print(f"    calibrated baseline: {base.runtime_h:.1f} h, "
          f"{base.energy_kwh:.1f} kWh")
    for dl, r in zip(deadlines, swept):
        met = "met " if r.runtime_h <= dl + 1.0 else "MISS"
        print(f"  deadline {dl:3d} h -> {r.runtime_h:6.1f} h [{met}]  "
              f"{r.energy_kwh:5.1f} kWh  {r.co2_kg:5.1f} kg CO2e")
    cheapest = min(swept, key=lambda r: r.co2_kg)
    print(f"  -> lowest-CO2e deadline: {cheapest.policy} "
          f"({cheapest.co2_kg:.1f} kg, {cheapest.runtime_h:.0f} h)")

    # the same trade, but one schedule object swept against ctx.deadline_h
    flexible = carina.deadline_schedule()        # reads ctx.deadline_h
    for dl in (200.0, 240.0):
        r = campaign.sweep([flexible], carbon_trace=trace,
                           deadline_h=dl)[0]
        print(f"  ctx-deadline {dl:.0f} h -> {r.runtime_h:.1f} h, "
              f"{r.co2_kg:.1f} kg CO2e")


if __name__ == "__main__":
    main()
