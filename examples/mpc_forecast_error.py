"""The value of a forecast: receding-horizon MPC vs forecast quality.

CARINA's optimizer plans against a carbon signal, but real grid signals
are forecasts that go stale mid-campaign.  This example runs the same
campaign closed-loop under `Campaign.run_mpc` with three forecast
models — `oracle` (perfect foresight), `day_ahead` (truth plus seeded
multiplicative noise on future hours), and `persistence` (yesterday
again) — and prints the value-of-forecast curve on *realized* CO2, the
experiment both West et al. carbon-shifting studies (arXiv:2503.13705,
arXiv:2508.14625) use to show savings hinge on forecast quality.  An
open-loop run (K=inf, one solve, never corrected) under the noisy
forecast shows what re-planning buys back.

    PYTHONPATH=src python examples/mpc_forecast_error.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.carina as carina

FAST = bool(os.environ.get("CARINA_EXAMPLE_FAST"))   # CI smoke mode


def ground_truth(days: int = 14) -> carina.TraceSignal:
    """Synthetic realized carbon with day-to-day regime drift: the
    diurnal swing's amplitude and phase wander across days, so
    yesterday's shape is a genuinely imperfect predictor of today's."""
    rng = np.random.default_rng(11)
    h = np.arange(days * 24, dtype=float)
    day = h // 24
    amp = 0.18 + 0.10 * np.sin(day * 2.1) + 0.03 * rng.standard_normal(
        h.size)
    phase = 0.8 * np.sin(day * 0.9)
    vals = 0.40 + amp * np.sin((h % 24) * 2 * np.pi / 24 + phase)
    vals += 0.02 * rng.standard_normal(h.size)
    return carina.as_trace(vals.clip(0.05), name="realized-grid")


def main() -> None:
    truth = ground_truth()
    wl, _ = carina.calibrate_workload(carina.OEM_CASE_1,
                                      carina.MachineProfile())
    # 1/4 of OEM case 1 (~45 h at full intensity) against a 96 h
    # deadline: enough slack that *when* you run decides the emissions.
    # Scale the measured calibration point with the scenario count, or
    # Campaign.calibrated() would re-derive a 4x slower rate.
    wl = dataclasses.replace(wl, n_scenarios=wl.n_scenarios // 4,
                             measured_hours=wl.measured_hours / 4,
                             measured_kwh=wl.measured_kwh / 4)
    campaign = carina.Campaign(wl, carbon=truth)
    solver = (dict(method="cem", candidates=12, iterations=2, seed=0)
              if FAST else
              dict(method="cem", candidates=32, iterations=6, seed=0))
    deadline, K = 96.0, 24.0

    runs = [
        ("oracle      (K=24h)", carina.oracle(), K),
        ("day_ahead   (K=24h)", carina.day_ahead(noise_sigma=0.35,
                                                 seed=0), K),
        ("persistence (K=24h)", carina.persistence(), K),
        ("day_ahead  (open loop)", carina.day_ahead(noise_sigma=0.35,
                                                    seed=0), None),
    ]
    print(f"OEM case 1 (scaled 1/4), deadline {deadline:.0f} h, "
          f"re-plan every {K:.0f} h")
    print(f"{'forecast':24s} {'realized CO2':>13s} {'vs oracle':>10s} "
          f"{'replans':>8s} {'fc MAE':>8s}")
    rows = {}
    for label, model, k in runs:
        out = campaign.run_mpc(truth, deadline_h=deadline, forecast=model,
                               replan_every_h=k, **solver)
        rows[label] = out
        base = rows[runs[0][0]].realized_co2_kg
        print(f"{label:24s} {out.realized_co2_kg:10.3f} kg "
              f"{100 * (out.realized_co2_kg / base - 1):+9.1f}% "
              f"{out.n_replans:8d} {out.forecast_mae:8.3f}")

    oracle_co2 = rows[runs[0][0]].realized_co2_kg
    worst = max(r.realized_co2_kg for r in rows.values())
    print(f"\nvalue of a perfect forecast: "
          f"{100 * (worst / oracle_co2 - 1):.1f}% realized CO2 between "
          f"the oracle and the worst run above.  Every re-plan resumed "
          f"from carried executor state — zero already-executed slots "
          f"recomputed (slots_reused="
          f"{rows[runs[2][0]].slots_reused} for persistence).")


if __name__ == "__main__":
    main()
