"""Plan against a carbon *ensemble*, not one forecast.

The carbon-aware workflow literature is blunt about single-trace
evaluations: savings estimates only mean something across many trace
windows.  This example slices six weeks of synthetic grid history into
an ensemble of overlapping two-week windows (`carina.trace_windows`),
sweeps the fixed policies against all members in one scan — every row
gets a mean ± spread instead of a point estimate — and then synthesizes
two schedules with `Campaign.optimize`: one minimizing *expected* CO2
(`robust="mean"`) and one minimizing the CVaR tail (`robust="cvar"`,
the mean of the worst 10% of carbon scenarios).  The CVaR schedule
gives up a little average CO2 to cut its bad-week exposure.

    PYTHONPATH=src python examples/ensemble_robust_schedule.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.carina as carina

FAST = bool(os.environ.get("CARINA_EXAMPLE_FAST"))   # CI smoke mode


def grid_history(weeks: int = 6) -> np.ndarray:
    """Synthetic hourly kg-CO2e/kWh history: diurnal swing, a weekly
    cycle, a slow seasonal drift, and weather-like noise."""
    h = np.arange(weeks * 7 * 24)
    rng = np.random.RandomState(11)
    return carina.DTE_FACTOR * (1.0
                                + 0.30 * np.sin(2 * np.pi * h / 24.0)
                                + 0.10 * np.sin(2 * np.pi * h / 168.0)
                                + 0.06 * np.cos(2 * np.pi * h / (weeks * 168))
                                + 0.07 * rng.randn(h.size))


def fmt(r) -> str:
    s = r.co2_ensemble
    return (f"{r.runtime_h:6.1f} h  {r.energy_kwh:5.1f} kWh  "
            f"CO2 {s.mean:5.2f} ±{s.std:.2f} kg  "
            f"[q05 {s.q05:.2f} .. q95 {s.q95:.2f}]")


def main():
    ensemble = carina.trace_windows(grid_history(), window_h=24 * 14,
                                    stride_h=24, name="history")
    if FAST:
        ensemble = carina.SignalEnsemble(ensemble.members[::2],
                                         name="history")
    print(f"=== {len(ensemble)} two-week carbon windows from six weeks of "
          "grid history\n")

    campaign = carina.Campaign(carina.OEM_CASE_1)
    six = campaign.sweep(list(carina.POLICIES.values()),
                         carbon_ensemble=ensemble)
    deadline = max(r.runtime_h for r in six)
    print(f"=== fixed Figure-1 policies across all members "
          f"(deadline {deadline:.0f} h)")
    for r in sorted(six, key=lambda r: r.co2_kg):
        print(f"  {r.policy:32s} {fmt(r)}")

    kw = (dict(candidates=48, iterations=6, steps=40) if FAST
          else dict(candidates=192, iterations=24, steps=300))
    method = "auto"
    results = {}
    for robust in ("mean", "cvar"):
        t0 = time.perf_counter()
        opt = campaign.optimize("co2", deadline_h=deadline,
                                carbon_ensemble=ensemble, robust=robust,
                                method=method, **kw)
        dt = time.perf_counter() - t0
        results[robust] = opt
        print(f"\n=== {opt.result.policy} ({opt.method}, "
              f"{opt.evaluations} evaluations, {dt:.1f} s)")
        print(f"  {fmt(opt.result)}")

    mean_tail = np.sort(results['mean'].co2_ensemble)[-3:].mean()
    cvar_tail = np.sort(results['cvar'].co2_ensemble)[-3:].mean()
    print(f"\n  worst-3-window CO2: mean-objective {mean_tail:.2f} kg, "
          f"cvar-objective {cvar_tail:.2f} kg")
    if cvar_tail < mean_tail - 1e-3:
        print("  (the CVaR schedule trades a sliver of average CO2 for a "
              "flatter bad-scenario tail)")
    else:
        print("  (on this ensemble the expected-CO2 optimum already has a "
              "flat tail, so both objectives agree — spikier histories "
              "separate them)")


if __name__ == "__main__":
    main()
