"""Close the loop with real data: grid archives in, measured physics out.

Everything CARINA optimizes rests on two inputs that, until now, were
asserted rather than measured: the grid carbon signal and the machine's
rate/power model.  This example exercises both halves of the new
ingestion/calibration layer end to end:

1. load a bundled ElectricityMaps-style multi-zone archive
   (`load_sample_archive`) and inspect its per-zone `QualityReport` —
   every DST fold, gap and unit conversion is counted, never silent;
2. run a campaign with *known* ("true") model parameters, tracked to a
   RunTracker JSONL log — standing in for a real measured run;
3. `Campaign.calibrate(...)` fits rate_at_full / gamma / idle_w /
   dyn_w / overhead_w_frac back out of the log (Adam through the
   differentiable model), with bootstrap confidence intervals;
4. apply the fitted physics and sweep schedules across all archive
   zones in one batched (schedule x zone) launch.

    PYTHONPATH=src python examples/calibrate_from_logs.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.carina as carina

FAST = bool(os.environ.get("CARINA_EXAMPLE_FAST"))   # CI smoke mode

TRUTH = {"rate_at_full": 3.4, "gamma": 0.65, "idle_w": 95.0,
         "dyn_w": 260.0, "overhead_w_frac": 0.45}


class ExciteSchedule:
    """Identification schedule: walk intensity across [0.3, 1.0] and
    alternate batch sizes, so every fitted parameter shows up in the
    logged (throughput, power) operating points."""
    name = "excite"

    def decide(self, ctx):
        h = int(ctx.hour_of_day)
        u = 0.3 + 0.7 * ((h * 7) % 24) / 23.0
        return carina.Decision(u, batch_size=8 if h % 2 else 32)


def main():
    # --- 1. a real-format carbon archive, validated ------------------
    arch = carina.load_sample_archive("grid_week_3z.csv")
    print(f"=== archive {arch.name!r}: zones {', '.join(arch.zones)}")
    for series in arch:
        q = series.quality
        print(f"  {series.zone:8s} {series.hours:4d} h  "
              f"mean {series.mean_kg_per_kwh:.3f} kg/kWh  "
              f"unit={q.unit} gaps={q.gaps_filled} "
              f"folds={q.dst_folds} clean={q.clean}")

    # --- 2. a measured run (simulated here with known-true physics) --
    zone = arch.zones[0]
    carbon = carina.GridCarbonModel(
        hourly_curve=carina.MIDWEST_HOURLY, zone=zone, source=arch.name)
    n = 60_000 if FAST else 150_000
    truth_wl = carina.OEMWorkload("measured", n,
                                  rate_at_full=TRUTH["rate_at_full"],
                                  batch_overhead_s=2.0)
    truth_machine = carina.MachineProfile(
        idle_w=TRUTH["idle_w"], dyn_w=TRUTH["dyn_w"],
        gamma=TRUTH["gamma"], overhead_w_frac=TRUTH["overhead_w_frac"])
    out_dir = tempfile.mkdtemp(prefix="carina-calibrate-")
    report = carina.Campaign(truth_wl, ExciteSchedule(), truth_machine,
                             carbon=carbon, out_dir=out_dir
                             ).run(track=True, render=False)
    log = os.path.join(out_dir, "units.jsonl")
    print(f"\n=== measured run: {report.summary.units} units logged "
          f"-> {log}")

    # --- 3. fit the model back out of the log ------------------------
    # the fitting campaign starts from a wrong-but-plausible prior
    nominal = carina.Campaign(
        carina.OEMWorkload("nominal", n, rate_at_full=3.0,
                           batch_overhead_s=2.0),
        ExciteSchedule(), carina.MachineProfile(), carbon=carbon)
    cm = nominal.calibrate(log, bootstrap=0 if FAST else 8, apply=True)
    print(f"\n=== calibrated ({cm.backend}, {cm.n_units} units, "
          f"zone={cm.zone}, loss={cm.loss:.2e})")
    print(f"  {'param':16s} {'prior':>9s} {'fitted':>9s} {'true':>9s} "
          f"{'err':>7s}")
    for p in cm.fit:
        err = abs(cm.params[p] / TRUTH[p] - 1.0)
        ci = (f"  [{cm.ci[p][0]:.3g}, {cm.ci[p][1]:.3g}]"
              if p in cm.ci else "")
        print(f"  {p:16s} {cm.init[p]:9.3f} {cm.params[p]:9.3f} "
              f"{TRUTH[p]:9.3f} {100 * err:6.2f}%{ci}")

    # --- 4. sweep the fitted physics across every archive zone -------
    scheds = [carina.BASELINE, carina.PEAK_AWARE_BOOSTED,
              carina.constant_schedule(0.6)]
    rows = nominal.sweep(scheds, zones=arch)
    print(f"\n=== (schedule x zone) sweep with the fitted model "
          f"({len(rows)} rows, one batched launch)")
    for r in sorted(rows, key=lambda r: r.co2_kg):
        print(f"  {r.policy:34s} {r.runtime_h:6.1f} h  "
              f"{r.energy_kwh:6.2f} kWh  {r.co2_kg:6.2f} kg CO2e")
    best = min(rows, key=lambda r: r.co2_kg)
    print(f"\nbest placement+schedule: {best.policy} "
          f"({best.co2_kg:.2f} kg CO2e)")


if __name__ == "__main__":
    main()
