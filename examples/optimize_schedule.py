"""Synthesize a near-optimal schedule instead of picking a hand-written one.

The paper's Figure 1 *evaluates* six fixed policies; `Campaign.optimize`
*searches* the schedule space.  Here a 7-day grid-carbon forecast and a
deadline define the problem — min energy subject to finishing on time —
and the optimizer (population search + gradient polish through the
jitted trace scan) returns a per-hour intensity schedule that beats
every fixed policy, including the paper's best (`OffHoursBoost`,
a.k.a. `peak_aware_boosted_offhours`: ~-9% energy at ~+7% runtime).

    PYTHONPATH=src python examples/optimize_schedule.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.carina as carina

FAST = bool(os.environ.get("CARINA_EXAMPLE_FAST"))   # CI smoke mode


def week_trace() -> carina.TraceSignal:
    """7 days of hourly kg-CO2e/kWh: diurnal swing + weekday drift +
    deterministic noise (non-periodic, so the trace engine handles it)."""
    h = np.arange(7 * 24)
    rng = np.random.RandomState(7)
    vals = carina.DTE_FACTOR * (1.0
                                + 0.30 * np.sin(2 * np.pi * h / 24.0)
                                + 0.08 * np.sin(2 * np.pi * h / 168.0)
                                + 0.05 * rng.randn(h.size))
    return carina.as_trace(vals, name="week-forecast")


def bar(u: float, width: int = 28) -> str:
    return "#" * round(u * width)


def main():
    campaign = carina.Campaign(carina.OEM_CASE_1)
    trace = week_trace()

    # the fixed six under the same forecast; the slowest sets the deadline
    six = campaign.sweep(list(carina.POLICIES.values()), carbon_trace=trace)
    deadline = max(r.runtime_h for r in six)
    boosted = next(r for r in six if "boosted" in r.policy)

    print(f"=== fixed Figure-1 policies under a 7-day carbon forecast "
          f"(deadline {deadline:.0f} h)")
    for r in sorted(six, key=lambda r: r.energy_kwh):
        print(f"  {r.policy:32s} {r.runtime_h:6.1f} h  "
              f"{r.energy_kwh:5.1f} kWh  {r.co2_kg:5.1f} kg CO2e")

    t0 = time.perf_counter()
    kw = (dict(candidates=96, iterations=8, steps=60) if FAST
          else dict(candidates=256, iterations=30, steps=400))
    opt = campaign.optimize("energy", deadline_h=deadline,
                            carbon_trace=trace, deltas=True, **kw)
    dt = time.perf_counter() - t0
    r = opt.result

    print(f"\n=== {r.policy} ({opt.method}, {opt.evaluations} candidate "
          f"evaluations, {dt:.1f} s)")
    print(f"  {r.runtime_h:6.1f} h  {r.energy_kwh:5.1f} kWh  "
          f"{r.co2_kg:5.1f} kg CO2e  ({r.energy_delta_pct:+.1f}% energy "
          f"vs baseline)")
    print(f"  vs OffHoursBoost: {100 * (r.energy_kwh / boosted.energy_kwh - 1):+.1f}% "
          f"energy, {100 * (r.co2_kg / boosted.co2_kg - 1):+.1f}% CO2e")

    print("\n  hour  optimized intensity                boost policy")
    u_opt = opt.schedule.intensity_table()
    bands = carina.TimeBands()
    for h in range(24):
        u_fix = carina.PEAK_AWARE_BOOSTED.intensity_at(bands.band_at(h))
        print(f"   {h:02d}   {u_opt[h]:.2f} {bar(u_opt[h]):28s} "
              f"{u_fix:.2f} {bar(u_fix)}")
    print("  (the optimizer rediscovers off-hours shifting on its own — "
          "and tunes the levels)")


if __name__ == "__main__":
    main()
