"""End-to-end carbon-aware training driver: any assigned architecture,
any CARINA policy, with fault tolerance, checkpointing, elastic resize,
and full energy/carbon accounting.

Demo preset (default, runs on CPU in a couple of minutes):
    PYTHONPATH=src python examples/carbon_aware_training.py

~100M-parameter end-to-end run (assignment deliverable (b); a few hundred
steps — size the step count to your machine):
    PYTHONPATH=src python examples/carbon_aware_training.py \
        --preset 100m --steps 200

Arbitrary arch / policy:
    PYTHONPATH=src python examples/carbon_aware_training.py \
        --arch falcon-mamba-7b --policy peak_aware_aggressive --steps 20
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.carina as carina
from repro.configs import get_config
from repro.core import POLICIES, SimClock
from repro.data.pipeline import SyntheticLM
from repro.distributed.fault_tolerance import (FailureInjector, Supervisor)
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.training.loop import LoopConfig, run_training


def preset_100m(cfg):
    """~100M-param llama-family config (tinyllama shrunk in width/depth)."""
    return dataclasses.replace(
        cfg, name="llama-100m", num_layers=10, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--policy", default="peak_aware_boosted_offhours",
                    choices=list(POLICIES))
    ap.add_argument("--preset", default="demo", choices=["demo", "100m"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="experiments/carbon_aware/ckpt")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args()

    if args.preset == "100m":
        cfg = preset_100m(get_config(args.arch, smoke=False))
        args.seq = max(args.seq, 256)
    else:
        cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} params={model.param_count():,} "
          f"policy={args.policy}")

    opt = AdamWConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1))
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq)
    campaign = carina.Campaign(
        carina.TrainingCampaign(f"{cfg.name}-{args.policy}", cfg.name,
                                total_steps=args.steps, steps_per_unit=5),
        POLICIES[args.policy],
        name=f"{cfg.name}-{args.policy}", out_dir="experiments/carbon_aware")
    controller = campaign.controller(
        max_replicas=1, clock=SimClock(start_hour=9.0, speedup=3600.0))
    injector = FailureInjector(
        fail_at_steps=(args.inject_failure_at,) if args.inject_failure_at >= 0
        else ())

    res = run_training(
        model, opt, data,
        LoopConfig(total_steps=args.steps, steps_per_unit=5,
                   ckpt_dir=args.ckpt_dir, log_every=5),
        controller=controller, injector=injector,
        supervisor=Supervisor(elastic=False))

    print(f"finished at step {res.final_step}, restarts={res.restarts}")
    for m in res.metrics_history[-5:]:
        print(f"  step {m['step']:4d} loss {m['loss']:.4f}")
    summary = campaign.finish(render=False)
    md = carina.render_run_dashboard(summary, "experiments/carbon_aware")
    print()
    print(md)


if __name__ == "__main__":
    main()
