"""Quickstart: train a small LM under CARINA tracking and print the
run dashboard.  Runs in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.core import (CarinaController, PEAK_AWARE_BOOSTED, RunTracker,
                        SimClock, render_run_dashboard)
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.training.loop import LoopConfig, run_training


def main():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count():,}")

    opt = AdamWConfig(total_steps=30, warmup_steps=3, peak_lr=1e-3)
    data = SyntheticLM(cfg, batch=4, seq=64)

    tracker = RunTracker("quickstart", log_path="experiments/quickstart/units.jsonl")
    controller = CarinaController(
        policy=PEAK_AWARE_BOOSTED, tracker=tracker, max_replicas=1,
        clock=SimClock(start_hour=12.0, speedup=7200.0))  # 1s wall = 2h sim

    res = run_training(model, opt, data,
                       LoopConfig(total_steps=30, steps_per_unit=5, log_every=5),
                       controller=controller)
    print(f"finished at step {res.final_step}")
    for m in res.metrics_history:
        print(f"  step {m['step']:3d} loss {m['loss']:.4f} lr {m['lr']:.2e}")

    md = render_run_dashboard(tracker.close(), "experiments/quickstart")
    print()
    print(md)


if __name__ == "__main__":
    main()
