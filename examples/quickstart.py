"""Quickstart: train a small LM under a CARINA campaign session and print
the run dashboard.  Runs in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.carina as carina
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.training.loop import LoopConfig, run_training


def main():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count():,}")

    opt = AdamWConfig(total_steps=30, warmup_steps=3, peak_lr=1e-3)
    data = SyntheticLM(cfg, batch=4, seq=64)

    # One session object owns tracking, carbon translation and reporting:
    campaign = carina.Campaign(
        carina.TrainingCampaign("quickstart", cfg.name,
                                total_steps=30, steps_per_unit=5),
        carina.PEAK_AWARE_BOOSTED,
        name="quickstart", out_dir="experiments/quickstart")
    controller = campaign.controller(
        max_replicas=1,
        clock=carina.SimClock(start_hour=12.0, speedup=7200.0))  # 1s = 2h sim

    res = run_training(model, opt, data,
                       LoopConfig(total_steps=30, steps_per_unit=5, log_every=5),
                       controller=controller)
    print(f"finished at step {res.final_step}")
    for m in res.metrics_history:
        print(f"  step {m['step']:3d} loss {m['loss']:.4f} lr {m['lr']:.2e}")

    summary = campaign.finish(render=False)
    md = carina.render_run_dashboard(summary, "experiments/quickstart")
    print()
    print(md)


if __name__ == "__main__":
    main()
