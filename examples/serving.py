"""Serve a small model with batched requests (continuous batching) under
CARINA per-request energy/carbon accounting — wired through the
`ServingSession` live mode: the session's carbon gate throttles
admissions and every engine tick is accounted (energy, CO2, band).

    PYTHONPATH=src python examples/serving.py --arch tinyllama-1.1b

Set CARINA_EXAMPLE_FAST=1 for the CI smoke mode (fewer requests).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (RunTracker, ServingSession, SimClock, StepCost,
                        render_run_dashboard, scan_stats)
from repro.models import build_model
from repro.serving.engine import ServingEngine

FAST = bool(int(os.environ.get("CARINA_EXAMPLE_FAST", "0")))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=4 if FAST else 8)
    ap.add_argument("--max-new", type=int, default=4 if FAST else 8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} ({model.param_count():,} params), "
          f"{args.slots} slots")

    tracker = RunTracker(f"serve-{cfg.name}")
    session = ServingSession(
        tracker=tracker, clock=SimClock(start_hour=10.0),
        step_cost=StepCost(flops=2e9 * model.param_count() / 1e9,
                           hbm_bytes=2 * model.param_count(), ici_bytes=0.0))

    engine = ServingEngine(model, params, slots=args.slots, s_max=128,
                           session=session)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12))
        rid = engine.submit(prompt.astype(np.int32), max_new=args.max_new)
        print(f"  submitted request {rid} (prompt len {len(prompt)})")

    done = engine.run_until_drained()
    for r in done:
        dt = (r.t_finish - r.t_submit) * 1e3
        print(f"  request {r.rid}: {len(r.generated)} tokens in {dt:.0f} ms "
              f"-> {r.generated[:6]}...")
    print(f"  session: {session.live_units} ticks, "
          f"{session.live_energy_kwh:.3e} kWh, "
          f"{session.live_co2_kg:.3e} kg CO2e")
    st = scan_stats()
    print(f"  engine: devices_used={st.devices_used} "
          f"precision={st.precision_mode or 'fp64'} "
          f"pallas_dispatches={st.pallas_dispatches} "
          f"requests_seen={st.requests_seen} "
          "(live ticks are accounted directly; window-mode sweeps run "
          "through execute_plan and report its scale-out counters here)")

    md = render_run_dashboard(tracker.close(), "experiments/serving")
    print()
    print(md)


if __name__ == "__main__":
    main()
