"""Two OEM-scale campaigns under one site power envelope.

The paper's two database-generation campaigns (1.48M and 3.66M
scenarios) ran on shared company infrastructure — the interesting
coupling is *between* the workflows: one office background load, one
site power budget, one grid carbon signal.  This example builds a
`Fleet` of both campaigns under a `Site` with an active power cap,
then:

  1. sweeps fleet-wide assignments (fixed policies and the bundled
     `AllocationSchedule` families) — each row is M per-campaign
     results plus a site rollup with the peak site draw;
  2. shows the cap biting: coupled runtimes vs free-running ones;
  3. synthesizes a *joint* schedule with `Fleet.optimize` — per-campaign
     deadlines, shared cap — and compares its site CO2 against the
     independently-optimized per-campaign schedules run under the same
     cap (the joint planner staggers the campaigns instead of letting
     the curtailment throttle both at once).

    PYTHONPATH=src python examples/fleet_shared_cap.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.carina as carina

FAST = bool(os.environ.get("CARINA_EXAMPLE_FAST"))   # CI smoke mode

DEADLINES = [300.0, 480.0]                           # hours, per campaign


def fmt(fr: "carina.FleetResult") -> str:
    s = fr.site
    peak = f"{s.peak_kw:.3f} kW" if s.peak_kw is not None else "untracked"
    return (f"makespan {s.runtime_h:6.1f} h  energy {s.energy_kwh:6.1f} kWh"
            f"  CO2 {s.co2_kg:5.1f} kg  peak {peak}")


def main():
    site = carina.Site(power_cap_kw=0.45, office_kw=0.12)
    fleet = carina.Fleet([carina.Campaign(carina.OEM_CASE_1),
                          carina.Campaign(carina.OEM_CASE_2)], site)
    print(f"=== fleet of {fleet.n_campaigns} campaigns under a "
          f"{site.power_cap_kw} kW site cap (office draw "
          f"{site.office_kw} kW at full background)\n")

    assignments = [
        carina.BASELINE,
        carina.PEAK_AWARE_BOOSTED,
        carina.proportional_split(0.8),
        carina.carbon_gated_cap(0.45),
        carina.deadline_weighted_split(DEADLINES),
    ]
    carina.reset_scan_stats()
    rows = fleet.sweep(assignments, deadlines=DEADLINES)
    st = carina.scan_stats()
    print("=== fleet-wide assignments (grouped-lane sweep, coupled)")
    print(f"  engine: devices_used={st.devices_used} "
          f"precision={st.precision_mode or 'fp64'} "
          f"pallas_dispatches={st.pallas_dispatches} "
          f"chunks={st.chunks} jit_shapes={st.jit_compiles}")
    for fr in rows:
        print(f"  {fr.policy:28s} {fmt(fr)}")
        for r in fr.campaigns:
            print(f"      {r.policy:44s} {r.runtime_h:6.1f} h "
                  f"{r.energy_kwh:5.1f} kWh")

    free = carina.Fleet(fleet.campaigns).sweep([carina.BASELINE])[0]
    capped = rows[0]
    print("\n=== the cap bites (baseline assignment)")
    for f, c in zip(free.campaigns, capped.campaigns):
        print(f"  {f.policy:24s} free {f.runtime_h:6.1f} h -> "
              f"capped {c.runtime_h:6.1f} h "
              f"({100 * (c.runtime_h / f.runtime_h - 1):+.1f}%)")

    kw = (dict(candidates=32, iterations=4, steps=40) if FAST
          else dict(candidates=128, iterations=20, steps=300))
    t0 = time.perf_counter()
    res = fleet.optimize("co2", deadlines=DEADLINES, **kw)
    dt = time.perf_counter() - t0
    print(f"\n=== joint optimization ({res.method}, {res.evaluations} "
          f"evaluations, {dt:.1f} s)")
    print(f"  joint       {fmt(carina.FleetResult(res.schedules[0].name, res.results, res.site))}")

    # the independently-optimized schedules, evaluated under the same cap
    wl_m = [c.calibrated() for c in fleet.campaigns]
    ind_cases = [
        carina.SweepCase(r.schedule, wl, mach, site.bands,
                         carina.GridCarbonModel(), 9.0,
                         label=r.schedule.name, deadline_h=d)
        for r, (wl, mach), d in zip(res.independent, wl_m, DEADLINES)]
    ind = carina.fleet_sweep([ind_cases], site, names=["independent"])[0]
    print(f"  independent {fmt(ind)}")
    saved = ind.site.co2_kg - res.site.co2_kg
    if saved > 1e-3:
        print(f"  -> joint planning saves {saved:.2f} kg CO2 "
              f"({100 * saved / ind.site.co2_kg:.1f}%) over per-campaign "
              "optima that fight for the same headroom")
    else:
        print("  -> on this cap the independent optima already stagger "
              "cleanly; tighter caps separate them further")

    for r, d in zip(res.results, DEADLINES):
        assert r.runtime_h <= d * 1.02, (r.policy, r.runtime_h, d)
    print("\nall campaigns met their deadlines under the shared cap")
    st = carina.scan_stats()
    print(f"engine totals: devices_used={st.devices_used} "
          f"precision={st.precision_mode or 'fp64'} "
          f"pallas_dispatches={st.pallas_dispatches} "
          f"chunks={st.chunks} jit_shapes={st.jit_compiles} "
          "(scale-out knobs: Fleet.sweep(devices=, precision=, pallas=))")


if __name__ == "__main__":
    main()
