"""Reproduce the paper's Figure 1 and §3 OEM case studies through the
session API: one Campaign per case gives the calibrated six-policy
frontier, dashboard artifacts (md/json/png), and — new with the
vectorized sweep engine — a 100-point intensity sweep mapping the whole
runtime/energy frontier in milliseconds.

    PYTHONPATH=src python examples/policy_comparison.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.carina as carina


def main():
    for case, paper_boosted_kwh in ((carina.OEM_CASE_1, 44.3),
                                    (carina.OEM_CASE_2, 67.5)):
        print(f"=== {case.name}: measured baseline "
              f"{case.measured_hours} h, {case.measured_kwh} kWh")
        campaign = carina.Campaign(
            case, out_dir=f"experiments/frontier/{case.name}",
            name=f"policy frontier — {case.name}")
        res = campaign.frontier(render=True)
        for r in res:
            print(f"  {r.policy:30s} {r.runtime_h:8.2f} h {r.energy_kwh:7.2f} kWh"
                  f"  dT={r.runtime_delta_pct:+6.2f}%  dE={r.energy_delta_pct:+6.2f}%"
                  f"  CO2e={r.co2_kg:5.1f} kg")
        boosted = next(r for r in res if "boosted" in r.policy)
        print(f"  -> boosted off-hours: {boosted.energy_kwh:.1f} kWh "
              f"(paper: ~{paper_boosted_kwh}); paper claim (-9%, +7%), "
              f"ours ({boosted.energy_delta_pct:+.1f}%, "
              f"{boosted.runtime_delta_pct:+.1f}%)")
        print(f"  dashboard -> experiments/frontier/{case.name}/")

        # Beyond the six fixed policies: sweep 100 candidate intensities
        # through the vectorized engine and report the efficient frontier.
        sweeps = [carina.constant_schedule(0.10 + 0.90 * i / 99)
                  for i in range(100)]
        t0 = time.perf_counter()
        swept = campaign.sweep(sweeps, deltas=False)
        dt = (time.perf_counter() - t0) * 1e3
        best = min(swept, key=lambda r: r.energy_kwh)
        print(f"  100-schedule sweep in {dt:.1f} ms: lowest-energy constant "
              f"intensity {best.policy} -> {best.energy_kwh:.1f} kWh "
              f"({best.runtime_h:.0f} h)")
        print()


if __name__ == "__main__":
    main()
