"""Reproduce the paper's Figure 1 and §3 OEM case studies: simulate all six
execution policies against the calibrated measured baselines, print the
frontier, and write dashboard artifacts (md/json/png).

    PYTHONPATH=src python examples/policy_comparison.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import policy_frontier, render_frontier_dashboard
from repro.core.workload import OEM_CASE_1, OEM_CASE_2


def main():
    for case, paper_boosted_kwh in ((OEM_CASE_1, 44.3), (OEM_CASE_2, 67.5)):
        print(f"=== {case.name}: measured baseline "
              f"{case.measured_hours} h, {case.measured_kwh} kWh")
        res = policy_frontier(case)
        for r in res:
            print(f"  {r.policy:30s} {r.runtime_h:8.2f} h {r.energy_kwh:7.2f} kWh"
                  f"  dT={r.runtime_delta_pct:+6.2f}%  dE={r.energy_delta_pct:+6.2f}%"
                  f"  CO2e={r.co2_kg:5.1f} kg")
        boosted = next(r for r in res if "boosted" in r.policy)
        print(f"  -> boosted off-hours: {boosted.energy_kwh:.1f} kWh "
              f"(paper: ~{paper_boosted_kwh}); paper claim (-9%, +7%), "
              f"ours ({boosted.energy_delta_pct:+.1f}%, "
              f"{boosted.runtime_delta_pct:+.1f}%)")
        render_frontier_dashboard(
            res, f"experiments/frontier/{case.name}",
            title=f"policy frontier — {case.name}")
        print(f"  dashboard -> experiments/frontier/{case.name}/")
        print()


if __name__ == "__main__":
    main()
