"""Request-level carbon-aware scheduling across the four load shapes.

Walks the serving layer end to end: generate a synthetic arrival stream
(`random` / `linear` / `peak` / `camel`), schedule one 24 h window with
each policy (carbon-blind FIFO, the carbon-gated greedy, the
CEM-optimized assignment), execute the admitted demand through the
compiled trace engine, and compare CO2 at equal SLO attainment.

    PYTHONPATH=src python examples/request_scheduling.py
    PYTHONPATH=src python examples/request_scheduling.py --n 200000

Set CARINA_EXAMPLE_FAST=1 for the CI smoke mode (fewer requests, two
shapes, no CEM policy).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.carina as carina

FAST = bool(int(os.environ.get("CARINA_EXAMPLE_FAST", "0")))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000 if FAST else 20000,
                    help="requests per 24 h window")
    ap.add_argument("--service-rate", type=float, default=None,
                    help="scenarios/s at full intensity (default: sized "
                         "for ~55%% window utilization)")
    args = ap.parse_args()
    # keep utilization constant as --n scales so the comparison stays fair
    rate = args.service_rate or args.n * 3e-5

    # the paper's Midwest grid: clean overnight, dirtiest early evening
    carbon = carina.HourlySignal(tuple(
        float(v) * carina.DTE_FACTOR for v in carina.MIDWEST_HOURLY))
    shapes = ("random", "peak") if FAST else carina.LOAD_SHAPES
    policies = ("fifo", "greedy") if FAST else ("fifo", "greedy", "optimized")

    print(f"{args.n} requests/window, service rate {rate:g}/s, "
          f"policies: {', '.join(policies)}\n")
    for shape in shapes:
        print(f"== load shape: {shape} ==")
        base_co2 = None
        for policy in policies:
            sess = carina.ServingSession(
                policy=policy, carbon=carbon, start_hour=6.0,
                service_rate=rate, seed=0)
            # windows start 6 am: the evening hump of `camel` (and the
            # late `peak`) can defer into the clean overnight hours
            sess.submit(n=args.n, shape=shape, seed=42,
                        slack_h=(4.0, 12.0), camel_fracs=(0.2, 0.55),
                        tier_mix=(0.8, 0.15, 0.05))
            rep = sess.tick()
            saved = ""
            if policy == "fifo":
                base_co2 = rep.co2_kg
            elif base_co2:
                saved = (f"  ({(1 - rep.co2_kg / base_co2) * 100:.1f}% "
                         f"CO2 saved vs fifo)")
            print(f"  {policy:9s} admitted {rep.n_admitted:6d}  "
                  f"rejected {rep.n_rejected:4d}  degraded "
                  f"{rep.n_degraded:4d}  SLO-miss {rep.slo_miss_rate:6.2%}  "
                  f"{rep.energy_kwh:7.3f} kWh  {rep.co2_kg:7.4f} kg{saved}")
        print()

    st = carina.scan_stats()
    print(f"scan stats: {st.requests_seen} requests seen, "
          f"{st.requests_admitted} admitted, {st.requests_rejected} "
          f"rejected, {st.requests_degraded} degraded, "
          f"{st.chunks} chunk launches, {st.jit_compiles} jit shapes")


if __name__ == "__main__":
    main()
