"""Measured-run calibration + the zone sweep axis.

The acceptance pins of the grid-data/calibration subsystem:

* round trip — simulate OEM-style campaigns with *known* model
  parameters, log them through `RunTracker`, and `Campaign.calibrate`
  recovers every fitted parameter within 2% (both the jax Adam path and
  the NumPy finite-difference fallback), with seeded bootstrap CIs and
  emission-factor provenance carried through;
* zone sweeps — `Campaign.sweep(zones=<3-zone archive>)` matches the
  three per-zone sweeps bitwise, goes through the persistent plan cache
  (disk_hits pinned on a warm re-sweep), and the `window_h` variant
  yields the full (S, E, zone) ensemble grid; `Fleet.sweep(zones=...)`
  expands assignments the same way.

Plus the tracker-log hardening that calibration leans on: schema
version stamping, torn/truncated/foreign lines skipped on load.
"""
import json
import os

import numpy as np
import pytest

from repro.core import (BASELINE, PEAK_AWARE_BOOSTED, Campaign, Decision,
                        Fleet, GridCarbonModel, MIDWEST_HOURLY,
                        MachineProfile, OEMWorkload, RunTracker, UnitRecord,
                        constant_schedule, load_sample_archive, load_units)
from repro.core.calibrate import (FIT_PARAMS, CalibrationObjective,
                                  observations_from_units)
from repro.core.tracker import SCHEMA_VERSION

jax = pytest.importorskip("jax")
from repro.core import engine_jax  # noqa: E402


# Ground-truth physics the measured run executes under; the fit starts
# from a wrong-but-plausible prior (default machine, rate_at_full=3.0).
TRUTH = {"rate_at_full": 3.4, "gamma": 0.65, "idle_w": 95.0,
         "dyn_w": 260.0, "overhead_w_frac": 0.45}


class Excite:
    """Identification schedule: walks intensity over [0.3, 1.0] and
    alternates small/large batches so every fitted parameter is excited
    (constant-u logs leave gamma/overhead_w_frac unidentifiable)."""
    name = "excite"

    def decide(self, ctx):
        h = int(ctx.hour_of_day)
        u = 0.3 + 0.7 * ((h * 7) % 24) / 23.0
        return Decision(u, batch_size=8 if h % 2 else 32)


def _carbon():
    # an hourly curve forces simulate_campaign onto the hourly segment
    # grid -> ~1 logged unit per hour; zone/source exercise provenance
    return GridCarbonModel(hourly_curve=MIDWEST_HOURLY, zone="US-MISO",
                           source="sample")


@pytest.fixture(scope="module")
def measured_log(tmp_path_factory):
    """Run the TRUTH campaign once, tracked; yield its units.jsonl dir."""
    out = str(tmp_path_factory.mktemp("measured"))
    wl = OEMWorkload("truth", 150_000, rate_at_full=TRUTH["rate_at_full"],
                     batch_overhead_s=2.0)
    m = MachineProfile(idle_w=TRUTH["idle_w"], dyn_w=TRUTH["dyn_w"],
                       gamma=TRUTH["gamma"],
                       overhead_w_frac=TRUTH["overhead_w_frac"])
    report = Campaign(wl, Excite(), m, carbon=_carbon(),
                      out_dir=out).run(track=True, render=False)
    assert report.summary is not None and report.summary.units >= 20
    return out


def _nominal(out_dir):
    wl = OEMWorkload("nominal", 150_000, rate_at_full=3.0,
                     batch_overhead_s=2.0)
    return Campaign(wl, Excite(), MachineProfile(), carbon=_carbon(),
                    out_dir=out_dir)


# ----------------------------------------------------------------------
# the round-trip pin
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jax", "numpy"])
def test_round_trip_recovers_truth(measured_log, backend):
    cm = _nominal(measured_log).calibrate(backend=backend)
    assert cm.backend == backend
    assert cm.fit == FIT_PARAMS and cm.n_units >= 20
    errs = cm.rel_error(TRUTH)
    assert set(errs) == set(FIT_PARAMS)
    assert max(errs.values()) < 0.02, errs          # the acceptance bar
    # provenance rides along: where the log came from, which grid zone
    assert cm.source == os.path.join(measured_log, "units.jsonl")
    assert cm.zone == "US-MISO"
    assert cm.init["rate_at_full"] == pytest.approx(3.0)
    # the recorded history is the monotone best-so-far loss curve
    assert cm.history[-1] <= cm.history[0]
    assert cm.loss < 1e-4


def test_bootstrap_cis_bracket_the_fit(measured_log):
    cm = _nominal(measured_log).calibrate(backend="numpy", bootstrap=4,
                                          seed=3)
    assert set(cm.ci) == set(FIT_PARAMS)
    for f, (lo, hi) in cm.ci.items():
        assert lo <= hi
        assert lo <= cm.params[f] * 1.05 and hi >= cm.params[f] * 0.95
    # seeded: same bootstrap seed -> identical intervals
    cm2 = _nominal(measured_log).calibrate(backend="numpy", bootstrap=4,
                                           seed=3)
    assert cm2.ci == cm.ci


def test_apply_updates_campaign_physics(measured_log):
    c = _nominal(measured_log)
    wl0, m0 = c.calibrated()
    cm = c.calibrate(backend="numpy", apply=True)
    wl1, m1 = c.calibrated()
    assert wl1.rate_at_full == pytest.approx(TRUTH["rate_at_full"],
                                             rel=0.02)
    assert m1.gamma == pytest.approx(TRUTH["gamma"], rel=0.02)
    assert m1.alpha == m0.alpha                    # not in the fit set
    assert wl0.rate_at_full == pytest.approx(3.0)  # original untouched
    assert cm.params.keys() == set(FIT_PARAMS)


def test_calibrate_from_live_units(measured_log):
    units = load_units(os.path.join(measured_log, "units.jsonl"))
    cm = _nominal(None).calibrate(units=units, backend="numpy", steps=300)
    assert max(cm.rel_error(TRUTH).values()) < 0.05
    assert cm.source is None                       # no disk round-trip


def test_calibrate_without_a_run_is_an_error(tmp_path):
    with pytest.raises(ValueError, match="measured run"):
        Campaign(OEMWorkload("x", 1000, rate_at_full=1.0,
                             batch_overhead_s=2.0)).calibrate()
    with pytest.raises(ValueError, match="measured run"):
        Campaign(OEMWorkload("x", 1000, rate_at_full=1.0,
                             batch_overhead_s=2.0),
                 out_dir=str(tmp_path)).calibrate()   # no units.jsonl yet


# ----------------------------------------------------------------------
# objective/observation plumbing
# ----------------------------------------------------------------------
def _unit(i, phase="night", intensity=0.8, runtime_s=3600.0,
          energy_kwh=0.2, scen=5000.0, batch=32):
    return UnitRecord(i, phase, intensity, runtime_s, energy_kwh, 0.05,
                      float(i), {"scenarios": scen, "batch": batch})


def test_observation_lifting_drops_junk_units():
    units = [_unit(0),
             _unit(1, runtime_s=0.0),              # no wall time
             _unit(2, phase="maintenance"),        # unknown band
             _unit(3, scen=0.0),                   # no scenario count
             _unit(4, energy_kwh=0.0),             # no energy reading
             _unit(5, phase="peak")]
    obs = observations_from_units(units)
    assert obs.n == 2
    assert obs.background.tolist() == [0.02, 0.65]  # night, peak
    assert obs.scen_per_s[0] == pytest.approx(5000.0 / 3600.0)
    assert obs.p_avg_w[0] == pytest.approx(0.2 * 3.6e6 / 3600.0)
    assert obs.weight.sum() == pytest.approx(1.0)
    with pytest.raises(ValueError, match="no calibratable units"):
        observations_from_units([_unit(0, runtime_s=0.0)])


def test_objective_rejects_bad_fit_sets():
    obs = observations_from_units([_unit(0)])
    wl = OEMWorkload("w", 1000, rate_at_full=2.0, batch_overhead_s=2.0)
    with pytest.raises(ValueError, match="unknown fit parameter"):
        CalibrationObjective(obs, wl, MachineProfile(), fit=("alpha_w",))
    wl0 = OEMWorkload("w", 1000, rate_at_full=0.0, batch_overhead_s=2.0)
    with pytest.raises(ValueError, match="zero initial"):
        CalibrationObjective(obs, wl0, MachineProfile())
    # p = 0 decodes to exactly the configured starting values
    o = CalibrationObjective(obs, wl, MachineProfile())
    th = o.theta(np.zeros(len(o.fit)))
    assert th["rate_at_full"] == 2.0
    assert th["idle_w"] == MachineProfile().idle_w


# ----------------------------------------------------------------------
# tracker hardening the calibration loop leans on
# ----------------------------------------------------------------------
def test_units_carry_schema_and_provenance(measured_log):
    units = load_units(os.path.join(measured_log, "units.jsonl"))
    assert units and all(r.schema == SCHEMA_VERSION for r in units)
    assert all(r.meta.get("zone") == "US-MISO" for r in units)
    assert all(r.meta.get("source") == "sample" for r in units)


def test_tracker_meta_records_emission_factor(tmp_path):
    t = RunTracker("t", carbon=_carbon(),
                   log_path=str(tmp_path / "u.jsonl"))
    s = t.close()
    assert s.meta["carbon_zone"] == "US-MISO"
    assert s.meta["carbon_source"] == "sample"
    assert s.meta["carbon_factor_kg_per_kwh"] > 0.0


def test_load_units_tolerates_torn_and_foreign_lines(tmp_path):
    p = tmp_path / "log.jsonl"
    good = _unit(0).to_json()
    newer = dict(json.loads(good), schema=99, future_field="?")
    lines = [good,
             good[: len(good) // 2],               # torn mid-write
             json.dumps({"index": 1, "phase": "night"}),   # truncated
             json.dumps(["not", "a", "record"]),   # wrong shape
             json.dumps({"summary": {"units": 1}}),  # clean close() line
             json.dumps(newer),                    # newer schema, extra key
             _unit(2).to_json()]
    p.write_text("\n".join(lines) + "\n")
    units = load_units(str(p))
    assert [u.index for u in units] == [0, 0, 2]
    assert units[1].schema == 99                   # preserved, not dropped
    assert not hasattr(units[1], "future_field")


# ----------------------------------------------------------------------
# the zone axis: (S, zone) and (S, E, zone) sweeps
# ----------------------------------------------------------------------
SCHEDS = [constant_schedule(0.4), constant_schedule(0.85),
          PEAK_AWARE_BOOSTED]


@pytest.fixture(scope="module")
def arch():
    return load_sample_archive("grid_week_3z.csv")   # DE, SE-SE3, US-MISO


def _sweep_campaign(cache_dir=None):
    wl = OEMWorkload("zsweep", 40_000, rate_at_full=2.3,
                     batch_overhead_s=2.0)
    return Campaign(wl, cache_dir=cache_dir)


def _key(r):
    return (r.runtime_h, r.energy_kwh, r.co2_kg)


def test_zone_sweep_matches_per_zone_bitwise(arch, tmp_path):
    engine_jax.clear_plan_cache()
    c = _sweep_campaign(cache_dir=str(tmp_path))
    rows = c.sweep(SCHEDS, zones=arch)
    labels = [f"{s.name}@{z}" for z in arch.zones for s in SCHEDS]
    assert [r.policy for r in rows] == labels
    for z in arch.zones:
        solo = _sweep_campaign().sweep(SCHEDS,
                                       carbon_trace=arch[z].to_trace())
        batched = [r for r in rows if r.policy.endswith(f"@{z}")]
        assert [_key(a) for a in batched] == [_key(b) for b in solo]

    # warm re-sweep: drop the in-process memo (counters too, disk kept),
    # so every plan must come back from the persistent cache
    engine_jax.clear_plan_cache()
    warm = _sweep_campaign(cache_dir=str(tmp_path)).sweep(SCHEDS,
                                                          zones=arch)
    st = engine_jax.scan_stats()
    assert st.disk_hits == 9 and st.disk_misses == 0
    assert [_key(a) for a in warm] == [_key(b) for b in rows]


def test_zone_ensemble_sweep_is_s_e_zone(arch):
    rows = _sweep_campaign().sweep(SCHEDS, zones=arch, window_h=48,
                                   stride_h=24)
    assert len(rows) == len(SCHEDS) * 3
    for r in rows:
        assert r.co2_ensemble is not None
        assert len(r.co2_ensemble.samples) == 6    # (168-48)/24 + 1
        assert r.co2_ensemble.lo <= r.co2_kg <= r.co2_ensemble.hi


def test_zone_argument_validation(arch):
    c = _sweep_campaign()
    with pytest.raises(ValueError, match="only one of"):
        c.sweep(SCHEDS, zones=arch, carbon_trace=[0.4] * 48)
    with pytest.raises(ValueError, match="need zones="):
        c.sweep(SCHEDS, window_h=48)
    with pytest.raises(TypeError, match="zones="):
        c.sweep(SCHEDS, zones=[0.4] * 48)
    with pytest.raises(ValueError, match="at least one zone"):
        c.sweep(SCHEDS, zones={})


def test_zone_mapping_accepts_raw_series():
    zones = {"FLAT": [0.5] * 72, "RAMP": list(np.linspace(0.2, 0.8, 72))}
    rows = _sweep_campaign().sweep([BASELINE], zones=zones)
    assert [r.policy for r in rows] == ["baseline@FLAT", "baseline@RAMP"]
    assert rows[0].co2_kg != rows[1].co2_kg


def test_fleet_zone_sweep_expands_assignments(arch):
    wl_a = OEMWorkload("a", 30_000, rate_at_full=2.3, batch_overhead_s=2.0)
    wl_b = OEMWorkload("b", 45_000, rate_at_full=2.3, batch_overhead_s=2.0)
    fleet = Fleet([Campaign(wl_a), Campaign(wl_b)])
    out = fleet.sweep([BASELINE], zones=arch)
    assert [fr.policy for fr in out] == [f"baseline@{z}"
                                         for z in arch.zones]
    for fr in out:
        assert len(fr.campaigns) == 2
    solo = Fleet([Campaign(wl_a), Campaign(wl_b)]).sweep(
        [BASELINE], carbon_trace=arch["DE"].to_trace())
    assert [_key(r) for r in out[0].campaigns] == \
        [_key(r) for r in solo[0].campaigns]
    with pytest.raises(ValueError, match="only one of"):
        fleet.sweep([BASELINE], zones=arch, carbon_trace=[0.4] * 48)
