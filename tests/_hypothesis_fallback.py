"""Deterministic stand-in for the subset of the `hypothesis` API this test
suite uses, installed by conftest.py only when the real package is missing
(the container cannot pip-install).  Not a property-based testing engine:
each @given test runs a fixed number of pseudo-random examples from a
seeded generator (plus the interval endpoints for scalar strategies), with
no shrinking.  If real hypothesis is available it is always preferred.
"""
from __future__ import annotations

import functools
import random
import types

_MAX_EXAMPLES_CAP = 25   # keep fallback suite runtime bounded
_SEED = 0xCA51A


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)


def _floats(min_value=0.0, max_value=1.0, **_kw):
    lo, hi = float(min_value), float(max_value)

    def draw(rnd):
        r = rnd.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return lo + (hi - lo) * rnd.random()

    return _Strategy(draw)


def _integers(min_value=0, max_value=100, **_kw):
    return _Strategy(lambda rnd: rnd.randint(int(min_value), int(max_value)))


def _booleans():
    return _Strategy(lambda rnd: rnd.random() < 0.5)


def _sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rnd: seq[rnd.randrange(len(seq))])


def _just(value):
    return _Strategy(lambda rnd: value)


def _lists(elements, min_size=0, max_size=10, **_kw):
    def draw(rnd):
        n = rnd.randint(int(min_size), int(max_size))
        return [elements.example(rnd) for _ in range(n)]

    return _Strategy(draw)


def _tuples(*elems):
    return _Strategy(lambda rnd: tuple(e.example(rnd) for e in elems))


strategies = types.SimpleNamespace(
    floats=_floats, integers=_integers, booleans=_booleans,
    sampled_from=_sampled_from, just=_just, lists=_lists, tuples=_tuples)


class _Unsatisfied(Exception):
    pass


def assume(condition):
    if not condition:
        raise _Unsatisfied
    return True


def given(*garg_strategies, **gkw_strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_max_examples", _MAX_EXAMPLES_CAP),
                    _MAX_EXAMPLES_CAP)
            rnd = random.Random(_SEED)
            ran = 0
            attempts = 0
            while ran < n and attempts < 10 * n:
                attempts += 1
                drawn = [s.example(rnd) for s in garg_strategies]
                kw = {k: s.example(rnd) for k, s in gkw_strategies.items()}
                try:
                    fn(*args, *drawn, **kwargs, **kw)
                except _Unsatisfied:
                    continue
                ran += 1
            if ran == 0:
                raise AssertionError(
                    "hypothesis fallback: assume() rejected every example; "
                    "the property was never exercised")
        # pytest resolves fixtures through __wrapped__'s signature; the
        # drawn parameters must not be mistaken for fixtures
        del wrapper.__wrapped__
        wrapper.hypothesis_fallback = True
        return wrapper
    return decorate


def settings(max_examples=_MAX_EXAMPLES_CAP, deadline=None, **_kw):
    def decorate(fn):
        # works whether applied above or below @given: the attribute is
        # copied onto the wrapper by functools.wraps (below) or set on the
        # wrapper directly (above)
        fn._max_examples = int(max_examples)
        return fn
    return decorate


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"
