"""Fleet: site-level joint scheduling (the PR-5 api_redesign bar).

* uncoupled parity: `Fleet.sweep` with no site cap is bitwise-identical
  to M independent `Campaign.sweep` calls, pinned on the chunked trace
  path, and grouping alone never changes results;
* coupled correctness: the grouped-lane kernel matches the sequential
  per-slot oracle (`simulate_fleet`) to <0.5 % under an active cap,
  across allocation families and backends, and site peaks agree;
* joint optimization: `Fleet.optimize` under a shared cap + per-campaign
  deadlines produces site CO2 <= the independently-optimized
  per-campaign schedules evaluated under the same cap (two-OEM example);
* satellites: `scan_stats(reset=True)` + plan-cache hits across two
  identical fleet sweeps, grouped-lane counting, duplicate-name dedupe /
  empty-sequence errors in Campaign and Fleet sweeps, `trace_windows`
  edge cases, and the dashboard's ensemble + site-rollup rendering.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import (BASELINE, Campaign, Fleet, GridCarbonModel,
                        MachineProfile, PEAK_AWARE_BOOSTED, Site, SweepCase,
                        TraceSignal, calibrate_workload, carbon_gated_cap,
                        constant_schedule, deadline_weighted_split,
                        proportional_split, site_throttle, trace_windows)
from repro.core.engine_jax import (compile_plan, execute_plan,
                                   reset_scan_stats, scan_stats)
from repro.core.fleet import fleet_sweep, simulate_fleet
from repro.core.schedule import dedupe_names
from repro.core.workload import OEM_CASE_1, OEM_CASE_2


@pytest.fixture(scope="module")
def calibrated():
    wl1, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    wl2 = dataclasses.replace(OEM_CASE_2, rate_at_full=wl1.rate_at_full)
    return wl1, wl2, m


@pytest.fixture(scope="module")
def campaigns():
    return [Campaign(OEM_CASE_1), Campaign(OEM_CASE_2)]


def _week_trace(scale: float = 0.448, seed: int = 7) -> TraceSignal:
    rng = np.random.RandomState(seed)
    h = np.arange(168)
    vals = scale * (1.0 + 0.30 * np.sin(2 * np.pi * h / 24.0)
                    + 0.05 * rng.randn(168))
    return TraceSignal(tuple(float(v) for v in vals), name=f"week{seed}")


# ---------------------------------------------------------------------------
# The coupling model
# ---------------------------------------------------------------------------
def test_site_throttle_step_semantics():
    """One fixed-point step: free headroom keeps f=1; a binding cap
    scales the sheddable component; an unreachable cap pins the floor;
    an uncapped site is inert.  With base_kw=0 the step degenerates to
    plain demand-proportional curtailment."""
    assert site_throttle(2.0, 0.0, 3.0) == 1.0     # headroom free: no cut
    assert abs(site_throttle(4.0, 0.0, 3.0) - 0.75) < 1e-12
    # sheddable-aware: base 2 kW is not sheddable, so meeting headroom 3
    # of a 4 kW draw needs the sheddable 2 kW cut in half
    assert abs(site_throttle(4.0, 2.0, 3.0) - 0.5) < 1e-12
    assert site_throttle(100.0, 0.0, 0.5) == 0.05  # floor: never deadlock
    assert site_throttle(5.0, 4.0, 2.0) == 0.05    # unreachable cap
    assert site_throttle(1.0, 0.5, math.inf) == 1.0   # uncapped site
    out = site_throttle(np.array([2.0, 4.0, 100.0]), 0.0, 3.0, xp=np)
    assert np.allclose(out, [1.0, 0.75, 0.05])
    # damped: the factor compounds across steps through f
    assert abs(site_throttle(4.0, 2.0, 3.0, f=0.5) - 0.25) < 1e-12


# ---------------------------------------------------------------------------
# Uncoupled parity (acceptance: bitwise on the chunked path)
# ---------------------------------------------------------------------------
def test_uncoupled_fleet_bitwise_matches_independent_sweeps(campaigns):
    """Fleet([c1, c2]).sweep with no site cap must equal two independent
    Campaign.sweep calls bit for bit — pinned on the chunked trace path
    (a week-long carbon trace forces every case onto it)."""
    c1, c2 = campaigns
    trace = _week_trace()
    scheds = [BASELINE, PEAK_AWARE_BOOSTED]
    fleet = Fleet([c1, c2], Site(carbon=trace))
    fres = fleet.sweep(scheds)
    ind = [c.sweep(scheds, carbon_trace=trace) for c in (c1, c2)]
    for i, fr in enumerate(fres):
        for m, r in enumerate(fr.campaigns):
            assert r.runtime_h == ind[m][i].runtime_h
            assert r.energy_kwh == ind[m][i].energy_kwh
            assert r.co2_kg == ind[m][i].co2_kg
        assert fr.site.runtime_h == max(r.runtime_h for r in fr.campaigns)
        assert fr.site.energy_kwh == sum(r.energy_kwh for r in fr.campaigns)


def test_uncapped_grouping_is_bitwise_inert(calibrated):
    """group_sizes with an infinite cap must not perturb the scan: the
    grouped plan runs the exact ungrouped kernels."""
    wl1, wl2, m = calibrated
    trace = _week_trace()
    cases = [SweepCase(BASELINE, wl1, m, carbon=trace),
             SweepCase(PEAK_AWARE_BOOSTED, wl2, m, carbon=trace)]
    from repro.core.engine_jax import trace_sweep
    ref = trace_sweep(cases)
    grp = trace_sweep(cases, group_sizes=[2], group_caps_kw=[None])
    for a, b in zip(ref, grp):
        assert a.runtime_h == b.runtime_h
        assert a.energy_kwh == b.energy_kwh
        assert a.co2_kg == b.co2_kg


def test_campaign_as_fleet_is_the_m1_special_case(campaigns):
    c1, _ = campaigns
    scheds = [BASELINE, PEAK_AWARE_BOOSTED]
    solo = c1.sweep(scheds)
    f = c1.as_fleet().sweep(scheds)
    for a, fr in zip(solo, f):
        assert len(fr.campaigns) == 1
        assert fr.campaigns[0].runtime_h == a.runtime_h
        assert fr.campaigns[0].energy_kwh == a.energy_kwh


# ---------------------------------------------------------------------------
# Coupled correctness (acceptance: <0.5 % vs the per-slot oracle)
# ---------------------------------------------------------------------------
SITE = Site(power_cap_kw=0.40, office_kw=0.12)


def _fleet_cases(calibrated, schedules, deadlines=(0.0, 0.0), carbon=None):
    wl1, wl2, m = calibrated
    return [SweepCase(s, wl, m, SITE.bands, carbon or GridCarbonModel(),
                      9.0, deadline_h=d)
            for s, wl, d in zip(schedules, (wl1, wl2), deadlines)]


@pytest.mark.parametrize("backend", ["jax", "numpy"])
def test_grouped_engine_matches_oracle_under_cap(calibrated, backend):
    """Every bundled allocation family, coupled under an active cap:
    the grouped-lane scan agrees with the python per-slot oracle to
    <0.5 % on runtime/energy/CO2, and site peaks to <1 %."""
    if backend == "jax":
        from repro.core.engine_jax import _HAS_JAX
        if not _HAS_JAX:
            pytest.skip("jax not importable")
    dls = (300.0, 480.0)
    families = [
        proportional_split(0.8).for_fleet(2),
        carbon_gated_cap(0.45).for_fleet(2),
        deadline_weighted_split(dls).for_fleet(2),
        (PEAK_AWARE_BOOSTED, PEAK_AWARE_BOOSTED),
    ]
    for scheds in families:
        cases = _fleet_cases(calibrated, scheds, dls)
        eng = fleet_sweep([cases], SITE, backend=backend)[0]
        orc = simulate_fleet(cases, SITE)
        for e, o in zip(eng.campaigns, orc.campaigns):
            assert abs(e.runtime_h / o.runtime_h - 1) < 5e-3, e.policy
            assert abs(e.energy_kwh / o.energy_kwh - 1) < 5e-3, e.policy
            assert abs(e.co2_kg / o.co2_kg - 1) < 5e-3, e.policy
        assert abs(eng.site.peak_kw / orc.site.peak_kw - 1) < 1e-2


def test_cap_actually_bites_and_slows_the_fleet(calibrated):
    """A tight cap must curtail: coupled runtimes strictly exceed the
    uncoupled ones, and the site peak sits near the cap instead of at
    the free-running draw."""
    scheds = (BASELINE, BASELINE)
    cases = _fleet_cases(calibrated, scheds)
    free = fleet_sweep([cases], Site())[0]
    capped = fleet_sweep([cases], SITE)[0]
    for f, c in zip(free.campaigns, capped.campaigns):
        assert c.runtime_h > f.runtime_h * 1.05
    assert capped.site.peak_kw < 0.52   # demand would be well above


def test_finished_campaign_releases_headroom(calibrated):
    """When the small campaign finishes, the big one must speed up: its
    coupled runtime is shorter than if the small one ran forever (pinned
    by comparing against a doubled-workload small campaign)."""
    wl1, wl2, m = calibrated
    scheds = (BASELINE, BASELINE)
    base = fleet_sweep([_fleet_cases((wl1, wl2, m), scheds)], SITE)[0]
    wl1_big = dataclasses.replace(wl1, n_scenarios=wl1.n_scenarios * 4)
    longer = fleet_sweep([_fleet_cases((wl1_big, wl2, m), scheds)], SITE)[0]
    assert base.campaigns[1].runtime_h < longer.campaigns[1].runtime_h - 5.0


def test_coupled_groups_reject_mixed_start_hours(calibrated):
    wl1, wl2, m = calibrated
    cases = [SweepCase(BASELINE, wl1, m, start_hour=9.0),
             SweepCase(BASELINE, wl2, m, start_hour=17.0)]
    with pytest.raises(ValueError, match="start_hour"):
        compile_plan(cases, group_sizes=[2], group_caps_kw=[0.4])


# ---------------------------------------------------------------------------
# Joint optimization (acceptance: joint site CO2 <= independent optima)
# ---------------------------------------------------------------------------
def test_fleet_optimize_beats_independent_under_shared_cap(campaigns):
    """The two-OEM example: joint optimization under a shared cap and
    per-campaign deadlines must find site CO2 <= the independently-
    optimized per-campaign schedules evaluated under the same cap (the
    joint search warm-starts from them and keeps the best seen)."""
    c1, c2 = campaigns
    site = Site(power_cap_kw=0.40, office_kw=0.12)
    fleet = Fleet([c1, c2], site)
    dls = [300.0, 480.0]
    res = fleet.optimize("co2", deadlines=dls, candidates=32, iterations=4,
                         steps=40)
    assert len(res.schedules) == 2 and len(res.independent) == 2
    # evaluate the independent optima as a fleet under the same cap
    wl1, m1 = c1.calibrated()
    wl2, m2 = c2.calibrated()
    cases = [SweepCase(r.schedule, wl, mach, site.bands, GridCarbonModel(),
                       9.0, label=r.schedule.name, deadline_h=d)
             for r, (wl, mach), d in zip(res.independent,
                                         ((wl1, m1), (wl2, m2)), dls)]
    ind = fleet_sweep([cases], site, names=["independent"])[0]
    assert res.site.co2_kg <= ind.site.co2_kg + 1e-9
    # joint result is feasible and engine-reported
    for r, d in zip(res.results, dls):
        assert r.runtime_h <= d * 1.02
    assert res.site.peak_kw is not None
    assert float(np.max(res.metrics.unfinished)) < 1e-6


def test_fleet_objective_peak_constraint_plans_around_budget(calibrated):
    """Planning mode: no physical cap, but a site_peak_kw constraint —
    the optimizer must return a schedule whose (uncoupled) peak draw
    respects the budget that free-running baselines exceed."""
    from repro.core.optimize import optimize_fleet
    wl1, wl2, m = calibrated
    cases = [SweepCase(BASELINE, wl1, m, deadline_h=320.0),
             SweepCase(BASELINE, wl2, m, deadline_h=500.0)]
    budget = 0.52
    free = fleet_sweep([_fleet_cases((wl1, wl2, m), (BASELINE, BASELINE))],
                       Site(power_cap_kw=5.0))[0]
    assert free.site.peak_kw > budget    # baselines bust the budget
    res = optimize_fleet(cases, Site(), objective="co2",
                         constraints={"site_peak_kw": budget},
                         init=0.6, candidates=32, iterations=4, steps=60)
    assert float(res.metrics.site_peak_kw) <= budget * 1.02
    assert float(np.max(res.metrics.unfinished)) < 1e-6


# ---------------------------------------------------------------------------
# Satellites
# ---------------------------------------------------------------------------
def test_scan_stats_reset_and_plan_cache_hits_on_repeated_fleet_sweep(
        calibrated):
    """Two identical fleet sweeps: the second must hit the per-case
    compile cache for every case, and `scan_stats(reset=True)` must
    hand back the pre-reset snapshot while zeroing the live counters."""
    cases = _fleet_cases(calibrated, (BASELINE, PEAK_AWARE_BOOSTED))
    fleet_sweep([cases], SITE)               # warm the plan cache
    reset_scan_stats()
    fleet_sweep([cases], SITE)
    snap = scan_stats(reset=True)
    assert snap.plan_hits >= len(cases) and snap.plan_misses == 0
    assert snap.grouped_lanes > 0            # coupled kernel ran
    assert snap.chunks > 0
    after = scan_stats()
    assert after.slot_work == 0 and after.chunks == 0
    assert after.grouped_lanes == 0 and after.plan_hits == 0
    assert after.jit_compiles == 0


def test_grouped_lanes_counter_zero_for_plain_sweeps(calibrated):
    wl1, _, m = calibrated
    reset_scan_stats()
    from repro.core.engine_jax import trace_sweep
    trace_sweep([SweepCase(BASELINE, wl1, m, carbon=_week_trace())])
    assert scan_stats(reset=True).grouped_lanes == 0


def test_sweep_dedupes_duplicate_names_and_rejects_empty(campaigns):
    c1, _ = campaigns
    dup = [constant_schedule(0.5, name="same"),
           constant_schedule(0.9, name="same"),
           constant_schedule(0.7, name="same")]
    rows = c1.sweep(dup)
    assert [r.policy for r in rows] == ["same", "same#1", "same#2"]
    assert len({r.policy for r in rows}) == 3
    with pytest.raises(ValueError, match="at least one schedule"):
        c1.sweep([])
    with pytest.raises(ValueError, match="at least one schedule"):
        c1.frontier([])
    front = c1.frontier(dup)
    assert [r.policy for r in front] == ["same", "same#1", "same#2"]
    fleet = Fleet([c1])
    with pytest.raises(ValueError, match="at least one assignment"):
        fleet.sweep([])
    frows = fleet.sweep([constant_schedule(0.5, name="dup"),
                         constant_schedule(0.9, name="dup")])
    assert [fr.policy for fr in frows] == ["dup", "dup#1"]


def test_dedupe_names_helper():
    assert dedupe_names(["a", "b", "a", "a"]) == ["a", "b", "a#1", "a#2"]
    assert dedupe_names([]) == []


def test_trace_windows_edge_cases():
    series = np.arange(48.0)
    # window exactly the archive: one member
    ens = trace_windows(series, window_h=48)
    assert len(ens) == 1
    assert ens.member(0).values == tuple(series)
    # window longer than the archive: a clear error
    with pytest.raises(ValueError, match="shorter than one"):
        trace_windows(series, window_h=49)
    # stride > window: gaps are legal, members skip data between windows
    ens = trace_windows(series, window_h=12, stride_h=24)
    assert len(ens) == 2
    assert ens.member(1).values[0] == 24.0
    # non-integer-hour archive lengths (not a whole number of days)
    ens = trace_windows(np.arange(31.0), window_h=10, stride_h=7)
    assert len(ens) == 4
    assert ens.member(3).values == tuple(np.arange(21.0, 31.0))
    # invalid strides fail loudly
    with pytest.raises(ValueError, match="positive"):
        trace_windows(series, window_h=0)
    with pytest.raises(ValueError, match="positive"):
        trace_windows(series, window_h=12, stride_h=0)


def test_fleet_sweep_with_carbon_ensemble_rolls_up_site_stats(campaigns):
    """Ensemble + fleet: per-campaign rows carry EnsembleStats, and the
    site rollup sums per-member CO2 across campaigns (same member
    alignment), uncapped so the lanes stay independent."""
    c1, c2 = campaigns
    ens = trace_windows(np.asarray(_week_trace().values) * 1.0,
                        window_h=24 * 5, stride_h=24)
    fleet = Fleet([c1, c2])
    fr = fleet.sweep([BASELINE], carbon_ensemble=ens)[0]
    assert all(r.co2_ensemble is not None for r in fr.campaigns)
    assert fr.site.co2_ensemble is not None
    total = np.sum([r.co2_ensemble.samples for r in fr.campaigns], axis=0)
    assert abs(fr.site.co2_ensemble.mean - total.mean()) < 1e-12
    assert abs(fr.site.co2_kg
               - sum(r.co2_kg for r in fr.campaigns)) < 1e-9


def test_coupled_fleet_rejects_carbon_dependent_ensemble(calibrated):
    wl1, wl2, m = calibrated
    ens = trace_windows(np.asarray(_week_trace().values), window_h=24 * 5,
                        stride_h=48)
    scheds = carbon_gated_cap(0.45).for_fleet(2)
    cases = [SweepCase(s, wl, m, carbon=ens)
             for s, wl in zip(scheds, (wl1, wl2))]
    with pytest.raises(ValueError, match="cannot share a site cap"):
        compile_plan(cases, group_sizes=[2], group_caps_kw=[0.4])


def test_dashboard_renders_ensemble_whiskers_and_site_rollup(
        campaigns, tmp_path):
    from repro.core.dashboard import render_frontier_dashboard
    c1, c2 = campaigns
    ens = trace_windows(np.asarray(_week_trace().values), window_h=24 * 5,
                        stride_h=24)
    fleet = Fleet([c1, c2])
    frs = fleet.sweep([BASELINE, PEAK_AWARE_BOOSTED], carbon_ensemble=ens)
    rows = [r for fr in frs for r in fr.campaigns]
    md = render_frontier_dashboard(
        rows, str(tmp_path), title="fleet test",
        site_rollups=[(fr.policy, fr.site) for fr in frs])
    assert "±" in md and "…" in md          # mean ±std [q05…q95]
    assert "Site rollup" in md
    assert "makespan" in md
    assert (tmp_path / "frontier.md").exists()
    assert (tmp_path / "frontier.json").exists()
    # plain (no-ensemble) rows still render the point-value column
    md2 = render_frontier_dashboard(
        [dataclasses.replace(rows[0], co2_ensemble=None, summary=None)],
        str(tmp_path), title="plain")
    assert "±" not in md2


def test_site_validation():
    with pytest.raises(ValueError, match="power_cap_kw"):
        Site(power_cap_kw=-1.0)
    with pytest.raises(ValueError, match="office_kw"):
        Site(office_kw=-0.1)
    s = Site(power_cap_kw=0.5, office_kw=0.2)
    assert s.headroom_kw(3.0) > s.headroom_kw(15.0)   # office peaks midday
    assert Site().headroom_kw(12.0) == math.inf


def test_allocation_schedule_contract():
    from repro.core.schedule import (AllocationSchedule, SchedulingContext)
    a = deadline_weighted_split([100.0, 200.0])
    assert a.n_members() == 2
    with pytest.raises(ValueError, match="campaigns"):
        a.for_fleet(3)
    ctx = SchedulingContext(10.0, "shoulder", 0.15, 0.4, elapsed_h=50.0,
                            progress=0.1)
    d = a.decide_joint([ctx, ctx])
    assert len(d) == 2
    assert d[0].intensity >= d[1].intensity   # tighter deadline -> more urgent
    with pytest.raises(ValueError, match="at least one"):
        AllocationSchedule(())
    b = proportional_split(0.8)
    assert [s.name for s in b.for_fleet(3)].count("const_0.80") == 3
    assert b.decide(ctx).intensity == 0.8


def test_allocation_schedule_degenerate_contexts():
    """Edge contexts never yield NaN or out-of-range demands: zero
    active campaigns mid-horizon, a fully spent cap (site_headroom=0),
    and an office draw already past the cap (negative headroom)."""
    from repro.core.schedule import SchedulingContext
    allocs = (proportional_split(0.8),
              deadline_weighted_split([100.0, 200.0]),
              carbon_gated_cap(0.4))
    ctxs = (
        SchedulingContext(12.0, "shoulder", 0.5, 0.6, n_active=0,
                          site_power_kw=0.0),
        SchedulingContext(12.0, "shoulder", 0.5, 0.6, elapsed_h=10.0,
                          progress=0.5, site_power_kw=5.0,
                          site_headroom=0.0, n_active=2),
        SchedulingContext(12.0, "shoulder", 0.5, 0.6, site_power_kw=9.0,
                          site_headroom=-0.25, n_active=2),
    )
    for a in allocs:
        for ctx in ctxs:
            for d in a.decide_joint([ctx] * a.n_members()):
                assert math.isfinite(d.intensity)
                assert 0.0 <= d.intensity <= 1.0


def test_site_throttle_all_members_finished():
    """With every campaign finished the fleet draw collapses to the
    non-sheddable base: the RATE_EPS guard keeps the step at f=1 (no
    0/0), and a headroom below even the base pins the floor instead of
    dividing by zero — for negative headroom too (office past cap)."""
    assert site_throttle(2.0, 2.0, 3.0) == 1.0
    assert site_throttle(0.0, 0.0, 3.0) == 1.0
    assert site_throttle(2.0, 2.0, 1.0) == 0.05
    assert site_throttle(4.0, 1.0, -0.5) == 0.05
    out = site_throttle(np.array([0.0, 2.0]), np.array([0.0, 2.0]), 3.0,
                        xp=np)
    assert np.allclose(out, 1.0)


def test_fleet_all_campaigns_finish_mid_horizon(calibrated):
    """Shrink both workloads so the whole fleet completes well inside
    the horizon under an active cap: results stay finite, runtimes are
    real, and the site peak still honours the cap after the fleet goes
    idle (office-only draw)."""
    wl1, wl2, m = calibrated
    tiny = (dataclasses.replace(wl1, n_scenarios=wl1.n_scenarios // 60),
            dataclasses.replace(wl2, n_scenarios=wl2.n_scenarios // 60))
    cases = _fleet_cases((tiny[0], tiny[1], m), (BASELINE, BASELINE))
    res = fleet_sweep([cases], SITE)[0]
    for c in res.campaigns:
        assert math.isfinite(c.runtime_h) and 0.0 < c.runtime_h < 24.0
        assert math.isfinite(c.co2_kg) and c.co2_kg > 0
    assert res.site.peak_kw <= SITE.power_cap_kw * 1.05
    assert res.site.peak_kw >= SITE.office_kw
