"""Algorithm-1 support features: system auto-detection and unit-log
verification (resume/merge/verify)."""
import json
import os

from repro.core import GridCarbonModel, RunTracker
from repro.core.sysinfo import (chip_profile_from_host, detect_host,
                                machine_profile_from_host)
from repro.core.verify import verify_unit_log


def test_detect_host_fields():
    info = detect_host()
    assert info["cpus"] >= 1
    assert "jax_backend" in info


def test_machine_profile_autodetect():
    m = machine_profile_from_host()
    assert m.idle_w > 0 and m.dyn_w > m.idle_w * 0.5
    assert m.name.startswith("auto-")


def test_chip_profile_autodetect_defaults_v5e():
    c = chip_profile_from_host({"jax_device_kind": "cpu"})
    assert c.name == "tpu-v5e"
    c2 = chip_profile_from_host({"jax_device_kind": "TPU v4"})
    assert c2.name == "tpu-v4"


def test_verify_clean_log(tmp_path):
    log = tmp_path / "units.jsonl"
    t = RunTracker("v", log_path=str(log))
    for i in range(5):
        t.record_unit(phase="night", intensity=0.9, runtime_s=10.0,
                      energy_kwh=0.02, sim_time_h=float(i))
    t.close()
    rep = verify_unit_log(str(log))
    assert rep.ok, rep.errors
    assert rep.n_units == 5
    assert abs(rep.energy_kwh - 0.1) < 1e-9


def test_verify_detects_tampering(tmp_path):
    log = tmp_path / "units.jsonl"
    t = RunTracker("v", log_path=str(log))
    for i in range(3):
        t.record_unit(phase="peak", intensity=0.4, runtime_s=5.0,
                      energy_kwh=0.01, sim_time_h=float(i))
    t.close()
    lines = log.read_text().splitlines()
    rec = json.loads(lines[1])
    rec["co2_kg"] *= 2            # corrupt the carbon translation
    lines[1] = json.dumps(rec)
    log.write_text("\n".join(lines) + "\n")
    rep = verify_unit_log(str(log))
    assert not rep.ok
    assert any("carbon mismatch" in e for e in rep.errors)


def test_verify_detects_missing_units_vs_summary(tmp_path):
    log = tmp_path / "units.jsonl"
    t = RunTracker("v", log_path=str(log))
    for i in range(4):
        t.record_unit(phase="shoulder", intensity=0.9, runtime_s=5.0,
                      energy_kwh=0.01, sim_time_h=float(i))
    t.close()
    lines = log.read_text().splitlines()
    del lines[0]                  # lose a unit (simulated crash/partial copy)
    log.write_text("\n".join(lines) + "\n")
    rep = verify_unit_log(str(log))
    assert not rep.ok
    assert any("summary" in e for e in rep.errors)
