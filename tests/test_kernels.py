"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles in
kernels/ref.py, executed in interpret mode on CPU (assignment requirement).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.moe_gemm import grouped_gemm
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels.xent import blocked_xent

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,sq,sk,causal", [
    (2, 4, 2, 256, 256, True),
    (1, 4, 1, 128, 384, False),     # MQA, cross lengths
    (2, 2, 2, 200, 200, True),      # non-divisible (padding path)
    (1, 8, 8, 128, 128, True),      # MHA
])
def test_flash_attention_fwd(b, h, hkv, sq, sk, causal, dtype):
    d = 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    o, lse = flash_attention_fwd(q, k, v, causal=causal, interpret=True)
    oref, lseref = ref.flash_attention_lse_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lseref),
                               rtol=1e-3, atol=1e-3)


def test_flash_attention_vjp():
    b, sq, h, hkv, d = 2, 256, 4, 2, 64
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sq, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sq, hkv, d), jnp.float32)
    do = jax.random.normal(ks[3], (b, sq, h, d), jnp.float32)

    def f(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True, None, True) * do)

    def fr(q, k, v):
        o = ref.flash_attention_ref(q.transpose(0, 2, 1, 3),
                                    k.transpose(0, 2, 1, 3),
                                    v.transpose(0, 2, 1, 3), causal=True)
        return jnp.sum(o.transpose(0, 2, 1, 3) * do)

    g = jax.grad(f, (0, 1, 2))(q, k, v)
    gr = jax.grad(fr, (0, 1, 2))(q, k, v)
    for a, b_ in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,sk,length,ns", [
    (2, 8, 2, 1024, 700, 4),
    (1, 4, 4, 512, 512, 2),
    (2, 16, 1, 2048, 100, 8),       # MQA, mostly-masked
    (1, 8, 2, 300, 77, 3),          # non-divisible
])
def test_decode_attention(b, h, hkv, sk, length, ns, dtype):
    d = 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, hkv, d), dtype)
    o = decode_attention(q, k, v, length, nsplit=ns, interpret=True)
    oref = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,C,ch,bc", [
    (2, 256, 512, 64, 256),
    (1, 100, 300, 32, 128),         # non-divisible both dims
    (2, 64, 64, 64, 64),            # single chunk/block
])
def test_ssm_scan(B, T, C, ch, bc, dtype):
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (B, T, C), jnp.float32, 0.5, 1.0).astype(dtype)
    b = (jax.random.normal(ks[1], (B, T, C), jnp.float32) * 0.1).astype(dtype)
    hs, hf = ssm_scan(a, b, chunk=ch, block_c=bc, interpret=True)
    hsr, hfr = ref.ssm_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hsr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d", [(512, 1024), (100, 768), (64, 64)])
def test_rmsnorm(t, d, dtype):
    x = jax.random.normal(KEY, (t, d), dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (d,), dtype) * 0.1
    y = rmsnorm(x, s, interpret=True)
    yr = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,bpe,d,f", [(4, 2, 256, 512), (8, 1, 512, 384),
                                       (2, 3, 128, 100)])
def test_grouped_gemm(e, bpe, d, f, dtype):
    bm = 128
    t = e * bpe * bm
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (t, d), dtype)
    w = (jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.05).astype(dtype)
    block_ids = jnp.repeat(jnp.arange(e, dtype=jnp.int32), bpe)
    gsz = jnp.full((e,), bpe * bm, jnp.int32)
    o = grouped_gemm(x, w, block_ids, block_m=bm, interpret=True)
    oref = ref.grouped_gemm_ref(x, w, gsz)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t,d,v,bv", [(512, 256, 1000, 512),
                                      (300, 128, 5000, 2048),
                                      (64, 64, 100, 64)])
def test_blocked_xent_kernel(t, d, v, bv):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    emb = jax.random.normal(ks[1], (v, d), jnp.float32) * 0.5
    lab = jax.random.randint(ks[2], (t,), 0, v)
    nll = blocked_xent(x, emb, lab, block_v=bv, interpret=True)
    nllr = ref.blocked_xent_ref(x, emb, lab)
    np.testing.assert_allclose(np.asarray(nll), np.asarray(nllr),
                               rtol=1e-4, atol=1e-4)


def test_blocked_xent_xla_scan_matches_kernel_ref():
    """models/loss.py blocked CE (the XLA-scan twin) vs full-logits oracle,
    including gradients."""
    from repro.models.loss import blocked_cross_entropy, cross_entropy
    t, d, v = 128, 64, 1000
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    emb = jax.random.normal(ks[1], (v, d), jnp.float32) * 0.5
    lab = jax.random.randint(ks[2], (t,), 0, v)

    def f_blocked(x, emb):
        return blocked_cross_entropy(x, emb, lab, block=256)[0]

    def f_ref(x, emb):
        return cross_entropy(jnp.einsum("td,vd->tv", x, emb), lab)[0]

    np.testing.assert_allclose(f_blocked(x, emb), f_ref(x, emb), rtol=1e-5)
    g1 = jax.grad(f_blocked, (0, 1))(x, emb)
    g2 = jax.grad(f_ref, (0, 1))(x, emb)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_model_e2e_pallas_vs_xla_path():
    """Full tinyllama forward through the Pallas flash-attention dispatch
    (interpret mode on CPU) must match the XLA chunked path."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models import layers as L

    cfg = get_config("tinyllama-1.1b", smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                          cfg.vocab_size)}
    loss_xla, _ = m.loss(params, batch)
    L.set_kernel_mode("pallas")
    try:
        loss_pl, _ = m.loss(params, batch)
    finally:
        L.set_kernel_mode("xla")
    assert abs(float(loss_xla) - float(loss_pl)) < 2e-3, \
        (float(loss_xla), float(loss_pl))
