"""End-to-end behaviour tests for the full CARINA system: training under the
carbon-aware controller traverses time bands and produces consistent
accounting; the serving engine drains requests with per-request units; the
dashboard renders; loss decreases over a short real training run.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CarinaController, PEAK_AWARE_BOOSTED, RunTracker,
                        SimClock, StepCost, render_frontier_dashboard,
                        render_run_dashboard, policy_frontier)
from repro.core.workload import OEM_CASE_1
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.training.loop import LoopConfig, run_training


def test_training_loss_decreases():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    opt = AdamWConfig(total_steps=30, warmup_steps=3, peak_lr=2e-3)

    # repeat one batch -> loss must drop (memorization sanity)
    class Fixed(SyntheticLM):
        def batch_at(self, step):
            return super().batch_at(0)

    res = run_training(model, opt, Fixed(cfg, batch=4, seq=32),
                       LoopConfig(total_steps=30, steps_per_unit=10,
                                  log_every=1))
    losses = [m["loss"] for m in res.metrics_history]
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


def test_carbon_aware_training_accounting():
    """A campaign crossing all bands: tracked energy is positive, carbon =
    factor x energy, peak units run at lower intensity than night units."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    opt = AdamWConfig(total_steps=24, warmup_steps=2)
    data = SyntheticLM(cfg, batch=2, seq=16)
    tracker = RunTracker("e2e")
    ctrl = CarinaController(
        policy=PEAK_AWARE_BOOSTED, tracker=tracker, max_replicas=4,
        clock=SimClock(start_hour=13.5, speedup=3.0e4),
        step_cost=StepCost(flops=1e12, hbm_bytes=1e10, ici_bytes=1e8, chips=4))
    run_training(model, opt, data,
                 LoopConfig(total_steps=24, steps_per_unit=3),
                 controller=ctrl)
    s = tracker.summary()
    assert s.units == 8
    assert s.energy_kwh > 0
    assert abs(s.co2_kg - 0.448 * s.energy_kwh) < 1e-9
    by_band = {r.phase: r.intensity for r in tracker.records}
    if "peak" in by_band and "night" in by_band:
        assert by_band["peak"] < by_band["night"]


def test_dashboard_artifacts(tmp_path):
    tracker = RunTracker("dash")
    for i in range(5):
        tracker.record_unit(phase="night", intensity=1.0, runtime_s=60.0,
                            energy_kwh=0.01, sim_time_h=float(i))
    md = render_run_dashboard(tracker.summary(), str(tmp_path))
    assert "CARINA run dashboard" in md
    assert (tmp_path / "dashboard.json").exists()
    res = policy_frontier(OEM_CASE_1)
    md2 = render_frontier_dashboard(res, str(tmp_path))
    assert "baseline" in md2
    assert (tmp_path / "frontier.json").exists()


def test_serving_engine_with_carina_units():
    from repro.core import ServingSession
    from repro.serving.engine import ServingEngine
    cfg = get_config("tinyllama-1.1b", smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tracker = RunTracker("serve")
    sess = ServingSession(tracker=tracker, clock=SimClock(start_hour=3.0))
    eng = ServingEngine(m, params, slots=2, s_max=64, session=sess)
    for i in range(4):
        eng.submit(np.arange(4 + i, dtype=np.int32) % cfg.vocab_size,
                   max_new=3)
    done = eng.run_until_drained(100)
    assert len(done) == 4
    assert all(len(r.generated) == 3 for r in done)
    s = tracker.summary()
    assert s.units > 0 and s.energy_kwh > 0
    assert sess.live_units == s.units
    assert abs(sess.live_energy_kwh - s.energy_kwh) < 1e-12


def test_greedy_decode_deterministic():
    """Same prompt twice -> same generation (engine/caches are pure)."""
    from repro.serving.engine import ServingEngine
    cfg = get_config("tinyllama-1.1b", smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = ServingEngine(m, params, slots=1, s_max=64)
        eng.submit(np.arange(6, dtype=np.int32), max_new=5)
        done = eng.run_until_drained(50)
        outs.append(tuple(done[0].generated))
    assert outs[0] == outs[1]
