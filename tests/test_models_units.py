"""Model-layer unit/property tests: scan equivalences, MoE invariants,
rope properties, chunked attention == dense attention, param spec
consistency.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import build_model
from repro.models import layers as L
from repro.models import ssm as SSM
from repro.models import moe as MOE
from repro.models import param as P

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
def test_chunked_attention_equals_dense():
    b, s, h, hkv, d = 2, 512, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    dense = L.attention(q, k, v, causal=True, chunk_q=10_000)
    chunked = L.attention(q, k, v, causal=True, chunk_q=128)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


def test_windowed_attention_masks_far_tokens():
    b, s, h, d = 1, 64, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    w = 8
    out = L.attention(q, k, v, causal=True, window=w)
    # manual: last query attends only to last w keys
    s_full = jnp.einsum("bshd,bkhd->bhsk", q, k) / math.sqrt(d)
    mask = (jnp.arange(s)[None, :] <= s - 1) & (s - 1 - jnp.arange(s)[None, :] < w)
    s_last = jnp.where(mask, s_full[:, :, -1, :], -1e30)
    p = jax.nn.softmax(s_last, axis=-1)
    ref_last = jnp.einsum("bhk,bkhd->bhd", p, v)
    np.testing.assert_allclose(np.asarray(out[:, -1]).transpose(0, 1, 2),
                               np.asarray(ref_last).transpose(0, 1, 2),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 3), st.integers(4, 64), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_chunked_diag_scan_matches_naive(b, t, c):
    ks = jax.random.split(jax.random.PRNGKey(t * 31 + c), 2)
    a = jax.random.uniform(ks[0], (b, t, c), jnp.float32, 0.2, 1.0)
    bb = jax.random.normal(ks[1], (b, t, c)) * 0.3
    hs, hf = SSM.chunked_diag_scan(a, bb, chunk=8)
    h = jnp.zeros((b, c))
    outs = []
    for i in range(t):
        h = a[:, i] * h + bb[:, i]
        outs.append(h)
    ref = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(ref[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_assoc_scan_matches_chunked():
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (2, 37, 5), jnp.float32, 0.2, 1.0)
    b = jax.random.normal(ks[1], (2, 37, 5)) * 0.3
    hs1, hf1 = SSM.chunked_diag_scan(a, b, chunk=8)
    hs2, hf2 = SSM.assoc_diag_scan(a, b)
    np.testing.assert_allclose(np.asarray(hs1), np.asarray(hs2),
                               rtol=1e-5, atol=1e-5)


def test_mamba_train_decode_equivalence():
    """Step-by-step mamba decode == full-sequence mamba block."""
    cfg = get_config("falcon-mamba-7b", smoke=True)
    spec = SSM.mamba_spec(cfg)
    p = P.init_params(spec, KEY)
    b, s = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(5), (b, s, cfg.d_model),
                          jnp.float32)
    full = SSM.mamba_block(x, p, cfg, chunk=4)
    di = cfg.ssm.expand * cfg.d_model
    conv = jnp.zeros((b, cfg.ssm.d_conv - 1, di))
    h = jnp.zeros((b, di, cfg.ssm.d_state))
    outs = []
    for i in range(s):
        y, conv, h = SSM.mamba_decode(x[:, i:i + 1], p, cfg, conv, h)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(step, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_rglru_train_decode_equivalence():
    cfg = get_config("recurrentgemma-9b", smoke=True)
    spec = SSM.rglru_spec(cfg)
    p = P.init_params(spec, KEY)
    b, s = 2, 10
    w = cfg.rglru.lru_width or cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(6), (b, s, cfg.d_model), jnp.float32)
    full = SSM.rglru_block(x, p, cfg, chunk=4)
    conv = jnp.zeros((b, cfg.rglru.d_conv - 1, w))
    h = jnp.zeros((b, w))
    outs = []
    for i in range(s):
        y, conv, h = SSM.rglru_decode(x[:, i:i + 1], p, cfg, conv, h)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(step, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
def test_moe_capacity_drop_and_gating():
    """Tokens over capacity are dropped (output = shared-expert only);
    within capacity the output is a convex combination of expert outputs."""
    cfg = get_config("moonshot-v1-16b-a3b", smoke=True)
    spec = MOE.moe_spec(cfg)
    p = P.init_params(spec, KEY)
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(7), (b, s, cfg.d_model),
                          jnp.bfloat16)
    y, aux = MOE.moe_block(x, p, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) >= 0.0
    # tiny capacity: routed contribution vanishes for dropped tokens but
    # output stays finite (residual + shared experts)
    y2, _ = MOE.moe_block(x, p, cfg, capacity=1)
    assert bool(jnp.all(jnp.isfinite(y2.astype(jnp.float32))))


def test_moe_aux_loss_uniform_router_is_one():
    """With perfectly uniform routing probs, Switch aux = E*(1/E*...)*w -> w
    times 1 (balanced)."""
    cfg = get_config("moonshot-v1-16b-a3b", smoke=True)
    m = cfg.moe
    t = 64
    probs = jnp.full((t, m.num_experts), 1.0 / m.num_experts)
    me = probs.mean(0)
    ce = jnp.full((m.num_experts,), 1.0 / m.num_experts)
    aux = m.num_experts * jnp.sum(me * ce)
    assert abs(float(aux) - 1.0) < 1e-5


# ---------------------------------------------------------------------------
@given(st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_rope_preserves_norm(s):
    d = 16
    x = jax.random.normal(jax.random.PRNGKey(s), (1, s, 2, d))
    cos, sin = L.rope_cos_sin(jnp.arange(s), d, 10000.0)
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-4)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    d = 32
    q = jax.random.normal(jax.random.PRNGKey(1), (d,))
    k = jax.random.normal(jax.random.PRNGKey(2), (d,))

    def dot_at(m, n):
        cm, sm = L.rope_cos_sin(jnp.array([m]), d, 10000.0)
        cn, sn = L.rope_cos_sin(jnp.array([n]), d, 10000.0)
        qr = L.apply_rope(q[None, None, None, :], cm, sm)[0, 0, 0]
        kr = L.apply_rope(k[None, None, None, :], cn, sn)[0, 0, 0]
        return float(qr @ kr)

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-3


def test_param_spec_consistency():
    """abstract_params shapes == init_params shapes; logical axes ranks match."""
    for name in ("tinyllama-1.1b", "deepseek-v2-lite-16b", "whisper-small"):
        m = build_model(get_config(name, smoke=True))
        ab = m.abstract_params()
        ax = m.logical_axes()
        real = m.init(KEY)
        for a, r, x in zip(jax.tree.leaves(ab), jax.tree.leaves(real),
                           jax.tree.leaves(ax, is_leaf=lambda t: isinstance(t, tuple))):
            assert a.shape == r.shape
            assert len(x) == len(a.shape)


def test_blocked_xent_model_path():
    """cfg.blocked_xent=True must give the same loss as the dense path."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    m1 = build_model(cfg)
    m2 = build_model(dataclasses.replace(cfg, blocked_xent=True, vocab_block=64))
    params = m1.init(KEY)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    l1, _ = m1.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    assert abs(float(l1) - float(l2)) < 2e-3, (float(l1), float(l2))
