"""Scale-out engine tests: device-sharded execute_plan, per-plan
precision policy, Pallas coupled-throttle kernel, XLA flag profiles,
and the reentrant `enable_x64` compat shim.

Multi-device cases run in one amortized subprocess (the virtual CPU
device count is an XLA_FLAGS setting locked at first jax init); the
subprocess pins sharded-vs-single results bitwise (fp64) and to the
documented 1e-6 tolerance (mixed), including a coupled fleet sweep.
Everything else — Pallas interpret-mode parity <1e-9 against the jnp
coupled kernel on the fleet-oracle scenario, precision accuracy bounds,
scan_stats counters, fallback rules — runs in-process on one device.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (BASELINE, GridCarbonModel, MachineProfile,
                        PEAK_AWARE_BOOSTED, Site, SweepCase,
                        calibrate_workload, constant_schedule)
from repro.core.engine_jax import (_HAS_JAX, _group_cuts, _pad_lanes,
                                   _pad_pow2, compile_plan, execute_plan,
                                   reset_scan_stats, scan_stats,
                                   summarize_plan)
from repro.core.fleet import fleet_sweep, simulate_fleet
from repro.core.workload import OEM_CASE_1, OEM_CASE_2

pytestmark = pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
SITE = Site(power_cap_kw=0.40, office_kw=0.12)


@pytest.fixture(scope="module")
def calibrated():
    wl1, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    wl2 = dataclasses.replace(OEM_CASE_2, rate_at_full=wl1.rate_at_full)
    return wl1, wl2, m


def _uncoupled_cases(calibrated, n=6):
    wl1, wl2, m = calibrated
    scheds = [BASELINE, PEAK_AWARE_BOOSTED, constant_schedule(0.6),
              constant_schedule(0.8), constant_schedule(0.95),
              constant_schedule(0.7)]
    return [SweepCase(s, w, m, carbon=GridCarbonModel())
            for s, w in zip(scheds[:n], ([wl1, wl2] * 3)[:n])]


def _coupled_plan(calibrated, precision="fp64"):
    wl1, wl2, m = calibrated
    cases = [SweepCase(s, w, m, SITE.bands, GridCarbonModel(), 9.0)
             for s, w in zip((BASELINE, PEAK_AWARE_BOOSTED,
                              constant_schedule(0.8), BASELINE),
                             (wl1, wl2, wl1, wl2))]
    return compile_plan(cases, group_sizes=[2, 2],
                        group_caps_kw=[SITE.power_cap_kw] * 2,
                        group_office_kw=[SITE.office_kw] * 2,
                        precision=precision)


# ---------------------------------------------------------------------------
# Multi-device subprocess (bitwise fp64, documented-tolerance mixed)
# ---------------------------------------------------------------------------
def run_subprocess(code: str, devices: int = 8) -> str:
    from repro.core.xla_profiles import fanout_env
    env = fanout_env(devices)
    env["PYTHONPATH"] = SRC
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


def test_sharded_execute_plan_8_virtual_devices():
    """One amortized 8-virtual-device subprocess: (a) uncoupled sharded
    fp64 is bitwise-identical to single-device; (b) mixed precision stays
    within the documented 1e-6 relative tolerance on kWh/CO2, sharded or
    not; (c) a coupled fleet sweep shards bitwise at group granularity;
    (d) the devices_used counter reports the fan-out."""
    code = """
    import dataclasses, json
    import jax
    from repro.core import (BASELINE, GridCarbonModel, MachineProfile,
                            PEAK_AWARE_BOOSTED, Site, SweepCase,
                            calibrate_workload, constant_schedule)
    from repro.core.engine_jax import (compile_plan, execute_plan,
                                       reset_scan_stats, scan_stats,
                                       summarize_plan)
    from repro.core.workload import OEM_CASE_1, OEM_CASE_2

    wl1, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    wl2 = dataclasses.replace(OEM_CASE_2, rate_at_full=wl1.rate_at_full)
    scheds = [BASELINE, PEAK_AWARE_BOOSTED, constant_schedule(0.6),
              constant_schedule(0.8), constant_schedule(0.95),
              constant_schedule(0.7), BASELINE, PEAK_AWARE_BOOSTED]
    cases = [SweepCase(s, w, m, carbon=GridCarbonModel())
             for s, w in zip(scheds, [wl1, wl2] * 4)]
    out = {"n_devices": len(jax.devices())}

    plan = compile_plan(cases)
    r1 = summarize_plan(plan, execute_plan(plan, devices=1))
    reset_scan_stats()
    r8 = summarize_plan(plan, execute_plan(plan, devices=8))
    out["devices_used"] = scan_stats().devices_used
    out["uncoupled_bitwise"] = all(
        a.runtime_h == b.runtime_h and a.energy_kwh == b.energy_kwh
        and a.co2_kg == b.co2_kg for a, b in zip(r1, r8))

    pm = compile_plan(cases, precision="mixed")
    rm8 = summarize_plan(pm, execute_plan(pm, devices=8))
    out["mixed_rel"] = max(
        max(abs(a.energy_kwh - b.energy_kwh) / abs(a.energy_kwh),
            abs(a.co2_kg - b.co2_kg) / abs(a.co2_kg))
        for a, b in zip(r1, rm8))

    SITE = Site(power_cap_kw=0.40, office_kw=0.12)
    fc = [SweepCase(s, w, m, SITE.bands, GridCarbonModel(), 9.0)
          for s, w in zip((BASELINE, PEAK_AWARE_BOOSTED,
                           constant_schedule(0.8), BASELINE),
                          (wl1, wl2, wl1, wl2))]
    cp = compile_plan(fc, group_sizes=[2, 2],
                      group_caps_kw=[SITE.power_cap_kw] * 2,
                      group_office_kw=[SITE.office_kw] * 2)
    c1 = summarize_plan(cp, execute_plan(cp, devices=1))
    reset_scan_stats()
    c2 = summarize_plan(cp, execute_plan(cp, devices=2))
    out["coupled_devices_used"] = scan_stats().devices_used
    out["coupled_bitwise"] = all(
        a.runtime_h == b.runtime_h and a.energy_kwh == b.energy_kwh
        and a.co2_kg == b.co2_kg for a, b in zip(c1, c2))
    print(json.dumps(out))
    """
    out = json.loads(run_subprocess(code, devices=8).strip().splitlines()[-1])
    assert out["n_devices"] == 8
    assert out["uncoupled_bitwise"] is True
    assert out["devices_used"] == 8
    assert out["mixed_rel"] < 1e-6, out["mixed_rel"]
    assert out["coupled_bitwise"] is True
    assert out["coupled_devices_used"] == 2


# ---------------------------------------------------------------------------
# Precision policy (single device)
# ---------------------------------------------------------------------------
def test_compile_plan_rejects_unknown_precision(calibrated):
    with pytest.raises(ValueError):
        compile_plan(_uncoupled_cases(calibrated, 2), precision="fp16")


def test_mixed_precision_within_documented_tolerance(calibrated):
    """The per-plan mixed policy (fp32 per-slot physics, fp64 carried
    state + accumulators) keeps kWh/CO2 within 1e-6 relative of the
    exact-fp64 default, and the stats counter reports the mode."""
    cases = _uncoupled_cases(calibrated)
    plan = compile_plan(cases)
    ref = summarize_plan(plan, execute_plan(plan))
    pm = compile_plan(cases, precision="mixed")
    reset_scan_stats()
    got = summarize_plan(pm, execute_plan(pm))
    assert scan_stats().precision_mode == "mixed"
    for a, b in zip(ref, got):
        assert abs(a.energy_kwh - b.energy_kwh) / abs(a.energy_kwh) < 1e-6
        assert abs(a.co2_kg - b.co2_kg) / abs(a.co2_kg) < 1e-6


def test_fp64_default_reports_precision_mode(calibrated):
    plan = compile_plan(_uncoupled_cases(calibrated, 2))
    reset_scan_stats()
    execute_plan(plan, devices=1)
    st = scan_stats()
    assert st.precision_mode == "fp64"
    assert st.devices_used == 1
    assert st.pallas_dispatches == 0


def test_coupled_mixed_precision_tolerance(calibrated):
    ref_plan = _coupled_plan(calibrated)
    ref = summarize_plan(ref_plan, execute_plan(ref_plan))
    pm = _coupled_plan(calibrated, precision="mixed")
    got = summarize_plan(pm, execute_plan(pm))
    for a, b in zip(ref, got):
        assert abs(a.energy_kwh - b.energy_kwh) / abs(a.energy_kwh) < 1e-6
        assert abs(a.co2_kg - b.co2_kg) / abs(a.co2_kg) < 1e-6


# ---------------------------------------------------------------------------
# Pallas coupled-throttle kernel (interpret mode on CPU)
# ---------------------------------------------------------------------------
def test_pallas_matches_jnp_coupled_kernel(calibrated):
    """pallas="interpret" reproduces the jnp coupled kernel to <1e-9 on
    the fleet-oracle scenario (active shared cap, grouped lanes),
    including runtimes, and bumps the dispatch counter."""
    plan = _coupled_plan(calibrated)
    ref = summarize_plan(plan, execute_plan(plan, devices=1))
    reset_scan_stats()
    # Pallas covers the single-device coupled path only (with devices>1
    # the group-sharded jnp kernel wins) — pin devices=1
    got = summarize_plan(plan, execute_plan(plan, devices=1,
                                            pallas="interpret"))
    assert scan_stats().pallas_dispatches > 0
    for a, b in zip(ref, got):
        assert abs(a.energy_kwh - b.energy_kwh) <= 1e-9 * abs(a.energy_kwh)
        assert abs(a.co2_kg - b.co2_kg) <= 1e-9 * abs(a.co2_kg)
        assert abs(a.runtime_h - b.runtime_h) <= 1e-9 * abs(a.runtime_h)


def test_pallas_fleet_sweep_matches_oracle(calibrated):
    """End-to-end: `fleet_sweep(pallas="interpret")` agrees with the
    python per-slot oracle to <0.5% under an active cap — the same bar
    the jnp kernel is held to — and site peaks match the jnp path."""
    wl1, wl2, m = calibrated
    cases = [SweepCase(s, w, m, SITE.bands, GridCarbonModel(), 9.0)
             for s, w in zip((BASELINE, PEAK_AWARE_BOOSTED), (wl1, wl2))]
    jnp_res = fleet_sweep([cases], SITE, devices=1)[0]
    pal_res = fleet_sweep([cases], SITE, devices=1,
                          pallas="interpret")[0]
    orc = simulate_fleet(cases, SITE)
    for a, b in zip(pal_res.campaigns, orc.campaigns):
        assert abs(a.runtime_h / b.runtime_h - 1) < 5e-3
        assert abs(a.energy_kwh / b.energy_kwh - 1) < 5e-3
        assert abs(a.co2_kg / b.co2_kg - 1) < 5e-3
    assert abs(pal_res.site.peak_kw - jnp_res.site.peak_kw) < 1e-9


def test_pallas_policy_fallback(calibrated, monkeypatch):
    """Fallback rules: unavailable Pallas silently degrades to the jnp
    kernel; an unknown policy string raises; the uncoupled path never
    dispatches Pallas (the kernel only covers the coupled chunk)."""
    import repro.core.engine_jax as ej
    plan = _coupled_plan(calibrated)
    monkeypatch.setattr(ej, "_pallas_available", lambda: False)
    reset_scan_stats()
    execute_plan(plan, devices=1, pallas=True)   # degrades, must not raise
    assert scan_stats().pallas_dispatches == 0
    monkeypatch.undo()
    with pytest.raises(ValueError):
        execute_plan(plan, devices=1, pallas="bogus")
    up = compile_plan(_uncoupled_cases(calibrated, 2))
    reset_scan_stats()
    execute_plan(up, devices=1, pallas="interpret")
    assert scan_stats().pallas_dispatches == 0


# ---------------------------------------------------------------------------
# enable_x64 reentrancy (the compat-shim regression)
# ---------------------------------------------------------------------------
def test_enable_x64_nested_contexts_restore_correctly():
    import jax
    from repro.compat import enable_x64
    base = bool(jax.config.jax_enable_x64)
    with enable_x64(True):
        assert jax.config.jax_enable_x64 is True
        with enable_x64(False):
            assert jax.config.jax_enable_x64 is False
            with enable_x64(True):
                assert jax.config.jax_enable_x64 is True
            assert jax.config.jax_enable_x64 is False
        assert jax.config.jax_enable_x64 is True
    assert bool(jax.config.jax_enable_x64) == base


def test_enable_x64_out_of_order_exit():
    """A frame closed while a newer frame is still active (e.g. a
    generator finalized mid-context) must not clobber the live value,
    and the surviving frame must restore the elder's saved value."""
    import jax
    from repro.compat import enable_x64
    base = bool(jax.config.jax_enable_x64)
    outer = enable_x64(True)
    outer.__enter__()
    inner = enable_x64(False)
    inner.__enter__()
    outer.__exit__(None, None, None)      # out of order: outer dies first
    assert jax.config.jax_enable_x64 is False   # inner still governs
    inner.__exit__(None, None, None)
    assert bool(jax.config.jax_enable_x64) == base


def test_enable_x64_generator_finalization():
    import jax
    from repro.compat import enable_x64
    base = bool(jax.config.jax_enable_x64)

    def gen():
        with enable_x64(True):
            yield 1
            yield 2

    g = gen()
    next(g)
    with enable_x64(True):
        g.close()                         # finalize inside a newer frame
        assert jax.config.jax_enable_x64 is True
    assert bool(jax.config.jax_enable_x64) == base


# ---------------------------------------------------------------------------
# XLA flag profiles
# ---------------------------------------------------------------------------
def test_xla_profiles_render_and_env():
    from repro.core.xla_profiles import (fanout_env, fanout_flags,
                                         flags_string)
    s = flags_string("cpu_scan", base="")
    assert "--xla_cpu_enable_fast_math=false" in s
    assert flags_string("default", base="--keep=1") == "--keep=1"
    with pytest.raises(KeyError):
        flags_string("nope")
    with pytest.raises(ValueError):
        fanout_flags(0)
    env = fanout_env(8, base_env={})
    assert env["JAX_PLATFORMS"] == "cpu"
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
    # later flags win in XLA's parser: the fan-out override comes last
    env2 = fanout_env(4, base_env={"XLA_FLAGS": "--xla_cpu_enable_fast_math=true"})
    assert env2["XLA_FLAGS"].index("fast_math=true") \
        < env2["XLA_FLAGS"].index("fast_math=false")


def test_apply_profile_warns_after_jax_init():
    import jax
    from repro.core.xla_profiles import apply_profile
    jax.devices()                         # force backend init
    before = os.environ.get("XLA_FLAGS")
    try:
        with pytest.warns(RuntimeWarning):
            apply_profile("cpu_scan")
    finally:
        if before is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = before


# ---------------------------------------------------------------------------
# Lane/group partition helpers
# ---------------------------------------------------------------------------
def test_pad_lanes_matches_single_device_bucketing():
    for n in (1, 2, 5, 8, 13, 64, 100):
        assert _pad_lanes(n, 1) == _pad_pow2(n, minimum=8)
        for n_dev in (2, 4, 8):
            p = _pad_lanes(n, n_dev)
            assert p % n_dev == 0 and p >= n


def test_group_cuts_cover_and_balance():
    cnt = np.array([5, 1, 3, 7, 2, 2, 4, 1])
    for n_dev in (1, 2, 3, 4, 8):
        bounds = _group_cuts(cnt, n_dev)
        assert bounds[0] == 0 and bounds[-1] == len(cnt)
        parts = np.diff(bounds)
        assert (parts >= 1).all()         # every device owns >=1 group
        assert parts.sum() == len(cnt)


def test_execute_plan_rejects_bad_devices(calibrated):
    plan = compile_plan(_uncoupled_cases(calibrated, 2))
    with pytest.raises(ValueError):
        execute_plan(plan, devices=0)
