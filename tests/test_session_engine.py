"""Session API + vectorized engine tests (the api_redesign acceptance bar):

* Campaign.run()/frontier() reproduce the old policy_frontier path exactly;
* the vectorized engine agrees with the per-batch oracle to <0.5% and with
  the sequential coarse path to float precision;
* a >=100-schedule sweep beats sequential simulation by a wide margin;
* satellites: controller floor+duty mapping, run-granularity CO2 under an
  hourly curve, merge_summaries / JSONL crash-resume.
"""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BASELINE, Campaign, CarinaController, DTE_FACTOR,
                        GridCarbonModel, MIDWEST_HOURLY, MachineProfile,
                        PEAK_AWARE_BOOSTED, POLICIES, RunTracker, SimClock,
                        SweepCase, TOU_PRICE, calibrate_workload,
                        constant_schedule, hourly_schedule, load_units,
                        merge_summaries, policy_frontier, simulate_campaign,
                        simulate_campaign_exact, summary_from_units, sweep)
from repro.core.schedule import FunctionSchedule, SchedulingContext
from repro.core.workload import OEM_CASE_1, OEMWorkload


@pytest.fixture(scope="module")
def calibrated():
    return calibrate_workload(OEM_CASE_1, MachineProfile())


# ---------------------------------------------------------------------------
# Campaign session vs the old free-function path
# ---------------------------------------------------------------------------
def test_campaign_run_matches_policy_frontier_exactly():
    old = {r.policy: r for r in policy_frontier(OEM_CASE_1)}
    rep = Campaign(OEM_CASE_1, PEAK_AWARE_BOOSTED).run()
    ref = old["peak_aware_boosted_offhours"]
    assert rep.result.runtime_h == ref.runtime_h
    assert rep.result.energy_kwh == ref.energy_kwh
    assert rep.result.runtime_delta_pct == ref.runtime_delta_pct
    assert rep.result.energy_delta_pct == ref.energy_delta_pct
    # and the paper-calibrated deltas themselves: ~-9% energy, ~+7% runtime
    assert -11.5 <= rep.result.energy_delta_pct <= -7.0
    assert 4.5 <= rep.result.runtime_delta_pct <= 9.5


def test_campaign_frontier_matches_policy_frontier_exactly():
    old = policy_frontier(OEM_CASE_1)
    new = Campaign(OEM_CASE_1).frontier()
    assert [r.policy for r in new] == [r.policy for r in old]
    for a, b in zip(new, old):
        assert a.runtime_h == b.runtime_h
        assert a.energy_kwh == b.energy_kwh
        assert a.co2_kg == b.co2_kg
        assert a.runtime_delta_pct == b.runtime_delta_pct
        assert a.energy_delta_pct == b.energy_delta_pct
    # a user schedule merely *named* "baseline" is still simulated, not
    # swapped for the cached BASELINE result
    rogue = Campaign(OEM_CASE_1).frontier(
        [constant_schedule(0.3, name="baseline")])[0]
    assert rogue.runtime_h > old[0].runtime_h * 1.5


def test_campaign_tracks_and_renders(tmp_path):
    rep = Campaign(OEM_CASE_1, PEAK_AWARE_BOOSTED,
                   out_dir=str(tmp_path)).run(track=True)
    assert rep.summary is not None
    assert abs(rep.summary.energy_kwh - rep.result.energy_kwh) < 1e-9
    assert (tmp_path / "units.jsonl").exists()
    assert (tmp_path / "dashboard.md").exists()
    assert (tmp_path / "frontier.md").exists()


def test_campaign_exact_mode_rejects_tracking(tmp_path):
    """The per-batch oracle records no units: combining it with tracking
    must be an explicit error, not a silent all-zero summary."""
    with pytest.raises(ValueError, match="exact"):
        Campaign(OEM_CASE_1, PEAK_AWARE_BOOSTED).run(track=True, exact=True)
    rep = Campaign(OEM_CASE_1, PEAK_AWARE_BOOSTED,
                   out_dir=str(tmp_path)).run(exact=True)
    assert rep.summary is None             # no fabricated zero summary
    assert rep.result.runtime_h > 100
    # exact-mode deltas compare against the exact baseline (same model):
    # the baseline schedule itself must report zero deltas
    b = Campaign(OEM_CASE_1, BASELINE).run(exact=True).result
    assert b.runtime_delta_pct == 0.0 and b.energy_delta_pct == 0.0


def test_campaign_tracked_co2_matches_result_under_hourly_curve():
    """Tracker units must attribute CO2 to the same grid hour the segment
    ran in, so the summary agrees with the SimResult under a curvy grid."""
    carbon = GridCarbonModel(hourly_curve=MIDWEST_HOURLY)
    rep = Campaign(OEM_CASE_1, PEAK_AWARE_BOOSTED, carbon=carbon).run(track=True)
    assert abs(rep.summary.energy_kwh - rep.result.energy_kwh) < 1e-9
    assert abs(rep.summary.co2_kg - rep.result.co2_kg) < 1e-9


def test_campaign_price_signal_costs_money():
    rep = Campaign(OEM_CASE_1, PEAK_AWARE_BOOSTED, price=TOU_PRICE).run()
    assert rep.result.cost_usd is not None
    # sanity: cost within the tariff's [min, max] * kWh envelope
    assert 0.11 * rep.result.energy_kwh <= rep.result.cost_usd \
        <= 0.21 * rep.result.energy_kwh
    # off-hours boosting buys cheaper electricity than flat baseline
    base = Campaign(OEM_CASE_1, BASELINE, price=TOU_PRICE).run()
    assert (rep.result.cost_usd / rep.result.energy_kwh
            < base.result.cost_usd / base.result.energy_kwh)
    # the reused baseline row in a priced frontier carries a cost too
    table = Campaign(OEM_CASE_1, price=TOU_PRICE).frontier()
    assert all(isinstance(r.cost_usd, float) for r in table)


def test_campaign_legacy_duck_typed_policy_still_works():
    class OldStyle:                      # pre-Schedule duck-typed policy
        name = "old_style"
        batch_size = 50

        def intensity_at(self, band):
            return 0.6

    r = Campaign(OEM_CASE_1, OldStyle()).run().result
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    ref = simulate_campaign(wl, constant_schedule(0.6), m)
    assert abs(r.runtime_h - ref.runtime_h) < 1e-9
    assert abs(r.energy_kwh - ref.energy_kwh) < 1e-9


# ---------------------------------------------------------------------------
# Vectorized engine vs the oracles
# ---------------------------------------------------------------------------
def test_engine_matches_exact_oracle_all_six_policies(calibrated):
    """Acceptance: <0.5% agreement on runtime/energy/CO2 for all Figure-1
    policies vs the atomic per-batch reference."""
    wl, m = calibrated
    results = sweep([SweepCase(p, wl, m) for p in POLICIES.values()])
    for r, p in zip(results, POLICIES.values()):
        exact = simulate_campaign_exact(wl, p, m)
        assert abs(r.runtime_h / exact.runtime_h - 1) < 0.005, p.name
        assert abs(r.energy_kwh / exact.energy_kwh - 1) < 0.005, p.name
        assert abs(r.co2_kg / exact.co2_kg - 1) < 0.005, p.name


def test_engine_matches_sequential_to_float_precision(calibrated):
    """Both paths integrate the same piecewise-hourly model, so agreement
    is float precision — including band schedules under an hourly carbon
    curve, where the sequential simulator refines its segment grid to
    hours instead of carbonizing a multi-hour band at its start hour."""
    wl, m = calibrated
    curvy = GridCarbonModel(hourly_curve=MIDWEST_HOURLY)
    cases = ([(constant_schedule(0.1 + 0.05 * i), None) for i in range(12)]
             + [(constant_schedule(0.15 + 0.05 * i), curvy) for i in range(6)]
             + [(hourly_schedule(f"h{i}", [0.3 + 0.7 * ((i + h) % 24) / 23
                                           for h in range(24)]), curvy)
                for i in range(6)])
    vec = sweep([SweepCase(s, wl, m, carbon=c) for s, c in cases])
    for r, (s, c) in zip(vec, cases):
        seq = simulate_campaign(wl, s, m, carbon=c)
        assert abs(r.runtime_h / seq.runtime_h - 1) < 1e-9, s.name
        assert abs(r.energy_kwh / seq.energy_kwh - 1) < 1e-9, s.name
        assert abs(r.co2_kg / seq.co2_kg - 1) < 1e-9, s.name


def test_engine_band_schedule_hourly_carbon_matches_exact(calibrated):
    """Band schedules under an hourly grid curve: engine and coarse
    simulator must both stay within the 0.5% bar of the per-batch oracle
    on CO2 (and on cost under a TOU price signal)."""
    wl, m = calibrated
    curvy = GridCarbonModel(hourly_curve=MIDWEST_HOURLY)
    for s in (constant_schedule(0.3), PEAK_AWARE_BOOSTED):
        vec = sweep([SweepCase(s, wl, m, carbon=curvy)], price=TOU_PRICE)[0]
        exact = simulate_campaign_exact(wl, s, m, carbon=curvy,
                                        price=TOU_PRICE)
        coarse = simulate_campaign(wl, s, m, carbon=curvy, price=TOU_PRICE)
        assert abs(vec.co2_kg / exact.co2_kg - 1) < 0.005
        assert abs(coarse.co2_kg / exact.co2_kg - 1) < 0.005
        assert abs(vec.cost_usd / exact.cost_usd - 1) < 0.005
        assert abs(coarse.cost_usd / exact.cost_usd - 1) < 0.005


def test_engine_custom_schedule_goes_through_decide(calibrated):
    """A schedule implementing only the protocol (no Policy subclassing)
    must be swept via its decide(), seeing real context values."""
    wl, m = calibrated
    seen = []

    def carbon_follower(ctx: SchedulingContext) -> float:
        seen.append((ctx.band, ctx.carbon_factor, ctx.background))
        return 0.9 if ctx.carbon_factor < DTE_FACTOR else 0.4

    sched = FunctionSchedule("carbon_follower", carbon_follower)
    carbon = GridCarbonModel(hourly_curve=MIDWEST_HOURLY)
    r = sweep([SweepCase(sched, wl, m, carbon=carbon)])[0]
    seq = simulate_campaign(wl, sched, m, carbon=carbon)
    assert abs(r.runtime_h / seq.runtime_h - 1) < 1e-9
    assert len(seen) >= 24 and any(b == "peak" for b, _, _ in seen)


def test_engine_dispatches_progress_dependent_schedules(calibrated):
    """A schedule consulting ctx.progress/elapsed_h cannot be represented
    on the periodic hourly grid; sweep() must route it to the trace-grid
    engine (instead of the PR-1 ValueError) and agree with the sequential
    simulator."""
    wl, m = calibrated
    ramp = FunctionSchedule("ramp", lambda ctx: 0.3 + 0.6 * ctx.progress)
    r_vec = sweep([SweepCase(ramp, wl, m)])[0]
    r_seq = simulate_campaign(wl, ramp, m)
    assert abs(r_vec.runtime_h / r_seq.runtime_h - 1) < 0.005
    assert abs(r_vec.energy_kwh / r_seq.energy_kwh - 1) < 0.005
    # the periodic-only sampling helper still refuses explicitly
    from repro.core import hourly_profile
    from repro.core.carbon import GridCarbonModel
    with pytest.raises(ValueError, match="progress"):
        hourly_profile(ramp, SweepCase(ramp, wl, m).bands, GridCarbonModel())


def test_engine_sweep_100_schedules_faster_than_sequential(calibrated):
    """Acceptance: >=100-schedule sweep at least 10x faster than sequential
    simulate_campaign calls.  Asserted at 3x here to keep CI robust to
    noisy machines; benchmarks/run.py frontier_sweep reports the real
    ratio (~30-80x)."""
    import time
    wl, m = calibrated
    scheds = [hourly_schedule(f"s{i}", [0.2 + 0.8 * ((3 * i + h) % 24) / 23
                                        for h in range(24)])
              for i in range(120)]
    cases = [SweepCase(s, wl, m) for s in scheds]
    sweep(cases[:2])                      # warm caches
    simulate_campaign(wl, scheds[0], m)
    t0 = time.perf_counter()
    vec = sweep(cases)
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    seq = [simulate_campaign(wl, s, m) for s in scheds]
    t_seq = time.perf_counter() - t0
    assert len(vec) == 120
    worst = max(abs(a.energy_kwh / b.energy_kwh - 1) for a, b in zip(vec, seq))
    assert worst < 1e-9
    assert t_seq / t_vec > 3.0, f"speedup only {t_seq / t_vec:.1f}x"


def test_campaign_sweep_product_and_deltas(calibrated):
    flat = GridCarbonModel()
    curvy = GridCarbonModel(hourly_curve=MIDWEST_HOURLY)
    c = Campaign(OEM_CASE_1)
    res = c.sweep(list(POLICIES.values()), carbons=[flat, curvy], deltas=True)
    assert len(res) == 2 * len(POLICIES)
    base = next(r for r in res if r.policy == "baseline")
    # deltas are vs the campaign's sequential baseline; the swept baseline
    # matches it to float precision
    assert abs(base.runtime_delta_pct) < 1e-9
    # a schedule set without "baseline" still gets deltas (vs the campaign
    # baseline), instead of silently zeroed columns
    only = c.sweep([PEAK_AWARE_BOOSTED], deltas=True)[0]
    assert only.energy_delta_pct < -5.0
    # same schedule under the curvy grid: same energy, different CO2
    by_name = {}
    for r in res:
        by_name.setdefault(r.policy, []).append(r)
    for name, pair in by_name.items():
        assert abs(pair[0].energy_kwh - pair[1].energy_kwh) < 1e-9
    boosted = by_name["peak_aware_boosted_offhours"]
    assert boosted[0].co2_kg != boosted[1].co2_kg


@given(st.lists(st.floats(0.1, 1.0), min_size=24, max_size=24),
       st.integers(10, 100))
@settings(max_examples=20, deadline=None)
def test_engine_vs_exact_property(intensities, batch):
    """Property pin: for random hourly schedules the engine stays within
    0.5% of the per-batch oracle on runtime/energy/CO2."""
    wl = OEMWorkload("prop", 250_000, rate_at_full=5.0, batch_overhead_s=2.0)
    m = MachineProfile()
    sched = hourly_schedule("prop", intensities, batch_size=batch)
    vec = sweep([SweepCase(sched, wl, m)])[0]
    exact = simulate_campaign_exact(wl, sched, m)
    assert abs(vec.runtime_h / exact.runtime_h - 1) < 0.005
    assert abs(vec.energy_kwh / exact.energy_kwh - 1) < 0.005
    assert abs(vec.co2_kg / exact.co2_kg - 1) < 0.005


# ---------------------------------------------------------------------------
# Satellite: controller replica/duty mapping
# ---------------------------------------------------------------------------
def test_controller_duty_covers_fractional_remainder():
    ctrl = CarinaController(policy=constant_schedule(0.6), max_replicas=4,
                            clock=SimClock(start_hour=3.0))
    d = ctrl.decide()
    # floor(0.6*4)=2 full replicas + 1 duty-cycled for the remainder
    assert d.replicas == 3
    assert abs(d.duty - 0.6 / 0.75) < 1e-12
    # realized * duty == u: nothing silently dropped
    assert abs(d.replicas / 4 * d.duty - 0.6) < 1e-12


def test_controller_exact_fraction_needs_no_extra_replica():
    ctrl = CarinaController(policy=constant_schedule(0.5), max_replicas=4,
                            clock=SimClock(start_hour=3.0))
    d = ctrl.decide()
    assert d.replicas == 2 and d.duty == 1.0


@given(st.floats(0.05, 1.0), st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_controller_realizes_intensity_exactly(u, max_replicas):
    ctrl = CarinaController(policy=constant_schedule(u),
                            max_replicas=max_replicas,
                            clock=SimClock(start_hour=3.0))
    d = ctrl.decide()
    assert 1 <= d.replicas <= max_replicas
    assert 0.0 < d.duty <= 1.0
    assert abs(d.replicas / max_replicas * d.duty - d.intensity) < 1e-9


# ---------------------------------------------------------------------------
# Satellite: run-granularity CO2 respects hourly curves
# ---------------------------------------------------------------------------
def test_run_granularity_co2_respects_hourly_curve():
    carbon = GridCarbonModel(hourly_curve=MIDWEST_HOURLY)
    t_run = RunTracker("run-mode", carbon=carbon, granularity="run")
    t_step = RunTracker("step-mode", carbon=carbon, granularity="step")
    for hour, kwh in ((3.0, 1.0), (17.0, 1.0), (12.5, 0.25)):
        for t in (t_run, t_step):
            t.record_unit(phase="x", intensity=0.5, runtime_s=600.0,
                          energy_kwh=kwh, sim_time_h=hour)
    s_run, s_step = t_run.summary(), t_step.summary()
    assert abs(s_run.co2_kg - s_step.co2_kg) < 1e-12
    # and it is genuinely hour-aware, not total-kWh * flat factor
    flat = s_run.energy_kwh * DTE_FACTOR
    assert abs(s_run.co2_kg - flat) > 1e-3


# ---------------------------------------------------------------------------
# Satellite: merge_summaries + JSONL crash/resume
# ---------------------------------------------------------------------------
def _record_units(tracker, units):
    for i, (phase, kwh) in enumerate(units):
        tracker.record_unit(phase=phase, intensity=0.7, runtime_s=120.0,
                            energy_kwh=kwh, sim_time_h=float(i),
                            meta={"i": i})


UNITS = [("night", 0.02), ("night", 0.03), ("shoulder", 0.05),
         ("peak", 0.01), ("peak", 0.015), ("shoulder", 0.04),
         ("night", 0.02), ("load_sensitive", 0.06)]


def test_jsonl_crash_resume_matches_uninterrupted(tmp_path):
    """Write units, truncate mid-unit, re-aggregate from the log, run the
    remainder, and the merged summary matches the uninterrupted run."""
    # --- uninterrupted reference
    ref = RunTracker("ref")
    _record_units(ref, UNITS)
    ref_summary = ref.summary()

    # --- crashed run: the 6th unit's line is half-written
    log = str(tmp_path / "units.jsonl")
    crashed = RunTracker("crashed", log_path=log)
    _record_units(crashed, UNITS[:6])
    crashed._log_file.flush()
    with open(log) as f:
        lines = f.readlines()
    assert len(lines) == 6
    with open(log, "w") as f:
        f.writelines(lines[:5])
        f.write(lines[5][: len(lines[5]) // 2])   # torn write

    # --- recovery: only the 5 durable units come back
    recovered = load_units(log)
    assert len(recovered) == 5
    assert [u.meta["i"] for u in recovered] == [0, 1, 2, 3, 4]
    part1 = summary_from_units(recovered, name="part1")

    # --- resume re-executes everything after the last durable unit
    resumed = RunTracker("part2")
    _record_units(resumed, UNITS[5:])
    merged = merge_summaries([part1, resumed.summary()], name="merged")

    assert merged.units == ref_summary.units
    assert math.isclose(merged.energy_kwh, ref_summary.energy_kwh,
                        rel_tol=1e-12)
    assert math.isclose(merged.co2_kg, ref_summary.co2_kg, rel_tol=1e-12)
    assert math.isclose(merged.runtime_h, ref_summary.runtime_h,
                        rel_tol=1e-12)
    assert set(merged.by_phase) == set(ref_summary.by_phase)
    for ph, d in ref_summary.by_phase.items():
        for k, v in d.items():
            assert math.isclose(merged.by_phase[ph][k], v, rel_tol=1e-12), \
                (ph, k)


def test_jsonl_resume_appends_after_torn_line(tmp_path):
    """A resumed tracker appending to a crashed log must not merge its
    first record into the torn line, and load_units must recover the
    units on both sides of the tear."""
    log = str(tmp_path / "units.jsonl")
    crashed = RunTracker("crashed", log_path=log)
    _record_units(crashed, UNITS[:6])
    crashed._log_file.flush()
    with open(log) as f:
        lines = f.readlines()
    with open(log, "w") as f:             # torn write, no trailing newline
        f.writelines(lines[:5])
        f.write(lines[5][: len(lines[5]) // 2])

    resumed = RunTracker("resumed", log_path=log)   # same log, append mode
    _record_units(resumed, UNITS[5:])
    resumed._log_file.flush()

    recovered = load_units(log)
    assert len(recovered) == 5 + len(UNITS[5:])     # only the torn unit lost
    merged = summary_from_units(recovered, name="merged")
    ref = RunTracker("ref")
    _record_units(ref, UNITS[:5])
    _record_units(ref, UNITS[5:])
    assert math.isclose(merged.energy_kwh, ref.summary().energy_kwh,
                        rel_tol=1e-12)


def test_load_units_skips_clean_close_summary_line(tmp_path):
    log = str(tmp_path / "units.jsonl")
    t = RunTracker("clean", log_path=log)
    _record_units(t, UNITS[:4])
    t.close()                               # appends the summary line
    units = load_units(log)
    assert len(units) == 4
    s = summary_from_units(units, name="reread")
    assert math.isclose(s.energy_kwh, sum(k for _, k in UNITS[:4]),
                        rel_tol=1e-12)


def test_merge_summaries_preserves_phase_breakdown():
    a, b = RunTracker("a"), RunTracker("b")
    _record_units(a, UNITS[:3])
    _record_units(b, UNITS[3:])
    m = merge_summaries([a.summary(), b.summary()])
    assert m.units == len(UNITS)
    assert math.isclose(m.energy_kwh, sum(k for _, k in UNITS), rel_tol=1e-12)
    assert m.by_phase["peak"]["units"] == 2.0
