"""Chunked resumable trace engine + carbon-trace ensemble tests (the
PR-4 multi_layer_refactor acceptance bar):

* the chunked executor matches the monolithic scan to 1e-9 on the
  existing trace-engine case families, across chunk sizes, on both
  backends, with the straggler re-scan gone (slot-work counters);
* `SignalEnsemble` semantics: (E, T) sampling, window slicing, E=1
  parity with the plain trace sweep, per-member parity with individual
  sweeps, carbon-dependent schedules expanded per member;
* robust objectives: mean/CVaR/worst reductions, constant-ensemble
  equivalence with the deterministic optimum, `Campaign.optimize(
  robust="cvar")` over E>=32 members under both jit and NumPy;
* satellites: early stall detection, per-plan signal sampling (grids
  extended, never re-sampled), plan-cache hits on repeated sweeps.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (BASELINE, Campaign, MachineProfile,
                        PEAK_AWARE_BOOSTED, POLICIES, SignalEnsemble,
                        SweepCase, TimeBands, TraceSignal, as_ensemble,
                        calibrate_workload, constant_schedule,
                        deadline_schedule, hourly_schedule,
                        progress_ramp_schedule, sweep, trace_sweep,
                        trace_windows)
from repro.core.engine_jax import (_HAS_JAX, TraceObjective, compile_plan,
                                   execute_plan, reset_scan_stats,
                                   scan_stats, summarize_plan)
from repro.core.optimize import (Objective, optimize_schedule,
                                 reduce_ensemble)
from repro.core.schedule import FunctionSchedule, parametric_schedule
from repro.core.workload import OEM_CASE_1, OEMWorkload


@pytest.fixture(scope="module")
def calibrated():
    return calibrate_workload(OEM_CASE_1, MachineProfile())


def _week_trace(scale: float = 0.448, seed: int = 7) -> TraceSignal:
    rng = np.random.RandomState(seed)
    h = np.arange(168)
    vals = scale * (1.0 + 0.30 * np.sin(2 * np.pi * h / 24.0)
                    + 0.08 * np.sin(2 * np.pi * h / 168.0)
                    + 0.05 * rng.randn(168))
    return TraceSignal(tuple(float(v) for v in vals), name=f"week{seed}")


def _ensemble(E: int = 4, scale: float = 0.448) -> SignalEnsemble:
    return SignalEnsemble(tuple(_week_trace(scale * (1.0 + 0.06 * e),
                                            seed=11 + e)
                                for e in range(E)), name=f"ens{E}")


# ---------------------------------------------------------------------------
# Tentpole: chunked == monolithic, across chunk sizes and backends
# ---------------------------------------------------------------------------
def test_chunked_matches_monolithic_across_case_families(calibrated):
    """Every trace-engine case family — periodic policies, progress
    ramps, deadline pace-keepers, week-long traces, heterogeneous
    machines — produces identical metrics whether the horizon is scanned
    in one monolithic pass or resumable chunks."""
    wl, m = calibrated
    m2 = MachineProfile(idle_w=120.0, dyn_w=300.0, alpha=1.5, gamma=0.5)
    trace = _week_trace()
    cases = ([SweepCase(p, wl, m) for p in POLICIES.values()]
             + [SweepCase(progress_ramp_schedule(0.4, 0.9), wl, m),
                SweepCase(deadline_schedule(200.0), wl, m, carbon=trace),
                SweepCase(BASELINE, wl, m2, carbon=trace, start_hour=17.0)])
    mono = trace_sweep(cases, mode="monolithic")
    chunked = trace_sweep(cases)
    for a, b in zip(mono, chunked):
        assert abs(b.runtime_h / a.runtime_h - 1) < 1e-9, a.policy
        assert abs(b.energy_kwh / a.energy_kwh - 1) < 1e-9, a.policy
        assert abs(b.co2_kg / a.co2_kg - 1) < 1e-9, a.policy


def test_chunked_identical_across_chunk_sizes(calibrated):
    """Chunk boundaries only split the accumulation; they must never
    move it: results are identical for 1-, 3- and 5-day chunks."""
    wl, m = calibrated
    cases = [SweepCase(PEAK_AWARE_BOOSTED, wl, m),
             SweepCase(deadline_schedule(210.0), wl, m,
                       carbon=_week_trace())]
    ref = trace_sweep(cases, chunk_days=4)
    for days in (1, 3, 5):
        res = trace_sweep(cases, chunk_days=days)
        for a, b in zip(ref, res):
            assert abs(b.energy_kwh / a.energy_kwh - 1) < 1e-12, days
            assert abs(b.runtime_h / a.runtime_h - 1) < 1e-12, days
            assert abs(b.co2_kg / a.co2_kg - 1) < 1e-12, days


def test_chunked_numpy_backend_matches_jax(calibrated):
    wl, m = calibrated
    cases = [SweepCase(BASELINE, wl, m),
             SweepCase(progress_ramp_schedule(0.4, 0.9), wl, m)]
    np_res = trace_sweep(cases, backend="numpy")
    if not _HAS_JAX:
        pytest.skip("jax not importable; numpy fallback already exercised")
    jax_res = trace_sweep(cases, backend="jax")
    for a, b in zip(np_res, jax_res):
        assert abs(b.runtime_h / a.runtime_h - 1) < 1e-12, a.policy
        assert abs(b.energy_kwh / a.energy_kwh - 1) < 1e-12, a.policy


def test_straggler_rescan_is_gone(calibrated):
    """A mixed-finish batch: the monolithic engine scans everyone to the
    straggler's horizon (and re-scans on undershoot); the chunked engine
    compacts finished cases out, so its slot-work is a fraction —
    the benchmark bar is >= 3x at S=1000, pinned here at a smaller S."""
    wl, m = calibrated
    scheds = [hourly_schedule(f"fast{i}",
                              [0.8 + 0.15 * ((i + h) % 24) / 23
                               for h in range(24)]) for i in range(40)]
    scheds += [hourly_schedule(f"slow{i}", [0.12] * 24) for i in range(2)]
    cases = [SweepCase(s, wl, m) for s in scheds]
    reset_scan_stats()
    chunked = trace_sweep(cases)
    work_chunked = scan_stats().slot_work
    reset_scan_stats()
    mono = trace_sweep(cases, mode="monolithic")
    work_mono = scan_stats().slot_work
    for a, b in zip(mono, chunked):
        assert abs(b.energy_kwh / a.energy_kwh - 1) < 1e-9
    assert work_mono >= 3 * work_chunked, (work_mono, work_chunked)


def test_compile_execute_summarize_stages_are_public(calibrated):
    """The staged API composes: a plan compiled once can be executed and
    summarized directly, matching trace_sweep."""
    wl, m = calibrated
    cases = [SweepCase(BASELINE, wl, m, carbon=_week_trace())]
    plan = compile_plan(cases)
    state = execute_plan(plan)
    res = summarize_plan(plan, state)[0]
    ref = trace_sweep(cases)[0]
    assert res.co2_kg == pytest.approx(ref.co2_kg, rel=1e-12)
    assert plan.n_lanes == 1 and plan.E == 1


def test_plan_cache_hits_on_repeated_sweeps(calibrated):
    """Re-sweeping the same (value-fingerprintable) cases must not
    re-probe or rebuild tables: the per-case compile cache reports hits —
    including for the default carbon=None configuration."""
    wl, m = calibrated
    for carbon in (_week_trace(), None):
        cases = [SweepCase(PEAK_AWARE_BOOSTED, wl, m, carbon=carbon)]
        trace_sweep(cases)                # populate
        reset_scan_stats()
        trace_sweep(cases)
        st = scan_stats()
        assert st.plan_hits >= 1, carbon
        assert st.plan_misses == 0, carbon


def test_custom_decide_grid_schedule_keeps_exact_per_slot_tables(calibrated):
    """A decide_grid schedule that does NOT declare `periodic_decisions`
    must keep exact chunk-built per-slot tables — the probe lattice alone
    cannot prove hour-of-day periodicity for arbitrary vectorized
    schedules.  ParametricSchedule declares the contract and lowers to
    one day-periodic table."""
    wl, m = calibrated

    class SneakyGrid:
        """Hour-of-day wave until day 3, then throttled — invisible to a
        probe lattice that samples days 0/1/2 and the horizon end."""
        name = "sneaky"
        batch_size = 50

        def _u(self, hod, elapsed):
            u = 0.5 + 0.4 * np.sin(2 * np.pi * np.asarray(hod) / 24.0) ** 2
            # thresholds off the hourly sample grid so slot-start and
            # just-inside-segment sampling see the same decisions
            return np.where((np.asarray(elapsed) > 71.5)
                            & (np.asarray(elapsed) < 999.5), 0.25, u)

        def decide(self, ctx):
            from repro.core.schedule import Decision
            return Decision(float(self._u(ctx.hour_of_day, ctx.elapsed_h)),
                            self.batch_size)

        def decide_grid(self, ctx):
            u = self._u(ctx.hour_of_day, ctx.elapsed_h)
            return u, np.broadcast_to(50.0, np.shape(u))

    sneaky = SneakyGrid()
    plan = compile_plan([SweepCase(sneaky, wl, m)])
    assert not plan.lane_periodic[0]      # chunk-built, exact per slot
    from repro.core import simulate_campaign
    r = trace_sweep([SweepCase(sneaky, wl, m)])[0]
    seq = simulate_campaign(wl, sneaky, m)
    assert abs(r.energy_kwh / seq.energy_kwh - 1) < 1e-9
    assert abs(r.runtime_h / seq.runtime_h - 1) < 1e-9
    # the optimizer's family declares hour-of-day-only decisions and
    # keeps the compact periodic lowering
    plan_p = compile_plan([SweepCase(parametric_schedule(24), wl, m)])
    assert plan_p.lane_periodic[0]


def test_decide_grid_progress_window_keeps_full_bucket_axis(calibrated):
    """A decide_grid schedule whose progress dependence lives entirely
    between the probe's lattice points must still get the full progress
    bucket axis (the old engine's exactness contract for vectorized
    schedules) — within the documented <0.5% bucket-interpolation bar of
    the per-segment oracle."""
    wl, m = calibrated

    class ProgressWindowGrid:
        """Boost only while progress is in (0.72, 0.94) — invisible at
        the probe's progress samples {0, 1/3, 1/2, 2/3, 0.999}."""
        name = "pwindow"
        batch_size = 50

        def _u(self, progress):
            p = np.asarray(progress)
            return np.where((p > 0.72) & (p < 0.94), 0.95, 0.4)

        def decide(self, ctx):
            from repro.core.schedule import Decision
            return Decision(float(self._u(ctx.progress)), self.batch_size)

        def decide_grid(self, ctx):
            u = np.broadcast_to(self._u(ctx.progress),
                                np.broadcast_shapes(
                                    np.shape(ctx.hour_of_day),
                                    np.shape(ctx.progress)))
            return u, np.broadcast_to(50.0, np.shape(u))

    sched = ProgressWindowGrid()
    from repro.core import simulate_campaign
    seq = simulate_campaign(wl, sched, m)
    # bang-bang progress thresholds are the documented worst case for
    # bucket interpolation (docs/API.md carves them out of the 0.5% bar;
    # error ~1/buckets at the discontinuities) — 1% here, vs ~19% when
    # the probe used to flatten the progress axis away entirely
    r = trace_sweep([SweepCase(sched, wl, m)], progress_buckets=64)[0]
    assert abs(r.runtime_h / seq.runtime_h - 1) < 0.01
    assert abs(r.energy_kwh / seq.energy_kwh - 1) < 0.01
    r32 = trace_sweep([SweepCase(sched, wl, m)])[0]
    assert abs(r32.energy_kwh / seq.energy_kwh - 1) < 0.02


def test_chunk_days_validated(calibrated):
    wl, m = calibrated
    cases = [SweepCase(BASELINE, wl, m)]
    with pytest.raises(ValueError, match="chunk_days"):
        trace_sweep(cases, chunk_days=-1)
    with pytest.raises(ValueError, match="mode"):
        trace_sweep(cases, mode="streamed")


# ---------------------------------------------------------------------------
# Satellite: early stall detection
# ---------------------------------------------------------------------------
def test_stall_raises_immediately_not_at_max_days(calibrated):
    """A zero-intensity schedule used to scan all the way to max_days
    before raising; now the first fully-scanned day with no progress
    raises the diagnostic (in both executors)."""
    wl, m = calibrated
    cases = [SweepCase(constant_schedule(0.0), wl, m)]
    for mode in ("chunked", "monolithic"):
        reset_scan_stats()
        with pytest.raises(RuntimeError, match="stalled at zero intensity"):
            trace_sweep(cases, mode=mode)
        # far less work than a 120-day scan of 2880 slots
        assert scan_stats().slot_work < 1500, mode


def test_slow_but_progressing_case_is_not_flagged_as_stalled():
    """A genuinely slow (but nonzero) schedule must finish, not trip the
    stall detector."""
    m = MachineProfile(gamma=0.0)
    wl = OEMWorkload("slow", 86_400, rate_at_full=10.0, batch_overhead_s=0.0)
    r = trace_sweep([SweepCase(constant_schedule(0.02), wl, m,
                               carbon=_week_trace())])[0]
    assert r.runtime_h == pytest.approx(120.0, rel=1e-6)


# ---------------------------------------------------------------------------
# Satellite: signals sampled once per plan, extended incrementally
# ---------------------------------------------------------------------------
def test_signal_grids_sampled_once_per_plan(calibrated):
    """Each (signal, offset) grid slot is sampled exactly once per plan:
    a counting signal sees every absolute hour at most once, even though
    the straggler forces several appended chunks."""
    wl, m = calibrated

    class CountingTrace:
        name = "counting"
        period_h = None

        def __init__(self):
            self.seen = []

        def at(self, hour):
            self.seen.append(float(hour))
            return 0.448

    fast_sig, slow_sig = CountingTrace(), CountingTrace()
    fast = hourly_schedule("fastc", [0.9] * 24)
    slow = hourly_schedule("slowc", [0.15] * 24)
    trace_sweep([SweepCase(fast, wl, m, carbon=fast_sig),
                 SweepCase(slow, wl, m, carbon=slow_sig)])
    for sig in (fast_sig, slow_sig):
        hours = np.asarray(sig.seen)
        uniq = np.unique(np.round(hours, 6))
        assert len(uniq) == len(hours)    # no hour sampled twice
    # the straggler extended further than the fast case, incrementally
    assert len(slow_sig.seen) > len(fast_sig.seen)


# ---------------------------------------------------------------------------
# SignalEnsemble semantics
# ---------------------------------------------------------------------------
def test_signal_ensemble_sampling_and_coercion():
    ens = _ensemble(3)
    assert len(ens) == 3 and ens.period_h is None
    block = ens.sample(np.arange(10.0))
    assert block.shape == (3, 10)
    for e in range(3):
        assert block[e, 4] == ens.member(e).at(4.0)
    # at() is the member mean (sequential-simulator view)
    assert ens.at(4.0) == pytest.approx(block[:, 4].mean())
    # coercions: passthrough, (E, T) array, list of sequences
    assert as_ensemble(ens) is ens
    arr = np.tile(np.linspace(0.3, 0.6, 48), (4, 1))
    e2 = as_ensemble(arr)
    assert len(e2) == 4 and isinstance(e2.member(0), TraceSignal)
    e3 = as_ensemble([[0.4] * 24, [0.5] * 24])
    assert len(e3) == 2
    with pytest.raises(ValueError):
        SignalEnsemble(())
    # a flat hourly series is one trace, not an ensemble of scalars
    with pytest.raises(TypeError, match="carbon_trace"):
        as_ensemble([0.4, 0.5, 0.6])


def test_trace_windows_slices_a_history():
    series = np.arange(24 * 10, dtype=float)
    ens = trace_windows(series, window_h=24 * 7, stride_h=24)
    assert len(ens) == 4                  # offsets 0, 24, 48, 72
    assert ens.member(1).values[0] == 24.0
    assert len(ens.member(0).values) == 24 * 7
    with pytest.raises(ValueError, match="shorter"):
        trace_windows(series[:100], window_h=168)


def test_ensemble_with_one_member_matches_plain_trace(calibrated):
    """E=1 is the degenerate ensemble: identical numbers to sweeping the
    single trace directly, plus the stats fields."""
    wl, m = calibrated
    trace = _week_trace()
    ens = SignalEnsemble((trace,))
    for sched in (BASELINE, deadline_schedule(210.0)):
        plain = sweep([SweepCase(sched, wl, m, carbon=trace)])[0]
        wrapped = sweep([SweepCase(sched, wl, m, carbon=ens)])[0]
        assert abs(wrapped.co2_kg / plain.co2_kg - 1) < 1e-9, sched.name
        assert abs(wrapped.energy_kwh / plain.energy_kwh - 1) < 1e-9
        assert abs(wrapped.runtime_h / plain.runtime_h - 1) < 1e-9
        assert wrapped.co2_ensemble is not None
        assert wrapped.co2_ensemble.n_members == 1
        assert plain.co2_ensemble is None


def test_ensemble_members_match_individual_sweeps(calibrated):
    """The (S, E) scan's per-member CO2 equals E independent sweeps."""
    wl, m = calibrated
    ens = _ensemble(4)
    for sched in (PEAK_AWARE_BOOSTED, progress_ramp_schedule(0.4, 0.9)):
        r = sweep([SweepCase(sched, wl, m, carbon=ens)])[0]
        singles = [sweep([SweepCase(sched, wl, m,
                                    carbon=ens.member(e))])[0].co2_kg
                   for e in range(4)]
        assert np.allclose(r.co2_ensemble.samples, singles, rtol=1e-9)
        assert r.co2_kg == pytest.approx(np.mean(singles), rel=1e-9)
        assert r.co2_ensemble.hi >= r.co2_ensemble.q95 >= r.co2_ensemble.q05
        # carbon-blind schedule: dynamics identical across members
        assert r.energy_ensemble is None


def test_carbon_dependent_schedule_expands_per_member(calibrated):
    """A schedule that consults ctx.carbon_factor decides differently
    under each member, so the scan expands it into E lanes and even
    energy/runtime get per-member spread."""
    wl, m = calibrated

    def carbon_follower(ctx):
        return 0.9 if ctx.carbon_factor < 0.45 else 0.3

    sched = FunctionSchedule("follower", carbon_follower)
    ens = _ensemble(3)
    r = sweep([SweepCase(sched, wl, m, carbon=ens)])[0]
    assert r.energy_ensemble is not None and r.runtime_ensemble is not None
    singles = [sweep([SweepCase(sched, wl, m,
                                carbon=ens.member(e))])[0]
               for e in range(3)]
    assert np.allclose(r.co2_ensemble.samples,
                       [s.co2_kg for s in singles], rtol=1e-9)
    assert np.allclose(r.runtime_ensemble.samples,
                       [s.runtime_h for s in singles], rtol=1e-9)
    assert r.runtime_ensemble.std > 0.0


def test_mismatched_ensemble_sizes_rejected(calibrated):
    wl, m = calibrated
    with pytest.raises(ValueError, match="same member count"):
        trace_sweep([SweepCase(BASELINE, wl, m, carbon=_ensemble(2)),
                     SweepCase(BASELINE, wl, m, carbon=_ensemble(3))])


def test_campaign_sweep_carbon_ensemble(calibrated):
    c = Campaign(OEM_CASE_1)
    ens = _ensemble(3)
    res = c.sweep([BASELINE, PEAK_AWARE_BOOSTED], carbon_ensemble=ens)
    assert len(res) == 2
    assert all(r.co2_ensemble is not None
               and r.co2_ensemble.n_members == 3 for r in res)
    with pytest.raises(ValueError, match="carbon_ensemble"):
        c.sweep([BASELINE], carbon_trace=[0.4] * 48, carbon_ensemble=ens)


# ---------------------------------------------------------------------------
# Robust objectives
# ---------------------------------------------------------------------------
def test_reduce_ensemble_modes():
    vals = np.array([[1.0, 3.0, 2.0, 10.0]])
    assert reduce_ensemble(vals, "mean")[0] == pytest.approx(4.0)
    assert reduce_ensemble(vals, "worst")[0] == pytest.approx(10.0)
    # alpha=0.5 on 4 members -> mean of worst 2
    assert reduce_ensemble(vals, "cvar", alpha=0.5)[0] == pytest.approx(6.5)
    # cvar interpolates between mean (alpha->0) and worst (alpha->1)
    cv = reduce_ensemble(vals, "cvar", alpha=0.9)[0]
    assert 4.0 <= cv <= 10.0
    with pytest.raises(ValueError, match="robust"):
        reduce_ensemble(vals, "median")
    with pytest.raises(ValueError, match="robust"):
        Objective(weights={"co2": 1.0}, robust="median")
    with pytest.raises(ValueError, match="cvar_alpha"):
        Objective(weights={"co2": 1.0}, cvar_alpha=1.5)


def test_trace_objective_ensemble_axis(calibrated):
    """TraceObjective grows the trailing (E,) CO2 axis; per-member
    values match E single-trace objectives."""
    wl, m = calibrated
    ens = _ensemble(3)
    case = SweepCase(parametric_schedule(24), wl, m, carbon=ens,
                     deadline_h=220.0)
    to = TraceObjective(case, horizon_h=260.0)
    U = np.full((2, 24), 0.6)
    mets = to.evaluate_batch(U)
    assert mets.co2_kg.shape == (2, 3)
    assert mets.energy_kwh.shape == (2,)
    for e in range(3):
        single = TraceObjective(dataclasses.replace(case,
                                                    carbon=ens.member(e)),
                                horizon_h=260.0).evaluate_batch(U)
        assert np.allclose(mets.co2_kg[:, e], single.co2_kg, rtol=1e-12)
        assert np.allclose(mets.energy_kwh, single.energy_kwh, rtol=1e-12)


def test_robust_optimize_constant_ensemble_matches_deterministic():
    """With E identical members every robust mode degenerates to the
    deterministic objective: same search trajectory, same optimum."""
    trace = _week_trace()
    ens = SignalEnsemble(tuple(trace for _ in range(4)), name="const")
    c = Campaign(OEM_CASE_1)
    det = c.optimize("co2", deadline_h=215.0, carbon_trace=trace,
                     method="cem", candidates=48, iterations=6, seed=9)
    for robust in ("mean", "cvar", "worst"):
        rob = c.optimize("co2", deadline_h=215.0, carbon_ensemble=ens,
                         robust=robust, method="cem", candidates=48,
                         iterations=6, seed=9)
        assert abs(rob.metrics.co2_kg / det.metrics.co2_kg - 1) < 1e-9, robust
        assert abs(rob.result.energy_kwh / det.result.energy_kwh - 1) < 1e-9
        assert np.allclose(rob.co2_ensemble, rob.metrics.co2_kg, rtol=1e-9)


def test_campaign_optimize_cvar_e32_numpy_backend():
    """Acceptance: robust CVaR optimization over E>=32 members on the
    NumPy fallback."""
    ens = _ensemble(32)
    c = Campaign(OEM_CASE_1)
    res = c.optimize("co2", deadline_h=220.0, carbon_ensemble=ens,
                     robust="cvar", method="cem", candidates=24,
                     iterations=4, backend="numpy", seed=2)
    assert res.method == "cem"
    assert res.objective.robust == "cvar"
    assert res.co2_ensemble is not None and len(res.co2_ensemble) == 32
    assert res.metrics.unfinished < 1e-9
    # CVaR at the optimum sits in the member tail, above the mean
    assert res.metrics.co2_kg >= np.mean(res.co2_ensemble) - 1e-12
    assert res.result.co2_ensemble is not None
    assert res.result.co2_ensemble.n_members == 32


@pytest.mark.skipif(not _HAS_JAX, reason="jit path needs jax")
def test_campaign_optimize_cvar_e32_jit_backend():
    """Acceptance: the same robust search through the jitted scan —
    including gradients through the CVaR sort."""
    ens = _ensemble(32)
    c = Campaign(OEM_CASE_1)
    res = c.optimize("co2", deadline_h=220.0, carbon_ensemble=ens,
                     robust="cvar", method="cem+grad", candidates=32,
                     iterations=4, steps=40, seed=2)
    assert res.method == "cem+grad"
    assert res.metrics.unfinished < 1e-9
    assert res.metrics.runtime_h <= 220.0 * 1.01
    assert len(res.co2_ensemble) == 32
    # robust ranking at one schedule: worst >= cvar >= mean
    mets = np.asarray(res.co2_ensemble)
    assert mets.max() + 1e-12 >= res.metrics.co2_kg >= mets.mean() - 1e-12
