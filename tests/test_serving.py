"""Serving layer: arrival streams + the request-level scheduler.

* determinism: `arrival_stream` is pure in its seed; scheduling is pure
  NumPy, so two runs pin identical schedules, and the NumPy fallback vs
  the jit execution path agree on every slot/tier and on CO2;
* the vectorized FIFO matches the per-request Python-loop oracle;
* the headline claim, pinned on a fixed seed + the Midwest trace: the
  greedy and CEM-optimized policies beat carbon-blind FIFO on total CO2
  at equal (zero) SLO-miss rate with every request admitted;
* scale: a 1-day stream of 1M requests schedules and executes in one
  compiled sweep (one chunk launch, one jit shape);
* `ServingSession` lifecycle (submit/tick/drain/rollup), the serving
  counters in `scan_stats`, degrade/reject behaviour under overload,
  and the live-mode gate + per-tick accounting.
"""
import numpy as np
import pytest

from repro.core import (ArrivalBatch, DEFAULT_TIERS, DTE_FACTOR,
                        HourlySignal, LOAD_SHAPES, MIDWEST_HOURLY,
                        QualityTier, RunTracker, ServingSession, SimClock,
                        StepCost, arrival_stream, serve_window)
from repro.core.engine_jax import reset_scan_stats, scan_stats
from repro.core.serve import (FifoServingPolicy, GreedyServingPolicy,
                              OptimizedServingPolicy, _fifo_assign_loop,
                              as_serving_policy)

MIDWEST = HourlySignal(tuple(float(v) * DTE_FACTOR for v in MIDWEST_HOURLY))


def _session(**kw):
    kw.setdefault("carbon", MIDWEST)
    kw.setdefault("service_rate", 0.6)
    kw.setdefault("start_hour", 6.0)
    return ServingSession(**kw)


# ---------------------------------------------------------------------------
# arrival streams
# ---------------------------------------------------------------------------
def test_arrival_stream_deterministic_in_seed():
    for shape in LOAD_SHAPES:
        a = arrival_stream(500, shape=shape, seed=7, tier_mix=(0.7, 0.3))
        b = arrival_stream(500, shape=shape, seed=7, tier_mix=(0.7, 0.3))
        for f in ("t_arrive_h", "deadline_h", "work", "tier"):
            assert np.array_equal(getattr(a, f), getattr(b, f)), (shape, f)
    c = arrival_stream(500, shape="random", seed=8)
    assert not np.array_equal(a.t_arrive_h, c.t_arrive_h)


@pytest.mark.parametrize("shape", LOAD_SHAPES)
def test_arrival_stream_well_formed(shape):
    b = arrival_stream(2000, horizon_h=12.0, shape=shape, seed=1,
                       slack_h=(0.5, 3.0), tier_mix=(0.6, 0.3, 0.1))
    assert b.n == 2000 and b.horizon_h == 12.0
    assert np.all(np.diff(b.t_arrive_h) >= 0)
    assert b.t_arrive_h[0] >= 0 and b.t_arrive_h[-1] <= 12.0
    assert np.all(b.deadline_h >= b.t_arrive_h + 0.5 - 1e-9)
    assert np.all(b.deadline_h <= b.t_arrive_h + 3.0 + 1e-9)
    assert np.all(b.work > 0)
    assert set(np.unique(b.tier)) <= {0, 1, 2}


def test_arrival_stream_shapes_differ():
    n, h = 4000, 24.0
    t = {s: arrival_stream(n, h, shape=s, seed=0).t_arrive_h
         for s in LOAD_SHAPES}
    # linear ramps up: mass sits later than the uniform stream
    assert t["linear"].mean() > t["random"].mean() + 1.0
    # peak concentrates around peak_frac * horizon (default 0.75)
    in_peak = np.mean((t["peak"] > 0.6 * h) & (t["peak"] < 0.9 * h))
    assert in_peak > 0.5 > np.mean((t["random"] > 0.6 * h)
                                   & (t["random"] < 0.9 * h))
    # camel is bimodal: a trough between the default humps (0.35, 0.8)
    trough = np.mean((t["camel"] > 0.5 * h) & (t["camel"] < 0.65 * h))
    hump = np.mean((t["camel"] > 0.275 * h) & (t["camel"] < 0.425 * h))
    assert hump > 2 * trough


def test_arrival_batch_validation_and_merge():
    ok = dict(t_arrive_h=np.array([0.0, 1.0]),
              deadline_h=np.array([2.0, 3.0]),
              work=np.array([1.0, 1.0]), tier=np.array([0, 0]))
    ArrivalBatch(**ok)
    with pytest.raises(ValueError, match="sorted"):
        ArrivalBatch(**{**ok, "t_arrive_h": np.array([1.0, 0.0])})
    with pytest.raises(ValueError, match="deadline"):
        ArrivalBatch(**{**ok, "deadline_h": np.array([2.0, 0.5])})
    with pytest.raises(ValueError, match="positive"):
        ArrivalBatch(**{**ok, "work": np.array([0.0, 1.0])})
    a = arrival_stream(50, shape="peak", seed=1)
    b = arrival_stream(70, shape="random", seed=2)
    m = ArrivalBatch.merge([a, b])
    assert m.n == 120
    assert np.all(np.diff(m.t_arrive_h) >= 0)
    assert m.work.sum() == pytest.approx(a.work.sum() + b.work.sum())
    with pytest.raises(ValueError, match="unknown load shape"):
        arrival_stream(10, shape="tsunami")
    with pytest.raises(ValueError, match="work_scale"):
        QualityTier("bad", 1.5)


# ---------------------------------------------------------------------------
# FIFO: vectorized == per-request loop oracle
# ---------------------------------------------------------------------------
def test_fifo_matches_python_loop_oracle():
    sess = _session(service_rate=0.05)        # tight: forces rejections
    w = sess.window()
    for shape, seed in (("random", 0), ("peak", 1), ("camel", 2)):
        batch = arrival_stream(5000, shape=shape, seed=seed,
                               tier_mix=(0.8, 0.2))
        asn = FifoServingPolicy().assign(batch, w, DEFAULT_TIERS)
        ref = _fifo_assign_loop(batch, w, DEFAULT_TIERS)
        assert np.array_equal(asn.slot, ref.slot), shape
        assert asn.demand.sum() == pytest.approx(ref.demand.sum())
        assert asn.n_admitted < batch.n       # the overload actually bites


# ---------------------------------------------------------------------------
# the headline: carbon-aware beats FIFO at equal SLO attainment (pinned)
# ---------------------------------------------------------------------------
def test_greedy_and_optimized_beat_fifo_on_co2_pinned():
    sess = _session()
    w = sess.window()
    batch = arrival_stream(20000, shape="camel", seed=3,
                           camel_fracs=(0.2, 0.55), slack_h=(4.0, 12.0))
    reports = {p: serve_window(batch, w, policy=p, backend="numpy")
               for p in ("fifo", "greedy", "optimized")}
    for p, r in reports.items():
        assert r.n_admitted == batch.n, p     # nobody buys CO2 with drops
        assert r.n_slo_miss == 0, p           # equal SLO-miss rate (zero)
    fifo, greedy, opt = (reports[p].co2_kg
                         for p in ("fifo", "greedy", "optimized"))
    assert greedy < 0.9 * fifo                # >= 10 % CO2 saved
    assert opt < 0.9 * fifo
    # pin the fixed-seed numbers so a silent regression is loud
    assert fifo == pytest.approx(3.3977, rel=0.02)
    assert greedy == pytest.approx(2.7872, rel=0.02)
    assert opt == pytest.approx(2.7251, rel=0.02)


def test_schedules_reproducible_and_numpy_matches_jit():
    sess = _session()
    w = sess.window()
    batch = arrival_stream(8000, shape="peak", seed=11, tier_mix=(0.7, 0.3),
                           slack_h=(2.0, 10.0))
    for policy in ("fifo", "greedy",
                   OptimizedServingPolicy(candidates=24, iterations=4)):
        pol = as_serving_policy(policy)
        a1 = pol.assign(batch, w, DEFAULT_TIERS, seed=0)
        a2 = pol.assign(batch, w, DEFAULT_TIERS, seed=0)
        assert np.array_equal(a1.slot, a2.slot), a1.policy
        assert np.array_equal(a1.tier, a2.tier), a1.policy
        assert np.array_equal(a1.demand, a2.demand), a1.policy
    # numpy fallback vs jit path: identical schedule, matching totals
    r_np = serve_window(batch, w, policy="greedy", backend="numpy")
    r_jax = serve_window(batch, w, policy="greedy", backend="jax")
    assert np.array_equal(r_np.assignment.slot, r_jax.assignment.slot)
    assert np.array_equal(r_np.assignment.tier, r_jax.assignment.tier)
    assert r_np.co2_kg == pytest.approx(r_jax.co2_kg, rel=1e-6)
    assert r_np.energy_kwh == pytest.approx(r_jax.energy_kwh, rel=1e-6)


# ---------------------------------------------------------------------------
# scale: 1M requests/day in one compiled sweep
# ---------------------------------------------------------------------------
def test_million_request_day_is_one_compiled_sweep():
    n = 1_000_000
    sess = _session(service_rate=30.0, policy="greedy")
    sess.submit(n=n, shape="camel", seed=5, slack_h=(4.0, 12.0))
    reset_scan_stats()
    rep = sess.tick()
    st = scan_stats()
    assert st.requests_seen == n
    assert st.requests_admitted == rep.n_admitted == n
    assert st.chunks == 1                     # one compiled sweep
    assert rep.n_slo_miss == 0
    assert rep.co2_kg > 0 and rep.energy_kwh > 0


# ---------------------------------------------------------------------------
# session lifecycle + counters
# ---------------------------------------------------------------------------
def test_session_submit_tick_drain_rollup():
    sess = _session(policy="greedy", seed=9)
    b1 = sess.submit(n=300, shape="random")
    b2 = sess.submit(n=400, shape="peak")
    assert sess.pending == 2
    assert b1.t_arrive_h[0] != b2.t_arrive_h[0]   # per-window seeds differ
    r1 = sess.tick()
    assert sess.pending == 1 and r1.t0_h == 6.0
    roll = sess.drain()
    assert sess.pending == 0 and roll.n_windows == 2
    assert roll.n_requests == 700
    assert roll.n_admitted == sum(r.n_admitted for r in sess.reports)
    assert roll.energy_kwh == pytest.approx(
        sum(r.energy_kwh for r in sess.reports))
    assert sess.reports[1].t0_h == 30.0           # clock advanced one window
    with pytest.raises(ValueError, match="submit"):
        sess.tick()
    with pytest.raises(ValueError, match="exceeds the session window"):
        _session(window_h=6.0).submit(arrival_stream(10, horizon_h=24.0))


def test_serving_counters_accumulate_and_reset():
    reset_scan_stats()
    sess = _session(service_rate=0.02)        # heavy overload
    sess.submit(n=500, shape="peak", seed=0, tier_mix=(0.5, 0.3, 0.2),
                slack_h=(1.0, 4.0), mean_work=10.0)
    rep = sess.tick()
    st = scan_stats()
    assert st.requests_seen == 500
    assert st.requests_admitted == rep.n_admitted
    assert st.requests_rejected == rep.n_rejected > 0
    assert st.requests_degraded == rep.n_degraded > 0
    assert rep.n_admitted + rep.n_rejected == 500
    reset_scan_stats()
    z = scan_stats()
    assert (z.requests_seen, z.requests_admitted, z.requests_rejected,
            z.requests_degraded) == (0, 0, 0, 0)


def test_degrade_off_keeps_requested_tiers():
    kw = dict(n=400, shape="peak", seed=2, tier_mix=(0.5, 0.5),
              slack_h=(1.0, 4.0), mean_work=10.0)
    sess = _session(service_rate=0.02,
                    policy=GreedyServingPolicy(degrade=False))
    sess.submit(**kw)
    rep = sess.tick()
    assert rep.n_degraded == 0
    strict = rep.n_admitted
    sess2 = _session(service_rate=0.02, policy="greedy")
    sess2.submit(**kw)
    rep2 = sess2.tick()
    assert rep2.n_degraded > 0
    assert rep2.n_admitted >= strict          # eco retry only ever helps


def test_request_attribution_sums_to_window_totals():
    sess = _session(policy="greedy")
    sess.submit(n=1000, shape="camel", seed=4, tier_mix=(0.8, 0.2))
    rep = sess.tick()
    assert rep.request_energy_kwh.sum() == pytest.approx(rep.energy_kwh,
                                                         rel=1e-9)
    assert rep.request_co2_kg.sum() == pytest.approx(rep.co2_kg, rel=1e-9)
    rejected = rep.assignment.slot < 0
    assert np.all(rep.request_energy_kwh[rejected] == 0.0)


# ---------------------------------------------------------------------------
# live mode (decode-serving adapter)
# ---------------------------------------------------------------------------
def test_live_gate_and_queue_pressure_override():
    clean, dirty = 3.5, 18.5                  # Midwest night vs evening
    sess = ServingSession(carbon=MIDWEST, gate=0.42, max_queue=4,
                          clock=SimClock(start_hour=clean))
    assert float(MIDWEST.at(clean)) < 0.42 < float(MIDWEST.at(dirty))
    assert sess.gate_open()
    sess.clock.advance_s((dirty - clean) * 3600.0)
    assert not sess.gate_open(queue_depth=0)
    assert sess.gate_open(queue_depth=4)      # backlog forces admission
    assert ServingSession(carbon=MIDWEST).gate_open()   # no gate -> open


def test_live_record_tick_accounting():
    tracker = RunTracker("live")
    sess = ServingSession(carbon=MIDWEST, tracker=tracker,
                          clock=SimClock(start_hour=2.0, speedup=3600.0),
                          step_cost=StepCost(flops=1e12, hbm_bytes=1e10,
                                             ici_bytes=1e8))
    kwh = sess.record_tick(1.0, active=3, steps=2)
    assert kwh > 0 and sess.live_units == 1
    assert sess.live_energy_kwh == pytest.approx(kwh)
    assert sess.live_co2_kg == pytest.approx(
        kwh * float(MIDWEST.at(sess.clock.hours)))
    # runtime-mode fallback (no StepCost) uses the machine profile
    sess2 = ServingSession(carbon=MIDWEST, clock=SimClock(start_hour=2.0))
    kwh2 = sess2.record_tick(10.0)
    assert kwh2 > 0
    assert tracker.records[0].meta["active"] == 3
