"""Grid-data ingestion layer (core/data.py): parse/validate split.

* bundled sample archives load offline, normalized to hourly kg/kWh;
* DST spring-forward gaps and fall-back duplicate hours are repaired
  and *counted* (QualityReport — nothing silent);
* sub-hourly (5-min) archives downsample onto the hourly slot grid;
* gap policies: interpolate / hold / raise, and `to_ensemble` rejects a
  series whose repaired gap exceeds the window;
* unit handling: explicit column, file-wide override, magnitude
  inference — and a g-vs-kg multi-zone mix without unit info is an
  error, not a 1000x corruption.
"""
import datetime as dt
import json
import os

import numpy as np
import pytest

from repro.core.data import (SAMPLE_ARCHIVES, CarbonArchive,
                             load_carbon_archive, load_sample_archive,
                             sample_archive_path, write_synthetic_archive)
from repro.core.signal import SignalEnsemble, TraceSignal, trace_windows


def _write_csv(path, rows, header=("datetime", "zone",
                                   "carbon_intensity")):
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(v) for v in r) + "\n")
    return str(path)


def _hours(start, n, skip=(), repeat=()):
    """ISO timestamps start+0h..start+n-1h, minus `skip`, doubling `repeat`."""
    t0 = dt.datetime.fromisoformat(start)
    out = []
    for i in range(n):
        if i in skip:
            continue
        out.append((i, (t0 + dt.timedelta(hours=i)).isoformat()))
        if i in repeat:
            out.append((i, (t0 + dt.timedelta(hours=i)).isoformat()))
    return out


# ----------------------------------------------------------------------
# bundled samples
# ----------------------------------------------------------------------
def test_bundled_samples_load():
    for name in SAMPLE_ARCHIVES:
        arch = load_sample_archive(name)
        assert isinstance(arch, CarbonArchive)
        for s in arch:
            assert s.hours >= 24
            vals = np.asarray(s.values)
            # normalized units: plausible kg CO2e/kWh, never grams
            assert 0.0 < vals.min() and vals.max() < 2.0
            assert s.quality.gap_policy == "interpolate"


def test_bundled_sample_path_errors():
    with pytest.raises(FileNotFoundError):
        sample_archive_path("no_such_archive.csv")


def test_three_zone_sample_shape():
    arch = load_sample_archive("grid_week_3z.csv")
    assert len(arch.zones) == 3
    assert arch["DE"].hours == 168
    assert arch["DE"].quality.unit == "g"          # source unit recorded
    with pytest.raises(KeyError):
        arch["FR"]
    with pytest.raises(ValueError):                # ambiguous zone pick
        arch.to_trace()
    t = arch.to_trace(zone="DE")
    assert isinstance(t, TraceSignal) and t.hours == 168.0


def test_zone_filter_on_load():
    arch = load_carbon_archive(sample_archive_path("grid_week_3z.csv"),
                               zone="SE-SE3")
    assert arch.zones == ("SE-SE3",)
    assert isinstance(arch.to_trace(), TraceSignal)   # unambiguous now
    with pytest.raises(ValueError):
        load_carbon_archive(sample_archive_path("grid_week_3z.csv"),
                            zone="XX")


# ----------------------------------------------------------------------
# DST edge cases
# ----------------------------------------------------------------------
def test_dst_spring_forward_gap_interpolated(tmp_path):
    rows = [(ts, "Z", 0.4 + 0.001 * i)
            for i, ts in _hours("2024-03-10T00:00", 30, skip={2})]
    p = _write_csv(tmp_path / "spring.csv", rows)
    arch = load_carbon_archive(p, unit="kg")
    s = arch["Z"]
    q = s.quality
    assert q.gaps_filled == 1 and q.dst_skips == 1
    assert q.gap_runs == (1,) and q.longest_gap_h == 1
    assert s.hours == 30                           # grid is contiguous
    # the skipped hour is the linear midpoint of its neighbours
    assert s.values[2] == pytest.approx(
        (s.values[1] + s.values[3]) / 2.0)


def test_dst_fall_back_duplicate_hour_collapsed(tmp_path):
    fold_vals = iter((0.3, 0.5))                   # the two 01:00 samples
    rows = [(ts, "Z", next(fold_vals) if i == 1 else 0.4)
            for i, ts in _hours("2024-11-03T00:00", 30, repeat={1})]
    p = _write_csv(tmp_path / "fall.csv", rows)
    s = load_carbon_archive(p, unit="kg")["Z"]
    q = s.quality
    assert q.duplicates_collapsed == 1 and q.dst_folds == 1
    assert q.gaps_filled == 0
    assert s.hours == 30
    assert s.values[1] == pytest.approx(0.4)       # mean of the fold


def test_bundled_dst_sample_has_both_defects():
    q = load_sample_archive("dst_week.csv")["US-CAL"].quality
    assert q.dst_skips == 1 and q.gaps_filled == 1
    assert q.dst_folds == 1 and q.duplicates_collapsed == 1


# ----------------------------------------------------------------------
# gap policies
# ----------------------------------------------------------------------
@pytest.fixture
def gappy(tmp_path):
    rows = [(ts, "Z", 0.2 + 0.01 * i)
            for i, ts in _hours("2024-01-01T00:00", 48,
                                skip={10, 11, 12, 13})]
    return _write_csv(tmp_path / "gappy.csv", rows)


def test_gap_policy_interpolate(gappy):
    s = load_carbon_archive(gappy, unit="kg")["Z"]
    assert s.quality.gaps_filled == 4
    assert s.quality.gap_runs == (4,)
    expect = np.interp([10, 11, 12, 13], [9, 14],
                       [s.values[9], s.values[14]])
    assert np.allclose(s.values[10:14], expect)


def test_gap_policy_hold(gappy):
    s = load_carbon_archive(gappy, unit="kg", gap_policy="hold")["Z"]
    assert all(v == s.values[9] for v in s.values[10:14])


def test_gap_policy_raise(gappy):
    with pytest.raises(ValueError, match="missing hour"):
        load_carbon_archive(gappy, unit="kg", gap_policy="raise")
    with pytest.raises(ValueError, match="gap_policy"):
        load_carbon_archive(gappy, unit="kg", gap_policy="zero")


def test_long_gap_rejected_by_to_ensemble(gappy):
    s = load_carbon_archive(gappy, unit="kg")["Z"]
    with pytest.raises(ValueError, match="repaired gap"):
        s.to_ensemble(3)                     # 4h repaired gap > 3h window
    ens = s.to_ensemble(12, 6)               # window covers the gap: fine
    assert isinstance(ens, SignalEnsemble)


# ----------------------------------------------------------------------
# sub-hourly downsampling
# ----------------------------------------------------------------------
def test_subhourly_downsampled_to_hourly(tmp_path):
    t0 = dt.datetime.fromisoformat("2024-06-01T00:00")
    rows = [((t0 + dt.timedelta(minutes=5 * i)).isoformat(), "Z",
             100.0 + (i // 12))                    # grams; constant per hour
            for i in range(12 * 36)]
    p = _write_csv(tmp_path / "fine.csv", rows)
    s = load_carbon_archive(p)["Z"]
    q = s.quality
    assert q.subhourly_minutes == 5
    assert q.duplicates_collapsed == 0             # cadence, not duplication
    assert s.hours == 36
    # in-hour mean of a constant block is that block's value, in kg
    assert s.values[0] == pytest.approx(0.100)
    assert s.values[35] == pytest.approx(0.135)


def test_bundled_5min_sample_downsamples():
    s = load_sample_archive("midwest_5min.json")["US-MISO"]
    assert s.quality.subhourly_minutes == 5
    assert s.hours == 48


# ----------------------------------------------------------------------
# units
# ----------------------------------------------------------------------
def test_mixed_inferred_units_rejected(tmp_path):
    rows = [(ts, "G-LAND", 450.0 + i) for i, ts in
            _hours("2024-01-01T00:00", 24)]
    rows += [(ts, "KG-LAND", 0.45 + 0.001 * i) for i, ts in
             _hours("2024-01-01T00:00", 24)]
    p = _write_csv(tmp_path / "mixed.csv", rows)
    with pytest.raises(ValueError, match="inferred"):
        load_carbon_archive(p)
    # an explicit per-row unit column resolves the same mix fine
    rows_u = [(ts, "G-LAND", 450.0, "gCO2/kWh") for _, ts in
              _hours("2024-01-01T00:00", 24)]
    rows_u += [(ts, "KG-LAND", 0.45, "kgCO2/kWh") for _, ts in
               _hours("2024-01-01T00:00", 24)]
    p2 = _write_csv(tmp_path / "mixed_units.csv", rows_u,
                    header=("datetime", "zone", "carbon_intensity",
                            "unit"))
    arch = load_carbon_archive(p2)
    assert arch["G-LAND"].values[0] == pytest.approx(0.450)
    assert arch["KG-LAND"].values[0] == pytest.approx(0.45)


def test_unit_override_and_lbs_per_mwh(tmp_path):
    rows = [(ts, "WT", 900.0) for _, ts in _hours("2024-01-01T00:00", 24)]
    p = _write_csv(tmp_path / "moer.csv", rows,
                   header=("point_time", "ba", "moer"))
    s = load_carbon_archive(p, unit="lbs/MWh")["WT"]
    assert s.values[0] == pytest.approx(900.0 * 0.453592 / 1000.0)
    with pytest.raises(ValueError, match="unit"):
        load_carbon_archive(p, unit="furlongs")


def test_out_of_order_rows_sorted_and_counted(tmp_path):
    ts = [t for _, t in _hours("2024-01-01T00:00", 6)]
    order = [0, 2, 1, 3, 5, 4]
    rows = [(ts[i], "Z", 0.1 * (i + 1)) for i in order]
    p = _write_csv(tmp_path / "shuffled.csv", rows)
    s = load_carbon_archive(p, unit="kg")["Z"]
    assert s.quality.out_of_order == 2
    assert list(s.values) == pytest.approx([0.1 * (i + 1)
                                            for i in range(6)])


# ----------------------------------------------------------------------
# formats + synthetic writer
# ----------------------------------------------------------------------
def test_json_record_forms(tmp_path):
    recs = [{"datetime": t, "carbon_intensity": 300.0 + i, "unit": "g"}
            for i, t in _hours("2024-01-01T00:00", 24)]
    p1 = tmp_path / "em.json"
    p1.write_text(json.dumps({"zone": "DE", "history": recs}))
    arch = load_carbon_archive(str(p1))
    assert arch.zones == ("DE",)
    assert arch["DE"].values[0] == pytest.approx(0.300)

    p2 = tmp_path / "list.json"
    p2.write_text(json.dumps(recs))
    s = load_carbon_archive(str(p2))["list"]       # zone <- file stem
    assert s.hours == 24

    p3 = tmp_path / "bad.json"
    p3.write_text(json.dumps({"whatever": 1}))
    with pytest.raises(ValueError):
        load_carbon_archive(str(p3))


def test_unix_timestamps_accepted(tmp_path):
    t0 = dt.datetime(2024, 1, 1, tzinfo=dt.timezone.utc)
    rows = [(int((t0 + dt.timedelta(hours=i)).timestamp()), "Z", 0.4)
            for i in range(24)]
    p = _write_csv(tmp_path / "unix.csv", rows)
    assert load_carbon_archive(p, unit="kg")["Z"].hours == 24


def test_synthetic_writer_roundtrip_and_seeding(tmp_path):
    p1 = write_synthetic_archive(str(tmp_path / "a.csv"),
                                 zones=("X", "Y"), days=3, seed=5)
    p2 = write_synthetic_archive(str(tmp_path / "b.csv"),
                                 zones=("X", "Y"), days=3, seed=5)
    a, b = load_carbon_archive(p1), load_carbon_archive(p2)
    assert a.zones == b.zones == ("X", "Y")
    assert a["X"].values == b["X"].values          # seeded determinism
    assert a["X"].quality.clean
    pj = write_synthetic_archive(str(tmp_path / "c.json"),
                                 zones=("X",), days=2, seed=5)
    assert load_carbon_archive(pj)["X"].hours == 48


def test_synthetic_writer_injects_defects(tmp_path):
    p = write_synthetic_archive(str(tmp_path / "d.csv"), zones=("Z",),
                                days=4, seed=1, dst="both", gap=(60, 5))
    q = load_carbon_archive(p)["Z"].quality
    assert q.dst_skips >= 1 and q.dst_folds >= 1
    assert q.longest_gap_h == 5


def test_trace_windows_accepts_trace_signal():
    s = load_sample_archive("grid_week_3z.csv")["DE"]
    via_trace = trace_windows(s.to_trace(), 48, 24)
    via_method = s.to_ensemble(48, 24)
    assert len(via_trace) == len(via_method)
    assert via_trace.members[0].values == via_method.members[0].values


def test_samples_are_small():
    # bundled fixtures must stay repo-friendly
    for name in SAMPLE_ARCHIVES:
        assert os.path.getsize(sample_archive_path(name)) < 200_000
