"""Trace-grid engine + trace-signal tests (the multi_layer_refactor
acceptance bar):

* the trace-grid scan agrees with the periodic 24-slot engine to float
  precision on all six Figure-1 policies, on both backends;
* it agrees with the per-batch oracle to <0.5% on the two case families
  the PR-1 engine rejected with ValueError: progress-aware deadline
  schedules and multi-day non-periodic carbon traces;
* sweep() dispatches mixed case sets to the right path, order preserved;
* satellites: HourlySignal floor fix, bounded engine memo caches,
  periodic-engine boundary cases (day-boundary residual, fractional
  start_hour, price=None) pinned against simulate_campaign_exact.
"""
import math

import numpy as np
import pytest

from repro.core import (BASELINE, GridCarbonModel, HourlySignal,
                        MachineProfile, MIDWEST_HOURLY, PEAK_AWARE_BOOSTED,
                        POLICIES, SweepCase, TimeBands, TraceSignal,
                        as_trace, calibrate_workload, constant_schedule,
                        deadline_schedule, default_signals, hourly_schedule,
                        progress_ramp_schedule, simulate_campaign,
                        simulate_campaign_exact, sweep, trace_sweep)
from repro.core import Campaign
from repro.core.engine import _band_table, _carbon_table
from repro.core.engine_jax import _HAS_JAX
from repro.core.policy import HourlyPolicy
from repro.core.workload import OEM_CASE_1, OEMWorkload


@pytest.fixture(scope="module")
def calibrated():
    return calibrate_workload(OEM_CASE_1, MachineProfile())


def _week_trace(scale: float = 0.448) -> TraceSignal:
    """A 7-day non-periodic carbon trace: diurnal swing + weekday drift +
    deterministic noise (nothing repeats with period 24)."""
    rng = np.random.RandomState(7)
    h = np.arange(168)
    vals = scale * (1.0 + 0.30 * np.sin(2 * np.pi * h / 24.0)
                    + 0.08 * np.sin(2 * np.pi * h / 168.0)
                    + 0.05 * rng.randn(168))
    return TraceSignal(tuple(float(v) for v in vals), name="week")


# ---------------------------------------------------------------------------
# Acceptance: parity with the periodic engine on periodic cases
# ---------------------------------------------------------------------------
def test_trace_engine_matches_periodic_engine_all_six_policies(calibrated):
    """Float-precision agreement on every Figure-1 policy: both engines
    integrate the same piecewise-hourly model, one by day-jump arithmetic,
    one by scanning every hour."""
    wl, m = calibrated
    cases = [SweepCase(p, wl, m) for p in POLICIES.values()]
    periodic = sweep(cases)
    traced = trace_sweep(cases)
    for a, b in zip(periodic, traced):
        assert abs(b.runtime_h / a.runtime_h - 1) < 1e-9, a.policy
        assert abs(b.energy_kwh / a.energy_kwh - 1) < 1e-9, a.policy
        assert abs(b.co2_kg / a.co2_kg - 1) < 1e-9, a.policy


def test_trace_engine_numpy_backend_matches_jax(calibrated):
    """The NumPy fallback runs the identical scan; with JAX present the
    two backends must agree to float64 precision."""
    wl, m = calibrated
    cases = [SweepCase(p, wl, m) for p in (BASELINE, PEAK_AWARE_BOOSTED)]
    cases += [SweepCase(progress_ramp_schedule(0.4, 0.9), wl, m)]
    np_res = trace_sweep(cases, backend="numpy")
    if not _HAS_JAX:
        pytest.skip("jax not importable; numpy fallback already exercised")
    jax_res = trace_sweep(cases, backend="jax")
    for a, b in zip(np_res, jax_res):
        assert abs(b.runtime_h / a.runtime_h - 1) < 1e-12, a.policy
        assert abs(b.energy_kwh / a.energy_kwh - 1) < 1e-12, a.policy


# ---------------------------------------------------------------------------
# Acceptance: the two PR-1 ValueError walls, now first-class cases
# ---------------------------------------------------------------------------
def test_deadline_schedule_sweeps_and_matches_exact_oracle(calibrated):
    """(a) a progress-aware deadline schedule — the periodic engine's
    probe rejects it, sweep() routes it to the trace grid, and the result
    stays within 0.5% of the per-batch oracle."""
    wl, m = calibrated
    sched = deadline_schedule(200.0)
    vec = sweep([SweepCase(sched, wl, m)])[0]
    exact = simulate_campaign_exact(wl, sched, m)
    assert abs(vec.runtime_h / exact.runtime_h - 1) < 0.005
    assert abs(vec.energy_kwh / exact.energy_kwh - 1) < 0.005
    assert abs(vec.co2_kg / exact.co2_kg - 1) < 0.005
    # and the pace-keeper meets its deadline with a small margin
    assert 180.0 < vec.runtime_h < 201.0


def test_week_long_trace_sweeps_and_matches_exact_oracle(calibrated):
    """(b) a 7-day non-periodic carbon trace — unrepresentable on the
    periodic 24-slot grid, exact on the trace grid."""
    wl, m = calibrated
    trace = _week_trace()
    for sched in (BASELINE, PEAK_AWARE_BOOSTED):
        vec = sweep([SweepCase(sched, wl, m, carbon=trace)])[0]
        exact = simulate_campaign_exact(wl, sched, m, carbon=trace)
        assert abs(vec.runtime_h / exact.runtime_h - 1) < 0.005
        assert abs(vec.energy_kwh / exact.energy_kwh - 1) < 0.005
        assert abs(vec.co2_kg / exact.co2_kg - 1) < 0.005
        # the sequential segment simulator handles traces too, and the
        # trace grid matches it to float precision (same hourly model)
        seq = simulate_campaign(wl, sched, m, carbon=trace)
        assert abs(vec.co2_kg / seq.co2_kg - 1) < 1e-9


def test_progress_and_trace_combined(calibrated):
    """Deadline pace-keeping under a week-long carbon trace: both
    previously-impossible features at once."""
    wl, m = calibrated
    sched = deadline_schedule(220.0)
    trace = _week_trace()
    vec = sweep([SweepCase(sched, wl, m, carbon=trace)])[0]
    exact = simulate_campaign_exact(wl, sched, m, carbon=trace)
    assert abs(vec.runtime_h / exact.runtime_h - 1) < 0.005
    assert abs(vec.co2_kg / exact.co2_kg - 1) < 0.005


def test_sweep_dispatch_preserves_order_and_periodic_results(calibrated):
    """A mixed case list: periodic cases keep the fast path's
    float-identical numbers, trace cases slot back in original order."""
    wl, m = calibrated
    ramp = progress_ramp_schedule(0.4, 0.9)
    mixed = [SweepCase(BASELINE, wl, m), SweepCase(ramp, wl, m),
             SweepCase(PEAK_AWARE_BOOSTED, wl, m)]
    res = sweep(mixed)
    assert [r.policy for r in res] == [BASELINE.name, ramp.name,
                                       PEAK_AWARE_BOOSTED.name]
    pure = sweep([mixed[0], mixed[2]])
    assert res[0].energy_kwh == pure[0].energy_kwh
    assert res[2].energy_kwh == pure[1].energy_kwh


def test_campaign_sweep_carbon_trace_and_deadline(calibrated):
    """Campaign.sweep grows carbon_trace= / deadline_h=: an hourly list
    becomes a TraceSignal, and the deadline reaches schedules through
    ctx.deadline_h."""
    trace_vals = list(_week_trace().values)
    c = Campaign(OEM_CASE_1)
    sched = deadline_schedule()          # no own deadline: reads ctx
    res = c.sweep([sched], carbon_trace=trace_vals, deadline_h=200.0)
    assert len(res) == 1
    wl, m = c.calibrated()
    exact = simulate_campaign_exact(wl, sched, m, carbon=_week_trace(),
                                    deadline_h=200.0)
    assert abs(res[0].runtime_h / exact.runtime_h - 1) < 0.005
    assert abs(res[0].co2_kg / exact.co2_kg - 1) < 0.005
    with pytest.raises(ValueError, match="carbon_trace"):
        c.sweep([sched], carbons=[GridCarbonModel()],
                carbon_trace=trace_vals)


def test_heterogeneous_start_hours_and_machines(calibrated):
    """The scan batches a heterogeneous fleet: per-case start_hour and
    machine profiles, each agreeing with its own sequential run."""
    wl, m = calibrated
    m2 = MachineProfile(idle_w=120.0, dyn_w=300.0, alpha=1.5, gamma=0.5)
    trace = _week_trace()
    cases = [SweepCase(BASELINE, wl, m, carbon=trace, start_hour=3.0),
             SweepCase(BASELINE, wl, m2, carbon=trace, start_hour=17.0)]
    res = trace_sweep(cases)
    for case, r in zip(cases, res):
        seq = simulate_campaign(wl, BASELINE, case.machine, carbon=trace,
                                start_hour=case.start_hour)
        assert abs(r.runtime_h / seq.runtime_h - 1) < 1e-9
        assert abs(r.co2_kg / seq.co2_kg - 1) < 1e-9


# ---------------------------------------------------------------------------
# Chunked resumable executor (PR-4): the default engine path scans in
# fixed-shape chunks with state carried across them — it must reproduce
# the monolithic single-scan numbers on this file's own case families.
# Deeper chunking/ensemble coverage lives in tests/test_ensemble.py.
# ---------------------------------------------------------------------------
def test_chunked_executor_matches_monolithic_on_this_files_cases(calibrated):
    wl, m = calibrated
    trace = _week_trace()
    cases = ([SweepCase(p, wl, m) for p in POLICIES.values()]
             + [SweepCase(deadline_schedule(200.0), wl, m, carbon=trace),
                SweepCase(progress_ramp_schedule(0.4, 0.9), wl, m,
                          carbon=trace, start_hour=3.0)])
    for chunk_days in (2, 4):
        chunked = trace_sweep(cases, chunk_days=chunk_days)
        mono = trace_sweep(cases, mode="monolithic")
        for a, b in zip(mono, chunked):
            assert abs(b.runtime_h / a.runtime_h - 1) < 1e-9, a.policy
            assert abs(b.energy_kwh / a.energy_kwh - 1) < 1e-9, a.policy
            assert abs(b.co2_kg / a.co2_kg - 1) < 1e-9, a.policy


def test_sweep_dispatches_ensemble_to_trace_path(calibrated):
    """A SignalEnsemble carbon is never representable on the periodic
    grid: sweep() must route it to the trace engine and attach per-member
    stats, order preserved in a mixed batch."""
    from repro.core import SignalEnsemble
    wl, m = calibrated
    ens = SignalEnsemble((_week_trace(), _week_trace(0.5)))
    mixed = [SweepCase(BASELINE, wl, m),
             SweepCase(BASELINE, wl, m, carbon=ens)]
    res = sweep(mixed)
    assert res[0].co2_ensemble is None
    assert res[1].co2_ensemble is not None
    assert res[1].co2_ensemble.n_members == 2
    assert res[1].co2_kg == pytest.approx(
        np.mean(res[1].co2_ensemble.samples))


# ---------------------------------------------------------------------------
# TraceSignal semantics
# ---------------------------------------------------------------------------
def test_trace_signal_clamps_and_samples():
    t = TraceSignal((1.0, 2.0, 3.0), name="t3")
    assert t.period_h is None
    assert t.at(-5.0) == 1.0             # clamp before range
    assert t.at(0.5) == 1.0
    assert t.at(2.9) == 3.0
    assert t.at(10.0) == 3.0             # hold-last beyond range
    assert list(t.sample([-1.0, 1.5, 99.0])) == [1.0, 2.0, 3.0]
    with pytest.raises(ValueError):
        TraceSignal(())


def test_custom_at_only_signal_routes_to_trace_path(calibrated):
    """A live-feed-style signal implementing only at(hour) — no period_h
    declaration — must not be collapsed onto one repeated day by the
    periodic engine: unknown periodicity routes to the trace grid."""
    wl, m = calibrated

    class DriftingFeed:                  # drifts 0.4 -> 0.7 over a week
        name = "drifting-feed"

        def at(self, hour):
            return 0.4 + 0.3 * min(max(hour / 168.0, 0.0), 1.0)

    feed = DriftingFeed()
    vec = sweep([SweepCase(BASELINE, wl, m, carbon=feed)])[0]
    seq = simulate_campaign(wl, BASELINE, m, carbon=feed)
    assert abs(vec.co2_kg / seq.co2_kg - 1) < 1e-9
    # a signal declaring 24 h periodicity still takes the periodic path
    class DeclaredPeriodic(DriftingFeed):
        period_h = 24.0
    from repro.core import is_periodic_24h
    assert is_periodic_24h(DeclaredPeriodic())
    assert not is_periodic_24h(feed)


def test_as_trace_coerces_sequences():
    t = as_trace([0.4] * 48, name="two-day")
    assert isinstance(t, TraceSignal) and len(t.values) == 48
    assert as_trace(t) is t
    # arrays exposing a non-callable `.at` indexer (jnp, pandas) are
    # sequences, not Signals — they must be converted, not passed through
    if _HAS_JAX:
        import jax.numpy as jnp
        tj = as_trace(jnp.linspace(0.4, 0.7, 48))
        assert isinstance(tj, TraceSignal) and len(tj.values) == 48
    # SignalSet.sample carries traces next to periodic signals
    sigs = default_signals(TimeBands(), GridCarbonModel())
    sigs = type(sigs)(background=sigs.background, carbon=_week_trace())
    assert not sigs.is_periodic()
    bg, cf, pr = sigs.sample([0.0, 30.0, 200.0])
    assert cf[0] == _week_trace().values[0]
    assert cf[2] == _week_trace().values[-1]    # clamped past the trace
    assert pr.tolist() == [0.0, 0.0, 0.0]


# ---------------------------------------------------------------------------
# Satellite: HourlySignal floor fix (and the same bug class elsewhere)
# ---------------------------------------------------------------------------
def test_hourly_signal_negative_and_large_hours():
    vals = tuple(float(h) for h in range(24))
    s = HourlySignal(vals)
    assert s.at(-0.5) == 23.0            # int() used to truncate to slot 0
    assert s.at(-24.5) == 23.0
    assert s.at(-1e-9) == 23.0
    assert s.at(24.5) == 0.0
    assert s.at(47.99) == 23.0
    curve = tuple(1.0 + 0.01 * h for h in range(24))
    g = GridCarbonModel(hourly_curve=curve)
    assert g.factor_at(-0.5) == pytest.approx(0.448 * curve[23])
    p = HourlyPolicy("h", {b: 0.5 for b in ("peak", "load_sensitive",
                                            "shoulder", "night")},
                     50, False, vals)
    assert p.intensity_at_hour(-0.5) == 23.0


# ---------------------------------------------------------------------------
# Satellite: bounded engine memo caches
# ---------------------------------------------------------------------------
def test_engine_caches_are_bounded(calibrated):
    wl, m = calibrated
    maxsize = _band_table.cache_info().maxsize
    assert maxsize is not None and maxsize <= 1024
    variants = [TimeBands(peak=((a, b),))
                for a in range(0, 23) for b in range(a + 1, 24)][:maxsize + 20]
    for bands in variants:
        sweep([SweepCase(BASELINE, wl, m, bands=bands)])
    assert _band_table.cache_info().currsize <= maxsize
    # unhashable hourly curves still work (uncached path)
    curvy = GridCarbonModel(hourly_curve=list(MIDWEST_HOURLY))
    assert _carbon_table(curvy).shape == (24,)


# ---------------------------------------------------------------------------
# Satellite: periodic-engine boundary cases vs the per-batch oracle
# ---------------------------------------------------------------------------
def test_residual_landing_exactly_on_day_boundary():
    """n_scenarios an exact multiple of one day's throughput: zero
    residual, runtime an exact whole number of days."""
    m = MachineProfile(gamma=0.0)        # contention off => exact rates
    wl = OEMWorkload("exact-days", 864_000, rate_at_full=10.0,
                     batch_overhead_s=0.0)
    sched = constant_schedule(0.5)       # 5 scen/s -> 432000/day -> 2 days
    r = sweep([SweepCase(sched, wl, m)])[0]
    assert r.runtime_h == pytest.approx(48.0, abs=1e-9)
    exact = simulate_campaign_exact(wl, sched, m)
    assert abs(r.runtime_h / exact.runtime_h - 1) < 0.005
    assert abs(r.energy_kwh / exact.energy_kwh - 1) < 0.005


def test_fractional_start_hour_partial_leading_slot(calibrated):
    """start_hour=9.5 splits the leading hour across lens[:,0]/lens[:,24];
    pinned against the oracle and float-identical to the sequential path."""
    wl, m = calibrated
    for sched in (PEAK_AWARE_BOOSTED,
                  hourly_schedule("hr", [0.3 + 0.02 * h for h in range(24)])):
        r = sweep([SweepCase(sched, wl, m, start_hour=9.5)])[0]
        exact = simulate_campaign_exact(wl, sched, m, start_hour=9.5)
        seq = simulate_campaign(wl, sched, m, start_hour=9.5)
        assert abs(r.runtime_h / exact.runtime_h - 1) < 0.005, sched.name
        assert abs(r.energy_kwh / exact.energy_kwh - 1) < 0.005, sched.name
        assert abs(r.energy_kwh / seq.energy_kwh - 1) < 1e-9, sched.name


def test_price_none_leaves_cost_none(calibrated):
    """No price signal => cost_usd stays None (not 0.0) on every path."""
    wl, m = calibrated
    assert sweep([SweepCase(BASELINE, wl, m)])[0].cost_usd is None
    assert trace_sweep([SweepCase(BASELINE, wl, m,
                                  carbon=_week_trace())])[0].cost_usd is None
    assert simulate_campaign_exact(wl, BASELINE, m).cost_usd is None


# ---------------------------------------------------------------------------
# Satellite: mixed-resolution sweeps in one process (PR-2 memo-cache audit).
# The sph-keyed caches (_bg_table) were fine, but the closed-form profile
# path crashed on sub-hour band edges (periodic_decision_profile sampled
# through the hourly-only _band_table) and mixed-resolution batches used
# max() instead of lcm() to pick the shared grid.
# ---------------------------------------------------------------------------
def test_sub_hour_band_edges_on_trace_path(calibrated):
    """Band policies with sub-hour edges route to the trace grid and match
    the sequential simulator (used to raise the periodic engine's
    'cannot represent sub-hour band edges' ValueError)."""
    wl, m = calibrated
    bands = TimeBands(peak=((14.5, 19),),
                      load_sensitive=((11, 14.5), (19, 21)))
    r = sweep([SweepCase(PEAK_AWARE_BOOSTED, wl, m, bands=bands)])[0]
    seq = simulate_campaign(wl, PEAK_AWARE_BOOSTED, m, bands=bands)
    assert abs(r.energy_kwh / seq.energy_kwh - 1) < 1e-9
    assert abs(r.runtime_h / seq.runtime_h - 1) < 1e-9


def test_hourly_profile_still_rejects_sub_hour_bands(calibrated):
    """The periodic-only helper keeps its guard: sampling sub-hour band
    edges on an incompatible grid raises instead of silently aliasing
    the edge onto the previous band (docs/API.md migration note)."""
    from repro.core import hourly_profile
    bands = TimeBands(peak=((14.5, 19),),
                      load_sensitive=((11, 14.5), (19, 21)))
    with pytest.raises(ValueError, match="alias|band edges"):
        hourly_profile(PEAK_AWARE_BOOSTED, bands, GridCarbonModel())


def test_mixed_resolution_sweeps_in_one_process(calibrated):
    """Alternating grid resolutions through the same memoization caches:
    hourly, half-hour, hourly again, quarter-hour — every sweep must
    match its own sequential run (a cache key ignoring slots_per_hour
    would replay the wrong resolution's tables)."""
    wl, m = calibrated
    half = TimeBands(peak=((14.5, 19),),
                     load_sensitive=((11, 14.5), (19, 21)))
    quarter = TimeBands(peak=((14.25, 19),),
                        load_sensitive=((11, 14.25), (19, 21)))
    for bands in (TimeBands(), half, TimeBands(), quarter, half):
        r = sweep([SweepCase(PEAK_AWARE_BOOSTED, wl, m, bands=bands)])[0]
        seq = simulate_campaign(wl, PEAK_AWARE_BOOSTED, m, bands=bands)
        assert abs(r.energy_kwh / seq.energy_kwh - 1) < 1e-9, bands.peak


def test_mixed_resolutions_in_one_batch_use_lcm_grid(calibrated):
    """One sweep() call mixing a half-hour case and a third-hour case:
    the shared trace grid must refine to lcm (6 slots/hour), not max."""
    wl, m = calibrated
    half = TimeBands(peak=((14.5, 19),),
                     load_sensitive=((11, 14.5), (19, 21)))
    third = TimeBands(peak=((43.0 / 3.0, 19),),
                      load_sensitive=((11, 43.0 / 3.0), (19, 21)))
    cases = [SweepCase(PEAK_AWARE_BOOSTED, wl, m, bands=half),
             SweepCase(PEAK_AWARE_BOOSTED, wl, m, bands=third)]
    res = sweep(cases)
    for case, r in zip(cases, res):
        seq = simulate_campaign(wl, PEAK_AWARE_BOOSTED, m, bands=case.bands)
        assert abs(r.energy_kwh / seq.energy_kwh - 1) < 1e-9


def test_sub_hour_parametric_schedule_forces_trace_dispatch(calibrated):
    """The dispatcher hook: a 48-slot ParametricSchedule advertises
    half-hour change hours, so its case needs slots_per_hour=2 and the
    trace path — sampling it hourly would alias away every second slot."""
    from repro.core.engine import case_slots_per_hour
    from repro.core.schedule import ParametricSchedule
    wl, m = calibrated
    ps = ParametricSchedule.from_intensities(
        [0.3 + 0.5 * math.sin(2 * math.pi * i / 48) ** 2 for i in range(48)],
        name="p48")
    case = SweepCase(ps, wl, m)
    assert case_slots_per_hour(case) == 2
    r = sweep([case])[0]
    seq = simulate_campaign(wl, ps, m)
    assert abs(r.energy_kwh / seq.energy_kwh - 1) < 1e-9
    assert abs(r.runtime_h / seq.runtime_h - 1) < 1e-9


# ---------------------------------------------------------------------------
# deadline_schedule behaviour
# ---------------------------------------------------------------------------
def test_deadline_schedule_paces_toward_deadline(calibrated):
    """A generous deadline is met near-exactly (the keeper slows down to
    it); an infeasible one degrades gracefully to ~flat-out runtime."""
    wl, m = calibrated
    generous = simulate_campaign(wl, deadline_schedule(260.0), m)
    assert 230.0 < generous.runtime_h < 261.0
    flat_out = simulate_campaign(wl, constant_schedule(0.95), m)
    tight = simulate_campaign(wl, deadline_schedule(100.0), m)
    assert tight.runtime_h < flat_out.runtime_h * 1.1
    # pacing draws far less average power than flat-out (total kWh still
    # grows with runtime here: whole-machine energy includes idle draw)
    assert (generous.energy_kwh / generous.runtime_h
            < 0.8 * flat_out.energy_kwh / flat_out.runtime_h)
    # no deadline anywhere -> flat out at u_high
    free = simulate_campaign(wl, deadline_schedule(), m)
    assert math.isclose(
        free.runtime_h,
        simulate_campaign(wl, constant_schedule(0.95), m).runtime_h,
        rel_tol=1e-9)
