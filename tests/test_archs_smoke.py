"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes + finite values (assignment
requirement), plus decode/prefill paths and prefill->decode consistency.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model

B, S = 2, 32


def make_batch(cfg, key=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_vision_tokens, cfg.d_model),
            jnp.bfloat16)
    if cfg.encdec:
        batch = {"frames": jax.random.normal(jax.random.PRNGKey(2),
                                             (B, S, cfg.d_model), jnp.bfloat16),
                 "tokens": jax.random.randint(jax.random.PRNGKey(key),
                                              (B, cfg.dec_train_len), 0,
                                              cfg.vocab_size)}
    return batch


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name, smoke=True)
            m = build_model(cfg)
            cache[name] = (cfg, m, m.init(jax.random.PRNGKey(0)))
        return cache[name]
    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_loss_finite(models, name):
    cfg, m, params = models(name)
    loss, metrics = jax.jit(m.loss)(params, make_batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    assert bool(jnp.isfinite(metrics["acc"]))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_no_nans(models, name):
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.training.step import make_train_step
    cfg, m, params = models(name)
    opt = AdamWConfig(total_steps=10, warmup_steps=2)
    state = {"params": params, "opt": init_opt_state(params, opt)}
    step = jax.jit(make_train_step(m, opt))
    state, metrics = step(state, make_batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step_shapes(models, name):
    cfg, m, params = models(name)
    cache = m.cache_zeros(B, 48)
    logits, cache2 = jax.jit(m.decode_step)(params, cache,
                                            jnp.ones((B, 1), jnp.int32), 5)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "deepseek-v2-lite-16b",
                                  "falcon-mamba-7b", "recurrentgemma-9b"])
def test_prefill_decode_consistency(models, name):
    """Greedy continuation: prefill(prompt) + decode steps must match the
    teacher-forced forward pass over the same tokens (scan-vs-step)."""
    cfg, m, params = models(name)
    s_prompt, n_extra = 16, 4
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, s_prompt + n_extra),
                                0, cfg.vocab_size)
    # teacher-forced logits over the full sequence
    full, _ = m.prefill(params, {"tokens": tokens})
    # incremental: prefill the prompt, then feed the next tokens one by one
    logits_p, pc = m.prefill(params, {"tokens": tokens[:, :s_prompt]})
    from repro.serving.engine import _write_slot
    cache = m.cache_zeros(1, s_prompt + n_extra + 4)
    cache = _write_slot(cache, pc, 0, cfg, s_prompt)
    last = None
    for i in range(n_extra):
        tok = tokens[:, s_prompt + i][:, None]
        last, cache = m.decode_step(params, cache, tok, s_prompt + i)
    # last decode logits == teacher-forced logits at the last position
    ref = full  # prefill returns last-position logits
    got = last[:, 0]
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < 0.15, (name, err)   # bf16 accumulation tolerance
    # and argmax agrees
    assert int(jnp.argmax(got)) == int(jnp.argmax(ref)), name
