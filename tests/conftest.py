import os

# Tests run single-device CPU (the dry-run sets its own 512-device flags in a
# separate process).  A couple of multi-device tests spawn subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
