import importlib.util
import os
import sys

# Tests run single-device CPU (the dry-run sets its own 512-device flags in a
# separate process).  A couple of multi-device tests spawn subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# The container has no `hypothesis`; fall back to the deterministic shim in
# tests/_hypothesis_fallback.py so the property-based modules still collect
# and run.  Real hypothesis, when installed (e.g. in CI), always wins.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
