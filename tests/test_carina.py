"""CARINA core tests: tracker invariants, carbon translation, energy models,
policy frontier vs the paper's claims (the §Paper-validation table), and
property-based invariants via hypothesis.
"""
import dataclasses
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BASELINE, DTE_FACTOR, GridCarbonModel, ChipProfile,
                        EnergyModel, MachineProfile, POLICIES, RunTracker,
                        StepCost, TimeBands, merge_summaries, policy_frontier,
                        simulate_campaign, calibrate_workload)
from repro.core.workload import OEM_CASE_1, OEM_CASE_2


# ---------------------------------------------------------------------------
# Paper-validation: the claims table from DESIGN.md §1
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def frontier_case1():
    return {r.policy: r for r in policy_frontier(OEM_CASE_1)}


def test_baseline_matches_measured_case1(frontier_case1):
    b = frontier_case1["baseline"]
    assert abs(b.runtime_h - 180.30) / 180.30 < 0.01
    assert abs(b.energy_kwh - 48.67) / 48.67 < 0.01
    # implied carbon: ~21.8 kg at the DTE factor
    assert abs(b.co2_kg - 21.8) < 0.3


def test_baseline_matches_measured_case2():
    res = {r.policy: r for r in policy_frontier(OEM_CASE_2)}
    b = res["baseline"]
    assert abs(b.runtime_h - 274.75) / 274.75 < 0.01
    assert abs(b.energy_kwh - 74.16) / 74.16 < 0.01
    assert abs(b.co2_kg - 33.2) < 0.4


def test_boosted_offhours_matches_paper_case1(frontier_case1):
    """Paper: ~9% energy savings for ~7% runtime overhead."""
    r = frontier_case1["peak_aware_boosted_offhours"]
    assert -11.5 <= r.energy_delta_pct <= -7.0, r.energy_delta_pct
    assert 4.5 <= r.runtime_delta_pct <= 9.5, r.runtime_delta_pct


def test_aggressive_largest_savings_highest_cost(frontier_case1):
    r = frontier_case1
    ag, bo = r["peak_aware_aggressive"], r["peak_aware_boosted_offhours"]
    assert ag.energy_delta_pct <= bo.energy_delta_pct      # most savings
    assert ag.runtime_delta_pct > bo.runtime_delta_pct     # most overhead


def test_low_priority_increases_energy(frontier_case1):
    """Paper: 'low-priority only slightly increases total energy use'."""
    r = frontier_case1["low_priority_only"]
    assert 0.0 < r.energy_delta_pct < 4.0


def test_small_batches_worse_than_low_priority(frontier_case1):
    r = frontier_case1
    assert (r["small_batches_25"].energy_delta_pct
            > r["low_priority_only"].energy_delta_pct)


def test_large_batches_improve_both(frontier_case1):
    r = frontier_case1["large_batches_100"]
    assert r.energy_delta_pct < 0 and r.runtime_delta_pct < 0


def test_boosted_applied_to_cases_close_to_paper(frontier_case1):
    """Paper: boosted reduces case 1 to ~44.3 kWh (we land within 1.5 kWh)."""
    assert abs(frontier_case1["peak_aware_boosted_offhours"].energy_kwh
               - 44.3) < 1.5


def test_implied_grid_factor():
    assert abs(21.8 / 48.67 - DTE_FACTOR) < 1e-3
    assert abs(33.2 / 74.16 - DTE_FACTOR) < 1e-3


# ---------------------------------------------------------------------------
# Tracker / carbon invariants (hypothesis)
# ---------------------------------------------------------------------------
@given(st.lists(st.tuples(st.floats(0.1, 1e4), st.floats(1e-6, 10.0)),
                min_size=1, max_size=40))
@settings(max_examples=50, deadline=None)
def test_tracker_totals_additive(units):
    t = RunTracker("prop")
    for i, (rt, kwh) in enumerate(units):
        t.record_unit(phase="night", intensity=1.0, runtime_s=rt,
                      energy_kwh=kwh, sim_time_h=float(i))
    s = t.summary()
    assert math.isclose(s.energy_kwh, sum(u[1] for u in units), rel_tol=1e-9)
    assert math.isclose(s.runtime_h, sum(u[0] for u in units) / 3600.0,
                        rel_tol=1e-9)
    # carbon = factor * kwh (flat curve)
    assert math.isclose(s.co2_kg, DTE_FACTOR * s.energy_kwh, rel_tol=1e-9)


@given(st.lists(st.integers(1, 5), min_size=2, max_size=5))
@settings(max_examples=20, deadline=None)
def test_merge_summaries_associative(sizes):
    def mk(n, name):
        t = RunTracker(name)
        for i in range(n):
            t.record_unit(phase="peak", intensity=0.5, runtime_s=10.0,
                          energy_kwh=0.01, sim_time_h=float(i))
        return t.summary()
    summaries = [mk(n, f"s{i}") for i, n in enumerate(sizes)]
    a = merge_summaries(summaries)
    b = merge_summaries([merge_summaries(summaries[:2])] + summaries[2:])
    assert math.isclose(a.energy_kwh, b.energy_kwh, rel_tol=1e-12)
    assert a.units == b.units


@given(st.floats(0.0, 1.0), st.floats(0.0, 0.8), st.floats(1.0, 1e4))
@settings(max_examples=100, deadline=None)
def test_power_at_least_idle(u, b, secs):
    m = MachineProfile()
    em = EnergyModel(machine=m)
    kwh = em.runtime_energy_kwh(secs, u, b)
    assert kwh >= m.idle_w * secs / 3.6e6 - 1e-12


@given(st.floats(1e9, 1e15), st.floats(1e6, 1e13), st.floats(0.0, 1e12),
       st.floats(0.05, 1.0))
@settings(max_examples=100, deadline=None)
def test_step_energy_monotone_in_work(flops, hbm, ici, duty):
    em = EnergyModel()
    c1 = StepCost(flops, hbm, ici, chips=4)
    c2 = StepCost(flops * 2, hbm, ici, chips=4)
    assert em.step_energy_j(c2, duty) >= em.step_energy_j(c1, duty)
    # lower duty (more idle stretch) never decreases energy
    assert em.step_energy_j(c1, duty) >= em.step_energy_j(c1, 1.0) - 1e-9


@given(st.floats(0.0, 23.99))
@settings(max_examples=100, deadline=None)
def test_bands_partition_the_day(hour):
    bands = TimeBands()
    assert bands.band_at(hour) in ("peak", "load_sensitive", "shoulder", "night")
    assert sum(bands.hours_per_day().values()) == 24.0


@given(st.floats(0.05, 1.0), st.floats(0.05, 1.0))
@settings(max_examples=30, deadline=None)
def test_campaign_runtime_monotone_in_intensity(u1, u2):
    """Higher constant intensity never runs longer."""
    from repro.core.policy import Policy, BANDS
    wl, machine = calibrate_workload(OEM_CASE_1, MachineProfile())
    lo, hi = sorted((u1, u2))
    p_lo = Policy("lo", {b: lo for b in BANDS})
    p_hi = Policy("hi", {b: hi for b in BANDS})
    r_lo = simulate_campaign(wl, p_lo, machine)
    r_hi = simulate_campaign(wl, p_hi, machine)
    assert r_hi.runtime_h <= r_lo.runtime_h * 1.0001


def test_roofline_bottleneck_identification():
    c = StepCost(flops=197e12, hbm_bytes=1e9, ici_bytes=0, chips=1)
    assert c.bottleneck() == "compute"
    c = StepCost(flops=1e9, hbm_bytes=819e9, ici_bytes=0, chips=1)
    assert c.bottleneck() == "memory"
    c = StepCost(flops=1e9, hbm_bytes=1e6, ici_bytes=50e9, chips=1)
    assert c.bottleneck() == "collective"


def test_time_varying_carbon_curve():
    from repro.core.carbon import MIDWEST_HOURLY
    g = GridCarbonModel(hourly_curve=MIDWEST_HOURLY)
    assert g.co2_kg(1.0, hour_of_day=17) > g.co2_kg(1.0, hour_of_day=3)


# ---------------------------------------------------------------------------
# Beyond-paper: time-varying carbon-intensity scheduling (paper's future work)
# ---------------------------------------------------------------------------
def test_carbon_weighted_dominates_boosted():
    """The carbon-weighted hybrid must dominate plain boosted on runtime,
    energy and CO2e under the time-varying Midwest grid curve."""
    from repro.core.carbon import MIDWEST_HOURLY
    from repro.core.policy import PEAK_AWARE_BOOSTED, make_carbon_weighted_boosted
    from repro.core.workload import OEM_CASE_1

    carbon = GridCarbonModel(hourly_curve=MIDWEST_HOURLY)
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    hybrid = make_carbon_weighted_boosted(carbon)
    r_b = simulate_campaign(wl, PEAK_AWARE_BOOSTED, m, carbon=carbon)
    r_h = simulate_campaign(wl, hybrid, m, carbon=carbon)
    assert r_h.runtime_h <= r_b.runtime_h * 1.001
    assert r_h.energy_kwh <= r_b.energy_kwh * 1.001
    assert r_h.co2_kg < r_b.co2_kg


def test_carbon_aware_dynamic_saves_co2_vs_baseline():
    from repro.core.carbon import MIDWEST_HOURLY
    from repro.core.policy import make_carbon_aware_policy
    from repro.core.workload import OEM_CASE_1

    carbon = GridCarbonModel(hourly_curve=MIDWEST_HOURLY)
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    r_base = simulate_campaign(wl, BASELINE, m, carbon=carbon)
    r_ca = simulate_campaign(wl, make_carbon_aware_policy(carbon), m,
                             carbon=carbon)
    assert r_ca.co2_kg < r_base.co2_kg * 0.95


def test_segment_simulation_matches_exact_batchwise():
    """The fast band-segment simulator must agree with the atomic per-batch
    reference to <0.5% on runtime/energy/CO2 for every policy."""
    from repro.core.simulator import simulate_campaign_exact
    from repro.core.workload import OEM_CASE_1

    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    for p in POLICIES.values():
        fast = simulate_campaign(wl, p, m)
        exact = simulate_campaign_exact(wl, p, m)
        assert abs(fast.runtime_h / exact.runtime_h - 1) < 0.005, p.name
        assert abs(fast.energy_kwh / exact.energy_kwh - 1) < 0.005, p.name
        assert abs(fast.co2_kg / exact.co2_kg - 1) < 0.005, p.name
