"""Distributed runtime tests: sharding rules, checkpoint roundtrip incl.
cross-mesh elastic restore, fault-tolerant training loop, int8 ring
all-reduce, overlap helper, compressed-DP step.  Multi-device cases run in
subprocesses (device count is locked at first jax init).
"""
import json
import math
import os
import subprocess
import sys
import tempfile
import textwrap

import jax

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


# ---------------------------------------------------------------------------
def test_resolve_pspec_divisibility_fallback():
    from repro.distributed.sharding import resolve_pspec
    code = """
    import jax
    from repro.distributed.sharding import resolve_pspec
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    # heads=6 not divisible by model=2? it is; kv=3 is not
    print(resolve_pspec((16, 6, 8), ("embed", "heads", None), mesh))
    print(resolve_pspec((16, 3, 8), ("embed", "kv_heads", None), mesh))
    print(resolve_pspec((100, 16), ("vocab", "embed"), mesh))
    """
    out = run_subprocess(code, devices=8)
    lines = out.strip().splitlines()
    assert "'model'" in lines[0]                    # heads sharded
    assert "'model'" not in lines[1]                # kv=3 replicated
    assert "'model'" in lines[2] and "'data'" in lines[2]


def test_checkpoint_roundtrip_identity():
    from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16) * 1.5,
                  "d": jnp.zeros((), jnp.int32) + 7}}
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 3, tree, {"step": 3})
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        got, meta = restore_checkpoint(td, like)
        assert meta["step"] == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_checkpoint_roundtrip_property(seed):
    from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint
    rng = np.random.default_rng(seed)
    tree = {"w": jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32)),
            "s": jnp.asarray(rng.integers(0, 100), jnp.int32)}
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 1, tree)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        got, _ = restore_checkpoint(td, like)
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(got["w"]))
        assert int(tree["s"]) == int(got["s"])


def test_checkpoint_keep_k_and_latest():
    from repro.checkpoint.checkpoint import latest_step, save_checkpoint
    tree = {"x": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as td:
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(td, s, tree, keep=2)
        assert latest_step(td) == 5
        dirs = sorted(d for d in os.listdir(td) if d.startswith("step_"))
        assert len(dirs) == 2


def test_elastic_cross_mesh_restore():
    """Save on an 8-device mesh, restore on 4 devices (elastic shrink)."""
    code = """
    import jax, jax.numpy as jnp, numpy as np, tempfile, os
    from repro.configs import get_config
    from repro.models import build_model, layers as L
    from repro.optim.adamw import AdamWConfig
    from repro.data.pipeline import SyntheticLM
    from repro.training.loop import LoopConfig, run_training
    from repro.launch.mesh import make_mesh_for

    cfg = get_config('tinyllama-1.1b', smoke=True)
    model = build_model(cfg)
    opt = AdamWConfig(total_steps=6, warmup_steps=1)
    data = SyntheticLM(cfg, batch=8, seq=16)
    def mesh_fn(r):
        m = make_mesh_for(r)
        L.set_activation_sharding(m)
        return m
    td = tempfile.mkdtemp()
    r1 = run_training(model, opt, data, LoopConfig(total_steps=3,
                      steps_per_unit=3, ckpt_dir=td),
                      mesh_fn=mesh_fn, initial_replicas=8)
    r2 = run_training(model, opt, data, LoopConfig(total_steps=6,
                      steps_per_unit=3, ckpt_dir=td),
                      mesh_fn=mesh_fn, initial_replicas=4)
    assert r2.final_step == 6
    print('OK', r1.final_step, r2.final_step)
    """
    out = run_subprocess(code, devices=8)
    assert "OK 3 6" in out


def test_failure_injection_and_restart():
    code = """
    import tempfile
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.data.pipeline import SyntheticLM
    from repro.training.loop import LoopConfig, run_training
    from repro.distributed.fault_tolerance import FailureInjector, Supervisor

    cfg = get_config('tinyllama-1.1b', smoke=True)
    model = build_model(cfg)
    opt = AdamWConfig(total_steps=20, warmup_steps=2)
    data = SyntheticLM(cfg, batch=4, seq=16)
    td = tempfile.mkdtemp()
    res = run_training(model, opt, data,
                       LoopConfig(total_steps=20, steps_per_unit=4, ckpt_dir=td),
                       injector=FailureInjector(fail_at_steps=(6, 13)),
                       supervisor=Supervisor(elastic=False))
    assert res.final_step == 20 and res.restarts == 2
    print('OK', res.final_step, res.restarts)
    """
    out = run_subprocess(code, devices=1)
    assert "OK 20 2" in out


def test_restart_budget_exhaustion():
    from repro.distributed.fault_tolerance import Supervisor, WorkerFailure
    s = Supervisor(max_restarts=2, elastic=False)
    s.on_failure(1, 4, WorkerFailure("x"))
    s.on_failure(2, 4, WorkerFailure("x"))
    with pytest.raises(RuntimeError, match="budget"):
        s.on_failure(3, 4, WorkerFailure("x"))


def test_straggler_detector():
    from repro.distributed.fault_tolerance import StragglerDetector
    d = StragglerDetector(threshold=2.0, policy="exclude")
    for i in range(10):
        assert d.observe(i, 1.0) is None
    ev = d.observe(10, 5.0)
    assert ev is not None and d.should_exclude(ev)
    assert d.observe(11, 1.0) is None


# ---------------------------------------------------------------------------
def test_int8_ring_allreduce_and_compressed_step():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.distributed.collectives import int8_ring_allreduce, \
        allgather_matmul_overlapped

    mesh = jax.make_mesh((8,), ('data',))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

    def f(xs):
        return int8_ring_allreduce(xs[0], 'data')   # same value all shards

    # each shard contributes its row; compare vs exact sum
    y = shard_map(lambda xs: int8_ring_allreduce(xs, 'data')[None],
                      mesh=mesh, in_specs=P('data', None),
                      out_specs=P('data', None), check_vma=False)(x)
    exact = np.asarray(x).sum(0)
    got = np.asarray(y)[0]
    rel = np.abs(got - exact).max() / (np.abs(exact).max() + 1e-9)
    assert rel < 0.05, rel           # int8 quantization tolerance
    for r in range(1, 8):            # every rank agrees
        np.testing.assert_allclose(np.asarray(y)[r], got, rtol=1e-6)

    # overlapped all-gather matmul == plain matmul
    k, f_ = 64, 32
    xx = jax.random.normal(jax.random.PRNGKey(1), (16, k))
    w = jax.random.normal(jax.random.PRNGKey(2), (k, f_)) * 0.1
    y2 = shard_map(
        lambda w_s: allgather_matmul_overlapped(xx, w_s, 'data'),
        mesh=mesh, in_specs=P('data', None), out_specs=P(), check_vma=False)(w)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(xx @ w),
                               rtol=1e-4, atol=1e-4)
    print('OK')
    """
    out = run_subprocess(code, devices=8)
    assert "OK" in out


def test_dp_compressed_train_step_decreases_loss():
    code = """
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.training.step import make_dp_compressed_step, \
        init_dp_compressed_state
    from repro.data.pipeline import SyntheticLM

    mesh = jax.make_mesh((4,), ('data',))
    cfg = get_config('tinyllama-1.1b', smoke=True)
    model = build_model(cfg)
    opt = AdamWConfig(total_steps=30, warmup_steps=2, peak_lr=1e-3)
    state = init_dp_compressed_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_dp_compressed_step(model, opt, mesh))
    data = SyntheticLM(cfg, batch=8, seq=16)
    losses = []
    with mesh:
        for i in range(15):
            batch = jax.tree.map(jnp.asarray, data.batch_at(0))  # same batch
            state, m = step(state, batch)
            losses.append(float(m['loss']))
    assert losses[-1] < losses[0], losses
    print('OK', round(losses[0], 3), round(losses[-1], 3))
    """
    out = run_subprocess(code, devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
def test_data_pipeline_determinism_and_sharding():
    from repro.configs import get_config
    from repro.data.pipeline import Prefetcher, SyntheticLM, synth_tokens
    cfg = get_config("tinyllama-1.1b", smoke=True)
    d = SyntheticLM(cfg, batch=4, seq=16, seed=7)
    a = d.batch_at(3)["tokens"]
    b = d.batch_at(3)["tokens"]
    np.testing.assert_array_equal(a, b)
    # row-sharded regeneration equals the full batch's rows
    shard = synth_tokens(7, 3, 2, 16, cfg.vocab_size, start_row=2)
    np.testing.assert_array_equal(a[2:4], shard)
    # prefetcher yields the same stream
    pf = Prefetcher(d.iterate(0), depth=2)
    first = next(pf)["tokens"]
    np.testing.assert_array_equal(first, d.batch_at(0)["tokens"])
    pf.close()
