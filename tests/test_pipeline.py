"""GPipe pipeline parallelism: forward equivalence vs sequential stages,
differentiability through the ppermute schedule, bubble accounting."""
import functools
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_bubble_fraction():
    from repro.distributed.pipeline import bubble_fraction
    assert bubble_fraction(1, 1) == 0.0
    assert abs(bubble_fraction(4, 4) - 3 / 7) < 1e-12
    assert bubble_fraction(32, 4) < 0.09


def test_pipeline_forward_and_grad():
    code = """
    import jax, jax.numpy as jnp, functools
    from repro.distributed.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ('pipe',))
    P_, L_per, d = 4, 2, 16
    ws = jax.random.normal(jax.random.PRNGKey(0), (P_, L_per, d, d)) * 0.3

    def stage_fn(params, x):
        for i in range(L_per):
            x = jnp.tanh(x @ params[i])
        return x

    x = jax.random.normal(jax.random.PRNGKey(1), (8, d))
    y = pipeline_apply(mesh, stage_fn, ws, x, n_micro=4)
    ref = functools.reduce(lambda a, s: stage_fn(ws[s], a), range(P_), x)
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-5
    g = jax.grad(lambda w: jnp.sum(pipeline_apply(mesh, stage_fn, w, x, 4)))(ws)
    gr = jax.grad(lambda w: jnp.sum(
        functools.reduce(lambda a, s: stage_fn(w[s], a), range(P_), x)))(ws)
    assert float(jnp.max(jnp.abs(g - gr))) < 1e-4
    print('OK')
    """
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "OK" in p.stdout
