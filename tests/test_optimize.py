"""Schedule-optimizer tests (the PR-3 acceptance bar):

* gradient search recovers the closed-form optimum of a two-band toy
  case (convex power, no contention, no overhead) to <1%;
* the vmapped population/CEM search matches gradient search on the same
  smooth family;
* `Campaign.optimize` finds a schedule for the OEM case-1 workload under
  a week-long carbon trace whose energy beats every fixed Figure-1
  policy at an equal deadline;
* the ParametricSchedule family, the pure `TraceObjective`/
  `evaluate_params` path (grad/vmap-compatible, engine-consistent), and
  Pareto-frontier extraction.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import (Campaign, MachineProfile, POLICIES, SweepCase,
                        TimeBands, TraceSignal, HourlySignal, trace_sweep)
from repro.core.engine_jax import _HAS_JAX, TraceObjective, evaluate_params
from repro.core.optimize import (Objective, canonical_metric,
                                 optimize_schedule, pareto_front)
from repro.core.schedule import ParametricSchedule, parametric_schedule
from repro.core.workload import OEM_CASE_1, OEMWorkload


class QuietBands(TimeBands):
    """Background load off: the analytic toy needs u to be the only load."""

    def background(self, band: str) -> float:
        return 0.0


def _toy_case():
    """Two-band toy with a closed-form optimum.

    idle=0, alpha=2, gamma=0, no batch overhead, zero background; carbon
    is c1=1.0 for hours 0-11 and c2=0.2 for 12-23; deadline one day.
    Minimizing CO2 = dyn * sum_i c_i u_i^2 tau_i subject to
    R * sum_i u_i tau_i = W gives u_i ∝ 1/c_i, so
    CO2* = dyn W^2 / (R^2 sum_i tau_i / c_i).
    """
    m = MachineProfile(idle_w=0.0, dyn_w=200.0, alpha=2.0, gamma=0.0)
    wl = OEMWorkload("toy", 388_800, rate_at_full=10.0, batch_overhead_s=0.0)
    carbon = HourlySignal(tuple([1.0] * 12 + [0.2] * 12), name="two-band")
    case = SweepCase(parametric_schedule(24), wl, m, QuietBands(), carbon,
                     start_hour=0.0, deadline_h=24.0)
    tau = 12 * 3600.0
    co2_star = (m.dyn_w * wl.n_scenarios ** 2
                / (wl.rate_at_full ** 2 * tau * (1 / 1.0 + 1 / 0.2))) / 3.6e6
    return case, co2_star


@pytest.fixture(scope="module")
def toy():
    return _toy_case()


@pytest.fixture(scope="module")
def calibrated_oem():
    from repro.core import calibrate_workload
    return calibrate_workload(OEM_CASE_1, MachineProfile())


@pytest.fixture(scope="module")
def week_trace():
    rng = np.random.RandomState(7)
    h = np.arange(168)
    vals = 0.448 * (1.0 + 0.30 * np.sin(2 * np.pi * h / 24.0)
                    + 0.08 * np.sin(2 * np.pi * h / 168.0)
                    + 0.05 * rng.randn(168))
    return TraceSignal(tuple(float(v) for v in vals), name="week")


# ---------------------------------------------------------------------------
# Acceptance: analytic optimum, grad vs population, beats the Figure-1 set
# ---------------------------------------------------------------------------
@pytest.mark.skipif(not _HAS_JAX, reason="gradient search needs jax")
def test_grad_recovers_analytic_two_band_optimum(toy):
    case, co2_star = toy
    res = optimize_schedule(case, "co2", {"runtime_h": 24.0}, method="grad",
                            u_min=0.02, u_max=1.0, steps=800, lr=0.1,
                            horizon_h=30.0)
    assert res.metrics.unfinished < 1e-9
    assert res.metrics.runtime_h <= 24.0 * 1.005
    assert abs(res.metrics.co2_kg / co2_star - 1) < 0.01
    # and the found structure is the analytic one: u ∝ 1/c per band
    u = res.schedule.intensity_table()
    assert u[:12].mean() < 0.5 * u[12:].mean()


def test_population_matches_grad_on_smooth_family(toy):
    """CEM needs no gradients but must land on the same optimum for the
    smooth per-slot family (within a percent of the analytic value)."""
    case, co2_star = toy
    res = optimize_schedule(case, "co2", {"runtime_h": 24.0}, method="cem",
                            u_min=0.02, u_max=1.0, candidates=256,
                            iterations=60, horizon_h=30.0, seed=1)
    assert res.evaluations >= 256 * 60
    assert res.metrics.runtime_h <= 24.0 * 1.005
    assert abs(res.metrics.co2_kg / co2_star - 1) < 0.01


def test_cem_runs_on_numpy_backend(toy):
    """The population search must not require jax (NumPy scan fallback)."""
    case, _ = toy
    res = optimize_schedule(case, "co2", {"runtime_h": 24.0}, method="cem",
                            u_min=0.02, u_max=1.0, candidates=64,
                            iterations=8, horizon_h=30.0, seed=2,
                            backend="numpy")
    assert res.method == "cem"
    assert res.metrics.unfinished < 1e-9
    # 8 cheap iterations already beat the flat seed
    flat = TraceObjective(case, slots_per_hour=1, horizon_h=30.0,
                          backend="numpy").evaluate_batch(
        np.full((1, 24), 0.6))
    assert res.metrics.co2_kg < float(flat.co2_kg[0])


def test_optimized_beats_six_policies_oem_case1(week_trace):
    """The headline claim: on the OEM case-1 workload under a week-long
    carbon trace, the synthesized schedule's energy is <= the best of the
    six fixed Figure-1 policies given the same deadline."""
    c = Campaign(OEM_CASE_1)
    six = c.sweep(list(POLICIES.values()), carbon_trace=week_trace)
    deadline = max(r.runtime_h for r in six)
    best_six = min(r.energy_kwh for r in six)
    method = "auto" if _HAS_JAX else "cem"
    res = c.optimize("energy", deadline_h=deadline, carbon_trace=week_trace,
                     method=method, candidates=256, iterations=30, steps=400)
    assert res.result.runtime_h <= deadline * 1.005
    assert res.result.energy_kwh <= best_six
    # the optimizer's own metrics agree with the engine's SimResult
    assert abs(res.metrics.energy_kwh / res.result.energy_kwh - 1) < 1e-9
    assert abs(res.metrics.runtime_h / res.result.runtime_h - 1) < 1e-9


# ---------------------------------------------------------------------------
# Objective semantics
# ---------------------------------------------------------------------------
def test_objective_coercion_and_aliases():
    obj = Objective.coerce("co2", {"runtime": 100.0})
    assert obj.weights == {"co2_kg": 1.0}
    assert obj.constraints == {"runtime_h": 100.0}
    obj2 = Objective.coerce({"energy": 1.0, "runtime_h": 0.2})
    assert set(obj2.weights) == {"energy_kwh", "runtime_h"}
    assert canonical_metric("carbon") == "co2_kg"
    with pytest.raises(ValueError, match="unknown metric"):
        Objective.coerce("joules")
    with pytest.raises(ValueError, match="at least one"):
        Objective(weights={})
    with pytest.raises(ValueError, match="positive"):
        Objective(weights={"co2": 1.0}, constraints={"runtime": -5.0})


def test_cost_objective_requires_price(toy):
    case, _ = toy
    with pytest.raises(ValueError, match="price"):
        optimize_schedule(case, "cost", horizon_h=30.0)


def test_runtime_cap_is_respected_as_epsilon_constraint(toy):
    """min energy s.t. a *tight* runtime cap: the cap binds (the
    unconstrained optimum runs slower) and is met within tolerance."""
    case, _ = toy
    res = optimize_schedule(case, "energy", {"runtime_h": 14.0},
                            method="cem", u_min=0.02, u_max=1.0,
                            candidates=128, iterations=40, horizon_h=30.0,
                            seed=3)
    assert res.metrics.runtime_h <= 14.0 * 1.01
    assert res.metrics.unfinished < 1e-9


# ---------------------------------------------------------------------------
# The pure objective path
# ---------------------------------------------------------------------------
def test_trace_objective_is_engine_consistent(toy):
    """TraceObjective.evaluate must reproduce the trace engine's numbers
    exactly for the equivalent ParametricSchedule (same grid + physics)."""
    case, _ = toy
    sched = ParametricSchedule.from_intensities(
        0.3 + 0.4 * np.sin(np.arange(24) / 24 * 2 * np.pi) ** 2,
        u_min=0.02, u_max=1.0, name="wavy")
    to = TraceObjective(case, slots_per_hour=1, horizon_h=60.0)
    mets = to.evaluate_batch(sched.intensity_table()[None, :])
    eng = trace_sweep([dataclasses.replace(case, schedule=sched)])[0]
    assert abs(float(mets.energy_kwh[0]) / eng.energy_kwh - 1) < 1e-9
    assert abs(float(mets.co2_kg[0]) / eng.co2_kg - 1) < 1e-9
    assert abs(float(mets.runtime_h[0]) / eng.runtime_h - 1) < 1e-9
    assert abs(float(mets.unfinished[0])) < 1e-12


@pytest.mark.skipif(not _HAS_JAX, reason="needs jax")
def test_evaluate_params_grad_and_vmap_compatible(toy):
    import jax
    import jax.numpy as jnp

    from repro.compat import enable_x64

    case, _ = toy
    with enable_x64():
        g = jax.grad(lambda p: evaluate_params(p, case,
                                               horizon_h=30.0).co2_kg)(
            jnp.zeros(24))
        assert g.shape == (24,)
        assert bool(jnp.isfinite(g).all())
        assert float(jnp.abs(g).max()) > 0.0
        to = TraceObjective(case, slots_per_hour=1, horizon_h=30.0)
        U = jnp.asarray(np.linspace(0.3, 0.9, 5)[:, None]
                        * np.ones((5, 24)))
        mets = jax.vmap(lambda u: to.evaluate(u))(U)
        assert mets.energy_kwh.shape == (5,)
        # more intensity, faster finish
        rts = np.asarray(mets.runtime_h)
        assert (np.diff(rts) < 0).all()


def test_unfinished_is_reported_not_grown(toy):
    """A schedule that cannot finish inside the horizon reports
    unfinished > 0 instead of growing the grid (no retry inside the
    objective)."""
    case, _ = toy
    to = TraceObjective(case, slots_per_hour=1, horizon_h=6.0)
    mets = to.evaluate_batch(np.full((1, 24), 0.1))
    assert float(mets.unfinished[0]) > 0.5
    assert float(mets.runtime_h[0]) == pytest.approx(6.0)


# ---------------------------------------------------------------------------
# Pareto extraction
# ---------------------------------------------------------------------------
def test_pareto_front_mask():
    pts = np.array([[1.0, 5.0], [2.0, 3.0], [3.0, 4.0], [4.0, 1.0],
                    [2.5, 3.0]])
    mask = pareto_front(pts)
    assert mask.tolist() == [True, True, False, True, False]
    # K>2 fallback agrees on the same points (third objective constant)
    pts3 = np.hstack([pts, np.ones((5, 1))])
    assert pareto_front(pts3).tolist() == mask.tolist()


def test_cem_pareto_frontier_attached(toy):
    case, _ = toy
    res = optimize_schedule(case, "co2", {"runtime_h": 24.0}, method="cem",
                            u_min=0.02, u_max=1.0, candidates=96,
                            iterations=12, horizon_h=30.0, seed=4,
                            pareto=True)
    assert len(res.frontier) >= 2
    rts = [r.runtime_h for r in res.frontier]
    co2 = [r.co2_kg for r in res.frontier]
    assert rts == sorted(rts)                  # sorted by runtime …
    assert co2 == sorted(co2, reverse=True)    # … and non-dominated


# ---------------------------------------------------------------------------
# ParametricSchedule family
# ---------------------------------------------------------------------------
def test_parametric_schedule_round_trip_and_protocol():
    u_in = np.linspace(0.1, 0.9, 24)
    s = ParametricSchedule.from_intensities(u_in, name="rt")
    assert np.allclose(s.intensity_table(), u_in, atol=1e-6)
    # decide() and decide_grid() agree on the same grid
    from repro.core.schedule import SchedulingContext
    hod = np.arange(24, dtype=float)
    ctx = SchedulingContext(hour_of_day=hod[:, None], band="",
                            background=0.0, carbon_factor=0.0)
    u_grid, b_grid = s.decide_grid(ctx)
    for h in range(24):
        d = s.decide(SchedulingContext(hour_of_day=float(h), band="",
                                       background=0.0, carbon_factor=0.0))
        assert d.intensity == pytest.approx(float(u_grid[h, 0]))
        assert d.batch_size == 50
    # sub-hour slots advertise sub-hour change hours
    s48 = parametric_schedule(48)
    assert 0.5 in s48.change_hours(TimeBands())
    assert math.isclose(max(s48.change_hours(TimeBands())), 24.0)
    with pytest.raises(ValueError, match="divide the day"):
        ParametricSchedule(tuple(np.zeros(7)))
    with pytest.raises(ValueError, match="u_min"):
        ParametricSchedule(tuple(np.zeros(24)), u_min=0.9, u_max=0.5)


def test_optimizer_quantizes_to_levels(toy):
    """Snapped tables are *exact* members of the level set, including
    levels at the range endpoints (a logit round trip cannot represent
    those bit-exactly — regression for the from_intensities clip)."""
    case, _ = toy
    levels = (0.1, 0.3, 0.5, 0.7, 1.0)
    res = optimize_schedule(case, "co2", {"runtime_h": 24.0}, method="cem",
                            u_min=0.02, u_max=1.0, candidates=64,
                            iterations=10, horizon_h=30.0, seed=5,
                            levels=levels)
    u = res.schedule.intensity_table()
    assert all(any(v == l for l in levels) for v in u)
    # candidates are snapped BEFORE evaluation, so the search optimized
    # the quantized objective and its constraints hold for the result
    assert res.metrics.runtime_h <= 24.0 * 1.01
    assert res.metrics.unfinished < 1e-9
    # the engine-reported result reflects the snapped table
    eng = trace_sweep([dataclasses.replace(case, schedule=res.schedule)])[0]
    assert abs(eng.energy_kwh / res.result.energy_kwh - 1) < 1e-12


def test_parametric_slot_lookup_with_non_binary_slot_width(calibrated_oem):
    """n_slots=120 (12-minute slots, width 0.2 h — not binary-
    representable): slot-edge grid hours must not truncate one slot low;
    engine vs sequential stays at the 1e-9 contract."""
    wl, m = calibrated_oem
    rng = np.random.RandomState(3)
    ps = ParametricSchedule.from_intensities(
        0.25 + 0.7 * rng.rand(120), name="p120")
    from repro.core import simulate_campaign, sweep
    r = sweep([SweepCase(ps, wl, m)])[0]
    seq = simulate_campaign(wl, ps, m)
    assert abs(r.energy_kwh / seq.energy_kwh - 1) < 1e-9
    assert abs(r.runtime_h / seq.runtime_h - 1) < 1e-9


def test_cem_candidates_validated(toy):
    case, _ = toy
    with pytest.raises(ValueError, match="candidates"):
        optimize_schedule(case, "co2", method="cem", candidates=1,
                          horizon_h=30.0)
    # levels need the quantized (population) search: snapping a smooth
    # gradient optimum afterwards could silently violate constraints
    with pytest.raises(ValueError, match="population"):
        optimize_schedule(case, "co2", method="grad", levels=(0.2, 0.9),
                          horizon_h=30.0)


def test_campaign_optimize_warm_starts_from_parametric_incumbent():
    """Re-optimizing a campaign whose schedule is already a
    ParametricSchedule must refine the incumbent, not restart flat: even
    a tiny budget returns a result no worse than the incumbent."""
    c0 = Campaign(OEM_CASE_1)
    first = c0.optimize("energy", deadline_h=210.0, method="cem",
                        candidates=64, iterations=10)
    c1 = Campaign(OEM_CASE_1, first.schedule)
    again = c1.optimize("energy", deadline_h=210.0, method="cem",
                        candidates=16, iterations=2, init_std=0.05)
    assert again.result.energy_kwh <= first.result.energy_kwh * 1.0001


def test_campaign_optimize_canonicalizes_constraint_aliases():
    """An aliased runtime cap ('runtime'/'deadline') must win over the
    deadline_h shorthand instead of being silently overridden."""
    c = Campaign(OEM_CASE_1)
    res = c.optimize("co2", constraints={"runtime": 150.0}, deadline_h=200.0,
                     method="cem", candidates=32, iterations=4)
    assert res.objective.constraints == {"runtime_h": 150.0}
    res2 = c.optimize("co2", constraints={"deadline": 150.0}, method="cem",
                      candidates=32, iterations=4)
    assert res2.objective.constraints == {"runtime_h": 150.0}


def test_campaign_optimize_smoke_and_deltas():
    """Session surface: constraints shorthand, warm start from the
    campaign schedule, delta columns vs the calibrated baseline."""
    c = Campaign(OEM_CASE_1)
    res = c.optimize("energy", deadline_h=200.0, method="cem",
                     candidates=48, iterations=6, deltas=True)
    assert res.result.policy.startswith("optimized[")
    assert res.objective.constraints == {"runtime_h": 200.0}
    assert res.result.energy_delta_pct != 0.0
    # the result schedule is a drop-in Schedule for any sweep
    again = c.sweep([res.schedule])[0]
    assert abs(again.energy_kwh / res.result.energy_kwh - 1) < 1e-9
