"""Persistent plan cache + incremental delta sweeps (the perf_opt
acceptance bar):

* a fresh-process re-sweep of an identical fleet batch hits the disk
  cache with zero classification/lowering work (`plan_misses == 0`,
  `disk_hits >= n_cases`), results bitwise vs the cold compile;
* `delta_sweep` with 1 changed schedule of S=100 recomputes <= 2% of
  the full sweep's `slot_work`, spliced results bitwise-equal to a
  full re-sweep, coupled groups re-scan whole;
* satellites: true-LRU in-memory memo (hit refreshes recency),
  opaque-fingerprint schedules bypass both layers without poisoning
  the store, corrupted entries and schema-version drift recompile
  instead of crashing, `plan_cache_info`/`clear_plan_cache` reset the
  new counters, and the disk store's size-bounded LRU eviction.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (MachineProfile, SweepCase, TraceSignal,
                        as_ensemble, calibrate_workload, constant_schedule,
                        trace_sweep)
from repro.core import engine_jax as ej
from repro.core import plancache
from repro.core.schedule import FunctionSchedule
from repro.core.workload import OEM_CASE_1

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def calibrated():
    return calibrate_workload(OEM_CASE_1, MachineProfile())


@pytest.fixture(autouse=True)
def _no_env_cache(monkeypatch):
    """Keep ambient CARINA_PLAN_CACHE* / CARINA_JAX_CACHE out of every
    test: caching is exercised only through explicit cache_dir=
    arguments here."""
    monkeypatch.delenv("CARINA_PLAN_CACHE", raising=False)
    monkeypatch.delenv("CARINA_PLAN_CACHE_MB", raising=False)
    monkeypatch.delenv("CARINA_JAX_CACHE", raising=False)


def _res_key(r):
    return (r.runtime_h, r.energy_kwh, r.co2_kg, r.cost_usd)


def _week_trace(seed: int = 3) -> TraceSignal:
    rng = np.random.RandomState(seed)
    h = np.arange(96)
    vals = 0.45 * (1.0 + 0.3 * np.sin(2 * np.pi * h / 24.0)
                   + 0.05 * rng.rand(96))
    return TraceSignal(tuple(float(v) for v in vals), name=f"trace{seed}")


def _cases(calibrated, n, scenarios=600.0):
    """n distinct small cases (distinct constant schedules, one shared
    non-periodic trace)."""
    wl, m = calibrated
    wl = dataclasses.replace(wl, n_scenarios=float(scenarios))
    trace = _week_trace()
    us = np.linspace(0.35, 1.0, n)
    return [SweepCase(constant_schedule(float(u)), wl, m, carbon=trace,
                      label=f"u{j}")
            for j, u in enumerate(us)]


# ---------------------------------------------------------------------------
# Acceptance: disk warm start does zero classification/lowering work
# ---------------------------------------------------------------------------
def test_disk_cache_warm_start_zero_work_bitwise(calibrated, tmp_path):
    cases = _cases(calibrated, 5)
    d = str(tmp_path / "store")
    ej.clear_plan_cache()
    cold = trace_sweep(cases, cache_dir=d, backend="numpy")
    s = ej.scan_stats()
    assert s.plan_misses == len(cases)
    assert s.disk_misses == len(cases)
    # simulate a fresh process: the in-memory memo is gone, disk stays
    ej.clear_plan_cache()
    warm = trace_sweep(cases, cache_dir=d, backend="numpy")
    s = ej.scan_stats()
    assert s.plan_misses == 0, "warm start must not compile anything"
    assert s.disk_hits >= len(cases)
    for a, b in zip(cold, warm):
        assert _res_key(a) == _res_key(b)


def test_fleet_warm_start_across_processes(calibrated, tmp_path):
    """The roadmap pin, for real: a second identical coupled fleet
    sweep in a *fresh python process* does zero classification/lowering
    work and reproduces the cold results bitwise."""
    d = str(tmp_path / "store")
    script = textwrap.dedent("""
        import dataclasses, json, sys
        import numpy as np
        from repro.core import (MachineProfile, Site, SweepCase,
                                TraceSignal, calibrate_workload,
                                constant_schedule, fleet_sweep)
        from repro.core import engine_jax as ej
        from repro.core.workload import OEM_CASE_1

        wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
        wl = dataclasses.replace(wl, n_scenarios=600.0)
        rng = np.random.RandomState(3)
        h = np.arange(96)
        vals = 0.45 * (1.0 + 0.3 * np.sin(2 * np.pi * h / 24.0)
                       + 0.05 * rng.rand(96))
        trace = TraceSignal(tuple(float(v) for v in vals), name="trace3")
        groups = [[SweepCase(constant_schedule(u), wl, m, carbon=trace,
                             label=f"u{j}")
                   for j, u in enumerate((0.5, 0.8, 1.0))]]
        site = Site(power_cap_kw=2.0)
        res = fleet_sweep(groups, site, backend="numpy",
                          cache_dir=sys.argv[1])
        s = ej.scan_stats()
        print(json.dumps({
            "co2": [r.co2_kg for r in res[0].campaigns],
            "runtime": [r.runtime_h for r in res[0].campaigns],
            "plan_misses": s.plan_misses, "disk_hits": s.disk_hits}))
    """)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"), JAX_PLATFORMS="cpu")
    env.pop("CARINA_PLAN_CACHE", None)
    runs = []
    for _ in range(2):
        out = subprocess.run([sys.executable, "-c", script, d], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr
        runs.append(json.loads(out.stdout.strip().splitlines()[-1]))
    cold, warm = runs
    assert cold["plan_misses"] == 3 and cold["disk_hits"] == 0
    assert warm["plan_misses"] == 0, "fresh process must warm-start"
    assert warm["disk_hits"] >= 3
    assert warm["co2"] == cold["co2"]
    assert warm["runtime"] == cold["runtime"]


def test_xla_compilation_cache_warm_across_processes(tmp_path):
    """Satellite: the persistent *XLA* compilation cache rides next to
    the plan store (`<cache_dir>/xla`, wired by compile_plan through
    `repro.compat.enable_persistent_compilation_cache`).  The plan
    store skips re-*lowering*; this skips re-*compiling* the jitted
    scan itself.  A fresh process re-running the same sweep must load
    its executable from disk: cold = compilation-cache misses + files
    written, warm = hits with zero misses, results bitwise."""
    d = str(tmp_path / "store")
    script = textwrap.dedent("""
        import dataclasses, glob, json, os, sys
        from jax._src import monitoring

        counts = {"misses": 0, "hits": 0}

        def _listen(event, *a, **kw):
            if event.endswith("cache_misses"):
                counts["misses"] += 1
            elif event.endswith("cache_hits"):
                counts["hits"] += 1

        monitoring.register_event_listener(_listen)

        from repro.core import (MachineProfile, SweepCase, TraceSignal,
                                calibrate_workload, constant_schedule,
                                trace_sweep)
        from repro.core.workload import OEM_CASE_1

        wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
        wl = dataclasses.replace(wl, n_scenarios=40_000.0)
        trace = TraceSignal(tuple([0.4] * 72), name="flat")
        res = trace_sweep([SweepCase(constant_schedule(0.8), wl, m,
                                     carbon=trace)],
                          cache_dir=sys.argv[1])
        xla = os.path.join(sys.argv[1], "xla")
        files = [p for p in glob.glob(os.path.join(xla, "**", "*"),
                                      recursive=True) if os.path.isfile(p)]
        print(json.dumps({"misses": counts["misses"],
                          "hits": counts["hits"], "files": len(files),
                          "co2": res[0].co2_kg}))
    """)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"), JAX_PLATFORMS="cpu")
    for k in ("CARINA_PLAN_CACHE", "CARINA_JAX_CACHE"):
        env.pop(k, None)
    runs = []
    for _ in range(2):
        out = subprocess.run([sys.executable, "-c", script, d], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr
        runs.append(json.loads(out.stdout.strip().splitlines()[-1]))
    cold, warm = runs
    assert cold["misses"] > 0 and cold["hits"] == 0
    assert cold["files"] > 0, "the cold run must persist its executable"
    assert warm["misses"] == 0, "a fresh process must not recompile"
    assert warm["hits"] > 0
    assert warm["co2"] == cold["co2"]


def test_env_var_jax_cache_override(tmp_path, monkeypatch):
    """CARINA_JAX_CACHE redirects the XLA cache independently of the
    plan store (compat-level guard, idempotent, soft-fail)."""
    import jax

    from repro import compat
    override = str(tmp_path / "elsewhere")
    monkeypatch.setenv("CARINA_JAX_CACHE", override)
    monkeypatch.setattr(compat, "_compilation_cache_dir", None)
    before = jax.config.jax_compilation_cache_dir
    try:
        active = compat.enable_persistent_compilation_cache(
            str(tmp_path / "ignored"))
        assert active == os.path.abspath(override)
        # idempotent: a second call with any argument keeps the active dir
        assert compat.enable_persistent_compilation_cache(None) == \
            os.path.abspath(override)
    finally:
        jax.config.update("jax_compilation_cache_dir", before)


def test_corrupted_entries_recompile_never_crash(calibrated, tmp_path):
    cases = _cases(calibrated, 3)
    d = str(tmp_path / "store")
    ej.clear_plan_cache()
    cold = trace_sweep(cases, cache_dir=d, backend="numpy")
    cache = plancache.get_cache(d)
    entries = cache._entries()
    assert entries, "the store should hold entries after a cold sweep"
    for e in entries:
        with open(e.path, "wb") as f:
            f.write(b"not an npz archive")
    ej.clear_plan_cache()
    again = trace_sweep(cases, cache_dir=d, backend="numpy")
    s = ej.scan_stats()
    assert s.plan_misses == len(cases), "corrupt entries must recompile"
    for a, b in zip(cold, again):
        assert _res_key(a) == _res_key(b)
    # the corrupt files were dropped and replaced by fresh writes
    for e in cache._entries():
        with open(e.path, "rb") as f:
            assert f.read(2) == b"PK"


def test_schema_version_salt_invalidates(calibrated, tmp_path, monkeypatch):
    cases = _cases(calibrated, 2)
    d = str(tmp_path / "store")
    ej.clear_plan_cache()
    trace_sweep(cases, cache_dir=d, backend="numpy")
    monkeypatch.setattr(plancache, "SCHEMA_VERSION",
                        plancache.SCHEMA_VERSION + 1)
    ej.clear_plan_cache()
    trace_sweep(cases, cache_dir=d, backend="numpy")
    s = ej.scan_stats()
    assert s.disk_hits == 0, "a version bump must orphan old entries"
    assert s.plan_misses == len(cases)


def test_opaque_schedule_bypasses_both_layers(calibrated, tmp_path):
    """A closure-bearing schedule has no value identity: it must
    compile fresh every time (no memo hit, no disk entry — the store
    cannot be poisoned by an object that can change behind its key)."""
    wl, m = calibrated
    wl = dataclasses.replace(wl, n_scenarios=400.0)
    knob = {"u": 0.7}
    sched = FunctionSchedule("closure", lambda ctx: knob["u"])
    case = SweepCase(sched, wl, m, carbon=_week_trace())
    d = str(tmp_path / "store")
    ej.clear_plan_cache()
    r1 = trace_sweep([case], cache_dir=d, backend="numpy")
    r2 = trace_sweep([case], cache_dir=d, backend="numpy")
    s = ej.scan_stats()
    assert s.plan_hits == 0 and s.disk_hits == 0
    assert s.plan_misses == 2, "opaque cases compile fresh every sweep"
    assert plancache.get_cache(d).info() == (0, 0), "no entry stored"
    assert _res_key(r1[0]) == _res_key(r2[0])
    # the closure really is live: mutating it changes the next sweep
    knob["u"] = 0.4
    r3 = trace_sweep([case], cache_dir=d, backend="numpy")
    assert r3[0].runtime_h > r1[0].runtime_h


def test_memo_true_lru_hit_refreshes_recency(calibrated, monkeypatch):
    """Regression for the insertion-order eviction bug: an entry hit
    recently must survive the eviction sweep even if it was compiled
    first."""
    monkeypatch.setattr(ej, "_PLAN_CACHE_SIZE", 4)
    cases = _cases(calibrated, 5)
    ej.clear_plan_cache()
    trace_sweep([cases[0]], backend="numpy")     # oldest by insertion
    for c in cases[1:4]:
        trace_sweep([c], backend="numpy")        # memo now full (4)
    trace_sweep([cases[0]], backend="numpy")     # hit -> young end
    assert ej.scan_stats().plan_hits == 1
    trace_sweep([cases[4]], backend="numpy")     # evicts oldest quarter
    ej._STATS.plan_hits = 0
    ej._STATS.plan_misses = 0
    trace_sweep([cases[0]], backend="numpy")
    s = ej.scan_stats()
    assert s.plan_hits == 1 and s.plan_misses == 0, \
        "the recently-hit entry must have survived eviction"
    # and the insertion-order victim is really gone
    trace_sweep([cases[1]], backend="numpy")
    assert ej.scan_stats().plan_misses == 1


def test_disk_lru_eviction_bounds_store(calibrated, tmp_path):
    cases = _cases(calibrated, 12)
    d = str(tmp_path / "store")
    ej.clear_plan_cache()
    cold = trace_sweep(cases, cache_dir=d, backend="numpy")
    cache = plancache.get_cache(d)
    n0, bytes0 = cache.info()
    assert n0 > 0
    # shrink the bound below the current footprint and trigger a sweep
    small = plancache.PlanCache(d, max_bytes=max(bytes0 // 2, 1))
    small._evict()
    n1, bytes1 = small.info()
    assert bytes1 <= small.max_bytes
    assert n1 < n0, "the oldest entries must have been swept"
    # a sweep against the thinned store still works (partial hits +
    # recompiles) and stays bitwise
    ej.clear_plan_cache()
    warm = trace_sweep(cases, cache_dir=d, backend="numpy")
    for a, b in zip(cold, warm):
        assert _res_key(a) == _res_key(b)


def test_plan_cache_info_and_clear(calibrated, tmp_path):
    cases = _cases(calibrated, 4)
    d = str(tmp_path / "store")
    ej.clear_plan_cache()
    trace_sweep(cases, cache_dir=d, backend="numpy")
    ej.clear_plan_cache()                        # memo gone, disk stays
    trace_sweep(cases, cache_dir=d, backend="numpy")
    info = ej.plan_cache_info(cache_dir=d)
    assert info.mem_entries == len(cases) and info.mem_bytes > 0
    assert info.disk_entries > 0 and info.disk_bytes > 0
    assert info.hits >= len(cases) and info.misses == 0
    assert info.hit_rate == 1.0
    ej.clear_plan_cache()
    s = ej.scan_stats()
    assert (s.plan_hits, s.plan_misses, s.disk_hits, s.disk_misses,
            s.lanes_recomputed, s.lanes_spliced) == (0, 0, 0, 0, 0, 0)
    info = ej.plan_cache_info(cache_dir=d)
    assert info.mem_entries == 0 and info.hit_rate == 0.0
    assert info.disk_entries > 0, "clear_plan_cache leaves disk alone"


# ---------------------------------------------------------------------------
# Acceptance: delta_sweep recomputes ~K/S of the slot work, bitwise
# ---------------------------------------------------------------------------
def test_delta_sweep_1_of_100_slot_work_and_bitwise(calibrated):
    S = 100
    cases = _cases(calibrated, S)
    plan = ej.compile_plan(cases)
    ej.reset_scan_stats()
    state = ej.execute_plan(plan, backend="numpy")
    base_work = ej.scan_stats().slot_work
    prev = ej.summarize_plan(plan, state)

    new_sched = constant_schedule(0.42)
    ej.reset_scan_stats()
    delta = ej.delta_sweep(plan, prev, schedules={7: new_sched},
                           backend="numpy")
    s = ej.scan_stats()
    assert s.lanes_recomputed == 1 and s.lanes_spliced == S - 1
    assert s.slot_work <= 0.02 * base_work, (
        f"1-of-{S} delta re-scanned {s.slot_work}/{base_work} slot units")
    assert delta.recomputed == (7,)
    assert len(delta.spliced) == S - 1

    full_cases = list(cases)
    full_cases[7] = dataclasses.replace(cases[7], schedule=new_sched)
    ref = trace_sweep(full_cases, backend="numpy")
    for a, b in zip(delta.results, ref):
        assert _res_key(a) == _res_key(b)
    # the returned plan is the delta base for the *next* cycle
    assert delta.plan.cases[7].schedule is new_sched


def test_delta_sweep_noop_delta_splices_everything(calibrated):
    cases = _cases(calibrated, 6)
    plan = ej.compile_plan(cases)
    prev = ej.summarize_plan(plan, ej.execute_plan(plan, backend="numpy"))
    ej.reset_scan_stats()
    # an "update" that fingerprints identically to the incumbent —
    # e.g. the orchestrator re-sends every schedule each cycle
    delta = ej.delta_sweep(plan, prev,
                           schedules=[c.schedule for c in cases],
                           backend="numpy")
    s = ej.scan_stats()
    assert delta.recomputed == ()
    assert s.lanes_recomputed == 0 and s.lanes_spliced == plan.n_lanes
    assert s.slot_work == 0, "a value-identical delta must scan nothing"
    assert [_res_key(r) for r in delta.results] == \
        [_res_key(r) for r in prev]


def test_delta_sweep_carbon_delta_rescans_its_cases(calibrated):
    cases = _cases(calibrated, 4)
    plan = ej.compile_plan(cases)
    prev = ej.summarize_plan(plan, ej.execute_plan(plan, backend="numpy"))
    new_trace = _week_trace(seed=11)
    ej.reset_scan_stats()
    delta = ej.delta_sweep(plan, prev, carbon={2: new_trace},
                           backend="numpy")
    assert delta.recomputed == (2,)
    full_cases = list(cases)
    full_cases[2] = dataclasses.replace(cases[2], carbon=new_trace)
    ref = trace_sweep(full_cases, backend="numpy")
    for a, b in zip(delta.results, ref):
        assert _res_key(a) == _res_key(b)


def test_delta_sweep_coupled_group_rescans_whole(calibrated):
    """A changed member of a site-capped group drags the whole group
    into the re-scan (lanes interact through the cap every slot);
    uncapped cases in the same plan still splice."""
    cases = _cases(calibrated, 5)
    plan = ej.compile_plan(cases, group_sizes=[3, 2],
                           group_caps_kw=[2.0, None])
    prev = ej.summarize_plan(plan, ej.execute_plan(plan, backend="numpy"))
    new_sched = constant_schedule(0.55)
    ej.reset_scan_stats()
    delta = ej.delta_sweep(plan, prev, schedules={0: new_sched},
                           backend="numpy")
    s = ej.scan_stats()
    assert delta.recomputed == (0, 1, 2), "the capped group goes whole"
    assert delta.spliced == (3, 4)
    assert s.lanes_recomputed == 3 and s.lanes_spliced == 2
    full_cases = list(cases)
    full_cases[0] = dataclasses.replace(cases[0], schedule=new_sched)
    full_plan = ej.compile_plan(full_cases, group_sizes=[3, 2],
                                group_caps_kw=[2.0, None])
    ref = ej.summarize_plan(full_plan,
                            ej.execute_plan(full_plan, backend="numpy"))
    for a, b in zip(delta.results, ref):
        assert _res_key(a) == _res_key(b)


def test_delta_sweep_revalidates_ensemble_width(calibrated):
    wl, m = calibrated
    wl = dataclasses.replace(wl, n_scenarios=400.0)
    ens = as_ensemble([_week_trace(1), _week_trace(2)], name="e2")
    cases = [SweepCase(constant_schedule(0.8), wl, m, carbon=ens)]
    plan = ej.compile_plan(cases)
    prev = ej.summarize_plan(plan, ej.execute_plan(plan, backend="numpy"))
    with pytest.raises(ValueError, match="ensemble width"):
        ej.delta_sweep(plan, prev, carbon={0: _week_trace(9)},
                       backend="numpy")


def test_delta_sweep_rejects_mismatched_results(calibrated):
    cases = _cases(calibrated, 3)
    plan = ej.compile_plan(cases)
    prev = ej.summarize_plan(plan, ej.execute_plan(plan, backend="numpy"))
    with pytest.raises(ValueError, match="full result list"):
        ej.delta_sweep(plan, prev[:-1], schedules={0: constant_schedule(0.5)})


def test_subset_plan_refuses_split_coupled_group(calibrated):
    cases = _cases(calibrated, 3)
    plan = ej.compile_plan(cases, group_sizes=[3], group_caps_kw=[2.0])
    with pytest.raises(ValueError, match="whole"):
        ej._subset_plan(plan, [1])
