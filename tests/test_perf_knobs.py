"""The §Perf hillclimb knobs must be numerically neutral: head-padded TP
attention, masked cache writes, grouped-KV decode, blocked CE, grad accum —
each compared against the baseline path on CPU.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.models import layers as L

KEY = jax.random.PRNGKey(0)


def test_pad_heads_attention_identical():
    """pad_heads only changes sharding; without a TP mesh it must be a
    no-op, and with padding forced the sliced result must match."""
    b, s, h, hkv, d = 2, 64, 6, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    base = L.attention(q, k, v, causal=True)
    padded = L.attention(q, k, v, causal=True, pad_heads=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(padded),
                               rtol=1e-6, atol=1e-6)
    # force the padding path via a fake TP axis setting
    L._TP_AXIS = ("model", 4)          # 6 % 4 != 0 -> pads to 8
    try:
        padded2 = L.attention(q, k, v, causal=True, pad_heads=True)
    finally:
        L._TP_AXIS = ()
    np.testing.assert_allclose(np.asarray(base), np.asarray(padded2),
                               rtol=1e-5, atol=1e-5)


def test_masked_cache_write_and_group_kv_decode_identical():
    """decode_cache_seq_shard switches to masked writes + grouped-KV
    attention; logits must match the scatter/repeat baseline exactly."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    cfg2 = dataclasses.replace(cfg, decode_cache_seq_shard=True)
    m1, m2 = build_model(cfg), build_model(cfg2)
    params = m1.init(KEY)
    B, S = 2, 32
    c1 = m1.cache_zeros(B, S)
    c2 = m2.cache_zeros(B, S)
    tok = jnp.array([[3], [7]], jnp.int32)
    for i in range(3):
        l1, c1 = m1.decode_step(params, c1, tok + i, i)
        l2, c2 = m2.decode_step(params, c2, tok + i, i)
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32),
                                   rtol=2e-2, atol=2e-2)
        assert int(jnp.argmax(l1[0, 0])) == int(jnp.argmax(l2[0, 0]))


def test_grad_accum_matches_full_batch():
    """grad_accum=2 must give (numerically) the same update as accum=1 on the
    same global batch (loss is mean-reduced per microbatch)."""
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.training.step import make_train_step
    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    opt = AdamWConfig(total_steps=10, warmup_steps=1)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab_size)}
    s1 = {"params": params, "opt": init_opt_state(params, opt)}
    s2 = jax.tree.map(lambda x: x, s1)
    st1, m1 = jax.jit(make_train_step(model, opt))(s1, batch)
    st2, m2 = jax.jit(make_train_step(model, opt, grad_accum=2))(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    for a, b in zip(jax.tree.leaves(st1["params"]),
                    jax.tree.leaves(st2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-3)


def test_decode_2d_tp_flag_numerics():
    """decode_2d_tp toggles sharding plans only; on one device the logits
    must be identical to baseline."""
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    cfg2 = dataclasses.replace(cfg, decode_cache_seq_shard=True,
                               decode_2d_tp=True)
    m1, m2 = build_model(cfg), build_model(cfg2)
    params = m1.init(KEY)
    B, S = 2, 24
    c1, c2 = m1.cache_zeros(B, S), m2.cache_zeros(B, S)
    tok = jnp.array([[5], [9]], jnp.int32)
    l1, _ = m1.decode_step(params, c1, tok, 2)
    l2, _ = m2.decode_step(params, c2, tok, 2)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=2e-2, atol=2e-2)
