"""Receding-horizon MPC tests (the ISSUE-8 acceptance bar):

* value-of-forecast pin: on the calibrated OEM case 1 workload (scaled
  1/8 so the suite stays fast), realized CO2 is monotone in forecast
  quality — oracle <= day_ahead(sigma) <= persistence within a 2%
  tolerance band, and oracle strictly beats persistence with no
  tolerance at all (fixed seeds throughout);
* K=infinity degenerates to plain open-loop `optimize_schedule`,
  bitwise: same schedule table, same realized CO2/energy/runtime, zero
  `replans`/`slots_reused` on the scan counters;
* zero-recompute pin: every mid-flight re-plan resumes from carried
  state — `scan_stats().slots_reused` equals the lane-slots carried
  across re-plans exactly, and no executed slot is ever re-scanned;
* forecast-model invariants as hypothesis properties (persistence at
  horizon 0 equals the realized trace; day_ahead with sigma=0, bias=0
  is the oracle bitwise; day_ahead is seed-deterministic);
* trace pad policy: the old silent clamp past the archive end is now an
  explicit `pad="hold"` default with an opt-in `pad="raise"`, and MPC
  refuses a truth trace that cannot cover the campaign window.
"""
import dataclasses
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Campaign, Fleet, MachineProfile, Site, SweepCase,
                        TraceSignal, as_trace, calibrate_workload,
                        constant_schedule, day_ahead, oracle, persistence,
                        sample_signal, trace_windows)
from repro.core.engine_jax import (compile_plan, execute_interval,
                                   execute_plan, replace_tables,
                                   reset_scan_stats, scan_stats)
from repro.core.mpc import MPCSession
from repro.core.signal import DayAheadForecast, as_forecast
from repro.core.workload import OEM_CASE_1

SOLVER = dict(method="cem", candidates=24, iterations=4, seed=0)


def _truth(days: int = 14, seed: int = 11) -> TraceSignal:
    """A non-periodic ground-truth carbon trace with day-to-day regime
    drift: diurnal swing whose amplitude and phase wander across days,
    plus seeded noise.  Persistence (yesterday again) and a noisy
    day-ahead forecast both err against it, the oracle does not."""
    rng = np.random.default_rng(seed)
    h = np.arange(24 * days, dtype=float)
    day = h // 24
    amp = 0.18 + 0.10 * np.sin(day * 2.1) + 0.03 * rng.standard_normal(
        24 * days)
    phase = 0.8 * np.sin(day * 0.9)
    vals = 0.40 + amp * np.sin((h % 24) * 2 * np.pi / 24 + phase)
    vals += 0.02 * rng.standard_normal(24 * days)
    return as_trace(vals.clip(0.05), start_hour=0.0, name="truth")


@pytest.fixture(scope="module")
def oem_small():
    """OEM case 1, calibrated, scaled to 1/8 the scenario count (~22 h
    at full intensity) so three MPC runs with re-plans stay fast."""
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    return dataclasses.replace(wl, n_scenarios=wl.n_scenarios // 8), m


def _mpc_case(oem_small, truth, deadline_h=96.0):
    wl, m = oem_small
    return SweepCase(constant_schedule(1.0), wl, m, carbon=truth,
                     start_hour=9.0, deadline_h=deadline_h)


# ---------------------------------------------------------------------------
# value-of-forecast pin


def test_value_of_forecast_ordering():
    """Realized CO2 is monotone in forecast quality on OEM case 1
    (scaled 1/4: ~45 h of work against a 96 h deadline, so *when* the
    work runs decides the emissions and a stale forecast costs real CO2
    — measured gap oracle -> persistence is ~13% at these seeds).

    Tolerance: the two inequalities that involve the stochastic
    day-ahead forecast hold within 2% of the oracle's realized CO2
    (small solver budgets make individual solves noisy; measured
    day_ahead-vs-oracle gap is +0.3%); the oracle-vs-persistence
    ordering must be strict with no tolerance at all.  All seeds fixed:
    truth seed 11, solver seed 0, forecast seed 0.
    """
    wl, m = calibrate_workload(OEM_CASE_1, MachineProfile())
    wl = dataclasses.replace(wl, n_scenarios=wl.n_scenarios // 4)
    truth = _truth()
    solver = dict(method="cem", candidates=32, iterations=6, seed=0)
    realized = {}
    for name, model in [("oracle", oracle()),
                        ("day_ahead", day_ahead(noise_sigma=0.35, seed=0)),
                        ("persistence", persistence())]:
        case = SweepCase(constant_schedule(1.0), wl, m, carbon=truth,
                         start_hour=9.0, deadline_h=96.0)
        sess = MPCSession(case, truth, constraints={"runtime_h": 96.0},
                          forecast=model, replan_every_h=24.0,
                          solver=solver)
        out = sess.run()
        realized[name] = out.realized_co2_kg
        assert out.realized_runtime_h <= 96.0 + 1e-6
    tol = 0.02 * realized["oracle"]
    assert realized["oracle"] <= realized["day_ahead"] + tol, realized
    assert realized["day_ahead"] <= realized["persistence"] + tol, realized
    assert realized["oracle"] < realized["persistence"], realized


def test_oracle_forecast_mae_is_zero(oem_small):
    truth = _truth()
    out = MPCSession(_mpc_case(oem_small, truth),
                     truth, constraints={"runtime_h": 96.0},
                     forecast="oracle", replan_every_h=24.0,
                     solver=SOLVER).run()
    assert out.forecast_mae == 0.0
    assert all(r.forecast_mae == 0.0 for r in out.replans)
    # under the oracle, solve-0's plan and reality agree on the plan's
    # own horizon; realized may differ (re-plans act on realized
    # progress) but must not be wildly off the open-loop prediction
    assert out.realized_co2_kg <= out.planned_co2_kg * 1.05


# ---------------------------------------------------------------------------
# K = infinity degenerates to plain open-loop optimize, bitwise


@pytest.mark.parametrize("k_inf", [None, math.inf])
def test_k_inf_matches_open_loop_bitwise(oem_small, k_inf):
    from repro.core.optimize import optimize_schedule
    truth = _truth()
    case = _mpc_case(oem_small, truth)
    reset_scan_stats()
    out = MPCSession(case, truth, constraints={"runtime_h": 96.0},
                     forecast="oracle", replan_every_h=k_inf,
                     solver=SOLVER).run()
    st_mpc = scan_stats(reset=True)
    ref = optimize_schedule(case, "co2", {"runtime_h": 96.0}, **SOLVER)
    # same solve -> same schedule table, bit for bit
    assert np.array_equal(out.schedule.intensity_table(),
                          ref.schedule.intensity_table())
    # same executed slots -> identical realized outcome, no tolerance
    assert out.realized_co2_kg == ref.result.co2_kg
    assert out.realized_energy_kwh == ref.result.energy_kwh
    assert out.realized_runtime_h == ref.result.runtime_h
    # open loop: exactly one solve, no table swap, nothing carried
    assert out.n_replans == 0
    assert out.slots_reused == 0
    assert st_mpc.replans == 0
    assert st_mpc.slots_reused == 0


# ---------------------------------------------------------------------------
# zero-recompute pin via the new scan counters


def test_replan_reuses_every_executed_slot(oem_small):
    truth = _truth()
    case = _mpc_case(oem_small, truth)
    reset_scan_stats()
    out = MPCSession(case, truth, constraints={"runtime_h": 96.0},
                     forecast="persistence", replan_every_h=8.0,
                     solver=SOLVER).run()
    stats = scan_stats(reset=True)
    assert out.n_replans >= 2            # ~25 h campaign, 8 h intervals
    # one replace_tables per mid-flight re-plan, none extra
    assert stats.replans == out.n_replans
    # every slot executed before a re-plan is carried, never re-scanned:
    # the engine counter and the per-record carry agree exactly
    carried = [r.slots_carried for r in out.replans]
    assert carried[0] == 0               # entry 0 is the initial solve
    assert all(c > 0 for c in carried[1:])
    assert carried[1:] == sorted(carried[1:])    # cursor only advances
    assert stats.slots_reused == sum(carried[1:])
    assert out.slots_reused == stats.slots_reused


def test_execute_interval_split_is_bitwise(oem_small):
    """Engine-level pin under the MPC loop: pausing/resuming at an
    arbitrary slot boundary is invisible in the final state."""
    wl, m = oem_small
    truth = _truth()
    case = SweepCase(constant_schedule(0.7), wl, m, carbon=truth,
                     start_hour=9.0, deadline_h=96.0)
    plan = compile_plan([case])
    ref = execute_plan(plan)
    cur = execute_interval(plan, until_slot=17)
    assert not cur.done and cur.t0 == 17
    cur = execute_interval(plan, cur, until_slot=40)
    cur = execute_interval(plan, cur)
    assert cur.done
    for a, b in zip(ref, cur.state):
        if a is None:
            assert b is None
        else:
            np.testing.assert_array_equal(a, b)


def test_replace_tables_identity_swap_is_noop(oem_small):
    """Swapping in the very same schedule/carbon mid-flight must not
    change the outcome — only the counters move."""
    wl, m = oem_small
    truth = _truth()
    case = SweepCase(constant_schedule(0.7), wl, m, carbon=truth,
                     start_hour=9.0, deadline_h=96.0)
    plan = compile_plan([case])
    ref = execute_plan(plan)
    reset_scan_stats()
    cur = execute_interval(plan, until_slot=24)
    plan2 = replace_tables(plan, cur, schedules={0: case.schedule},
                           carbon=truth)
    cur = execute_interval(plan2, cur)
    stats = scan_stats(reset=True)
    assert stats.replans == 1
    assert stats.slots_reused == 24 * plan.n_lanes
    for a, b in zip(ref, cur.state):
        if a is None:
            assert b is None
        else:
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# fleet MPC


def test_fleet_run_mpc_smoke(oem_small):
    wl, m = oem_small
    truth = _truth()
    small = dataclasses.replace(wl, n_scenarios=wl.n_scenarios // 2)
    f = Fleet([Campaign(wl, machine=m, carbon=truth),
               Campaign(small, machine=m, carbon=truth)],
              Site(power_cap_kw=1.5, office_kw=0.2, carbon=truth))
    out = f.run_mpc(truth, deadlines=96.0, forecast="persistence",
                    replan_every_h=48.0, method="cem", candidates=12,
                    iterations=2, seed=0)
    assert out.n_replans >= 1
    assert len(out.result.campaigns) == 2
    assert out.result.site.peak_kw is not None
    assert out.result.site.peak_kw <= 1.5 + 1e-9
    assert out.realized_co2_kg == pytest.approx(out.result.site.co2_kg)
    assert all(r.runtime_h > 0 for r in out.result.campaigns)


# ---------------------------------------------------------------------------
# ForecastModel invariants (hypothesis)


@settings(max_examples=25, deadline=None)
@given(st.floats(0.0, 24 * 9.0), st.integers(0, 2**31 - 1))
def test_persistence_horizon_zero_equals_realized(now_h, seed):
    """At horizon 0 there is nothing to predict: the persistence view of
    the (floor-aligned) current hour equals the realized trace."""
    truth = _truth(days=10, seed=seed % 1000)
    fc = persistence().forecast(truth, now_h, 0.0)
    h0 = math.floor(now_h)
    hours = np.array([h0], dtype=float)
    np.testing.assert_array_equal(sample_signal(fc.member(0), hours),
                                  sample_signal(truth, hours))


@settings(max_examples=25, deadline=None)
@given(st.floats(0.0, 24 * 6.0), st.floats(1.0, 96.0),
       st.integers(0, 2**31 - 1))
def test_day_ahead_sigma_zero_is_oracle(now_h, horizon_h, seed):
    truth = _truth(days=11, seed=3)
    fc = DayAheadForecast(noise_sigma=0.0, bias=0.0, seed=seed)
    got = fc.forecast(truth, now_h, horizon_h)
    want = oracle().forecast(truth, now_h, horizon_h)
    hours = np.arange(math.floor(now_h),
                      math.ceil(now_h + horizon_h), dtype=float)
    np.testing.assert_array_equal(sample_signal(got.member(0), hours),
                                  sample_signal(want.member(0), hours))


@settings(max_examples=25, deadline=None)
@given(st.floats(0.0, 24 * 6.0), st.floats(1.0, 96.0),
       st.integers(0, 2**16), st.floats(0.01, 0.5))
def test_day_ahead_is_seed_deterministic(now_h, horizon_h, seed, sigma):
    truth = _truth(days=11, seed=5)
    hours = np.arange(math.floor(now_h),
                      math.ceil(now_h + horizon_h), dtype=float)
    a = DayAheadForecast(noise_sigma=sigma, seed=seed).forecast(
        truth, now_h, horizon_h)
    b = DayAheadForecast(noise_sigma=sigma, seed=seed).forecast(
        truth, now_h, horizon_h)
    np.testing.assert_array_equal(sample_signal(a.member(0), hours),
                                  sample_signal(b.member(0), hours))
    # ... and past hours are never perturbed (forecasts rewrite the
    # future, not the record)
    past = hours[hours <= now_h]
    if past.size:
        np.testing.assert_array_equal(sample_signal(a.member(0), past),
                                      sample_signal(truth, past))


def test_as_forecast_names_and_passthrough():
    assert as_forecast("oracle").name == "oracle"
    assert as_forecast("persistence").name == "persistence"
    assert as_forecast("day_ahead").name == "day_ahead"
    model = day_ahead(noise_sigma=0.2)
    assert as_forecast(model) is model
    with pytest.raises(ValueError):
        as_forecast("nowcast")


# ---------------------------------------------------------------------------
# trace pad policy: the archive-end clamp is explicit now


def test_trace_pad_hold_is_default_and_clamps():
    tr = as_trace([0.1, 0.2, 0.3], start_hour=0.0)
    assert tr.pad == "hold"
    assert tr.at(7.0) == 0.3             # clamped to the last value
    assert tr.at(-3.0) == 0.1


def test_trace_pad_raise_rejects_out_of_range():
    tr = TraceSignal(values=(0.1, 0.2, 0.3), start_hour=0.0, pad="raise")
    assert tr.at(1.5) == 0.2
    with pytest.raises(ValueError, match="covers hours"):
        tr.at(3.0)                        # end_hour is exclusive
    with pytest.raises(ValueError, match="covers hours"):
        sample_signal(tr, np.array([1.0, 5.0]))
    with pytest.raises(ValueError):
        TraceSignal(values=(0.1,), start_hour=0.0, pad="bogus")


def test_trace_windows_forwards_pad():
    vals = list(np.linspace(0.1, 1.0, 24 * 14))
    ens = trace_windows(vals, window_h=24 * 7, pad="raise")
    member = ens.member(0)
    assert member.pad == "raise"
    with pytest.raises(ValueError, match="covers hours"):
        member.at(member.end_hour + 1.0)


def test_mpc_rejects_uncovered_truth(oem_small):
    """MPC executes against realized data; a truth archive shorter than
    the campaign window would silently fabricate emissions under the
    hold clamp, so the session refuses it up front."""
    truth = _truth(days=2)                # 48 h of truth, 96 h deadline
    case = _mpc_case(oem_small, truth)
    with pytest.raises(ValueError, match="needs coverage"):
        MPCSession(case, truth, constraints={"runtime_h": 96.0},
                   solver=SOLVER)


def test_mpc_requires_finite_deadline(oem_small):
    truth = _truth()
    case = _mpc_case(oem_small, truth)
    with pytest.raises(ValueError, match="runtime cap"):
        MPCSession(case, truth, solver=SOLVER)
    with pytest.raises(ValueError, match="positive"):
        MPCSession(case, truth, constraints={"runtime_h": 96.0},
                   replan_every_h=0.0, solver=SOLVER)
