"""Top-level alias so `import carina` works with PYTHONPATH=src.

The canonical module is `repro.carina`; this keeps the paper-style
`carina.Campaign(...)` spelling available without the package prefix.
"""
from repro.carina import *  # noqa: F401,F403
