"""The public CARINA surface in one namespace.

    import repro.carina as carina

    report = carina.Campaign(carina.OEM_CASE_1,
                             carina.PEAK_AWARE_BOOSTED).run()
    table = carina.Campaign(carina.OEM_CASE_1).frontier()
    swept = carina.Campaign(carina.OEM_CASE_1).sweep(
        [carina.constant_schedule(u / 100) for u in range(10, 101)])

See docs/API.md for the Schedule / Signal / Campaign contract and the
migration table from the old free functions.
"""
from repro.core import (  # noqa: F401
    # session API
    Campaign, CampaignReport,
    # fleet (site-level) session API
    Fleet, FleetResult, Site, SiteRollup, fleet_sweep, simulate_fleet,
    # scheduling surface
    AllocationSchedule, CarbonGateSchedule, DeadlineSchedule, Decision,
    FunctionSchedule, HourlyPolicy,
    ParametricSchedule, Policy, Schedule, SchedulingContext, as_schedule,
    carbon_gated_cap, constant_schedule, deadline_schedule,
    deadline_weighted_split, dedupe_names, hourly_schedule,
    make_carbon_aware_policy, make_carbon_weighted_boosted,
    parametric_schedule, progress_ramp_schedule, proportional_split,
    # the six Figure-1 policies
    BASELINE, PEAK_AWARE_BOOSTED, PEAK_AWARE_AGGRESSIVE, LOW_PRIORITY_ONLY,
    SMALL_BATCHES, LARGE_BATCHES, POLICIES,
    # signals
    Signal, SignalEnsemble, SignalSet, BandSignal, ConstantSignal,
    HourlySignal, TOU_PRICE, TraceSignal, as_ensemble, as_trace,
    background_signal, carbon_signal, default_signals, is_periodic_24h,
    sample_signal, trace_windows,
    # forecast-error models (MPC loop itself is lazy below)
    ForecastModel, OracleForecast, PersistenceForecast, DayAheadForecast,
    as_forecast, oracle, persistence, day_ahead,
    # ensemble reporting
    EnsembleStats, ensemble_stats,
    # time structure + models
    BANDS, TimeBands, GridCarbonModel, MIDWEST_HOURLY, DTE_FACTOR,
    ChipProfile, EnergyModel, MachineProfile, StepCost, site_throttle,
    # grid-data ingestion (numpy-only; calibration itself is lazy below)
    GAP_POLICIES, SAMPLE_ARCHIVES, CarbonArchive, QualityReport,
    ZoneSeries, load_carbon_archive, load_sample_archive,
    sample_archive_path, write_synthetic_archive,
    # sweep engines (periodic 24-slot; the trace-grid scan's trace_sweep
    # is re-exported lazily below so importing carina stays jax-free)
    SweepCase, frontier_from_sweep, hourly_profile, sweep,
    # execution + tracking
    CarinaController, IntensityDecision, SimClock, RunTracker, RunSummary,
    UnitRecord, load_units, merge_summaries, summary_from_units,
    # arrival streams (serving data side; the scheduler itself is lazy)
    ArrivalBatch, DEFAULT_TIERS, LOAD_SHAPES, QualityTier, arrival_stream,
    # workloads + back-compat free functions
    OEMWorkload, OEM_CASE_1, OEM_CASE_2, TrainingCampaign, SimResult,
    calibrate_workload, policy_frontier, simulate_campaign,
    simulate_campaign_exact,
    # reporting
    render_frontier_dashboard, render_run_dashboard,
)


_LAZY = ("trace_sweep", "TraceObjective", "EvalMetrics", "evaluate_params",
         "FleetTraceObjective", "FleetEvalMetrics",
         "SweepPlan", "compile_plan", "execute_plan", "summarize_plan",
         "ScanStats", "scan_stats", "reset_scan_stats",
         "PlanCursor", "new_cursor", "execute_interval", "replace_tables",
         # recurrence: persistent plan cache + incremental delta sweeps
         "delta_sweep", "DeltaSweepResult", "clear_plan_cache",
         "plan_cache_info", "PlanCacheInfo", "PlanCache",
         # receding-horizon MPC (drives optimize + the trace engine)
         "MPCSession", "FleetMPCSession", "MPCResult", "ReplanRecord",
         "run_mpc",
         # measured-run calibration (fits via the optimizer -> lazy)
         "CalibratedModel", "CalibrationObjective", "FIT_PARAMS",
         "Observations", "fit_calibration", "load_observations",
         "observations_from_units",
         "Objective", "OptimizeResult", "FleetOptimizeResult",
         "optimize_schedule", "optimize_fleet", "pareto_front",
         "reduce_ensemble", "ROBUST_MODES", "scalarize_fleet",
         # online serving (executes through the trace engine -> lazy)
         "Assignment", "DEFAULT_FILL_FRAC", "FifoServingPolicy",
         "GreedyServingPolicy", "OptimizedServingPolicy",
         "SERVING_POLICIES", "ServingRollup", "ServingSession",
         "ServingWindow", "WindowReport", "as_serving_policy",
         "execute_assignment", "serve_window")


def __getattr__(name):
    if name in _LAZY:                    # lazy: avoids eager jax import
        import repro.core
        return getattr(repro.core, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
