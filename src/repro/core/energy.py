"""Estimation-based energy models (the paper's central methodological choice:
"estimates energy load ... enabling use even when direct device-level carbon
metrology is unavailable").

Two modes behind one API:

* RUNTIME mode (paper-faithful): E = integral of P(u, b) dt over tracked
  units, with a machine power profile (idle watts + convex dynamic term and
  background contention).  This is what the policy simulator and the OEM
  case reproduction use.

* ROOFLINE mode (TPU-native adaptation): per-step joules derived from the
  dry-run's compiled cost analysis —
      E_step = FLOPs*pJ/FLOP + HBM_bytes*pJ/B + ICI_bytes*pJ/B + idle*t_step
  grounded in the same three terms as EXPERIMENTS.md §Roofline.  This is
  strictly better-grounded than runtime-only estimation and keeps the
  paper's estimation-not-metering philosophy on hardware we cannot meter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import model

# ---------------------------------------------------------------------------
# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_BW = 50e9                     # B/s per link


@dataclasses.dataclass(frozen=True)
class ChipProfile:
    """TPU chip energy profile (estimation constants, documented basis).

    pj_per_flop is set so that 100% MFU compute power ~= board TDP-class
    power: 200 W / 197e12 FLOP/s ~= 1.0 pJ/FLOP.  HBM ~15 pJ/B and ICI
    ~30 pJ/B are DRAM/interconnect-class figures from the architecture
    literature (order-of-magnitude estimates, as the paper's method allows).
    """
    name: str = "tpu-v5e"
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW
    idle_w: float = 60.0
    tdp_w: float = 200.0
    pj_per_flop: float = 1.0
    pj_per_hbm_byte: float = 15.0
    pj_per_ici_byte: float = 30.0


@dataclasses.dataclass(frozen=True)
class MachineProfile:
    """Workstation profile for RUNTIME mode (paper's OEM context).

    P(u, b) = idle_w + dyn_w * (u + b)^alpha  — u is our worker intensity,
    b the background (interactive office) load; alpha > 1 captures
    frequency/turbo convexity.  gamma is the contention throughput penalty:
    effective throughput = R * u * (1 - gamma * b).

    Defaults are the calibrated values (EXPERIMENTS.md §Paper-validation):
    with dyn_w solved per-case so the baseline kWh matches exactly, the
    boosted-off-hours policy lands at (-9.6% energy, +7.0% runtime) against
    the paper's reported (~-9%, ~+7%).
    """
    name: str = "oem-workstation"
    idle_w: float = 80.0
    dyn_w: float = 220.0            # re-solved by calibration per case
    alpha: float = 1.7
    gamma: float = 0.8
    overhead_w_frac: float = 0.35   # power fraction of dyn during batch overhead

    def power(self, u: float, b: float = 0.0) -> float:
        return model.power_w(u + b, self.idle_w, self.dyn_w, self.alpha)

    def background_power(self, b: float) -> float:
        return model.power_w(b, self.idle_w, self.dyn_w, self.alpha)


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StepCost:
    """Per-step compiled cost terms (from launch/dryrun.py analysis)."""
    flops: float                      # per chip
    hbm_bytes: float                  # per chip
    ici_bytes: float                  # per chip
    chips: int = 1

    def roofline_seconds(self, chip: ChipProfile = ChipProfile()) -> Dict[str, float]:
        return {
            "compute_s": self.flops / chip.peak_flops,
            "memory_s": self.hbm_bytes / chip.hbm_bw,
            "collective_s": self.ici_bytes / chip.ici_bw,
        }

    def step_seconds(self, chip: ChipProfile = ChipProfile()) -> float:
        t = self.roofline_seconds(chip)
        # roofline execution model: bounded by the dominant term
        return max(t.values())

    def bottleneck(self, chip: ChipProfile = ChipProfile()) -> str:
        t = self.roofline_seconds(chip)
        return max(t, key=t.get).replace("_s", "")


class EnergyModel:
    """Unified estimator. Construct with a ChipProfile (roofline mode) and/or
    a MachineProfile (runtime mode)."""

    def __init__(self, chip: ChipProfile = ChipProfile(),
                 machine: MachineProfile = MachineProfile()):
        self.chip = chip
        self.machine = machine

    # ---- roofline mode ----------------------------------------------------
    def step_energy_j(self, cost: StepCost, intensity: float = 1.0) -> float:
        """Joules per step across all chips at a given duty intensity.
        Duty-cycling stretches wall time (idle power accrues) but not the
        switched work."""
        c = self.chip
        dyn = (cost.flops * c.pj_per_flop
               + cost.hbm_bytes * c.pj_per_hbm_byte
               + cost.ici_bytes * c.pj_per_ici_byte) * 1e-12
        t = cost.step_seconds(c) / max(intensity, 1e-6)
        return (dyn + c.idle_w * t) * cost.chips

    def step_power_w(self, cost: StepCost, intensity: float = 1.0) -> float:
        t = cost.step_seconds(self.chip) / max(intensity, 1e-6)
        return self.step_energy_j(cost, intensity) / max(t, 1e-12)

    # ---- runtime mode (paper) ----------------------------------------------
    def runtime_energy_kwh(self, seconds: float, intensity: float,
                           background: float = 0.0) -> float:
        return self.machine.power(intensity, background) * seconds / 3.6e6

    def idle_energy_kwh(self, seconds: float, background: float = 0.0) -> float:
        return self.machine.background_power(background) * seconds / 3.6e6
