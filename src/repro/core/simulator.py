"""Campaign simulator: executes a recurrent workload under an execution
policy over simulated wall-clock, producing the Figure-1 runtime/energy
frontier and the OEM case-study tables.

Mechanics (all estimation-based, per the paper's method):
  * time advances batch by batch; each batch sees the band at its start;
  * effective throughput R_eff = R * u * (1 - gamma * b)   (contention);
  * machine power P(u, b) = idle + dyn * (u + b)^alpha      (convex);
  * per-batch orchestration overhead runs at overhead power (no work);
  * energy is whole-machine over the campaign (that is what the paper's
    kWh figures measure: 48.67 kWh / 180.30 h = 270 W average).

Calibration: R is solved so the baseline policy reproduces the measured
runtime exactly, then dyn_w so it reproduces the measured kWh exactly.
The six policy *deltas* are then genuine model predictions, validated
against the paper's reported numbers (benchmarks/policy_frontier.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.carbon import GridCarbonModel
from repro.core.energy import EnergyModel, MachineProfile
from repro.core.policy import (BANDS, BASELINE, POLICIES, Policy, TimeBands)
from repro.core.tracker import RunSummary, RunTracker
from repro.core.workload import OEMWorkload


@dataclasses.dataclass
class SimResult:
    policy: str
    runtime_h: float
    energy_kwh: float
    co2_kg: float
    runtime_delta_pct: float = 0.0   # vs baseline (+ = slower)
    energy_delta_pct: float = 0.0    # vs baseline (- = saves)
    summary: Optional[RunSummary] = None


def simulate_campaign(workload: OEMWorkload, policy: Policy,
                      machine: MachineProfile,
                      bands: TimeBands = TimeBands(),
                      carbon: Optional[GridCarbonModel] = None,
                      start_hour: float = 9.0,
                      tracker: Optional[RunTracker] = None,
                      coarse: bool = True) -> SimResult:
    """Simulate the full campaign. `coarse=True` advances band-by-band
    (exact for piecewise-constant bands, ~1000x faster than per-batch)."""
    carbon = carbon or GridCarbonModel()
    em = EnergyModel(machine=machine)
    remaining = float(workload.n_scenarios)
    t_h = start_hour
    energy_kwh = 0.0
    co2_kg = 0.0
    batch = policy.batch_size
    per_batch_oh = workload.batch_overhead_s

    hourly = hasattr(policy, "intensity_at_hour") and \
        getattr(policy, "hourly_intensity", ())
    while remaining > 0:
        band = bands.band_at(t_h)
        u = policy.intensity_at_hour(t_h) if hourly else policy.intensity_at(band)
        b = bands.background(band)
        # time until next band boundary (hourly policies: next hour)
        nxt = math.floor(t_h) + 1
        if not hourly:
            while bands.band_at(nxt % 24.0) == band and nxt - t_h < 24.0:
                nxt += 1
        seg_h = nxt - t_h

        r_eff = workload.rate_at_full * u * max(1.0 - machine.gamma * b, 0.05)
        batch_time_s = per_batch_oh + batch / max(r_eff, 1e-9)
        work_frac = (batch / max(r_eff, 1e-9)) / batch_time_s
        scen_per_s = batch / batch_time_s

        seg_s = seg_h * 3600.0
        max_scen = scen_per_s * seg_s
        if max_scen >= remaining:
            seg_s = remaining / scen_per_s
            done = remaining
        else:
            done = max_scen

        p_work = machine.power(u, b)
        p_oh = machine.idle_w + machine.dyn_w * (
            machine.overhead_w_frac * u + b) ** machine.alpha
        p_avg = work_frac * p_work + (1 - work_frac) * p_oh
        e_kwh = p_avg * seg_s / 3.6e6
        c_kg = carbon.co2_kg(e_kwh, hour_of_day=t_h % 24.0)
        energy_kwh += e_kwh
        co2_kg += c_kg
        if tracker is not None:
            tracker.record_unit(phase=band, intensity=u, runtime_s=seg_s,
                                energy_kwh=e_kwh,
                                sim_time_h=t_h - start_hour,
                                meta={"scenarios": done, "batch": batch})
        remaining -= done
        t_h += seg_s / 3600.0

    runtime_h = t_h - start_hour
    return SimResult(policy.name, runtime_h, energy_kwh, co2_kg,
                     summary=tracker.summary() if tracker else None)


def simulate_campaign_exact(workload: OEMWorkload, policy: Policy,
                            machine: MachineProfile,
                            bands: TimeBands = TimeBands(),
                            carbon: Optional[GridCarbonModel] = None,
                            start_hour: float = 9.0) -> SimResult:
    """Batch-by-batch reference simulation (each batch is atomic and sees the
    band at its start — the segment-based simulate_campaign splits batches at
    band boundaries; tests/test_carina.py checks they agree to <0.5 %)."""
    carbon = carbon or GridCarbonModel()
    hourly = hasattr(policy, "intensity_at_hour") and \
        getattr(policy, "hourly_intensity", ())
    remaining = float(workload.n_scenarios)
    t_h = start_hour
    energy_kwh = 0.0
    co2_kg = 0.0
    batch = policy.batch_size
    while remaining > 0:
        band = bands.band_at(t_h)
        u = policy.intensity_at_hour(t_h) if hourly else policy.intensity_at(band)
        b = bands.background(band)
        r_eff = workload.rate_at_full * u * max(1.0 - machine.gamma * b, 0.05)
        n = min(batch, remaining)
        t_work = n / max(r_eff, 1e-9)
        t_oh = workload.batch_overhead_s
        p_work = machine.power(u, b)
        p_oh = machine.idle_w + machine.dyn_w * (
            machine.overhead_w_frac * u + b) ** machine.alpha
        e = (p_work * t_work + p_oh * t_oh) / 3.6e6
        energy_kwh += e
        co2_kg += carbon.co2_kg(e, hour_of_day=t_h % 24.0)
        t_h += (t_work + t_oh) / 3600.0
        remaining -= n
    return SimResult(policy.name, t_h - start_hour, energy_kwh, co2_kg)


# ---------------------------------------------------------------------------
def calibrate_workload(workload: OEMWorkload, machine: MachineProfile,
                       bands: TimeBands = TimeBands(),
                       tol: float = 1e-4) -> Tuple[OEMWorkload, MachineProfile]:
    """Solve (rate_at_full, dyn_w) so the BASELINE policy reproduces the
    measured (hours, kWh) exactly.  Bisection; runtime is monotone in R and
    energy in dyn_w."""
    assert workload.measured_hours and workload.measured_kwh

    def runtime_for(r: float) -> float:
        wl = dataclasses.replace(workload, rate_at_full=r)
        return simulate_campaign(wl, BASELINE, machine, bands).runtime_h

    lo, hi = 1e-3, 1e3
    for _ in range(80):
        mid = math.sqrt(lo * hi)
        if runtime_for(mid) > workload.measured_hours:
            lo = mid
        else:
            hi = mid
        if hi / lo < 1 + tol:
            break
    wl = dataclasses.replace(workload, rate_at_full=math.sqrt(lo * hi))

    def energy_for(d: float) -> float:
        m = dataclasses.replace(machine, dyn_w=d)
        return simulate_campaign(wl, BASELINE, m, bands).energy_kwh

    lo, hi = 1.0, 2000.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if energy_for(mid) < workload.measured_kwh:
            lo = mid
        else:
            hi = mid
        if hi - lo < tol * workload.measured_kwh:
            break
    m = dataclasses.replace(machine, dyn_w=0.5 * (lo + hi))
    return wl, m


def policy_frontier(workload: OEMWorkload,
                    machine: MachineProfile = MachineProfile(),
                    bands: TimeBands = TimeBands(),
                    carbon: Optional[GridCarbonModel] = None,
                    calibrate: bool = True) -> List[SimResult]:
    """The Figure-1 table: all six policies vs the measured baseline."""
    if calibrate:
        workload, machine = calibrate_workload(workload, machine, bands)
    base = simulate_campaign(workload, BASELINE, machine, bands, carbon)
    out = []
    for p in POLICIES.values():
        r = (base if p.name == BASELINE.name
             else simulate_campaign(workload, p, machine, bands, carbon))
        r.runtime_delta_pct = 100.0 * (r.runtime_h / base.runtime_h - 1.0)
        r.energy_delta_pct = 100.0 * (r.energy_kwh / base.energy_kwh - 1.0)
        out.append(r)
    return out
