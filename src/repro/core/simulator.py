"""Campaign simulator: executes a recurrent workload under an execution
schedule over simulated wall-clock, producing the Figure-1 runtime/energy
frontier and the OEM case-study tables.

Mechanics (all estimation-based, per the paper's method):
  * time advances segment by segment; a segment ends wherever the schedule's
    decision or any input signal can change (`schedule.change_hours`);
  * effective throughput R_eff = R * u * (1 - gamma * b)   (contention);
  * machine power P(u, b) = idle + dyn * (u + b)^alpha      (convex);
  * per-batch orchestration overhead runs at overhead power (no work);
  * energy is whole-machine over the campaign (that is what the paper's
    kWh figures measure: 48.67 kWh / 180.30 h = 270 W average).

All scheduling goes through `Schedule.decide(SchedulingContext)` — there is
no duck-typed `intensity_at_hour` probing here anymore; old policy objects
are coerced via `repro.core.schedule.as_schedule`.

Calibration: R is solved so the baseline policy reproduces the measured
runtime exactly, then dyn_w so it reproduces the measured kWh exactly.
The six policy *deltas* are then genuine model predictions, validated
against the paper's reported numbers (benchmarks/run.py).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import List, Optional, Tuple

from repro.core import model
from repro.core.carbon import GridCarbonModel
from repro.core.energy import MachineProfile
from repro.core.policy import BASELINE, POLICIES, TimeBands
from repro.core.schedule import (Schedule, SchedulingContext, as_schedule,
                                 change_hours)
from repro.core.signal import ConstantSignal, Signal, carbon_signal
from repro.core.tracker import RunSummary, RunTracker
from repro.core.workload import OEMWorkload


@dataclasses.dataclass(frozen=True)
class EnsembleStats:
    """Distribution of one metric over a carbon-trace ensemble.

    Built by `ensemble_stats` from the per-member samples the trace-grid
    scan produces; `mean`/`std`/`min`/`max` plus the 5/50/95 % quantiles
    summarize it, and `samples` keeps the raw per-member values (order =
    ensemble member order) for custom risk measures.
    """
    mean: float
    std: float
    lo: float                         # min over members
    hi: float                         # max over members
    q05: float
    q50: float
    q95: float
    samples: Tuple[float, ...]

    @property
    def n_members(self) -> int:
        return len(self.samples)

    def quantile(self, q: float) -> float:
        import numpy as _np
        return float(_np.quantile(_np.asarray(self.samples), q))


def ensemble_stats(samples) -> EnsembleStats:
    """`EnsembleStats` from an array of per-member metric values."""
    import numpy as _np
    arr = _np.asarray(samples, dtype=float).ravel()
    q05, q50, q95 = (float(q) for q in _np.quantile(arr, (0.05, 0.5, 0.95)))
    return EnsembleStats(mean=float(arr.mean()), std=float(arr.std()),
                         lo=float(arr.min()), hi=float(arr.max()),
                         q05=q05, q50=q50, q95=q95,
                         samples=tuple(float(v) for v in arr))


@dataclasses.dataclass
class SimResult:
    policy: str
    runtime_h: float
    energy_kwh: float
    co2_kg: float
    runtime_delta_pct: float = 0.0   # vs baseline (+ = slower)
    energy_delta_pct: float = 0.0    # vs baseline (- = saves)
    cost_usd: Optional[float] = None  # set when a price Signal is supplied
    summary: Optional[RunSummary] = None
    # Filled by ensemble sweeps (carbon = SignalEnsemble): the scalar
    # columns above then hold ensemble means, and these carry the spread.
    # energy/runtime stats appear only when the schedule consults the
    # carbon signal (then the dynamics themselves vary per member).
    co2_ensemble: Optional[EnsembleStats] = None
    energy_ensemble: Optional[EnsembleStats] = None
    runtime_ensemble: Optional[EnsembleStats] = None


def _segment_grid(schedule: Schedule, bands: TimeBands,
                  hourly_signals: bool = False) -> List[float]:
    """Hours in [0, 24) where the decision or any integrated quantity may
    change.

    Union of the band edges (background changes there) and the schedule's
    own change hours; always contains 0.0 so the cyclic successor of the
    last breakpoint is 24.0 + grid[0] == 24.0.  When an hourly-varying
    signal (grid carbon curve, price tariff) is active, segments must not
    span hours — a multi-hour band segment would be carbonized/priced
    entirely at its start hour — so the grid refines to every hour.
    """
    hs = {float(h) for h in range(24)} if hourly_signals else {0.0}
    for h in bands.edges():
        hs.add(float(h) % 24.0)
    for h in change_hours(schedule, bands):
        hs.add(float(h) % 24.0)
    return sorted(hs)


def _next_boundary(grid: List[float], hour: float) -> float:
    """Smallest grid hour strictly greater than `hour` (cyclic, in (h, 24])."""
    i = bisect.bisect_right(grid, hour + 1e-9)
    return grid[i] if i < len(grid) else 24.0 + grid[0]


def simulate_campaign(workload: OEMWorkload, policy, machine: MachineProfile,
                      bands: TimeBands = TimeBands(),
                      carbon=None,
                      start_hour: float = 9.0,
                      tracker: Optional[RunTracker] = None,
                      coarse: bool = True,
                      price: Optional[Signal] = None,
                      deadline_h: float = 0.0) -> SimResult:
    """Simulate the full campaign under any Schedule (or legacy Policy).

    `coarse=True` advances segment-by-segment (exact for piecewise-constant
    decisions, ~1000x faster than per-batch); `coarse=False` delegates to
    the per-batch reference oracle `simulate_campaign_exact`.

    `carbon` may be a GridCarbonModel or any carbon Signal (including a
    non-periodic TraceSignal); signals are sampled at absolute campaign
    hours.  `deadline_h` is surfaced to schedules via `ctx.deadline_h`.

    This free function is the back-compat surface; prefer
    `repro.carina.Campaign` for new code (it owns calibration, tracking,
    and dashboards) and `repro.core.engine.sweep` for many-schedule sweeps.
    """
    if not coarse:
        return simulate_campaign_exact(workload, policy, machine, bands,
                                       carbon, start_hour, price=price,
                                       deadline_h=deadline_h)
    carbon_sig = carbon_signal(carbon or GridCarbonModel())
    schedule = as_schedule(policy)
    grid = _segment_grid(
        schedule, bands,
        hourly_signals=(price is not None
                        or not isinstance(carbon_sig, ConstantSignal)))
    n_total = float(workload.n_scenarios)
    remaining = n_total
    t_h = start_hour
    energy_kwh = 0.0
    co2_kg = 0.0
    cost_usd = 0.0

    while remaining > 0:
        h = t_h % 24.0
        # sample piecewise-constant inputs just *inside* the segment
        # (same 1e-9 tolerance as _next_boundary): accumulated fp drift
        # can land t_h a few ulps below a band edge that is not exactly
        # representable (e.g. 43/3 h), and sampling at t_h then applies
        # the previous band to the whole following segment
        h_in, t_in = (h + 1e-9) % 24.0, t_h + 1e-9
        band = bands.band_at(h_in)
        b = bands.background(band)
        cf = carbon_sig.at(t_in)
        ctx = SchedulingContext(
            hour_of_day=h_in, band=band, background=b,
            carbon_factor=cf,
            price_usd_per_kwh=price.at(t_in) if price is not None else 0.0,
            elapsed_h=t_h - start_hour,
            progress=1.0 - remaining / n_total,
            deadline_h=deadline_h)
        d = schedule.decide(ctx)
        u, batch = d.intensity, d.batch_size
        seg_h = _next_boundary(grid, h) - h

        r = model.campaign_rates(u, batch, b, workload, machine)
        scen_per_s = r.scen_per_s

        seg_s = seg_h * 3600.0
        max_scen = scen_per_s * seg_s
        if max_scen >= remaining:
            seg_s = remaining / scen_per_s
            done = remaining
        else:
            done = max_scen

        e_kwh = r.p_avg_w * seg_s / 3.6e6
        c_kg = e_kwh * cf
        energy_kwh += e_kwh
        co2_kg += c_kg
        if price is not None:
            cost_usd += e_kwh * ctx.price_usd_per_kwh
        if tracker is not None:
            # sim_time_h is absolute simulated time (hour-of-day = % 24),
            # matching the controller's clock.hours, so the tracker's
            # hour-aware CO2 uses the same grid hour this segment ran in
            tracker.record_unit(phase=band, intensity=u, runtime_s=seg_s,
                                energy_kwh=e_kwh, sim_time_h=t_h,
                                meta={"scenarios": done, "batch": batch})
        remaining -= done
        t_h += seg_s / 3600.0

    runtime_h = t_h - start_hour
    return SimResult(schedule.name, runtime_h, energy_kwh, co2_kg,
                     cost_usd=cost_usd if price is not None else None,
                     summary=tracker.summary() if tracker else None)


def simulate_campaign_exact(workload: OEMWorkload, policy,
                            machine: MachineProfile,
                            bands: TimeBands = TimeBands(),
                            carbon=None,
                            start_hour: float = 9.0,
                            price: Optional[Signal] = None,
                            deadline_h: float = 0.0) -> SimResult:
    """Batch-by-batch reference simulation (each batch is atomic and sees the
    band at its start — the segment-based simulate_campaign and the
    vectorized engines split batches at boundaries; tests pin agreement to
    <0.5 %).  This is the per-batch oracle the sweep engines are checked
    against.  `carbon` may be a GridCarbonModel or any carbon Signal."""
    carbon_sig = carbon_signal(carbon or GridCarbonModel())
    schedule = as_schedule(policy)
    n_total = float(workload.n_scenarios)
    remaining = n_total
    t_h = start_hour
    energy_kwh = 0.0
    co2_kg = 0.0
    cost_usd = 0.0
    while remaining > 0:
        h = t_h % 24.0
        band = bands.band_at(h)
        b = bands.background(band)
        cf = carbon_sig.at(t_h)
        ctx = SchedulingContext(
            hour_of_day=h, band=band, background=b,
            carbon_factor=cf,
            price_usd_per_kwh=price.at(t_h) if price is not None else 0.0,
            elapsed_h=t_h - start_hour,
            progress=1.0 - remaining / n_total,
            deadline_h=deadline_h)
        d = schedule.decide(ctx)
        u, batch = d.intensity, d.batch_size
        r = model.campaign_rates(u, batch, b, workload, machine)
        n = min(batch, remaining)
        t_work = n / max(r.r_eff, 1e-9)
        t_oh = workload.batch_overhead_s
        e = (r.p_work_w * t_work + r.p_oh_w * t_oh) / 3.6e6
        energy_kwh += e
        co2_kg += e * cf
        if price is not None:
            cost_usd += e * ctx.price_usd_per_kwh
        t_h += (t_work + t_oh) / 3600.0
        remaining -= n
    return SimResult(schedule.name, t_h - start_hour, energy_kwh, co2_kg,
                     cost_usd=cost_usd if price is not None else None)


# ---------------------------------------------------------------------------
def calibrate_workload(workload: OEMWorkload, machine: MachineProfile,
                       bands: TimeBands = TimeBands(),
                       tol: float = 1e-4) -> Tuple[OEMWorkload, MachineProfile]:
    """Solve (rate_at_full, dyn_w) so the BASELINE policy reproduces the
    measured (hours, kWh) exactly.  Bisection; runtime is monotone in R and
    energy in dyn_w."""
    assert workload.measured_hours and workload.measured_kwh

    def runtime_for(r: float) -> float:
        wl = dataclasses.replace(workload, rate_at_full=r)
        return simulate_campaign(wl, BASELINE, machine, bands).runtime_h

    lo, hi = 1e-3, 1e3
    for _ in range(80):
        mid = math.sqrt(lo * hi)
        if runtime_for(mid) > workload.measured_hours:
            lo = mid
        else:
            hi = mid
        if hi / lo < 1 + tol:
            break
    wl = dataclasses.replace(workload, rate_at_full=math.sqrt(lo * hi))

    def energy_for(d: float) -> float:
        m = dataclasses.replace(machine, dyn_w=d)
        return simulate_campaign(wl, BASELINE, m, bands).energy_kwh

    lo, hi = 1.0, 2000.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if energy_for(mid) < workload.measured_kwh:
            lo = mid
        else:
            hi = mid
        if hi - lo < tol * workload.measured_kwh:
            break
    m = dataclasses.replace(machine, dyn_w=0.5 * (lo + hi))
    return wl, m


def fill_deltas(results: List[SimResult], base: SimResult) -> List[SimResult]:
    """Fill the delta-vs-baseline columns in place (single definition used
    by the frontier, the session API, and the sweep engine)."""
    for r in results:
        r.runtime_delta_pct = 100.0 * (r.runtime_h / base.runtime_h - 1.0)
        r.energy_delta_pct = 100.0 * (r.energy_kwh / base.energy_kwh - 1.0)
    return results


def policy_frontier(workload: OEMWorkload,
                    machine: MachineProfile = MachineProfile(),
                    bands: TimeBands = TimeBands(),
                    carbon: Optional[GridCarbonModel] = None,
                    calibrate: bool = True) -> List[SimResult]:
    """The Figure-1 table: all six policies vs the measured baseline.

    Back-compat shim — `repro.carina.Campaign(...).frontier()` is the
    session-level equivalent and `Campaign.sweep(...)` the vectorized one.
    """
    if calibrate:
        workload, machine = calibrate_workload(workload, machine, bands)
    base = simulate_campaign(workload, BASELINE, machine, bands, carbon)
    out = [base if p.name == BASELINE.name
           else simulate_campaign(workload, p, machine, bands, carbon)
           for p in POLICIES.values()]
    return fill_deltas(out, base)
