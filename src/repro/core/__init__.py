"""CARINA: Carbon-Aware Recurrent INdustrial Analytics (the paper's core).

New code should reach for the session API (`repro.carina.Campaign`, the
`Schedule`/`Signal` protocols, and the vectorized `sweep` engine); the
free functions `simulate_campaign` / `policy_frontier` and direct
`Policy` subclassing remain as back-compat shims.
"""
from repro.core.arrivals import (ArrivalBatch, DEFAULT_TIERS, LOAD_SHAPES,  # noqa: F401
                                 QualityTier, arrival_stream)
from repro.core.carbon import DTE_FACTOR, GridCarbonModel, MIDWEST_HOURLY  # noqa: F401
from repro.core.controller import CarinaController, IntensityDecision, SimClock  # noqa: F401
from repro.core.dashboard import render_frontier_dashboard, render_run_dashboard  # noqa: F401
from repro.core.data import (GAP_POLICIES, SAMPLE_ARCHIVES, CarbonArchive,  # noqa: F401
                             QualityReport, ZoneSeries, load_carbon_archive,
                             load_sample_archive, sample_archive_path,
                             write_synthetic_archive)
from repro.core.energy import (ChipProfile, EnergyModel, MachineProfile,  # noqa: F401
                               StepCost)
from repro.core.engine import SweepCase, frontier_from_sweep, hourly_profile, sweep  # noqa: F401
from repro.core.fleet import (Fleet, FleetResult, Site, SiteRollup,  # noqa: F401
                              fleet_sweep, simulate_fleet)
from repro.core.model import (Rates, campaign_rates, power_w, rates,  # noqa: F401
                              site_throttle)
from repro.core.policy import (BANDS, BASELINE, LARGE_BATCHES,  # noqa: F401
                               LOW_PRIORITY_ONLY, PEAK_AWARE_AGGRESSIVE,
                               PEAK_AWARE_BOOSTED, POLICIES, SMALL_BATCHES,
                               HourlyPolicy, Policy, TimeBands,
                               constant_schedule, hourly_schedule,
                               make_carbon_aware_policy,
                               make_carbon_weighted_boosted)
from repro.core.schedule import (AllocationSchedule, CarbonGateSchedule,  # noqa: F401
                                 DeadlineSchedule, Decision,
                                 FunctionSchedule, ParametricSchedule,
                                 Schedule, SchedulingContext, as_schedule,
                                 carbon_gated_cap, deadline_schedule,
                                 deadline_weighted_split, dedupe_names,
                                 parametric_schedule,
                                 progress_ramp_schedule, proportional_split)
from repro.core.session import Campaign, CampaignReport  # noqa: F401
from repro.core.signal import (TOU_PRICE, BandSignal, ConstantSignal,  # noqa: F401
                               DayAheadForecast, ForecastModel,
                               HourlySignal, OracleForecast,
                               PersistenceForecast, Signal, SignalEnsemble,
                               SignalSet, TraceSignal, as_ensemble,
                               as_forecast, as_trace, background_signal,
                               carbon_signal, day_ahead, default_signals,
                               is_periodic_24h, oracle, persistence,
                               sample_signal, trace_windows)
from repro.core.simulator import (EnsembleStats, SimResult,  # noqa: F401
                                  calibrate_workload, ensemble_stats,
                                  fill_deltas, policy_frontier,
                                  simulate_campaign, simulate_campaign_exact)
from repro.core.tracker import (RunSummary, RunTracker, UnitRecord,  # noqa: F401
                                load_units, merge_summaries,
                                summary_from_units)
from repro.core.workload import OEM_CASE_1, OEM_CASE_2, OEMWorkload, TrainingCampaign  # noqa: F401


_LAZY = {
    # Resolved lazily (PEP 562): core/engine_jax.py attempts a
    # module-level jax import, and eager re-export here would make every
    # `import repro.core` pay jax startup even on pure-NumPy paths
    # (core/optimize.py imports engine_jax transitively).  engine.sweep()
    # likewise imports the trace engine on demand.
    "trace_sweep": "repro.core.engine_jax",
    "TraceObjective": "repro.core.engine_jax",
    "EvalMetrics": "repro.core.engine_jax",
    "evaluate_params": "repro.core.engine_jax",
    "SweepPlan": "repro.core.engine_jax",
    "compile_plan": "repro.core.engine_jax",
    "execute_plan": "repro.core.engine_jax",
    "summarize_plan": "repro.core.engine_jax",
    "ScanStats": "repro.core.engine_jax",
    "scan_stats": "repro.core.engine_jax",
    "reset_scan_stats": "repro.core.engine_jax",
    "PlanCursor": "repro.core.engine_jax",
    "new_cursor": "repro.core.engine_jax",
    "execute_interval": "repro.core.engine_jax",
    "replace_tables": "repro.core.engine_jax",
    # recurrence layer: persistent plan cache + incremental delta sweeps
    "delta_sweep": "repro.core.engine_jax",
    "DeltaSweepResult": "repro.core.engine_jax",
    "clear_plan_cache": "repro.core.engine_jax",
    "plan_cache_info": "repro.core.engine_jax",
    "PlanCacheInfo": "repro.core.engine_jax",
    "PlanCache": "repro.core.plancache",
    # MPC loop: drives optimize + engine_jax, so it rides the lazy door
    "MPCSession": "repro.core.mpc",
    "FleetMPCSession": "repro.core.mpc",
    "MPCResult": "repro.core.mpc",
    "ReplanRecord": "repro.core.mpc",
    "run_mpc": "repro.core.mpc",
    "FleetTraceObjective": "repro.core.engine_jax",
    "FleetEvalMetrics": "repro.core.engine_jax",
    # measured-run calibration: the jit path rides optimize/_grad_search,
    # so the module stays behind the lazy door like the optimizer itself
    "CalibratedModel": "repro.core.calibrate",
    "CalibrationObjective": "repro.core.calibrate",
    "FIT_PARAMS": "repro.core.calibrate",
    "Observations": "repro.core.calibrate",
    "fit_calibration": "repro.core.calibrate",
    "load_observations": "repro.core.calibrate",
    "observations_from_units": "repro.core.calibrate",
    "Objective": "repro.core.optimize",
    "OptimizeResult": "repro.core.optimize",
    "FleetOptimizeResult": "repro.core.optimize",
    "optimize_schedule": "repro.core.optimize",
    "optimize_fleet": "repro.core.optimize",
    "pareto_front": "repro.core.optimize",
    "reduce_ensemble": "repro.core.optimize",
    "ROBUST_MODES": "repro.core.optimize",
    "scalarize_fleet": "repro.core.optimize",
    # serving layer: core/serve.py executes through engine_jax, so it
    # rides the same lazy door (core/arrivals.py above is numpy-only
    # and re-exports eagerly)
    "Assignment": "repro.core.serve",
    "DEFAULT_FILL_FRAC": "repro.core.serve",
    "FifoServingPolicy": "repro.core.serve",
    "GreedyServingPolicy": "repro.core.serve",
    "OptimizedServingPolicy": "repro.core.serve",
    "SERVING_POLICIES": "repro.core.serve",
    "ServingRollup": "repro.core.serve",
    "ServingSession": "repro.core.serve",
    "ServingWindow": "repro.core.serve",
    "WindowReport": "repro.core.serve",
    "as_serving_policy": "repro.core.serve",
    "execute_assignment": "repro.core.serve",
    "serve_window": "repro.core.serve",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
