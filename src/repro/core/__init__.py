"""CARINA: Carbon-Aware Recurrent INdustrial Analytics (the paper's core)."""
from repro.core.carbon import DTE_FACTOR, GridCarbonModel, MIDWEST_HOURLY  # noqa: F401
from repro.core.controller import CarinaController, SimClock  # noqa: F401
from repro.core.dashboard import render_frontier_dashboard, render_run_dashboard  # noqa: F401
from repro.core.energy import (ChipProfile, EnergyModel, MachineProfile,  # noqa: F401
                               StepCost)
from repro.core.policy import (BANDS, BASELINE, LARGE_BATCHES,  # noqa: F401
                               LOW_PRIORITY_ONLY, PEAK_AWARE_AGGRESSIVE,
                               PEAK_AWARE_BOOSTED, POLICIES, SMALL_BATCHES,
                               Policy, TimeBands)
from repro.core.simulator import (SimResult, calibrate_workload,  # noqa: F401
                                  policy_frontier, simulate_campaign)
from repro.core.tracker import RunSummary, RunTracker, UnitRecord, merge_summaries  # noqa: F401
from repro.core.workload import OEM_CASE_1, OEM_CASE_2, OEMWorkload, TrainingCampaign  # noqa: F401
