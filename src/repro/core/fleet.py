"""The fleet session API: many concurrent campaigns under one site.

The paper runs its two OEM database-generation campaigns on *shared*
company infrastructure — the real coupling (office background load, a
site power budget, one grid carbon/price signal) is between workflows,
not inside any one of them.  A `Fleet` makes that joint execution
first-class:

    import repro.carina as carina
    site = carina.Site(power_cap_kw=0.45, office_kw=0.15)
    fleet = carina.Fleet([carina.Campaign(carina.OEM_CASE_1),
                          carina.Campaign(carina.OEM_CASE_2)], site)
    rows = fleet.sweep([carina.PEAK_AWARE_BOOSTED,
                        carina.proportional_split(0.8)])
    rows[0].site.co2_kg                     # site rollup
    rows[0].campaigns[1].runtime_h          # per-campaign SimResult
    best = fleet.optimize("co2", deadlines=[260.0, 420.0])

A `Site` owns the shared inputs (one `SignalSet`: band background, grid
carbon, price), the site power cap in kW, and the office/background
draw.  Under an active cap, campaigns couple through the one definition
of site contention (`model.site_throttle`): per slot, the summed active
draw is compared to the headroom and every campaign's worker intensity
is curtailed by the same demand-proportional factor.  Execution runs on
the trace engine's grouped lanes (`core/engine_jax.py`): the M campaigns
of each fleet case occupy adjacent scan lanes and the chunk kernel
applies the cap coupling across the group each slot — an uncoupled
fleet (`power_cap_kw=None`) is dispatched through the plain engine and
is bitwise-identical to M independent `Campaign.sweep` calls.

`Campaign` is the M=1 special case: `Campaign.as_fleet()` wraps a
campaign, and `Fleet([c]).sweep(...)` reproduces `c.sweep(...)` row for
row.  `simulate_fleet` is the sequential per-slot oracle the grouped
engine is validated against (<0.5 %, tests/test_fleet.py).
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import model
from repro.core.carbon import GridCarbonModel
from repro.core.engine import SweepCase, case_slots_per_hour, sweep
from repro.core.policy import TimeBands
from repro.core.schedule import (AllocationSchedule, Schedule,
                                 SchedulingContext, as_schedule,
                                 dedupe_names as _dedupe_names)
from repro.core.signal import (Signal, SignalSet, as_ensemble, as_trace,
                               carbon_signal, default_signals)
from repro.core.simulator import SimResult, ensemble_stats, fill_deltas


@dataclasses.dataclass(frozen=True)
class Site:
    """The shared execution environment of a fleet of campaigns.

    `power_cap_kw` is the site's power envelope (None = unconstrained);
    `office_kw` the peak office/background draw in kW, scaled over the
    day by the band background curve (the same contention signal the
    campaigns see); `bands`/`carbon`/`price` are the one `SignalSet`
    every campaign of the fleet shares.
    """
    power_cap_kw: Optional[float] = None
    office_kw: float = 0.0
    bands: TimeBands = TimeBands()
    carbon: Optional[object] = None          # GridCarbonModel or Signal
    price: Optional[Signal] = None
    name: str = "site"

    def __post_init__(self):
        if self.power_cap_kw is not None and self.power_cap_kw <= 0.0:
            raise ValueError(f"power_cap_kw must be positive kW or None, "
                             f"got {self.power_cap_kw}")
        if self.office_kw < 0.0:
            raise ValueError(f"office_kw must be >= 0, got {self.office_kw}")

    @property
    def signals(self) -> SignalSet:
        return default_signals(self.bands, self.carbon or GridCarbonModel(),
                               self.price)

    def office_draw_kw(self, hour: float) -> float:
        """Office draw at an absolute hour (follows the band background)."""
        return self.office_kw * self.bands.background(
            self.bands.band_at(hour % 24.0))

    def headroom_kw(self, hour: float) -> float:
        """Power left for campaigns at an absolute hour (inf when uncapped)."""
        if self.power_cap_kw is None:
            return math.inf
        return self.power_cap_kw - self.office_draw_kw(hour)


@dataclasses.dataclass
class SiteRollup:
    """Site-level totals of one fleet execution: makespan, summed
    energy/CO2/cost, and (coupled runs) the peak total site draw."""
    runtime_h: float                  # makespan: max over campaigns
    energy_kwh: float                 # summed over campaigns
    co2_kg: float
    cost_usd: Optional[float] = None
    peak_kw: Optional[float] = None   # office + fleet; None when untracked
    n_campaigns: int = 0
    co2_ensemble: Optional[object] = None   # EnsembleStats of summed CO2


@dataclasses.dataclass
class FleetResult:
    """One fleet case's outcome: per-campaign `SimResult`s + site rollup."""
    policy: str
    campaigns: List[SimResult]
    site: SiteRollup


def _rollup(name: str, members: Sequence[SimResult],
            peak_kw: Optional[float] = None) -> SiteRollup:
    cost = (sum(r.cost_usd for r in members)
            if all(r.cost_usd is not None for r in members) else None)
    co2_ens = None
    if all(r.co2_ensemble is not None for r in members):
        samples = np.sum([r.co2_ensemble.samples for r in members], axis=0)
        co2_ens = ensemble_stats(samples)
    return SiteRollup(
        runtime_h=max(r.runtime_h for r in members),
        energy_kwh=sum(r.energy_kwh for r in members),
        co2_kg=sum(r.co2_kg for r in members),
        cost_usd=cost, peak_kw=peak_kw, n_campaigns=len(members),
        co2_ensemble=co2_ens)


# ---------------------------------------------------------------------------
# The grouped-lane fleet sweep (engine-level entry point)
# ---------------------------------------------------------------------------
def fleet_sweep(fleet_cases: Sequence[Sequence[SweepCase]],
                site: Site, price: Optional[Signal] = None, *,
                names: Optional[Sequence[str]] = None,
                progress_buckets: int = 32, max_days: int = 240,
                backend: Optional[str] = None,
                chunk_days: Optional[int] = None,
                precision: str = "fp64",
                devices: Optional[int] = None,
                pallas=None,
                cache_dir: Optional[str] = None) -> List[FleetResult]:
    """Evaluate fleet cases (each a group of M member `SweepCase`s) on
    the grouped-lane trace engine; order is preserved.

    Every group shares `site`'s cap/office draw; with no cap the flat
    batch runs through the regular `sweep()` dispatcher (periodic cases
    keep the cheap 24-slot path, and results are bitwise-identical to
    sweeping the members independently).

    `precision`/`devices`/`pallas` are the engine's scale-out knobs
    (dtype policy, shard_map lane fan-out, coupled-kernel dispatch —
    see `engine_jax.compile_plan` and `execute_plan`); coupled sweeps
    shard at group boundaries so the site cap stays device-local.
    `cache_dir` points plan compilation at a persistent on-disk cache
    (default: the `CARINA_PLAN_CACHE` env var; see `core.plancache`).
    """
    if not len(fleet_cases):
        return []
    flat: List[SweepCase] = [c for grp in fleet_cases for c in grp]
    sizes = [len(grp) for grp in fleet_cases]
    if names is None:
        names = [grp[0].name() for grp in fleet_cases]
    if site.power_cap_kw is None:
        res = sweep(flat, price=price, progress_buckets=progress_buckets,
                    backend=backend, max_days=max_days,
                    precision=precision, devices=devices,
                    cache_dir=cache_dir)
        out = []
        i = 0
        for name, M in zip(names, sizes):
            members = res[i:i + M]
            out.append(FleetResult(policy=name, campaigns=members,
                                   site=_rollup(name, members)))
            i += M
        return out

    from repro.core.engine_jax import compile_plan, execute_plan, \
        summarize_plan
    sph = 1
    for c in flat:
        sph = math.lcm(sph, case_slots_per_hour(c))
    G = len(fleet_cases)
    plan = compile_plan(flat, price, slots_per_hour=sph,
                        progress_buckets=progress_buckets, max_days=max_days,
                        group_sizes=sizes,
                        group_caps_kw=[site.power_cap_kw] * G,
                        group_office_kw=[site.office_kw] * G,
                        precision=precision, cache_dir=cache_dir)
    state = execute_plan(plan, backend=backend, chunk_days=chunk_days,
                         devices=devices, pallas=pallas)
    res = summarize_plan(plan, state)
    out = []
    i = 0
    for g, (name, M) in enumerate(zip(names, sizes)):
        members = res[i:i + M]
        lanes = np.flatnonzero(plan.lane_group == g)
        peak = float(state.site_kw_peak[lanes].max())
        out.append(FleetResult(policy=name, campaigns=members,
                               site=_rollup(name, members, peak_kw=peak)))
        i += M
    return out


# ---------------------------------------------------------------------------
# Sequential per-slot oracle (the grouped engine's accuracy reference)
# ---------------------------------------------------------------------------
def simulate_fleet(cases: Sequence[SweepCase], site: Site,
                   price: Optional[Signal] = None, *,
                   slots_per_hour: int = 1,
                   max_days: int = 240) -> FleetResult:
    """Step M campaigns jointly, slot by slot, in plain Python.

    The reference implementation of site-coupled execution: per slot,
    every running campaign's schedule decides its demand from a full
    `SchedulingContext` (exact progress, and live site fields —
    `site_power_kw`, `site_headroom`, `n_active`), the summed demanded
    draw is curtailed by `model.site_throttle` against the slot's
    headroom, and the physics advances.  The grouped-lane engine is
    pinned against this oracle to <0.5 % (its decision tables quantize
    progress into buckets; the coupling arithmetic is identical).
    """
    M = len(cases)
    if not M:
        raise ValueError("simulate_fleet needs at least one case")
    if len({c.start_hour for c in cases}) > 1:
        raise ValueError("fleet campaigns share the site clock: all cases "
                         "must have the same start_hour")
    sph = int(slots_per_hour)
    start = float(cases[0].start_hour)
    g0 = math.floor(start * sph) / sph
    scheds = [as_schedule(c.schedule) for c in cases]
    carbon_sig = carbon_signal(site.carbon or GridCarbonModel())
    bands = site.bands
    cap = site.power_cap_kw if site.power_cap_kw is not None else math.inf

    remaining = np.array([float(c.workload.n_scenarios) for c in cases])
    n_scen = remaining.copy()
    rt = np.zeros(M)
    kwh = np.zeros(M)
    co2 = np.zeros(M)
    cost = np.zeros(M)
    peak_kw = 0.0
    prev_site_kw = site.office_draw_kw(g0)

    for t in range(int(max_days) * 24 * sph):
        active = remaining > 1e-6 * n_scen
        if not active.any():
            break
        t_abs = g0 + t / sph
        slot_s = (3600.0 / sph if t else (g0 + 1.0 / sph - start) * 3600.0)
        hod = t_abs % 24.0
        band = bands.band_at(hod)
        bg = bands.background(band)
        cf = float(carbon_sig.at(t_abs))
        pr = float(price.at(t_abs)) if price is not None else 0.0
        office = site.office_kw * bg
        headroom = cap - office
        n_active = int(active.sum())
        head_frac = (1.0 if not math.isfinite(cap)
                     else max(cap - prev_site_kw, 0.0) / cap)

        # demands: every running campaign decides from the full context
        u = np.zeros(M)
        bt = np.ones(M)
        for m in range(M):
            if not active[m]:
                continue
            ctx = SchedulingContext(
                hour_of_day=hod, band=band, background=bg, carbon_factor=cf,
                price_usd_per_kwh=pr,
                elapsed_h=max(t_abs - start, 0.0),
                progress=1.0 - remaining[m] / n_scen[m],
                deadline_h=cases[m].deadline_h,
                site_power_kw=prev_site_kw, site_headroom=head_frac,
                n_active=n_active)
            d = scheds[m].decide(ctx)
            u[m], bt[m] = d.intensity, d.batch_size

        rates = [model.campaign_rates(u[m], bt[m], bg, cases[m].workload,
                                      cases[m].machine) for m in range(M)]
        base = sum(model.power_w(bg, cases[m].machine.idle_w,
                                 cases[m].machine.dyn_w,
                                 cases[m].machine.alpha) / 1000.0
                   for m in range(M) if active[m])
        f = 1.0
        cur = rates
        for _ in range(model.SITE_THROTTLE_ITERS):
            fleet_kw = sum(r.p_avg_w / 1000.0
                           for m, r in enumerate(cur) if active[m])
            f = model.site_throttle(fleet_kw, base, headroom, f)
            cur = [model.campaign_rates(u[m] * f, bt[m], bg,
                                        cases[m].workload, cases[m].machine)
                   for m in range(M)]
        site_kw = office
        for m in range(M):
            if not active[m]:
                continue
            r2 = cur[m]
            dt = min(slot_s, remaining[m] / max(r2.scen_per_s, 1e-30))
            e = r2.kwh_per_s * dt
            remaining[m] -= r2.scen_per_s * dt
            rt[m] += dt
            kwh[m] += e
            co2[m] += e * cf
            cost[m] += e * pr
            site_kw += r2.p_avg_w / 1000.0
        peak_kw = max(peak_kw, site_kw)
        prev_site_kw = site_kw
    # checked after the loop (not for/else): a fleet finishing in the
    # very last allowed slot exhausts the range without re-entering it
    if (remaining > 1e-6 * n_scen).any():
        worst = int(np.argmax(remaining / n_scen))
        raise RuntimeError(
            f"fleet case {cases[worst].name()!r} did not finish within "
            f"max_days={max_days} under the site cap")

    members = [SimResult(policy=c.name(), runtime_h=rt[m] / 3600.0,
                         energy_kwh=float(kwh[m]), co2_kg=float(co2[m]),
                         cost_usd=(float(cost[m]) if price is not None
                                   else None))
               for m, c in enumerate(cases)]
    name = cases[0].name()
    return FleetResult(policy=name, campaigns=members,
                       site=_rollup(name, members, peak_kw=float(peak_kw)))


# ---------------------------------------------------------------------------
# The session object
# ---------------------------------------------------------------------------
class Fleet:
    """N campaigns bound to one `Site` — the M-campaigns axis of the
    session API.

    Campaign-level knobs (workload, machine, calibration, start hour)
    come from the member `Campaign`s; the fleet replaces their
    individual signals with the site's shared ones.  `Campaign` is the
    M=1 special case: `Fleet([c]).sweep(scheds)` reproduces
    `c.sweep(scheds)` exactly (with no site cap the same engine
    dispatch runs the same lanes).
    """

    def __init__(self, campaigns: Sequence, site: Optional[Site] = None,
                 *, name: Optional[str] = None,
                 out_dir: Optional[str] = None,
                 cache_dir: Optional[str] = None):
        if not len(campaigns):
            raise ValueError("Fleet needs at least one campaign")
        self.campaigns = list(campaigns)
        self.cache_dir = cache_dir
        if site is None:
            c0 = self.campaigns[0]
            site = Site(bands=c0.bands, carbon=c0.carbon, price=c0.price)
        self.site = site
        if site.power_cap_kw is not None:
            starts = {c.start_hour for c in self.campaigns}
            if len(starts) > 1:
                raise ValueError(
                    f"campaigns under a site cap share the site clock; got "
                    f"start_hours {sorted(starts)}")
        self.name = name or "+".join(
            getattr(c.workload, "name", c.name) for c in self.campaigns)
        self.out_dir = out_dir

    @property
    def n_campaigns(self) -> int:
        return len(self.campaigns)

    # ------------------------------------------------------------------
    def _member_schedules(self, assignment) -> Tuple[str, List[Schedule]]:
        """(label, M per-campaign schedules) for one fleet assignment:
        an `AllocationSchedule`, a single Schedule (broadcast), or a
        sequence of exactly M schedules."""
        M = self.n_campaigns
        if isinstance(assignment, AllocationSchedule):
            return assignment.name, [as_schedule(s)
                                     for s in assignment.for_fleet(M)]
        if isinstance(assignment, (list, tuple)):
            if len(assignment) != M:
                raise ValueError(
                    f"per-campaign assignment needs {M} schedules "
                    f"(one per campaign), got {len(assignment)}")
            scheds = [as_schedule(s) for s in assignment]
            names = [s.name for s in scheds]
            label = (names[0] if len(set(names)) == 1
                     else "|".join(names))
            return label, scheds
        s = as_schedule(assignment)
        return s.name, [s] * M

    def _cases(self, scheds: Sequence[Schedule], *, carbon, deadlines,
               label: str) -> List[SweepCase]:
        dls = self._deadlines(deadlines)
        out = []
        for m, (c, s) in enumerate(zip(self.campaigns, scheds)):
            wl, mach = c.calibrated()
            out.append(SweepCase(
                s, wl, mach, self.site.bands, carbon, c.start_hour,
                label=f"{label}/{getattr(wl, 'name', c.name)}",
                deadline_h=dls[m]))
        return out

    def _deadlines(self, deadlines) -> List[float]:
        M = self.n_campaigns
        if deadlines is None:
            return [0.0] * M
        if np.ndim(deadlines) == 0:
            return [float(deadlines)] * M
        if len(deadlines) != M:
            raise ValueError(f"deadlines needs {M} entries (one per "
                             f"campaign), got {len(deadlines)}")
        return [float(d) for d in deadlines]

    def _carbon(self, carbon_trace, carbon_ensemble):
        if carbon_trace is not None and carbon_ensemble is not None:
            raise ValueError("pass either carbon_trace= or "
                             "carbon_ensemble=, not both")
        if carbon_ensemble is not None:
            return as_ensemble(carbon_ensemble, name="carbon-ensemble")
        if carbon_trace is not None:
            return as_trace(carbon_trace, name="carbon-trace")
        return self.site.carbon or GridCarbonModel()

    # ------------------------------------------------------------------
    def sweep(self, assignments: Sequence, *,
              deadlines=None,
              carbon_trace=None, carbon_ensemble=None,
              zones=None,
              window_h: Optional[int] = None,
              stride_h: Optional[int] = None,
              deltas: bool = False,
              backend: Optional[str] = None,
              max_days: int = 240,
              precision: str = "fp64",
              devices: Optional[int] = None,
              pallas=None) -> List[FleetResult]:
        """Evaluate fleet assignments jointly under the site.

        Each assignment is an `AllocationSchedule`, a single schedule
        (applied to every campaign), or a sequence of M per-campaign
        schedules; each yields one `FleetResult` (M per-campaign
        `SimResult`s + a site rollup).  Duplicate assignment labels are
        disambiguated with an indexed suffix.  `deadlines` is a scalar
        or one deadline per campaign, surfaced via `ctx.deadline_h`;
        `carbon_trace`/`carbon_ensemble` swap the site's carbon signal
        exactly like `Campaign.sweep`.  With a site cap the grouped-lane
        trace engine couples the campaigns each slot; with
        `power_cap_kw=None` results are bitwise-identical to
        sweeping each campaign independently.  `deltas=True` fills each
        member's delta columns vs its own standalone calibrated
        baseline — the delta then reads "what this assignment (and the
        coupling) cost this campaign".

        `zones=` (a `CarbonArchive` or {zone: series} mapping; mutually
        exclusive with the other carbon arguments) expands every
        assignment across N real grid zones in the same batched launch:
        one `FleetResult` per (assignment, zone), labeled
        `"<assignment>@<zone>"`, each zone's group carrying that zone's
        hourly trace (or, with `window_h`/`stride_h`, its sliding-window
        ensemble).  Zone groups ride the same grouped-lane plan and the
        plan cache unchanged.
        """
        assignments = list(assignments)
        if not assignments:
            raise ValueError("Fleet.sweep needs at least one assignment "
                             "(got an empty sequence)")
        resolved = [self._member_schedules(a) for a in assignments]
        labels = _dedupe_names([label for label, _ in resolved])
        if zones is not None:
            if carbon_trace is not None or carbon_ensemble is not None:
                raise ValueError("pass only one of carbon_trace=, "
                                 "carbon_ensemble=, zones=")
            from repro.core.session import _zone_signals
            pairs = _zone_signals(zones, window_h, stride_h)
            groups = [self._cases(scheds, carbon=sig, deadlines=deadlines,
                                  label=f"{lbl}@{z}")
                      for (_, scheds), lbl in zip(resolved, labels)
                      for z, sig in pairs]
            labels = [f"{lbl}@{z}" for lbl in labels for z, _ in pairs]
        else:
            if window_h is not None or stride_h is not None:
                raise ValueError("window_h=/stride_h= shape the per-zone "
                                 "ensembles and need zones=")
            carbon = self._carbon(carbon_trace, carbon_ensemble)
            groups = [self._cases(scheds, carbon=carbon,
                                  deadlines=deadlines, label=lbl)
                      for (_, scheds), lbl in zip(resolved, labels)]
        out = fleet_sweep(groups, self.site, price=self.site.price,
                          names=labels, backend=backend, max_days=max_days,
                          precision=precision, devices=devices,
                          pallas=pallas, cache_dir=self.cache_dir)
        if deltas:
            for fr in out:
                for c, r in zip(self.campaigns, fr.campaigns):
                    fill_deltas([r], c.baseline())
        return out

    def frontier(self, assignments: Optional[Sequence] = None, *,
                 deadlines=None, render: bool = False) -> List[FleetResult]:
        """The fleet Figure-1 table: bundled policies (or the given
        assignments) applied fleet-wide, with per-campaign deltas vs
        each campaign's standalone baseline and a site rollup per row."""
        from repro.core.policy import POLICIES
        if assignments is None:
            assignments = list(POLICIES.values())
        out = self.sweep(assignments, deadlines=deadlines, deltas=True)
        if render and self.out_dir:
            from repro.core.dashboard import render_frontier_dashboard
            rows = [r for fr in out for r in fr.campaigns]
            render_frontier_dashboard(
                rows, self.out_dir, title=f"fleet {self.name}",
                site_rollups=[(fr.policy, fr.site) for fr in out])
        return out

    def optimize(self, objective="co2", *, constraints=None,
                 deadlines=None, carbon_trace=None, **kwargs):
        """Synthesize a *joint* schedule for the whole fleet.

        Searches the joint `ParametricSchedule` space — one M x n_slots
        logit block, campaign m's day schedule in row m — against the
        coupled fleet objective (`FleetTraceObjective`): site metrics
        are summed over campaigns and `deadlines` become per-campaign
        runtime caps.  An active site cap is enforced by the physical
        curtailment *inside* the objective (no soft constraint is
        added — idle/office draw cannot be shed, so the reported peak
        may sit slightly above an unreachable cap); to plan under a
        peak *budget* without curtailment, drop the cap from the Site
        and pass `constraints={"site_peak_kw": budget}`.  By default
        the search warm-starts from the independently-optimized
        per-campaign schedules (`init="independent"`), so the joint
        result is never worse than running the members' own optima
        under the shared cap.

        Returns a `FleetOptimizeResult`: `.schedules` (M drop-in
        `ParametricSchedule`s), `.results`/`.site` (per-campaign
        `SimResult`s + rollup, evaluated by the grouped-lane engine),
        plus the usual optimizer fields.  Remaining kwargs go to
        `optimize_fleet` (method, candidates, iterations, steps, lr,
        u_min/u_max, seed, backend, ...).
        """
        from repro.core.optimize import optimize_fleet
        carbon = self._carbon(carbon_trace, None)
        dls = self._deadlines(deadlines)
        cases = self._cases([c.schedule for c in self.campaigns],
                            carbon=carbon, deadlines=dls, label="fleet")
        return optimize_fleet(
            cases, site=self.site, objective=objective,
            constraints=constraints, price=self.site.price, **kwargs)

    def run_mpc(self, carbon_trace=None, objective="co2", *,
                constraints=None, deadlines=None, forecast="oracle",
                replan_every_h=24.0, backend=None, chunk_days=None,
                **kwargs):
        """Run the fleet closed-loop under receding-horizon MPC.

        The M-campaign analogue of `Campaign.run_mpc`: every
        `replan_every_h` hours (None/inf = open loop) the *unfinished*
        campaigns' remaining workloads are jointly re-optimized via
        `optimize_fleet` against a fresh `forecast` of the ground-truth
        trace (`carbon_trace`, defaulting to the site's carbon),
        warm-started from the incumbent schedules, and the grouped-lane
        plan resumes from carried state — already-executed slots are
        never recomputed.  `deadlines` (scalar or per-campaign, all
        finite) define the receding horizons.  Remaining keyword
        arguments configure every `optimize_fleet` solve.

        Returns an `MPCResult` whose `.result` is a `FleetResult`
        (per-campaign `SimResult`s + site rollup) realized against the
        truth.
        """
        from repro.core.mpc import FleetMPCSession
        truth = self._carbon(carbon_trace, None)
        dls = self._deadlines(deadlines)
        cases = self._cases([c.schedule for c in self.campaigns],
                            carbon=truth, deadlines=dls, label="mpc")
        return FleetMPCSession(
            cases, self.site, truth, objective=objective,
            constraints=constraints, forecast=forecast,
            replan_every_h=replan_every_h, price=self.site.price,
            backend=backend, chunk_days=chunk_days,
            cache_dir=self.cache_dir, solver=kwargs).run()

    # ------------------------------------------------------------------
    def run(self, assignment=None, *, deadlines=None,
            render: Optional[bool] = None) -> FleetResult:
        """Execute the fleet once under one assignment (default: each
        campaign's own schedule), via the grouped engine."""
        if assignment is None:
            assignment = [c.schedule for c in self.campaigns]
        res = self.sweep([assignment], deadlines=deadlines)[0]
        if (render if render is not None else bool(self.out_dir)):
            from repro.core.dashboard import render_frontier_dashboard
            out = self.out_dir or os.path.join("experiments", self.name)
            render_frontier_dashboard(
                res.campaigns, out, title=f"fleet {self.name}",
                site_rollups=[(res.policy, res.site)])
        return res


__all__ = ["Fleet", "FleetResult", "Site", "SiteRollup", "fleet_sweep",
           "simulate_fleet"]
