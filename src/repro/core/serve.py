"""Online serving: CARINA as a carbon-aware request-level scheduler.

The trace engine plans *campaigns*; this module schedules *streaming
request traffic* (core/arrivals.py) against per-slot grid carbon, then
executes the resulting demand through the same compiled machinery —
so the chunked resumable kernels, lane groups, and site power caps all
apply unchanged to request workloads:

  1. **Window** — an arrival window is discretized into service slots
     (`ServingWindow`): per-slot carbon, background load, and service
     capacity at full intensity (from THE rate model, core/model.py).
  2. **Assign** — a pluggable policy maps every request to a service
     slot and an executed quality tier, or rejects it:
       * `FifoServingPolicy` — the carbon-blind baseline: a single
         FIFO queue served in arrival order (vectorized over the whole
         window via the cumulative served-work curve);
       * `GreedyServingPolicy` — the carbon-gated heuristic: slots are
         filled greenest-first, requests earliest-deadline-first, with
         an optional quality-degrade pass when clean capacity is
         scarce (the CarbonShiftML slot + model-quality assignment);
       * `OptimizedServingPolicy` — reuses the CEM/grad machinery
         (core/optimize.py) to synthesize the window's per-slot
         offered-capacity profile, then packs requests into it.
  3. **Execute** — the admitted per-tier demand becomes an
     `AllocationSchedule`-shaped block of scan lanes (one lane per
     quality tier, intensities inverted from demand through the rate
     model) and runs through `compile_plan -> execute_plan ->
     summarize_plan` in ONE compiled sweep — a million-request day is
     a handful of scan lanes.  A `Site` turns on the grouped-lane
     site-cap kernel exactly as for fleets.

`ServingSession` is the session surface (submit / tick / drain with a
`SiteRollup`-style rollup) plus a lightweight live-mode adapter
(`gate_open` / `record_tick`) that the decode-serving engine
(repro/serving/engine.py) uses in place of the legacy
`CarinaController` wiring.

Determinism: assignment is pure NumPy (bit-identical across runs and
backends); `OptimizedServingPolicy` runs its search on the NumPy
backend by default so the synthesized budgets — and therefore the
assignment — do not depend on whether JAX is installed.  Execution may
still run jitted; both backends agree to float64 precision.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import engine_jax, model
from repro.core.arrivals import (ArrivalBatch, DEFAULT_TIERS, QualityTier,
                                 arrival_stream)
from repro.core.carbon import GridCarbonModel
from repro.core.energy import ChipProfile, EnergyModel, MachineProfile, StepCost
from repro.core.engine import SweepCase
from repro.core.engine_jax import compile_plan, execute_plan, summarize_plan
from repro.core.controller import SimClock
from repro.core.policy import TimeBands
from repro.core.schedule import AllocationSchedule, ParametricSchedule
from repro.core.signal import Signal, carbon_signal, sample_signal
from repro.core.simulator import SimResult
from repro.core.workload import OEMWorkload

#: Safety margin: a policy may book at most this fraction of a slot's
#: full-intensity capacity, leaving headroom for rate-model curvature.
DEFAULT_FILL_FRAC = 0.9


# ---------------------------------------------------------------------------
# The window: per-slot carbon / background / capacity
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServingWindow:
    """One arrival window, discretized into service slots.

    All times are hours; `slot_hours[s]` is slot s's *absolute* start
    hour (`t0_h + s * slot_h` — hour 0 is midnight of the session's
    first day).  `cap_work[s]` is the work a server completes in slot s
    at intensity 1.0 under that slot's background load (scenario units,
    from `model.campaign_rates`); policies book at most
    `fill_frac * cap_work` per slot.
    """
    t0_h: float
    window_h: float
    sph: int
    slot_hours: np.ndarray           # (S,) absolute slot start hours
    carbon: np.ndarray               # (S,) kg CO2e/kWh at the slot
    background: np.ndarray           # (S,) office load in [0, 1]
    cap_work: np.ndarray             # (S,) scenarios servable at u = 1
    fill_frac: float
    workload: OEMWorkload            # service-rate template (n_scenarios unused)
    machine: MachineProfile
    bands: TimeBands
    carbon_sig: Signal
    price: Optional[Signal]
    batch_size: int

    @property
    def n_slots(self) -> int:
        return len(self.slot_hours)

    @property
    def slot_h(self) -> float:
        return 1.0 / self.sph

    @property
    def budgets(self) -> np.ndarray:
        """(S,) bookable work per slot (`fill_frac * cap_work`)."""
        return self.fill_frac * self.cap_work

    @staticmethod
    def build(t0_h: float, window_h: float, *, slots_per_hour: int = 1,
              workload: OEMWorkload, machine: MachineProfile,
              bands: TimeBands, carbon_sig: Signal,
              price: Optional[Signal] = None,
              fill_frac: float = DEFAULT_FILL_FRAC,
              batch_size: int = 50) -> "ServingWindow":
        if not (0.0 < window_h <= 24.0):
            raise ValueError(
                f"window_h must be in (0, 24] (the demand lanes lower to "
                f"day-periodic decision tables), got {window_h}")
        sph = int(slots_per_hour)
        S = int(round(window_h * sph))
        if S < 1 or abs(S / sph - window_h) > 1e-9:
            raise ValueError(f"window_h={window_h} is not a whole number "
                             f"of slots at {sph} slots/hour")
        slot_h = 1.0 / sph
        hours = t0_h + slot_h * np.arange(S)
        carbon = sample_signal(carbon_sig, hours + 0.5 * slot_h)
        bg = np.array([bands.background(bands.band_at(h % 24.0))
                       for h in hours])
        r = model.campaign_rates(1.0, batch_size, bg, workload, machine,
                                 xp=np)
        cap = np.asarray(r.r_eff, dtype=float) * 3600.0 * slot_h
        return ServingWindow(
            t0_h=float(t0_h), window_h=float(window_h), sph=sph,
            slot_hours=hours, carbon=np.asarray(carbon, dtype=float),
            background=bg, cap_work=cap, fill_frac=float(fill_frac),
            workload=workload, machine=machine, bands=bands,
            carbon_sig=carbon_sig, price=price, batch_size=int(batch_size))


# ---------------------------------------------------------------------------
# Assignments
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Assignment:
    """One policy's answer for one window: per-request service slot and
    executed tier, plus the per-(tier, slot) demand block the executor
    lowers into scan lanes.

    `slot[i] == -1` means request i was rejected (no feasible slot at
    any allowed tier).  `t_finish_h[i]` is the window-relative finish
    estimate (slot end for slot-packed policies; fractional within the
    slot for FIFO); rejected requests carry `inf`.
    """
    policy: str
    slot: np.ndarray                 # (N,) int, -1 = rejected
    tier: np.ndarray                 # (N,) int, executed tier
    t_finish_h: np.ndarray           # (N,) float, window-relative
    demand: np.ndarray               # (T, S) scheduled work per tier x slot

    @property
    def admitted(self) -> np.ndarray:
        return self.slot >= 0

    @property
    def n_admitted(self) -> int:
        return int(np.count_nonzero(self.slot >= 0))


def _slot_bounds(batch: ArrivalBatch, window: ServingWindow
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-request (earliest, latest) feasible service slot: a request
    may be served from its arrival slot through the last slot that ends
    by its deadline (clipped to the window; latest < earliest means no
    slot meets the deadline inside this window)."""
    slot_h = window.slot_h
    a = np.minimum((batch.t_arrive_h / slot_h).astype(np.int64),
                   window.n_slots - 1)
    d = np.floor(batch.deadline_h / slot_h - 1.0 + 1e-9).astype(np.int64)
    return a, np.minimum(d, window.n_slots - 1)


def _scaled_work(batch: ArrivalBatch, tiers: Sequence[QualityTier],
                 tier_idx: np.ndarray) -> np.ndarray:
    scales = np.array([t.work_scale for t in tiers])
    return batch.work * scales[np.minimum(tier_idx, len(tiers) - 1)]


def _fifo_curve(batch: ArrivalBatch, window: ServingWindow,
                work: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """The FIFO queue's cumulative served-work curve.

    `served[s]` is the total work completed by the end of slot s when
    requests are served strictly in arrival order at the slot budgets:
    `served[s] = min(arrived_work[s], served[s-1] + budget[s])` — the
    min is the idle case (queue drained before new arrivals).  Returns
    (cum_work per request, served per slot).
    """
    budgets = window.budgets
    cw = np.cumsum(work)
    slot_ends = window.slot_h * (1.0 + np.arange(window.n_slots))
    arrived = np.searchsorted(batch.t_arrive_h, slot_ends, side="right")
    arrived_cw = np.concatenate([[0.0], cw])[arrived]
    served = np.empty(window.n_slots)
    prev = 0.0
    for s in range(window.n_slots):           # scalar recursion, S is small
        prev = min(float(arrived_cw[s]), prev + float(budgets[s]))
        served[s] = prev
    return cw, served


def _fifo_demand(batch: ArrivalBatch, window: ServingWindow,
                 work: np.ndarray, tier_idx: np.ndarray,
                 cw: np.ndarray, served: np.ndarray,
                 n_tiers: int) -> np.ndarray:
    """(T, S) demand block of the FIFO curve: request i's work interval
    on the served-work axis is (cw[i]-work[i], cw[i]]; slot s owns the
    range (served[s-1], served[s]].  The overlap split attributes work
    spanning a slot boundary to both slots, so the executed lanes carry
    exactly the work the queue model served per slot.

    The intervals are disjoint and ordered (start[i] >= cw[i-1]), so a
    slot boundary cuts at most one request: the cumulative tier mass at
    a boundary is a prefix sum plus one partial term, and the demand
    block falls out of a diff — O(n + S log n) per tier, no per-slot
    pass over the requests."""
    demand = np.empty((n_tiers, window.n_slots))
    start = cw - work
    for t in range(n_tiers):
        sel = np.flatnonzero(tier_idx == t)
        if not len(sel):
            demand[t] = 0.0
            continue
        cw_t, st_t, w_t = cw[sel], start[sel], work[sel]
        wcum = np.concatenate([[0.0], np.cumsum(w_t)])
        k = np.searchsorted(cw_t, served, side="right")
        kc = np.minimum(k, len(sel) - 1)      # boundary-cut candidate
        part = np.where(k < len(sel),
                        np.clip(served - st_t[kc], 0.0, w_t[kc]), 0.0)
        mass = wcum[k] + part
        demand[t] = np.diff(np.concatenate([[0.0], mass]))
    return demand


class FifoServingPolicy:
    """Carbon-blind baseline: one FIFO queue served in arrival order at
    the slot budgets, deadlines ignored until the post-hoc SLO check.
    Every request runs at its requested tier."""

    name = "fifo"

    def assign(self, batch: ArrivalBatch, window: ServingWindow,
               tiers: Sequence[QualityTier], *, seed: int = 0) -> Assignment:
        n = batch.n
        tier_idx = np.minimum(batch.tier, len(tiers) - 1)
        work = _scaled_work(batch, tiers, tier_idx)
        cw, served = _fifo_curve(batch, window, work)

        slot = np.searchsorted(served, cw - 1e-9, side="left")
        fits = cw <= served[-1] + 1e-9
        slot = np.where(fits, np.minimum(slot, window.n_slots - 1), -1)

        prev = np.concatenate([[0.0], served[:-1]])
        budgets = window.budgets
        s_safe = np.maximum(slot, 0)
        frac = (cw - prev[s_safe]) / np.maximum(budgets[s_safe], 1e-12)
        t_fin = window.slot_h * (s_safe + np.clip(frac, 0.0, 1.0))
        t_fin = np.where(fits, t_fin, np.inf)

        demand = _fifo_demand(batch, window, work * fits, tier_idx, cw,
                              served, len(tiers))
        return Assignment("fifo", slot.astype(np.int64), tier_idx, t_fin,
                          demand)


def _latest_slots(a_slot: np.ndarray, d_slot: np.ndarray, work: np.ndarray,
                  budgets: np.ndarray, used: np.ndarray,
                  eligible: np.ndarray) -> np.ndarray:
    """Each request's *latest feasible slot* under contention: the
    defer-everything schedule, computed by EDF run in reverse time
    (slots latest-first, requests latest-arrival-first — the mirror of
    earliest-deadline-first, so it is feasibility-optimal).  Requests
    it cannot place (-1) fit in no schedule at this work size.
    Accumulates into `used` so a second pass (degraded work sizes) can
    claim only leftover budget."""
    n = len(a_slot)
    L = np.full(n, -1, dtype=np.int64)
    order = np.argsort(-a_slot, kind="stable")
    order = order[eligible[order]]
    for s in range(len(budgets) - 1, -1, -1):
        room = float(budgets[s] - used[s])
        if room <= 0.0:
            continue
        cand = order[(L[order] < 0) & (a_slot[order] <= s)
                     & (d_slot[order] >= s)]
        if not cand.size:
            continue
        cum = np.cumsum(work[cand])
        k = int(np.searchsorted(cum, room + 1e-12, side="right"))
        if k:
            L[cand[:k]] = s
            used[s] += float(cum[k - 1])
    return L


def _edf_pack(name: str, batch: ArrivalBatch, window: ServingWindow,
              tiers: Sequence[QualityTier], green_budget: np.ndarray,
              *, degrade: bool, pro_ok=None) -> Assignment:
    """The shared packing core of the carbon-aware policies.

    Two passes.  A *reverse-time* EDF pass computes each request's
    latest feasible slot `L` under budget contention (degrading to the
    cheapest tier, then rejecting, whatever fits in no schedule).  The
    *forward* pass then serves slots in time order: requests whose `L`
    is the current slot are **forced** — served against the full slot
    budget regardless of carbon — and everything else is served
    *proactively*, earliest-deadline-first, only up to
    `green_budget[s]` (0 on dirty slots — those requests wait) and,
    when `pro_ok` is given, only for the requests `pro_ok(s)` marks
    willing (the greedy policy's wait-for-clean rule).

    Forcing at `L` rather than at the raw deadline slot is what makes
    carbon-driven waiting free: when deferred work piles up against a
    deadline cluster, the reverse pass has already spread the pile
    over the latest slots that still fit it, so the forward pass never
    meets an overflow the reverse pass didn't resolve — admissions
    match the feasibility-optimal carbon-blind schedule.
    """
    n = batch.n
    S = window.n_slots
    budgets = window.budgets
    a_slot, d_slot = _slot_bounds(batch, window)
    tier_idx = np.minimum(batch.tier, len(tiers) - 1)
    work = _scaled_work(batch, tiers, tier_idx)
    order_d = np.argsort(batch.deadline_h, kind="stable")

    # reverse pass: latest feasible slots, eco retry for the leftovers
    exec_tier = tier_idx.copy()
    w_eff = work.copy()
    r_used = np.zeros(S)
    L = _latest_slots(a_slot, d_slot, work, budgets, r_used,
                      np.ones(n, dtype=bool))
    if degrade and len(tiers) > 1:
        eco = len(tiers) - 1
        eco_work = batch.work * tiers[eco].work_scale
        retry = (L < 0) & (tier_idx != eco)
        if retry.any():
            L2 = _latest_slots(a_slot, d_slot, eco_work, budgets, r_used,
                               retry)
            got = retry & (L2 >= 0)
            L = np.where(got, L2, L)
            exec_tier = np.where(got, eco, exec_tier)
            w_eff = np.where(got, eco_work, w_eff)

    assigned = np.full(n, -1, dtype=np.int64)
    used = np.zeros(S)

    def _take(cand: np.ndarray, room: float, s: int) -> float:
        cum = np.cumsum(w_eff[cand])
        k = int(np.searchsorted(cum, room + 1e-12, side="right"))
        if not k:
            return 0.0
        assigned[cand[:k]] = s
        return float(cum[k - 1])

    for s in range(S):
        # forced class: at the latest feasible slot — full budget
        forced = order_d[(assigned[order_d] < 0) & (L[order_d] >= 0)
                         & (L[order_d] <= s) & (a_slot[order_d] <= s)]
        room = float(budgets[s] - used[s])
        if forced.size and room > 0.0:
            used[s] += _take(forced, room, s)
        # proactive class: EDF up to the slot's green budget
        room = float(min(green_budget[s], budgets[s]) - used[s])
        if room > 0.0:
            mask = ((assigned[order_d] < 0) & (L[order_d] > s)
                    & (a_slot[order_d] <= s))
            if pro_ok is not None:
                mask &= pro_ok(s)[order_d]
            cand = order_d[mask]
            if cand.size:
                used[s] += _take(cand, room, s)

    t_fin = np.where(assigned >= 0,
                     window.slot_h * (np.maximum(assigned, 0) + 1.0), np.inf)
    demand = np.zeros((len(tiers), S))
    adm = assigned >= 0
    np.add.at(demand, (exec_tier[adm], assigned[adm]), w_eff[adm])
    return Assignment(name, assigned, exec_tier, t_fin, demand)


class GreedyServingPolicy:
    """Carbon-gated greedy heuristic: a request is served proactively
    only when the current slot is within `tol` of the *cleanest slot
    still ahead in its own deadline window* — work waits for its best
    reachable carbon, and contention self-regulates (when the valley
    slot fills, the runners-up become each leftover request's new best
    and pick it up).  Requests whose window runs out are served as
    deadline-forced work regardless of carbon, so waiting never costs
    admissions; forced overflow degrades to the cheapest quality tier
    (when `degrade`) before rejecting.

    An explicit `gate` (kg CO2e/kWh) replaces the per-request rule
    with a static one: slots at or below the gate serve proactively,
    dirtier slots serve only forced work.
    """

    name = "greedy"

    def __init__(self, gate: Optional[float] = None, degrade: bool = True,
                 tol: float = 0.1):
        self.gate = gate
        self.degrade = degrade
        self.tol = float(tol)

    def assign(self, batch: ArrivalBatch, window: ServingWindow,
               tiers: Sequence[QualityTier], *, seed: int = 0) -> Assignment:
        if self.gate is not None:
            green = np.where(window.carbon <= self.gate, window.budgets, 0.0)
            return _edf_pack(self.name, batch, window, tiers, green,
                             degrade=self.degrade)
        carbon = window.carbon
        d_clip = np.minimum(
            np.floor(batch.deadline_h / window.slot_h - 1.0 + 1e-9
                     ).astype(np.int64), window.n_slots - 1)

        def pro_ok(s: int) -> np.ndarray:
            # cleanest carbon still reachable: cummin of carbon[s:]
            # indexed by each request's last feasible slot
            fmin = np.minimum.accumulate(carbon[s:])
            best = fmin[np.maximum(d_clip - s, 0)]
            return carbon[s] <= (1.0 + self.tol) * best + 1e-12

        return _edf_pack(self.name, batch, window, tiers, window.budgets,
                         degrade=self.degrade, pro_ok=pro_ok)


class OptimizedServingPolicy:
    """Optimized slot assignment: synthesize the window's per-slot
    offered-capacity profile with the existing CEM/grad machinery
    (`optimize_schedule` on an aggregate demand block — the window's
    total work as one campaign under the window's carbon trace, with
    the window length as the runtime cap), then pack requests into the
    synthesized profile with the same EDF time-order core as the
    greedy policy (the profile plays the role of the green budgets;
    deadline-forced requests still draw on the full slot budget, so
    the optimizer shapes carbon, never SLOs).

    The search runs on the NumPy backend by default so the synthesized
    budgets — and therefore the assignment — are bit-identical whether
    or not JAX is importable; pass `backend=None` to let the search
    jit.  Seeded: the CEM population is driven by the `seed` handed to
    `assign` (offset by `self.seed`)."""

    name = "optimized"

    def __init__(self, objective: str = "co2", *, candidates: int = 48,
                 iterations: int = 10, method: str = "cem",
                 backend: Optional[str] = "numpy", degrade: bool = True,
                 seed: int = 0):
        self.objective = objective
        self.candidates = int(candidates)
        self.iterations = int(iterations)
        self.method = method
        self.backend = backend
        self.degrade = degrade
        self.seed = int(seed)

    def _budgets(self, total_work: float, window: ServingWindow,
                 seed: int) -> np.ndarray:
        from repro.core.optimize import optimize_schedule
        wl = dataclasses.replace(window.workload, name="serving-window",
                                 n_scenarios=float(total_work))
        day = 24 * window.sph
        sched = ParametricSchedule.from_intensities(
            np.full(day, 0.6), u_min=0.0, u_max=1.0,
            batch_size=window.batch_size, name="serving-seed")
        trace = _window_trace(window)
        case = SweepCase(schedule=sched, workload=wl,
                         machine=window.machine, bands=window.bands,
                         carbon=trace, start_hour=window.t0_h % 24.0,
                         label="serving-window",
                         deadline_h=window.window_h)
        res = optimize_schedule(
            case, self.objective, {"runtime_h": window.window_h},
            method=self.method, n_slots=day, u_min=0.0, u_max=1.0,
            batch_size=window.batch_size, price=window.price,
            candidates=self.candidates, iterations=self.iterations,
            seed=self.seed + seed, backend=self.backend)
        u_day = res.schedule.intensity_table()
        day_idx = _day_slot_index(window)
        u = u_day[day_idx]
        r = model.campaign_rates(u, window.batch_size, window.background,
                                 window.workload, window.machine, xp=np)
        cap_u = np.asarray(r.r_eff, dtype=float) * 3600.0 * window.slot_h
        return np.minimum(window.fill_frac * cap_u, window.budgets)

    def assign(self, batch: ArrivalBatch, window: ServingWindow,
               tiers: Sequence[QualityTier], *, seed: int = 0) -> Assignment:
        tier_idx = np.minimum(batch.tier, len(tiers) - 1)
        total = float(_scaled_work(batch, tiers, tier_idx).sum())
        green = self._budgets(total, window, seed)
        return _edf_pack(self.name, batch, window, tiers, green,
                         degrade=self.degrade)


SERVING_POLICIES: Dict[str, type] = {
    "fifo": FifoServingPolicy,
    "greedy": GreedyServingPolicy,
    "optimized": OptimizedServingPolicy,
}


def as_serving_policy(policy) -> object:
    """Coerce a registry name or a policy object (anything with
    `assign(batch, window, tiers, seed=)`) into a serving policy."""
    if isinstance(policy, str):
        try:
            return SERVING_POLICIES[policy]()
        except KeyError:
            raise ValueError(
                f"unknown serving policy {policy!r}; choose from "
                f"{sorted(SERVING_POLICIES)}") from None
    if hasattr(policy, "assign"):
        return policy
    raise TypeError(f"cannot interpret {policy!r} as a serving policy")


# ---------------------------------------------------------------------------
# Execution: the demand block through the compiled trace engine
# ---------------------------------------------------------------------------
def _day_slot_index(window: ServingWindow) -> np.ndarray:
    """(S,) index of each window slot in the 24h-periodic day table."""
    day = 24 * window.sph
    s0 = int(round((window.t0_h % 24.0) * window.sph))
    return (s0 + np.arange(window.n_slots)) % day


def _window_trace(window: ServingWindow):
    """The window's carbon as a TraceSignal anchored at the lane start
    (padded past the window so a residual trickle clamps, not wraps)."""
    from repro.core.signal import TraceSignal
    hours = window.t0_h + np.arange(int(math.ceil(window.window_h)) + 48)
    vals = sample_signal(window.carbon_sig, hours + 0.5)
    return TraceSignal(tuple(float(v) for v in vals),
                       start_hour=window.t0_h % 24.0, name="serving-carbon")


def _u_for_demand(demand: np.ndarray, window: ServingWindow,
                  k: int = 129) -> np.ndarray:
    """Invert the rate model per slot: the intensity at which one lane
    completes `demand[s]` scenarios within slot s under that slot's
    background load.  Monotone interpolation on a shared u-grid."""
    us = np.linspace(0.0, 1.0, k)
    r = model.campaign_rates(us[:, None], window.batch_size,
                             window.background[None, :], window.workload,
                             window.machine, xp=np)
    cap = np.asarray(r.r_eff, dtype=float) * 3600.0 * window.slot_h
    u = np.zeros(window.n_slots)
    for s in range(window.n_slots):
        u[s] = np.interp(demand[s], cap[:, s], us)
    return u


def execute_assignment(assignment: Assignment, window: ServingWindow,
                       tiers: Sequence[QualityTier], *, site=None,
                       backend: Optional[str] = None,
                       precision: str = "fp64",
                       devices: Optional[int] = None,
                       pallas=None,
                       cache_dir: Optional[str] = None
                       ) -> Tuple[List[SimResult], AllocationSchedule,
                                  Optional[float]]:
    """Lower the admitted demand block into per-tier scan lanes and run
    them through `compile_plan -> execute_plan -> summarize_plan` — one
    compiled sweep for the whole window.  Returns the per-lane
    `SimResult`s (empty tiers skipped), the executed
    `AllocationSchedule` demand block, and the peak site draw (kW,
    site-coupled runs only).  `precision`/`devices`/`pallas` forward to
    the engine's scale-out knobs (see `engine_jax.execute_plan`)."""
    day = 24 * window.sph
    day_idx = _day_slot_index(window)
    trace = _window_trace(window)
    members: List[ParametricSchedule] = []
    cases: List[SweepCase] = []
    lane_tiers: List[int] = []
    for t, tier in enumerate(tiers):
        w_t = float(assignment.demand[t].sum())
        if w_t <= 0.0:
            continue
        u = _u_for_demand(assignment.demand[t], window)
        day_u = np.zeros(day)
        day_u[day_idx] = u
        sched = ParametricSchedule.from_intensities(
            day_u, u_min=0.0, u_max=1.0, batch_size=window.batch_size,
            name=f"serving[{assignment.policy}]/{tier.name}")
        wl = dataclasses.replace(window.workload,
                                 name=f"serving-{tier.name}",
                                 n_scenarios=w_t)
        cases.append(SweepCase(schedule=sched, workload=wl,
                               machine=window.machine, bands=window.bands,
                               carbon=trace,
                               start_hour=window.t0_h % 24.0,
                               label=sched.name))
        members.append(sched)
        lane_tiers.append(t)
    alloc = AllocationSchedule(
        tuple(members) or (ParametricSchedule.from_intensities(
            np.zeros(day), u_min=0.0, u_max=1.0,
            batch_size=window.batch_size, name="serving-empty"),),
        name=f"serving[{assignment.policy}]")
    if not cases:
        return [], alloc, None

    groups = {}
    if site is not None:
        groups = dict(group_sizes=[len(cases)],
                      group_caps_kw=[getattr(site, "power_cap_kw", None)],
                      group_office_kw=[float(getattr(site, "office_kw", 0.0)
                                             or 0.0)])
    plan = compile_plan(cases, price=window.price,
                        slots_per_hour=window.sph, precision=precision,
                        cache_dir=cache_dir, **groups)
    state = execute_plan(plan, backend=backend, devices=devices,
                         pallas=pallas)
    results = summarize_plan(plan, state)
    peak = (float(np.max(state.site_kw_peak))
            if state.site_kw_peak is not None else None)
    for r, t in zip(results, lane_tiers):
        r.policy = f"{assignment.policy}/{tiers[t].name}"
    return results, alloc, peak


# ---------------------------------------------------------------------------
# Window reports and the session rollup
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class WindowReport:
    """One scheduled-and-executed arrival window."""
    policy: str
    t0_h: float
    window_h: float
    n_requests: int
    n_admitted: int
    n_rejected: int
    n_degraded: int
    n_slo_miss: int
    energy_kwh: float
    co2_kg: float
    cost_usd: Optional[float]
    peak_kw: Optional[float]
    assignment: Assignment
    schedule: AllocationSchedule          # the executed demand block
    lanes: List[SimResult]
    request_energy_kwh: np.ndarray        # (N,) attribution (sums to total)
    request_co2_kg: np.ndarray            # (N,) carbon-weighted attribution
    slo_ok: np.ndarray                    # (N,) bool

    @property
    def slo_miss_rate(self) -> float:
        return self.n_slo_miss / max(self.n_requests, 1)


@dataclasses.dataclass(frozen=True)
class ServingRollup:
    """Session-level totals across every executed window — the serving
    analogue of the fleet's `SiteRollup`."""
    n_requests: int
    n_admitted: int
    n_rejected: int
    n_degraded: int
    n_slo_miss: int
    energy_kwh: float
    co2_kg: float
    cost_usd: Optional[float] = None
    peak_kw: Optional[float] = None
    n_windows: int = 0

    @property
    def slo_miss_rate(self) -> float:
        return self.n_slo_miss / max(self.n_requests, 1)


def serve_window(batch: ArrivalBatch, window: ServingWindow, *,
                 policy="greedy", tiers: Sequence[QualityTier] = DEFAULT_TIERS,
                 site=None, seed: int = 0,
                 backend: Optional[str] = None,
                 cache_dir: Optional[str] = None) -> WindowReport:
    """Schedule one arrival window and execute it in one compiled
    sweep: policy assignment (admission + slot + tier), engine
    execution of the admitted demand block, per-request SLO check and
    energy/CO2 attribution.  The functional core under
    `ServingSession.tick` (use it directly for policy comparisons on a
    shared window)."""
    pol = as_serving_policy(policy)
    asn = pol.assign(batch, window, tiers, seed=seed)
    lanes, alloc, peak = execute_assignment(asn, window, tiers, site=site,
                                            backend=backend,
                                            cache_dir=cache_dir)

    adm = asn.admitted
    slo_ok = adm & (asn.t_finish_h <= batch.deadline_h + 1e-9)
    tier_req = np.minimum(batch.tier, len(tiers) - 1)
    degraded = adm & (asn.tier != tier_req)
    n = batch.n

    # per-request attribution: energy by work share within the tier
    # lane, CO2 additionally weighted by the assigned slot's carbon —
    # shares sum exactly to the lane totals the engine reported
    w_exec = _scaled_work(batch, tiers, asn.tier) * adm
    req_kwh = np.zeros(n)
    req_co2 = np.zeros(n)
    slot_carbon = window.carbon[np.maximum(asn.slot, 0)]
    for t in range(len(tiers)):
        r = next((lr for lr in lanes
                  if lr.policy.endswith("/" + tiers[t].name)), None)
        if r is None:
            continue
        m = adm & (asn.tier == t)
        wt = w_exec * m
        tot = wt.sum()
        if tot > 0.0:
            req_kwh += r.energy_kwh * wt / tot
            cwt = wt * slot_carbon
            req_co2 += r.co2_kg * cwt / max(cwt.sum(), 1e-300)

    stats = engine_jax._STATS
    stats.requests_seen += n
    stats.requests_admitted += int(adm.sum())
    stats.requests_rejected += int(n - adm.sum())
    stats.requests_degraded += int(degraded.sum())

    cost = (sum(r.cost_usd for r in lanes)
            if lanes and all(r.cost_usd is not None for r in lanes) else None)
    return WindowReport(
        policy=asn.policy, t0_h=window.t0_h, window_h=window.window_h,
        n_requests=n, n_admitted=int(adm.sum()),
        n_rejected=int(n - adm.sum()), n_degraded=int(degraded.sum()),
        n_slo_miss=int(n - slo_ok.sum()),
        energy_kwh=float(sum(r.energy_kwh for r in lanes)),
        co2_kg=float(sum(r.co2_kg for r in lanes)), cost_usd=cost,
        peak_kw=peak, assignment=asn, schedule=alloc, lanes=lanes,
        request_energy_kwh=req_kwh, request_co2_kg=req_co2, slo_ok=slo_ok)


# ---------------------------------------------------------------------------
# The session surface
# ---------------------------------------------------------------------------
class ServingSession:
    """Carbon-aware request-level scheduling as a session object.

    **Windowed mode** (the batch path): `submit()` queues arrivals —
    an `ArrivalBatch`, or generator kwargs forwarded to
    `arrival_stream` — `tick()` schedules and executes one window
    through the compiled sweep, `drain()` runs the queue dry and
    returns the `ServingRollup`.

        sess = carina.ServingSession(policy="greedy", service_rate=50.0)
        sess.submit(n=1_000_000, shape="camel", seed=7)
        rollup = sess.drain()
        rollup.co2_kg, rollup.slo_miss_rate

    **Live mode** (the decode-serving adapter): `gate_open()` gates
    admissions on the current grid carbon (with queue-pressure
    override) and `record_tick()` accounts one engine iteration's
    runtime/energy/CO2 — the surface repro/serving/engine.py plugs
    into, replacing the legacy `CarinaController` wiring.
    """

    def __init__(self, workload: Optional[OEMWorkload] = None,
                 machine: Optional[MachineProfile] = None,
                 bands: Optional[TimeBands] = None,
                 carbon=None, price: Optional[Signal] = None, *,
                 window_h: float = 24.0, slots_per_hour: int = 1,
                 start_hour: float = 0.0, service_rate: float = 25.0,
                 batch_size: int = 50, batch_overhead_s: float = 2.0,
                 tiers: Sequence[QualityTier] = DEFAULT_TIERS,
                 policy="greedy", site=None,
                 fill_frac: float = DEFAULT_FILL_FRAC, seed: int = 0,
                 backend: Optional[str] = None,
                 clock: Optional[SimClock] = None,
                 chip: Optional[ChipProfile] = None,
                 step_cost: Optional[StepCost] = None, tracker=None,
                 gate: Optional[float] = None, max_queue: int = 32,
                 cache_dir: Optional[str] = None):
        self.workload = workload or OEMWorkload(
            "serving", 0, rate_at_full=float(service_rate),
            batch_overhead_s=float(batch_overhead_s))
        if self.workload.rate_at_full <= 0.0:
            raise ValueError("the serving workload template needs a "
                             "positive rate_at_full (the service rate)")
        self.machine = machine or MachineProfile()
        self.bands = bands or TimeBands()
        self._carbon_raw = carbon if carbon is not None else GridCarbonModel()
        self.carbon_sig = carbon_signal(self._carbon_raw)
        self.price = price
        self.window_h = float(window_h)
        self.sph = int(slots_per_hour)
        self.tiers = tuple(tiers)
        self.policy = policy
        self.site = site
        self.fill_frac = float(fill_frac)
        self.batch_size = int(batch_size)
        self.seed = int(seed)
        self.backend = backend
        self.cache_dir = cache_dir
        self._t0 = float(start_hour)
        self._queue: List[ArrivalBatch] = []
        self.reports: List[WindowReport] = []
        # live-mode accessories (the decode-engine adapter)
        self.clock = clock or SimClock(start_hour=float(start_hour))
        self.energy = EnergyModel(chip=chip or ChipProfile())
        self.step_cost = step_cost
        self.tracker = tracker
        self.gate = gate
        self.max_queue = int(max_queue)
        self.live_units = 0
        self.live_energy_kwh = 0.0
        self.live_co2_kg = 0.0

    # ---- windowed mode ----------------------------------------------------
    def submit(self, arrivals: Union[ArrivalBatch, int, None] = None,
               **gen_kwargs) -> ArrivalBatch:
        """Queue one window of arrivals: pass an `ArrivalBatch`, or
        `n=`/generator kwargs forwarded to `arrival_stream` (the
        window length is the session's; the seed defaults to the
        session seed offset by the windows queued so far)."""
        if isinstance(arrivals, ArrivalBatch):
            if gen_kwargs:
                raise ValueError("pass either an ArrivalBatch or "
                                 "generator kwargs, not both")
            batch = arrivals
        else:
            if isinstance(arrivals, int):
                gen_kwargs.setdefault("n", arrivals)
            gen_kwargs.setdefault("seed",
                                  self.seed + len(self._queue)
                                  + len(self.reports))
            batch = arrival_stream(horizon_h=self.window_h, **gen_kwargs)
        if batch.horizon_h > self.window_h + 1e-9:
            raise ValueError(
                f"batch horizon {batch.horizon_h} h exceeds the session "
                f"window ({self.window_h} h)")
        self._queue.append(batch)
        return batch

    @property
    def pending(self) -> int:
        """Windows queued and not yet ticked."""
        return len(self._queue)

    def window(self) -> ServingWindow:
        """The next window's per-slot context (capacity, carbon,
        background), without scheduling anything."""
        return ServingWindow.build(
            self._t0, self.window_h, slots_per_hour=self.sph,
            workload=self.workload, machine=self.machine, bands=self.bands,
            carbon_sig=self.carbon_sig, price=self.price,
            fill_frac=self.fill_frac, batch_size=self.batch_size)

    def tick(self) -> WindowReport:
        """Schedule and execute the oldest queued window in one
        compiled sweep; advances the session clock by one window."""
        if not self._queue:
            raise ValueError("no arrivals queued; submit() first")
        batch = self._queue.pop(0)
        report = serve_window(
            batch, self.window(), policy=self.policy, tiers=self.tiers,
            site=self.site, seed=self.seed + len(self.reports),
            backend=self.backend, cache_dir=self.cache_dir)
        self._t0 += self.window_h
        self.reports.append(report)
        return report

    def drain(self, max_windows: int = 10_000) -> ServingRollup:
        """Tick until the queue is empty; returns the session rollup."""
        for _ in range(max_windows):
            if not self._queue:
                break
            self.tick()
        return self.rollup()

    def rollup(self) -> ServingRollup:
        rs = self.reports
        cost = (sum(r.cost_usd for r in rs)
                if rs and all(r.cost_usd is not None for r in rs) else None)
        peaks = [r.peak_kw for r in rs if r.peak_kw is not None]
        return ServingRollup(
            n_requests=sum(r.n_requests for r in rs),
            n_admitted=sum(r.n_admitted for r in rs),
            n_rejected=sum(r.n_rejected for r in rs),
            n_degraded=sum(r.n_degraded for r in rs),
            n_slo_miss=sum(r.n_slo_miss for r in rs),
            energy_kwh=sum(r.energy_kwh for r in rs),
            co2_kg=sum(r.co2_kg for r in rs), cost_usd=cost,
            peak_kw=max(peaks) if peaks else None, n_windows=len(rs))

    # ---- live mode (decode-serving adapter) -------------------------------
    def gate_open(self, queue_depth: int = 0) -> bool:
        """Admission gate for the live decode engine: open when the
        current grid carbon is at or below `gate` (always open with no
        gate), with a queue-pressure override — a backlog at or above
        `max_queue` forces admissions so dirty hours delay, never
        starve, traffic."""
        if self.gate is None:
            return True
        if queue_depth >= self.max_queue:
            return True
        return float(self.carbon_sig.at(self.clock.hours)) <= self.gate

    def record_tick(self, runtime_s: float, *, active: int = 1,
                    steps: int = 1, intensity: float = 1.0,
                    meta: Optional[dict] = None) -> float:
        """Account one live engine iteration: advance the session
        clock, estimate energy (roofline when a `StepCost` is known,
        machine-profile runtime mode otherwise), convert to CO2 at the
        current grid intensity, and append a tracked unit when the
        session owns a `RunTracker`.  Returns the kWh recorded."""
        self.clock.advance_s(runtime_s)
        if self.step_cost is not None:
            kwh = steps * max(active, 1) * self.energy.step_energy_j(
                self.step_cost, intensity) / 3.6e6
        else:
            kwh = self.energy.runtime_energy_kwh(runtime_s, intensity)
        hour = self.clock.hour_of_day()
        co2 = kwh * float(self.carbon_sig.at(self.clock.hours))
        self.live_units += 1
        self.live_energy_kwh += kwh
        self.live_co2_kg += co2
        if self.tracker is not None:
            self.tracker.record_unit(
                phase=self.bands.band_at(hour), intensity=float(intensity),
                runtime_s=float(runtime_s), energy_kwh=float(kwh),
                sim_time_h=self.clock.hours,
                meta=dict(meta or {}, active=active, steps=steps))
        return kwh


# ---------------------------------------------------------------------------
# Reference implementation (benchmark baseline)
# ---------------------------------------------------------------------------
def _fifo_assign_loop(batch: ArrivalBatch, window: ServingWindow,
                      tiers: Sequence[QualityTier] = DEFAULT_TIERS
                      ) -> Assignment:
    """Per-request Python-loop FIFO — the naive implementation the
    vectorized `FifoServingPolicy` replaces.  Kept as the benchmark
    baseline (`benchmarks/run.py serving_sweep`) and as an equivalence
    oracle; produces the same outputs (service slot, finish time, the
    per-tier demand block) one request at a time."""
    budgets = window.budgets
    tier_idx = np.minimum(batch.tier, len(tiers) - 1)
    work = _scaled_work(batch, tiers, tier_idx)
    slot_h = window.slot_h
    S = window.n_slots
    out = np.full(batch.n, -1, dtype=np.int64)
    t_fin = np.full(batch.n, np.inf)
    demand = np.zeros((len(tiers), S))
    s = 0
    room = float(budgets[0]) if S else 0.0
    for i in range(batch.n):
        a = min(int(batch.t_arrive_h[i] / slot_h), S - 1)
        if s < a:
            s = a
            room = float(budgets[s])
        need = float(work[i])
        t = int(tier_idx[i])
        spill = []                      # (slot, amount) before the last
        while need > room + 1e-12:
            need -= room
            spill.append((s, room))
            s += 1
            if s >= S:
                break
            room = float(budgets[s])
        if s >= S:
            break                       # rejected: spill never lands
        room -= need
        for sp, amt in spill:
            demand[t, sp] += amt
        demand[t, s] += need
        out[i] = s
        b = float(budgets[s])
        t_fin[i] = slot_h * (s + min(max((b - room) / max(b, 1e-12),
                                         0.0), 1.0))
    return Assignment("fifo-loop", out, tier_idx, t_fin, demand)


__all__ = ["Assignment", "DEFAULT_FILL_FRAC", "FifoServingPolicy",
           "GreedyServingPolicy", "OptimizedServingPolicy",
           "SERVING_POLICIES", "ServingRollup", "ServingSession",
           "ServingWindow", "WindowReport", "as_serving_policy",
           "execute_assignment", "serve_window"]
