"""Tuned XLA flag profiles for the scan engine.

XLA reads its flags from the ``XLA_FLAGS`` environment variable once, at
backend initialization — flags changed after the first `jax.devices()`
call are silently ignored.  This module therefore deals only in
*strings and environment dicts* (no jax import at module scope) so that
benchmark parents and test harnesses can assemble an environment for a
subprocess, and applications can call `apply_profile` before first use.

The flag-dictionary pattern (one dict per profile, merged and rendered
as ``--name=value`` tokens) mirrors how production jax codebases ship
tuned flag sets per topology; profiles here are deliberately small and
CPU-focused since that is where the test matrix runs:

- ``cpu_scan``    — conservative CPU profile for the chunked scan: keep
  fast-math off so fp parity pins stay honest, let Eigen use the host
  threads it finds.
- ``cpu_fanout``  — `cpu_scan` plus ``xla_force_host_platform_device_count``
  so one host exposes N virtual CPU devices for `shard_map` lanes.
- ``default``     — empty; inherit whatever the process already has.

Usage::

    from repro.core.xla_profiles import apply_profile, fanout_env
    apply_profile("cpu_scan")           # before any jax.* call
    env = fanout_env(8)                 # env dict for a subprocess
"""
from __future__ import annotations

import os
import sys
from typing import Dict, Mapping, Optional

# One dict per profile; values are strings exactly as XLA parses them.
CPU_SCAN_FLAGS: Dict[str, str] = {
    # Parity pins (bitwise fp64 shard-vs-single, 1e-9 Pallas-vs-jnp)
    # assume IEEE semantics; never trade them for fast-math.
    "xla_cpu_enable_fast_math": "false",
    # The chunk kernels are large fused loops; multi-threaded Eigen
    # helps the single-device path on multi-core hosts.
    "xla_cpu_multi_thread_eigen": "true",
}

PROFILES: Dict[str, Dict[str, str]] = {
    "default": {},
    "cpu_scan": CPU_SCAN_FLAGS,
}


def fanout_flags(devices: int) -> Dict[str, str]:
    """Flags exposing `devices` virtual CPU devices on one host."""
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    return {"xla_force_host_platform_device_count": str(int(devices))}


def flags_string(profile: str = "default", *,
                 extra: Optional[Mapping[str, str]] = None,
                 base: Optional[str] = None) -> str:
    """Render a profile (plus overrides) as an ``XLA_FLAGS`` string.

    `base` is an existing ``XLA_FLAGS`` value to prepend (defaults to
    the current environment's); profile flags and then `extra` override
    duplicates by coming later in the string — XLA takes the last
    occurrence of a flag.
    """
    if profile not in PROFILES:
        raise KeyError(f"unknown XLA profile {profile!r}; "
                       f"have {sorted(PROFILES)}")
    if base is None:
        base = os.environ.get("XLA_FLAGS", "")
    merged = dict(PROFILES[profile])
    if extra:
        merged.update({str(k): str(v) for k, v in extra.items()})
    tokens = [base.strip()] if base and base.strip() else []
    tokens += [f"--{k}={v}" for k, v in merged.items()]
    return " ".join(tokens)


def fanout_env(devices: int, profile: str = "cpu_scan", *,
               extra: Optional[Mapping[str, str]] = None,
               base_env: Optional[Mapping[str, str]] = None
               ) -> Dict[str, str]:
    """A full environment dict for launching a subprocess with `devices`
    virtual CPU devices under `profile`.  Pins ``JAX_PLATFORMS=cpu`` so
    the fan-out flag is honored even where other backends exist."""
    env = dict(base_env if base_env is not None else os.environ)
    merged = dict(fanout_flags(devices))
    if extra:
        merged.update(extra)
    env["XLA_FLAGS"] = flags_string(profile, extra=merged,
                                    base=env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    return env


def _jax_initialized() -> bool:
    """Best-effort: has this process already stood up an XLA backend?"""
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        xb = sys.modules.get("jax._src.xla_bridge")
        return bool(xb is not None and getattr(xb, "_backends", None))
    except Exception:  # pragma: no cover - defensive
        return False


def apply_profile(profile: str = "cpu_scan", *,
                  extra: Optional[Mapping[str, str]] = None) -> str:
    """Install a profile into this process's ``XLA_FLAGS``.

    Must run before jax initializes a backend; if one already exists the
    flags are still set (harmless) but a warning is emitted because XLA
    will not re-read them.  Returns the installed string.
    """
    if _jax_initialized():
        import warnings
        warnings.warn("apply_profile called after jax backend "
                      "initialization; XLA_FLAGS changes will not take "
                      "effect in this process", RuntimeWarning,
                      stacklevel=2)
    flags = flags_string(profile, extra=extra)
    os.environ["XLA_FLAGS"] = flags
    return flags
