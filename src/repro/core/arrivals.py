"""Request-arrival streams for the online-serving layer (core/serve.py).

The paper plans *recurrent* campaigns offline; the serving layer
schedules *streaming* arrivals — requests that show up continuously,
each with a deadline, a work size (in the same scenario units the rate
model speaks), and a requested quality tier.  This module is the data
side of that layer:

  * `ArrivalBatch` — one arrival window as a struct-of-arrays (sorted
    arrival times, absolute deadlines, work sizes, requested tiers), so
    a million-request day is four NumPy arrays, not a million objects;
  * `QualityTier` — the CarbonShiftML-style quality axis: tier k runs
    `work * work_scale` (a cheaper model / coarser analysis), which
    admission policies may fall back to when clean capacity is scarce;
  * `arrival_stream` — seeded synthetic generators for the four load
    shapes of the temporal-shifting literature (arXiv:2508.14625):
    `random`, `linear`, `peak`, `camel`.

Everything is deterministic under an explicit `seed=` — generators own
a `np.random.default_rng(seed)` and never touch global RNG state, so a
(seed, shape, n) triple pins the exact same stream across runs and
backends.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

#: The synthetic load shapes (density of arrivals over the window).
LOAD_SHAPES: Tuple[str, ...] = ("random", "linear", "peak", "camel")


@dataclasses.dataclass(frozen=True)
class QualityTier:
    """One rung of the quality/effort ladder a request may run at.

    `work_scale` multiplies the request's full-quality work size: a
    0.25 tier does a quarter of the compute (and typically delivers a
    degraded answer).  Policies may *degrade* a request to a cheaper
    tier than requested, never upgrade it.
    """
    name: str
    work_scale: float

    def __post_init__(self):
        if not (0.0 < self.work_scale <= 1.0):
            raise ValueError(f"work_scale must be in (0, 1], got "
                             f"{self.work_scale}")


#: Full quality, a half-compute tier, and an eco tier — the default
#: ladder admission policies degrade down when clean capacity is scarce.
DEFAULT_TIERS: Tuple[QualityTier, ...] = (
    QualityTier("full", 1.0),
    QualityTier("reduced", 0.5),
    QualityTier("eco", 0.25),
)


@dataclasses.dataclass(frozen=True)
class ArrivalBatch:
    """One window of request arrivals, as parallel arrays sorted by
    arrival time.

    Times are hours relative to the window start: request i arrives at
    `t_arrive_h[i]` and must finish by `deadline_h[i]` (absolute, not
    slack — always >= the arrival).  `work[i]` is the full-quality work
    size in scenario units (the rate model's currency); `tier[i]` the
    *requested* quality tier index into the session's tier ladder.
    """
    t_arrive_h: np.ndarray       # (N,) float, sorted ascending
    deadline_h: np.ndarray       # (N,) float, >= t_arrive_h
    work: np.ndarray             # (N,) float, > 0
    tier: np.ndarray             # (N,) int, requested quality tier
    horizon_h: float = 24.0

    def __post_init__(self):
        arr = np.asarray(self.t_arrive_h, dtype=float)
        ddl = np.asarray(self.deadline_h, dtype=float)
        wrk = np.asarray(self.work, dtype=float)
        tr = np.asarray(self.tier, dtype=np.int64)
        if not (len(arr) == len(ddl) == len(wrk) == len(tr)):
            raise ValueError(
                f"arrival arrays disagree on length: "
                f"{len(arr)}/{len(ddl)}/{len(wrk)}/{len(tr)}")
        if len(arr) and np.any(arr[1:] < arr[:-1]):
            raise ValueError("arrivals must be sorted by t_arrive_h")
        if np.any(ddl < arr):
            raise ValueError("every deadline must be >= its arrival")
        if np.any(wrk <= 0.0):
            raise ValueError("work sizes must be positive")
        if np.any(tr < 0):
            raise ValueError("tier indices must be >= 0")
        if len(arr) and float(arr[-1]) >= float(self.horizon_h):
            raise ValueError(
                f"arrival at {float(arr[-1]):g} h is outside the "
                f"{float(self.horizon_h):g} h window")
        object.__setattr__(self, "t_arrive_h", arr)
        object.__setattr__(self, "deadline_h", ddl)
        object.__setattr__(self, "work", wrk)
        object.__setattr__(self, "tier", tr)
        object.__setattr__(self, "horizon_h", float(self.horizon_h))

    @property
    def n(self) -> int:
        return len(self.t_arrive_h)

    def __len__(self) -> int:
        return self.n

    @staticmethod
    def merge(batches: Sequence["ArrivalBatch"]) -> "ArrivalBatch":
        """Merge same-window batches into one, re-sorted by arrival
        (stable, so equal arrival times keep submission order)."""
        if not batches:
            raise ValueError("merge needs at least one batch")
        horizon = max(b.horizon_h for b in batches)
        arr = np.concatenate([b.t_arrive_h for b in batches])
        order = np.argsort(arr, kind="stable")
        return ArrivalBatch(
            arr[order],
            np.concatenate([b.deadline_h for b in batches])[order],
            np.concatenate([b.work for b in batches])[order],
            np.concatenate([b.tier for b in batches])[order],
            horizon_h=horizon)


def _shape_density(shape: str, t: np.ndarray, horizon_h: float,
                   peak_frac: float, camel_fracs: Tuple[float, float]
                   ) -> np.ndarray:
    """Un-normalized arrival density over window-relative hours `t`."""
    x = t / horizon_h                       # [0, 1)
    if shape == "random":
        return np.ones_like(x)
    if shape == "linear":
        # ramp from 0.2x to 1.8x the mean rate across the window
        return 0.2 + 1.6 * x
    if shape == "peak":
        # one bump (diurnal rush) on a floor of background traffic
        return 0.1 + np.exp(-0.5 * ((x - peak_frac) / 0.10) ** 2)
    if shape == "camel":
        # two humps (morning + evening) on the same floor
        a, b = camel_fracs
        return (0.1 + np.exp(-0.5 * ((x - a) / 0.08) ** 2)
                + np.exp(-0.5 * ((x - b) / 0.08) ** 2))
    raise ValueError(f"unknown load shape {shape!r}; choose from "
                     f"{LOAD_SHAPES}")


def arrival_stream(n: int, horizon_h: float = 24.0,
                   shape: str = "random", *, seed: int = 0,
                   mean_work: float = 1.0, work_sigma: float = 0.5,
                   slack_h: Tuple[float, float] = (1.0, 8.0),
                   tier_mix: Sequence[float] = (1.0,),
                   peak_frac: float = 0.75,
                   camel_fracs: Tuple[float, float] = (0.35, 0.8)
                   ) -> ArrivalBatch:
    """A seeded synthetic arrival stream of `n` requests over one window.

    `shape` picks the arrival-density curve (`LOAD_SHAPES`); arrival
    times are drawn by inverse-CDF sampling of that density, so the
    empirical histogram follows the curve at any `n`.  Work sizes are
    lognormal around `mean_work` (σ = `work_sigma` in log space,
    mean-corrected so the expected work is exactly `mean_work`);
    deadlines are the arrival plus a uniform slack in `slack_h`;
    requested tiers are drawn from the `tier_mix` weights (index k =
    tier k of the session's ladder — the default requests full quality
    for everyone).  `peak_frac` / `camel_fracs` place the bump centers
    as fractions of the window.

    Deterministic: one `np.random.default_rng(seed)` drives every draw;
    no global RNG state is read or written.
    """
    if n < 1:
        raise ValueError(f"need at least one request, got n={n}")
    if horizon_h <= 0.0:
        raise ValueError(f"horizon_h must be positive, got {horizon_h}")
    lo, hi = float(slack_h[0]), float(slack_h[1])
    if not (0.0 < lo <= hi):
        raise ValueError(f"slack_h must satisfy 0 < lo <= hi, got {slack_h}")
    rng = np.random.default_rng(seed)

    # inverse-CDF sampling on a fine grid: density -> CDF -> quantiles
    grid = np.linspace(0.0, horizon_h, 2049)
    mid = 0.5 * (grid[1:] + grid[:-1])
    dens = _shape_density(shape, mid, horizon_h, peak_frac, camel_fracs)
    cdf = np.concatenate([[0.0], np.cumsum(dens)])
    cdf /= cdf[-1]
    t = np.interp(rng.random(n), cdf, grid)
    t = np.sort(np.minimum(t, np.nextafter(horizon_h, 0.0)))

    # mean-corrected lognormal work sizes (E[work] == mean_work)
    work = mean_work * np.exp(
        work_sigma * rng.standard_normal(n) - 0.5 * work_sigma ** 2)
    work = np.maximum(work, 1e-3 * mean_work)

    deadline = t + rng.uniform(lo, hi, size=n)

    mix = np.asarray(tier_mix, dtype=float)
    if mix.ndim != 1 or len(mix) < 1 or np.any(mix < 0.0) or mix.sum() <= 0:
        raise ValueError(f"tier_mix must be non-negative weights, got "
                         f"{tier_mix}")
    tier = rng.choice(len(mix), size=n, p=mix / mix.sum())

    return ArrivalBatch(t, deadline, work, tier, horizon_h=horizon_h)


__all__ = ["ArrivalBatch", "DEFAULT_TIERS", "LOAD_SHAPES", "QualityTier",
           "arrival_stream"]
