"""Fit the shared rate/power model to *measured* runs (RunTracker logs).

CARINA's sweeps and optimizers are only as good as `core/model.py`'s
parameters — and until now those were asserted, never fitted.  This
module closes the loop: lift a `RunTracker` JSONL unit stream into
per-slot observed (throughput, average power) targets, then fit the
rate/power parameters by the same Adam machinery the schedule optimizer
uses (`optimize._grad_search`), with the model's scalar/np/jnp
polymorphism providing the gradient path for free.

`CalibrationObjective` is the per-slot measured-targets analogue of the
engine's `TraceObjective`: where `TraceObjective` maps a *schedule*
parameter vector to a scalar loss through the scan, this maps a *model*
parameter vector to a scalar misfit against logged units — same closure
contract, so `_grad_search` (jit + Adam through `jax.value_and_grad`)
drives both.  Parameters are fitted in log space
(theta_i = init_i * exp(p_i)): positivity is structural and the search
is conditioned on *relative* moves, so watts-scale and unitless
parameters share one learning rate.

A NumPy fallback (`_fd_adam`, deterministic central differences + the
same Adam update) keeps calibration working where jax is unavailable;
bootstrap confidence intervals resample units via multinomial weights
(so no array re-gather, and the numpy refits are cheap).

Surfaced as `Campaign.calibrate(log_path=...)`; pinned by the
round-trip test (simulate with known params -> log -> fit recovers them
within 2%) in tests/test_calibrate.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import model
from repro.core.policy import TimeBands
from repro.core.tracker import UnitRecord, load_units

# The identifiable parameter set, given band-varying background and
# hour-varying intensity: throughput observations pin (rate_at_full,
# gamma), power observations pin (idle_w, dyn_w, overhead_w_frac).
# `alpha` and `batch_overhead_s` stay fixed at their configured values —
# alpha trades off against dyn_w on smooth load ranges, and the batch
# overhead is directly measurable, not worth burning excitation on.
FIT_PARAMS = ("rate_at_full", "gamma", "idle_w", "dyn_w",
              "overhead_w_frac")
_WORKLOAD_PARAMS = frozenset({"rate_at_full", "batch_overhead_s"})
_MACHINE_PARAMS = frozenset({"idle_w", "dyn_w", "alpha", "gamma",
                             "overhead_w_frac"})


@dataclasses.dataclass(frozen=True)
class Observations:
    """Per-unit measured operating points lifted from a tracker log."""
    u: np.ndarray            # worker intensity commanded
    batch: np.ndarray        # batch size
    background: np.ndarray   # contention load (from the unit's band)
    scen_per_s: np.ndarray   # observed throughput (scenarios / wall s)
    p_avg_w: np.ndarray      # observed average power (W)
    weight: np.ndarray       # per-unit weight (wall seconds, normalized)

    @property
    def n(self) -> int:
        return int(self.u.shape[0])


def observations_from_units(units: Sequence[UnitRecord],
                            bands: Optional[TimeBands] = None
                            ) -> Observations:
    """Lift tracked units into calibration targets.

    Keeps units that carry what the model predicts: positive runtime, a
    commanded intensity, a scenario count (`meta["scenarios"]`) and a
    batch size (`meta["batch"]`).  The unit's band name maps to the
    contention background via `bands`; units from unknown bands are
    dropped rather than guessed at.
    """
    bands = bands or TimeBands()
    u, batch, bg, thr, pw, w = [], [], [], [], [], []
    for r in units:
        scen = float(r.meta.get("scenarios", 0.0) or 0.0)
        b = float(r.meta.get("batch", 0.0) or 0.0)
        if r.runtime_s <= 0.0 or r.intensity <= 0.0 or scen <= 0.0 \
                or b <= 0.0 or r.energy_kwh <= 0.0:
            continue
        try:
            background = float(bands.background(r.phase))
        except KeyError:
            continue
        u.append(float(r.intensity))
        batch.append(b)
        bg.append(background)
        thr.append(scen / r.runtime_s)
        pw.append(r.energy_kwh * 3.6e6 / r.runtime_s)
        w.append(r.runtime_s)
    if not u:
        raise ValueError(
            "no calibratable units: records need runtime_s > 0, "
            "intensity > 0, energy_kwh > 0 and meta scenarios/batch "
            "(RunTracker logs from simulate_campaign / Campaign.run("
            "track=True) qualify)")
    weight = np.asarray(w, dtype=float)
    return Observations(u=np.asarray(u, dtype=float),
                        batch=np.asarray(batch, dtype=float),
                        background=np.asarray(bg, dtype=float),
                        scen_per_s=np.asarray(thr, dtype=float),
                        p_avg_w=np.asarray(pw, dtype=float),
                        weight=weight / weight.sum())


def load_observations(log_path: str,
                      bands: Optional[TimeBands] = None) -> Observations:
    """`observations_from_units` over a JSONL tracker log on disk."""
    return observations_from_units(load_units(log_path), bands)


class CalibrationObjective:
    """Model-parameter vector -> weighted relative-misfit scalar.

    The loss is the runtime-weighted mean of squared *relative* errors
    in throughput and average power — relative, so scenarios/s and
    watts contribute on equal footing and the optimum is scale-free.
    `loss_fn(xp)` returns a closure `loss(p, w=None)` over the chosen
    array namespace (np or jnp; the model is polymorphic), where `w`
    is an optional per-unit resampling weight vector (bootstrap).
    """

    def __init__(self, obs: Observations, workload, machine,
                 fit: Sequence[str] = FIT_PARAMS):
        bad = [f for f in fit
               if f not in _WORKLOAD_PARAMS | _MACHINE_PARAMS]
        if bad:
            raise ValueError(f"unknown fit parameter(s) {bad}; choose "
                             f"from {sorted(_WORKLOAD_PARAMS | _MACHINE_PARAMS)}")
        self.obs = obs
        self.fit: Tuple[str, ...] = tuple(fit)
        self.params: Dict[str, float] = {
            "rate_at_full": float(workload.rate_at_full),
            "batch_overhead_s": float(workload.batch_overhead_s),
            "idle_w": float(machine.idle_w),
            "dyn_w": float(machine.dyn_w),
            "alpha": float(machine.alpha),
            "gamma": float(machine.gamma),
            "overhead_w_frac": float(machine.overhead_w_frac)}
        zero = [f for f in self.fit if self.params[f] == 0.0]
        if zero:
            raise ValueError(
                f"cannot fit {zero} from a zero initial value (log-space "
                "parameterization needs a nonzero starting point); set a "
                "rough prior on the workload/machine first")

    def theta(self, p) -> Dict[str, object]:
        """Decode a log-space search vector into named parameters."""
        out = dict(self.params)
        for i, f in enumerate(self.fit):
            out[f] = self.params[f] * np.exp(np.asarray(p, dtype=float)[i])
        return {k: float(v) for k, v in out.items()}

    def loss_fn(self, xp=np):
        o = self.obs
        fixed = self.params
        fit = self.fit
        u, batch, bg = o.u, o.batch, o.background
        obs_r, obs_p, base_w = o.scen_per_s, o.p_avg_w, o.weight

        def loss(p, w=None):
            th = dict(fixed)
            for i, f in enumerate(fit):
                th[f] = fixed[f] * xp.exp(p[i])
            r = model.rates(u, batch, bg,
                            rate_at_full=th["rate_at_full"],
                            batch_overhead_s=th["batch_overhead_s"],
                            idle_w=th["idle_w"], dyn_w=th["dyn_w"],
                            alpha=th["alpha"], gamma=th["gamma"],
                            overhead_w_frac=th["overhead_w_frac"], xp=xp)
            err = ((r.scen_per_s - obs_r) / obs_r) ** 2 \
                + ((r.p_avg_w - obs_p) / obs_p) ** 2
            ww = base_w if w is None else base_w * w
            return (ww * err).sum() / ww.sum()

        return loss


def _fd_adam(loss, p0, steps: int, lr: float, eps: float = 1e-5
             ) -> Tuple[np.ndarray, List[float]]:
    """Deterministic central-difference Adam: the NumPy fallback mirror
    of `optimize._grad_search` (same moments, same 10.0 norm clip, best
    parameters seen returned — the loss is nonconvex)."""
    b1, b2, adam_eps = 0.9, 0.999, 1e-8
    p = np.asarray(p0, dtype=float).copy()
    m = np.zeros_like(p)
    v = np.zeros_like(p)
    best_val, best_p = math.inf, p.copy()
    history: List[float] = []
    for t in range(1, steps + 1):
        val = float(loss(p))
        if val < best_val:
            best_val, best_p = val, p.copy()
        history.append(min(val, history[-1]) if history else val)
        g = np.empty_like(p)
        for i in range(len(p)):
            d = np.zeros_like(p)
            d[i] = eps
            g[i] = (float(loss(p + d)) - float(loss(p - d))) / (2.0 * eps)
        gnorm = float(np.linalg.norm(g))
        if gnorm > 10.0:
            g *= 10.0 / gnorm
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mh = m / (1.0 - b1 ** t)
        vh = v / (1.0 - b2 ** t)
        p = p - lr * mh / (np.sqrt(vh) + adam_eps)
    return best_p, history


def _fit(objective: CalibrationObjective, p0: np.ndarray, steps: int,
         lr: float, backend: str) -> Tuple[np.ndarray, List[float]]:
    if backend == "jax":
        import jax.numpy as jnp

        from repro.core.optimize import _grad_search
        loss = objective.loss_fn(jnp)
        best_p, history, _ = _grad_search(loss, p0, steps, lr)
        return np.asarray(best_p, dtype=float), history
    best_p, history = _fd_adam(objective.loss_fn(np), p0, steps, lr)
    return best_p, history


def _resolve_backend(backend: Optional[str]) -> str:
    if backend not in (None, "jax", "numpy"):
        raise ValueError(f"backend must be 'jax' or 'numpy', got "
                         f"{backend!r}")
    if backend is not None:
        return backend
    try:
        import jax  # noqa: F401
        return "jax"
    except Exception:
        return "numpy"


@dataclasses.dataclass(frozen=True)
class CalibratedModel:
    """A fitted parameter set + provenance, ready to apply to a session."""
    params: Dict[str, float]            # fitted values (fit subset only)
    init: Dict[str, float]              # the starting values
    ci: Dict[str, Tuple[float, float]]  # bootstrap CI per fitted param
    fit: Tuple[str, ...]
    loss: float
    history: Tuple[float, ...]
    n_units: int
    backend: str
    source: Optional[str] = None        # log path the fit came from
    zone: Optional[str] = None          # emission-factor zone, if logged

    def apply(self, workload, machine):
        """(workload, machine) with the fitted parameters substituted."""
        wl_kw = {k: v for k, v in self.params.items()
                 if k in _WORKLOAD_PARAMS}
        m_kw = {k: v for k, v in self.params.items()
                if k in _MACHINE_PARAMS}
        return (dataclasses.replace(workload, **wl_kw) if wl_kw
                else workload,
                dataclasses.replace(machine, **m_kw) if m_kw else machine)

    def rel_error(self, truth: Mapping[str, float]) -> Dict[str, float]:
        """|fitted/true - 1| per fitted parameter present in `truth`."""
        return {k: abs(self.params[k] / float(truth[k]) - 1.0)
                for k in self.params if k in truth}


def fit_calibration(obs: Observations, workload, machine, *,
                    fit: Sequence[str] = FIT_PARAMS,
                    steps: int = 500, lr: float = 0.1,
                    bootstrap: int = 0, seed: int = 0,
                    confidence: float = 0.95,
                    backend: Optional[str] = None,
                    source: Optional[str] = None,
                    zone: Optional[str] = None) -> CalibratedModel:
    """Fit model parameters to observations; the calibration entry point.

    The point estimate runs on `backend` ("jax" = Adam through
    `jax.value_and_grad` via `optimize._grad_search`; "numpy" = the
    deterministic finite-difference mirror; None = jax when available).
    `bootstrap` > 0 adds seeded unit-resampling confidence intervals:
    each replicate reweights units by a multinomial draw and refits on
    the (cheap, compile-free) numpy path, warm-started from the point
    estimate; `ci` maps each fitted parameter to its central
    `confidence` interval.
    """
    be = _resolve_backend(backend)
    objective = CalibrationObjective(obs, workload, machine, fit=fit)
    p0 = np.zeros(len(objective.fit))
    best_p, history = _fit(objective, p0, steps, lr, be)
    fitted = objective.theta(best_p)
    final_loss = float(objective.loss_fn(np)(best_p))

    ci: Dict[str, Tuple[float, float]] = {}
    if bootstrap > 0:
        rng = np.random.RandomState(seed)
        loss_np = objective.loss_fn(np)
        boot_steps = max(100, steps // 3)
        thetas = []
        for _ in range(int(bootstrap)):
            w = rng.multinomial(obs.n, np.full(obs.n, 1.0 / obs.n)
                                ).astype(float)
            bp, _ = _fd_adam(lambda p: loss_np(p, w), best_p,
                             boot_steps, lr)
            thetas.append([objective.theta(bp)[f] for f in objective.fit])
        arr = np.asarray(thetas)
        tail = 100.0 * (1.0 - confidence) / 2.0
        lo = np.percentile(arr, tail, axis=0)
        hi = np.percentile(arr, 100.0 - tail, axis=0)
        ci = {f: (float(lo[i]), float(hi[i]))
              for i, f in enumerate(objective.fit)}

    return CalibratedModel(
        params={f: fitted[f] for f in objective.fit},
        init={f: objective.params[f] for f in objective.fit},
        ci=ci, fit=objective.fit, loss=final_loss,
        history=tuple(history), n_units=obs.n, backend=be,
        source=source, zone=zone)


__all__ = ["FIT_PARAMS", "CalibratedModel", "CalibrationObjective",
           "Observations", "fit_calibration", "load_observations",
           "observations_from_units"]
