"""Run-level and step-level instrumentation (paper Algorithm 1).

For each tracked unit CARINA records runtime, selected worker intensity,
estimated energy load, translated carbon burden, and execution metadata;
units aggregate into a run summary.  Records stream to JSONL so a crash
loses at most the open unit (resume/merge logic re-aggregates).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional

from repro.core.carbon import GridCarbonModel

# Version stamped into every JSONL record so logs are self-describing:
# readers can evolve the schema without guessing what an old log meant.
# v1 = the original field set + the carbon provenance meta keys.
SCHEMA_VERSION = 1


@dataclasses.dataclass
class UnitRecord:
    index: int
    phase: str                    # time band at execution
    intensity: float
    runtime_s: float
    energy_kwh: float
    co2_kg: float
    sim_time_h: float             # absolute simulated clock (hour-of-day = % 24)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schema: int = SCHEMA_VERSION

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)


@dataclasses.dataclass
class RunSummary:
    name: str
    units: int
    runtime_h: float
    energy_kwh: float
    co2_kg: float
    by_phase: Dict[str, Dict[str, float]]
    meta: Dict[str, Any]


class RunTracker:
    """granularity: "run" collapses everything into one unit at close();
    "step" records each tracked unit (paper: whole-run or step-level)."""

    def __init__(self, name: str, carbon: Optional[GridCarbonModel] = None,
                 granularity: str = "step", log_path: Optional[str] = None,
                 meta: Optional[dict] = None):
        assert granularity in ("run", "step")
        self.name = name
        self.carbon = carbon or GridCarbonModel()
        self.granularity = granularity
        self.records: List[UnitRecord] = []
        self.meta = dict(meta or {})
        # emission-factor provenance: calibration replays a log long
        # after the session that wrote it, so the log itself must say
        # which grid factor translated kWh to kg
        self.meta.setdefault("carbon_factor_kg_per_kwh",
                             self.carbon.factor_kg_per_kwh)
        if self.carbon.zone:
            self.meta.setdefault("carbon_zone", self.carbon.zone)
        if self.carbon.source:
            self.meta.setdefault("carbon_source", self.carbon.source)
        self._log_path = log_path
        self._log_file = None
        if log_path:
            os.makedirs(os.path.dirname(log_path) or ".", exist_ok=True)
            # a crashed predecessor may have left a torn (newline-less)
            # final line; isolate it so resumed records stay parseable
            if os.path.exists(log_path) and os.path.getsize(log_path) > 0:
                with open(log_path, "rb") as f:
                    f.seek(-1, os.SEEK_END)
                    torn = f.read(1) != b"\n"
            else:
                torn = False
            self._log_file = open(log_path, "a", buffering=1)
            if torn:
                self._log_file.write("\n")
        self._open_accum = {"runtime_s": 0.0, "energy_kwh": 0.0, "co2_kg": 0.0}

    # ------------------------------------------------------------------
    def record_unit(self, *, phase: str, intensity: float, runtime_s: float,
                    energy_kwh: float, sim_time_h: float,
                    meta: Optional[dict] = None) -> UnitRecord:
        co2 = self.carbon.co2_kg(energy_kwh, hour_of_day=sim_time_h % 24.0)
        if self.carbon.zone or self.carbon.source:
            meta = dict(meta or {})
            if self.carbon.zone:
                meta.setdefault("zone", self.carbon.zone)
            if self.carbon.source:
                meta.setdefault("source", self.carbon.source)
        if self.granularity == "run":
            # accumulate the hour-aware CO2 too, so run-mode totals respect
            # an hourly_curve instead of re-deriving at the flat factor
            self._open_accum["runtime_s"] += runtime_s
            self._open_accum["energy_kwh"] += energy_kwh
            self._open_accum["co2_kg"] += co2
            rec = UnitRecord(len(self.records), phase, intensity, runtime_s,
                             energy_kwh, co2, sim_time_h, meta or {})
            return rec  # not appended; aggregated at close
        rec = UnitRecord(len(self.records), phase, intensity, runtime_s,
                         energy_kwh, co2, sim_time_h, meta or {})
        self.records.append(rec)
        if self._log_file:
            self._log_file.write(rec.to_json() + "\n")
        return rec

    # ------------------------------------------------------------------
    def summary(self) -> RunSummary:
        if self.granularity == "run" and not self.records:
            e = self._open_accum["energy_kwh"]
            self.records.append(UnitRecord(
                0, "run", 1.0, self._open_accum["runtime_s"], e,
                self._open_accum["co2_kg"], 0.0, {}))
        by_phase: Dict[str, Dict[str, float]] = {}
        for r in self.records:
            d = by_phase.setdefault(r.phase, {"runtime_s": 0.0, "energy_kwh": 0.0,
                                              "co2_kg": 0.0, "units": 0.0})
            d["runtime_s"] += r.runtime_s
            d["energy_kwh"] += r.energy_kwh
            d["co2_kg"] += r.co2_kg
            d["units"] += 1
        return RunSummary(
            name=self.name,
            units=len(self.records),
            runtime_h=sum(r.runtime_s for r in self.records) / 3600.0,
            energy_kwh=sum(r.energy_kwh for r in self.records),
            co2_kg=sum(r.co2_kg for r in self.records),
            by_phase=by_phase,
            meta=self.meta,
        )

    def close(self) -> RunSummary:
        s = self.summary()
        if self._log_file:
            self._log_file.write(json.dumps(
                {"summary": dataclasses.asdict(s)}, sort_keys=True) + "\n")
            self._log_file.close()
            self._log_file = None
        return s


def load_units(path: str) -> List[UnitRecord]:
    """Recover the tracked units from a JSONL log (crash/resume path).

    Malformed lines (a unit torn mid-write by a crash) are skipped, not
    fatal — a resumed tracker appends to the same log, so valid records can
    follow a torn one.  A crash loses at most the unit that was mid-write.
    Summary lines from clean close() calls are skipped too.  Unknown keys
    (a record written by a *newer* schema) are dropped rather than fatal,
    and records missing the v1 fields are treated like torn lines — the
    `schema` field says what the writer meant, so readers degrade
    gracefully in both directions.
    """
    known = {f.name for f in dataclasses.fields(UnitRecord)}
    required = known - {"meta", "schema"}
    units: List[UnitRecord] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError:
                continue                   # torn mid-write: skip that unit
            if not isinstance(d, dict) or "summary" in d:
                continue
            if not required <= set(d):
                continue                   # truncated / foreign record
            units.append(UnitRecord(**{k: v for k, v in d.items()
                                       if k in known}))
    return units


def summary_from_units(units: List[UnitRecord], name: str = "resumed",
                       meta: Optional[dict] = None) -> RunSummary:
    """Re-aggregate recovered units into a RunSummary (same roll-up as
    RunTracker.summary, without needing a live tracker)."""
    t = RunTracker(name, meta=meta)
    t.records = list(units)
    return t.summary()


def merge_summaries(summaries: List[RunSummary], name: str = "merged") -> RunSummary:
    """Resume/merge logic: combine partial runs (paper §2)."""
    by_phase: Dict[str, Dict[str, float]] = {}
    for s in summaries:
        for ph, d in s.by_phase.items():
            t = by_phase.setdefault(ph, {"runtime_s": 0.0, "energy_kwh": 0.0,
                                         "co2_kg": 0.0, "units": 0.0})
            for k in t:
                t[k] += d[k]
    return RunSummary(
        name=name,
        units=sum(s.units for s in summaries),
        runtime_h=sum(s.runtime_h for s in summaries),
        energy_kwh=sum(s.energy_kwh for s in summaries),
        co2_kg=sum(s.co2_kg for s in summaries),
        by_phase=by_phase,
        meta={"merged_from": [s.name for s in summaries]},
    )
