"""The CARINA session API: one object that owns the whole pipeline.

    import repro.carina as carina
    report = carina.Campaign(OEM_CASE_1, PEAK_AWARE_BOOSTED).run()

A `Campaign` binds a workload to a schedule and a machine, and owns
everything the examples used to hand-wire: calibration against the
measured baseline, run tracking, carbon/price translation, dashboard
rendering, the Figure-1 frontier, vectorized sweeps, and (for training
workloads) a fully wired `CarinaController`.

Simulation campaigns (OEMWorkload):
    Campaign(workload, schedule).run()          -> CampaignReport
    Campaign(workload).frontier()               -> six-policy Figure-1 table
    Campaign(workload).sweep(schedules)         -> vectorized many-schedule pass

Training campaigns (TrainingCampaign):
    c = Campaign(training_workload, schedule)
    controller = c.controller(max_replicas=n_dev, clock=SimClock(...))
    run_training(..., controller=controller)
    c.finish()                                  -> summary + dashboard
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Sequence, Tuple

from repro.core.carbon import GridCarbonModel
from repro.core.controller import CarinaController, SimClock
from repro.core.dashboard import render_frontier_dashboard, render_run_dashboard
from repro.core.energy import ChipProfile, MachineProfile, StepCost
from repro.core.engine import SweepCase, frontier_from_sweep, sweep
from repro.core.policy import BASELINE, POLICIES, TimeBands
from repro.core.schedule import Schedule, as_schedule, dedupe_names
from repro.core.signal import (Signal, SignalSet, as_ensemble, as_trace,
                               default_signals)
from repro.core.simulator import (SimResult, calibrate_workload, fill_deltas,
                                  simulate_campaign, simulate_campaign_exact)
from repro.core.tracker import RunSummary, RunTracker
from repro.core.workload import OEMWorkload, TrainingCampaign


@dataclasses.dataclass
class CampaignReport:
    """What a finished campaign hands back."""
    result: SimResult
    summary: Optional[RunSummary] = None
    dashboard_dir: Optional[str] = None


def _zone_signals(zones, window_h: Optional[int],
                  stride_h: Optional[int]) -> List[tuple]:
    """Normalize a `zones=` argument into ordered (name, signal) pairs.

    Accepts a `CarbonArchive` (every zone, archive order) or a mapping
    of zone name -> `ZoneSeries` / Signal / hourly sequence.  Without
    `window_h` each zone lowers to its hourly trace; with it, to a
    sliding-window ensemble (the (S, E, zone) sweep shape).  Shared by
    `Campaign.sweep` and `Fleet.sweep`.
    """
    from repro.core.data import CarbonArchive, ZoneSeries
    from repro.core.signal import trace_windows
    if isinstance(zones, CarbonArchive):
        items = [(s.zone, s) for s in zones]
    elif isinstance(zones, dict):
        items = list(zones.items())
    else:
        raise TypeError(
            f"zones= takes a CarbonArchive or a {{zone: series}} "
            f"mapping, got {type(zones).__name__}")
    if not items:
        raise ValueError("zones= needs at least one zone")
    out = []
    for zname, v in items:
        if isinstance(v, ZoneSeries):
            sig = (v.to_ensemble(window_h, stride_h) if window_h
                   else v.to_trace())
        elif window_h:
            sig = trace_windows(v, window_h, stride_h,
                                name=f"carbon:{zname}")
        else:
            sig = as_trace(v, name=f"carbon:{zname}")
        out.append((str(zname), sig))
    return out


class Campaign:
    """A workload bound to a schedule, a machine, and its input signals."""

    def __init__(self, workload, schedule=BASELINE,
                 machine: Optional[MachineProfile] = None, *,
                 bands: TimeBands = TimeBands(),
                 carbon: Optional[GridCarbonModel] = None,
                 price: Optional[Signal] = None,
                 start_hour: float = 9.0,
                 calibrate: bool = True,
                 name: Optional[str] = None,
                 out_dir: Optional[str] = None,
                 cache_dir: Optional[str] = None):
        self.workload = workload
        self.schedule: Schedule = as_schedule(schedule)
        self.machine = machine or MachineProfile()
        self.bands = bands
        self.carbon = carbon or GridCarbonModel()
        self.price = price
        self.cache_dir = cache_dir
        self.start_hour = start_hour
        # the ctor flag keeps its public name; the attribute moved to
        # auto_calibrate so the measured-run `calibrate()` *method* can
        # exist (the bool gates the measured-baseline solve below, the
        # method fits the full rate/power model from tracker logs)
        self.auto_calibrate = calibrate
        self.name = name or f"{getattr(workload, 'name', 'campaign')}" \
                            f"-{self.schedule.name}"
        self.out_dir = out_dir
        self.tracker: Optional[RunTracker] = None
        self._calibrated: Optional[Tuple[OEMWorkload, MachineProfile]] = None
        self._baselines: dict = {}

    # ------------------------------------------------------------------
    @property
    def signals(self) -> SignalSet:
        return default_signals(self.bands, self.carbon, self.price)

    def calibrated(self) -> Tuple[OEMWorkload, MachineProfile]:
        """(workload, machine) with the measured baseline solved in; cached."""
        if self._calibrated is None:
            wl, m = self.workload, self.machine
            if (self.auto_calibrate and isinstance(wl, OEMWorkload)
                    and wl.measured_hours and wl.measured_kwh):
                wl, m = calibrate_workload(wl, m, self.bands)
            self._calibrated = (wl, m)
        return self._calibrated

    def baseline(self, exact: bool = False) -> SimResult:
        """The calibrated BASELINE run (reference for delta columns).
        `exact=True` gives the per-batch-oracle baseline so exact-mode
        deltas compare like against like."""
        key = "exact" if exact else "coarse"
        if key not in self._baselines:
            wl, m = self.calibrated()
            simulate = simulate_campaign_exact if exact else simulate_campaign
            self._baselines[key] = simulate(
                wl, BASELINE, m, self.bands, self.carbon, self.start_hour,
                price=self.price)
        return self._baselines[key]

    def calibrate(self, log_path: Optional[str] = None, *, units=None,
                  fit=None, steps: int = 500, lr: float = 0.1,
                  bootstrap: int = 0, seed: int = 0,
                  backend: Optional[str] = None, apply: bool = False):
        """Fit the rate/power model to a measured run (RunTracker log).

        Reads `log_path` (default: this campaign's `out_dir/units.jsonl`,
        the log `run(track=True)` writes), lifts the units into observed
        (throughput, power) targets, and fits `core/model.py`'s
        parameters starting from this campaign's configured values —
        Adam through the differentiable model (`core/calibrate.py`;
        `backend="numpy"` forces the finite-difference fallback).
        `bootstrap` > 0 adds seeded unit-resampling confidence
        intervals.  Returns a `CalibratedModel`; with `apply=True` the
        fitted (workload, machine) replace this campaign's calibrated
        pair, so subsequent sweep/optimize/run calls use the measured
        physics.  Pass `units=` (a `UnitRecord` sequence, e.g. a live
        tracker's `.records`) to skip the disk round-trip.
        """
        from repro.core.calibrate import (FIT_PARAMS, fit_calibration,
                                          observations_from_units)
        from repro.core.tracker import load_units
        source = log_path
        if units is None:
            source = log_path or (os.path.join(self.out_dir, "units.jsonl")
                                  if self.out_dir else None)
            if source is None or not os.path.exists(source):
                raise ValueError(
                    "Campaign.calibrate needs a measured run: pass "
                    "log_path= (a RunTracker JSONL), or run(track=True) "
                    "with out_dir set first, or pass units= directly")
            units = load_units(source)
        obs = observations_from_units(units, self.bands)
        cm = fit_calibration(
            obs, self.workload, self.machine,
            fit=tuple(fit) if fit is not None else FIT_PARAMS,
            steps=steps, lr=lr, bootstrap=bootstrap, seed=seed,
            backend=backend, source=source,
            zone=getattr(self.carbon, "zone", None))
        if apply:
            self._calibrated = cm.apply(self.workload, self.machine)
            self._baselines = {}       # stale vs the fitted physics
        return cm

    # ------------------------------------------------------------------
    # Simulation campaigns
    # ------------------------------------------------------------------
    def run(self, *, track: bool = False, exact: bool = False,
            render: Optional[bool] = None) -> CampaignReport:
        """Execute the campaign under this schedule.

        Fills the delta-vs-baseline columns, records per-segment units when
        `track` (or an `out_dir` JSONL log) is requested, and renders the
        run dashboard into `out_dir` when one is set.  `exact=True` runs
        the per-batch oracle instead of the segment simulator; the oracle
        does not record units, so it cannot be combined with tracking.
        """
        if not isinstance(self.workload, OEMWorkload):
            raise TypeError(
                "Campaign.run() simulates OEMWorkload campaigns; for a "
                "TrainingCampaign use Campaign.controller() with "
                "repro.training.loop.run_training")
        if exact and track:
            raise ValueError("track=True needs the segment simulator; the "
                             "per-batch oracle (exact=True) does not record "
                             "units")
        wl, m = self.calibrated()
        tracker = None
        if not exact and (track or self.out_dir):
            log = (os.path.join(self.out_dir, "units.jsonl")
                   if self.out_dir else None)
            tracker = RunTracker(self.name, carbon=self.carbon, log_path=log)
            self.tracker = tracker
        if exact:
            res = simulate_campaign_exact(wl, self.schedule, m, self.bands,
                                          self.carbon, self.start_hour,
                                          price=self.price)
        else:
            res = simulate_campaign(wl, self.schedule, m, self.bands,
                                    self.carbon, self.start_hour,
                                    tracker=tracker, price=self.price)
        fill_deltas([res], self.baseline(exact=exact))
        summary = tracker.close() if tracker else None
        dash = None
        if render if render is not None else bool(self.out_dir):
            dash = self.out_dir or os.path.join("experiments", self.name)
            if summary is not None:
                render_run_dashboard(summary, dash)
            render_frontier_dashboard([res], dash, title=self.name)
        return CampaignReport(result=res, summary=summary, dashboard_dir=dash)

    def frontier(self, schedules: Optional[Sequence] = None,
                 render: bool = False) -> List[SimResult]:
        """The Figure-1 table: each schedule vs the calibrated baseline.

        With the default schedule set this reproduces `policy_frontier`
        float-for-float (same sequential code path, same calibration).
        """
        schedules = (list(schedules) if schedules is not None
                     else list(POLICIES.values()))
        if not schedules:
            raise ValueError("Campaign.frontier needs at least one schedule "
                             "(got an empty sequence); omit the argument "
                             "for the bundled policy set")
        wl, m = self.calibrated()
        base = self.baseline()
        out = []
        for s in schedules:
            s = as_schedule(s)
            # reuse the cached baseline only for the bundled BASELINE object;
            # a user schedule merely *named* "baseline" is still simulated
            out.append(base if s is BASELINE
                       else simulate_campaign(wl, s, m, self.bands,
                                              self.carbon, self.start_hour,
                                              price=self.price))
        fill_deltas(out, base)
        # duplicate schedule names would collide in dashboards and any
        # name-keyed view of the table; renamed rows are copies so the
        # cached baseline object keeps its canonical name
        names = dedupe_names([r.policy for r in out])
        out = [r if r.policy == n else dataclasses.replace(r, policy=n)
               for r, n in zip(out, names)]
        if render and self.out_dir:
            render_frontier_dashboard(out, self.out_dir, title=self.name)
        return out

    def sweep(self, schedules: Sequence, *,
              carbons: Optional[Sequence] = None,
              workloads: Optional[Sequence[OEMWorkload]] = None,
              deltas: bool = False,
              carbon_trace=None,
              carbon_ensemble=None,
              zones=None,
              window_h: Optional[int] = None,
              stride_h: Optional[int] = None,
              deadline_h: float = 0.0) -> List[SimResult]:
        """Vectorized (schedule x workload x grid-curve) sweep.

        Uses the calibrated machine/rate; hundreds of candidate schedules
        evaluate in one batched pass (core/engine.py).  Order: the
        cartesian product iterates schedules fastest, then carbons, then
        workloads.  Cases representable on the periodic 24-slot grid take
        the fast NumPy path; everything else — progress/elapsed-aware
        schedules, trace signals — is routed to the trace-grid scan
        engine (core/engine_jax.py) automatically.

        `carbon_trace` accepts an hourly kg-CO2e/kWh sequence of any
        length (e.g. a week-long forecast; hour 0 = midnight of day 0) or
        a ready Signal, and replaces `carbons`.  `carbon_ensemble`
        accepts a `SignalEnsemble` (or an (E, T) array / list of traces;
        see `repro.core.signal.as_ensemble` and `trace_windows`) and
        evaluates every schedule against all E carbon scenarios in one
        scan: results carry the ensemble-mean `co2_kg` plus per-member
        `EnsembleStats` in `co2_ensemble`.  A non-zero `deadline_h`
        is surfaced to every schedule via `ctx.deadline_h`, so one
        deadline-aware schedule can be swept against many deadlines.

        `zones=` opens the grid axis: a `CarbonArchive` (or a
        {zone: series} mapping) expands the sweep to (schedule x zone)
        in ONE batched launch — each zone contributes its hourly trace
        (or, with `window_h`/`stride_h`, its sliding-window scenario
        ensemble, making the sweep (S, E, zone)).  Rows are labeled
        `"<schedule>@<zone>"`, and results are bitwise-identical to
        sweeping each zone independently (zone lanes share one plan,
        so the plan cache serves all zones from one batch entry).
        Mutually exclusive with the other carbon arguments.
        """
        exclusive = [n for n, v in (("carbons", carbons),
                                    ("carbon_trace", carbon_trace),
                                    ("carbon_ensemble", carbon_ensemble),
                                    ("zones", zones))
                     if v is not None]
        if len(exclusive) > 1:
            raise ValueError(f"pass only one of carbons=, carbon_trace=, "
                             f"carbon_ensemble=, zones=; got {exclusive}")
        zone_names = None
        if carbon_trace is not None:
            carbons = [as_trace(carbon_trace, name="carbon-trace")]
        elif carbon_ensemble is not None:
            carbons = [as_ensemble(carbon_ensemble, name="carbon-ensemble")]
        elif zones is not None:
            pairs = _zone_signals(zones, window_h, stride_h)
            zone_names = [z for z, _ in pairs]
            carbons = [sig for _, sig in pairs]
        elif window_h is not None or stride_h is not None:
            raise ValueError("window_h=/stride_h= shape the per-zone "
                             "ensembles and need zones=")
        schedules = [as_schedule(s) for s in schedules]
        if not schedules:
            raise ValueError("Campaign.sweep needs at least one schedule "
                             "(got an empty sequence)")
        # duplicate names collide in dashboards and name-keyed result
        # views; disambiguated labels keep every row addressable
        labels = dedupe_names([s.name for s in schedules])
        wl0, m = self.calibrated()
        cases = []
        for wl in (workloads if workloads is not None else [wl0]):
            if wl is not wl0 and not wl.rate_at_full:
                wl = dataclasses.replace(wl, rate_at_full=wl0.rate_at_full)
            for ci, carbon in enumerate(carbons if carbons is not None
                                        else [self.carbon]):
                for s, lbl in zip(schedules, labels):
                    cases.append(SweepCase(
                        s, wl, m, self.bands, carbon, self.start_hour,
                        label=(f"{lbl}@{zone_names[ci]}" if zone_names
                               else lbl),
                        deadline_h=deadline_h))
        results = sweep(cases, price=self.price, cache_dir=self.cache_dir)
        return (frontier_from_sweep(results, base=self.baseline())
                if deltas else results)

    def optimize(self, objective="co2", *, constraints=None,
                 deadline_h: float = 0.0, carbon_trace=None,
                 carbon_ensemble=None, robust: Optional[str] = None,
                 deltas: bool = False, **kwargs):
        """Synthesize a near-optimal schedule for this campaign.

        Searches the `ParametricSchedule` space (per-slot intensities)
        against the calibrated workload/machine on the trace-grid
        objective (core/optimize.py): gradient descent through the
        jitted scan for the smooth family, or a vmapped population/CEM
        search evaluating hundreds of candidates per jit call.

        `objective` is a metric name ("co2", "energy", "runtime",
        "cost"), a weights mapping for weighted-sum trade-offs, or an
        `Objective`; `constraints` maps metrics to caps
        (ε-constraints).  `deadline_h` is shorthand for a runtime cap —
        ``optimize("co2", deadline_h=200.0)`` reads *min CO2 subject to
        finishing in 200 h*.  `carbon_trace` swaps in a non-periodic
        hourly forecast exactly like `Campaign.sweep`; `carbon_ensemble`
        swaps in a whole scenario ensemble (`SignalEnsemble`, (E, T)
        array, or list of traces), and `robust` picks how the
        per-member CO2 collapses into the loss — ``"mean"`` (expected),
        ``"cvar"`` (tail mean at `cvar_alpha`, pass via kwargs), or
        ``"worst"`` — so ``optimize("co2", robust="cvar",
        carbon_ensemble=windows)`` synthesizes a schedule whose *bad
        carbon weeks* are cheap, not just its average one.  Remaining
        keyword arguments go to `optimize_schedule` (method, candidates,
        iterations, steps, lr, n_slots, u_min/u_max, levels, pareto,
        seed, cvar_alpha, ...).

        Returns an `OptimizeResult`: `.schedule` (a drop-in Schedule),
        `.result` (a SimResult comparable to sweep/frontier rows —
        delta columns filled vs the calibrated baseline when
        `deltas=True`), and `.frontier` (the population's Pareto set,
        when `pareto=True` with the cem method).
        """
        from repro.core.optimize import canonical_metric, optimize_schedule
        wl, m = self.calibrated()
        if carbon_trace is not None and carbon_ensemble is not None:
            raise ValueError("pass either carbon_trace= or "
                             "carbon_ensemble=, not both")
        if carbon_ensemble is not None:
            carbon = as_ensemble(carbon_ensemble, name="carbon-ensemble")
        elif carbon_trace is not None:
            carbon = as_trace(carbon_trace, name="carbon-trace")
        else:
            carbon = self.carbon
        if robust is not None:
            kwargs["robust"] = robust
        # canonicalize aliases ("runtime", "deadline") BEFORE merging the
        # deadline_h shorthand, so an explicit user cap always wins and
        # the runtime cap is found for case.deadline_h below
        constraints = {canonical_metric(k): float(v)
                       for k, v in dict(constraints or {}).items()}
        if deadline_h:
            constraints.setdefault("runtime_h", float(deadline_h))
        case = SweepCase(self.schedule, wl, m, self.bands, carbon,
                         self.start_hour,
                         deadline_h=float(constraints.get("runtime_h", 0.0)))
        if "init" not in kwargs:
            # warm-start from this campaign's own schedule when it has a
            # closed-form day profile (gradient polish converges much
            # faster near a sensible incumbent than from a flat table);
            # sampled at the case's grid resolution so sub-hour band
            # edges are not aliased away
            from repro.core.engine import (case_slots_per_hour,
                                           periodic_decision_profile)
            from repro.core.schedule import ParametricSchedule
            prof = periodic_decision_profile(self.schedule, self.bands,
                                             case_slots_per_hour(case))
            if prof is not None:
                kwargs["init"] = prof[0]
            elif isinstance(self.schedule, ParametricSchedule):
                # a previous optimization's result IS a day profile:
                # refine the incumbent instead of restarting flat
                kwargs["init"] = self.schedule.intensity_table()
        out = optimize_schedule(case, objective, constraints,
                                price=self.price, **kwargs)
        if deltas:
            fill_deltas([out.result] + out.frontier, self.baseline())
        return out

    def run_mpc(self, carbon_trace=None, objective="co2", *,
                constraints=None, deadline_h: float = 0.0,
                forecast="oracle", replan_every_h=24.0,
                backend=None, chunk_days=None, **kwargs):
        """Run this campaign closed-loop under receding-horizon MPC.

        `carbon_trace` is the *ground truth* the campaign executes
        against (an hourly trace or Signal; defaults to the campaign's
        own carbon when that is a trace).  `forecast` names what the
        optimizer *sees* — ``"oracle"`` / ``"day_ahead"`` /
        ``"persistence"``, or any `repro.core.signal.ForecastModel` —
        and every `replan_every_h` hours (None/inf = open loop) the
        remaining horizon is re-optimized from the carried executor
        state, warm-started from the incumbent schedule's intensity
        table.  A finite runtime cap is required (`deadline_h` or
        `constraints={"runtime_h": ...}`): the receding horizon is
        defined relative to it.  Remaining keyword arguments configure
        every `optimize_schedule` solve (method, candidates, iterations,
        seed, ...).

        Returns an `MPCResult` — realized vs planned CO2/energy,
        per-re-plan solve stats, and the realized forecast error (see
        docs/OPTIMIZER.md, "Receding-horizon MPC").
        """
        from repro.core.mpc import MPCSession
        from repro.core.optimize import canonical_metric
        wl, m = self.calibrated()
        truth = (as_trace(carbon_trace, name="carbon-trace")
                 if carbon_trace is not None else self.carbon)
        constraints = {canonical_metric(k): float(v)
                       for k, v in dict(constraints or {}).items()}
        if deadline_h:
            constraints.setdefault("runtime_h", float(deadline_h))
        case = SweepCase(self.schedule, wl, m, self.bands, truth,
                         self.start_hour,
                         deadline_h=float(constraints.get("runtime_h", 0.0)))
        solver = dict(kwargs)
        if "init" not in solver:
            from repro.core.engine import (case_slots_per_hour,
                                           periodic_decision_profile)
            from repro.core.schedule import ParametricSchedule
            prof = periodic_decision_profile(self.schedule, self.bands,
                                             case_slots_per_hour(case))
            if prof is not None:
                solver["init"] = prof[0]
            elif isinstance(self.schedule, ParametricSchedule):
                solver["init"] = self.schedule.intensity_table()
        return MPCSession(case, truth, objective=objective,
                          constraints=constraints, forecast=forecast,
                          replan_every_h=replan_every_h, price=self.price,
                          backend=backend, chunk_days=chunk_days,
                          cache_dir=self.cache_dir, solver=solver).run()

    # ------------------------------------------------------------------
    def as_fleet(self, site=None, **kwargs):
        """This campaign as an M=1 `Fleet` (the degenerate special case:
        `c.as_fleet().sweep(scheds)` reproduces `c.sweep(scheds)` row
        for row).  `site` is a `repro.core.fleet.Site`; by default the
        fleet inherits this campaign's bands/carbon/price with no cap."""
        from repro.core.fleet import Fleet
        return Fleet([self], site, **kwargs)

    # ------------------------------------------------------------------
    # Training campaigns
    # ------------------------------------------------------------------
    def controller(self, *, max_replicas: int = 1, min_replicas: int = 1,
                   clock: Optional[SimClock] = None,
                   chip: Optional[ChipProfile] = None,
                   step_cost: Optional[StepCost] = None,
                   granularity: str = "step",
                   log_units: bool = True) -> CarinaController:
        """A fully wired CarinaController sharing this campaign's schedule,
        bands, carbon/price signals and tracker (training/serving side)."""
        if self.tracker is not None:
            self.tracker.close()        # don't orphan a previous wiring's log
        log = (os.path.join(self.out_dir, "units.jsonl")
               if (self.out_dir and log_units) else None)
        self.tracker = RunTracker(self.name, carbon=self.carbon,
                                  granularity=granularity, log_path=log)
        if step_cost is None and isinstance(self.workload, TrainingCampaign):
            step_cost = self.workload.step_cost
        return CarinaController(
            policy=self.schedule, bands=self.bands, tracker=self.tracker,
            max_replicas=max_replicas, min_replicas=min_replicas,
            clock=clock or SimClock(start_hour=self.start_hour),
            chip=chip or ChipProfile(), step_cost=step_cost,
            carbon=self.carbon, price=self.price)

    def finish(self, render: bool = True) -> Optional[RunSummary]:
        """Close the tracker and render the run dashboard (if out_dir)."""
        if self.tracker is None:
            return None
        summary = self.tracker.close()
        if render and self.out_dir:
            render_run_dashboard(summary, self.out_dir)
        return summary
