"""Schedule synthesis: search the schedule space instead of spot-checking it.

The paper's policy analysis *evaluates* six hand-written policies
(off-hours boosting buys ~-9% energy for ~+7% runtime); the carbon-aware
workflow literature (arXiv:2503.13705, arXiv:2508.14625) shows the
interesting question is what the *optimal* schedule looks like.  This
module answers it by treating the trace-grid engine as an objective:

  * the search space is `ParametricSchedule` — one intensity logit per
    day slot, squashed into [u_min, u_max], so every parameter vector is
    a feasible schedule (`core/schedule.py`);
  * the objective is `TraceObjective` (`core/engine_jax.py`) — the
    campaign scan as a pure function of the intensity table, vmappable
    across candidates and differentiable through `jax.lax.scan`;
  * two search modes share one scalarization: **grad** (Adam through the
    scan — exact gradients of energy/CO2/runtime w.r.t. every slot) for
    the smooth family, and **cem** (a vmapped cross-entropy population
    search, hundreds of candidates per jit call, NumPy fallback when JAX
    is absent) which needs no gradients and handles quantized/discrete
    intensity levels.

Objectives are weighted sums over campaign metrics plus ε-constraints
(caps) turned into hinge penalties: `minimize co2 s.t. runtime <= D` is
`Objective(weights={"co2_kg": 1}, constraints={"runtime_h": D})`.  All
metrics are normalized by a reference evaluation so penalty weights mean
the same thing across workloads.  `pareto_front` extracts the
non-dominated set from a population's evaluations, giving the
runtime/energy (or runtime/CO2) trade curve in one search — the same
`SimResult` rows the frontier dashboards already render.

The session-level entry point is `Campaign.optimize(...)`
(`core/session.py`); this module is the engine room and is importable
without JAX (method="cem" runs on the NumPy backend).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.engine import case_slots_per_hour
from repro.core.engine_jax import EvalMetrics, TraceObjective, trace_sweep
from repro.core.schedule import ParametricSchedule
from repro.core.simulator import SimResult

#: Metrics an objective may weight or cap, with their accepted aliases.
#: `site_peak_kw` is fleet-level only (`optimize_fleet`): the peak total
#: site draw over the horizon.
METRIC_ALIASES: Dict[str, str] = {
    "energy": "energy_kwh", "energy_kwh": "energy_kwh", "kwh": "energy_kwh",
    "co2": "co2_kg", "co2_kg": "co2_kg", "carbon": "co2_kg",
    "runtime": "runtime_h", "runtime_h": "runtime_h", "deadline": "runtime_h",
    "cost": "cost_usd", "cost_usd": "cost_usd", "price": "cost_usd",
    "site_peak_kw": "site_peak_kw", "peak_kw": "site_peak_kw",
    "site_peak": "site_peak_kw",
}
METRIC_KEYS: Tuple[str, ...] = ("energy_kwh", "co2_kg", "runtime_h",
                                "cost_usd")


def canonical_metric(name: str) -> str:
    try:
        return METRIC_ALIASES[name.lower()]
    except KeyError:
        raise ValueError(f"unknown metric {name!r}; choose from "
                         f"{sorted(set(METRIC_ALIASES))}") from None


#: Robust reductions over a carbon-trace ensemble's CO2 axis.
ROBUST_MODES: Tuple[str, ...] = ("mean", "cvar", "worst")


def reduce_ensemble(values, robust: str = "mean", alpha: float = 0.9,
                    xp=np):
    """Collapse the trailing ensemble axis of a per-member metric block.

    `"mean"` is the expected value; `"worst"` the max over members;
    `"cvar"` the Conditional Value-at-Risk at level `alpha` — the mean
    of the worst `(1 - alpha)` fraction of members (`alpha=0.9` averages
    the worst 10 %), the standard coherent risk measure between the two
    extremes.  All three are differentiable on the JAX backend (sort and
    max propagate gradients), so robust objectives flow through the same
    grad/CEM machinery as deterministic ones.
    """
    if robust == "mean":
        return values.mean(axis=-1)
    if robust == "worst":
        return values.max(axis=-1)
    if robust == "cvar":
        E = values.shape[-1]
        k = max(1, int(math.ceil((1.0 - alpha) * E)))
        srt = xp.sort(values, axis=-1)
        return srt[..., E - k:].mean(axis=-1)
    raise ValueError(f"unknown robust mode {robust!r}; choose from "
                     f"{ROBUST_MODES}")


def _reduce_metrics(metrics: EvalMetrics, objective: "Objective",
                    xp=np) -> EvalMetrics:
    """Collapse the ensemble axis of `co2_kg` (when present) under the
    objective's robust mode.  The ensemble only carbonizes — the
    schedule family is carbon-blind, so energy/runtime/cost carry no
    member axis — which is why co2 is the one reduced field."""
    co2 = metrics.co2_kg
    if np.ndim(co2) > np.ndim(metrics.energy_kwh):
        co2 = reduce_ensemble(co2, objective.robust, objective.cvar_alpha,
                              xp=xp)
        metrics = metrics._replace(co2_kg=co2)
    return metrics


@dataclasses.dataclass(frozen=True)
class Objective:
    """What "best schedule" means: weighted metrics + ε-constraints.

    `weights` are summed over normalized metrics (lower is better);
    `constraints` are caps handled as one-sided hinge penalties of weight
    `penalty` per *relative* violation — at `penalty=200`, exceeding a
    cap by 1% costs as much as 2 units of normalized objective, so
    feasible optima sit within a fraction of a percent of active caps.
    Unfinished campaigns (workload left past the evaluation horizon) are
    penalized separately and much harder: they are not schedules at all.

    When the case's carbon is a `SignalEnsemble`, `robust` picks how the
    per-member CO2 axis collapses before weighting and constraining:
    `"mean"` (expected CO2), `"cvar"` (mean of the worst `1 - cvar_alpha`
    fraction of members), or `"worst"` (max over members).  A CO2 cap
    under `robust="cvar"` therefore reads "the CVaR of CO2 must stay
    under the cap".
    """
    weights: Mapping[str, float]
    constraints: Mapping[str, float] = dataclasses.field(default_factory=dict)
    penalty: float = 200.0
    unfinished_penalty: float = 1e4
    robust: str = "mean"
    cvar_alpha: float = 0.9

    def __post_init__(self):
        object.__setattr__(self, "weights", {
            canonical_metric(k): float(v) for k, v in self.weights.items()})
        object.__setattr__(self, "constraints", {
            canonical_metric(k): float(v)
            for k, v in self.constraints.items()})
        if not self.weights:
            raise ValueError("objective needs at least one weighted metric")
        for k, cap in self.constraints.items():
            if cap <= 0.0:
                raise ValueError(f"constraint cap for {k} must be positive, "
                                 f"got {cap}")
        if self.robust not in ROBUST_MODES:
            raise ValueError(f"unknown robust mode {self.robust!r}; choose "
                             f"from {ROBUST_MODES}")
        if not (0.0 < self.cvar_alpha < 1.0):
            raise ValueError(f"cvar_alpha must be in (0, 1), got "
                             f"{self.cvar_alpha}")

    @classmethod
    def coerce(cls, objective, constraints=None) -> "Objective":
        """Accept an Objective, a metric name, or a weights mapping."""
        if isinstance(objective, Objective):
            if constraints:
                merged = dict(objective.constraints)
                merged.update({canonical_metric(k): float(v)
                               for k, v in constraints.items()})
                return dataclasses.replace(objective, constraints=merged)
            return objective
        if isinstance(objective, str):
            weights = {canonical_metric(objective): 1.0}
        else:
            weights = dict(objective)
        return cls(weights=weights, constraints=dict(constraints or {}))

    def label(self) -> str:
        """Short provenance tag for schedule/result names."""
        parts = [k.split("_")[0] for k, w in self.weights.items() if w]
        for k in self.constraints:
            parts.append(f"{k.split('_')[0]}<={self.constraints[k]:g}")
        if self.robust != "mean":
            tag = (f"cvar{self.cvar_alpha:g}" if self.robust == "cvar"
                   else self.robust)
            parts.append(tag)
        return ",".join(parts)


def scalarize(metrics: EvalMetrics, objective: Objective,
              scales: Mapping[str, float], xp=np):
    """The scalar loss both search modes minimize (float or array in,
    same shape out; polymorphic over NumPy/jnp like the rate model).

    An ensemble CO2 axis (co2_kg one dim wider than the other metrics)
    is collapsed first under the objective's robust mode, so weights and
    caps always act on one scalar CO2 per candidate.
    """
    metrics = _reduce_metrics(metrics, objective, xp=xp)
    val = 0.0
    for k, w in objective.weights.items():
        val = val + w * getattr(metrics, k) / scales[k]
    for k, cap in objective.constraints.items():
        val = val + objective.penalty * xp.maximum(
            getattr(metrics, k) / cap - 1.0, 0.0)
    # deadband on the unfinished penalty: a linear term would leak the
    # (analytically zero, numerically fp-noise) gradient of the finished
    # state's residual into every step
    return val + objective.unfinished_penalty * xp.maximum(
        metrics.unfinished - 1e-9, 0.0)


@dataclasses.dataclass
class OptimizeResult:
    """What a schedule search hands back.

    `schedule` is the optimized `ParametricSchedule` (drop it into
    `Campaign.run/sweep`, simulators, or controllers like any other
    schedule); `result` is its `SimResult` as evaluated by the real sweep
    engine, directly comparable to any sweep/frontier row; `frontier` is
    the non-dominated set of the final population (population methods
    only) for the frontier dashboards.
    """
    schedule: ParametricSchedule
    result: SimResult
    value: float                      # scalarized objective at the optimum
    metrics: EvalMetrics              # raw metrics at the optimum (floats)
    objective: Objective
    method: str
    history: List[float]              # best objective value per iteration
    evaluations: int                  # total candidate evaluations
    frontier: List[SimResult] = dataclasses.field(default_factory=list)
    co2_ensemble: Optional[np.ndarray] = None   # per-member CO2 at optimum


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Boolean mask of the non-dominated rows of `points` (N, K), all
    objectives minimized.  K=2 runs the sort-and-scan algorithm (fine for
    whole-population inputs); K>2 falls back to pairwise checks."""
    pts = np.asarray(points, dtype=float)
    n, k = pts.shape
    mask = np.zeros(n, dtype=bool)
    if k == 2:
        order = np.lexsort((pts[:, 1], pts[:, 0]))
        best_y = math.inf
        for i in order:
            if pts[i, 1] < best_y - 1e-12:
                mask[i] = True
                best_y = pts[i, 1]
        return mask
    for i in range(n):
        d = ((pts <= pts[i]).all(axis=1) & (pts < pts[i]).any(axis=1))
        mask[i] = not d.any()
    return mask


def _metrics_at(metrics: EvalMetrics, i) -> EvalMetrics:
    return EvalMetrics(*(float(np.asarray(f)[i]) for f in metrics))


def _result_from_metrics(name: str, m: EvalMetrics,
                         has_price: bool) -> SimResult:
    return SimResult(policy=name, runtime_h=m.runtime_h,
                     energy_kwh=m.energy_kwh, co2_kg=m.co2_kg,
                     cost_usd=m.cost_usd if has_price else None)


# ---------------------------------------------------------------------------
# Search modes
# ---------------------------------------------------------------------------
def _grad_search(loss, p0, steps: int, lr: float
                 ) -> Tuple[np.ndarray, List[float], int]:
    """Adam on the logits, gradients through the scan.  `loss` maps a
    (traced jnp) parameter vector to the scalar objective — the single-
    campaign and joint-fleet searches differ only in that closure.
    Returns the best parameters seen (not the last iterate — the loss
    is nonconvex)."""
    import jax
    import jax.numpy as jnp

    from repro.compat import enable_x64

    value_and_grad = jax.jit(jax.value_and_grad(loss))
    b1, b2, eps = 0.9, 0.999, 1e-8
    history: List[float] = []
    with enable_x64():
        p = jnp.asarray(np.asarray(p0, dtype=float))
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        best_val, best_p = math.inf, p
        for t in range(1, steps + 1):
            val, g = value_and_grad(p)
            val = float(val)
            if val < best_val:
                best_val, best_p = val, p
            history.append(min(val, history[-1]) if history else val)
            # clip the global norm: one pathological step (a constraint
            # kink, a slot-boundary tie) must not poison Adam's moments
            gnorm = jnp.linalg.norm(g)
            g = jnp.where(gnorm > 10.0, g * (10.0 / gnorm), g)
            m = b1 * m + (1.0 - b1) * g
            v = b2 * v + (1.0 - b2) * g * g
            mhat = m / (1.0 - b1 ** t)
            vhat = v / (1.0 - b2 ** t)
            # hold, then cosine-decay over the last 40%: the constraint
            # hinges make the endgame landscape stiff and a fixed step
            # oscillates across them, but decaying from the start freezes
            # the slot structure before it has moved
            frac = max(t / steps - 0.6, 0.0) / 0.4
            lr_t = lr * (0.05 + 0.475 * (1.0 + math.cos(math.pi * frac)))
            p = p - lr_t * mhat / (jnp.sqrt(vhat) + eps)
        return np.asarray(best_p), history, steps


def _cem_search(evaluate, p0, candidates: int, iterations: int,
                elite_frac: float, init_std: float, smoothing: float,
                seed: int) -> Tuple[np.ndarray, List[float], int]:
    """Cross-entropy method over the logits: sample a Gaussian population,
    evaluate all candidates in one call, refit mean/std on the elites.
    `evaluate` maps an (N, D) logit population to (N,) objective values
    (one vmapped/jitted `evaluate_batch` underneath; the closure owns
    level snapping and Pareto collection).  Needs no gradients, so it
    runs on the NumPy backend too and survives quantized intensity
    levels: candidates are snapped *before* evaluation, so the search
    optimizes the same quantized objective the result reports —
    snapping only the final answer could silently break the constraints
    the smooth search satisfied."""
    rng = np.random.RandomState(seed)
    n = len(p0)
    mean = np.asarray(p0, dtype=float).copy()
    std = np.full(n, float(init_std))
    n_elite = max(2, int(round(candidates * elite_frac)))
    best_val, best_p = math.inf, mean.copy()
    history: List[float] = []
    for _ in range(iterations):
        pop = mean[None, :] + std[None, :] * rng.randn(candidates, n)
        pop[0] = mean                     # incumbent mean
        pop[1] = best_p                   # elitism: best-so-far survives
        vals = np.asarray(evaluate(pop))
        order = np.argsort(vals)
        if vals[order[0]] < best_val:
            best_val = float(vals[order[0]])
            best_p = pop[order[0]].copy()
        history.append(best_val)
        elite = pop[order[:n_elite]]
        mean = smoothing * elite.mean(axis=0) + (1.0 - smoothing) * mean
        std = smoothing * elite.std(axis=0) + (1.0 - smoothing) * std
        std = np.maximum(std, 0.02)       # keep exploring
    return best_p, history, candidates * iterations


# ---------------------------------------------------------------------------
# The entry point
# ---------------------------------------------------------------------------
def optimize_schedule(case, objective: Union[str, Mapping, Objective] = "co2",
                      constraints: Optional[Mapping] = None, *,
                      method: str = "auto",
                      n_slots: Optional[int] = None,
                      u_min: float = 0.05, u_max: float = 1.0,
                      batch_size: int = 50,
                      price=None,
                      horizon_h: Optional[float] = None,
                      candidates: int = 256, iterations: int = 40,
                      elite_frac: float = 0.125, init_std: float = 1.5,
                      smoothing: float = 0.7,
                      steps: int = 800, lr: float = 0.1,
                      init: Union[float, Sequence[float]] = 0.6,
                      levels: Optional[Sequence[float]] = None,
                      seed: int = 0, backend: Optional[str] = None,
                      pareto: bool = False,
                      robust: Optional[str] = None,
                      cvar_alpha: Optional[float] = None,
                      precision: str = "fp64") -> OptimizeResult:
    """Search the `ParametricSchedule` space for the case's best schedule.

    `objective` is a metric name, a weights mapping, or an `Objective`;
    `constraints` maps metrics to caps (ε-constraints), e.g.
    ``optimize_schedule(case, "co2", {"runtime_h": 200.0})`` for
    *min CO2 s.t. the 200 h deadline*.  `method`: ``"grad"`` (Adam
    through the scan; JAX only — excellent from a warm start, can stall
    from a cold one), ``"cem"`` (vmapped population search; robust, runs
    on the NumPy backend too), ``"cem+grad"`` (population search, then
    gradient polish from its best candidate), or ``"auto"``
    (cem+grad when JAX is importable, else cem).  `init` seeds the
    search — a flat intensity or
    a per-slot table (e.g. an existing policy's, via
    `ParametricSchedule.from_intensities`).  `levels`, if given,
    restricts intensities to a discrete level set: population candidates
    are snapped *before* evaluation (the search optimizes the quantized
    objective, so constraints hold for the quantized schedule) and the
    returned schedule's table is exactly level-valued.  `pareto=True`
    (cem only) attaches the non-dominated runtime-vs-primary-metric set
    of every candidate evaluated.

    `robust` / `cvar_alpha` override the objective's ensemble reduction
    when the case's carbon is a `SignalEnsemble` — "mean" optimizes
    expected CO2 across the members, "cvar" the mean of the worst
    `1 - cvar_alpha` tail, "worst" the maximum (see `reduce_ensemble`);
    all three run under both the jitted and the NumPy backends.

    `precision="mixed"` evaluates search candidates with fp32 scan
    dynamics (fp64 accumulators — see `TraceObjective`); the final
    reported row always re-runs through the engine at exact fp64, so
    only the search trajectory is approximate.

    See docs/OPTIMIZER.md for objective/constraint semantics and for
    when grad beats population search.
    """
    obj = Objective.coerce(objective, constraints)
    if robust is not None or cvar_alpha is not None:
        obj = dataclasses.replace(
            obj, robust=robust if robust is not None else obj.robust,
            cvar_alpha=(cvar_alpha if cvar_alpha is not None
                        else obj.cvar_alpha))
    if candidates < 2:
        raise ValueError(f"candidates must be >= 2, got {candidates} "
                         "(the population keeps the incumbent mean and "
                         "the best-so-far candidate)")
    sph = case_slots_per_hour(case)
    if n_slots is not None:
        if n_slots % 24:
            raise ValueError(f"n_slots must be a multiple of 24, "
                             f"got {n_slots}")
        sph = math.lcm(sph, n_slots // 24)
    n = 24 * sph

    needs_price = any(k == "cost_usd" for k in
                      list(obj.weights) + list(obj.constraints))
    if needs_price and price is None:
        raise ValueError("objective involves cost_usd but no price signal "
                         "was given")

    if horizon_h is None and "runtime_h" in obj.constraints:
        horizon_h = obj.constraints["runtime_h"] * 1.25 + 24.0
    to = TraceObjective(case, price=price, slots_per_hour=sph,
                        horizon_h=horizon_h, batch_size=float(batch_size),
                        backend=backend, precision=precision)

    if np.ndim(init) == 0:
        init_u = np.full(n, float(init))
    else:
        init_arr = np.asarray(init, dtype=float)
        if n % len(init_arr):
            raise ValueError(f"init table of {len(init_arr)} slots does not "
                             f"tile the {n}-slot grid")
        init_u = np.repeat(init_arr, n // len(init_arr))
    seed_sched = ParametricSchedule.from_intensities(
        init_u, u_min=u_min, u_max=u_max, batch_size=batch_size)
    p0 = np.asarray(seed_sched.logits, dtype=float)

    # normalization: one reference evaluation makes weights/penalties
    # workload-independent ("1 unit" = the seed schedule's metric);
    # ensemble CO2 is reduced first so the scale matches the reduced
    # quantity the loss actually weights
    ref = _reduce_metrics(to.evaluate_batch(init_u[None, :]), obj, xp=np)
    scales = {k: max(abs(float(np.asarray(getattr(ref, k))[0])), 1e-9)
              for k in METRIC_KEYS}

    if method == "auto":
        method = ("cem+grad" if (to.use_jax and levels is None) else "cem")
    if method in ("grad", "cem+grad") and not to.use_jax:
        raise RuntimeError(f"method={method!r} needs the JAX backend "
                           "(jax is not importable or backend='numpy')")
    if method not in ("grad", "cem", "cem+grad"):
        raise ValueError(f"unknown method {method!r}; use 'grad', 'cem', "
                         "'cem+grad' or 'auto'")
    if levels is not None and "grad" in method:
        raise ValueError(
            "levels= needs a population method (use method='cem' or "
            "'auto'): a gradient search optimizes the smooth objective, "
            "and snapping its result afterwards could silently violate "
            "the constraints the search satisfied")

    lv = (np.sort(np.asarray(levels, dtype=float))
          if levels is not None else None)
    collect: Optional[list] = [] if (pareto and "cem" in method) else None
    n_evals = 0
    history: List[float] = []
    if "cem" in method:
        def eval_pop(pop):
            u = ParametricSchedule.u_from_logits(pop, u_min, u_max, xp=np)
            if lv is not None:            # same snap as the final schedule
                u = lv[np.argmin(np.abs(u[..., None]
                                        - lv[None, None, :]), axis=-1)]
            mets = to.evaluate_batch(u)
            vals = np.asarray(scalarize(mets, obj, scales, xp=np))
            if collect is not None:
                collect.append((pop.copy(), mets))
            return vals

        best_p, history, n_evals = _cem_search(
            eval_pop, p0, candidates, iterations, elite_frac, init_std,
            smoothing, seed)
        p0 = best_p                       # grad polish starts from the
    if "grad" in method:                  # population's best candidate
        import jax.numpy as jnp

        def grad_loss(p):
            u = ParametricSchedule.u_from_logits(p, u_min, u_max, xp=jnp)
            return scalarize(to.evaluate(u), obj, scales, xp=jnp)

        best_p, ghist, gevals = _grad_search(grad_loss, p0, steps, lr)
        start = history[-1] if history else math.inf
        history += [min(v, start) for v in ghist]
        n_evals += gevals

    name = f"optimized[{obj.label()}]"
    sched = seed_sched.with_logits(best_p, name=name)
    if lv is not None:
        # snap at table materialization (ParametricSchedule.levels) — the
        # identical argmin the search applied per candidate; a
        # from_intensities round trip could not reproduce the level
        # values bit-exactly
        sched = dataclasses.replace(sched, name=name + "#q",
                                    levels=tuple(float(v) for v in lv))

    # report through the real engine so the row is directly comparable to
    # any sweep/frontier output (same physics; fp-level agreement)
    final_case = dataclasses.replace(case, schedule=sched, label=sched.name)
    result = trace_sweep([final_case], price=price, slots_per_hour=sph,
                         backend=backend)[0]
    raw_best = to.evaluate_batch(sched.intensity_table()[None, :])
    co2_members = (np.asarray(raw_best.co2_kg)[0].copy()
                   if to.ensemble_size else None)
    best_metrics = _metrics_at(_reduce_metrics(raw_best, obj, xp=np), 0)
    value = float(scalarize(best_metrics, obj, scales, xp=np))

    frontier: List[SimResult] = []
    if collect:
        all_mets = EvalMetrics(*(np.concatenate(
            [np.asarray(getattr(m, k)) for _, m in collect])
            for k in EvalMetrics._fields))
        all_mets = _reduce_metrics(all_mets, obj, xp=np)
        # frontier axes: runtime vs the heaviest non-runtime weighted
        # metric (runtime is always the frontier's x-axis)
        others = [k for k in obj.weights
                  if k != "runtime_h" and obj.weights[k]]
        primary = (max(others, key=lambda k: abs(obj.weights[k]))
                   if others else "energy_kwh")
        feasible = all_mets.unfinished <= 1e-6
        for k, cap in obj.constraints.items():
            if k != "runtime_h":
                feasible &= getattr(all_mets, k) <= cap * (1.0 + 1e-6)
        idx = np.flatnonzero(feasible)
        if idx.size:
            pts = np.stack([all_mets.runtime_h[idx],
                            getattr(all_mets, primary)[idx]], axis=1)
            front = idx[pareto_front(pts)]
            front = front[np.argsort(all_mets.runtime_h[front])]
            frontier = [
                _result_from_metrics(f"{name}/pareto{j}",
                                     _metrics_at(all_mets, i), to.has_price)
                for j, i in enumerate(front)]

    return OptimizeResult(schedule=sched, result=result, value=value,
                          metrics=best_metrics, objective=obj, method=method,
                          history=history, evaluations=n_evals,
                          frontier=frontier, co2_ensemble=co2_members)


# ---------------------------------------------------------------------------
# Joint fleet optimization (the M-campaigns axis)
# ---------------------------------------------------------------------------
def scalarize_fleet(fm, objective: Objective, scales: Mapping[str, float],
                    deadlines=None, xp=np):
    """The scalar loss of a joint fleet schedule (FleetEvalMetrics in,
    float or (...,) array out; polymorphic over NumPy/jnp).

    Weighted metrics act on *site totals* (summed over campaigns);
    `site_peak_kw` weights/caps act on the site-level peak draw; a
    `runtime_h` cap and the per-campaign `deadlines` act per campaign
    (campaigns run concurrently — a sum of runtimes means nothing).
    Unfinished campaigns are penalized per member, like the single-
    campaign `scalarize`.
    """
    site = {k: getattr(fm, k).sum(axis=-1)
            for k in ("energy_kwh", "co2_kg", "cost_usd")}
    val = 0.0
    for k, w in objective.weights.items():
        if k == "site_peak_kw":
            val = val + w * fm.site_peak_kw / scales[k]
        elif k == "runtime_h":
            # makespan: the fleet is done when its last campaign is
            val = val + w * fm.runtime_h.max(axis=-1) / scales[k]
        else:
            val = val + w * site[k] / scales[k]
    for k, cap in objective.constraints.items():
        if k == "site_peak_kw":
            val = val + objective.penalty * xp.maximum(
                fm.site_peak_kw / cap - 1.0, 0.0)
        elif k == "runtime_h":
            val = val + objective.penalty * xp.maximum(
                fm.runtime_h / cap - 1.0, 0.0).sum(axis=-1)
        else:
            val = val + objective.penalty * xp.maximum(
                site[k] / cap - 1.0, 0.0)
    if deadlines is not None:
        dl = np.asarray(deadlines, dtype=float)
        dl = np.where(dl > 0.0, dl, np.inf)
        val = val + objective.penalty * xp.maximum(
            fm.runtime_h / dl - 1.0, 0.0).sum(axis=-1)
    return val + objective.unfinished_penalty * xp.maximum(
        fm.unfinished - 1e-9, 0.0).sum(axis=-1)


@dataclasses.dataclass
class FleetOptimizeResult:
    """What a joint fleet-schedule search hands back.

    `schedules[m]` is campaign m's optimized `ParametricSchedule` (a
    drop-in Schedule); `results`/`site` are the per-campaign
    `SimResult`s and site rollup as evaluated by the real grouped-lane
    engine under the site cap; `independent` (when the search
    warm-started from per-campaign optima) holds those standalone
    `OptimizeResult`s for comparison.
    """
    schedules: List[ParametricSchedule]
    results: List[SimResult]
    site: object                          # fleet.SiteRollup
    value: float
    metrics: object                       # FleetEvalMetrics at the optimum
    objective: Objective
    method: str
    history: List[float]
    evaluations: int
    independent: List[OptimizeResult] = dataclasses.field(
        default_factory=list)


def optimize_fleet(cases: Sequence, site=None, *,
                   objective: Union[str, Mapping, Objective] = "co2",
                   constraints: Optional[Mapping] = None,
                   method: str = "auto",
                   n_slots: Optional[int] = None,
                   u_min: float = 0.05, u_max: float = 1.0,
                   batch_size: int = 50,
                   price=None,
                   horizon_h: Optional[float] = None,
                   candidates: int = 192, iterations: int = 30,
                   elite_frac: float = 0.125, init_std: float = 1.0,
                   smoothing: float = 0.7,
                   steps: int = 500, lr: float = 0.1,
                   init: Union[str, float, Sequence] = "independent",
                   seed: int = 0,
                   backend: Optional[str] = None) -> FleetOptimizeResult:
    """Search the joint `ParametricSchedule` space for a whole fleet.

    `cases` are the M member `SweepCase`s (shared start_hour/bands, one
    carbon signal; per-campaign `deadline_h` become runtime caps) and
    `site` a `repro.core.fleet.Site` whose cap/office draw couple them
    (None = uncoupled).  The parameter vector is M x n_slots logits —
    campaign m's day schedule in row m — optimized through
    `FleetTraceObjective` with the same Adam-through-the-scan and
    vmapped-CEM machinery as `optimize_schedule` (the searches share
    one generic loss interface).

    A *physical* site cap is enforced by the curtailment inside the
    objective (no separate constraint needed — idle and office draw are
    not sheddable, so a soft `site_peak_kw` cap below the physical one
    would only distort the objective).  To instead *plan* under a peak
    budget — schedule around the peak rather than rely on reactive
    throttling — pass an uncapped site and an explicit
    `constraints={"site_peak_kw": budget}`.

    `init="independent"` (default) warm-starts from each campaign's own
    `optimize_schedule` optimum (same budgets, no coupling): since both
    searches keep the best candidate seen — including the start — the
    joint result is never worse than the independent optima evaluated
    under the shared cap.  `init` also accepts a flat intensity or an
    (M, n_slots) intensity table.
    """
    if not len(cases):
        raise ValueError("optimize_fleet needs at least one case")
    M = len(cases)
    obj = Objective.coerce(objective, constraints)
    site_cap = getattr(site, "power_cap_kw", None)
    office_kw = float(getattr(site, "office_kw", 0.0) or 0.0)
    deadlines = np.array([float(getattr(c, "deadline_h", 0.0) or 0.0)
                          for c in cases])

    sph = 1
    for c in cases:
        sph = math.lcm(sph, case_slots_per_hour(c))
    if n_slots is not None:
        if n_slots % 24:
            raise ValueError(f"n_slots must be a multiple of 24, "
                             f"got {n_slots}")
        sph = math.lcm(sph, n_slots // 24)
    n = 24 * sph

    needs_price = any(k == "cost_usd" for k in
                      list(obj.weights) + list(obj.constraints))
    if needs_price and price is None:
        raise ValueError("objective involves cost_usd but no price signal "
                         "was given")

    if horizon_h is None and deadlines.max(initial=0.0) > 0.0:
        horizon_h = float(deadlines.max()) * 1.25 + 24.0
    from repro.core.engine_jax import FleetTraceObjective
    fo = FleetTraceObjective(cases, site_cap_kw=site_cap,
                             office_kw=office_kw, price=price,
                             slots_per_hour=sph, horizon_h=horizon_h,
                             batch_size=float(batch_size), backend=backend)

    # ---- seed the joint search -------------------------------------------
    independent: List[OptimizeResult] = []
    if isinstance(init, str):
        if init != "independent":
            raise ValueError(f"unknown init {init!r}; use 'independent', a "
                             "flat intensity, or an (M, n_slots) table")
        # the single-campaign objective knows no site_peak_kw: strip it
        # from constraints AND weights (a peak-only objective falls back
        # to CO2 for the warm start — the joint search still optimizes
        # the real objective afterwards)
        sub_weights = {k: v for k, v in obj.weights.items()
                       if k != "site_peak_kw"}
        sub_obj = dataclasses.replace(
            obj, weights=sub_weights or {"co2_kg": 1.0},
            constraints={k: v for k, v in obj.constraints.items()
                         if k != "site_peak_kw"})
        for m, c in enumerate(cases):
            independent.append(optimize_schedule(
                c, sub_obj,
                {"runtime_h": deadlines[m]} if deadlines[m] else None,
                method=method, n_slots=n, u_min=u_min, u_max=u_max,
                batch_size=batch_size, price=price,
                candidates=candidates, iterations=iterations,
                elite_frac=elite_frac, init_std=init_std,
                smoothing=smoothing, steps=steps, lr=lr, seed=seed + m,
                backend=backend))
        init_u = np.stack([r.schedule.intensity_table()
                           for r in independent])
    elif np.ndim(init) == 0:
        init_u = np.full((M, n), float(init))
    else:
        init_u = np.asarray(init, dtype=float)
        if init_u.shape[0] != M or n % init_u.shape[1]:
            raise ValueError(f"init table of shape {init_u.shape} does not "
                             f"tile the ({M}, {n}) joint grid")
        init_u = np.repeat(init_u, n // init_u.shape[1], axis=1)

    seed_scheds = [ParametricSchedule.from_intensities(
        init_u[m], u_min=u_min, u_max=u_max, batch_size=batch_size)
        for m in range(M)]
    p0 = np.concatenate([np.asarray(s.logits, dtype=float)
                         for s in seed_scheds])

    # normalization: one reference evaluation of the seed makes weights
    # and penalties workload-independent, like the single-campaign path
    ref = fo.evaluate_batch(init_u[None])
    scales = {k: max(abs(float(np.asarray(getattr(ref, k)).sum())), 1e-9)
              for k in METRIC_KEYS}
    scales["site_peak_kw"] = max(float(np.asarray(ref.site_peak_kw)
                                       .ravel()[0]), 1e-9)

    if method == "auto":
        method = "cem+grad" if fo.use_jax else "cem"
    if method in ("grad", "cem+grad") and not fo.use_jax:
        raise RuntimeError(f"method={method!r} needs the JAX backend "
                           "(jax is not importable or backend='numpy')")
    if method not in ("grad", "cem", "cem+grad"):
        raise ValueError(f"unknown method {method!r}; use 'grad', 'cem', "
                         "'cem+grad' or 'auto'")

    n_evals = 0
    history: List[float] = []
    if "cem" in method:
        def eval_pop(pop):
            u = ParametricSchedule.u_from_logits(
                pop.reshape(-1, M, n), u_min, u_max, xp=np)
            fm = fo.evaluate_batch(u)
            return np.asarray(scalarize_fleet(fm, obj, scales, deadlines,
                                              xp=np))

        best_p, history, n_evals = _cem_search(
            eval_pop, p0, candidates, iterations, elite_frac, init_std,
            smoothing, seed)
        p0 = best_p
    if "grad" in method:
        import jax.numpy as jnp

        def grad_loss(p):
            u = ParametricSchedule.u_from_logits(p.reshape(M, n), u_min,
                                                 u_max, xp=jnp)
            return scalarize_fleet(fo.evaluate(u), obj, scales, deadlines,
                                   xp=jnp)

        best_p, ghist, gevals = _grad_search(grad_loss, p0, steps, lr)
        start = history[-1] if history else math.inf
        history += [min(v, start) for v in ghist]
        n_evals += gevals

    label = f"optimized_fleet[{obj.label()}]"
    best_logits = np.asarray(best_p, dtype=float).reshape(M, n)
    schedules = [
        seed_scheds[m].with_logits(
            best_logits[m],
            name=f"{label}/{getattr(cases[m].workload, 'name', m)}")
        for m in range(M)]

    # report through the real grouped-lane engine so the rows are
    # directly comparable to any fleet sweep
    from repro.core.fleet import Site, fleet_sweep
    eng_site = site if site is not None else Site(
        power_cap_kw=site_cap, office_kw=office_kw, bands=cases[0].bands,
        carbon=cases[0].carbon, price=price)
    final_cases = [dataclasses.replace(c, schedule=s, label=s.name)
                   for c, s in zip(cases, schedules)]
    fr = fleet_sweep([final_cases], eng_site, price=price, names=[label])[0]

    u_best = np.stack([s.intensity_table() for s in schedules])
    raw = fo.evaluate_batch(u_best[None])
    best_metrics = type(raw)(*(np.asarray(f)[0] for f in raw))
    value = float(np.asarray(scalarize_fleet(raw, obj, scales, deadlines,
                                             xp=np))[0])
    return FleetOptimizeResult(
        schedules=schedules, results=fr.campaigns, site=fr.site,
        value=value, metrics=best_metrics, objective=obj, method=method,
        history=history, evaluations=n_evals, independent=independent)


__all__ = ["METRIC_KEYS", "ROBUST_MODES", "FleetOptimizeResult", "Objective",
           "OptimizeResult", "canonical_metric", "optimize_fleet",
           "optimize_schedule", "pareto_front", "reduce_ensemble",
           "scalarize", "scalarize_fleet"]
