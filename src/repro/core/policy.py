"""Fixed clock-time execution policies (paper §2).

Time bands (local time) and the six Figure-1 policies.  A policy maps each
band to a worker intensity plus a batch size; the controller additionally
maps intensity onto TPU-native knobs (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

from repro.core.schedule import Decision, SchedulingContext

PEAK = "peak"
LOAD_SENSITIVE = "load_sensitive"
SHOULDER = "shoulder"
NIGHT = "night"

BANDS = (PEAK, LOAD_SENSITIVE, SHOULDER, NIGHT)


@dataclasses.dataclass(frozen=True)
class TimeBands:
    """Hour-of-day -> band.  Defaults: peak 14-19, load-sensitive 11-14 &
    19-21, shoulder 7-11 & 21-24, night 0-7 (paper's office-day structure)."""
    peak: Tuple[Tuple[int, int], ...] = ((14, 19),)
    load_sensitive: Tuple[Tuple[int, int], ...] = ((11, 14), (19, 21))
    shoulder: Tuple[Tuple[int, int], ...] = ((7, 11), (21, 24))

    def band_at(self, hour_of_day: float) -> str:
        h = hour_of_day % 24.0
        for lo, hi in self.peak:
            if lo <= h < hi:
                return PEAK
        for lo, hi in self.load_sensitive:
            if lo <= h < hi:
                return LOAD_SENSITIVE
        for lo, hi in self.shoulder:
            if lo <= h < hi:
                return SHOULDER
        return NIGHT

    def hours_per_day(self) -> Dict[str, float]:
        out = {b: 0.0 for b in BANDS}
        for h in range(24):
            out[self.band_at(h)] += 1.0
        return out

    def edges(self) -> Tuple[float, ...]:
        """Sorted hours in [0, 24] where the band (and hence the background
        load) can change — the segmentation grid for band-level schedules."""
        hs = {0.0, 24.0}
        for ranges in (self.peak, self.load_sensitive, self.shoulder):
            for lo, hi in ranges:
                hs.add(float(lo) % 24.0)
                hs.add(24.0 if hi == 24 else float(hi) % 24.0)
        return tuple(sorted(hs))

    # background (interactive/office) load per band — the contention model
    # (calibrated jointly with MachineProfile; EXPERIMENTS.md §Paper-validation)
    def background(self, band: str) -> float:
        return {PEAK: 0.65, LOAD_SENSITIVE: 0.50, SHOULDER: 0.15, NIGHT: 0.02}[band]


@dataclasses.dataclass(frozen=True)
class Policy:
    """Worker intensity per band + batch size (+ priority flag, which on the
    workstation meant OS niceness; here it is an extra constant throttle)."""
    name: str
    intensity: Dict[str, float]
    batch_size: int = 50
    low_priority: bool = False

    def intensity_at(self, band: str) -> float:
        u = self.intensity[band]
        return u * 0.82 if self.low_priority else u

    # ---- Schedule protocol -------------------------------------------------
    def decide(self, ctx: SchedulingContext) -> Decision:
        return Decision(self.intensity_at(ctx.band), self.batch_size)

    def change_hours(self, bands: "TimeBands") -> Tuple[float, ...]:
        return bands.edges()


def _const(u: float) -> Dict[str, float]:
    return {b: u for b in BANDS}


def constant_schedule(u: float, batch_size: int = 50,
                      name: str = "") -> Policy:
    """A constant-intensity Schedule (sweep-engine building block)."""
    return Policy(name or f"const_{u:.2f}", _const(u), batch_size=batch_size)


# The six Figure-1 policies.  Baseline runs at a constant working intensity;
# peak-aware policies throttle sensitive bands and boost off-hours to recover
# throughput; batch policies change orchestration granularity only.
BASELINE = Policy("baseline", _const(0.85), batch_size=50)

PEAK_AWARE_BOOSTED = Policy(
    "peak_aware_boosted_offhours",
    {PEAK: 0.35, LOAD_SENSITIVE: 0.55, SHOULDER: 0.90, NIGHT: 0.95},
    batch_size=50)

PEAK_AWARE_AGGRESSIVE = Policy(
    "peak_aware_aggressive",
    {PEAK: 0.10, LOAD_SENSITIVE: 0.35, SHOULDER: 0.90, NIGHT: 1.00},
    batch_size=50)

LOW_PRIORITY_ONLY = Policy("low_priority_only", _const(0.85), batch_size=50,
                           low_priority=True)

SMALL_BATCHES = Policy("small_batches_25", _const(0.85), batch_size=25)

LARGE_BATCHES = Policy("large_batches_100", _const(0.85), batch_size=100)

POLICIES = {p.name: p for p in (
    BASELINE, PEAK_AWARE_BOOSTED, PEAK_AWARE_AGGRESSIVE, LOW_PRIORITY_ONLY,
    SMALL_BATCHES, LARGE_BATCHES)}


# ---------------------------------------------------------------------------
# Beyond-paper extension: carbon-intensity-driven scheduling (the paper's
# stated future work — "continuously updated regional carbon-intensity
# feeds").  Intensity follows the *grid carbon curve* hour by hour instead
# of fixed clock bands: CO2-optimal rather than energy-optimal.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HourlyPolicy(Policy):
    hourly_intensity: Tuple[float, ...] = ()      # len 24

    def intensity_at_hour(self, hour: float) -> float:
        u = self.hourly_intensity[math.floor(hour) % 24]
        return u * 0.82 if self.low_priority else u

    # ---- Schedule protocol -------------------------------------------------
    def decide(self, ctx: SchedulingContext) -> Decision:
        if not self.hourly_intensity:        # un-filled: fall back to bands
            return Decision(self.intensity_at(ctx.band), self.batch_size)
        return Decision(self.intensity_at_hour(ctx.hour_of_day),
                        self.batch_size)

    def change_hours(self, bands: TimeBands) -> Tuple[float, ...]:
        if not self.hourly_intensity:
            return bands.edges()
        return tuple(float(h) for h in range(25))


def hourly_schedule(name: str, intensities, batch_size: int = 50) -> HourlyPolicy:
    """A 24-slot hourly Schedule (sweep-engine building block)."""
    vals = tuple(float(v) for v in intensities)
    if len(vals) != 24:
        raise ValueError(f"hourly_schedule needs 24 intensities, got {len(vals)}")
    return HourlyPolicy(name, _const(0.85), batch_size, False, vals)


def _carbon_values(carbon):
    """Hourly carbon factors from a GridCarbonModel *or* any Signal."""
    from repro.core.signal import sample_hourly
    return list(sample_hourly(carbon))


def make_carbon_aware_policy(carbon, u_low: float = 0.30, u_high: float = 1.0,
                             batch_size: int = 50) -> HourlyPolicy:
    """Map normalized grid carbon intensity -> worker intensity (inverse
    linear): full speed in the cleanest hours, u_low in the dirtiest.
    Pure-carbon following; see make_carbon_weighted_boosted for the variant
    that dominates (EXPERIMENTS.md bonus B4).  `carbon` may be a
    GridCarbonModel or any carbon Signal."""
    vals = _carbon_values(carbon)
    lo, hi = min(vals), max(vals)
    rng = (hi - lo) or 1.0
    inten = tuple(u_high - (v - lo) / rng * (u_high - u_low) for v in vals)
    return HourlyPolicy("carbon_aware_dynamic", _const(0.85), batch_size,
                        False, inten)


def make_carbon_weighted_boosted(carbon, bands: TimeBands = TimeBands(),
                                 swing: float = 0.30,
                                 batch_size: int = 50) -> HourlyPolicy:
    """Beyond-paper hybrid: the paper's boosted-off-hours band intensities,
    modulated ±swing/2 by the normalized hourly grid carbon intensity.
    Strictly dominates plain boosted on runtime, energy AND CO2e under a
    time-varying grid (tests/test_carina.py::test_carbon_weighted_dominates)."""
    vals = _carbon_values(carbon)
    lo, hi = min(vals), max(vals)
    rng = (hi - lo) or 1.0
    inten = []
    for h in range(24):
        u = PEAK_AWARE_BOOSTED.intensity[bands.band_at(h)]
        mod = (1.0 + swing / 2) - swing * (vals[h] - lo) / rng
        inten.append(min(1.0, max(0.1, u * mod)))
    return HourlyPolicy("carbon_weighted_boosted", _const(0.85), batch_size,
                        False, tuple(inten))
