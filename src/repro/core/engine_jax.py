"""Trace-grid sweep engine: a jit-compiled `jax.lax.scan` over a fine
hourly time grid, vmapped across cases as batched (S,)-vectors.

The periodic 24-slot engine (core/engine.py) collapses a campaign into
one repeated day, which is exact only when every decision and signal is
24 h-periodic and ignorant of campaign position.  This engine instead
*steps* the campaign hour by hour (or finer, for sub-hour band edges),
carrying `(remaining, elapsed)` state through the scan, so it natively
represents everything the periodic grid cannot:

  * progress/elapsed-aware schedules (deadline pace-keepers, progress
    ramps) via a precompiled per-case decision table over
    (hour-row, progress-bucket) — the scan picks the row by grid position
    and the bucket by live progress;
  * non-periodic multi-day signals (`TraceSignal` grid-carbon forecasts,
    trace prices) sampled per slot;
  * heterogeneous fleets: per-case machines, workloads, bands and
    `start_hour`s batch into the same scan.

Decision tables stay compact: schedules whose decisions are detected (by
probing) to be hour-of-day-periodic keep 24*sph rows indexed modulo the
day; progress-free schedules keep a single bucket.  Physics per slot
comes from the shared rate model (core/model.py) with `xp=jnp`.

JAX is optional: with `backend="numpy"` (or when JAX is absent, following
the repro/compat.py guard pattern) the identical scan runs as a NumPy
loop over the grid — still vectorized across cases, just not jitted.
JAX runs under `enable_x64` so both backends agree to float64 precision
with the periodic engine on periodic cases.
"""
from __future__ import annotations

import functools
import math
from typing import Any, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core import model
from repro.core.carbon import GridCarbonModel
from repro.core.schedule import SchedulingContext, as_schedule
from repro.core.signal import Signal, carbon_signal, sample_signal
from repro.core.simulator import SimResult

try:                                    # JAX is optional on the trace path
    import jax
    import jax.numpy as jnp

    from repro.compat import enable_x64
    _HAS_JAX = True
except Exception:                       # pragma: no cover - env without jax
    jax = jnp = enable_x64 = None
    _HAS_JAX = False

_PROBE_PROGRESS = (0.0, 1.0 / 3.0, 2.0 / 3.0, 0.999)
_PROBE_OFFSETS = (0.0, 3.0, 5.0, 9.0, 13.0, 17.0, 21.0)


@functools.lru_cache(maxsize=256)       # bounded, same policy as engine.py
def _bg_table(bands, sph: int) -> np.ndarray:
    """Background load per grid row over one day ((24*sph,), memoized)."""
    return np.array([bands.background(bands.band_at(r / sph))
                     for r in range(24 * sph)])


def _ctx_factory(case, carbon_sig, price_sig):
    """ctx(t_abs, progress) for probing and decision-table sampling,
    built exactly like the sequential simulators build theirs."""
    bands = case.bands
    start = case.start_hour

    def make(t_abs: float, progress: float) -> SchedulingContext:
        hod = t_abs % 24.0
        band = bands.band_at(hod)
        return SchedulingContext(
            hour_of_day=hod, band=band, background=bands.background(band),
            carbon_factor=float(carbon_sig.at(t_abs)),
            price_usd_per_kwh=(float(price_sig.at(t_abs))
                               if price_sig is not None else 0.0),
            elapsed_h=max(t_abs - start, 0.0), progress=progress,
            deadline_h=case.deadline_h)

    return make


def _probe(sched, make_ctx, g0: float, horizon_h: float):
    """(progress_dep, elapsed_dep, decision_samples) from a coarse lattice.

    `elapsed_dep` is true when the same hour-of-day decides differently on
    different days (a deadline pace, or a schedule following a non-periodic
    carbon trace through ctx.carbon_factor); `progress_dep` when decisions
    move with ctx.progress.  Exact for the bundled schedule families;
    arbitrary callables are sampled on the lattice (documented heuristic —
    a schedule varying only between lattice points can be misclassified).
    """
    days = sorted({0.0, 24.0, 48.0,
                   max(math.floor(horizon_h / 48.0) * 24.0, 0.0),
                   max((math.floor(horizon_h / 24.0) - 1) * 24.0, 0.0)})
    progress_dep = elapsed_dep = False
    samples = []
    for off in _PROBE_OFFSETS:
        base = None
        for day_h in days:
            t_abs = g0 + day_h + off
            if t_abs - g0 > horizon_h + 24.0:
                continue
            d0 = sched.decide(make_ctx(t_abs, 0.5))
            key0 = (d0.intensity, d0.batch_size)
            samples.append((t_abs, d0.intensity, d0.batch_size))
            if base is None:
                base = key0
            elif key0 != base:
                elapsed_dep = True
            for p in _PROBE_PROGRESS:
                dp = sched.decide(make_ctx(t_abs, p))
                if (dp.intensity, dp.batch_size) != key0:
                    progress_dep = True
                    samples.append((t_abs, dp.intensity, dp.batch_size))
    return progress_dep, elapsed_dep, samples


def _table_depends_on_t(sched, prof, probe) -> bool:
    """True when the case's decision table has T rows (and so must be
    rebuilt if the retry loop grows the horizon)."""
    if prof is not None:
        return False
    if hasattr(sched, "decide_grid"):
        return True
    return probe[1]                      # elapsed_dep


def _case_tables(case, carbon_sig, price_sig, sph: int, T: int, B: int,
                 prof, probe) -> Tuple[np.ndarray, np.ndarray, bool]:
    """Decision table (u_rows, batch_rows) of shape (R, B_i) plus a flag:
    periodic tables have R = 24*sph rows indexed modulo the day; full
    tables have R = T rows indexed by grid slot.  `prof` (closed-form
    24 h profile or None) and `probe` (dependence classification) are
    computed once per case by the caller — probing costs ~10^2 decide()
    calls and must not repeat per retry."""
    sched = as_schedule(case.schedule)
    H = 24 * sph
    if prof is not None:                 # bundled Policy/HourlyPolicy,
        u_rows, b_rows = prof            # already sampled at sph resolution
        return (u_rows[:, None].astype(float),
                b_rows[:, None].astype(float), True)

    g0 = math.floor(case.start_hour * sph) / sph
    if hasattr(sched, "decide_grid"):
        # vectorized decision protocol: the whole (T, B) table in one call
        t_abs = g0 + np.arange(T) / sph
        s0 = int(round(g0 * sph)) % H
        centers = (np.arange(B) + 0.5) / B
        ctx = SchedulingContext(
            hour_of_day=t_abs[:, None] % 24.0, band="",
            background=_bg_table(case.bands, sph)[
                (s0 + np.arange(T)) % H][:, None],
            carbon_factor=sample_signal(carbon_sig, t_abs)[:, None],
            price_usd_per_kwh=(sample_signal(price_sig, t_abs)[:, None]
                               if price_sig is not None else 0.0),
            elapsed_h=np.maximum(t_abs - case.start_hour, 0.0)[:, None],
            progress=centers[None, :], deadline_h=case.deadline_h)
        u, b = sched.decide_grid(ctx)
        return (np.broadcast_to(np.asarray(u, dtype=float), (T, B)).copy(),
                np.broadcast_to(np.asarray(b, dtype=float), (T, B)).copy(),
                False)

    make_ctx = _ctx_factory(case, carbon_sig, price_sig)
    progress_dep, elapsed_dep, _ = probe
    B_i = B if progress_dep else 1
    if elapsed_dep:
        rows = T
        t_abs = g0 + np.arange(T) / sph
    else:
        rows = H
        hod = np.arange(H) / sph
        t_abs = g0 + ((hod - g0) % 24.0)   # first occurrence of each row
    u_rows = np.empty((rows, B_i))
    b_rows = np.empty((rows, B_i))
    for ri in range(rows):
        t = float(t_abs[ri])
        for bi in range(B_i):
            p = (bi + 0.5) / B_i if progress_dep else 0.0
            d = sched.decide(make_ctx(t, p))
            u_rows[ri, bi] = d.intensity
            b_rows[ri, bi] = d.batch_size
    return u_rows, b_rows, not elapsed_dep


def _estimate_hours(case, prof, probe, max_hours: float,
                    sph: int = 1) -> float:
    """Campaign-duration estimate sizing the scan grid.

    Near-exact for periodic progress-free tables (one day's throughput is
    computable up front); conservative — slowest sampled decision — for
    decide()-probed schedules.  The scan retries with a doubled horizon
    if it undershoots."""
    sched = as_schedule(case.schedule)
    bg_day = _bg_table(case.bands, sph)
    if prof is not None:                 # (24*sph,) day profile
        u_rows, b_rows = prof
        r = model.campaign_rates(np.asarray(u_rows), np.asarray(b_rows),
                                 bg_day, case.workload, case.machine, xp=np)
        day_scen = float(r.scen_per_s.sum()) * 3600.0 / sph
        if day_scen <= 0.0:
            return max_hours
        dur = case.workload.n_scenarios / day_scen * 24.0
        return min(dur * 1.02 + 28.0, max_hours)
    samples = probe[2]
    u = np.array([s[1] for s in samples])
    b = np.array([s[2] for s in samples])
    bg = bg_day[np.floor([(s[0] % 24.0) * sph for s in samples]).astype(int)]
    rs = model.campaign_rates(u, b, bg, case.workload, case.machine,
                              xp=np).scen_per_s
    floor = rs[rs > 0.02 * rs.max()] if rs.size else rs
    if not floor.size:
        return max_hours
    if hasattr(sched, "decide_grid"):
        # vectorized tables are cheap to rebuild, so start from the mean
        # sampled rate (a feedback controller like the deadline keeper
        # mixes its extremes) and let the retry loop double on undershoot
        dur = case.workload.n_scenarios / (float(floor.mean()) * 3600.0)
        return min(dur * 1.25 + 26.0, max_hours)
    dur = case.workload.n_scenarios / (float(floor.min()) * 3600.0)
    return min(dur * 1.15 + 26.0, max_hours)


# ---------------------------------------------------------------------------
# The scan itself, in both backends.  State: (remaining, runtime_s, kwh,
# co2, cost); per-slot inputs: decision-table row index, background,
# carbon factor, price, slot length.
# ---------------------------------------------------------------------------
def _bucket_lookup(xp, u_tab, b_tab, sidx, row, prog, B):
    """Decision at live progress: linear interpolation between the two
    nearest bucket centers (tables are sampled at centers (b+0.5)/B), so
    smooth progress-aware schedules see no quantization bias."""
    if B == 1:
        return u_tab[sidx, row, 0], b_tab[sidx, row, 0]
    x = prog * B - 0.5
    b0 = xp.clip(xp.floor(x), 0, B - 2).astype("int32")
    w = xp.clip(x - b0, 0.0, 1.0)
    u = (1.0 - w) * u_tab[sidx, row, b0] + w * u_tab[sidx, row, b0 + 1]
    bt = (1.0 - w) * b_tab[sidx, row, b0] + w * b_tab[sidx, row, b0 + 1]
    return u, bt


def _scan_step_np(state, u_tab, b_tab, row, bg, cf, pr, ln, params, B):
    remaining, rt, kwh, co2, cost = state
    (n_scen, rate, oh, idle, dyn, alpha, gamma, ohfrac, sidx) = params
    prog = 1.0 - remaining / n_scen
    u, bt = _bucket_lookup(np, u_tab, b_tab, sidx, row, prog, B)
    r = model.rates(u, bt, bg, rate_at_full=rate, batch_overhead_s=oh,
                    idle_w=idle, dyn_w=dyn, alpha=alpha, gamma=gamma,
                    overhead_w_frac=ohfrac, xp=np)
    dt = np.where(remaining > 0.0,
                  np.minimum(ln, remaining / np.maximum(r.scen_per_s, 1e-30)),
                  0.0)
    e = r.kwh_per_s * dt
    return (remaining - r.scen_per_s * dt, rt + dt, kwh + e,
            co2 + e * cf, cost + e * pr)


def _scan_np(u_tab, b_tab, rowidx, bg, cf, pr, lens, n_scen, rate, oh,
             idle, dyn, alpha, gamma, ohfrac, B: int):
    S, T = rowidx.shape
    params = (n_scen, rate, oh, idle, dyn, alpha, gamma, ohfrac,
              np.arange(S))
    state = (n_scen.copy(), np.zeros(S), np.zeros(S), np.zeros(S),
             np.zeros(S))
    for t in range(T):
        if not (state[0] > 0.0).any():
            break
        state = _scan_step_np(state, u_tab, b_tab, rowidx[:, t], bg[:, t],
                              cf[:, t], pr[:, t], lens[:, t], params, B)
    return state


if _HAS_JAX:
    @functools.partial(jax.jit, static_argnames=("B",))
    def _scan_jax(u_tab, b_tab, rowidx, bg, cf, pr, lens, n_scen, rate, oh,
                  idle, dyn, alpha, gamma, ohfrac, B: int):
        S = u_tab.shape[0]
        sidx = jnp.arange(S)

        def step(carry, xs):
            remaining, rt, kwh, co2, cost = carry
            row, bg_t, cf_t, pr_t, ln = xs
            prog = 1.0 - remaining / n_scen
            u, bt = _bucket_lookup(jnp, u_tab, b_tab, sidx, row, prog, B)
            r = model.rates(u, bt, bg_t, rate_at_full=rate,
                            batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                            alpha=alpha, gamma=gamma, overhead_w_frac=ohfrac,
                            xp=jnp)
            dt = jnp.where(
                remaining > 0.0,
                jnp.minimum(ln, remaining / jnp.maximum(r.scen_per_s, 1e-30)),
                0.0)
            e = r.kwh_per_s * dt
            carry = (remaining - r.scen_per_s * dt, rt + dt, kwh + e,
                     co2 + e * cf_t, cost + e * pr_t)
            return carry, None

        zero = jnp.zeros(S)
        init = (n_scen, zero, zero, zero, zero)
        xs = (rowidx.T, bg.T, cf.T, pr.T, lens.T)
        final, _ = jax.lax.scan(step, init, xs)
        return final


# ---------------------------------------------------------------------------
# Differentiable objective path (the substrate of core/optimize.py).
#
# `trace_sweep` above is built for *evaluation*: it probes schedules with
# Python `decide()` calls, classifies them, and retries with a doubled
# horizon — none of which can live inside a jax trace.  `TraceObjective`
# is the same physics specialized for *search*: everything that depends
# on the case (signals, background, slot lengths, machine scalars) is
# precomputed once as static arrays, and what remains is a pure function
#     per-slot intensities (..., n_slots)  ->  EvalMetrics
# with no Python in the traced region, so `jax.grad` flows through the
# scan and `jax.vmap` batches hundreds of candidates per jit call.
# ---------------------------------------------------------------------------
class EvalMetrics(NamedTuple):
    """Campaign outcome as a differentiable pytree (floats or arrays).

    `cost_usd` is 0 when no price signal was given; `unfinished` is the
    fraction of the workload left at the end of the horizon (0 when the
    campaign completed — optimizers penalize it so solutions that stall
    past the horizon are driven back into range).
    """
    energy_kwh: Any
    co2_kg: Any
    runtime_h: Any
    cost_usd: Any
    unfinished: Any


class TraceObjective:
    """One sweep case as a pure, vmappable objective over day schedules.

    Construction samples the case's signals over a *fixed* horizon
    (`horizon_h`, default sized from a mid-intensity duration estimate or
    the case deadline) — there is no retry-doubling or probe
    classification afterwards.  `evaluate(u_day)` maps per-slot
    intensities of shape (..., n_slots) to `EvalMetrics` of shape (...,):
    on the JAX backend the computation is traceable (grad/vmap/jit
    compose over it); on the NumPy backend the identical scan runs as a
    loop, still vectorized over leading axes.

    A schedule that finishes inside the horizon gets exactly the numbers
    `trace_sweep` would produce for the equivalent `ParametricSchedule`
    (same grid, same shared rate model); one that does not reports
    `unfinished > 0` instead of growing the grid.
    """

    def __init__(self, case, *, price: Optional[Signal] = None,
                 slots_per_hour: int = 1, horizon_h: Optional[float] = None,
                 batch_size: float = 50.0, max_days: int = 120,
                 backend: Optional[str] = None):
        sph = int(slots_per_hour)
        self.case = case
        self.sph = sph
        self.n_slots = 24 * sph
        self.batch_size = float(batch_size)
        self.has_price = price is not None
        self.use_jax = _use_jax(backend)
        self._jit = None

        wl, mach = case.workload, case.machine
        self._scalars = (float(wl.n_scenarios), float(wl.rate_at_full),
                         float(wl.batch_overhead_s), float(mach.idle_w),
                         float(mach.dyn_w), float(mach.alpha),
                         float(mach.gamma), float(mach.overhead_w_frac))

        carbon_sig = carbon_signal(case.carbon or GridCarbonModel())
        start = float(case.start_hour)
        g0 = math.floor(start * sph) / sph
        bg_day = _bg_table(case.bands, sph)
        if horizon_h is None:
            horizon_h = self._default_horizon(bg_day, max_days)
        self.horizon_h = float(min(horizon_h, max_days * 24.0))
        T = max(int(math.ceil(self.horizon_h * sph)), 1)
        slot = np.arange(T)
        t_abs = g0 + slot / sph
        s0 = int(round(g0 * sph)) % self.n_slots
        self.rowidx = ((s0 + slot) % self.n_slots).astype(np.int32)
        self.bg = bg_day[self.rowidx]
        self.cf = sample_signal(carbon_sig, t_abs)
        self.pr = (sample_signal(price, t_abs) if price is not None
                   else np.zeros(T))
        lens = np.full(T, 3600.0 / sph)
        lens[0] = (g0 + 1.0 / sph - start) * 3600.0
        self.lens = lens
        self.hours = t_abs                 # absolute hour of each slot

    def _default_horizon(self, bg_day: np.ndarray, max_days: int) -> float:
        """Mid-intensity duration estimate, stretched; or the deadline
        with margin, whichever is larger (deadline-capped optima sit at
        the cap, so the grid must comfortably cover it)."""
        n_scen, *_ = self._scalars
        r = model.campaign_rates(0.35, self.batch_size, float(bg_day.mean()),
                                 self.case.workload, self.case.machine)
        dur = n_scen / max(r.scen_per_s, 1e-9) / 3600.0
        est = dur * 1.6 + 48.0
        dl = float(getattr(self.case, "deadline_h", 0.0) or 0.0)
        if dl > 0.0:
            est = max(est, dl * 1.25 + 24.0)
        return min(est, max_days * 24.0)

    # ------------------------------------------------------------------
    def evaluate(self, u_day) -> EvalMetrics:
        """EvalMetrics for per-slot intensities `u_day` (..., n_slots).

        Pure: jnp inputs stay traced on the JAX backend (compose with
        jit/grad/vmap as you like, ideally under `enable_x64` so results
        match the engines' float64); NumPy inputs run the loop backend.
        """
        if self.use_jax and not isinstance(u_day, np.ndarray):
            return self._evaluate_jax(u_day)
        return self._evaluate_np(np.asarray(u_day, dtype=float))

    def evaluate_batch(self, U) -> EvalMetrics:
        """Concrete (NumPy) EvalMetrics for a (N, n_slots) population,
        evaluated in one jitted call on the JAX backend."""
        U = np.asarray(U, dtype=float)
        if not self.use_jax:
            return self._evaluate_np(U)
        with enable_x64():
            out = self._jitted_eval()(jnp.asarray(U))
        return EvalMetrics(*(np.asarray(x) for x in out))

    def _jitted_eval(self):
        if self._jit is None:
            self._jit = jax.jit(self._evaluate_jax)
        return self._jit

    # ------------------------------------------------------------------
    def _step_rates(self, u, bg_t, xp):
        (_, rate, oh, idle, dyn, alpha, gamma, ohfrac) = self._scalars[:8]
        return model.rates(u, self.batch_size, bg_t, rate_at_full=rate,
                           batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                           alpha=alpha, gamma=gamma, overhead_w_frac=ohfrac,
                           xp=xp)

    def _evaluate_jax(self, u_day) -> EvalMetrics:
        n_scen = self._scalars[0]
        u_day = jnp.asarray(u_day)
        u_t = jnp.moveaxis(u_day[..., jnp.asarray(self.rowidx)], -1, 0)
        shape = u_day.shape[:-1]

        def step(carry, xs):
            remaining, rt, kwh, co2, cost = carry
            u, bg_t, cf_t, pr_t, ln = xs
            r = self._step_rates(u, bg_t, jnp)
            scen = jnp.maximum(r.scen_per_s, 1e-30)
            # strict branch selection, NOT jnp.minimum(ln, remaining/scen):
            # when the campaign finishes exactly on a slot boundary, the
            # minimum's tie splits its gradient across both branches and
            # the analytic cancellation d(remaining - scen*dt)/du == 0 of
            # the finish branch is lost — the residue, scaled by the
            # optimizer's unfinished penalty, produced gradient norms
            # ~1000x too large at such points.  The tie must take the
            # finish branch, where the cancellation is exact.
            dt = jnp.where(remaining > scen * ln, ln, remaining / scen)
            dt = jnp.where(remaining > 0.0, dt, 0.0)
            e = r.kwh_per_s * dt
            return (remaining - r.scen_per_s * dt, rt + dt, kwh + e,
                    co2 + e * cf_t, cost + e * pr_t), None

        zero = jnp.zeros(shape)
        init = (jnp.full(shape, n_scen), zero, zero, zero, zero)
        xs = (u_t, jnp.asarray(self.bg), jnp.asarray(self.cf),
              jnp.asarray(self.pr), jnp.asarray(self.lens))
        (remaining, rt, kwh, co2, cost), _ = jax.lax.scan(step, init, xs)
        return EvalMetrics(kwh, co2, rt / 3600.0, cost, remaining / n_scen)

    def _evaluate_np(self, u_day: np.ndarray) -> EvalMetrics:
        n_scen = self._scalars[0]
        u_t = u_day[..., self.rowidx]                       # (..., T)
        shape = u_day.shape[:-1]
        remaining = np.full(shape, n_scen)
        rt = np.zeros(shape)
        kwh = np.zeros(shape)
        co2 = np.zeros(shape)
        cost = np.zeros(shape)
        for t in range(len(self.lens)):
            if not (remaining > 0.0).any():
                break
            r = self._step_rates(u_t[..., t], float(self.bg[t]), np)
            scen = np.maximum(r.scen_per_s, 1e-30)
            ln = self.lens[t]
            dt = np.where(remaining > 0.0,
                          np.where(remaining > scen * ln, ln,
                                   remaining / scen),
                          0.0)
            e = r.kwh_per_s * dt
            remaining = remaining - r.scen_per_s * dt
            rt = rt + dt
            kwh = kwh + e
            co2 = co2 + e * self.cf[t]
            cost = cost + e * self.pr[t]
        return EvalMetrics(kwh, co2, rt / 3600.0, cost, remaining / n_scen)


def evaluate_params(params, case, *, u_min: float = 0.05, u_max: float = 1.0,
                    batch_size: float = 50.0,
                    price: Optional[Signal] = None, slots_per_hour: int = 1,
                    horizon_h: Optional[float] = None,
                    backend: Optional[str] = None) -> EvalMetrics:
    """`EvalMetrics` (energy_kwh, co2_kg, runtime_h, cost_usd, unfinished)
    for `ParametricSchedule` logits `params` on `case`.

    Pure and jax.grad-/jax.vmap-compatible: the squash and the scan are
    both traceable, so `jax.grad(lambda p: evaluate_params(p, case).co2_kg)`
    just works.  For repeated evaluation (optimization loops) build one
    `TraceObjective` instead — this convenience resamples the case's
    signals on every call.
    """
    from repro.core.schedule import ParametricSchedule
    obj = TraceObjective(case, price=price, slots_per_hour=slots_per_hour,
                         horizon_h=horizon_h, batch_size=batch_size,
                         backend=backend)
    traced = obj.use_jax and not isinstance(params, np.ndarray)
    xp = jnp if traced else np
    u = ParametricSchedule.u_from_logits(xp.asarray(params), u_min, u_max,
                                         xp=xp)
    return obj.evaluate(u)


def _use_jax(backend: Optional[str]) -> bool:
    if backend == "numpy":
        return False
    if backend == "jax":
        if not _HAS_JAX:
            raise RuntimeError("backend='jax' requested but jax is not "
                               "importable")
        return True
    return _HAS_JAX


def trace_sweep(cases: Sequence, price: Optional[Signal] = None, *,
                slots_per_hour: int = 1, progress_buckets: int = 32,
                max_days: int = 120,
                backend: Optional[str] = None) -> List[SimResult]:
    """Evaluate cases on the trace grid; order is preserved.

    Use `repro.core.engine.sweep` for mixed workloads — it keeps the
    cheaper periodic path for cases that qualify and calls this for the
    rest.  `progress_buckets` sets the progress resolution of decision
    tables for progress-aware schedules (error scales ~1/buckets and is
    pinned <0.5 % vs the per-batch oracle by tests/test_trace_engine.py).
    """
    if not len(cases):
        return []
    sph = int(slots_per_hour)
    B = int(progress_buckets)
    S = len(cases)
    max_hours = float(max_days) * 24.0

    carbon_sigs = [carbon_signal(c.carbon or GridCarbonModel())
                   for c in cases]
    n_scen = np.array([float(c.workload.n_scenarios) for c in cases])
    rate = np.array([c.workload.rate_at_full for c in cases])
    oh = np.array([c.workload.batch_overhead_s for c in cases])
    idle = np.array([c.machine.idle_w for c in cases])
    dyn = np.array([c.machine.dyn_w for c in cases])
    alpha = np.array([c.machine.alpha for c in cases])
    gamma = np.array([c.machine.gamma for c in cases])
    ohfrac = np.array([c.machine.overhead_w_frac for c in cases])
    start = np.array([c.start_hour for c in cases])
    g0 = np.floor(start * sph) / sph
    s0 = np.round(g0 * sph).astype(int) % (24 * sph)

    # classify every case exactly once: closed-form profile, or a probe of
    # its decide() over the coarse lattice (both feed the duration
    # estimate AND the table builder — probing is ~10^2 Python calls per
    # case, so it must not repeat per retry)
    from repro.core.engine import periodic_decision_profile
    scheds = [as_schedule(c.schedule) for c in cases]
    profs = [periodic_decision_profile(s, c.bands, sph)
             for s, c in zip(scheds, cases)]
    probes = [None if prof is not None else
              _probe(scheds[i], _ctx_factory(cases[i], carbon_sigs[i],
                                             price),
                     float(g0[i]), max_hours)
              for i, prof in enumerate(profs)]

    est_h = max(_estimate_hours(c, prof, probe, max_hours, sph)
                for c, prof, probe in zip(cases, profs, probes))
    T = int(math.ceil(min(est_h, max_hours) * sph))

    tabs: List[Optional[Tuple[np.ndarray, np.ndarray, bool]]] = [None] * S
    while True:
        H = 24 * sph
        slot = np.arange(T)
        t_abs = g0[:, None] + slot[None, :] / sph                   # (S, T)
        lens = np.full((S, T), 3600.0 / sph)
        lens[:, 0] = (g0 + 1.0 / sph - start) * 3600.0

        for i, c in enumerate(cases):
            # T-dependent tables (decide_grid / elapsed-aware) must track
            # the grown horizon; periodic ones are reused across retries
            if tabs[i] is None or _table_depends_on_t(scheds[i], profs[i],
                                                      probes[i]):
                tabs[i] = _case_tables(c, carbon_sigs[i], price, sph, T, B,
                                       profs[i], probes[i])
        R = max(t[0].shape[0] for t in tabs)
        Bg = max(t[0].shape[1] for t in tabs)
        u_tab = np.zeros((S, R, Bg))
        b_tab = np.ones((S, R, Bg))
        rowidx = np.empty((S, T), dtype=np.int32)
        bg = np.empty((S, T))
        cf = np.empty((S, T))
        pr = np.zeros((S, T))
        for i, (c, (u_r, b_r, periodic)) in enumerate(zip(cases, tabs)):
            rows = u_r.shape[0]
            u_tab[i, :rows] = np.broadcast_to(u_r, (rows, Bg)) \
                if u_r.shape[1] == 1 else u_r
            b_tab[i, :rows] = np.broadcast_to(b_r, (rows, Bg)) \
                if b_r.shape[1] == 1 else b_r
            rowidx[i] = (s0[i] + slot) % H if periodic else slot
            bg[i] = _bg_table(c.bands, sph)[(s0[i] + slot) % H]
            cf[i] = sample_signal(carbon_sigs[i], t_abs[i])
            if price is not None:
                pr[i] = sample_signal(price, t_abs[i])

        args = (u_tab, b_tab, rowidx, bg, cf, pr, lens, n_scen, rate, oh,
                idle, dyn, alpha, gamma, ohfrac)
        if _use_jax(backend):
            with enable_x64():
                final = _scan_jax(*(jnp.asarray(a) for a in args), B=Bg)
            final = tuple(np.asarray(f) for f in final)
        else:
            final = _scan_np(*args, B=Bg)
        remaining, runtime_s, kwh, co2, cost = final

        if (remaining <= 1e-6 * n_scen).all():
            break
        if T >= int(max_hours * sph):
            worst = int(np.argmax(remaining / n_scen))
            raise RuntimeError(
                f"case {cases[worst].name()!r} did not finish within "
                f"max_days={max_days} on the trace grid (remaining "
                f"{remaining[worst]:.0f} of {n_scen[worst]:.0f} scenarios); "
                "its schedule may be stalled at zero intensity")
        T = min(T * 2, int(max_hours * sph))

    out = []
    for i, c in enumerate(cases):
        out.append(SimResult(
            policy=c.name(), runtime_h=float(runtime_s[i]) / 3600.0,
            energy_kwh=float(kwh[i]), co2_kg=float(co2[i]),
            cost_usd=float(cost[i]) if price is not None else None))
    return out
