"""Trace-grid sweep engine: compile -> execute -> summarize.

The periodic 24-slot engine (core/engine.py) collapses a campaign into
one repeated day, which is exact only when every decision and signal is
24 h-periodic and ignorant of campaign position.  This engine instead
*steps* the campaign hour by hour (or finer, for sub-hour band edges),
carrying `(remaining, elapsed, energy/CO2/cost accumulators)` state, so
it natively represents everything the periodic grid cannot: progress/
elapsed-aware schedules, non-periodic multi-day `TraceSignal`s, carbon
**ensembles** (`SignalEnsemble` — E scenario members evaluated in one
scan), and heterogeneous fleets.

The sweep is staged:

  * **compile** (`compile_plan`) classifies every case exactly once —
    closed-form day profile, probed decide() lattice, or the vectorized
    `decide_grid` protocol — and lowers it into a `SweepPlan`: padded
    decision tables, per-lane physics scalars, day-periodic background
    tables, and incremental signal grids, all built with batched NumPy.
    Per-case compilation is memoized by case fingerprint, so repeated
    sweeps and `Campaign.optimize` warm-start loops do not re-probe or
    rebuild tables.

  * **execute** (`execute_plan`) runs a *chunked resumable scan*: the
    horizon is covered by fixed-shape chunks (default 4 days), state is
    carried across chunks, finished lanes are compacted out of the
    batch, and unfinished lanes simply get more chunks appended — no
    slot is ever recomputed (the old engine re-scanned the entire batch
    from t=0 with a doubled horizon whenever one straggler didn't
    finish).  Fixed chunk shapes plus bucketed padding of the
    (lanes, rows, buckets) tables mean the jitted kernel compiles once
    per bucket signature instead of once per horizon length.
    `mode="monolithic"` keeps the old single-scan/retry-doubling
    behaviour for comparison benchmarks (`scan_stats()` counts the
    slot-work either way).

  * **summarize** (`summarize_plan`) folds the final state into
    `SimResult`s; ensemble cases get mean CO2 plus full per-member
    `EnsembleStats`.

Decision tables stay compact: schedules whose decisions are detected (by
probing) to be hour-of-day-periodic keep 24*sph rows indexed modulo the
day; progress-free schedules keep a single bucket; elapsed-aware
schedules get their table rows built chunk by chunk, never for slots
already scanned.  Physics per slot comes from the shared rate model
(core/model.py) with `xp=jnp`.

JAX is optional: with `backend="numpy"` (or when JAX is absent, following
the repro/compat.py guard pattern) the identical scan runs as a NumPy
loop over the grid — still vectorized across lanes, just not jitted.
JAX runs under `enable_x64` so both backends agree to float64 precision
with the periodic engine on periodic cases.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os
from collections import OrderedDict
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Sequence, Set, Tuple)

import numpy as np

from repro.core import model, plancache
from repro.core.carbon import GridCarbonModel
from repro.core.schedule import SchedulingContext, as_schedule
from repro.core.signal import (Signal, SignalEnsemble, carbon_signal,
                               sample_signal)
from repro.core.simulator import SimResult, ensemble_stats

try:                                    # JAX is optional on the trace path
    import jax
    import jax.numpy as jnp

    from repro.compat import (enable_persistent_compilation_cache,
                              enable_x64)
    _HAS_JAX = True
except Exception:                       # pragma: no cover - env without jax
    jax = jnp = enable_x64 = None
    _HAS_JAX = False

    def enable_persistent_compilation_cache(cache_dir=None):
        return None                     # nothing to cache without jax

_PROBE_PROGRESS = (0.0, 1.0 / 3.0, 2.0 / 3.0, 0.999)
_PROBE_OFFSETS = (0.0, 3.0, 5.0, 9.0, 13.0, 17.0, 21.0)

#: Chunk length of the resumable scan, in days.  One compiled kernel
#: shape serves every campaign length; stragglers just get more chunks.
DEFAULT_CHUNK_DAYS = 4

#: Fraction of a case's workload that must complete per scanned day for
#: the case to count as progressing (zero-intensity schedules leak a
#: ~1e-10/day numerical trickle through the rate floor, real schedules
#: complete orders of magnitude more).
_STALL_FRAC_PER_DAY = 1e-9

#: Remaining-work fraction below which a lane counts as finished — the
#: executor's compaction threshold, and the site-coupled kernels' power
#: mask: a lane whose fp residue is epsilon-positive must not demand a
#: full slot of site power (backends round the final subtraction
#: differently, and one phantom throttled slot costs the rest of the
#: group real throughput).
_FINISH_FRAC = 1e-6


# ---------------------------------------------------------------------------
# Scan statistics: benchmarks (and curious users) read these to see how
# much slot-work a sweep actually executed and how often the jitted
# chunk kernel saw a brand-new shape signature.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ScanStats:
    """Counters over every scan executed since the last reset.

    `slot_work` counts scan-lane x slot units actually executed (the
    wasted-work metric the chunked executor minimizes); `chunks` counts
    kernel launches; `grouped_lanes` counts lane x chunk units that ran
    through the site-coupled (grouped-lane) kernel — 0 for plain sweeps;
    `plan_hits`/`plan_misses` count the per-case compile cache;
    `jit_shapes` holds the distinct shape signatures handed to the
    jitted kernels (each costs one XLA compile, summarized by
    `jit_compiles`).  The `requests_*` counters are fed by the serving
    layer (core/serve.py) as it schedules arrival windows: seen is
    every request offered, admitted/rejected partition them, and
    degraded counts admissions that only fit at a cheaper quality tier.
    Scale-out observability: `devices_used` is the widest `shard_map`
    fan-out any chunk executed on (0 until a scan runs, 1 for purely
    single-device scans); `precision_mode` is the dtype policy
    ("fp64"/"mixed") of the most recent `execute_plan`; and
    `pallas_dispatches` counts launches of the coupled-throttle Pallas
    kernel (0 whenever the jnp fallback ran instead).
    MPC observability: `replans` counts `replace_tables` calls (one per
    mid-flight re-plan) and `slots_reused` counts the lane x slot units
    of already-executed state carried across those re-plans — work a
    naive plan-from-scratch loop would have recomputed and the resumable
    executor did not.
    Recurrence observability: `disk_hits`/`disk_misses` count per-case
    compile artifacts served from (or absent from) the persistent plan
    cache (core/plancache.py; a fresh-process warm start of an S-case
    sweep shows `disk_hits == S` with `plan_misses == 0` — zero
    classification/lowering work), and `lanes_recomputed`/
    `lanes_spliced` partition a `delta_sweep`'s lanes into re-scanned
    vs result-spliced (a 1-of-S schedule change shows ~1/S recomputed).
    Counters accumulate per process — pass `scan_stats(reset=True)`
    (or call `reset_scan_stats()`) to zero them between measurements.
    """
    slot_work: int = 0            # scan-lane x slot units executed
    chunks: int = 0               # kernel launches
    grouped_lanes: int = 0        # lane x chunk units in coupled groups
    plan_hits: int = 0            # per-case compile cache hits
    plan_misses: int = 0
    replans: int = 0              # replace_tables calls (mid-flight re-plans)
    slots_reused: int = 0         # lane x slot units carried across re-plans
    disk_hits: int = 0            # compile artifacts loaded from disk
    disk_misses: int = 0          # disk lookups that fell through to compile
    lanes_recomputed: int = 0     # delta_sweep lanes re-scanned
    lanes_spliced: int = 0        # delta_sweep lanes served from prev results
    requests_seen: int = 0        # requests offered to the serving layer
    requests_admitted: int = 0    # ... assigned a service slot
    requests_rejected: int = 0    # ... infeasible at every allowed tier
    requests_degraded: int = 0    # ... admitted at a cheaper tier
    devices_used: int = 0         # max devices any chunk sharded across
    precision_mode: str = ""      # dtype policy of the last executed plan
    pallas_dispatches: int = 0    # coupled-chunk Pallas kernel launches
    jit_shapes: Set[tuple] = dataclasses.field(default_factory=set)

    @property
    def jit_compiles(self) -> int:
        """Distinct shape signatures handed to the jitted kernel (each
        one costs a fresh XLA compile)."""
        return len(self.jit_shapes)


_STATS = ScanStats()


def scan_stats(reset: bool = False) -> ScanStats:
    """A snapshot copy of the engine's scan counters.

    `reset=True` zeroes the live counters *after* taking the snapshot —
    the idiom for before/after measurements in one process:

        scan_stats(reset=True)        # drop whatever accumulated
        run_sweep()
        work = scan_stats().slot_work
    """
    snap = dataclasses.replace(_STATS, jit_shapes=set(_STATS.jit_shapes))
    if reset:
        reset_scan_stats()
    return snap


def reset_scan_stats() -> None:
    """Zero the counters (including the jit-shape signature set)."""
    _STATS.slot_work = 0
    _STATS.chunks = 0
    _STATS.grouped_lanes = 0
    _STATS.plan_hits = 0
    _STATS.plan_misses = 0
    _STATS.replans = 0
    _STATS.slots_reused = 0
    _STATS.disk_hits = 0
    _STATS.disk_misses = 0
    _STATS.lanes_recomputed = 0
    _STATS.lanes_spliced = 0
    _STATS.requests_seen = 0
    _STATS.requests_admitted = 0
    _STATS.requests_rejected = 0
    _STATS.requests_degraded = 0
    _STATS.devices_used = 0
    _STATS.precision_mode = ""
    _STATS.pallas_dispatches = 0
    _STATS.jit_shapes = set()


@functools.lru_cache(maxsize=256)       # bounded, same policy as engine.py
def _bg_table(bands, sph: int) -> np.ndarray:
    """Background load per grid row over one day ((24*sph,), memoized)."""
    return np.array([bands.background(bands.band_at(r / sph))
                     for r in range(24 * sph)])


def _ctx_factory(case, carbon_sig, price_sig):
    """ctx(t_abs, progress) for probing and decision-table sampling,
    built exactly like the sequential simulators build theirs."""
    bands = case.bands
    start = case.start_hour

    def make(t_abs: float, progress: float) -> SchedulingContext:
        hod = t_abs % 24.0
        band = bands.band_at(hod)
        return SchedulingContext(
            hour_of_day=hod, band=band, background=bands.background(band),
            carbon_factor=float(carbon_sig.at(t_abs)),
            price_usd_per_kwh=(float(price_sig.at(t_abs))
                               if price_sig is not None else 0.0),
            elapsed_h=max(t_abs - start, 0.0), progress=progress,
            deadline_h=case.deadline_h)

    return make


class ProbeInfo(NamedTuple):
    """Dependence classification of one schedule's decide()."""
    progress_dep: bool
    elapsed_dep: bool
    carbon_dep: bool
    samples: list                 # (t_abs, intensity, batch) lattice points


def _probe(sched, make_ctx, g0: float, horizon_h: float) -> ProbeInfo:
    """Classify a schedule's decide() from a coarse lattice.

    `elapsed_dep` is true when the same hour-of-day decides differently on
    different days (a deadline pace, or a schedule following a non-periodic
    carbon trace through ctx.carbon_factor); `progress_dep` when decisions
    move with ctx.progress; `carbon_dep` when perturbing ctx.carbon_factor
    alone changes the decision (such schedules need per-member decision
    tables under a carbon ensemble).  Exact for the bundled schedule
    families; arbitrary callables are sampled on the lattice (documented
    heuristic — a schedule varying only between lattice points can be
    misclassified).
    """
    days = sorted({0.0, 24.0, 48.0,
                   max(math.floor(horizon_h / 48.0) * 24.0, 0.0),
                   max((math.floor(horizon_h / 24.0) - 1) * 24.0, 0.0)})
    progress_dep = elapsed_dep = carbon_dep = False
    samples = []
    for off in _PROBE_OFFSETS:
        base = None
        for day_h in days:
            t_abs = g0 + day_h + off
            if t_abs - g0 > horizon_h + 24.0:
                continue
            ctx0 = make_ctx(t_abs, 0.5)
            d0 = sched.decide(ctx0)
            key0 = (d0.intensity, d0.batch_size)
            samples.append((t_abs, d0.intensity, d0.batch_size))
            if base is None:
                base = key0
            elif key0 != base:
                elapsed_dep = True
            if not carbon_dep:
                dc = sched.decide(dataclasses.replace(
                    ctx0, carbon_factor=ctx0.carbon_factor * 1.5 + 0.05))
                if (dc.intensity, dc.batch_size) != key0:
                    carbon_dep = True
            for p in _PROBE_PROGRESS:
                dp = sched.decide(make_ctx(t_abs, p))
                if (dp.intensity, dp.batch_size) != key0:
                    progress_dep = True
                    samples.append((t_abs, dp.intensity, dp.batch_size))
    return ProbeInfo(progress_dep, elapsed_dep, carbon_dep, samples)


def _case_g0(case, sph: int) -> float:
    return math.floor(case.start_hour * sph) / sph


def _grid_ctx(case, carbon_sig, price_sig, sph: int, t_abs: np.ndarray,
              B_i: int) -> SchedulingContext:
    """Array SchedulingContext over absolute hours `t_abs` for the
    vectorized `decide_grid` protocol (shape (T, 1) x (1, B))."""
    H = 24 * sph
    rows = np.floor(t_abs * sph + 1e-9).astype(int) % H
    centers = (np.arange(B_i) + 0.5) / B_i
    return SchedulingContext(
        hour_of_day=t_abs[:, None] % 24.0, band="",
        background=_bg_table(case.bands, sph)[rows][:, None],
        carbon_factor=sample_signal(carbon_sig, t_abs)[:, None],
        price_usd_per_kwh=(sample_signal(price_sig, t_abs)[:, None]
                           if price_sig is not None else 0.0),
        elapsed_h=np.maximum(t_abs - case.start_hour, 0.0)[:, None],
        progress=centers[None, :], deadline_h=case.deadline_h)


def _day_table(case, sched, probe: Optional[ProbeInfo], carbon_sig,
               price_sig, sph: int, B: int
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Periodic decision table of shape (24*sph, B_i) for a schedule whose
    decide() was probed hour-of-day-periodic (rows are indexed modulo the
    day; each row is sampled at its first occurrence on the grid)."""
    H = 24 * sph
    g0 = _case_g0(case, sph)
    hod = np.arange(H) / sph
    t_abs = g0 + ((hod - g0) % 24.0)     # first occurrence of each row
    progress_dep = probe.progress_dep if probe is not None else False
    B_i = B if progress_dep else 1
    if hasattr(sched, "decide_grid"):
        u, b = sched.decide_grid(_grid_ctx(case, carbon_sig, price_sig, sph,
                                           t_abs, B_i))
        return (np.broadcast_to(np.asarray(u, dtype=float), (H, B_i)).copy(),
                np.broadcast_to(np.asarray(b, dtype=float), (H, B_i)).copy())
    make_ctx = _ctx_factory(case, carbon_sig, price_sig)
    u_rows = np.empty((H, B_i))
    b_rows = np.empty((H, B_i))
    for ri in range(H):
        for bi in range(B_i):
            p = (bi + 0.5) / B_i if progress_dep else 0.0
            d = sched.decide(make_ctx(float(t_abs[ri]), p))
            u_rows[ri, bi] = d.intensity
            b_rows[ri, bi] = d.batch_size
    return u_rows, b_rows


def _chunk_table_builder(case, sched, probe: ProbeInfo, carbon_sig,
                         price_sig, sph: int, B: int) -> Callable:
    """builder(t0_slot, C) -> (u, b) of shape (C, B_i) for an
    elapsed-aware schedule: decision rows for global grid slots
    [t0, t0 + C) only — slots already scanned are never re-decided."""
    g0 = _case_g0(case, sph)
    # decide_grid schedules always get the full progress axis: the grid
    # call is vectorized (extra buckets are nearly free) and the probe
    # lattice must not flatten a progress window it happened to miss —
    # only probed decide() schedules, where buckets cost B Python calls
    # per row, use the probe's progress classification
    B_i = B if (probe.progress_dep or hasattr(sched, "decide_grid")) else 1
    if hasattr(sched, "decide_grid"):
        def build_grid(t0_slot: int, C: int):
            t_abs = g0 + (t0_slot + np.arange(C)) / sph
            u, b = sched.decide_grid(_grid_ctx(case, carbon_sig, price_sig,
                                               sph, t_abs, B_i))
            return (np.broadcast_to(np.asarray(u, dtype=float),
                                    (C, B_i)).copy(),
                    np.broadcast_to(np.asarray(b, dtype=float),
                                    (C, B_i)).copy())
        return build_grid

    make_ctx = _ctx_factory(case, carbon_sig, price_sig)

    def build_loop(t0_slot: int, C: int):
        u_rows = np.empty((C, B_i))
        b_rows = np.empty((C, B_i))
        for ri in range(C):
            t = g0 + (t0_slot + ri) / sph
            for bi in range(B_i):
                p = (bi + 0.5) / B_i if probe.progress_dep else 0.0
                d = sched.decide(make_ctx(t, p))
                u_rows[ri, bi] = d.intensity
                b_rows[ri, bi] = d.batch_size
        return u_rows, b_rows

    return build_loop


def _estimate_hours(case, prof, probe: Optional[ProbeInfo],
                    max_hours: float, sph: int = 1) -> float:
    """Campaign-duration estimate (sizes the monolithic scan grid; the
    chunked executor doesn't need it — it just appends chunks).

    Near-exact for periodic progress-free tables (one day's throughput is
    computable up front); conservative — slowest sampled decision — for
    decide()-probed schedules."""
    sched = as_schedule(case.schedule)
    bg_day = _bg_table(case.bands, sph)
    if prof is not None:                 # (24*sph,) day profile
        u_rows, b_rows = prof
        r = model.campaign_rates(np.asarray(u_rows), np.asarray(b_rows),
                                 bg_day, case.workload, case.machine, xp=np)
        day_scen = float(r.scen_per_s.sum()) * 3600.0 / sph
        if day_scen <= 0.0:
            return max_hours
        dur = case.workload.n_scenarios / day_scen * 24.0
        return min(dur * 1.02 + 28.0, max_hours)
    samples = probe.samples
    u = np.array([s[1] for s in samples])
    b = np.array([s[2] for s in samples])
    bg = bg_day[np.floor([(s[0] % 24.0) * sph for s in samples]).astype(int)]
    rs = model.campaign_rates(u, b, bg, case.workload, case.machine,
                              xp=np).scen_per_s
    floor = rs[rs > 0.02 * rs.max()] if rs.size else rs
    if not floor.size:
        return max_hours
    if hasattr(sched, "decide_grid"):
        # vectorized tables are cheap to rebuild, so start from the mean
        # sampled rate (a feedback controller like the deadline keeper
        # mixes its extremes) and let the retry loop double on undershoot
        dur = case.workload.n_scenarios / (float(floor.mean()) * 3600.0)
        return min(dur * 1.25 + 26.0, max_hours)
    dur = case.workload.n_scenarios / (float(floor.min()) * 3600.0)
    return min(dur * 1.15 + 26.0, max_hours)


# ---------------------------------------------------------------------------
# Case compilation: classify once, cache by fingerprint.
# ---------------------------------------------------------------------------
class _CaseCompiled(NamedTuple):
    """Everything expensive about one case, computed exactly once."""
    prof: Optional[Tuple[np.ndarray, np.ndarray]]   # closed-form day profile
    probe: Optional[ProbeInfo]
    table: Optional[Tuple[np.ndarray, np.ndarray]]  # periodic (H, B_i) rows
    periodic: bool        # True: rowidx wraps mod day; False: chunk-built
    carbon_dep: bool      # decisions consult live carbon (ensemble expansion)
    est_h: float          # duration estimate for the monolithic mode
    stalled: bool = False  # provably never finishes (zero day throughput)


def _table_stalled(case, table: Tuple[np.ndarray, np.ndarray],
                   sph: int) -> bool:
    """True when a day-periodic decision table provably never finishes:
    one full day at campaign start (progress-bucket 0) completes a
    negligible fraction of the workload, and the table repeats forever.
    Catches zero-intensity schedules at compile time instead of after a
    scan to max_days."""
    u_rows, b_rows = table
    r = model.campaign_rates(u_rows[:, 0], b_rows[:, 0],
                             _bg_table(case.bands, sph), case.workload,
                             case.machine, xp=np)
    day_scen = float(r.scen_per_s.sum()) * 3600.0 / sph
    return day_scen <= _STALL_FRAC_PER_DAY * case.workload.n_scenarios


_PLAN_CACHE: "OrderedDict[tuple, _CaseCompiled]" = OrderedDict()
_PLAN_CACHE_SIZE = 4096               # entries are ~1 KB (tables + probe)


def _memo_get(key: tuple) -> Optional[_CaseCompiled]:
    """In-memory memo lookup with LRU recency: a hit moves the entry to
    the young end, so hot entries compiled early survive eviction."""
    comp = _PLAN_CACHE.get(key)
    if comp is not None:
        _PLAN_CACHE.move_to_end(key)
    return comp


def _memo_put(key: tuple, comp: _CaseCompiled) -> None:
    """Insert at the young end; when full, evict the oldest quarter (in
    true recency order — `_memo_get` refreshes on hit)."""
    if key in _PLAN_CACHE:
        _PLAN_CACHE.move_to_end(key)
        _PLAN_CACHE[key] = comp
        return
    if len(_PLAN_CACHE) >= _PLAN_CACHE_SIZE:
        for _ in range(max(_PLAN_CACHE_SIZE // 4, 1)):
            if not _PLAN_CACHE:
                break
            _PLAN_CACHE.popitem(last=False)
    _PLAN_CACHE[key] = comp


class _Opaque(Exception):
    """A fingerprint component has no value identity (e.g. a closure)."""


_OPAQUE_FROZEN = object()     # memoized "this component is opaque" marker


def _freeze(obj):
    """Recursively lower a fingerprint component to a hashable value:
    dataclasses by field values, dicts/sequences by sorted/ordered
    tuples, arrays by bytes.  Raises `_Opaque` for anything without a
    value identity — plain class instances hash by identity, which says
    nothing about the *decisions* the object makes (it could mutate, or
    close over mutable state), so such cases are simply compiled fresh.
    Every bundled schedule/signal family is a (frozen) dataclass and
    freezes by value."""
    if obj is None or isinstance(obj, (bool, int, float, str, bytes)):
        return obj
    if isinstance(obj, np.ndarray):
        return ("ndarray", obj.dtype.str, obj.shape, obj.tobytes())
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return (type(obj),) + tuple(_freeze(getattr(obj, f.name))
                                    for f in dataclasses.fields(obj))
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    try:
        hash(obj)
    except TypeError:
        raise _Opaque from None
    if type(obj).__hash__ is object.__hash__:   # identity hash only
        raise _Opaque
    return obj


def _fingerprint(case, price, sph: int, B: int, max_days: int,
                 memo: Optional[dict] = None) -> Optional[tuple]:
    """Hashable value identity of one case's compilation inputs, or None
    when a component is opaque (then the case is compiled fresh).

    `memo` (id -> (obj, frozen)) de-duplicates the freeze of components
    shared across a batch — a 1000-case sweep over one workload/machine/
    trace freezes each shared object once, not 1000 times.  The memo
    keeps the object referenced, so ids cannot be recycled while it
    lives (one compile_plan call).
    """
    def freeze(obj):
        if memo is None:
            return _freeze(obj)
        entry = memo.get(id(obj))
        if entry is None:
            try:
                entry = (obj, _freeze(obj))
            except _Opaque:
                entry = (obj, _OPAQUE_FROZEN)
            memo[id(obj)] = entry
        if entry[1] is _OPAQUE_FROZEN:
            raise _Opaque
        return entry[1]

    try:
        return (freeze(case.schedule), freeze(case.workload),
                freeze(case.machine), freeze(case.bands),
                freeze(case.carbon), case.start_hour, case.deadline_h,
                freeze(price) if price is not None else None,
                sph, B, max_days)
    except _Opaque:
        return None


def clear_plan_cache() -> None:
    """Empty the in-process compile memo and zero every cache counter
    (`plan_hits`/`plan_misses`, the disk `disk_hits`/`disk_misses`, and
    the delta-sweep `lanes_recomputed`/`lanes_spliced`) so hit-rate
    measurements restart clean.  Disk entries are left in place — use
    `plancache.get_cache(dir).clear()` to empty a store."""
    _PLAN_CACHE.clear()
    _STATS.plan_hits = 0
    _STATS.plan_misses = 0
    _STATS.disk_hits = 0
    _STATS.disk_misses = 0
    _STATS.lanes_recomputed = 0
    _STATS.lanes_spliced = 0


def _comp_nbytes(comp: _CaseCompiled) -> int:
    n = 256                               # flags, floats, tuple overhead
    for pair in (comp.prof, comp.table):
        if pair is not None:
            n += int(pair[0].nbytes) + int(pair[1].nbytes)
    if comp.probe is not None:
        n += 24 * len(comp.probe.samples)
    return n


@dataclasses.dataclass(frozen=True)
class PlanCacheInfo:
    """One dashboard row over both plan-cache layers: the in-process
    memo (`mem_*`) and the persistent disk store (`disk_*`, zero when
    caching is off).  `hits`/`misses` aggregate since the last
    `clear_plan_cache()`/`reset_scan_stats()`: a hit is a compile
    avoided by either layer, a miss is an actual `_compile_case` run."""
    mem_entries: int
    mem_bytes: int
    disk_entries: int
    disk_bytes: int
    hits: int
    misses: int

    @property
    def hit_rate(self) -> float:
        """Fraction of case lookups served without compiling (0.0 when
        nothing has been looked up yet)."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


def plan_cache_info(cache_dir: Optional[str] = None) -> PlanCacheInfo:
    """Entries, bytes, and hit rate of the plan cache (memo + disk).

    `cache_dir` resolves like everywhere else (explicit dir, else the
    ``CARINA_PLAN_CACHE`` env default, else no disk layer)."""
    cache = plancache.get_cache(cache_dir)
    disk_entries, disk_bytes = cache.info() if cache is not None else (0, 0)
    return PlanCacheInfo(
        mem_entries=len(_PLAN_CACHE),
        mem_bytes=sum(_comp_nbytes(c) for c in _PLAN_CACHE.values()),
        disk_entries=disk_entries, disk_bytes=disk_bytes,
        hits=_STATS.plan_hits + _STATS.disk_hits,
        misses=_STATS.plan_misses)


def _obtain_case(case, dec_sig, price, sph: int, B: int, max_days: int,
                 max_hours: float, key: Optional[tuple],
                 cache: Optional[plancache.PlanCache]) -> _CaseCompiled:
    """One case's compile artifact through the layered cache: in-memory
    memo, then the disk store, then `_compile_case` (write-through to
    both layers).  Opaque-fingerprint cases (key None) bypass both
    layers entirely — no entry is ever stored for them, so a
    closure-bearing schedule can never poison the cache."""
    comp = _memo_get(key) if key is not None else None
    if comp is not None:
        _STATS.plan_hits += 1
        return comp
    if cache is not None and key is not None:
        comp = cache.get_case(key)
        if comp is not None:
            _STATS.disk_hits += 1
            _memo_put(key, comp)
            return comp
        _STATS.disk_misses += 1
    comp = _compile_case(case, dec_sig, price, sph, B, max_hours)
    _STATS.plan_misses += 1
    if key is not None:
        _memo_put(key, comp)
        if cache is not None:
            cache.put_case(key, comp)
    return comp


def _compile_case(case, dec_sig, price, sph: int, B: int,
                  max_hours: float) -> _CaseCompiled:
    """Classify one case and build whatever table can be built up front.
    `dec_sig` is the carbon signal decisions see (for an ensemble: the
    first member — the probe's carbon_dep flag tells us whether the
    member choice can matter)."""
    from repro.core.engine import periodic_decision_profile
    sched = as_schedule(case.schedule)
    prof = periodic_decision_profile(sched, case.bands, sph)
    if prof is not None:                 # closed-form: never consults ctx
        u_rows, b_rows = prof
        table = (u_rows[:, None].astype(float), b_rows[:, None].astype(float))
        return _CaseCompiled(prof=prof, probe=None, table=table,
                             periodic=True, carbon_dep=False,
                             est_h=_estimate_hours(case, prof, None,
                                                   max_hours, sph),
                             stalled=_table_stalled(case, table, sph))
    probe = _probe(sched, _ctx_factory(case, dec_sig, price),
                   _case_g0(case, sph), max_hours)
    est = _estimate_hours(case, None, probe, max_hours, sph)
    # decide_grid tables are exact per-slot and cheap to rebuild per
    # chunk, so schedules implementing it only get the compact
    # day-periodic lowering when they *declare* hour-of-day-only
    # decisions (`periodic_decisions`, e.g. ParametricSchedule) — the
    # probe lattice alone must not demote a vectorized schedule whose
    # elapsed-dependence it happens to miss.  Plain decide() schedules
    # keep the probe classification (the pre-existing, documented
    # heuristic).
    grid_ok = (not hasattr(sched, "decide_grid")
               or getattr(sched, "periodic_decisions", False))
    if not probe.elapsed_dep and grid_ok:
        table = _day_table(case, sched, probe, dec_sig, price, sph, B)
        return _CaseCompiled(prof=None, probe=probe, table=table,
                             periodic=True, carbon_dep=probe.carbon_dep,
                             est_h=est,
                             stalled=_table_stalled(case, table, sph))
    return _CaseCompiled(prof=None, probe=probe, table=None, periodic=False,
                         carbon_dep=probe.carbon_dep, est_h=est)


@dataclasses.dataclass
class SweepPlan:
    """The compiled form of one trace sweep: everything the chunked scan
    needs, laid out as batched arrays over scan *lanes*.

    A lane is one scan row: normally one case; a carbon-dependent
    schedule under an E-member ensemble expands into E lanes (one per
    member, since each member induces different decisions).  Decision
    tables are either periodic (`lane_table`, rows indexed modulo the
    day) or built chunk-by-chunk (`lane_builder`, for elapsed-aware
    schedules).  `grids` memoizes signal samples per (signal, grid
    offset): each grid slot is sampled exactly once per plan and
    extended incrementally as chunks are appended — never re-sampled
    per retry.
    """
    cases: Tuple
    price: Optional[Signal]
    sph: int
    B: int
    max_days: int
    E: int                                   # ensemble width (1 = none)
    case_ensemble: List[Optional[SignalEnsemble]]   # per case
    case_expanded: List[bool]                # per case: E lanes?
    lane_case: np.ndarray                    # (L,) case index per lane
    lane_member: np.ndarray                  # (L,) member driving decisions
    lane_table: List[Optional[Tuple[np.ndarray, np.ndarray]]]
    lane_builder: List[Optional[Callable]]
    lane_periodic: np.ndarray                # (L,) bool (== has a table)
    tab_u: np.ndarray                        # (L, 24*sph, B_t) stacked tables
    tab_b: np.ndarray                        # (zero/one rows for chunk-built)
    tab_buckets: int                         # B_t: 1, or B with progress lanes
    lane_co2_sigs: List[Tuple[Signal, ...]]  # (E,) carbon signals per lane
    # per-lane physics scalars, all shape (L,)
    n_scen: np.ndarray
    rate: np.ndarray
    oh: np.ndarray
    idle: np.ndarray
    dyn: np.ndarray
    alpha: np.ndarray
    gamma: np.ndarray
    ohfrac: np.ndarray
    start: np.ndarray
    g0: np.ndarray
    s0: np.ndarray
    bg_day: np.ndarray                       # (L, 24*sph)
    est_h: float                             # max over cases
    # fleet (lane-group) layout: adjacent cases of one fleet share a
    # group; a finite per-group cap turns on the site-coupled kernel
    group_sizes: Tuple[int, ...] = ()        # cases per group (sum = n cases)
    case_group: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=int))   # (n cases,)
    lane_group: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=int))   # (L,)
    group_cap_kw: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))  # (G,), inf = uncoupled
    group_office_kw: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))  # (G,) peak office draw
    #: dtype policy of the scan ("fp64" exact, or "mixed": fp32 state
    #: and inputs with fp64 kWh/CO2/cost accumulators)
    precision: str = "fp64"
    grids: Dict[tuple, np.ndarray] = dataclasses.field(default_factory=dict)

    @property
    def n_lanes(self) -> int:
        return len(self.lane_case)

    @property
    def max_slots(self) -> int:
        return int(self.max_days * 24 * self.sph)

    @property
    def coupled(self) -> bool:
        """True when any group has a finite site cap (the scan must run
        the grouped site-coupled kernel)."""
        return bool(np.isfinite(self.group_cap_kw).any())


class _ScanState(NamedTuple):
    """Scan accumulators, carried across chunks."""
    remaining: np.ndarray     # (L,)
    runtime_s: np.ndarray     # (L,)
    kwh: np.ndarray           # (L,)
    co2: np.ndarray           # (L, E)
    cost: np.ndarray          # (L,)
    # site draw peak (kW, office + fleet) seen by each lane's group while
    # the lane was active; None on uncoupled plans (the plain kernels do
    # not track it).  Group peak = max over the group's lanes.
    site_kw_peak: Optional[np.ndarray] = None


@dataclasses.dataclass
class PlanCursor:
    """Resumable position of one plan execution, paused at a chunk
    boundary.

    `state` holds full-length (L,) accumulators — finished lanes keep
    their final values; `t0` is the next global grid slot to scan and
    `active` the lane indices still unfinished.  A cursor is what
    `execute_interval` returns and accepts: the MPC loop executes one
    control interval, re-plans (`replace_tables`), and resumes from the
    same cursor — no already-executed slot is ever recomputed.
    Cursors are immutable in practice: `execute_interval` copies the
    state arrays, so earlier cursors stay valid snapshots.
    """
    state: _ScanState
    t0: int = 0
    active: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=int))

    @property
    def done(self) -> bool:
        """True when every lane has finished its workload."""
        return self.active.size == 0


def new_cursor(plan: SweepPlan) -> PlanCursor:
    """A fresh cursor at slot 0 with every lane active."""
    L = plan.n_lanes
    state = _ScanState(
        plan.n_scen.copy(), np.zeros(L), np.zeros(L),
        np.zeros((L, plan.E)), np.zeros(L),
        np.zeros(L) if plan.coupled else None)
    return PlanCursor(state=state, t0=0, active=np.arange(L))


def compile_plan(cases: Sequence, price: Optional[Signal] = None, *,
                 slots_per_hour: int = 1, progress_buckets: int = 32,
                 max_days: int = 120,
                 group_sizes: Optional[Sequence[int]] = None,
                 group_caps_kw: Optional[Sequence[Optional[float]]] = None,
                 group_office_kw: Optional[Sequence[float]] = None,
                 precision: str = "fp64",
                 cache_dir: Optional[str] = None) -> SweepPlan:
    """Lower a case batch into a `SweepPlan` (the scan's input form).

    Per-case classification (closed-form profile / probe / decide_grid)
    is memoized by case fingerprint across calls, so re-sweeping the
    same cases — or re-evaluating an optimizer's warm-start loop — skips
    the Python probing entirely.  `cache_dir` (default: the
    ``CARINA_PLAN_CACHE`` environment variable; caching off when both
    are unset) adds the persistent layer: compile artifacts are also
    served from / written through to a disk-backed content-addressed
    store (core/plancache.py), so a *fresh process* re-compiling the
    same batch does zero classification/probing/lowering work — one
    whole-batch entry read (accounted as `scan_stats().disk_hits`)
    replaces the S-case compile, bitwise-identically.

    `group_sizes` partitions the case sequence into fleet *groups* of
    adjacent cases (the M campaigns of one fleet case); `group_caps_kw`
    gives each group's site power cap in kW (None/inf = uncoupled) and
    `group_office_kw` its peak office/background draw (scaled by the
    band background over the day).  Groups with a finite cap run the
    site-coupled kernel: per slot, the summed active draw of the group
    is compared to the headroom and every member's intensity is
    curtailed by the shared `model.site_throttle` factor.  With the
    defaults every case is its own uncoupled group and the scan is
    byte-identical to the ungrouped engine.

    `precision` selects the scan's dtype policy on the JAX backend:
    `"fp64"` (default) keeps the exact double-precision behaviour;
    `"mixed"` runs the per-slot dynamics (remaining work, rates,
    elapsed time) in fp32 while the kWh/CO2/cost sums still accumulate
    in fp64 — kWh/CO2 totals stay within ~1e-6 relative of fp64 (pinned
    by tests) at roughly half the memory traffic.  The NumPy backend
    ignores the policy and always runs fp64.
    """
    if precision not in ("fp64", "mixed"):
        raise ValueError(f"unknown precision {precision!r}; "
                         "use 'fp64' or 'mixed'")
    sph = int(slots_per_hour)
    B = int(progress_buckets)
    max_hours = float(max_days) * 24.0
    H = 24 * sph

    # ---- group layout ----------------------------------------------------
    if group_sizes is None:
        group_sizes = (1,) * len(cases)
    group_sizes = tuple(int(g) for g in group_sizes)
    if sum(group_sizes) != len(cases) or any(g < 1 for g in group_sizes):
        raise ValueError(
            f"group_sizes {group_sizes} must be positive and sum to the "
            f"case count ({len(cases)})")
    G = len(group_sizes)
    caps = np.full(G, np.inf)
    if group_caps_kw is not None:
        if len(group_caps_kw) != G:
            raise ValueError(f"group_caps_kw needs one entry per group "
                             f"({G}), got {len(group_caps_kw)}")
        caps = np.array([np.inf if c is None else float(c)
                         for c in group_caps_kw])
        if (caps <= 0.0).any():
            raise ValueError("site caps must be positive kW (or None for "
                             "uncoupled)")
    office = np.zeros(G)
    if group_office_kw is not None:
        if len(group_office_kw) != G:
            raise ValueError(f"group_office_kw needs one entry per group "
                             f"({G}), got {len(group_office_kw)}")
        office = np.array([float(o) for o in group_office_kw])
    case_group = np.repeat(np.arange(G), group_sizes)
    for g in np.flatnonzero(np.isfinite(caps)):
        members = [cases[i] for i in np.flatnonzero(case_group == g)]
        if len({c.start_hour for c in members}) > 1:
            raise ValueError(
                f"coupled group {g} mixes start_hours "
                f"{sorted({c.start_hour for c in members})}: campaigns "
                "under one site cap share the site's clock (their scan "
                "grids must align slot for slot)")
        if len({id(c.bands) for c in members}) > 1 and \
                len({c.bands for c in members}) > 1:
            raise ValueError(
                f"coupled group {g} mixes TimeBands: campaigns under one "
                "site share the site's band structure (the office draw "
                "follows one background curve)")

    ensembles: List[Optional[SignalEnsemble]] = []
    for c in cases:
        ens = c.carbon if isinstance(c.carbon, SignalEnsemble) else None
        ensembles.append(ens)
    sizes = {len(e) for e in ensembles if e is not None}
    if len(sizes) > 1:
        raise ValueError(
            f"all carbon ensembles in one sweep must have the same member "
            f"count; got {sorted(sizes)}")
    E = sizes.pop() if sizes else 1

    # decision-carbon signal per case: ensemble member 0 stands in for
    # the ensemble (carbon_dep probing tells us if the choice matters).
    # Cases on the default grid model share ONE signal object, so the
    # id-keyed signal-grid dedup fires across the whole batch.
    default_sig = carbon_signal(GridCarbonModel())
    dec_sigs = [carbon_signal(ens.member(0)) if ens is not None
                else (carbon_signal(c.carbon) if c.carbon is not None
                      else default_sig)
                for c, ens in zip(cases, ensembles)]

    cache = plancache.get_cache(cache_dir)
    # fresh-process warm starts should skip XLA compiles too, not just
    # plan staging: point jax's persistent compilation cache at a
    # sibling of the plan store ("<root>/xla"; CARINA_JAX_CACHE wins)
    enable_persistent_compilation_cache(
        os.path.join(cache.root, "xla") if cache is not None else None)
    memo: dict = {}
    keys = [_fingerprint(c, price, sph, B, max_days, memo) for c in cases]
    compiled: List[Optional[_CaseCompiled]] = [
        _memo_get(k) if k is not None else None for k in keys]
    _STATS.plan_hits += sum(c is not None for c in compiled)
    missing = [i for i, c in enumerate(compiled) if c is None]
    batch_digest = (cache.batch_digest(keys)
                    if cache is not None and len(cases)
                    and all(k is not None for k in keys) else None)
    batch_missed = False
    if missing and batch_digest is not None:
        # whole-batch warm start: one entry read replaces up to S
        # per-case reads (the common recurrence shape — the same batch,
        # verbatim, next cycle in a fresh process)
        batch = cache.get_batch(batch_digest, len(cases))
        if batch is not None:
            for i in missing:
                compiled[i] = batch[i]
                _memo_put(keys[i], batch[i])
            _STATS.disk_hits += len(missing)
            missing = []
        else:
            batch_missed = True
    for i in missing:
        compiled[i] = _obtain_case(cases[i], dec_sigs[i], price, sph, B,
                                   max_days, max_hours, keys[i], cache)
    if batch_missed:
        cache.put_batch(batch_digest, compiled)
    for c, comp in zip(cases, compiled):
        if comp.stalled:
            raise RuntimeError(
                f"case {c.name()!r} can never finish on the trace grid: one "
                f"full day of its schedule completes a negligible fraction "
                f"of {c.workload.n_scenarios:.0f} scenarios and the "
                "decision table is day-periodic — the schedule is stalled "
                "at zero intensity")

    # ---- lane layout -----------------------------------------------------
    lane_case: List[int] = []
    lane_member: List[int] = []
    lane_table: List[Optional[Tuple[np.ndarray, np.ndarray]]] = []
    lane_builder: List[Optional[Callable]] = []
    lane_periodic: List[bool] = []
    lane_co2: List[Tuple[Signal, ...]] = []
    case_expanded: List[bool] = []
    lane_group: List[int] = []
    for i, (c, comp, ens) in enumerate(zip(cases, compiled, ensembles)):
        sched = as_schedule(c.schedule)
        expand = ens is not None and comp.carbon_dep
        if expand and np.isfinite(caps[case_group[i]]):
            raise ValueError(
                f"case {c.name()!r}: a carbon-consulting schedule under a "
                "carbon ensemble expands into per-member lanes, which "
                "cannot share a site cap (each member lane is an "
                "alternative scenario, not a concurrent campaign) — use a "
                "carbon-blind schedule, a single trace, or drop the cap")
        case_expanded.append(expand)
        members = range(E) if expand else (0,)
        for e in members:
            lane_case.append(i)
            lane_group.append(int(case_group[i]))
            lane_member.append(e)
            if expand:
                # per-member decisions: rebuild the table (or builder)
                # against member e's carbon signal
                sig_e = carbon_signal(ens.member(e))
                if comp.periodic:
                    lane_table.append(
                        comp.table if comp.prof is not None else
                        _day_table(c, sched, comp.probe, sig_e, price,
                                   sph, B))
                    lane_builder.append(None)
                else:
                    lane_table.append(None)
                    lane_builder.append(_chunk_table_builder(
                        c, sched, comp.probe, sig_e, price, sph, B))
                # the member's own trace carbonizes every ensemble column
                # (summarize reads the diagonal lane e / member e)
                lane_co2.append(tuple(carbon_signal(ens.member(e))
                                      for _ in range(E)))
            else:
                if comp.periodic:
                    lane_table.append(comp.table)
                    lane_builder.append(None)
                else:
                    lane_table.append(None)
                    lane_builder.append(_chunk_table_builder(
                        c, sched, comp.probe, dec_sigs[i], price, sph, B))
                if ens is not None:
                    lane_co2.append(tuple(carbon_signal(ens.member(e2))
                                          for e2 in range(E)))
                else:
                    lane_co2.append(tuple(dec_sigs[i] for _ in range(E)))
            lane_periodic.append(comp.periodic)

    lc = np.asarray(lane_case, dtype=int)
    wl = [cases[i].workload for i in lane_case]
    mach = [cases[i].machine for i in lane_case]
    start = np.array([cases[i].start_hour for i in lane_case], dtype=float)
    g0 = np.floor(start * sph) / sph
    # periodic decision tables, stacked once so the per-chunk assembly is
    # one fancy-index slice instead of a per-lane Python loop
    L = len(lane_case)
    B_t = max((t[0].shape[1] for t in lane_table if t is not None),
              default=1)
    tab_u = np.zeros((L, H, B_t))
    tab_b = np.ones((L, H, B_t))
    for lane, t in enumerate(lane_table):
        if t is not None:
            u_r, b_r = t
            tab_u[lane] = u_r if u_r.shape[1] == B_t \
                else np.broadcast_to(u_r, (H, B_t))
            tab_b[lane] = b_r if b_r.shape[1] == B_t \
                else np.broadcast_to(b_r, (H, B_t))
    return SweepPlan(
        cases=tuple(cases), price=price, sph=sph, B=B, max_days=int(max_days),
        E=E, case_ensemble=ensembles, case_expanded=case_expanded,
        lane_case=lc, lane_member=np.asarray(lane_member, dtype=int),
        lane_table=lane_table, lane_builder=lane_builder,
        lane_periodic=np.asarray(lane_periodic, dtype=bool),
        tab_u=tab_u, tab_b=tab_b, tab_buckets=B_t,
        lane_co2_sigs=lane_co2,
        n_scen=np.array([float(w.n_scenarios) for w in wl]),
        rate=np.array([w.rate_at_full for w in wl]),
        oh=np.array([w.batch_overhead_s for w in wl]),
        idle=np.array([m.idle_w for m in mach]),
        dyn=np.array([m.dyn_w for m in mach]),
        alpha=np.array([m.alpha for m in mach]),
        gamma=np.array([m.gamma for m in mach]),
        ohfrac=np.array([m.overhead_w_frac for m in mach]),
        start=start, g0=g0,
        s0=np.round(g0 * sph).astype(int) % H,
        bg_day=np.stack([_bg_table(cases[i].bands, sph)
                         for i in lane_case]),
        est_h=max(comp.est_h for comp in compiled),
        precision=precision,
        group_sizes=group_sizes, case_group=case_group,
        lane_group=np.asarray(lane_group, dtype=int),
        group_cap_kw=caps, group_office_kw=office)


def _normalize_replace_maps(plan: SweepPlan, schedules, carbon
                            ) -> Tuple[Dict[int, object], Dict[int, object]]:
    """Normalize `replace_tables`/`delta_sweep` deltas to index maps:
    `schedules` may be a mapping {case index -> schedule}, a per-case
    sequence (None = keep), or — for 1-case plans — a bare schedule;
    `carbon` a mapping {case index -> signal}, one signal applied to
    every case, or a per-case sequence."""
    n = len(plan.cases)
    sched_map: Dict[int, object] = {}
    if schedules is not None:
        if hasattr(schedules, "items"):
            sched_map = {int(i): s for i, s in schedules.items()}
        elif callable(getattr(schedules, "decide", None)) or \
                callable(getattr(schedules, "decide_grid", None)):
            if n != 1:
                raise ValueError(
                    f"a bare schedule is ambiguous for a {n}-case plan; "
                    "pass a mapping {case index: schedule} or a per-case "
                    "sequence")
            sched_map = {0: schedules}
        else:
            seq = list(schedules)
            if len(seq) != n:
                raise ValueError(
                    f"schedules sequence needs one entry per case ({n}), "
                    f"got {len(seq)}")
            sched_map = {i: s for i, s in enumerate(seq) if s is not None}
    carbon_map: Dict[int, object] = {}
    if carbon is not None:
        if hasattr(carbon, "items") and not callable(
                getattr(carbon, "at", None)):
            carbon_map = {int(i): c for i, c in carbon.items()}
        elif isinstance(carbon, (list, tuple)) and not callable(
                getattr(carbon, "at", None)):
            if len(carbon) != n:
                raise ValueError(
                    f"carbon sequence needs one entry per case ({n}), "
                    f"got {len(carbon)}")
            carbon_map = {i: c for i, c in enumerate(carbon)
                          if c is not None}
        else:
            carbon_map = {i: carbon for i in range(n)}
    for i in list(sched_map) + list(carbon_map):
        if not 0 <= i < n:
            raise ValueError(f"case index {i} out of range for a "
                             f"{n}-case plan")
    return sched_map, carbon_map


def replace_tables(plan: SweepPlan, cursor: Optional[PlanCursor] = None, *,
                   schedules=None, carbon=None,
                   cache_dir: Optional[str] = None) -> SweepPlan:
    """Swap decision tables and/or carbon signals on an in-flight plan.

    The MPC re-plan primitive: given a plan paused at `cursor`, return a
    new `SweepPlan` whose changed cases carry fresh decision tables (and
    optionally new carbon signals) while every *unchanged* lane keeps its
    compiled tables, builders, and incrementally-sampled signal grids —
    nothing already classified, lowered, or executed is redone.  Resume
    with `execute_interval(new_plan, cursor)`: the carried state is valid
    because the lane layout is preserved (enforced below).

    `schedules` is a mapping {case index -> schedule} or a sequence with
    one entry per case (None = keep); `carbon` is one signal applied to
    every changed-carbon case or a per-case sequence (None = keep).  A
    case's ensemble width and lane expansion must not change — an
    in-flight lane is a scan row with carried state and cannot be split
    or merged mid-campaign.

    Changed cases are re-classified through the layered plan cache
    (`plan_hits`/`plan_misses`/`disk_hits` account it; `cache_dir`
    resolves like `compile_plan`'s); `scan_stats().replans` counts
    each call and `slots_reused` accumulates `cursor.t0 * n_lanes` — the
    lane x slot units of executed state carried forward instead of
    recomputed.
    """
    sched_map, carbon_map = _normalize_replace_maps(plan, schedules, carbon)
    changed = sorted(set(sched_map) | set(carbon_map))
    _STATS.replans += 1
    if cursor is not None:
        if len(cursor.state.remaining) != plan.n_lanes:
            raise ValueError(
                f"cursor carries {len(cursor.state.remaining)} lanes but "
                f"the plan has {plan.n_lanes}")
        _STATS.slots_reused += int(cursor.t0) * plan.n_lanes
    if not changed:
        return plan

    H = 24 * plan.sph
    max_hours = float(plan.max_days) * 24.0
    new_cases = list(plan.cases)
    ensembles = list(plan.case_ensemble)
    lane_table = list(plan.lane_table)
    lane_builder = list(plan.lane_builder)
    lane_periodic = plan.lane_periodic.copy()
    lane_co2 = list(plan.lane_co2_sigs)
    est_h = plan.est_h
    cache = plancache.get_cache(cache_dir)
    memo: dict = {}
    for i in changed:
        case = plan.cases[i]
        lanes = np.flatnonzero(plan.lane_case == i)
        new_carb = carbon_map.get(i, case.carbon)
        if i in carbon_map:
            ens_new = (new_carb if isinstance(new_carb, SignalEnsemble)
                       else None)
            old_e = len(ensembles[i]) if ensembles[i] is not None else 1
            new_e = len(ens_new) if ens_new is not None else 1
            if (ens_new is None) != (ensembles[i] is None) or old_e != new_e:
                raise ValueError(
                    f"case {case.name()!r}: replacing a "
                    f"{old_e}-member carbon with a {new_e}-member one "
                    "would change the plan's lane/ensemble layout; "
                    "re-plans must keep the ensemble width")
            ensembles[i] = ens_new
        ens = ensembles[i]
        new_case = dataclasses.replace(
            case, schedule=sched_map.get(i, case.schedule), carbon=new_carb)
        new_cases[i] = new_case
        sched = as_schedule(new_case.schedule)
        if ens is not None:
            dec_sig = carbon_signal(ens.member(0))
        elif new_case.carbon is not None:
            dec_sig = carbon_signal(new_case.carbon)
        else:
            # default-grid case: keep the plan's existing shared signal
            dec_sig = lane_co2[int(lanes[0])][0]
        key = _fingerprint(new_case, plan.price, plan.sph, plan.B,
                           plan.max_days, memo)
        comp = _obtain_case(new_case, dec_sig, plan.price, plan.sph,
                            plan.B, plan.max_days, max_hours, key, cache)
        if comp.stalled:
            raise RuntimeError(
                f"case {new_case.name()!r}: the replacement schedule is "
                "stalled at zero intensity (one full day completes a "
                "negligible fraction of the workload)")
        expand = ens is not None and comp.carbon_dep
        if expand != plan.case_expanded[i]:
            raise ValueError(
                f"case {new_case.name()!r}: the replacement schedule "
                f"{'consults' if expand else 'ignores'} the carbon signal "
                "under an ensemble, which would "
                f"{'expand' if expand else 'collapse'} its lanes; "
                "re-plans must keep the lane layout")
        est_h = max(est_h, comp.est_h)
        for lane in lanes:
            lane = int(lane)
            e = int(plan.lane_member[lane])
            if expand:
                sig_e = carbon_signal(ens.member(e))
                if comp.periodic:
                    lane_table[lane] = (
                        comp.table if comp.prof is not None else
                        _day_table(new_case, sched, comp.probe, sig_e,
                                   plan.price, plan.sph, plan.B))
                    lane_builder[lane] = None
                else:
                    lane_table[lane] = None
                    lane_builder[lane] = _chunk_table_builder(
                        new_case, sched, comp.probe, sig_e, plan.price,
                        plan.sph, plan.B)
                lane_co2[lane] = tuple(carbon_signal(ens.member(e))
                                       for _ in range(plan.E))
            else:
                if comp.periodic:
                    lane_table[lane] = comp.table
                    lane_builder[lane] = None
                else:
                    lane_table[lane] = None
                    lane_builder[lane] = _chunk_table_builder(
                        new_case, sched, comp.probe, dec_sig, plan.price,
                        plan.sph, plan.B)
                if ens is not None:
                    lane_co2[lane] = tuple(carbon_signal(ens.member(e2))
                                           for e2 in range(plan.E))
                else:
                    lane_co2[lane] = tuple(dec_sig
                                           for _ in range(plan.E))
            lane_periodic[lane] = comp.periodic

    # restack the periodic tables (cheap NumPy; no classification)
    L = plan.n_lanes
    B_t = max((t[0].shape[1] for t in lane_table if t is not None),
              default=1)
    tab_u = np.zeros((L, H, B_t))
    tab_b = np.ones((L, H, B_t))
    for lane, t in enumerate(lane_table):
        if t is not None:
            u_r, b_r = t
            tab_u[lane] = u_r if u_r.shape[1] == B_t \
                else np.broadcast_to(u_r, (H, B_t))
            tab_b[lane] = b_r if b_r.shape[1] == B_t \
                else np.broadcast_to(b_r, (H, B_t))
    # grids dict is shared by reference: unchanged signals keep their
    # incrementally-sampled prefixes, so resuming re-samples nothing
    return dataclasses.replace(
        plan, cases=tuple(new_cases), case_ensemble=ensembles,
        lane_table=lane_table, lane_builder=lane_builder,
        lane_periodic=lane_periodic, tab_u=tab_u, tab_b=tab_b,
        tab_buckets=B_t, lane_co2_sigs=lane_co2, est_h=est_h)


def _value_changed(old, new) -> bool:
    """True unless `new` provably carries the same value identity as
    `old` (same object, or equal `_freeze` fingerprints).  Opaque
    components (closures) are always treated as changed — correctness
    over splicing."""
    if old is new:
        return False
    try:
        return _freeze(old) != _freeze(new)
    except _Opaque:
        return True


def _subset_plan(plan: SweepPlan, case_idx: Sequence[int]) -> SweepPlan:
    """A `SweepPlan` over a case subset, sliced — not recompiled — from
    `plan`: tables, builders, physics scalars, and the incrementally
    sampled signal `grids` (shared by reference) all carry over, so
    building the subset does zero classification or lowering work.
    Coupled groups must be included whole (their lanes interact through
    the site cap every slot); per-lane scan results are unchanged by
    the subsetting, exactly as with finished-lane compaction."""
    idx = np.asarray(sorted(int(i) for i in case_idx), dtype=int)
    keep = np.zeros(len(plan.cases), dtype=bool)
    keep[idx] = True
    for g in sorted({int(plan.case_group[i]) for i in idx}):
        if np.isfinite(plan.group_cap_kw[g]):
            members = np.flatnonzero(plan.case_group == g)
            if not keep[members].all():
                raise ValueError(
                    f"coupled group {g} must be subset whole: its lanes "
                    "share the site cap every slot")
    case_pos = {int(i): j for j, i in enumerate(idx)}
    lanes = np.flatnonzero(np.isin(plan.lane_case, idx))
    old_groups = sorted({int(plan.case_group[i]) for i in idx})
    gmap = {g: k for k, g in enumerate(old_groups)}
    ga = np.asarray(old_groups, dtype=int)
    return dataclasses.replace(
        plan,
        cases=tuple(plan.cases[i] for i in idx),
        case_ensemble=[plan.case_ensemble[i] for i in idx],
        case_expanded=[plan.case_expanded[i] for i in idx],
        lane_case=np.array([case_pos[int(c)]
                            for c in plan.lane_case[lanes]], dtype=int),
        lane_member=plan.lane_member[lanes],
        lane_table=[plan.lane_table[int(ln)] for ln in lanes],
        lane_builder=[plan.lane_builder[int(ln)] for ln in lanes],
        lane_periodic=plan.lane_periodic[lanes],
        tab_u=plan.tab_u[lanes], tab_b=plan.tab_b[lanes],
        lane_co2_sigs=[plan.lane_co2_sigs[int(ln)] for ln in lanes],
        n_scen=plan.n_scen[lanes], rate=plan.rate[lanes],
        oh=plan.oh[lanes], idle=plan.idle[lanes], dyn=plan.dyn[lanes],
        alpha=plan.alpha[lanes], gamma=plan.gamma[lanes],
        ohfrac=plan.ohfrac[lanes], start=plan.start[lanes],
        g0=plan.g0[lanes], s0=plan.s0[lanes], bg_day=plan.bg_day[lanes],
        group_sizes=tuple(
            int(np.isin(np.flatnonzero(plan.case_group == g), idx).sum())
            for g in old_groups),
        case_group=np.array([gmap[int(plan.case_group[i])] for i in idx],
                            dtype=int),
        lane_group=np.array([gmap[int(g)] for g in plan.lane_group[lanes]],
                            dtype=int),
        group_cap_kw=plan.group_cap_kw[ga],
        group_office_kw=plan.group_office_kw[ga],
        grids=plan.grids)


@dataclasses.dataclass
class DeltaSweepResult:
    """One incremental re-sweep: per-case `SimResult`s for the whole
    batch (`results`, order preserved), the updated plan to delta
    against next cycle (`plan`), and the case-index partition into
    re-scanned (`recomputed`) vs prev-result-spliced (`spliced`)."""
    results: List[SimResult]
    plan: SweepPlan
    recomputed: Tuple[int, ...]
    spliced: Tuple[int, ...]


def delta_sweep(prev_plan: SweepPlan, prev_results: Sequence[SimResult], *,
                schedules=None, carbon=None,
                backend: Optional[str] = None,
                chunk_days: Optional[int] = None,
                devices: Optional[int] = None, pallas=None,
                cache_dir: Optional[str] = None) -> DeltaSweepResult:
    """Re-sweep a recurring batch incrementally: re-scan only the cases
    a delta actually affects and splice last cycle's `SimResult`s for
    the rest.

    The recurrence primitive: given last cycle's compiled plan and its
    results, plus this cycle's delta — `schedules` (mapping {case index
    -> schedule} or per-case sequence, None = keep) and/or `carbon`
    (one signal for every case or a per-case sequence) — return the
    full result list as if the whole batch had been re-swept.  Deltas
    are screened by value: a "changed" schedule or carbon signal that
    fingerprints identically to the incumbent is a no-op (its lanes are
    spliced, not re-scanned).  Changed cases re-lower through
    `replace_tables` — the ensemble width and lane expansion of every
    case must be preserved, exactly as for an in-flight re-plan — and
    re-execute from slot 0 as a fresh cycle on a sliced subplan;
    results for them are bitwise-identical to a full re-sweep (lanes
    do not interact across groups, so subsetting is equivalent to the
    executor's finished-lane compaction).  A changed case inside a
    site-capped fleet group drags its whole group into the re-scan
    (coupled lanes share the cap every slot — splicing a member of a
    changed group would be wrong, not just stale).

    `scan_stats().lanes_recomputed`/`lanes_spliced` account the lane
    partition; with K changed schedules out of S the re-scanned slot
    work is ~K/S of a full re-sweep.  `cache_dir` resolves like
    `compile_plan`'s.  Note a changed *carbon* signal affects every
    case it applies to even under carbon-blind schedules — the CO2
    integral runs over the realized trace — so a new carbon window
    re-scans all of its cases; the savings there come from the plan
    cache (tables and classification are reused), not from splicing.
    """
    prev_results = list(prev_results)
    n = len(prev_plan.cases)
    if len(prev_results) != n:
        raise ValueError(
            f"prev_results carries {len(prev_results)} results but the "
            f"plan has {n} cases — pass last cycle's full result list")
    sched_map, carbon_map = _normalize_replace_maps(prev_plan, schedules,
                                                    carbon)
    sched_map = {i: s for i, s in sched_map.items()
                 if _value_changed(prev_plan.cases[i].schedule, s)}
    carbon_map = {i: c for i, c in carbon_map.items()
                  if _value_changed(prev_plan.cases[i].carbon, c)}
    new_plan = replace_tables(prev_plan, None,
                              schedules=sched_map or None,
                              carbon=carbon_map or None,
                              cache_dir=cache_dir)
    affected = set(sched_map) | set(carbon_map)
    # lane-group revalidation: a changed member of a site-capped group
    # invalidates the whole group's scan, not just its own lane
    for g in sorted({int(new_plan.case_group[i]) for i in affected}):
        if np.isfinite(new_plan.group_cap_kw[g]):
            affected.update(
                int(i) for i in np.flatnonzero(new_plan.case_group == g))
    if not affected:
        _STATS.lanes_spliced += new_plan.n_lanes
        return DeltaSweepResult(results=prev_results, plan=new_plan,
                                recomputed=(), spliced=tuple(range(n)))
    sub = sorted(affected)
    subplan = _subset_plan(new_plan, sub)
    _STATS.lanes_recomputed += subplan.n_lanes
    _STATS.lanes_spliced += new_plan.n_lanes - subplan.n_lanes
    state = execute_plan(subplan, backend=backend, chunk_days=chunk_days,
                         devices=devices, pallas=pallas)
    sub_results = summarize_plan(subplan, state)
    results = prev_results
    for j, i in enumerate(sub):
        results[i] = sub_results[j]
    return DeltaSweepResult(
        results=results, plan=new_plan, recomputed=tuple(sub),
        spliced=tuple(i for i in range(n) if i not in affected))


# ---------------------------------------------------------------------------
# Incremental signal grids: every grid slot of every (signal, offset)
# pair is sampled exactly once per plan; appended chunks only sample the
# new tail (the old engine re-sampled every signal per case per retry).
# ---------------------------------------------------------------------------
def _sig_slice(plan: SweepPlan, sig, g0: float, t0: int,
               C: int) -> np.ndarray:
    key = (id(sig), float(g0))
    vals = plan.grids.get(key)
    have = 0 if vals is None else len(vals)
    if have < t0 + C:
        t_abs = g0 + np.arange(have, t0 + C) / plan.sph
        tail = sample_signal(sig, t_abs)
        vals = tail if vals is None else np.concatenate([vals, tail])
        plan.grids[key] = vals
    return vals[t0:t0 + C]


# ---------------------------------------------------------------------------
# The scan kernels.  State: (remaining, runtime_s, kwh, co2[(L, E)],
# cost); per-slot inputs: decision-table row index, background, carbon
# factors (one per ensemble member), price, slot length.
# ---------------------------------------------------------------------------
def _bucket_lookup(xp, u_tab, b_tab, sidx, row, prog, B):
    """Decision at live progress: linear interpolation between the two
    nearest bucket centers (tables are sampled at centers (b+0.5)/B), so
    smooth progress-aware schedules see no quantization bias."""
    if B == 1:
        return u_tab[sidx, row, 0], b_tab[sidx, row, 0]
    x = prog * B - 0.5
    b0 = xp.clip(xp.floor(x), 0, B - 2).astype("int32")
    w = xp.clip(x - b0, 0.0, 1.0)
    u = (1.0 - w) * u_tab[sidx, row, b0] + w * u_tab[sidx, row, b0 + 1]
    bt = (1.0 - w) * b_tab[sidx, row, b0] + w * b_tab[sidx, row, b0 + 1]
    return u, bt


def _scan_chunk_np(u_tab, b_tab, rowidx, bg, cf, pr, lens, state, scalars,
                   B: int) -> tuple:
    """One chunk on the NumPy backend: identical arithmetic to the jitted
    kernel, vectorized across lanes, looped over slots."""
    remaining, rt, kwh, co2, cost = (a.copy() for a in state)
    (n_scen, rate, oh, idle, dyn, alpha, gamma, ohfrac) = scalars
    A, C = rowidx.shape
    sidx = np.arange(A)
    steps = 0
    for t in range(C):
        if not (remaining > 0.0).any():
            break
        steps += 1
        prog = 1.0 - remaining / n_scen
        u, bt = _bucket_lookup(np, u_tab, b_tab, sidx, rowidx[:, t], prog, B)
        r = model.rates(u, bt, bg[:, t], rate_at_full=rate,
                        batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                        alpha=alpha, gamma=gamma, overhead_w_frac=ohfrac,
                        xp=np)
        dt = np.where(
            remaining > 0.0,
            np.minimum(lens[:, t],
                       remaining / np.maximum(r.scen_per_s, 1e-30)),
            0.0)
        e = r.kwh_per_s * dt
        remaining = remaining - r.scen_per_s * dt
        rt = rt + dt
        kwh = kwh + e
        co2 = co2 + e[:, None] * cf[:, :, t]
        cost = cost + e * pr[:, t]
    _STATS.slot_work += A * steps
    return remaining, rt, kwh, co2, cost


def _scan_chunk_np_coupled(u_tab, b_tab, rowidx, bg, cf, pr, lens,
                           gid, cap_g, office, state, scalars,
                           B: int) -> tuple:
    """Site-coupled chunk on the NumPy backend: per slot, each group's
    summed active draw is compared to its headroom (cap minus office)
    and every member's intensity is curtailed by the one shared
    `model.site_throttle` factor before the physics is re-evaluated —
    identical arithmetic to the jitted coupled kernel."""
    remaining, rt, kwh, co2, cost, speak = (a.copy() for a in state)
    (n_scen, rate, oh, idle, dyn, alpha, gamma, ohfrac) = scalars
    A, C = rowidx.shape
    G = len(cap_g)
    sidx = np.arange(A)
    steps = 0
    for t in range(C):
        if not (remaining > 0.0).any():
            break
        steps += 1
        prog = 1.0 - remaining / n_scen
        u, bt = _bucket_lookup(np, u_tab, b_tab, sidx, rowidx[:, t], prog, B)
        r = model.rates(u, bt, bg[:, t], rate_at_full=rate,
                        batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                        alpha=alpha, gamma=gamma, overhead_w_frac=ohfrac,
                        xp=np)
        active = remaining > _FINISH_FRAC * n_scen
        base_lane = np.where(
            active, model.power_w(bg[:, t], idle, dyn, alpha, xp=np),
            0.0) / 1000.0
        base = np.bincount(gid, weights=base_lane, minlength=G)
        head = cap_g - office[:, t]
        f = np.ones(G)
        r2 = r
        for _ in range(model.SITE_THROTTLE_ITERS):
            draw = np.bincount(
                gid, weights=np.where(active, r2.p_avg_w, 0.0) / 1000.0,
                minlength=G)
            f = model.site_throttle(draw, base, head, f, xp=np)
            r2 = model.rates(u * f[gid], bt, bg[:, t], rate_at_full=rate,
                             batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                             alpha=alpha, gamma=gamma,
                             overhead_w_frac=ohfrac, xp=np)
        dt = np.where(
            remaining > 0.0,
            np.minimum(lens[:, t],
                       remaining / np.maximum(r2.scen_per_s, 1e-30)),
            0.0)
        e = r2.kwh_per_s * dt
        site_kw = np.bincount(
            gid, weights=np.where(active, r2.p_avg_w, 0.0) / 1000.0,
            minlength=G) + office[:, t]
        speak = np.where(active, np.maximum(speak, site_kw[gid]), speak)
        remaining = remaining - r2.scen_per_s * dt
        rt = rt + dt
        kwh = kwh + e
        co2 = co2 + e[:, None] * cf[:, :, t]
        cost = cost + e * pr[:, t]
    _STATS.slot_work += A * steps
    return remaining, rt, kwh, co2, cost, speak


if _HAS_JAX:
    def _scan_chunk_jax_impl(u_tab, b_tab, rowidx, bg, cf, pr, lens,
                             remaining, rt, kwh, co2, cost,
                             n_scen, rate, oh, idle, dyn, alpha, gamma,
                             ohfrac, B: int):
        A = u_tab.shape[0]
        sidx = jnp.arange(A)

        def step(carry, xs):
            remaining, rt, kwh, co2, cost = carry
            row, bg_t, cf_t, pr_t, ln = xs          # cf_t: (A, E)
            # mixed precision: the lookup/rates run at the tables' dtype
            # while the carried state stays fp64 (no-op cast on fp64)
            prog = (1.0 - remaining / n_scen).astype(u_tab.dtype)
            u, bt = _bucket_lookup(jnp, u_tab, b_tab, sidx, row, prog, B)
            r = model.rates(u, bt, bg_t, rate_at_full=rate,
                            batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                            alpha=alpha, gamma=gamma, overhead_w_frac=ohfrac,
                            xp=jnp)
            dt = jnp.where(
                remaining > 0.0,
                jnp.minimum(ln, remaining / jnp.maximum(r.scen_per_s, 1e-30)),
                0.0)
            e = r.kwh_per_s * dt
            carry = (remaining - r.scen_per_s * dt, rt + dt, kwh + e,
                     co2 + e[:, None] * cf_t, cost + e * pr_t)
            return carry, None

        init = (remaining, rt, kwh, co2, cost)
        xs = (rowidx.T, bg.T, cf.transpose(2, 0, 1), pr.T, lens.T)
        final, _ = jax.lax.scan(step, init, xs)
        return final

    _scan_chunk_jax = functools.partial(
        jax.jit, static_argnames=("B",))(_scan_chunk_jax_impl)

    def _scan_chunk_jax_coupled_impl(u_tab, b_tab, rowidx, bg, cf, pr,
                                     lens, gid, cap_g, office,
                                     remaining, rt, kwh, co2, cost, speak,
                                     n_scen, rate, oh, idle, dyn, alpha,
                                     gamma, ohfrac, B: int, G: int):
        A = u_tab.shape[0]
        sidx = jnp.arange(A)

        def step(carry, xs):
            remaining, rt, kwh, co2, cost, speak = carry
            row, bg_t, cf_t, pr_t, ln, off_t = xs      # off_t: (G,)
            prog = (1.0 - remaining / n_scen).astype(u_tab.dtype)
            u, bt = _bucket_lookup(jnp, u_tab, b_tab, sidx, row, prog, B)
            r = model.rates(u, bt, bg_t, rate_at_full=rate,
                            batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                            alpha=alpha, gamma=gamma, overhead_w_frac=ohfrac,
                            xp=jnp)
            active = remaining > _FINISH_FRAC * n_scen
            base_lane = jnp.where(
                active, model.power_w(bg_t, idle, dyn, alpha, xp=jnp),
                0.0) / 1000.0
            base = jnp.zeros(G, base_lane.dtype).at[gid].add(base_lane)
            head = cap_g - off_t
            f = jnp.ones(G, base_lane.dtype)
            r2 = r
            for _ in range(model.SITE_THROTTLE_ITERS):
                draw = jnp.zeros(G, base_lane.dtype).at[gid].add(
                    jnp.where(active, r2.p_avg_w, 0.0) / 1000.0)
                f = model.site_throttle(draw, base, head, f, xp=jnp)
                r2 = model.rates(u * f[gid], bt, bg_t, rate_at_full=rate,
                                 batch_overhead_s=oh, idle_w=idle,
                                 dyn_w=dyn, alpha=alpha, gamma=gamma,
                                 overhead_w_frac=ohfrac, xp=jnp)
            dt = jnp.where(
                remaining > 0.0,
                jnp.minimum(ln,
                            remaining / jnp.maximum(r2.scen_per_s, 1e-30)),
                0.0)
            e = r2.kwh_per_s * dt
            site_kw = jnp.zeros(G, base_lane.dtype).at[gid].add(
                jnp.where(active, r2.p_avg_w, 0.0) / 1000.0) + off_t
            speak = jnp.where(active, jnp.maximum(speak, site_kw[gid]),
                              speak)
            carry = (remaining - r2.scen_per_s * dt, rt + dt, kwh + e,
                     co2 + e[:, None] * cf_t, cost + e * pr_t, speak)
            return carry, None

        init = (remaining, rt, kwh, co2, cost, speak)
        xs = (rowidx.T, bg.T, cf.transpose(2, 0, 1), pr.T, lens.T, office.T)
        final, _ = jax.lax.scan(step, init, xs)
        return final

    _scan_chunk_jax_coupled = functools.partial(
        jax.jit, static_argnames=("B", "G"))(_scan_chunk_jax_coupled_impl)

    @functools.lru_cache(maxsize=64)
    def _sharded_plain(n_dev: int, B: int):
        """Jitted `shard_map` wrapper of the plain chunk kernel: every
        argument (and every output) is a lane-leading array split along
        the mesh's "lanes" axis, so the scan runs embarrassingly
        parallel — zero cross-device communication, and each lane's
        arithmetic is bitwise-identical to the single-device kernel."""
        from jax.sharding import Mesh, PartitionSpec

        from repro.compat import shard_map
        mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("lanes",))
        spec = PartitionSpec("lanes")
        fn = shard_map(functools.partial(_scan_chunk_jax_impl, B=B),
                       mesh=mesh, in_specs=(spec,) * 20,
                       out_specs=(spec,) * 5, check_vma=False)
        return jax.jit(fn)

    @functools.lru_cache(maxsize=64)
    def _sharded_coupled(n_dev: int, B: int, G: int):
        """Jitted `shard_map` wrapper of the coupled chunk kernel.  The
        caller partitions lanes at *group* boundaries (groups are
        contiguous in lane order) and stacks per-device blocks, so each
        device's segment-sum sees only its own G=`G` local groups and
        the site-cap fixed point never crosses a shard."""
        from jax.sharding import Mesh, PartitionSpec

        from repro.compat import shard_map
        mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("lanes",))
        spec = PartitionSpec("lanes")
        fn = shard_map(functools.partial(_scan_chunk_jax_coupled_impl,
                                         B=B, G=G),
                       mesh=mesh, in_specs=(spec,) * 24,
                       out_specs=(spec,) * 6, check_vma=False)
        return jax.jit(fn)


def _pad_pow2(n: int, minimum: int = 8) -> int:
    return max(minimum, 1 << max(n - 1, 0).bit_length())


def _pad_lanes(n: int, n_dev: int = 1) -> int:
    """Padded lane count: `n_dev` equal device blocks, each a pow2
    bucket.  For `n_dev == 1` this is exactly the historic
    `_pad_pow2(n)`; for power-of-two fan-outs it still equals the
    single-device padding whenever that padding is divisible, so
    slot-work accounting (and shape-bucket counts) match across device
    counts."""
    per = -(-n // n_dev)
    return n_dev * _pad_pow2(per, minimum=max(8 // n_dev, 1))


def _plan_dtypes(plan: SweepPlan):
    """(compute, accumulator) dtypes of the plan's precision policy.

    The compute dtype covers the per-slot physics inputs (decision
    tables, grid/carbon/price series, slot lengths, machine scalars);
    the accumulator dtype covers the *carried* scan state, including
    `remaining`.  Keeping the trajectory state fp64 while the table
    lookups and `model.rates` chains run fp32 is what holds the mixed
    policy's kWh/CO2 totals within 1e-6 relative of fp64 — an fp32
    `remaining` compounds per-slot rounding into the slot-time
    trajectory and blows past that bar."""
    if plan.precision == "mixed":
        return np.float32, np.float64
    return np.float64, np.float64


def _resolve_devices(devices, use_jax: bool) -> int:
    """Number of devices the chunk kernels shard across.

    `devices=None` auto-fans across every local device; an explicit
    count is clamped to what the platform exposes.  The NumPy backend
    is always single-device."""
    if not use_jax or not _HAS_JAX:
        return 1
    avail = len(jax.devices())
    if devices is None:
        return avail
    n = int(devices)
    if n < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    return min(n, avail)


@functools.lru_cache(maxsize=1)
def _pallas_available() -> bool:
    """Can the coupled-throttle Pallas kernel be imported at all?"""
    try:
        import repro.kernels.coupled_throttle  # noqa: F401
    except Exception:
        return False
    return True


def _resolve_pallas(pallas, use_jax: bool) -> str:
    """Resolve the Pallas dispatch policy to "off"/"on"/"interpret".

    `pallas=None` defers to the ``CARINA_PALLAS`` environment variable
    (default "auto": compiled Pallas on TPU backends, jnp fallback
    elsewhere).  `True`/"on" forces the kernel — in interpreter mode on
    non-TPU backends, where Pallas has no compiled lowering;
    "interpret" forces interpreter mode everywhere (the test pin path);
    `False`/"off" disables.  Whenever the kernel module is unavailable
    the answer is "off" — the jnp kernel is always a clean fallback."""
    if pallas is None:
        pallas = os.environ.get("CARINA_PALLAS", "auto")
    if pallas is True:
        pallas = "on"
    elif pallas is False:
        pallas = "off"
    pallas = str(pallas).lower()
    if pallas not in ("auto", "on", "off", "interpret"):
        raise ValueError(f"unknown pallas policy {pallas!r}; use "
                         "'auto', 'on', 'off' or 'interpret'")
    if pallas == "off" or not use_jax or not _HAS_JAX:
        return "off"
    if pallas == "auto":
        pallas = "on" if jax.default_backend() == "tpu" else "off"
    if pallas == "off" or not _pallas_available():
        return "off"
    if pallas == "on" and jax.default_backend() != "tpu":
        return "interpret"
    return pallas


def _run_chunk(plan: SweepPlan, active: np.ndarray, inputs, state_slices,
               use_jax: bool, n_dev: int = 1,
               pallas: str = "off") -> tuple:
    """Execute one chunk for the active lanes, padding the batch to
    bucketed shapes on the JAX backend so repeated sweeps reuse the
    compiled kernel instead of recompiling per exact size.

    Site-coupled plans (any finite group cap) route to the grouped
    kernel; everything else takes the exact pre-fleet code path, so
    plain sweeps stay byte-identical.  With `n_dev > 1` the padded lane
    axis is split into equal device blocks and dispatched through
    `shard_map` — lanes never interact in the plain kernel, so the
    sharded result is bitwise-identical per lane.  The plan's
    `precision` policy picks the input/state dtypes (`_plan_dtypes`);
    fp64 accumulators ride along either way."""
    if plan.coupled:
        return _run_chunk_coupled(plan, active, inputs, state_slices,
                                  use_jax, n_dev, pallas)
    u_tab, b_tab, rowidx, bg, cf, pr, lens = inputs
    A, C = rowidx.shape
    Bg = u_tab.shape[2]
    scalars = tuple(arr[active] for arr in
                    (plan.n_scen, plan.rate, plan.oh, plan.idle, plan.dyn,
                     plan.alpha, plan.gamma, plan.ohfrac))
    if not use_jax:
        out = _scan_chunk_np(u_tab, b_tab, rowidx, bg, cf, pr, lens,
                             state_slices, scalars, Bg)
        _STATS.chunks += 1
        _STATS.devices_used = max(_STATS.devices_used, 1)
        return out

    n_dev = max(1, min(n_dev, A))
    Ap = _pad_lanes(A, n_dev)
    if Ap != A:
        pad = Ap - A

        def padv(a, fill=0.0):
            w = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
            return np.pad(a, w, constant_values=fill)

        u_tab, rowidx, bg, cf, pr = (padv(x) for x in
                                     (u_tab, rowidx, bg, cf, pr))
        b_tab = padv(b_tab, 1.0)
        lens = padv(lens, 3600.0 / plan.sph)
        remaining, rt, kwh, co2, cost = state_slices
        state_slices = (padv(remaining), padv(rt), padv(kwh), padv(co2),
                        padv(cost))
        # safe physics for padded lanes: zero rate, zero power, done
        n_scen, rate, oh, idle, dyn, alpha, gamma, ohfrac = scalars
        scalars = (padv(n_scen, 1.0), padv(rate), padv(oh), padv(idle),
                   padv(dyn), padv(alpha, 1.0), padv(gamma),
                   padv(ohfrac))
    cdt, adt = _plan_dtypes(plan)
    sig = (Ap, u_tab.shape[1], Bg, C, cf.shape[1], plan.price is not None,
           plan.precision, n_dev)
    _STATS.jit_shapes.add(sig)
    _STATS.chunks += 1
    _STATS.slot_work += Ap * C
    _STATS.devices_used = max(_STATS.devices_used, n_dev)
    with enable_x64():
        ins = (jnp.asarray(u_tab, cdt), jnp.asarray(b_tab, cdt),
               jnp.asarray(rowidx), jnp.asarray(bg, cdt),
               jnp.asarray(cf, cdt), jnp.asarray(pr, cdt),
               jnp.asarray(lens, cdt))
        st = tuple(jnp.asarray(a, adt) for a in state_slices)
        sc = tuple(jnp.asarray(a, cdt) for a in scalars)
        if n_dev > 1:
            out = _sharded_plain(n_dev, Bg)(*ins, *st, *sc)
        else:
            out = _scan_chunk_jax(*ins, *st, *sc, B=Bg)
    out = tuple(np.asarray(o) for o in out)
    if Ap != A:
        out = tuple(o[:A] for o in out)
    return out


def _run_chunk_coupled(plan: SweepPlan, active: np.ndarray, inputs,
                       state_slices, use_jax: bool, n_dev: int = 1,
                       pallas: str = "off") -> tuple:
    """One chunk through the grouped site-coupled kernel.

    Active lanes' groups are remapped to dense ids (finished groups
    drop out with their lanes); group count and lane count are both
    padded to power-of-two buckets on the JAX backend, with padded
    lanes assigned a dummy uncapped group, so the jitted kernel's
    shape-signature set stays small as the fleet drains.

    Device fan-out splits lanes at *group* boundaries only (`n_dev` is
    clamped to the live group count), so the site-cap segment-sum and
    throttle fixed point stay device-local.  On a single device the
    coupled step can instead dispatch to the Pallas kernel
    (kernels/coupled_throttle.py) per the resolved `pallas` policy."""
    u_tab, b_tab, rowidx, bg, cf, pr, lens = inputs
    A, C = rowidx.shape
    Bg = u_tab.shape[2]
    scalars = tuple(arr[active] for arr in
                    (plan.n_scen, plan.rate, plan.oh, plan.idle, plan.dyn,
                     plan.alpha, plan.gamma, plan.ohfrac))
    uniq, first, gid = np.unique(plan.lane_group[active],
                                 return_index=True, return_inverse=True)
    Gd = len(uniq)
    gid = gid.astype(np.int32)
    cap_g = plan.group_cap_kw[uniq]
    # each group's office draw follows its own band background over the
    # chunk (group members share bands — validated at compile time)
    office = plan.group_office_kw[uniq][:, None] * bg[first]      # (Gd, C)
    _STATS.grouped_lanes += A
    if not use_jax:
        out = _scan_chunk_np_coupled(u_tab, b_tab, rowidx, bg, cf, pr, lens,
                                     gid, cap_g, office, state_slices,
                                     scalars, Bg)
        _STATS.chunks += 1
        _STATS.devices_used = max(_STATS.devices_used, 1)
        return out

    n_dev = max(1, min(n_dev, Gd))
    if n_dev > 1:
        return _run_chunk_coupled_sharded(
            plan, inputs, state_slices, scalars, gid, cap_g, office,
            Gd, n_dev)
    if pallas in ("on", "interpret"):
        return _run_chunk_coupled_pallas(
            plan, inputs, state_slices, scalars, gid, cap_g, office,
            Gd, interpret=(pallas == "interpret"))

    Ap = _pad_pow2(A)
    if Ap != A:
        pad = Ap - A

        def padv(a, fill=0.0):
            w = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
            return np.pad(a, w, constant_values=fill)

        u_tab, rowidx, bg, cf, pr = (padv(x) for x in
                                     (u_tab, rowidx, bg, cf, pr))
        b_tab = padv(b_tab, 1.0)
        lens = padv(lens, 3600.0 / plan.sph)
        gid = padv(gid, Gd)               # dummy (uncapped) group
        remaining, rt, kwh, co2, cost, speak = state_slices
        state_slices = (padv(remaining), padv(rt), padv(kwh), padv(co2),
                        padv(cost), padv(speak))
        n_scen, rate, oh, idle, dyn, alpha, gamma, ohfrac = scalars
        scalars = (padv(n_scen, 1.0), padv(rate), padv(oh), padv(idle),
                   padv(dyn), padv(alpha, 1.0), padv(gamma),
                   padv(ohfrac))
    Gp = _pad_pow2(Gd + 1, minimum=2)     # +1: the dummy group always fits
    cap_g = np.pad(cap_g, (0, Gp - Gd), constant_values=np.inf)
    office = np.pad(office, ((0, Gp - Gd), (0, 0)))
    cdt, adt = _plan_dtypes(plan)
    sig = (Ap, u_tab.shape[1], Bg, C, cf.shape[1], Gp,
           plan.price is not None, "coupled", plan.precision, 1)
    _STATS.jit_shapes.add(sig)
    _STATS.chunks += 1
    _STATS.slot_work += Ap * C
    _STATS.devices_used = max(_STATS.devices_used, 1)
    with enable_x64():
        out = _scan_chunk_jax_coupled(
            jnp.asarray(u_tab, cdt), jnp.asarray(b_tab, cdt),
            jnp.asarray(rowidx), jnp.asarray(bg, cdt),
            jnp.asarray(cf, cdt), jnp.asarray(pr, cdt),
            jnp.asarray(lens, cdt),
            jnp.asarray(gid), jnp.asarray(cap_g, cdt),
            jnp.asarray(office, cdt),
            *(jnp.asarray(a, adt) for a in state_slices),
            *(jnp.asarray(a, cdt) for a in scalars), B=Bg, G=Gp)
    out = tuple(np.asarray(o) for o in out)
    if Ap != A:
        out = tuple(o[:A] for o in out)
    return out


def _group_cuts(cnt: np.ndarray, n_dev: int) -> np.ndarray:
    """Contiguous group-boundary indices (`n_dev + 1`,) splitting `cnt`
    (lanes per group) into device parts balanced by lane count; every
    part gets at least one group (requires `n_dev <= len(cnt)`)."""
    Gd = len(cnt)
    csum = np.concatenate([[0], np.cumsum(cnt)])
    total = int(csum[-1])
    bounds = np.empty(n_dev + 1, dtype=int)
    bounds[0] = 0
    for d in range(1, n_dev):
        target = total * d / n_dev
        g = int(np.searchsorted(csum, target, side="left"))
        bounds[d] = min(max(g, bounds[d - 1] + 1), Gd - (n_dev - d))
    bounds[n_dev] = Gd
    return bounds


def _run_chunk_coupled_sharded(plan: SweepPlan, inputs, state_slices,
                               scalars, gid: np.ndarray,
                               cap_g: np.ndarray, office: np.ndarray,
                               Gd: int, n_dev: int) -> tuple:
    """Coupled chunk across devices: groups (contiguous in lane order)
    are partitioned into `n_dev` balanced contiguous parts, each part's
    lanes padded to a common pow2 block and its groups renumbered
    device-locally (plus one dummy uncapped group for padded lanes),
    then the blocks are stacked along the lane axis and dispatched
    through the `shard_map` wrapper — each device runs the unchanged
    coupled kernel on exactly its own groups."""
    u_tab, b_tab, rowidx, bg, cf, pr, lens = inputs
    A, C = rowidx.shape
    Bg = u_tab.shape[2]
    cnt = np.bincount(gid, minlength=Gd)
    bounds = _group_cuts(cnt, n_dev)
    csum = np.concatenate([[0], np.cumsum(cnt)])
    lane_lo = csum[bounds[:-1]]
    lane_hi = csum[bounds[1:]]
    Ld = _pad_pow2(int((lane_hi - lane_lo).max()),
                   minimum=max(8 // n_dev, 1))
    Gp = _pad_pow2(int((bounds[1:] - bounds[:-1]).max()) + 1, minimum=2)

    def stack_lane(a, fill=0.0):
        out = np.full((n_dev * Ld,) + a.shape[1:], fill, dtype=a.dtype)
        for d in range(n_dev):
            lo, hi = lane_lo[d], lane_hi[d]
            out[d * Ld:d * Ld + (hi - lo)] = a[lo:hi]
        return out

    gid_s = np.empty(n_dev * Ld, dtype=np.int32)
    cap_s = np.full(n_dev * Gp, np.inf)
    off_s = np.zeros((n_dev * Gp, C))
    for d in range(n_dev):
        lo, hi = lane_lo[d], lane_hi[d]
        gb0, gb1 = bounds[d], bounds[d + 1]
        gid_s[d * Ld:(d + 1) * Ld] = gb1 - gb0        # dummy group
        gid_s[d * Ld:d * Ld + (hi - lo)] = gid[lo:hi] - gb0
        cap_s[d * Gp:d * Gp + (gb1 - gb0)] = cap_g[gb0:gb1]
        off_s[d * Gp:d * Gp + (gb1 - gb0)] = office[gb0:gb1]

    remaining, rt, kwh, co2, cost, speak = state_slices
    n_scen, rate, oh, idle, dyn, alpha, gamma, ohfrac = scalars
    cdt, adt = _plan_dtypes(plan)
    sig = (n_dev * Ld, u_tab.shape[1], Bg, C, cf.shape[1], Gp,
           plan.price is not None, "coupled", plan.precision, n_dev)
    _STATS.jit_shapes.add(sig)
    _STATS.chunks += 1
    _STATS.slot_work += n_dev * Ld * C
    _STATS.devices_used = max(_STATS.devices_used, n_dev)
    with enable_x64():
        out = _sharded_coupled(n_dev, Bg, Gp)(
            jnp.asarray(stack_lane(u_tab), cdt),
            jnp.asarray(stack_lane(b_tab, 1.0), cdt),
            jnp.asarray(stack_lane(rowidx)),
            jnp.asarray(stack_lane(bg), cdt),
            jnp.asarray(stack_lane(cf), cdt),
            jnp.asarray(stack_lane(pr), cdt),
            jnp.asarray(stack_lane(lens, 3600.0 / plan.sph), cdt),
            jnp.asarray(gid_s), jnp.asarray(cap_s, cdt),
            jnp.asarray(off_s, cdt),
            jnp.asarray(stack_lane(remaining), adt),
            jnp.asarray(stack_lane(rt), adt),
            jnp.asarray(stack_lane(kwh), adt),
            jnp.asarray(stack_lane(co2), adt),
            jnp.asarray(stack_lane(cost), adt),
            jnp.asarray(stack_lane(speak), adt),
            jnp.asarray(stack_lane(n_scen, 1.0), cdt),
            jnp.asarray(stack_lane(rate), cdt),
            jnp.asarray(stack_lane(oh), cdt),
            jnp.asarray(stack_lane(idle), cdt),
            jnp.asarray(stack_lane(dyn), cdt),
            jnp.asarray(stack_lane(alpha, 1.0), cdt),
            jnp.asarray(stack_lane(gamma), cdt),
            jnp.asarray(stack_lane(ohfrac), cdt))
    final = []
    for o in out:
        o = np.asarray(o)
        final.append(np.concatenate(
            [o[d * Ld:d * Ld + (lane_hi[d] - lane_lo[d])]
             for d in range(n_dev)]))
    return tuple(final)


def _run_chunk_coupled_pallas(plan: SweepPlan, inputs, state_slices,
                              scalars, gid: np.ndarray, cap_g: np.ndarray,
                              office: np.ndarray, Gd: int,
                              interpret: bool) -> tuple:
    """Coupled chunk through the Pallas kernel: lanes are repacked into
    a dense (group, lane-in-group) layout with the per-slot decision
    rows pre-gathered, the kernel runs one program per group with the
    slot loop inside, and results scatter back to flat lane order.
    Parity with the jnp kernel is pinned to <1e-9 by tests."""
    from repro.kernels.coupled_throttle import coupled_chunk
    u_tab, b_tab, rowidx, bg, cf, pr, lens = inputs
    A, C = rowidx.shape
    Bg = u_tab.shape[2]
    E = cf.shape[1]
    cnt = np.bincount(gid, minlength=Gd)
    csum = np.concatenate([[0], np.cumsum(cnt)])
    pos = np.arange(A) - csum[gid]        # position within own group
    Lp = _pad_pow2(int(cnt.max()))
    Gp = _pad_pow2(Gd, minimum=1)

    def dense(a, fill=0.0):
        out = np.full((Gp, Lp) + a.shape[1:], fill, dtype=a.dtype)
        out[gid, pos] = a
        return out

    # hoist the per-lane dynamic row gather out of the kernel
    u_rows = np.take_along_axis(u_tab, rowidx[:, :, None], axis=1)
    b_rows = np.take_along_axis(b_tab, rowidx[:, :, None], axis=1)
    cap_p = np.pad(cap_g, (0, Gp - Gd), constant_values=np.inf)
    off_p = np.pad(office, ((0, Gp - Gd), (0, 0)))
    remaining, rt, kwh, co2, cost, speak = state_slices
    n_scen, rate, oh, idle, dyn, alpha, gamma, ohfrac = scalars
    cdt, adt = _plan_dtypes(plan)
    sig = ("pallas", Gp, Lp, C, Bg, E, plan.price is not None,
           plan.precision)
    _STATS.jit_shapes.add(sig)
    _STATS.chunks += 1
    _STATS.slot_work += Gp * Lp * C
    _STATS.devices_used = max(_STATS.devices_used, 1)
    _STATS.pallas_dispatches += 1
    with enable_x64():
        out = coupled_chunk(
            jnp.asarray(dense(u_rows), cdt),
            jnp.asarray(dense(b_rows, 1.0), cdt),
            jnp.asarray(dense(bg), cdt),
            jnp.asarray(dense(cf), cdt),
            jnp.asarray(dense(pr), cdt),
            jnp.asarray(dense(lens, 3600.0 / plan.sph), cdt),
            jnp.asarray(cap_p, cdt), jnp.asarray(off_p, cdt),
            jnp.asarray(dense(remaining), adt),
            jnp.asarray(dense(rt), adt),
            jnp.asarray(dense(kwh), adt),
            jnp.asarray(dense(co2), adt),
            jnp.asarray(dense(cost), adt),
            jnp.asarray(dense(speak), adt),
            jnp.asarray(dense(n_scen, 1.0), cdt),
            jnp.asarray(dense(rate), cdt),
            jnp.asarray(dense(oh), cdt),
            jnp.asarray(dense(idle), cdt),
            jnp.asarray(dense(dyn), cdt),
            jnp.asarray(dense(alpha, 1.0), cdt),
            jnp.asarray(dense(gamma), cdt),
            jnp.asarray(dense(ohfrac), cdt),
            iters=model.SITE_THROTTLE_ITERS, finish_frac=_FINISH_FRAC,
            interpret=interpret)
    return tuple(np.asarray(o)[gid, pos] for o in out)


def _chunk_inputs(plan: SweepPlan, active: np.ndarray, t0: int,
                  C: int) -> tuple:
    """Assemble the per-slot inputs for global slots [t0, t0 + C) of the
    active lanes: decision tables (padded to a common (R, B) bucket),
    row indices, background, carbon (per ensemble member), price and
    slot lengths — all batched NumPy, no per-slot Python."""
    H = 24 * plan.sph
    A = active.size
    slot = t0 + np.arange(C)
    s_rows = (plan.s0[active][:, None] + slot[None, :]) % H       # (A, C)
    bg = np.take_along_axis(plan.bg_day[active], s_rows, axis=1)
    lens = np.full((A, C), 3600.0 / plan.sph)
    if t0 == 0:
        lens[:, 0] = (plan.g0[active] + 1.0 / plan.sph
                      - plan.start[active]) * 3600.0

    # decision tables: periodic lanes come from the plan's precompiled
    # stack in one fancy-index slice; only chunk-built (elapsed-aware)
    # lanes pay per-lane Python here — typically the few stragglers
    has_tab = plan.lane_periodic[active]
    built_pos = np.flatnonzero(~has_tab)
    built = [plan.lane_builder[active[p]](t0, C) for p in built_pos]
    Bg = plan.tab_buckets
    R = H
    if built:
        R = max(R, C)
        Bg = max(Bg, max(u.shape[1] for u, _ in built))
    u_tab = np.zeros((A, R, Bg))
    b_tab = np.ones((A, R, Bg))
    tab_pos = np.flatnonzero(has_tab)
    if tab_pos.size:
        # (n, H, B_t) -> (n, H, Bg): last axis broadcasts when B_t == 1
        u_tab[tab_pos, :H, :] = plan.tab_u[active[tab_pos]]
        b_tab[tab_pos, :H, :] = plan.tab_b[active[tab_pos]]
    for p, (u_r, b_r) in zip(built_pos, built):
        rows = u_r.shape[0]
        u_tab[p, :rows] = np.broadcast_to(u_r, (rows, Bg)) \
            if u_r.shape[1] == 1 else u_r
        b_tab[p, :rows] = np.broadcast_to(b_r, (rows, Bg)) \
            if b_r.shape[1] == 1 else b_r
    rowidx = np.where(has_tab[:, None], s_rows,
                      np.arange(C)[None, :]).astype(np.int32)

    # signals: one grid lookup + one batched assignment per distinct
    # (signal, offset) pair, not one per lane
    cf = np.empty((A, plan.E, C))
    groups: Dict[tuple, list] = {}
    for k, lane in enumerate(active):
        g0 = float(plan.g0[lane])
        for e, sig in enumerate(plan.lane_co2_sigs[lane]):
            groups.setdefault((id(sig), g0), []).append((k, e, sig))
    for (_, g0), members in groups.items():
        vals = _sig_slice(plan, members[0][2], g0, t0, C)
        ks = np.fromiter((m[0] for m in members), int, len(members))
        es = np.fromiter((m[1] for m in members), int, len(members))
        cf[ks, es] = vals[None, :]
    if plan.price is not None:
        pr = np.empty((A, C))
        pgroups: Dict[float, list] = {}
        for k, lane in enumerate(active):
            pgroups.setdefault(float(plan.g0[lane]), []).append(k)
        for g0, ks in pgroups.items():
            pr[np.asarray(ks)] = _sig_slice(plan, plan.price, g0,
                                            t0, C)[None, :]
    else:
        pr = np.zeros((A, C))
    return u_tab, b_tab, rowidx, bg, cf, pr, lens


def _stall_diagnostic(plan: SweepPlan, lane: int, remaining: float) -> str:
    case = plan.cases[plan.lane_case[lane]]
    return (f"case {case.name()!r} made no progress over a full scanned "
            f"day on the trace grid (remaining {remaining:.0f} of "
            f"{plan.n_scen[lane]:.0f} scenarios); its schedule is "
            "stalled at zero intensity")


def execute_plan(plan: SweepPlan, *, backend: Optional[str] = None,
                 chunk_days: Optional[int] = None,
                 mode: str = "chunked",
                 devices: Optional[int] = None,
                 pallas=None) -> _ScanState:
    """Run the scan over a compiled plan and return the final state.

    `mode="chunked"` (default) is the resumable scan: fixed-shape chunks
    are appended until every lane finishes, finished lanes are compacted
    out, and no slot is ever scanned twice.  `mode="monolithic"` keeps
    the previous engine behaviour — one scan sized by the duration
    estimate, re-run from t=0 with a doubled horizon on undershoot —
    for equivalence tests and wasted-work benchmarks.

    `devices` shards the lane axis across local devices via `shard_map`
    (`None` = every device `jax.devices()` reports; expose virtual CPU
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    *before* jax initializes — see core/xla_profiles.py).  Uncoupled
    sweeps shard bitwise-identically; coupled plans split only at group
    boundaries so the site cap never crosses a shard.  `pallas` picks
    the coupled-chunk kernel implementation (None → ``CARINA_PALLAS``
    env, default "auto"; see `_resolve_pallas`); the Pallas path is
    single-device only and the jnp kernel remains the fallback.  The
    scan's dtype policy is fixed at `compile_plan(precision=...)` time.

    Stall detection: provably-dead periodic tables are diagnosed at
    compile time; beyond that, the chunked executor raises the stall
    diagnostic as soon as a day-periodic lane completes a full scanned
    day with zero progress (the monolithic executor can only see
    zero-progress-from-t=0, so a schedule that stalls mid-campaign
    still scans to `max_days` there before the generic failure).
    """
    if mode not in ("chunked", "monolithic"):
        raise ValueError(f"unknown mode {mode!r}; use 'chunked' or "
                         "'monolithic'")
    if chunk_days is not None and int(chunk_days) < 1:
        raise ValueError(f"chunk_days must be >= 1, got {chunk_days}")
    if mode == "monolithic":
        use_jax = _use_jax(backend)
        n_dev = _resolve_devices(devices, use_jax)
        pallas_mode = _resolve_pallas(pallas, use_jax)
        _STATS.precision_mode = plan.precision if use_jax else "fp64"
        return _execute_monolithic(plan, use_jax, n_dev, pallas_mode)

    return execute_interval(plan, backend=backend, chunk_days=chunk_days,
                            devices=devices, pallas=pallas).state


def execute_interval(plan: SweepPlan, cursor: Optional[PlanCursor] = None, *,
                     until_slot: Optional[int] = None,
                     backend: Optional[str] = None,
                     chunk_days: Optional[int] = None,
                     devices: Optional[int] = None,
                     pallas=None) -> PlanCursor:
    """Advance the chunked scan from `cursor` (a fresh one when None) to
    `until_slot` (to completion when None) and return the new cursor.

    This is the resumable core of `execute_plan` exposed as a primitive:
    the MPC loop calls it once per control interval, swaps tables with
    `replace_tables` in between, and never recomputes an executed slot.
    The input cursor is not mutated — its state arrays are copied — so
    callers can keep earlier cursors as snapshots.  Lanes that finish
    before `until_slot` compact out exactly as in `execute_plan`;
    stall detection and the `max_days` guard behave identically.
    """
    if chunk_days is not None and int(chunk_days) < 1:
        raise ValueError(f"chunk_days must be >= 1, got {chunk_days}")
    use_jax = _use_jax(backend)
    n_dev = _resolve_devices(devices, use_jax)
    pallas_mode = _resolve_pallas(pallas, use_jax)
    _STATS.precision_mode = plan.precision if use_jax else "fp64"
    H = 24 * plan.sph
    max_slots = plan.max_slots
    if cursor is None:
        cursor = new_cursor(plan)
    stop = max_slots if until_slot is None else min(int(until_slot),
                                                   max_slots)
    C = int(chunk_days or DEFAULT_CHUNK_DAYS) * H
    coupled = plan.coupled
    st = cursor.state
    remaining = st.remaining.copy()
    rt = st.runtime_s.copy()
    kwh = st.kwh.copy()
    co2 = st.co2.copy()
    cost = st.cost.copy()
    speak = st.site_kw_peak.copy() if st.site_kw_peak is not None else (
        np.zeros(plan.n_lanes) if coupled else None)
    active = cursor.active.copy()
    t0 = int(cursor.t0)
    while active.size and t0 < stop:
        C_eff = min(C, stop - t0)
        inputs = _chunk_inputs(plan, active, t0, C_eff)
        state = (remaining[active], rt[active], kwh[active], co2[active],
                 cost[active])
        if coupled:
            state = state + (speak[active],)
        before = remaining[active].copy()
        out = _run_chunk(plan, active, inputs, state, use_jax, n_dev,
                         pallas_mode)
        if coupled:
            speak[active] = out[5]
        remaining[active], rt[active], kwh[active], co2[active], \
            cost[active] = out[:5]
        unfinished = (remaining[active]
                      > _FINISH_FRAC * plan.n_scen[active])
        if C_eff >= H:
            made = before - remaining[active]
            days = C_eff / H
            stalled = (unfinished & plan.lane_periodic[active]
                       & (made <= _STALL_FRAC_PER_DAY * days
                          * plan.n_scen[active]))
            if stalled.any():
                lane = int(active[np.flatnonzero(stalled)[0]])
                raise RuntimeError(_stall_diagnostic(
                    plan, lane, float(remaining[lane])))
        active = active[unfinished]
        t0 += C_eff
        if active.size and t0 >= max_slots:
            worst = int(active[np.argmax(remaining[active]
                                         / plan.n_scen[active])])
            case = plan.cases[plan.lane_case[worst]]
            raise RuntimeError(
                f"case {case.name()!r} did not finish within "
                f"max_days={plan.max_days} on the trace grid (remaining "
                f"{remaining[worst]:.0f} of {plan.n_scen[worst]:.0f} "
                "scenarios); its schedule may be stalled at zero intensity")
    return PlanCursor(state=_ScanState(remaining, rt, kwh, co2, cost, speak),
                      t0=t0, active=active)


def _execute_monolithic(plan: SweepPlan, use_jax: bool, n_dev: int = 1,
                        pallas: str = "off") -> _ScanState:
    """The pre-chunking behaviour: scan everything from t=0 over one
    estimated horizon, double and re-scan on undershoot."""
    H = 24 * plan.sph
    L = plan.n_lanes
    max_slots = plan.max_slots
    all_lanes = np.arange(L)
    T = int(math.ceil(min(plan.est_h, plan.max_days * 24.0) * plan.sph))
    while True:
        inputs = _chunk_inputs(plan, all_lanes, 0, T)
        state = (plan.n_scen.copy(), np.zeros(L), np.zeros(L),
                 np.zeros((L, plan.E)), np.zeros(L))
        if plan.coupled:
            state = state + (np.zeros(L),)
        out = _run_chunk(plan, all_lanes, inputs, state, use_jax, n_dev,
                         pallas)
        remaining = out[0]
        if (remaining <= _FINISH_FRAC * plan.n_scen).all():
            return _ScanState(*out)
        if T >= H:
            made = plan.n_scen - remaining
            stalled = ((remaining > _FINISH_FRAC * plan.n_scen)
                       & plan.lane_periodic
                       & (made <= _STALL_FRAC_PER_DAY * (T / H)
                          * plan.n_scen))
            if stalled.any():
                lane = int(np.flatnonzero(stalled)[0])
                raise RuntimeError(_stall_diagnostic(
                    plan, lane, float(remaining[lane])))
        if T >= max_slots:
            worst = int(np.argmax(remaining / plan.n_scen))
            case = plan.cases[plan.lane_case[worst]]
            raise RuntimeError(
                f"case {case.name()!r} did not finish within "
                f"max_days={plan.max_days} on the trace grid (remaining "
                f"{remaining[worst]:.0f} of {plan.n_scen[worst]:.0f} "
                "scenarios); its schedule may be stalled at zero intensity")
        T = min(T * 2, max_slots)


def summarize_plan(plan: SweepPlan, state: _ScanState) -> List[SimResult]:
    """Fold the final scan state into one `SimResult` per case.

    Deterministic cases report scalars; ensemble cases report ensemble
    means in the scalar columns plus per-member `EnsembleStats` for CO2
    (and for energy/runtime/cost too when the schedule's decisions
    consulted the carbon signal, i.e. the dynamics themselves varied).
    """
    has_price = plan.price is not None
    out: List[SimResult] = []
    for i, case in enumerate(plan.cases):
        lanes = np.flatnonzero(plan.lane_case == i)
        ens = plan.case_ensemble[i]
        if ens is None:
            lane = int(lanes[0])
            out.append(SimResult(
                policy=case.name(),
                runtime_h=float(state.runtime_s[lane]) / 3600.0,
                energy_kwh=float(state.kwh[lane]),
                co2_kg=float(state.co2[lane, 0]),
                cost_usd=float(state.cost[lane]) if has_price else None))
            continue
        if not plan.case_expanded[i]:
            lane = int(lanes[0])
            co2_samples = state.co2[lane]
            out.append(SimResult(
                policy=case.name(),
                runtime_h=float(state.runtime_s[lane]) / 3600.0,
                energy_kwh=float(state.kwh[lane]),
                co2_kg=float(co2_samples.mean()),
                cost_usd=float(state.cost[lane]) if has_price else None,
                co2_ensemble=ensemble_stats(co2_samples)))
            continue
        # carbon-dependent schedule: lane e ran member e's decisions, and
        # only its own member's CO2 column is meaningful (the diagonal)
        members = plan.lane_member[lanes]
        co2_samples = state.co2[lanes, members]
        rt_samples = state.runtime_s[lanes] / 3600.0
        kwh_samples = state.kwh[lanes]
        cost_samples = state.cost[lanes]
        out.append(SimResult(
            policy=case.name(),
            runtime_h=float(rt_samples.mean()),
            energy_kwh=float(kwh_samples.mean()),
            co2_kg=float(co2_samples.mean()),
            cost_usd=float(cost_samples.mean()) if has_price else None,
            co2_ensemble=ensemble_stats(co2_samples),
            energy_ensemble=ensemble_stats(kwh_samples),
            runtime_ensemble=ensemble_stats(rt_samples)))
    return out


# ---------------------------------------------------------------------------
# Differentiable objective path (the substrate of core/optimize.py).
#
# `trace_sweep` above is built for *evaluation*: it probes schedules with
# Python `decide()` calls, classifies them, and extends the horizon —
# none of which can live inside a jax trace.  `TraceObjective` is the
# same physics specialized for *search*: everything that depends on the
# case (signals, background, slot lengths, machine scalars) is
# precomputed once as static arrays, and what remains is a pure function
#     per-slot intensities (..., n_slots)  ->  EvalMetrics
# with no Python in the traced region, so `jax.grad` flows through the
# scan and `jax.vmap` batches hundreds of candidates per jit call.
# ---------------------------------------------------------------------------
class EvalMetrics(NamedTuple):
    """Campaign outcome as a differentiable pytree (floats or arrays).

    `cost_usd` is 0 when no price signal was given; `unfinished` is the
    fraction of the workload left at the end of the horizon (0 when the
    campaign completed — optimizers penalize it so solutions that stall
    past the horizon are driven back into range).  When the case's
    carbon is a `SignalEnsemble`, `co2_kg` carries one trailing ensemble
    axis (..., E) — one value per member — while the other fields keep
    shape (...): the schedule family is carbon-blind, so the dynamics
    are identical across members and only the carbonization varies.
    `repro.core.optimize.reduce_ensemble` collapses that axis under a
    robust objective (mean / CVaR / worst-case).
    """
    energy_kwh: Any
    co2_kg: Any
    runtime_h: Any
    cost_usd: Any
    unfinished: Any


class TraceObjective:
    """One sweep case as a pure, vmappable objective over day schedules.

    Construction samples the case's signals over a *fixed* horizon
    (`horizon_h`, default sized from a mid-intensity duration estimate or
    the case deadline) — there is no retry-doubling or probe
    classification afterwards.  `evaluate(u_day)` maps per-slot
    intensities of shape (..., n_slots) to `EvalMetrics` of shape (...,):
    on the JAX backend the computation is traceable (grad/vmap/jit
    compose over it); on the NumPy backend the identical scan runs as a
    loop, still vectorized over leading axes.

    A schedule that finishes inside the horizon gets exactly the numbers
    `trace_sweep` would produce for the equivalent `ParametricSchedule`
    (same grid, same shared rate model); one that does not reports
    `unfinished > 0` instead of growing the grid.

    A `SignalEnsemble` carbon turns `co2_kg` into a (..., E) block — the
    substrate of `Campaign.optimize(robust=...)`.

    `precision="mixed"` runs the traced scan dynamics in fp32 with fp64
    kWh/CO2/cost accumulators (same policy as
    `compile_plan(precision=...)`) — useful to halve optimizer search
    cost; the default keeps exact fp64.
    """

    def __init__(self, case, *, price: Optional[Signal] = None,
                 slots_per_hour: int = 1, horizon_h: Optional[float] = None,
                 batch_size: float = 50.0, max_days: int = 120,
                 backend: Optional[str] = None, precision: str = "fp64"):
        if precision not in ("fp64", "mixed"):
            raise ValueError(f"unknown precision {precision!r}; "
                             "use 'fp64' or 'mixed'")
        sph = int(slots_per_hour)
        self.precision = precision
        self.case = case
        self.sph = sph
        self.n_slots = 24 * sph
        self.batch_size = float(batch_size)
        self.has_price = price is not None
        self.use_jax = _use_jax(backend)
        self._jit = None

        wl, mach = case.workload, case.machine
        self._scalars = (float(wl.n_scenarios), float(wl.rate_at_full),
                         float(wl.batch_overhead_s), float(mach.idle_w),
                         float(mach.dyn_w), float(mach.alpha),
                         float(mach.gamma), float(mach.overhead_w_frac))

        carbon = case.carbon or GridCarbonModel()
        self.ensemble_size = (len(carbon)
                              if isinstance(carbon, SignalEnsemble) else 0)
        start = float(case.start_hour)
        g0 = math.floor(start * sph) / sph
        bg_day = _bg_table(case.bands, sph)
        if horizon_h is None:
            horizon_h = self._default_horizon(bg_day, max_days)
        self.horizon_h = float(min(horizon_h, max_days * 24.0))
        T = max(int(math.ceil(self.horizon_h * sph)), 1)
        slot = np.arange(T)
        t_abs = g0 + slot / sph
        s0 = int(round(g0 * sph)) % self.n_slots
        self.rowidx = ((s0 + slot) % self.n_slots).astype(np.int32)
        self.bg = bg_day[self.rowidx]
        if self.ensemble_size:
            self.cf = carbon.sample(t_abs)           # (E, T)
        else:
            self.cf = sample_signal(carbon_signal(carbon), t_abs)
        self.pr = (sample_signal(price, t_abs) if price is not None
                   else np.zeros(T))
        lens = np.full(T, 3600.0 / sph)
        lens[0] = (g0 + 1.0 / sph - start) * 3600.0
        self.lens = lens
        self.hours = t_abs                 # absolute hour of each slot

    def _default_horizon(self, bg_day: np.ndarray, max_days: int) -> float:
        """Mid-intensity duration estimate, stretched; or the deadline
        with margin, whichever is larger (deadline-capped optima sit at
        the cap, so the grid must comfortably cover it)."""
        n_scen, *_ = self._scalars
        r = model.campaign_rates(0.35, self.batch_size, float(bg_day.mean()),
                                 self.case.workload, self.case.machine)
        dur = n_scen / max(r.scen_per_s, 1e-9) / 3600.0
        est = dur * 1.6 + 48.0
        dl = float(getattr(self.case, "deadline_h", 0.0) or 0.0)
        if dl > 0.0:
            est = max(est, dl * 1.25 + 24.0)
        return min(est, max_days * 24.0)

    # ------------------------------------------------------------------
    def evaluate(self, u_day) -> EvalMetrics:
        """EvalMetrics for per-slot intensities `u_day` (..., n_slots).

        Pure: jnp inputs stay traced on the JAX backend (compose with
        jit/grad/vmap as you like, ideally under `enable_x64` so results
        match the engines' float64); NumPy inputs run the loop backend.
        """
        if self.use_jax and not isinstance(u_day, np.ndarray):
            return self._evaluate_jax(u_day)
        return self._evaluate_np(np.asarray(u_day, dtype=float))

    def evaluate_batch(self, U) -> EvalMetrics:
        """Concrete (NumPy) EvalMetrics for a (N, n_slots) population,
        evaluated in one jitted call on the JAX backend."""
        U = np.asarray(U, dtype=float)
        if not self.use_jax:
            return self._evaluate_np(U)
        with enable_x64():
            out = self._jitted_eval()(jnp.asarray(U))
        return EvalMetrics(*(np.asarray(x) for x in out))

    def _jitted_eval(self):
        if self._jit is None:
            self._jit = jax.jit(self._evaluate_jax)
        return self._jit

    # ------------------------------------------------------------------
    def _step_rates(self, u, bg_t, xp):
        (_, rate, oh, idle, dyn, alpha, gamma, ohfrac) = self._scalars[:8]
        return model.rates(u, self.batch_size, bg_t, rate_at_full=rate,
                           batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                           alpha=alpha, gamma=gamma, overhead_w_frac=ohfrac,
                           xp=xp)

    def _evaluate_jax(self, u_day) -> EvalMetrics:
        n_scen = self._scalars[0]
        E = self.ensemble_size
        u_day = jnp.asarray(u_day)
        u_t = jnp.moveaxis(u_day[..., jnp.asarray(self.rowidx)], -1, 0)
        shape = u_day.shape[:-1]

        def step(carry, xs):
            remaining, rt, kwh, co2, cost = carry
            u, bg_t, cf_t, pr_t, ln = xs
            r = self._step_rates(u, bg_t, jnp)
            scen = jnp.maximum(r.scen_per_s, 1e-30)
            # strict branch selection, NOT jnp.minimum(ln, remaining/scen):
            # when the campaign finishes exactly on a slot boundary, the
            # minimum's tie splits its gradient across both branches and
            # the analytic cancellation d(remaining - scen*dt)/du == 0 of
            # the finish branch is lost — the residue, scaled by the
            # optimizer's unfinished penalty, produced gradient norms
            # ~1000x too large at such points.  The tie must take the
            # finish branch, where the cancellation is exact.
            dt = jnp.where(remaining > scen * ln, ln, remaining / scen)
            dt = jnp.where(remaining > 0.0, dt, 0.0)
            e = r.kwh_per_s * dt
            co2 = (co2 + e[..., None] * cf_t) if E else (co2 + e * cf_t)
            return (remaining - r.scen_per_s * dt, rt + dt, kwh + e,
                    co2, cost + e * pr_t), None

        mixed = self.precision == "mixed"
        zero = jnp.zeros(shape)
        co2_0 = jnp.zeros(shape + (E,)) if E else zero
        # mixed policy: fp32 per-slot inputs/physics, fp64 carried state
        # and accumulators (matches the engine's `_plan_dtypes` split)
        cdt = jnp.float32 if mixed else zero.dtype
        if mixed:
            u_t = u_t.astype(cdt)
        init = (jnp.full(shape, n_scen), zero, zero, co2_0, zero)
        cf_xs = jnp.asarray(self.cf.T if E else self.cf, cdt)
        xs = (u_t, jnp.asarray(self.bg, cdt), cf_xs,
              jnp.asarray(self.pr, cdt), jnp.asarray(self.lens, cdt))
        (remaining, rt, kwh, co2, cost), _ = jax.lax.scan(step, init, xs)
        return EvalMetrics(kwh, co2, rt / 3600.0, cost, remaining / n_scen)

    def _evaluate_np(self, u_day: np.ndarray) -> EvalMetrics:
        n_scen = self._scalars[0]
        E = self.ensemble_size
        u_t = u_day[..., self.rowidx]                       # (..., T)
        shape = u_day.shape[:-1]
        remaining = np.full(shape, n_scen)
        rt = np.zeros(shape)
        kwh = np.zeros(shape)
        co2 = np.zeros(shape + (E,)) if E else np.zeros(shape)
        cost = np.zeros(shape)
        for t in range(len(self.lens)):
            if not (remaining > 0.0).any():
                break
            r = self._step_rates(u_t[..., t], float(self.bg[t]), np)
            scen = np.maximum(r.scen_per_s, 1e-30)
            ln = self.lens[t]
            dt = np.where(remaining > 0.0,
                          np.where(remaining > scen * ln, ln,
                                   remaining / scen),
                          0.0)
            e = r.kwh_per_s * dt
            remaining = remaining - r.scen_per_s * dt
            rt = rt + dt
            kwh = kwh + e
            co2 = (co2 + e[..., None] * self.cf[:, t]) if E \
                else (co2 + e * self.cf[t])
            cost = cost + e * self.pr[t]
        return EvalMetrics(kwh, co2, rt / 3600.0, cost, remaining / n_scen)


def evaluate_params(params, case, *, u_min: float = 0.05, u_max: float = 1.0,
                    batch_size: float = 50.0,
                    price: Optional[Signal] = None, slots_per_hour: int = 1,
                    horizon_h: Optional[float] = None,
                    backend: Optional[str] = None) -> EvalMetrics:
    """`EvalMetrics` (energy_kwh, co2_kg, runtime_h, cost_usd, unfinished)
    for `ParametricSchedule` logits `params` on `case`.

    Pure and jax.grad-/jax.vmap-compatible: the squash and the scan are
    both traceable, so `jax.grad(lambda p: evaluate_params(p, case).co2_kg)`
    just works.  For repeated evaluation (optimization loops) build one
    `TraceObjective` instead — this convenience resamples the case's
    signals on every call.
    """
    from repro.core.schedule import ParametricSchedule
    obj = TraceObjective(case, price=price, slots_per_hour=slots_per_hour,
                         horizon_h=horizon_h, batch_size=batch_size,
                         backend=backend)
    traced = obj.use_jax and not isinstance(params, np.ndarray)
    xp = jnp if traced else np
    u = ParametricSchedule.u_from_logits(xp.asarray(params), u_min, u_max,
                                         xp=xp)
    return obj.evaluate(u)


class FleetEvalMetrics(NamedTuple):
    """Joint outcome of M concurrent campaigns as a differentiable
    pytree: per-campaign fields carry a trailing (..., M) axis,
    `site_peak_kw` is the site-level scalar (..., ) — the peak total
    site draw (office + all campaigns) over the horizon, the quantity a
    `site_peak_kw <= cap` constraint caps."""
    energy_kwh: Any          # (..., M)
    co2_kg: Any              # (..., M)
    runtime_h: Any           # (..., M)
    cost_usd: Any            # (..., M)
    unfinished: Any          # (..., M)
    site_peak_kw: Any        # (...,)


class FleetTraceObjective:
    """M concurrent campaigns under one site as a pure objective.

    The fleet analogue of `TraceObjective`: construction samples the
    shared signals over a fixed horizon; `evaluate(u)` maps a joint
    intensity block of shape (..., M, n_slots) — campaign m's day
    schedule in row m — to `FleetEvalMetrics` of shape (..., M)/(...,).
    Each slot applies the one site-coupling definition
    (`model.site_throttle`): demands are decided from the intensity
    tables, the summed active draw is compared to the site headroom
    (cap minus office draw, which follows the band background), every
    campaign's intensity is curtailed by the shared factor, and the
    physics re-evaluated — exactly what the grouped-lane chunk kernels
    and the sequential fleet oracle do, so optimized schedules report
    identically through the real engine.

    Differentiable end to end on the JAX backend (the throttle's
    min/max and the running site-peak max carry subgradients), with the
    same strict finish-branch selection as `TraceObjective`; the NumPy
    backend runs the identical scan as a loop.  `site_cap_kw=None`
    evaluates the uncoupled fleet (throttle factor pinned at 1) while
    still reporting `site_peak_kw`, so a planner can satisfy a peak cap
    by *scheduling* around it rather than relying on reactive
    curtailment.  Carbon ensembles are not supported here (fleet
    robustness composes poorly with joint curtailment; sweep the
    optimized schedules against an ensemble instead).
    """

    def __init__(self, cases: Sequence, *,
                 site_cap_kw: Optional[float] = None,
                 office_kw: float = 0.0,
                 price: Optional[Signal] = None,
                 slots_per_hour: int = 1,
                 horizon_h: Optional[float] = None,
                 batch_size: float = 50.0, max_days: int = 120,
                 backend: Optional[str] = None):
        if not len(cases):
            raise ValueError("FleetTraceObjective needs at least one case")
        if len({c.start_hour for c in cases}) > 1:
            raise ValueError("fleet campaigns share the site clock: all "
                             "cases must have the same start_hour")
        if len({c.bands for c in cases}) > 1:
            raise ValueError("fleet campaigns share the site's TimeBands "
                             "(one background/office curve); got differing "
                             "bands across cases")
        if any(isinstance(c.carbon, SignalEnsemble) for c in cases):
            raise ValueError("FleetTraceObjective does not take carbon "
                             "ensembles; optimize against one trace and "
                             "sweep the result against the ensemble")
        sph = int(slots_per_hour)
        self.cases = tuple(cases)
        self.M = len(cases)
        self.sph = sph
        self.n_slots = 24 * sph
        self.batch_size = float(batch_size)
        self.site_cap_kw = (float(site_cap_kw) if site_cap_kw is not None
                            else None)
        self.office_kw = float(office_kw)
        self.has_price = price is not None
        self.use_jax = _use_jax(backend)
        self._jit = None

        case0 = cases[0]
        self._scalars = tuple(
            np.array([getattr(c.workload, wkey) for c in cases])
            for wkey in ("n_scenarios", "rate_at_full", "batch_overhead_s")
        ) + tuple(
            np.array([getattr(c.machine, mkey) for c in cases])
            for mkey in ("idle_w", "dyn_w", "alpha", "gamma",
                         "overhead_w_frac"))
        self.deadlines_h = np.array([float(c.deadline_h) for c in cases])

        carbon = case0.carbon or GridCarbonModel()
        start = float(case0.start_hour)
        g0 = math.floor(start * sph) / sph
        bg_day = _bg_table(case0.bands, sph)
        if horizon_h is None:
            horizon_h = self._default_horizon(bg_day, max_days)
        self.horizon_h = float(min(horizon_h, max_days * 24.0))
        T = max(int(math.ceil(self.horizon_h * sph)), 1)
        slot = np.arange(T)
        t_abs = g0 + slot / sph
        s0 = int(round(g0 * sph)) % self.n_slots
        self.rowidx = ((s0 + slot) % self.n_slots).astype(np.int32)
        self.bg = bg_day[self.rowidx]
        self.cf = sample_signal(carbon_signal(carbon), t_abs)
        self.pr = (sample_signal(price, t_abs) if price is not None
                   else np.zeros(T))
        lens = np.full(T, 3600.0 / sph)
        lens[0] = (g0 + 1.0 / sph - start) * 3600.0
        self.lens = lens
        self.office = self.office_kw * self.bg          # (T,) kW
        cap = np.inf if self.site_cap_kw is None else self.site_cap_kw
        self.headroom = cap - self.office               # (T,) kW

    def _default_horizon(self, bg_day: np.ndarray, max_days: int) -> float:
        """Slowest standalone campaign at mid intensity, stretched by the
        demanded-draw vs headroom ratio (a capped fleet runs longer than
        any member would alone), or the largest deadline with margin."""
        durs = []
        draw_kw = 0.0
        for c in self.cases:
            r = model.campaign_rates(0.35, self.batch_size,
                                     float(bg_day.mean()), c.workload,
                                     c.machine)
            durs.append(c.workload.n_scenarios
                        / max(r.scen_per_s, 1e-9) / 3600.0)
            draw_kw += r.p_avg_w / 1000.0
        stretch = 1.0
        if self.site_cap_kw is not None:
            head = max(self.site_cap_kw - self.office_kw * 0.3, 1e-9)
            stretch = max(draw_kw / head, 1.0)
        est = max(durs) * 1.6 * stretch + 48.0
        dl = float(self.deadlines_h.max(initial=0.0))
        if dl > 0.0:
            est = max(est, dl * 1.25 + 24.0)
        return min(est, max_days * 24.0)

    # ------------------------------------------------------------------
    def evaluate(self, u) -> FleetEvalMetrics:
        """`FleetEvalMetrics` for a joint intensity block (..., M,
        n_slots); pure and traceable on the JAX backend."""
        if self.use_jax and not isinstance(u, np.ndarray):
            return self._evaluate_jax(u)
        return self._evaluate_np(np.asarray(u, dtype=float))

    def evaluate_batch(self, U) -> FleetEvalMetrics:
        """Concrete (NumPy) metrics for an (N, M, n_slots) population,
        one jitted call on the JAX backend."""
        U = np.asarray(U, dtype=float)
        if not self.use_jax:
            return self._evaluate_np(U)
        if self._jit is None:
            self._jit = jax.jit(self._evaluate_jax)
        with enable_x64():
            out = self._jit(jnp.asarray(U))
        return FleetEvalMetrics(*(np.asarray(x) for x in out))

    # ------------------------------------------------------------------
    def _rates(self, u, bg_t, xp):
        (_, rate, oh, idle, dyn, alpha, gamma, ohfrac) = self._scalars
        if xp is not np:
            rate, oh, idle, dyn, alpha, gamma, ohfrac = (
                xp.asarray(a) for a in (rate, oh, idle, dyn, alpha, gamma,
                                        ohfrac))
        return model.rates(u, self.batch_size, bg_t, rate_at_full=rate,
                           batch_overhead_s=oh, idle_w=idle, dyn_w=dyn,
                           alpha=alpha, gamma=gamma, overhead_w_frac=ohfrac,
                           xp=xp)

    def _step(self, carry, u, bg_t, cf_t, pr_t, ln, off_t, head_t, xp):
        """One slot of the coupled fleet scan — the one definition both
        backends share (xp = np or jnp)."""
        remaining, rt, kwh, co2, cost, peak = carry
        n_scen = (self._scalars[0] if xp is np
                  else xp.asarray(self._scalars[0]))
        r = self._rates(u, bg_t, xp)
        active = remaining > _FINISH_FRAC * n_scen
        if self.site_cap_kw is None:
            # uncoupled: skip the solve — an infinite headroom would pin
            # f = 1 but still poison gradients with inf in the chain rule
            r2 = r
        else:
            (_, _, _, idle, dyn, alpha, _, _) = self._scalars
            base = (xp.where(active,
                             model.power_w(bg_t, idle, dyn, alpha, xp=xp),
                             0.0) / 1000.0).sum(axis=-1)
            f = xp.ones(base.shape) if hasattr(base, "shape") else 1.0
            r2 = r
            for _ in range(model.SITE_THROTTLE_ITERS):
                fleet_kw = (xp.where(active, r2.p_avg_w, 0.0)
                            / 1000.0).sum(axis=-1)
                f = model.site_throttle(fleet_kw, base, head_t, f, xp=xp)
                r2 = self._rates(u * f[..., None], bg_t, xp)
        scen = xp.maximum(r2.scen_per_s, 1e-30)
        # strict finish-branch selection (see TraceObjective): the tie
        # must take the finish branch, where the gradient cancellation
        # of the residual is analytic
        dt = xp.where(remaining > scen * ln, ln, remaining / scen)
        dt = xp.where(remaining > 0.0, dt, 0.0)
        e = r2.kwh_per_s * dt
        site_kw = (xp.where(active, r2.p_avg_w, 0.0) / 1000.0
                   ).sum(axis=-1) + off_t
        peak = xp.maximum(peak, site_kw)
        return (remaining - r2.scen_per_s * dt, rt + dt, kwh + e,
                co2 + e * cf_t, cost + e * pr_t, peak)

    def _evaluate_jax(self, u) -> FleetEvalMetrics:
        n_scen = jnp.asarray(self._scalars[0])
        u = jnp.asarray(u)
        u_t = jnp.moveaxis(u[..., jnp.asarray(self.rowidx)], -1, 0)
        shape = u.shape[:-1]                      # (..., M)

        def step(carry, xs):
            u_s, bg_t, cf_t, pr_t, ln, off_t, head_t = xs
            return self._step(carry, u_s, bg_t, cf_t, pr_t, ln, off_t,
                              head_t, jnp), None

        zero = jnp.zeros(shape)
        init = (jnp.broadcast_to(n_scen * 1.0, shape), zero, zero, zero,
                zero, jnp.zeros(shape[:-1]))
        xs = (u_t, jnp.asarray(self.bg), jnp.asarray(self.cf),
              jnp.asarray(self.pr), jnp.asarray(self.lens),
              jnp.asarray(self.office), jnp.asarray(self.headroom))
        (remaining, rt, kwh, co2, cost, peak), _ = jax.lax.scan(
            step, init, xs)
        return FleetEvalMetrics(kwh, co2, rt / 3600.0, cost,
                                remaining / n_scen, peak)

    def _evaluate_np(self, u: np.ndarray) -> FleetEvalMetrics:
        n_scen = self._scalars[0]
        u_t = u[..., self.rowidx]                 # (..., M, T)
        shape = u.shape[:-1]
        carry = (np.broadcast_to(n_scen, shape).astype(float).copy(),
                 np.zeros(shape), np.zeros(shape), np.zeros(shape),
                 np.zeros(shape), np.zeros(shape[:-1]))
        for t in range(len(self.lens)):
            if not (carry[0] > 0.0).any():
                break
            carry = self._step(carry, u_t[..., t], float(self.bg[t]),
                               float(self.cf[t]), float(self.pr[t]),
                               float(self.lens[t]), float(self.office[t]),
                               float(self.headroom[t]), np)
        remaining, rt, kwh, co2, cost, peak = carry
        return FleetEvalMetrics(kwh, co2, rt / 3600.0, cost,
                                remaining / n_scen, peak)


def _use_jax(backend: Optional[str]) -> bool:
    if backend == "numpy":
        return False
    if backend == "jax":
        if not _HAS_JAX:
            raise RuntimeError("backend='jax' requested but jax is not "
                               "importable")
        return True
    return _HAS_JAX


def trace_sweep(cases: Sequence, price: Optional[Signal] = None, *,
                slots_per_hour: int = 1, progress_buckets: int = 32,
                max_days: int = 120, backend: Optional[str] = None,
                chunk_days: Optional[int] = None,
                mode: str = "chunked",
                group_sizes: Optional[Sequence[int]] = None,
                group_caps_kw: Optional[Sequence[Optional[float]]] = None,
                group_office_kw: Optional[Sequence[float]] = None,
                precision: str = "fp64",
                devices: Optional[int] = None,
                pallas=None,
                cache_dir: Optional[str] = None) -> List[SimResult]:
    """Evaluate cases on the trace grid; order is preserved.

    Compile -> execute -> summarize: the case batch is lowered into a
    `SweepPlan` (classification and tables memoized by case fingerprint),
    scanned in fixed-shape resumable chunks (`chunk_days`, default
    4-day chunks; finished cases are compacted out, stragglers extend
    without re-scanning anything), and folded into `SimResult`s —
    including per-member `EnsembleStats` for `SignalEnsemble` carbon.

    Use `repro.core.engine.sweep` for mixed workloads — it keeps the
    cheaper periodic path for cases that qualify and calls this for the
    rest.  `progress_buckets` sets the progress resolution of decision
    tables for progress-aware schedules (error scales ~1/buckets and is
    pinned <0.5 % vs the per-batch oracle by tests/test_trace_engine.py).
    `mode="monolithic"` runs the pre-chunking single-scan/retry-doubling
    executor (identical results; kept for equivalence tests and the
    wasted-work benchmark).

    `group_sizes`/`group_caps_kw`/`group_office_kw` partition the cases
    into fleet groups sharing a site power envelope (see `compile_plan`);
    `repro.core.fleet.fleet_sweep` is the session-level entry that also
    returns per-group site rollups.

    Scale-out knobs: `precision` is the plan dtype policy (see
    `compile_plan`), `devices` the `shard_map` lane fan-out and
    `pallas` the coupled-kernel dispatch policy (see `execute_plan`).
    `cache_dir` points compilation at a persistent on-disk plan cache
    (default: the `CARINA_PLAN_CACHE` env var; see `core.plancache`).
    """
    if not len(cases):
        return []
    plan = compile_plan(cases, price, slots_per_hour=slots_per_hour,
                        progress_buckets=progress_buckets, max_days=max_days,
                        group_sizes=group_sizes, group_caps_kw=group_caps_kw,
                        group_office_kw=group_office_kw,
                        precision=precision, cache_dir=cache_dir)
    state = execute_plan(plan, backend=backend, chunk_days=chunk_days,
                         mode=mode, devices=devices, pallas=pallas)
    return summarize_plan(plan, state)
