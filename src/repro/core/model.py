"""The one rate/power model every simulator and engine shares.

The physics of a CARINA campaign segment — contention-throttled effective
throughput, per-batch orchestration overhead, and the convex whole-machine
power draw — used to be copy-pasted three times (both sequential
simulators and the vectorized engine), which meant the model could
silently diverge.  This module is now the single definition:

  * effective throughput   R_eff = R * u * max(1 - gamma * b, 0.05)
  * batch wall time        t_batch = oh_s + batch / max(R_eff, eps)
  * work power             P_work = idle + dyn * max(u + b, 0)^alpha
  * overhead power         P_oh   = idle + dyn * max(f_oh * u + b, 0)^alpha
  * average power          P_avg  = w * P_work + (1 - w) * P_oh
                           with w = t_work / t_batch

Every entry point is polymorphic over the array namespace: pass Python
floats with the default ``xp=SCALAR`` and you get Python floats back
(bit-identical to the historical scalar code paths); pass NumPy arrays
with ``xp=numpy`` or jnp arrays with ``xp=jax.numpy`` and the same
expressions broadcast/trace.  Callers:

  * ``core/simulator.py``   (both sequential simulators; scalars)
  * ``core/engine.py``      (periodic vectorized engine; NumPy)
  * ``core/engine_jax.py``  (trace-grid scan engine; jnp or NumPy)
  * ``core/energy.py``      (``MachineProfile.power`` delegates here)
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace
from typing import Any

# Effective throughput never drops below 5% of nominal (a fully contended
# machine still makes progress) and divisions are guarded by a tiny floor.
CONTENTION_FLOOR = 0.05
RATE_EPS = 1e-9

# Scalar namespace: Python-float arithmetic, bit-identical to the
# historical `max(...)`-based scalar code in the sequential simulators.
SCALAR = SimpleNamespace(maximum=lambda a, b: a if a > b else b,
                         minimum=lambda a, b: a if a < b else b)

# A site-throttled campaign's worker intensity never drops below 5% of
# its demand (the curtailment sheds worker load, not the whole machine;
# same floor philosophy as CONTENTION_FLOOR).
SITE_THROTTLE_FLOOR = 0.05

# Fixed-point steps of the curtailment solve per slot.  The sheddable-
# power update below converges geometrically (site draw within ~0.1% of
# a reachable cap in 3-4 steps); a fixed count keeps the jitted kernels
# shape-stable and every consumer bit-consistent.
SITE_THROTTLE_ITERS = 4


def power_w(load: Any, idle_w: Any, dyn_w: Any, alpha: Any,
            xp=SCALAR) -> Any:
    """Whole-machine power at combined load: idle + dyn * max(load, 0)^alpha.

    This is THE convex-power expression; nothing else in the repo spells
    it out (``MachineProfile.power`` and both power terms in ``rates``
    all come through here).
    """
    return idle_w + dyn_w * xp.maximum(load, 0.0) ** alpha


@dataclasses.dataclass(frozen=True)
class Rates:
    """Per-unit-time view of one (intensity, batch, background) operating
    point.  Fields are floats or arrays, matching the inputs."""
    r_eff: Any          # effective scenarios/s while working
    batch_time_s: Any   # wall seconds per batch (work + orchestration)
    scen_per_s: Any     # scenarios per wall second
    work_frac: Any      # fraction of wall time spent working
    p_work_w: Any       # power while working
    p_oh_w: Any         # power during orchestration overhead
    p_avg_w: Any        # time-averaged power over the batch cycle
    kwh_per_s: Any      # p_avg_w expressed as kWh per wall second


def rates(u: Any, batch_size: Any, background: Any, *,
          rate_at_full: Any, batch_overhead_s: Any,
          idle_w: Any, dyn_w: Any, alpha: Any, gamma: Any,
          overhead_w_frac: Any, xp=SCALAR) -> Rates:
    """The shared rate model at one operating point (scalar or batched)."""
    mx = xp.maximum
    r_eff = rate_at_full * u * mx(1.0 - gamma * background, CONTENTION_FLOOR)
    work_t = batch_size / mx(r_eff, RATE_EPS)
    batch_time = batch_overhead_s + work_t
    scen_per_s = batch_size / batch_time
    work_frac = work_t / batch_time
    p_work = power_w(u + background, idle_w, dyn_w, alpha, xp=xp)
    p_oh = power_w(overhead_w_frac * u + background, idle_w, dyn_w, alpha,
                   xp=xp)
    p_avg = work_frac * p_work + (1.0 - work_frac) * p_oh
    return Rates(r_eff=r_eff, batch_time_s=batch_time, scen_per_s=scen_per_s,
                 work_frac=work_frac, p_work_w=p_work, p_oh_w=p_oh,
                 p_avg_w=p_avg, kwh_per_s=p_avg / 3.6e6)


def campaign_rates(u: Any, batch_size: Any, background: Any,
                   workload, machine, xp=SCALAR) -> Rates:
    """``rates`` with the parameters unpacked from an ``OEMWorkload``-like
    and a ``MachineProfile``-like object (duck-typed; no imports)."""
    return rates(u, batch_size, background,
                 rate_at_full=workload.rate_at_full,
                 batch_overhead_s=workload.batch_overhead_s,
                 idle_w=machine.idle_w, dyn_w=machine.dyn_w,
                 alpha=machine.alpha, gamma=machine.gamma,
                 overhead_w_frac=machine.overhead_w_frac, xp=xp)


def site_throttle(fleet_kw: Any, base_kw: Any, headroom_kw: Any,
                  f: Any = 1.0, xp=SCALAR) -> Any:
    """THE definition of site-coupled contention between concurrent
    campaigns sharing one power envelope: one damped fixed-point step of
    the shared curtailment factor.

    When the summed draw of a fleet's *active* campaigns (`fleet_kw`,
    evaluated at the current factor `f`) exceeds the site headroom
    (site cap minus office draw), every campaign's worker intensity is
    curtailed by the same factor.  Because most of a machine's draw is
    not sheddable (idle power plus the background-induced term,
    `base_kw` = Σ power_w(background) over active campaigns), the update
    iterates on the *sheddable* component:

        f' = clip(f * (headroom - base) / (fleet_kw - base),
                  SITE_THROTTLE_FLOOR, 1.0)

    Consumers apply exactly `SITE_THROTTLE_ITERS` steps per slot,
    re-evaluating the fleet draw at each step's factor — the sequential
    fleet oracle (core/fleet.py), the grouped-lane chunk kernels
    (core/engine_jax.py), and `FleetTraceObjective` all run this same
    loop, so they agree bit for bit.  A reachable cap is met to ~0.1 %;
    an unreachable one (headroom below the non-sheddable draw) pins the
    floor, so campaigns trickle instead of deadlocking and the reported
    site peak honestly exceeds the cap.  Each campaign's effective
    throughput R_eff then scales with the final factor — the
    per-campaign r_eff depends on the *summed* fleet power vs the cap.
    Polymorphic over the array namespace like the rest of the model.
    """
    shed_target = xp.maximum(headroom_kw - base_kw, 0.0)
    shed = xp.maximum(fleet_kw - base_kw, RATE_EPS)
    return xp.maximum(xp.minimum(f * shed_target / shed, 1.0),
                      SITE_THROTTLE_FLOOR)


__all__ = ["CONTENTION_FLOOR", "RATE_EPS", "SCALAR", "SITE_THROTTLE_FLOOR",
           "SITE_THROTTLE_ITERS", "Rates", "power_w", "rates",
           "campaign_rates", "site_throttle"]
