"""Receding-horizon MPC: rolling re-plan on the resumable executor.

The optimizer (core/optimize.py) plans once against a fully known carbon
trace; real grid signals are *forecasts* that go stale mid-campaign.
`MPCSession` closes the loop: every `replan_every_h` hours it
re-optimizes the remaining horizon against a fresh forecast of the
ground-truth trace (a `ForecastModel` from core/signal.py), swaps the
re-optimized schedule into the in-flight plan with
`engine_jax.replace_tables`, and resumes execution against the
*realized* trace from the carried `PlanCursor` — no already-executed
slot is ever recomputed (pinned by the `replans`/`slots_reused` scan
counters).

The control loop, per re-plan instant `t_k`:

1. observe the carried state (scenarios remaining, elapsed hours);
2. forecast the remaining horizon: `model.forecast(truth, t_k, H_k)`;
3. re-optimize the remaining workload under the forecast, warm-started
   from the previous solution's intensity table (day-periodic logits,
   so the previous tail *is* the warm start);
4. swap tables (`replace_tables`) and execute one control interval
   against the realized truth (`execute_interval`).

With `replan_every_h=None` (or infinity) the loop degenerates to
open-loop planning: one solve, one execution — bitwise identical to
`optimize_schedule` + sweep when the forecast is the oracle.

`FleetMPCSession` is the M-campaign analogue on `optimize_fleet` and
grouped-lane plans; both are surfaced as `Campaign.run_mpc(...)` and
`Fleet.run_mpc(...)`.

The value-of-forecast experiment from the West et al. carbon-shifting
studies (arXiv:2503.13705, arXiv:2508.14625) — realized CO2 under
oracle vs day-ahead vs persistence forecasts — is a few lines on top
(examples/mpc_forecast_error.py; pinned by tests/test_mpc.py).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.engine import SweepCase, case_slots_per_hour
from repro.core.signal import (SignalEnsemble, as_forecast, as_trace,
                               sample_signal)


@dataclasses.dataclass
class ReplanRecord:
    """Solve stats of one MPC planning instant (entry 0 is the initial
    plan; later entries are mid-flight re-plans)."""
    at_hour: float            # absolute hour the plan was made
    planned_co2_kg: float     # predicted CO2 of the remaining horizon
    planned_runtime_h: float  # ... and its predicted remaining runtime
    solve_s: float            # optimizer wall time for this solve
    evaluations: int          # candidate evaluations in this solve
    slots_carried: int        # lane x slot units carried into this re-plan
    forecast_mae: float       # realized mean |forecast - truth| over the
    #                           control interval that followed (kg/kWh)


@dataclasses.dataclass
class MPCResult:
    """Outcome of one receding-horizon MPC run.

    `result` is the *realized* outcome (a `SimResult`, or a
    `FleetResult` for fleet sessions) — executed against the ground
    truth, comparable to any sweep row.  `planned_co2_kg` is what the
    initial open-loop plan predicted under its forecast; the gap to
    `realized_co2_kg` is the cost of forecast error (zero under the
    oracle).  `replans[0]` is the initial solve; `n_replans` counts only
    the mid-flight re-plans.
    """
    result: object                      # SimResult | fleet.FleetResult
    schedule: object                    # final schedule(s) in force
    replans: List[ReplanRecord]
    forecast: str                       # forecast model name
    replan_every_h: Optional[float]     # None = open loop
    planned_co2_kg: float
    realized_co2_kg: float
    planned_runtime_h: float
    realized_runtime_h: float
    realized_energy_kwh: float
    solve_s: float                      # summed optimizer wall time
    forecast_mae: float                 # mean |forecast - truth| over
    #                                     every executed hour (kg/kWh)
    slots_reused: int                   # executed lane x slot units carried
    #                                     across re-plans (never recomputed)

    @property
    def n_replans(self) -> int:
        return max(len(self.replans) - 1, 0)


def _as_member_signal(fc: SignalEnsemble):
    """A single-member forecast collapses to its bare member signal, so
    an oracle forecast hands the optimizer the *same object* as an
    open-loop optimize against the truth (bitwise-identical plans,
    shared signal-grid cache entries)."""
    return fc.member(0) if fc.n_members == 1 else fc


def _check_truth_coverage(truth, start_hour: float, deadline_h: float
                          ) -> None:
    """An MPC session executes against the realized trace; silently
    holding the archive's last value past its end (TraceSignal's default
    pad) would fabricate realized emissions.  Require coverage of the
    campaign window up front (see TraceSignal.pad for the policy)."""
    end = getattr(truth, "end_hour", None)
    if end is None:
        return
    need = start_hour + deadline_h
    if end < need:
        raise ValueError(
            f"ground-truth trace '{getattr(truth, 'name', 'trace')}' ends "
            f"at hour {end:g} but the campaign needs coverage through "
            f"hour {need:g} (start {start_hour:g} + deadline "
            f"{deadline_h:g}); extend the archive or shorten the deadline")


def _interval_mae(fc_sig, truth, hours: np.ndarray) -> float:
    """Realized mean absolute forecast error over executed hours."""
    if hours.size == 0:
        return 0.0
    return float(np.abs(sample_signal(fc_sig, hours)
                        - sample_signal(truth, hours)).mean())


class MPCSession:
    """Receding-horizon MPC over one campaign (see module docstring).

    `case` binds the workload/machine/bands and the *initial* schedule
    (used only as the first solve's warm start); `truth` is the realized
    hourly carbon trace; `constraints` must include a finite runtime cap
    (the horizon the receding re-plans recede toward).  `solver` kwargs
    are forwarded to every `optimize_schedule` call (method, candidates,
    iterations, steps, seed, init, ...).
    """

    def __init__(self, case: SweepCase, truth, *,
                 objective="co2",
                 constraints: Optional[dict] = None,
                 forecast="oracle",
                 replan_every_h: Optional[float] = 24.0,
                 price=None, backend: Optional[str] = None,
                 chunk_days: Optional[int] = None,
                 max_days: int = 120,
                 cache_dir: Optional[str] = None,
                 solver: Optional[dict] = None):
        from repro.core.optimize import canonical_metric
        self.constraints = {canonical_metric(k): float(v)
                            for k, v in dict(constraints or {}).items()}
        deadline = self.constraints.get("runtime_h", 0.0)
        if not deadline or not math.isfinite(deadline):
            raise ValueError(
                "MPC needs a finite runtime cap: pass "
                "constraints={'runtime_h': ...} (or deadline_h= via "
                "Campaign.run_mpc) — the receding horizon is defined "
                "relative to it")
        self.truth = as_trace(truth, name="truth")
        _check_truth_coverage(self.truth, case.start_hour, deadline)
        self.case = dataclasses.replace(case, carbon=self.truth,
                                        deadline_h=deadline)
        self.objective = objective
        self.model = as_forecast(forecast)
        if replan_every_h is not None:
            k = float(replan_every_h)
            if k <= 0:
                raise ValueError(
                    f"replan_every_h must be positive (or None for open "
                    f"loop), got {replan_every_h}")
            replan_every_h = None if math.isinf(k) else k
        self.replan_every_h = replan_every_h
        self.price = price
        self.backend = backend
        self.chunk_days = chunk_days
        self.max_days = int(max_days)
        self.cache_dir = cache_dir
        self.solver = dict(solver or {})

    # ------------------------------------------------------------------
    def _forecast_signal(self, now_h: float, horizon_h: float):
        fc = self.model.forecast(self.truth, now_h, horizon_h)
        return _as_member_signal(fc)

    def _solve(self, opt_case: SweepCase, remaining_cap_h: float,
               init) -> "object":
        from repro.core.optimize import optimize_schedule
        kwargs = dict(self.solver)
        if init is not None:
            # a mid-flight warm start (the incumbent's own table) always
            # wins over a solver-level init, which seeds only solve 0
            kwargs["init"] = init
        constraints = dict(self.constraints)
        constraints["runtime_h"] = remaining_cap_h
        return optimize_schedule(opt_case, self.objective, constraints,
                                 price=self.price, backend=self.backend,
                                 **kwargs)

    def run(self) -> MPCResult:
        from repro.core.engine_jax import (compile_plan, execute_interval,
                                           replace_tables, summarize_plan)
        case = self.case
        truth = self.truth
        deadline = case.deadline_h
        K = self.replan_every_h

        # initial solve at t = start against the first forecast
        fc_sig = self._forecast_signal(case.start_hour,
                                       deadline * 1.25 + 48.0)
        t_solve = time.perf_counter()
        res = self._solve(dataclasses.replace(case, carbon=fc_sig),
                          deadline, init=None)
        solve_s = time.perf_counter() - t_solve
        planned_co2 = float(np.mean(res.metrics.co2_kg))
        planned_runtime = float(np.mean(res.metrics.runtime_h))
        records = [ReplanRecord(
            at_hour=case.start_hour, planned_co2_kg=planned_co2,
            planned_runtime_h=planned_runtime, solve_s=solve_s,
            evaluations=res.evaluations, slots_carried=0,
            forecast_mae=0.0)]
        sched = res.schedule
        sph = case_slots_per_hour(dataclasses.replace(case, schedule=sched))
        interval_slots = (None if K is None
                          else max(1, int(round(K * sph))))

        # one plan against the realized truth, executed in intervals
        plan = compile_plan(
            [dataclasses.replace(case, schedule=sched)], self.price,
            slots_per_hour=sph, max_days=self.max_days,
            cache_dir=self.cache_dir)
        g0 = float(plan.g0[0])
        cursor = None
        fc_sigs = [fc_sig]
        mae_hours = 0.0
        mae_sum = 0.0
        slots_reused = 0
        while True:
            t_prev = 0 if cursor is None else cursor.t0
            until = (None if interval_slots is None
                     else t_prev + interval_slots)
            cursor = execute_interval(plan, cursor, until_slot=until,
                                      backend=self.backend,
                                      chunk_days=self.chunk_days)
            hours = g0 + np.arange(t_prev, cursor.t0) / sph
            mae = _interval_mae(fc_sigs[-1], truth, hours)
            records[-1] = dataclasses.replace(records[-1], forecast_mae=mae)
            mae_sum += mae * hours.size
            mae_hours += hours.size
            if cursor.done:
                break
            now = g0 + cursor.t0 / sph
            remaining_cap = deadline - (now - case.start_hour)
            if remaining_cap <= 1.0 / sph:
                # deadline (nearly) spent: no room to re-plan — run the
                # last schedule to completion (best effort past the cap)
                cursor = execute_interval(plan, cursor,
                                          backend=self.backend,
                                          chunk_days=self.chunk_days)
                break
            remaining_scen = float(cursor.state.remaining[0])
            fc_sig = self._forecast_signal(now, remaining_cap * 1.25 + 48.0)
            fc_sigs.append(fc_sig)
            opt_case = dataclasses.replace(
                case, schedule=sched, carbon=fc_sig, start_hour=now,
                deadline_h=remaining_cap,
                workload=dataclasses.replace(case.workload,
                                             n_scenarios=remaining_scen))
            t_solve = time.perf_counter()
            res = self._solve(opt_case, remaining_cap,
                              init=sched.intensity_table()
                              if hasattr(sched, "intensity_table") else None)
            solve_s = time.perf_counter() - t_solve
            sched = res.schedule
            slots_reused += cursor.t0 * plan.n_lanes
            records.append(ReplanRecord(
                at_hour=now, planned_co2_kg=float(np.mean(res.metrics.co2_kg)),
                planned_runtime_h=float(np.mean(res.metrics.runtime_h)),
                solve_s=solve_s, evaluations=res.evaluations,
                slots_carried=cursor.t0 * plan.n_lanes, forecast_mae=0.0))
            plan = replace_tables(plan, cursor, schedules={0: sched},
                                  cache_dir=self.cache_dir)

        realized = summarize_plan(plan, cursor.state)[0]
        return MPCResult(
            result=realized, schedule=sched, replans=records,
            forecast=self.model.name, replan_every_h=K,
            planned_co2_kg=planned_co2, realized_co2_kg=realized.co2_kg,
            planned_runtime_h=planned_runtime,
            realized_runtime_h=realized.runtime_h,
            realized_energy_kwh=realized.energy_kwh,
            solve_s=sum(r.solve_s for r in records),
            forecast_mae=(mae_sum / mae_hours if mae_hours else 0.0),
            slots_reused=slots_reused)


class FleetMPCSession:
    """Receding-horizon MPC over M campaigns under one site.

    The fleet analogue of `MPCSession`: each re-plan jointly
    re-optimizes every *unfinished* campaign's remaining workload via
    `optimize_fleet` (warm-started from the previous schedules'
    intensity tables), swaps all changed tables in one `replace_tables`
    call, and resumes the grouped-lane plan.  Campaigns that finish
    drop out of the joint search; campaigns whose deadline is spent
    fall back to best-effort (uncapped) completion.
    """

    def __init__(self, cases: Sequence[SweepCase], site, truth, *,
                 objective="co2",
                 constraints: Optional[dict] = None,
                 forecast="oracle",
                 replan_every_h: Optional[float] = 24.0,
                 price=None, backend: Optional[str] = None,
                 chunk_days: Optional[int] = None,
                 max_days: int = 240,
                 cache_dir: Optional[str] = None,
                 solver: Optional[dict] = None):
        if not len(cases):
            raise ValueError("FleetMPCSession needs at least one case")
        deadlines = [float(getattr(c, "deadline_h", 0.0) or 0.0)
                     for c in cases]
        if not all(d > 0 and math.isfinite(d) for d in deadlines):
            raise ValueError(
                "MPC needs a finite deadline per campaign (the receding "
                f"horizon is defined relative to it); got {deadlines}")
        starts = {c.start_hour for c in cases}
        if len(starts) > 1:
            raise ValueError(
                f"fleet MPC campaigns share the site clock; got "
                f"start_hours {sorted(starts)}")
        self.truth = as_trace(truth, name="truth")
        start = cases[0].start_hour
        _check_truth_coverage(self.truth, start, max(deadlines))
        self.cases = [dataclasses.replace(c, carbon=self.truth)
                      for c in cases]
        self.site = site
        self.objective = objective
        self.constraints = dict(constraints or {})
        self.model = as_forecast(forecast)
        if replan_every_h is not None:
            k = float(replan_every_h)
            if k <= 0:
                raise ValueError(
                    f"replan_every_h must be positive (or None for open "
                    f"loop), got {replan_every_h}")
            replan_every_h = None if math.isinf(k) else k
        self.replan_every_h = replan_every_h
        self.price = price
        self.backend = backend
        self.chunk_days = chunk_days
        self.max_days = int(max_days)
        self.cache_dir = cache_dir
        self.solver = dict(solver or {})

    # ------------------------------------------------------------------
    def _solve(self, opt_cases: Sequence[SweepCase], init):
        from repro.core.optimize import optimize_fleet
        kwargs = dict(self.solver)
        if init is not None:
            kwargs["init"] = init
        return optimize_fleet(list(opt_cases), site=self.site,
                              objective=self.objective,
                              constraints=self.constraints or None,
                              price=self.price, backend=self.backend,
                              **kwargs)

    def run(self) -> MPCResult:
        from repro.core.engine_jax import (compile_plan, execute_interval,
                                           replace_tables, summarize_plan)
        from repro.core.fleet import FleetResult, _rollup
        cases = self.cases
        truth = self.truth
        M = len(cases)
        start = cases[0].start_hour
        deadlines = np.array([c.deadline_h for c in cases])
        K = self.replan_every_h
        cap = getattr(self.site, "power_cap_kw", None)
        office = float(getattr(self.site, "office_kw", 0.0) or 0.0)

        horizon0 = float(deadlines.max()) * 1.25 + 48.0
        fc_sig = _as_member_signal(self.model.forecast(truth, start,
                                                       horizon0))
        t_solve = time.perf_counter()
        res = self._solve([dataclasses.replace(c, carbon=fc_sig)
                           for c in cases], init=None)
        solve_s = time.perf_counter() - t_solve
        scheds = list(res.schedules)
        planned_co2 = float(res.site.co2_kg)
        planned_runtime = float(res.site.runtime_h)
        records = [ReplanRecord(
            at_hour=start, planned_co2_kg=planned_co2,
            planned_runtime_h=planned_runtime, solve_s=solve_s,
            evaluations=res.evaluations, slots_carried=0,
            forecast_mae=0.0)]
        sph = 1
        for c, s in zip(cases, scheds):
            sph = math.lcm(sph, case_slots_per_hour(
                dataclasses.replace(c, schedule=s)))
        interval_slots = (None if K is None
                          else max(1, int(round(K * sph))))

        plan = compile_plan(
            [dataclasses.replace(c, schedule=s)
             for c, s in zip(cases, scheds)],
            self.price, slots_per_hour=sph, max_days=self.max_days,
            group_sizes=[M], group_caps_kw=[cap], group_office_kw=[office],
            cache_dir=self.cache_dir)
        g0 = float(plan.g0[0])
        cursor = None
        last_fc = fc_sig
        mae_hours = 0.0
        mae_sum = 0.0
        slots_reused = 0
        while True:
            t_prev = 0 if cursor is None else cursor.t0
            until = (None if interval_slots is None
                     else t_prev + interval_slots)
            cursor = execute_interval(plan, cursor, until_slot=until,
                                      backend=self.backend,
                                      chunk_days=self.chunk_days)
            hours = g0 + np.arange(t_prev, cursor.t0) / sph
            mae = _interval_mae(last_fc, truth, hours)
            records[-1] = dataclasses.replace(records[-1], forecast_mae=mae)
            mae_sum += mae * hours.size
            mae_hours += hours.size
            if cursor.done:
                break
            now = g0 + cursor.t0 / sph
            elapsed = now - start
            remaining_caps = deadlines - elapsed
            # campaigns still running with re-plannable room; a spent
            # deadline degrades to best-effort (uncapped) completion
            active = [int(plan.lane_case[la]) for la in cursor.active]
            replannable = [m for m in active
                           if remaining_caps[m] > 1.0 / sph]
            if not replannable:
                cursor = execute_interval(plan, cursor,
                                          backend=self.backend,
                                          chunk_days=self.chunk_days)
                break
            rem = cursor.state.remaining
            horizon = float(remaining_caps[replannable].max()) * 1.25 + 48.0
            last_fc = _as_member_signal(self.model.forecast(truth, now,
                                                            horizon))
            opt_cases = []
            for m in replannable:
                lane = int(np.flatnonzero(plan.lane_case == m)[0])
                opt_cases.append(dataclasses.replace(
                    cases[m], schedule=scheds[m], carbon=last_fc,
                    start_hour=now, deadline_h=float(remaining_caps[m]),
                    workload=dataclasses.replace(
                        cases[m].workload,
                        n_scenarios=float(rem[lane]))))
            init = np.stack([scheds[m].intensity_table()
                             if hasattr(scheds[m], "intensity_table")
                             else np.full(24 * sph, 0.6)
                             for m in replannable])
            t_solve = time.perf_counter()
            res = self._solve(opt_cases, init=init)
            solve_s = time.perf_counter() - t_solve
            for m, s in zip(replannable, res.schedules):
                scheds[m] = s
            slots_reused += cursor.t0 * plan.n_lanes
            records.append(ReplanRecord(
                at_hour=now, planned_co2_kg=float(res.site.co2_kg),
                planned_runtime_h=float(res.site.runtime_h),
                solve_s=solve_s, evaluations=res.evaluations,
                slots_carried=cursor.t0 * plan.n_lanes, forecast_mae=0.0))
            plan = replace_tables(
                plan, cursor,
                schedules={m: scheds[m] for m in replannable},
                cache_dir=self.cache_dir)

        results = summarize_plan(plan, cursor.state)
        peak = (float(cursor.state.site_kw_peak.max())
                if cursor.state.site_kw_peak is not None else None)
        realized = FleetResult(policy="mpc", campaigns=results,
                               site=_rollup("mpc", results, peak_kw=peak))
        return MPCResult(
            result=realized, schedule=list(scheds), replans=records,
            forecast=self.model.name, replan_every_h=K,
            planned_co2_kg=planned_co2,
            realized_co2_kg=realized.site.co2_kg,
            planned_runtime_h=planned_runtime,
            realized_runtime_h=realized.site.runtime_h,
            realized_energy_kwh=realized.site.energy_kwh,
            solve_s=sum(r.solve_s for r in records),
            forecast_mae=(mae_sum / mae_hours if mae_hours else 0.0),
            slots_reused=slots_reused)


def run_mpc(case: SweepCase, truth, **kwargs) -> MPCResult:
    """Functional one-shot form of `MPCSession` (see class docstring)."""
    return MPCSession(case, truth, **kwargs).run()


__all__ = ["MPCSession", "FleetMPCSession", "MPCResult", "ReplanRecord",
           "run_mpc"]
