"""Disk-backed plan cache: cross-process warm starts for the trace engine.

`compile_plan` memoizes per-case compilation (classification, probing,
table lowering) by value fingerprint — but the memo dies with the
process, and CARINA's whole premise is *recurrent* analytics: the same
fleet re-swept every refresh cycle.  This module persists the expensive
compile artifacts (`_CaseCompiled`: decision tables, probe metadata,
duration estimates) to a content-addressed store on disk so the second
nightly cycle pays a file read instead of a re-probe.

Store layout and contract:

  * **Content-addressed keys.**  An entry's filename is the SHA-256 of
    its case fingerprint (the same `_freeze` value identity the
    in-memory memo uses: schedule/workload/machine/bands/carbon by
    field values, price, sph/B/max_days) salted with `SCHEMA_VERSION`.
    Bumping the schema version orphans every old entry — versioned
    invalidation without a migration step (orphans age out via the LRU
    sweep).  Cases whose fingerprint is opaque (closure-bearing
    schedules — no value identity) are never stored.
  * **Two entry kinds.**  `*.case` holds one `_CaseCompiled`; `*.plan`
    holds a whole compiled batch (every `_CaseCompiled` of one
    `compile_plan` call, keyed by the tuple of case keys) so a warm
    start of an S-case sweep is one file read, not S.  Both serialize
    to NumPy ``.npz`` archives (arrays exact to the byte, JSON
    metadata, no pickle) — results after a disk hit are bitwise
    identical to a cold compile.
  * **Atomic writes, corruption-tolerant reads.**  Entries are written
    to a temp file and `os.replace`d into place; a reader either sees
    a whole entry or none.  Any load failure (truncated file, bad zip,
    schema drift) is treated as a miss: the entry is deleted and the
    case recompiled — a corrupt cache can cost time, never correctness.
  * **Size-bounded LRU.**  Hits refresh the entry's mtime; when the
    store exceeds `max_bytes` (``CARINA_PLAN_CACHE_MB``, default 512),
    the oldest entries are swept until it is back under ~3/4 of the
    bound.

The engine resolves the cache via `get_cache(cache_dir)`: an explicit
``cache_dir=`` wins, else the ``CARINA_PLAN_CACHE`` environment
variable, else caching is off.  `scan_stats()` exposes the traffic as
`disk_hits`/`disk_misses`; `repro.core.engine_jax.plan_cache_info()`
rolls both memo layers into one dashboard row.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import tempfile
from typing import Dict, List, Optional, Tuple

import numpy as np

#: Version salt of the on-disk entry format *and* of the compile
#: semantics it captures.  Bump whenever `_CaseCompiled`, `ProbeInfo`,
#: probing, or table lowering change meaning — old entries then simply
#: never match (versioned invalidation) and age out of the store.
SCHEMA_VERSION = 1

_DEFAULT_MAX_MB = 512.0


# ---------------------------------------------------------------------------
# Stable digests of fingerprint values.  `_freeze` (engine_jax) lowers a
# case to nested tuples of primitives, ndarray descriptors, and class
# objects; this walk maps that structure to one SHA-256, with explicit
# type tags so e.g. 1 and "1" and True cannot collide.
# ---------------------------------------------------------------------------
def _feed(h, obj) -> None:
    if obj is None:
        h.update(b"N")
    elif isinstance(obj, bool):
        h.update(b"T" if obj else b"F")
    elif isinstance(obj, int):
        h.update(b"i" + str(obj).encode())
    elif isinstance(obj, float):
        h.update(b"f" + repr(obj).encode())
    elif isinstance(obj, str):
        b = obj.encode()
        h.update(b"s" + str(len(b)).encode() + b":" + b)
    elif isinstance(obj, bytes):
        h.update(b"b" + str(len(obj)).encode() + b":" + obj)
    elif isinstance(obj, type):
        h.update(b"t" + f"{obj.__module__}.{obj.__qualname__}".encode())
    elif isinstance(obj, tuple):
        h.update(b"(")
        for v in obj:
            _feed(h, v)
        h.update(b")")
    else:
        # hashable leaf with a value-based __hash__ (enum members and
        # the like); repr is the best stable identity available — a
        # drifting repr only costs a recompile, never a wrong hit
        # within one python version
        h.update(b"o" + type(obj).__qualname__.encode()
                 + repr(obj).encode())


def fingerprint_digest(frozen, kind: str = "case") -> str:
    """Hex digest of one frozen case fingerprint (or, for
    ``kind="plan"``, of a tuple of per-case digests), salted with the
    schema version."""
    h = hashlib.sha256()
    _feed(h, (kind, SCHEMA_VERSION, frozen))
    return h.hexdigest()


# ---------------------------------------------------------------------------
# _CaseCompiled <-> npz payload
# ---------------------------------------------------------------------------
def _encode_case(comp, prefix: str, meta: dict, arrays: dict) -> None:
    probe = None
    if comp.probe is not None:
        probe = {"progress_dep": bool(comp.probe.progress_dep),
                 "elapsed_dep": bool(comp.probe.elapsed_dep),
                 "carbon_dep": bool(comp.probe.carbon_dep)}
        arrays[prefix + "ps"] = np.asarray(
            [[float(t), float(u), float(b)]
             for t, u, b in comp.probe.samples],
            dtype=np.float64).reshape(-1, 3)
    if comp.prof is not None:
        arrays[prefix + "pu"] = np.asarray(comp.prof[0])
        arrays[prefix + "pb"] = np.asarray(comp.prof[1])
    if comp.table is not None:
        arrays[prefix + "tu"] = np.asarray(comp.table[0])
        arrays[prefix + "tb"] = np.asarray(comp.table[1])
    meta[prefix] = {"periodic": bool(comp.periodic),
                    "carbon_dep": bool(comp.carbon_dep),
                    "est_h": float(comp.est_h),
                    "stalled": bool(comp.stalled),
                    "prof": comp.prof is not None,
                    "table": comp.table is not None,
                    "probe": probe}


def _decode_case(prefix: str, meta: dict, arrays) -> "object":
    from repro.core.engine_jax import ProbeInfo, _CaseCompiled
    m = meta[prefix]
    probe = None
    if m["probe"] is not None:
        samples = [(float(t), float(u), float(b))
                   for t, u, b in arrays[prefix + "ps"]]
        probe = ProbeInfo(bool(m["probe"]["progress_dep"]),
                          bool(m["probe"]["elapsed_dep"]),
                          bool(m["probe"]["carbon_dep"]), samples)
    prof = ((arrays[prefix + "pu"], arrays[prefix + "pb"])
            if m["prof"] else None)
    table = ((arrays[prefix + "tu"], arrays[prefix + "tb"])
             if m["table"] else None)
    return _CaseCompiled(prof=prof, probe=probe, table=table,
                         periodic=bool(m["periodic"]),
                         carbon_dep=bool(m["carbon_dep"]),
                         est_h=float(m["est_h"]),
                         stalled=bool(m["stalled"]))


# ---------------------------------------------------------------------------
# The store
# ---------------------------------------------------------------------------
class PlanCache:
    """One directory of content-addressed compile artifacts (see the
    module docstring for the key/invalidation/eviction contract)."""

    def __init__(self, root: str, max_bytes: Optional[int] = None):
        self.root = os.path.abspath(os.path.expanduser(root))
        if max_bytes is None:
            mb = float(os.environ.get("CARINA_PLAN_CACHE_MB",
                                      _DEFAULT_MAX_MB))
            max_bytes = int(mb * 1e6)
        self.max_bytes = int(max_bytes)
        os.makedirs(self.root, exist_ok=True)

    # -- paths ---------------------------------------------------------
    def _path(self, digest: str, kind: str) -> str:
        return os.path.join(self.root, f"{digest}.{kind}")

    # -- low-level entry IO --------------------------------------------
    def _store(self, path: str, meta: dict, arrays: Dict[str, np.ndarray]
               ) -> None:
        """Atomic write: serialize to memory, write a sibling temp file,
        `os.replace` into place.  IO failures are swallowed — a cache
        that cannot write is slow, not broken."""
        meta = dict(meta)
        meta["schema"] = SCHEMA_VERSION
        buf = io.BytesIO()
        payload = dict(arrays)
        payload["__meta__"] = np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8)
        np.savez(buf, **payload)
        try:
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    f.write(buf.getvalue())
                os.replace(tmp, path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        except OSError:
            return
        self._evict()

    def _load(self, path: str) -> Optional[Tuple[dict, dict]]:
        """Read one entry; any failure (missing, truncated, bad zip,
        schema drift) deletes the entry and reports a miss."""
        try:
            with np.load(path) as npz:
                arrays = {k: npz[k] for k in npz.files}
            meta = json.loads(bytes(arrays.pop("__meta__")).decode())
            if meta.get("schema") != SCHEMA_VERSION:
                raise ValueError(f"schema {meta.get('schema')} != "
                                 f"{SCHEMA_VERSION}")
        except FileNotFoundError:
            return None
        except Exception:
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        try:                              # LRU recency: touch on hit
            os.utime(path, None)
        except OSError:
            pass
        return meta, arrays

    # -- case entries --------------------------------------------------
    def get_case(self, frozen) -> Optional["object"]:
        """The `_CaseCompiled` stored under this fingerprint, or None."""
        entry = self._load(self._path(fingerprint_digest(frozen), "case"))
        if entry is None:
            return None
        meta, arrays = entry
        try:
            return _decode_case("c", meta, arrays)
        except Exception:
            return None

    def put_case(self, frozen, comp) -> None:
        meta: dict = {}
        arrays: Dict[str, np.ndarray] = {}
        _encode_case(comp, "c", meta, arrays)
        self._store(self._path(fingerprint_digest(frozen), "case"),
                    meta, arrays)

    # -- whole-batch (SweepPlan) entries -------------------------------
    def batch_digest(self, frozen_keys) -> str:
        """Digest of one compile batch: the ordered tuple of per-case
        fingerprints (group layout, precision, and execution knobs do
        not enter — they affect lowering and the scan, not the per-case
        compile artifacts the entry holds)."""
        return fingerprint_digest(tuple(frozen_keys), kind="plan")

    def get_batch(self, digest: str, n_cases: int) -> Optional[List]:
        """The compiled-case list of one whole batch, or None."""
        entry = self._load(self._path(digest, "plan"))
        if entry is None:
            return None
        meta, arrays = entry
        try:
            if int(meta["n"]) != n_cases:
                return None
            return [_decode_case(f"c{i}_", meta, arrays)
                    for i in range(n_cases)]
        except Exception:
            return None

    def put_batch(self, digest: str, comps) -> None:
        meta: dict = {"n": len(comps)}
        arrays: Dict[str, np.ndarray] = {}
        for i, comp in enumerate(comps):
            _encode_case(comp, f"c{i}_", meta, arrays)
        self._store(self._path(digest, "plan"), meta, arrays)

    # -- accounting + eviction -----------------------------------------
    def _entries(self) -> List[os.DirEntry]:
        try:
            return [e for e in os.scandir(self.root)
                    if e.is_file() and (e.name.endswith(".case")
                                        or e.name.endswith(".plan"))]
        except OSError:
            return []

    def info(self) -> Tuple[int, int]:
        """(entry count, total bytes) currently on disk."""
        entries = self._entries()
        total = 0
        for e in entries:
            try:
                total += e.stat().st_size
            except OSError:
                pass
        return len(entries), total

    def clear(self) -> None:
        """Delete every entry (leaves the directory in place)."""
        for e in self._entries():
            try:
                os.unlink(e.path)
            except OSError:
                pass

    def _evict(self) -> None:
        """LRU sweep: when the store exceeds `max_bytes`, drop the
        oldest-mtime entries until it is back under ~3/4 of the bound
        (hysteresis, so a hot store is not swept on every put)."""
        stats = []
        total = 0
        for e in self._entries():
            try:
                st = e.stat()
            except OSError:
                continue
            stats.append((st.st_mtime_ns, st.st_size, e.path))
            total += st.st_size
        if total <= self.max_bytes:
            return
        target = int(self.max_bytes * 0.75)
        for _, size, path in sorted(stats):
            if total <= target:
                break
            try:
                os.unlink(path)
                total -= size
            except OSError:
                pass


# ---------------------------------------------------------------------------
# Resolution: explicit dir > CARINA_PLAN_CACHE env > off.  One PlanCache
# per resolved directory, process-wide.
# ---------------------------------------------------------------------------
_CACHES: Dict[str, PlanCache] = {}


def resolve_cache_dir(cache_dir: Optional[str] = None) -> Optional[str]:
    if cache_dir is None:
        cache_dir = os.environ.get("CARINA_PLAN_CACHE") or None
    return cache_dir or None


def get_cache(cache_dir: Optional[str] = None) -> Optional[PlanCache]:
    """The `PlanCache` for `cache_dir` (or the ``CARINA_PLAN_CACHE``
    default), memoized per directory; None when caching is off."""
    root = resolve_cache_dir(cache_dir)
    if root is None:
        return None
    root = os.path.abspath(os.path.expanduser(root))
    cache = _CACHES.get(root)
    if cache is None:
        cache = PlanCache(root)
        _CACHES[root] = cache
    return cache


__all__ = ["SCHEMA_VERSION", "PlanCache", "fingerprint_digest",
           "get_cache", "resolve_cache_dir"]
