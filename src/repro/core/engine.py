"""Vectorized sweep engine: evaluate many (schedule x workload x grid-curve)
combinations in one batched NumPy pass.

The sequential simulators walk a campaign segment by segment in Python;
fine for six policies, too slow for the ROADMAP goal of sweeping "as many
scenarios as you can imagine".  This engine exploits the structure every
bundled schedule and signal share: decisions and signals are periodic over
24 h and piecewise-constant per hour (band edges fall on integer hours).
A campaign is then a periodic piecewise-linear accumulation of scenarios,
energy, CO2e and cost, so for S cases we can:

  1. sample each case's schedule/signals onto a 24-slot hourly grid
     (S x 24 arrays of intensity, batch, background, carbon, price);
  2. derive per-slot scenario/energy/CO2e/cost *rates* with closed-form
     NumPy expressions (same contention + convex-power model as the
     sequential simulator);
  3. jump over whole days with integer arithmetic and resolve the final
     partial day with one cumulative-sum search — no per-segment loop.

Agreement with the per-batch oracle `simulate_campaign_exact` is pinned to
<0.5 % by tests/test_session_engine.py (the same tolerance the coarse
sequential path is held to); against the coarse sequential path the engine
agrees to float precision (both integrate the same piecewise-hourly
model).  Schedules that vary within an
hour are not representable on the hourly grid, nor are schedules that
consult the progress/elapsed_h context fields (the grid is sampled once
with both at zero) — use the sequential simulators for those.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.carbon import GridCarbonModel
from repro.core.energy import MachineProfile
from repro.core.policy import TimeBands
from repro.core.schedule import Schedule, SchedulingContext, as_schedule
from repro.core.signal import Signal, sample_hourly
from repro.core.simulator import SimResult, fill_deltas
from repro.core.workload import OEMWorkload


@dataclasses.dataclass(frozen=True)
class SweepCase:
    """One point of a sweep: a schedule run against one scenario setup."""
    schedule: Schedule
    workload: OEMWorkload
    machine: MachineProfile = MachineProfile()
    bands: TimeBands = TimeBands()
    carbon: Optional[GridCarbonModel] = None
    start_hour: float = 9.0
    label: str = ""

    def name(self) -> str:
        return self.label or as_schedule(self.schedule).name


def _band_table(bands: TimeBands):
    """(band_name[24], background[24]) for one TimeBands, memoized — band
    lookups are the hot part of profile sampling in large sweeps."""
    key = bands  # frozen dataclass -> hashable
    hit = _band_table.cache.get(key)
    if hit is None:
        if any(float(e) % 1.0 for e in bands.edges()):
            raise ValueError(
                "the vectorized engine samples bands on the hourly grid and "
                "cannot represent sub-hour band edges; use the sequential "
                "simulators for these TimeBands")
        names = [bands.band_at(float(h)) for h in range(24)]
        hit = (names, np.array([bands.background(b) for b in names]))
        _band_table.cache[key] = hit
    return hit


_band_table.cache = {}


def _carbon_table(carbon: GridCarbonModel) -> np.ndarray:
    try:
        hit = _carbon_table.cache.get(carbon)
    except TypeError:                       # unhashable hourly_curve (list)
        return np.array(sample_hourly(carbon))
    if hit is None:
        hit = np.array(sample_hourly(carbon))
        _carbon_table.cache[carbon] = hit
    return hit


_carbon_table.cache = {}


def hourly_profile(schedule, bands: TimeBands, carbon: GridCarbonModel,
                   price: Optional[Signal] = None):
    """Sample a schedule's decisions on the 24-hour grid.

    Returns (intensity[24], batch[24]).  Exact for any schedule whose
    decision is constant within each local hour (all bundled ones are).
    The bundled Policy/HourlyPolicy classes take a closed-form path; any
    schedule with its own decide() is sampled through the full context.
    """
    from repro.core.policy import HourlyPolicy, Policy

    sched = as_schedule(schedule)
    band_names, bg24 = _band_table(bands)
    decide = type(sched).decide if isinstance(sched, Policy) else None
    if decide is HourlyPolicy.decide and sched.hourly_intensity:
        u = np.array(sched.hourly_intensity, dtype=float)
        if sched.low_priority:
            u = u * 0.82
        return u, np.full(24, float(sched.batch_size))
    if decide in (Policy.decide, HourlyPolicy.decide):
        per_band = {b: sched.intensity_at(b) for b in set(band_names)}
        u = np.array([per_band[b] for b in band_names])
        return u, np.full(24, float(sched.batch_size))

    cf24 = _carbon_table(carbon)
    pr24 = ([price.at(float(h)) for h in range(24)] if price is not None
            else None)
    u = np.empty(24)
    batch = np.empty(24)
    for h in range(24):
        ctx = SchedulingContext(
            hour_of_day=float(h), band=band_names[h],
            background=float(bg24[h]), carbon_factor=float(cf24[h]),
            price_usd_per_kwh=pr24[h] if pr24 is not None else 0.0)
        d = sched.decide(ctx)
        # the grid is sampled once per hour-of-day and reused for every
        # simulated day, so a schedule that consults progress/elapsed_h is
        # not representable — probe at a different campaign position and
        # refuse rather than return silently wrong sweep numbers
        d_late = sched.decide(dataclasses.replace(
            ctx, elapsed_h=24.0 + h, progress=0.5))
        if (d_late.intensity, d_late.batch_size) != (d.intensity,
                                                     d.batch_size):
            raise ValueError(
                f"schedule {sched.name!r} varies with campaign progress/"
                "elapsed time; the vectorized engine's periodic hourly grid "
                "cannot represent it — use the sequential simulators")
        u[h] = d.intensity
        batch[h] = d.batch_size
    return u, batch


def sweep(cases: Sequence[SweepCase],
          price: Optional[Signal] = None) -> List[SimResult]:
    """Evaluate all cases in one vectorized pass; order is preserved."""
    if not len(cases):
        return []
    S = len(cases)
    u = np.empty((S, 24))
    batch = np.empty((S, 24))
    bg = np.empty((S, 24))
    cf = np.empty((S, 24))
    pr = np.zeros((S, 24))
    n_scen = np.empty(S)
    rate = np.empty(S)
    oh_s = np.empty(S)
    idle = np.empty(S)
    dyn = np.empty(S)
    alpha = np.empty(S)
    gamma = np.empty(S)
    ohfrac = np.empty(S)
    start = np.empty(S)

    pr24 = (np.array([price.at(float(h)) for h in range(24)])
            if price is not None else None)
    for i, c in enumerate(cases):
        carbon = c.carbon or GridCarbonModel()
        u[i], batch[i] = hourly_profile(c.schedule, c.bands, carbon, price)
        bg[i] = _band_table(c.bands)[1]
        cf[i] = _carbon_table(carbon)
        if pr24 is not None:
            pr[i] = pr24
        n_scen[i] = c.workload.n_scenarios
        rate[i] = c.workload.rate_at_full
        oh_s[i] = c.workload.batch_overhead_s
        m = c.machine
        idle[i], dyn[i], alpha[i] = m.idle_w, m.dyn_w, m.alpha
        gamma[i], ohfrac[i] = m.gamma, m.overhead_w_frac
        start[i] = c.start_hour

    # ---- per-slot rates (same model as the sequential simulator) ----------
    r_eff = rate[:, None] * u * np.maximum(1.0 - gamma[:, None] * bg, 0.05)
    work_t = batch / np.maximum(r_eff, 1e-9)          # work seconds per batch
    batch_time = oh_s[:, None] + work_t
    scen_rate = batch / batch_time                    # scenarios per second
    work_frac = work_t / batch_time
    p_work = idle[:, None] + dyn[:, None] * np.maximum(u + bg, 0.0) ** alpha[:, None]
    p_oh = idle[:, None] + dyn[:, None] * \
        np.maximum(ohfrac[:, None] * u + bg, 0.0) ** alpha[:, None]
    p_avg = work_frac * p_work + (1.0 - work_frac) * p_oh
    kwh_rate = p_avg / 3.6e6                          # kWh per second
    co2_rate = kwh_rate * cf
    cost_rate = kwh_rate * pr

    # ---- slot sequence of one 24 h period starting at start_hour ----------
    # K = 25 slots: a (possibly zero-length) partial leading slot, 23 full
    # hours, and the trailing remainder of the leading hour.
    h0 = np.floor(start).astype(int)
    frac = start - h0                                  # fraction into hour h0
    K = 25
    k = np.arange(K)
    slot_hour = (h0[:, None] + k[None, :]) % 24        # (S, K)
    lens = np.full((S, K), 3600.0)
    lens[:, 0] = (1.0 - frac) * 3600.0
    lens[:, 24] = frac * 3600.0

    scen_seq = np.take_along_axis(scen_rate, slot_hour, axis=1) * lens
    kwh_seq = np.take_along_axis(kwh_rate, slot_hour, axis=1) * lens
    co2_seq = np.take_along_axis(co2_rate, slot_hour, axis=1) * lens
    cost_seq = np.take_along_axis(cost_rate, slot_hour, axis=1) * lens

    day_scen = scen_seq.sum(axis=1)
    days = np.floor(n_scen / day_scen)
    residual = n_scen - days * day_scen                # scenarios past midnight N

    # find the slot where the residual completes (first cum >= residual)
    cum_scen = np.cumsum(scen_seq, axis=1)
    k_stop = np.minimum((cum_scen < residual[:, None] - 1e-9).sum(axis=1),
                        K - 1)
    rows = np.arange(S)
    before = cum_scen[rows, k_stop] - scen_seq[rows, k_stop]
    stop_rate = np.take_along_axis(scen_rate, slot_hour, axis=1)[rows, k_stop]
    tail_s = np.maximum(residual - before, 0.0) / np.maximum(stop_rate, 1e-30)

    def total(per_seg, per_s_rate):
        excl = np.cumsum(per_seg, axis=1) - per_seg    # sum of slots < k_stop
        day_total = per_seg.sum(axis=1)
        seq_rate = np.take_along_axis(per_s_rate, slot_hour, axis=1)
        return (days * day_total + excl[rows, k_stop]
                + seq_rate[rows, k_stop] * tail_s)

    lens_excl = np.cumsum(lens, axis=1) - lens
    runtime_s = days * 86400.0 + lens_excl[rows, k_stop] + tail_s
    energy = total(kwh_seq, kwh_rate)
    co2 = total(co2_seq, co2_rate)
    cost = total(cost_seq, cost_rate)

    out = []
    for i, c in enumerate(cases):
        out.append(SimResult(
            policy=c.name(), runtime_h=float(runtime_s[i]) / 3600.0,
            energy_kwh=float(energy[i]), co2_kg=float(co2[i]),
            cost_usd=float(cost[i]) if price is not None else None))
    return out


def frontier_from_sweep(results: List[SimResult],
                        baseline_name: str = "baseline",
                        base: Optional[SimResult] = None) -> List[SimResult]:
    """Fill the delta-vs-baseline columns of a sweep in place.

    The reference is `base` when given, else the swept result named
    `baseline_name`; with neither, results are returned unchanged.
    """
    if base is None:
        base = next((r for r in results if r.policy == baseline_name), None)
    if base is None:
        return results
    return fill_deltas(results, base)
