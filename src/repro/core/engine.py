"""Vectorized sweep engine: evaluate many (schedule x workload x grid-curve)
combinations in one batched pass, dispatching each case to the cheapest
representation that is exact for it.

Two vectorized paths sit behind one `sweep()` entry point:

  * the **periodic 24-slot path** (this module): decisions and signals
    that are periodic over 24 h and piecewise-constant per hour collapse a
    campaign into a periodic piecewise-linear accumulation — sample each
    case onto a 24-slot grid, derive per-slot rates with the shared rate
    model (core/model.py), jump whole days with integer arithmetic, and
    resolve the final partial day with one cumulative-sum search;

  * the **trace-grid path** (core/engine_jax.py): anything the periodic
    grid cannot represent — progress/elapsed-aware schedules, non-periodic
    multi-day `TraceSignal`s, carbon ensembles (`SignalEnsemble`),
    sub-hour band edges — is compiled into a `SweepPlan` and stepped
    through a chunked resumable `jax.lax.scan` (NumPy fallback) that
    carries `(remaining, elapsed, accumulator)` state across fixed-shape
    horizon chunks.

`sweep()` classifies every case and routes it; the per-case probe that
used to *reject* progress-aware schedules with a ValueError now simply
sends them down the trace-grid path.  Agreement with the per-batch oracle
`simulate_campaign_exact` is pinned to <0.5 % for both paths by
tests/test_session_engine.py and tests/test_trace_engine.py; against the
coarse sequential path the periodic engine agrees to float precision
(both integrate the same piecewise-hourly model).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import model
from repro.core.carbon import GridCarbonModel
from repro.core.energy import MachineProfile
from repro.core.policy import TimeBands
from repro.core.schedule import (Schedule, SchedulingContext, as_schedule,
                                 change_hours)
from repro.core.signal import Signal, is_periodic_24h, sample_hourly
from repro.core.simulator import SimResult, fill_deltas
from repro.core.workload import OEMWorkload

# Memo caches below are bounded (long-running sweep services construct
# unbounded numbers of TimeBands/carbon variants; the old module-level
# dicts grew forever).
_CACHE_SIZE = 256


@dataclasses.dataclass(frozen=True)
class SweepCase:
    """One point of a sweep: a schedule run against one scenario setup.

    `carbon` may be a GridCarbonModel or any carbon Signal (a non-periodic
    `TraceSignal` routes the case to the trace-grid engine).  A non-zero
    `deadline_h` is surfaced to the schedule via `ctx.deadline_h`.
    """
    schedule: Schedule
    workload: OEMWorkload
    machine: MachineProfile = MachineProfile()
    bands: TimeBands = TimeBands()
    carbon: Optional[object] = None
    start_hour: float = 9.0
    label: str = ""
    deadline_h: float = 0.0

    def name(self) -> str:
        return self.label or as_schedule(self.schedule).name


@functools.lru_cache(maxsize=_CACHE_SIZE)
def _band_table(bands: TimeBands):
    """(band_name[24], background[24]) for one TimeBands, memoized — band
    lookups are the hot part of profile sampling in large sweeps."""
    if any(float(e) % 1.0 for e in bands.edges()):
        raise ValueError(
            "the periodic engine samples bands on the hourly grid and "
            "cannot represent sub-hour band edges; sweep() routes such "
            "cases to the trace-grid engine")
    names = [bands.band_at(float(h)) for h in range(24)]
    return (names, np.array([bands.background(b) for b in names]))


@functools.lru_cache(maxsize=_CACHE_SIZE)
def _carbon_table_cached(carbon) -> np.ndarray:
    return np.array(sample_hourly(carbon))


def _carbon_table(carbon) -> np.ndarray:
    try:
        return _carbon_table_cached(carbon)
    except TypeError:                       # unhashable hourly_curve (list)
        return np.array(sample_hourly(carbon))


def _grid_resolution(edges) -> int:
    """Smallest slots-per-hour (a divisor of 60) aligning every edge;
    raises for edges finer than one minute."""
    for k in (1, 2, 3, 4, 5, 6, 10, 12, 15, 20, 30, 60):
        if all(abs(float(e) * k - round(float(e) * k)) < 1e-9
               for e in edges):
            return k
    raise ValueError(
        "edges finer than one minute cannot be aligned to a "
        "simulation grid; use the sequential simulators")


def slots_per_hour(bands: TimeBands) -> int:
    """Smallest sub-hour grid resolution that aligns every band edge.

    1 for integral edges; e.g. 2 for half-hour edges.  Raises for edges
    finer than one minute (not representable on any reasonable grid).
    """
    return _grid_resolution(bands.edges())


def case_slots_per_hour(case: "SweepCase") -> int:
    """Finest grid resolution a case needs: the lcm of the band-edge
    resolution and the schedule's own `change_hours` resolution.

    This is the dispatcher hook that lets a *schedule* force a sub-hour
    grid: a 48-slot `ParametricSchedule` advertises half-hour change
    hours, so its cases route to the trace engine at slots_per_hour=2
    even under hour-aligned bands.  All resolutions are divisors of 60,
    so the lcm is too.
    """
    sched = as_schedule(case.schedule)
    return math.lcm(slots_per_hour(case.bands),
                    _grid_resolution(change_hours(sched, case.bands)))


def periodic_decision_profile(schedule, bands: TimeBands,
                              slots_per_hour: int = 1
                              ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Closed-form (intensity, batch) day profiles of shape (24*sph,) for
    the bundled Policy / HourlyPolicy classes, which are periodic and
    progress-free by construction; None for anything that needs decide()
    sampling.  Bands are sampled directly on the sph grid — NOT through
    the hourly `_band_table`, which rejects sub-hour band edges (the
    trace engine calls this with sph>1 exactly for those)."""
    from repro.core.policy import HourlyPolicy, Policy

    sph = int(slots_per_hour)
    sched = as_schedule(schedule)
    decide = type(sched).decide if isinstance(sched, Policy) else None
    if decide is HourlyPolicy.decide and sched.hourly_intensity:
        u = np.repeat(np.array(sched.hourly_intensity, dtype=float), sph)
        if sched.low_priority:
            u = u * 0.82
        return u, np.full(24 * sph, float(sched.batch_size))
    if decide in (Policy.decide, HourlyPolicy.decide):
        need = _grid_resolution(bands.edges())
        if sph % need:
            raise ValueError(
                f"slots_per_hour={sph} cannot represent band edges that "
                f"need {need} slots/hour — sampling would silently alias "
                "them; sweep() routes such cases to the trace-grid engine "
                "at the right resolution")
        names = [bands.band_at(r / sph) for r in range(24 * sph)]
        per_band = {b: sched.intensity_at(b) for b in set(names)}
        u = np.array([per_band[b] for b in names])
        return u, np.full(24 * sph, float(sched.batch_size))
    return None


def _try_hourly_profile(schedule, bands: TimeBands, carbon,
                        price: Optional[Signal] = None,
                        deadline_h: float = 0.0
                        ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Sample a schedule's decisions on the 24-hour grid, or None when the
    schedule consults progress/elapsed_h (the periodic grid is sampled
    once per hour-of-day and reused for every simulated day, so such
    schedules are not representable — the dispatcher sends them to the
    trace-grid engine instead)."""
    closed = periodic_decision_profile(schedule, bands)
    if closed is not None:
        return closed

    sched = as_schedule(schedule)
    band_names, bg24 = _band_table(bands)
    cf24 = _carbon_table(carbon)
    pr24 = ([price.at(float(h)) for h in range(24)] if price is not None
            else None)
    u = np.empty(24)
    batch = np.empty(24)
    for h in range(24):
        ctx = SchedulingContext(
            hour_of_day=float(h), band=band_names[h],
            background=float(bg24[h]), carbon_factor=float(cf24[h]),
            price_usd_per_kwh=pr24[h] if pr24 is not None else 0.0,
            deadline_h=deadline_h)
        d = sched.decide(ctx)
        # probe at other campaign positions: a schedule that consults
        # progress/elapsed_h decides differently somewhere and needs the
        # trace-grid engine's (hour, progress-bucket) decision tables.
        # Several (elapsed, progress) pairs, spanning behind-schedule and
        # ahead-of-schedule states, so pace-style controllers whose
        # decision happens to coincide at one probe point are still caught.
        for elapsed, progress in ((24.0 + h, 0.5), (720.0 + h, 0.02),
                                  (float(h), 0.98), (240.0 + h, 0.999)):
            d_probe = sched.decide(dataclasses.replace(
                ctx, elapsed_h=elapsed, progress=progress))
            if (d_probe.intensity, d_probe.batch_size) != (d.intensity,
                                                           d.batch_size):
                return None
        u[h] = d.intensity
        batch[h] = d.batch_size
    return u, batch


def hourly_profile(schedule, bands: TimeBands, carbon: GridCarbonModel,
                   price: Optional[Signal] = None):
    """Sample a schedule's decisions on the 24-hour grid.

    Returns (intensity[24], batch[24]).  Exact for any schedule whose
    decision is constant within each local hour (all bundled ones are).
    Raises for progress/elapsed-aware schedules — `sweep()` handles those
    transparently via the trace-grid engine; call that instead.
    """
    prof = _try_hourly_profile(schedule, bands, carbon, price)
    if prof is None:
        raise ValueError(
            f"schedule {as_schedule(schedule).name!r} varies with campaign "
            "progress/elapsed time; the periodic hourly grid cannot "
            "represent it — sweep() routes such schedules to the "
            "trace-grid engine automatically")
    return prof


def _case_is_periodic(case: SweepCase, price: Optional[Signal]) -> bool:
    """Cheap structural checks for the periodic 24-slot representation
    (the schedule's own probe happens later, in profile sampling)."""
    carbon = case.carbon or GridCarbonModel()
    if not is_periodic_24h(carbon):
        return False
    if price is not None and not is_periodic_24h(price):
        return False
    # the schedule's change_hours count too: a sub-hour-slot schedule
    # (e.g. a 48-slot ParametricSchedule) aliases on the hourly grid
    return case_slots_per_hour(case) == 1


def sweep(cases: Sequence[SweepCase],
          price: Optional[Signal] = None,
          progress_buckets: int = 32,
          backend: Optional[str] = None,
          max_days: int = 120,
          precision: str = "fp64",
          devices: Optional[int] = None,
          cache_dir: Optional[str] = None) -> List[SimResult]:
    """Evaluate all cases in vectorized passes; order is preserved.

    Each case is dispatched to the periodic 24-slot path when its
    schedule, bands, and signals are all 24 h-periodic and hour-aligned,
    and to the trace-grid scan engine (core/engine_jax.py) otherwise —
    progress/elapsed-aware schedules, `TraceSignal` carbon/price,
    `SignalEnsemble` carbon (E scenario members per scan, summarized as
    mean + `EnsembleStats`), and sub-hour band edges all take the trace
    path instead of raising.

    `progress_buckets`, `backend` ("jax"/"numpy") and `max_days` (the
    trace grid's horizon cap) tune the trace path, as do the scale-out
    knobs `precision` ("fp64" exact / "mixed" fp32 dynamics with fp64
    accumulators) and `devices` (shard_map lane fan-out, None = all
    local devices) — see `engine_jax.compile_plan`/`execute_plan`.
    `cache_dir` points trace-path compilation at a persistent on-disk
    plan cache (default: the `CARINA_PLAN_CACHE` env var).
    """
    if not len(cases):
        return []
    periodic_idx: List[int] = []
    trace_idx: List[int] = []
    profiles = {}
    for i, c in enumerate(cases):
        prof = (_try_hourly_profile(c.schedule, c.bands,
                                    c.carbon or GridCarbonModel(), price,
                                    c.deadline_h)
                if _case_is_periodic(c, price) else None)
        if prof is None:
            trace_idx.append(i)
        else:
            periodic_idx.append(i)
            profiles[i] = prof

    out: List[Optional[SimResult]] = [None] * len(cases)
    if periodic_idx:
        res = _sweep_periodic([cases[i] for i in periodic_idx], price,
                              [profiles[i] for i in periodic_idx])
        for i, r in zip(periodic_idx, res):
            out[i] = r
    if trace_idx:
        from repro.core.engine_jax import trace_sweep
        sub = [cases[i] for i in trace_idx]
        # lcm, not max: mixing half-hour and 20-minute cases in one batch
        # needs a grid aligning both (all resolutions divide 60)
        sph = functools.reduce(math.lcm,
                               (case_slots_per_hour(c) for c in sub))
        res = trace_sweep(sub, price=price, slots_per_hour=sph,
                          progress_buckets=progress_buckets, backend=backend,
                          max_days=max_days, precision=precision,
                          devices=devices, cache_dir=cache_dir)
        for i, r in zip(trace_idx, res):
            out[i] = r
    return out  # type: ignore[return-value]


def _sweep_periodic(cases: Sequence[SweepCase], price: Optional[Signal],
                    profiles: Sequence[Tuple[np.ndarray, np.ndarray]]
                    ) -> List[SimResult]:
    """The periodic 24-slot path: one batched NumPy pass over all cases."""
    S = len(cases)
    u = np.empty((S, 24))
    batch = np.empty((S, 24))
    bg = np.empty((S, 24))
    cf = np.empty((S, 24))
    pr = np.zeros((S, 24))
    n_scen = np.empty(S)
    rate = np.empty(S)
    oh_s = np.empty(S)
    idle = np.empty(S)
    dyn = np.empty(S)
    alpha = np.empty(S)
    gamma = np.empty(S)
    ohfrac = np.empty(S)
    start = np.empty(S)

    pr24 = (np.array([price.at(float(h)) for h in range(24)])
            if price is not None else None)
    for i, c in enumerate(cases):
        carbon = c.carbon or GridCarbonModel()
        u[i], batch[i] = profiles[i]
        bg[i] = _band_table(c.bands)[1]
        cf[i] = _carbon_table(carbon)
        if pr24 is not None:
            pr[i] = pr24
        n_scen[i] = c.workload.n_scenarios
        rate[i] = c.workload.rate_at_full
        oh_s[i] = c.workload.batch_overhead_s
        m = c.machine
        idle[i], dyn[i], alpha[i] = m.idle_w, m.dyn_w, m.alpha
        gamma[i], ohfrac[i] = m.gamma, m.overhead_w_frac
        start[i] = c.start_hour

    # ---- per-slot rates (the shared rate model, batched over (S, 24)) -----
    r = model.rates(u, batch, bg,
                    rate_at_full=rate[:, None], batch_overhead_s=oh_s[:, None],
                    idle_w=idle[:, None], dyn_w=dyn[:, None],
                    alpha=alpha[:, None], gamma=gamma[:, None],
                    overhead_w_frac=ohfrac[:, None], xp=np)
    scen_rate = r.scen_per_s                          # scenarios per second
    kwh_rate = r.kwh_per_s                            # kWh per second
    co2_rate = kwh_rate * cf
    cost_rate = kwh_rate * pr

    # ---- slot sequence of one 24 h period starting at start_hour ----------
    # K = 25 slots: a (possibly zero-length) partial leading slot, 23 full
    # hours, and the trailing remainder of the leading hour.
    h0 = np.floor(start).astype(int)
    frac = start - h0                                  # fraction into hour h0
    K = 25
    k = np.arange(K)
    slot_hour = (h0[:, None] + k[None, :]) % 24        # (S, K)
    lens = np.full((S, K), 3600.0)
    lens[:, 0] = (1.0 - frac) * 3600.0
    lens[:, 24] = frac * 3600.0

    scen_seq = np.take_along_axis(scen_rate, slot_hour, axis=1) * lens
    kwh_seq = np.take_along_axis(kwh_rate, slot_hour, axis=1) * lens
    co2_seq = np.take_along_axis(co2_rate, slot_hour, axis=1) * lens
    cost_seq = np.take_along_axis(cost_rate, slot_hour, axis=1) * lens

    day_scen = scen_seq.sum(axis=1)
    days = np.floor(n_scen / day_scen)
    residual = n_scen - days * day_scen                # scenarios past midnight N

    # find the slot where the residual completes (first cum >= residual)
    cum_scen = np.cumsum(scen_seq, axis=1)
    k_stop = np.minimum((cum_scen < residual[:, None] - 1e-9).sum(axis=1),
                        K - 1)
    rows = np.arange(S)
    before = cum_scen[rows, k_stop] - scen_seq[rows, k_stop]
    stop_rate = np.take_along_axis(scen_rate, slot_hour, axis=1)[rows, k_stop]
    tail_s = np.maximum(residual - before, 0.0) / np.maximum(stop_rate, 1e-30)

    def total(per_seg, per_s_rate):
        excl = np.cumsum(per_seg, axis=1) - per_seg    # sum of slots < k_stop
        day_total = per_seg.sum(axis=1)
        seq_rate = np.take_along_axis(per_s_rate, slot_hour, axis=1)
        return (days * day_total + excl[rows, k_stop]
                + seq_rate[rows, k_stop] * tail_s)

    lens_excl = np.cumsum(lens, axis=1) - lens
    runtime_s = days * 86400.0 + lens_excl[rows, k_stop] + tail_s
    energy = total(kwh_seq, kwh_rate)
    co2 = total(co2_seq, co2_rate)
    cost = total(cost_seq, cost_rate)

    out = []
    for i, c in enumerate(cases):
        out.append(SimResult(
            policy=c.name(), runtime_h=float(runtime_s[i]) / 3600.0,
            energy_kwh=float(energy[i]), co2_kg=float(co2[i]),
            cost_usd=float(cost[i]) if price is not None else None))
    return out


def frontier_from_sweep(results: List[SimResult],
                        baseline_name: str = "baseline",
                        base: Optional[SimResult] = None) -> List[SimResult]:
    """Fill the delta-vs-baseline columns of a sweep in place.

    The reference is `base` when given, else the swept result named
    `baseline_name`; with neither, results are returned unchanged.
    """
    if base is None:
        base = next((r for r in results if r.policy == baseline_name), None)
    if base is None:
        return results
    return fill_deltas(results, base)
