"""Real carbon-intensity archives -> CARINA signals (ingestion layer).

ElectricityMaps/WattTime-style CSV/JSON archives are parsed, pushed
through a strict validation/quality pass, and lowered onto the existing
signal machinery: one hourly `TraceSignal` per zone (`ZoneSeries
.to_trace`), or a sliding-window `SignalEnsemble` per zone
(`ZoneSeries.to_ensemble`, via `trace_windows`).  Parsing and validation
are deliberately separate stages:

  * **parse** (`_parse_csv` / `_parse_json`) only maps the file onto raw
    `(timestamp, value, unit)` samples per zone — flexible about column
    names and record forms, strict about malformed values.
  * **validate/repair** (`_regularize`) owns every temporal/unit
    judgement call: sorting non-monotone rows, normalizing
    gCO2/kWh / kgCO2/kWh / lbs/MWh onto kg CO2e per kWh, collapsing
    duplicate hours (DST fall-back folds), filling gaps per an explicit
    `gap_policy` ("interpolate" | "hold" | "raise"; spring-forward
    skips show up as 1-hour gaps), and downsampling sub-hourly archives
    onto the hourly slot grid by in-hour means.  Every repair is counted
    in a per-zone `QualityReport` so nothing is silently invented.

Units: rows may carry a `unit` column; otherwise `unit=` applies to the
whole file, and failing that the unit is inferred per zone from the
value magnitude (median >= 10 reads as gCO2/kWh).  A multi-zone file
whose zones *infer* different units is rejected — that is the classic
g-vs-kg mixed-archive bug, and guessing would corrupt one zone by 1000x.

A seeded `write_synthetic_archive` generates realistic offline fixtures;
2-3 small bundled archives live under `src/repro/data/samples/` (see
`sample_archive_path` / `load_sample_archive`) so tests and examples
never need network access.
"""
from __future__ import annotations

import csv
import dataclasses
import datetime as _dt
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.carbon import MIDWEST_HOURLY, GridCarbonModel
from repro.core.signal import SignalEnsemble, TraceSignal, trace_windows

GAP_POLICIES = ("interpolate", "hold", "raise")

# Accepted spellings, in match priority order (case-insensitive).
_TS_COLS = ("datetime", "timestamp", "point_time", "utc_datetime",
            "datetime_utc", "date", "time")
_ZONE_COLS = ("zone", "zone_name", "zone_id", "ba", "region")
_VALUE_COLS = ("carbon_intensity_avg", "carbon_intensity",
               "carbonintensity", "co2_intensity", "moer", "intensity",
               "value")
_UNIT_COLS = ("unit", "units", "carbon_intensity_unit")

# kg CO2e per kWh per 1.0 of the source unit.  "lb" is the WattTime MOER
# convention, lbs CO2 per *MWh*: 0.453592 kg/lb / 1000 kWh/MWh.
_UNIT_SCALE = {"kg": 1.0, "g": 1e-3, "lb": 0.453592e-3}
_UNIT_LABEL = {"kg": "kgCO2/kWh", "g": "gCO2/kWh", "lb": "lbs/MWh"}


def _unit_key(text) -> Optional[str]:
    """Normalize a unit spelling to 'kg' | 'g' | 'lb' (None for blank)."""
    t = str(text).strip().lower().replace(" ", "")
    if not t:
        return None
    if t.startswith("kg") or "kgco2" in t:
        return "kg"
    if t.startswith("lb"):
        return "lb"
    if t.startswith("g"):
        return "g"
    raise ValueError(
        f"unrecognized carbon-intensity unit {text!r}; expected a "
        "gCO2/kWh, kgCO2/kWh, or lbs/MWh spelling")


def _parse_when(value) -> _dt.datetime:
    """One timestamp -> naive UTC datetime (ISO 8601 or unix seconds)."""
    if isinstance(value, (int, float)) and not isinstance(value, bool):
        return _dt.datetime.fromtimestamp(
            float(value), _dt.timezone.utc).replace(tzinfo=None)
    s = str(value).strip()
    try:
        return _dt.datetime.fromtimestamp(
            float(s), _dt.timezone.utc).replace(tzinfo=None)
    except ValueError:
        pass
    if s.endswith("Z"):
        s = s[:-1] + "+00:00"
    try:
        d = _dt.datetime.fromisoformat(s)
    except ValueError:
        raise ValueError(f"cannot parse timestamp {value!r} (ISO 8601 "
                         "or unix seconds)") from None
    if d.tzinfo is not None:
        d = d.astimezone(_dt.timezone.utc).replace(tzinfo=None)
    return d


# One raw sample: (timestamp, value in source units, unit key or None).
_Raw = Tuple[_dt.datetime, float, Optional[str]]


def _pick(cols: Dict[str, str], names) -> Optional[str]:
    for n in names:
        if n in cols:
            return cols[n]
    return None


def _parse_csv(path: str, default_zone: str) -> Dict[str, List[_Raw]]:
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        if not reader.fieldnames:
            raise ValueError(f"{path}: empty CSV (no header row)")
        cols = {c.strip().lower(): c for c in reader.fieldnames}
        ts_col = _pick(cols, _TS_COLS)
        val_col = _pick(cols, _VALUE_COLS)
        if ts_col is None or val_col is None:
            raise ValueError(
                f"{path}: need a timestamp column (one of {_TS_COLS}) "
                f"and an intensity column (one of {_VALUE_COLS}); got "
                f"{tuple(cols)}")
        zone_col = _pick(cols, _ZONE_COLS)
        unit_col = _pick(cols, _UNIT_COLS)
        out: Dict[str, List[_Raw]] = {}
        for i, row in enumerate(reader):
            raw_val = (row.get(val_col) or "").strip()
            if not raw_val and not (row.get(ts_col) or "").strip():
                continue                          # blank line
            try:
                val = float(raw_val)
            except ValueError:
                raise ValueError(
                    f"{path} row {i + 2}: bad intensity value "
                    f"{raw_val!r}") from None
            when = _parse_when(row[ts_col])
            unit = _unit_key(row[unit_col]) if unit_col else None
            zone = ((row.get(zone_col) or "").strip() or default_zone
                    if zone_col else default_zone)
            out.setdefault(zone, []).append((when, val, unit))
    return out


def _record_fields(rec: dict) -> Tuple[_dt.datetime, float, Optional[str],
                                       Optional[str]]:
    low = {str(k).strip().lower(): v for k, v in rec.items()}
    ts = _pick({k: k for k in low}, _TS_COLS)
    val = _pick({k: k for k in low}, _VALUE_COLS)
    if ts is None or val is None:
        raise ValueError(f"JSON record {rec!r} has no recognizable "
                         "timestamp/intensity keys")
    zone = _pick({k: k for k in low}, _ZONE_COLS)
    unit = _pick({k: k for k in low}, _UNIT_COLS)
    return (_parse_when(low[ts]), float(low[val]),
            _unit_key(low[unit]) if unit and low[unit] is not None else None,
            str(low[zone]) if zone else None)


def _parse_json(path: str, default_zone: str) -> Dict[str, List[_Raw]]:
    with open(path) as f:
        obj = json.load(f)
    out: Dict[str, List[_Raw]] = {}

    def add(records, zone_hint):
        for rec in records:
            when, val, unit, zone = _record_fields(rec)
            out.setdefault(zone or zone_hint or default_zone,
                           []).append((when, val, unit))

    if isinstance(obj, dict) and isinstance(obj.get("zones"), dict):
        for z, records in obj["zones"].items():
            add(records, str(z))
    elif isinstance(obj, dict):
        records = obj.get("data", obj.get("history"))
        if not isinstance(records, list):
            raise ValueError(
                f"{path}: JSON archives are a record list, a "
                "{'zone':..., 'data'|'history': [...]} object, or a "
                "{'zones': {name: [...]}} object")
        add(records, str(obj["zone"]) if obj.get("zone") else None)
    elif isinstance(obj, list):
        add(obj, None)
    else:
        raise ValueError(f"{path}: cannot interpret "
                         f"{type(obj).__name__} as a carbon archive")
    return out


# ----------------------------------------------------------------------
# Validation / quality pass
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QualityReport:
    """What the validation pass repaired for one zone (nothing silent)."""
    zone: str
    unit: str                    # source unit key: "kg" | "g" | "lb"
    rows: int                    # raw samples parsed
    hours: int                   # hours in the regularized series
    out_of_order: int            # samples re-sorted into place
    duplicates_collapsed: int    # extra same-hour samples averaged away
    dst_folds: int               # hours seen exactly twice (fall-back)
    gaps_filled: int             # missing hours synthesized per policy
    gap_runs: Tuple[int, ...]    # length of each repaired gap run
    longest_gap_h: int
    dst_skips: int               # 1-hour gaps (spring-forward signature)
    subhourly_minutes: Optional[int]   # source cadence when < 60 min
    gap_policy: str

    @property
    def clean(self) -> bool:
        return not (self.out_of_order or self.duplicates_collapsed
                    or self.gaps_filled)


@dataclasses.dataclass(frozen=True)
class ZoneSeries:
    """One zone's regularized hourly series (kg CO2e/kWh) + its report."""
    zone: str
    values: Tuple[float, ...]
    start: str                   # ISO timestamp of values[0]'s hour
    quality: QualityReport

    @property
    def hours(self) -> int:
        return len(self.values)

    @property
    def mean_kg_per_kwh(self) -> float:
        return float(np.mean(self.values))

    def to_trace(self, start_hour: float = 0.0, name: Optional[str] = None,
                 pad: str = "hold") -> TraceSignal:
        """This zone as a campaign-anchored hourly `TraceSignal`.

        `start_hour` re-anchors the archive onto the campaign clock
        (hour 0 = midnight of campaign day 0) — archives carry absolute
        timestamps, campaigns count hours from their own day 0.
        """
        return TraceSignal(self.values, start_hour=start_hour,
                           name=name or f"carbon:{self.zone}", pad=pad)

    def to_ensemble(self, window_h: int, stride_h: Optional[int] = None,
                    *, start_hour: float = 0.0,
                    name: Optional[str] = None,
                    pad: str = "hold") -> SignalEnsemble:
        """Sliding `window_h`-hour windows as a scenario ensemble.

        Refuses a series whose longest repaired gap exceeds `window_h`:
        such an ensemble would contain members made entirely of
        interpolated/held fiction.  Re-load with a shorter horizon or a
        better archive instead.
        """
        gap = self.quality.longest_gap_h
        if gap > int(window_h):
            raise ValueError(
                f"zone {self.zone!r}: archive has a {gap}-hour repaired "
                f"gap (> window_h={int(window_h)}); an ensemble window "
                "falling inside it would be pure "
                f"{self.quality.gap_policy!r} fiction — use a longer "
                "window, a cleaner archive, or slice around the gap")
        return trace_windows(self.values, window_h, stride_h,
                             start_hour=start_hour,
                             name=name or f"carbon:{self.zone}", pad=pad)

    def to_carbon_model(self, source: Optional[str] = None) -> GridCarbonModel:
        """Flat-factor summary model (mean intensity), zone-stamped."""
        return GridCarbonModel(factor_kg_per_kwh=self.mean_kg_per_kwh,
                               zone=self.zone, source=source)


def _regularize(zone: str, samples: List[_Raw], scale_by_row: np.ndarray,
                unit: str, gap_policy: str) -> ZoneSeries:
    """The quality pass: raw samples -> strict hourly kg/kWh series."""
    whens = [s[0] for s in samples]
    vals = np.asarray([s[1] for s in samples], dtype=float) * scale_by_row
    if not np.all(np.isfinite(vals)):
        bad = int(np.sum(~np.isfinite(vals)))
        raise ValueError(f"zone {zone!r}: {bad} non-finite intensity "
                         "value(s); archives must be numeric")
    base = min(whens).replace(minute=0, second=0, microsecond=0)
    t = np.asarray([(w - base).total_seconds() / 3600.0 for w in whens])
    out_of_order = int(np.sum(np.diff(t) < -1e-9))
    order = np.argsort(t, kind="stable")
    t, vals = t[order], vals[order]

    dt_pos = np.diff(t)
    dt_pos = dt_pos[dt_pos > 1e-9]
    step_h = float(np.median(dt_pos)) if dt_pos.size else 1.0
    subhourly = step_h < 0.999
    subhourly_minutes = int(round(step_h * 60.0)) if subhourly else None

    hour = np.floor(t + 1e-9).astype(int)
    uniq, inv, counts = np.unique(hour, return_inverse=True,
                                  return_counts=True)
    hourly = np.bincount(inv, weights=vals) / counts
    if subhourly:
        # multiple in-hour samples are the cadence, not duplication
        duplicates = dst_folds = 0
    else:
        duplicates = int(np.sum(counts - 1))
        dst_folds = int(np.sum(counts == 2))

    full = np.arange(uniq[0], uniq[-1] + 1)
    present = np.zeros(len(full), dtype=bool)
    present[uniq - uniq[0]] = True
    gap_runs: List[int] = []
    run = 0
    for p in present:
        if p:
            if run:
                gap_runs.append(run)
            run = 0
        else:
            run += 1
    gaps_filled = int(sum(gap_runs))
    if gaps_filled and gap_policy == "raise":
        raise ValueError(
            f"zone {zone!r}: {gaps_filled} missing hour(s) across "
            f"{len(gap_runs)} gap(s) (longest {max(gap_runs)} h) and "
            "gap_policy='raise'; re-load with gap_policy='interpolate' "
            "or 'hold' to repair explicitly")
    values = np.empty(len(full), dtype=float)
    values[present] = hourly
    if gaps_filled:
        if gap_policy == "interpolate":
            values[~present] = np.interp(full[~present], uniq, hourly)
        else:                                     # "hold"
            idx = np.arange(len(full))
            last = np.maximum.accumulate(np.where(present, idx, 0))
            values = values[last]
    start = (base + _dt.timedelta(hours=int(uniq[0]))).isoformat()
    report = QualityReport(
        zone=zone, unit=unit, rows=len(samples), hours=len(full),
        out_of_order=out_of_order, duplicates_collapsed=duplicates,
        dst_folds=dst_folds, gaps_filled=gaps_filled,
        gap_runs=tuple(gap_runs),
        longest_gap_h=max(gap_runs) if gap_runs else 0,
        dst_skips=int(sum(1 for g in gap_runs if g == 1)),
        subhourly_minutes=subhourly_minutes, gap_policy=gap_policy)
    return ZoneSeries(zone=zone, values=tuple(float(v) for v in values),
                      start=start, quality=report)


# ----------------------------------------------------------------------
# The archive object + loader
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CarbonArchive:
    """A validated multi-zone carbon-intensity archive (hourly, kg/kWh)."""
    series: Tuple[ZoneSeries, ...]
    path: Optional[str] = None
    name: str = "archive"

    def __post_init__(self):
        if not self.series:
            raise ValueError("CarbonArchive needs at least one zone")

    @property
    def zones(self) -> Tuple[str, ...]:
        return tuple(s.zone for s in self.series)

    @property
    def quality(self) -> Dict[str, QualityReport]:
        return {s.zone: s.quality for s in self.series}

    def __len__(self) -> int:
        return len(self.series)

    def __iter__(self):
        return iter(self.series)

    def __getitem__(self, zone: str) -> ZoneSeries:
        for s in self.series:
            if s.zone == zone:
                return s
        raise KeyError(f"zone {zone!r} not in archive "
                       f"{self.name!r}; zones: {self.zones}")

    def _one(self, zone: Optional[str]) -> ZoneSeries:
        if zone is not None:
            return self[zone]
        if len(self.series) == 1:
            return self.series[0]
        raise ValueError(f"archive {self.name!r} has zones {self.zones}; "
                         "pass zone= to pick one")

    def to_trace(self, zone: Optional[str] = None, **kw) -> TraceSignal:
        return self._one(zone).to_trace(**kw)

    def to_ensemble(self, window_h: int, stride_h: Optional[int] = None,
                    zone: Optional[str] = None, **kw) -> SignalEnsemble:
        return self._one(zone).to_ensemble(window_h, stride_h, **kw)


def load_carbon_archive(path: str, zone: Optional[str] = None, *,
                        unit: Optional[str] = None,
                        gap_policy: str = "interpolate",
                        name: Optional[str] = None) -> CarbonArchive:
    """Parse + validate a CSV/JSON carbon-intensity archive.

    `zone=` keeps only that zone; `unit=` asserts the file-wide source
    unit ("g" / "kg" / "lb" or a full spelling) when rows don't carry
    one; `gap_policy` picks how missing hours are repaired (see module
    docstring).  Returns a `CarbonArchive` of hourly kg-CO2e/kWh
    `ZoneSeries`, each with a `QualityReport` of every repair made.
    """
    if gap_policy not in GAP_POLICIES:
        raise ValueError(f"gap_policy must be one of {GAP_POLICIES}, "
                         f"got {gap_policy!r}")
    stem = os.path.splitext(os.path.basename(path))[0]
    ext = os.path.splitext(path)[1].lower()
    if ext == ".csv":
        raw = _parse_csv(path, default_zone=zone or stem)
    elif ext == ".json":
        raw = _parse_json(path, default_zone=zone or stem)
    else:
        raise ValueError(f"unsupported archive format {ext!r} "
                         "(expected .csv or .json)")
    if zone is not None:
        if zone not in raw:
            raise ValueError(f"zone {zone!r} not in {path}; zones: "
                             f"{tuple(sorted(raw))}")
        raw = {zone: raw[zone]}

    file_unit = _unit_key(unit) if unit is not None else None
    inferred: Dict[str, str] = {}
    resolved: Dict[str, Tuple[np.ndarray, str]] = {}
    for z, samples in sorted(raw.items()):
        if not samples:
            raise ValueError(f"zone {z!r} in {path} has no samples")
        row_units = [u for _, _, u in samples]
        explicit = next((u for u in row_units if u), None)
        if file_unit is not None:
            default = file_unit
        elif explicit is not None:
            default = explicit
        else:
            med = float(np.median([v for _, v, _ in samples]))
            default = "g" if med >= 10.0 else "kg"
            inferred[z] = default
        scale = np.asarray([_UNIT_SCALE[u or default] for u in row_units])
        resolved[z] = (scale, default)
    if len(set(inferred.values())) > 1:
        raise ValueError(
            f"{path}: zones disagree on *inferred* units "
            f"({dict(sorted(inferred.items()))}) — a g-vs-kg mix in one "
            "multi-zone file; add a unit column or pass unit= to "
            "disambiguate")

    series = tuple(_regularize(z, raw[z], resolved[z][0], resolved[z][1],
                               gap_policy)
                   for z in sorted(raw))
    return CarbonArchive(series=series, path=path, name=name or stem)


# ----------------------------------------------------------------------
# Synthetic archives + bundled samples
# ----------------------------------------------------------------------
def write_synthetic_archive(path: str, zones=("ZONE-A",), days: int = 7, *,
                            seed: int = 0, unit: str = "kg",
                            cadence_min: int = 60,
                            dst: Optional[str] = None,
                            gap: Optional[Tuple[int, int]] = None,
                            start: str = "2024-03-08T00:00",
                            include_unit_column: bool = True) -> str:
    """Write a seeded, realistic CSV/JSON carbon archive (offline fixture).

    Per zone: a diurnal shape (evening-ramp peakers), a weekend dip, and
    2% noise around a seeded base level.  `dst="spring"` drops local
    02:00 of day 1 (skip), `"fall"` doubles 01:00 of day 2 (fold),
    `"both"` does both; `gap=(start_hour, length_h)` deletes a run of
    hours — all on every zone, so loaders can be pinned against known
    defects.  Format follows the extension (.csv / .json).
    """
    if dst not in (None, "spring", "fall", "both"):
        raise ValueError("dst must be None, 'spring', 'fall', or 'both'")
    ukey = _unit_key(unit)
    out_scale = 1.0 / _UNIT_SCALE[ukey]
    rng = np.random.RandomState(seed)
    start_dt = _dt.datetime.fromisoformat(start)
    n = days * 24 * 60 // int(cadence_min)
    spring_h, fall_h = 26, 49          # day-1 02:00 skip, day-2 01:00 fold
    rows: List[Tuple[str, str, float]] = []   # (zone, iso, value in unit)
    for z in zones:
        base = 0.2 + 0.4 * rng.rand()
        for i in range(n):
            h = i * cadence_min / 60.0
            hidx = int(h)
            if gap is not None and gap[0] <= hidx < gap[0] + gap[1]:
                continue
            if dst in ("spring", "both") and hidx == spring_h:
                continue
            kg = (base * MIDWEST_HOURLY[hidx % 24]
                  * (0.88 if (hidx // 24) % 7 >= 5 else 1.0)
                  * (1.0 + 0.02 * rng.randn()))
            kg = max(kg, 0.01)
            when = (start_dt + _dt.timedelta(minutes=i * cadence_min)
                    ).isoformat()
            rows.append((z, when, kg * out_scale))
            if dst in ("fall", "both") and hidx == fall_h:
                rows.append((z, when, max(kg * (1.0 + 0.02 * rng.randn()),
                                          0.01) * out_scale))
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    ext = os.path.splitext(path)[1].lower()
    if ext == ".csv":
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            header = ["datetime", "zone", "carbon_intensity"]
            if include_unit_column:
                header.append("unit")
            w.writerow(header)
            for z, when, val in rows:
                line = [when, z, f"{val:.6g}"]
                if include_unit_column:
                    line.append(_UNIT_LABEL[ukey])
                w.writerow(line)
    elif ext == ".json":
        by_zone: Dict[str, list] = {}
        for z, when, val in rows:
            rec = {"datetime": when, "carbon_intensity": round(val, 6)}
            if include_unit_column:
                rec["unit"] = _UNIT_LABEL[ukey]
            by_zone.setdefault(z, []).append(rec)
        with open(path, "w") as f:
            json.dump({"zones": by_zone}, f, indent=None,
                      separators=(",", ":"))
    else:
        raise ValueError(f"unsupported archive format {ext!r} "
                         "(expected .csv or .json)")
    return path


SAMPLE_ARCHIVES = ("grid_week_3z.csv", "midwest_5min.json", "dst_week.csv")


def samples_dir() -> str:
    return os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "data", "samples")


def sample_archive_path(name: str) -> str:
    """Absolute path of a bundled sample archive (offline fixtures)."""
    p = os.path.join(samples_dir(), name)
    if not os.path.exists(p):
        raise FileNotFoundError(
            f"no bundled sample archive {name!r}; available: "
            f"{SAMPLE_ARCHIVES}")
    return p


def load_sample_archive(name: str, **kw) -> CarbonArchive:
    """`load_carbon_archive` over a bundled sample (see SAMPLE_ARCHIVES)."""
    return load_carbon_archive(sample_archive_path(name), **kw)


__all__ = ["GAP_POLICIES", "SAMPLE_ARCHIVES", "CarbonArchive",
           "QualityReport", "ZoneSeries", "load_carbon_archive",
           "load_sample_archive", "sample_archive_path", "samples_dir",
           "write_synthetic_archive"]
