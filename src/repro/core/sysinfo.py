"""System auto-detection (paper Algorithm 1, line 3: "Detect machine
characteristics and initialize tracker"; §2: "the current implementation
also supports system auto-detection").

Detects host characteristics (cores, memory, accelerator platform/count)
and derives an estimation MachineProfile / ChipProfile.  Pure estimation —
no meters — per the paper's method; every inferred constant is carried in
the profile `meta` so dashboards can show the provenance of the estimate.
"""
from __future__ import annotations

import dataclasses
import os
import platform
from typing import Dict, Optional

from repro.core.energy import ChipProfile, MachineProfile


def _read_meminfo_gb() -> Optional[float]:
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemTotal:"):
                    return int(line.split()[1]) / 1024 / 1024
    except OSError:
        pass
    return None


def detect_host() -> Dict:
    """Raw host characteristics."""
    info: Dict = {
        "hostname": platform.node(),
        "machine": platform.machine(),
        "system": platform.system(),
        "cpus": os.cpu_count() or 1,
        "mem_gb": _read_meminfo_gb(),
    }
    try:
        import jax
        info["jax_backend"] = jax.default_backend()
        info["jax_devices"] = len(jax.devices())
        info["jax_device_kind"] = jax.devices()[0].device_kind
    except Exception:
        info["jax_backend"] = None
        info["jax_devices"] = 0
        info["jax_device_kind"] = "unknown"
    return info


# Workstation-class TDP estimation by core count (estimation-based, as the
# paper's method allows; the calibration pass re-solves dyn_w anyway).
_TDP_BY_CORES = ((4, 65.0), (8, 95.0), (16, 145.0), (32, 220.0), (64, 320.0))


def machine_profile_from_host(info: Optional[Dict] = None) -> MachineProfile:
    info = info or detect_host()
    cores = info.get("cpus", 8)
    dyn = next((w for c, w in _TDP_BY_CORES if cores <= c), 360.0)
    idle = max(30.0, dyn * 0.35)
    return dataclasses.replace(MachineProfile(), name=f"auto-{info.get('hostname', 'host')}",
                               idle_w=idle, dyn_w=dyn)


# Known accelerator energy profiles (per-chip; estimation constants)
_CHIP_TABLE = {
    "tpu v5e": ChipProfile(),
    "tpu v5": ChipProfile(name="tpu-v5p", peak_flops=459e12, hbm_bw=2765e9,
                          ici_bw=90e9, idle_w=90.0, tdp_w=350.0),
    "tpu v4": ChipProfile(name="tpu-v4", peak_flops=275e12, hbm_bw=1228e9,
                          ici_bw=50e9, idle_w=90.0, tdp_w=300.0),
}


def chip_profile_from_host(info: Optional[Dict] = None) -> ChipProfile:
    info = info or detect_host()
    kind = (info.get("jax_device_kind") or "").lower()
    for key, prof in _CHIP_TABLE.items():
        if key in kind:
            return prof
    return ChipProfile()  # v5e-class default (the assignment target)
