"""Time-varying input signals (paper §2 instrumentation + stated future work).

A Signal is any time-varying scalar input the scheduler or the simulator
consumes: background office load, grid carbon intensity, electricity
price.  The paper hard-wires the first two (band levels in
`TimeBands.background`, an hourly multiplier in `GridCarbonModel`); this
module lifts them behind one interface so a live forecast feed — the
paper's "continuously updated regional carbon-intensity feeds" — can later
implement the same protocol without touching the simulator or the engine.

Signals are sampled with *absolute* campaign hours (hour 0 = midnight of
the campaign's first day).  Periodic signals wrap mod 24 internally, so
hour-of-day and absolute-hour sampling agree for them; a `TraceSignal`
(an arbitrary-length hourly series such as a week-long grid-carbon
forecast) is genuinely non-periodic and is what routes a sweep onto the
trace-grid engine (core/engine_jax.py) instead of the periodic 24-slot
one (core/engine.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np


@runtime_checkable
class Signal(Protocol):
    """A scalar input varying with time."""

    name: str

    def at(self, hour_of_day: float) -> float:
        """Value at the given hour (absolute campaign hours; periodic
        signals wrap mod 24, so hour-of-day works too)."""
        ...


def period_hours(signal) -> Optional[float]:
    """A signal's period in hours; None when unknown or non-periodic.

    Signals may declare their own `period_h`; the bundled periodic
    classes (ConstantSignal / HourlySignal / BandSignal, plus the
    GridCarbonModel duck type) are known to repeat every 24 h.  Anything
    else is conservatively treated as non-periodic — a custom live-feed
    signal implementing only `at(hour)` must not be silently collapsed
    onto one repeated day by the periodic sweep engine.
    """
    if hasattr(signal, "period_h"):
        return signal.period_h
    if isinstance(signal, (ConstantSignal, HourlySignal, BandSignal)):
        return 24.0
    if hasattr(signal, "factor_at"):      # GridCarbonModel duck type
        return 24.0
    return None


def is_periodic_24h(signal) -> bool:
    """True when the signal is known to repeat every 24 h (the periodic
    sweep engine's representability condition)."""
    return period_hours(signal) == 24.0


@dataclasses.dataclass(frozen=True)
class ConstantSignal:
    """Flat signal (e.g. the paper's single DTE grid factor)."""
    value: float
    name: str = "constant"

    def at(self, hour_of_day: float) -> float:
        return self.value


@dataclasses.dataclass(frozen=True)
class HourlySignal:
    """24-slot piecewise-constant signal (one value per local hour)."""
    values: Tuple[float, ...]
    name: str = "hourly"

    def __post_init__(self):
        if len(self.values) != 24:
            raise ValueError(
                f"HourlySignal needs exactly 24 values, got {len(self.values)}")

    def at(self, hour_of_day: float) -> float:
        # math.floor, not int(): int() truncates toward zero, mapping hour
        # -0.5 to slot 0 instead of slot 23
        return self.values[math.floor(hour_of_day) % 24]


@dataclasses.dataclass(frozen=True)
class BandSignal:
    """Signal defined per time band (e.g. background office load).

    `bands` is a TimeBands instance (duck-typed to avoid the import cycle);
    `levels` maps band name -> value.
    """
    bands: object
    levels: dict
    name: str = "band"

    def at(self, hour_of_day: float) -> float:
        return self.levels[self.bands.band_at(hour_of_day)]


@dataclasses.dataclass(frozen=True)
class TraceSignal:
    """A non-periodic hourly series of arbitrary length (e.g. a week-long
    grid-carbon or forecast trace).

    `values[i]` covers absolute hours `[start_hour + i, start_hour + i + 1)`
    where hour 0 is midnight of the campaign's first day.  `period_h` is
    None: sweeps over a TraceSignal are routed to the trace-grid engine.

    `pad` makes the out-of-range policy explicit instead of incidental:

    - ``"hold"`` (default): outside the covered range the trace clamps
      (holds its first/last value), so a campaign that outruns its
      forecast keeps the most recent sample rather than wrapping to
      stale data.
    - ``"raise"``: sampling outside ``[start_hour, end_hour)`` raises
      ``ValueError``.  Use this when silently repeating the archive's
      last value would corrupt a result — e.g. an MPC horizon that
      extends past the end of a ground-truth trace.
    """
    values: Tuple[float, ...]
    start_hour: float = 0.0
    name: str = "trace"
    pad: str = "hold"

    def __post_init__(self):
        if len(self.values) < 1:
            raise ValueError("TraceSignal needs at least one value")
        if self.pad not in ("hold", "raise"):
            raise ValueError(
                f"pad must be 'hold' or 'raise', got {self.pad!r}")
        # frozen dataclass: stash the array form once (sample() is hot in
        # large sweeps and must not re-convert the tuple per case)
        object.__setattr__(self, "_arr",
                           np.asarray(self.values, dtype=float))

    @property
    def period_h(self) -> Optional[float]:
        return None

    @property
    def hours(self) -> float:
        """Length of the covered range in hours."""
        return float(len(self.values))

    @property
    def end_hour(self) -> float:
        """First absolute hour past the covered range."""
        return self.start_hour + len(self.values)

    def covers(self, hour: float) -> bool:
        """True when `hour` falls inside the covered range."""
        return self.start_hour <= hour < self.end_hour

    def _check_range(self, lo: float, hi: float) -> None:
        if lo < self.start_hour or hi >= self.end_hour:
            raise ValueError(
                f"trace '{self.name}' covers hours [{self.start_hour}, "
                f"{self.end_hour}) but was sampled at hour "
                f"{lo if lo < self.start_hour else hi}; extend the "
                "archive, shorten the horizon, or use pad='hold' to "
                "clamp explicitly")

    def at(self, hour: float) -> float:
        if self.pad == "raise":
            self._check_range(hour, hour)
        i = math.floor(hour - self.start_hour)
        return self.values[min(max(i, 0), len(self.values) - 1)]

    def sample(self, hours) -> np.ndarray:
        """Vectorized `at` over an array of absolute hours."""
        hours = np.asarray(hours, dtype=float)
        if self.pad == "raise" and hours.size:
            self._check_range(float(hours.min()), float(hours.max()))
        idx = np.clip(np.floor(hours - self.start_hour).astype(int),
                      0, len(self.values) - 1)
        return self._arr[idx]


@dataclasses.dataclass(frozen=True)
class SignalEnsemble:
    """A stack of carbon (or price) traces treated as one uncertain signal.

    The carbon-aware workflow literature evaluates savings across *many*
    trace windows, not one deterministic forecast; a `SignalEnsemble`
    carries those E scenario members side by side.  Members are usually
    `TraceSignal`s (historical windows, forecast samples) but any Signal
    works.  `sample(hours)` returns the whole `(E, *hours.shape)` block in
    one vectorized call — the shape the trace-grid scan vmaps its CO2
    accumulators over to produce per-member metrics.

    `period_h` is None, so a sweep case whose carbon is an ensemble always
    routes to the trace-grid engine.  `at(hour)` returns the member mean
    (the sequential simulators see the ensemble's central scenario; use
    `member(e)` to simulate one realization).
    """
    members: Tuple[Signal, ...]
    name: str = "ensemble"

    def __post_init__(self):
        if len(self.members) < 1:
            raise ValueError("SignalEnsemble needs at least one member")

    def __len__(self) -> int:
        return len(self.members)

    @property
    def n_members(self) -> int:
        return len(self.members)

    @property
    def period_h(self) -> Optional[float]:
        return None

    def member(self, e: int) -> Signal:
        return self.members[e]

    def at(self, hour: float) -> float:
        at = 0.0
        for m in self.members:
            at += float(m.at(hour))
        return at / len(self.members)

    def sample(self, hours) -> np.ndarray:
        """Vectorized sampling of every member: (E, *hours.shape)."""
        hours = np.asarray(hours, dtype=float)
        return np.stack([sample_signal(m, hours) for m in self.members])


def as_ensemble(value, name: str = "ensemble") -> SignalEnsemble:
    """Coerce to a `SignalEnsemble`.

    Accepts an ensemble (passed through), a 2-D array of shape (E, T)
    (each row becomes an hourly `TraceSignal`), or an iterable of members
    where each member is a Signal or an hourly sequence (`as_trace`
    coercion per member).
    """
    if isinstance(value, SignalEnsemble):
        return value
    arr = None
    if not callable(getattr(value, "at", None)):
        try:
            arr = np.asarray(value, dtype=float)
        except (TypeError, ValueError):
            arr = None
    if arr is not None and arr.ndim == 2:
        return SignalEnsemble(tuple(
            TraceSignal(tuple(float(v) for v in row), name=f"{name}[{e}]")
            for e, row in enumerate(arr)), name=name)
    if arr is not None and arr.ndim == 1 and arr.dtype != object:
        raise TypeError(
            "a flat hourly series is one trace, not an ensemble — pass it "
            "as carbon_trace= (or wrap it: as_ensemble([series]), or give "
            "an (E, T) array / list of traces)")
    try:
        members = list(value)
    except TypeError:
        raise TypeError(
            f"cannot interpret {type(value).__name__} as a SignalEnsemble; "
            "pass an ensemble, an (E, T) array, or a list of traces/Signals"
        ) from None
    if not members:
        raise ValueError("SignalEnsemble needs at least one member")
    return SignalEnsemble(tuple(as_trace(m, name=f"{name}[{e}]")
                                for e, m in enumerate(members)), name=name)


def trace_windows(values, window_h: int, stride_h: Optional[int] = None,
                  start_hour: float = 0.0,
                  name: str = "windows", pad: str = "hold") -> SignalEnsemble:
    """Slice one long hourly series into an ensemble of sliding windows.

    The standard way to build a scenario ensemble from a historical
    grid-carbon archive: every `stride_h` (default `window_h`, i.e.
    non-overlapping) a `window_h`-hour window becomes one member, each
    re-anchored to `start_hour` so all members cover the same campaign
    hours.  Raises if the series is shorter than one window.  `pad` is
    forwarded to every member `TraceSignal` — pass ``"raise"`` to make
    sampling past a window's end an error instead of a silent clamp
    (see `TraceSignal.pad`).  A `TraceSignal` is accepted directly
    (e.g. a `ZoneSeries.to_trace()` from an archive): its values are
    windowed and, like any other series, every member is re-anchored to
    `start_hour`.
    """
    if isinstance(values, TraceSignal):
        values = values.values
    arr = np.asarray(list(values), dtype=float).ravel()
    window_h = int(window_h)
    stride = int(stride_h) if stride_h is not None else window_h
    if window_h < 1 or stride < 1:
        raise ValueError("window_h and stride_h must be positive")
    if len(arr) < window_h:
        raise ValueError(f"series of {len(arr)} hours is shorter than one "
                         f"{window_h}-hour window")
    members = []
    for e, o in enumerate(range(0, len(arr) - window_h + 1, stride)):
        members.append(TraceSignal(tuple(float(v)
                                         for v in arr[o:o + window_h]),
                                   start_hour=start_hour,
                                   name=f"{name}[{e}]", pad=pad))
    return SignalEnsemble(tuple(members), name=name)


def as_trace(values, start_hour: float = 0.0,
             name: str = "trace") -> TraceSignal:
    """Coerce an hourly sequence (or pass through a Signal) to a trace.

    The Signal test requires a *callable* `at` — jnp arrays and pandas
    Series expose a non-callable `.at` indexer and must be treated as
    plain hourly sequences, not passed through unconverted.
    """
    if isinstance(values, TraceSignal):
        return values
    if callable(getattr(values, "at", None)):   # already some Signal
        return values
    return TraceSignal(tuple(float(v) for v in values),
                       start_hour=start_hour, name=name)


def sample_signal(signal, hours) -> np.ndarray:
    """Vectorized sampling of any Signal (or GridCarbonModel) at an array
    of absolute hours.  Bundled signal classes take closed-form index
    paths; anything else falls back to a per-hour `at` loop."""
    hours = np.asarray(hours, dtype=float)
    if isinstance(signal, ConstantSignal):
        return np.full(hours.shape, signal.value)
    if isinstance(signal, HourlySignal):
        idx = np.floor(hours).astype(int) % 24
        return np.asarray(signal.values, dtype=float)[idx]
    if isinstance(signal, TraceSignal):
        return signal.sample(hours)
    if isinstance(signal, SignalEnsemble):   # scalar view: the member mean
        return signal.sample(hours).mean(axis=0)
    if hasattr(signal, "factor_at"):    # GridCarbonModel duck type
        return sample_signal(carbon_signal(signal), hours)
    return np.array([float(signal.at(float(h))) for h in hours.ravel()]
                    ).reshape(hours.shape)


def background_signal(bands) -> BandSignal:
    """The paper's contention model as a Signal: band -> background load."""
    from repro.core.policy import BANDS
    return BandSignal(bands, {b: bands.background(b) for b in BANDS},
                      name="background")


def sample_hourly(source) -> Tuple[float, ...]:
    """24 hourly samples from a GridCarbonModel or any Signal — the one
    place the hour grid is applied to a signal (engine, factories, and
    carbon_signal all build on this)."""
    at = getattr(source, "factor_at", None) or source.at
    return tuple(at(float(h)) for h in range(24))


def carbon_signal(carbon) -> Signal:
    """Grid carbon intensity (kg CO2e / kWh) as a Signal.

    Accepts a GridCarbonModel *or* any Signal (TraceSignal included, which
    passes through unchanged) — the one coercion point that lets the
    simulators and engines treat carbon uniformly instead of special-casing
    GridCarbonModel vs Signal.
    """
    if hasattr(carbon, "factor_at"):            # GridCarbonModel duck type
        if getattr(carbon, "hourly_curve", None) is None:
            return ConstantSignal(carbon.factor_kg_per_kwh, name="carbon")
        return HourlySignal(sample_hourly(carbon), name="carbon")
    if callable(getattr(carbon, "at", None)):   # already a Signal
        return carbon
    raise TypeError(
        f"carbon must be a GridCarbonModel or a Signal with a callable "
        f"at(hour); got {type(carbon).__name__} (plain hourly sequences "
        "are coerced with repro.core.signal.as_trace)")


# ---------------------------------------------------------------------------
# Electricity price (new input class; DTE-like time-of-use tariff).
# Off-peak 0.11 $/kWh, mid-day shoulder 0.15, on-peak 15-19 h at 0.21.
# ---------------------------------------------------------------------------
DTE_TOU_HOURLY: Tuple[float, ...] = (
    0.11, 0.11, 0.11, 0.11, 0.11, 0.11, 0.11, 0.15,
    0.15, 0.15, 0.15, 0.15, 0.15, 0.15, 0.15, 0.21,
    0.21, 0.21, 0.21, 0.21, 0.15, 0.15, 0.11, 0.11,
)

TOU_PRICE = HourlySignal(DTE_TOU_HOURLY, name="dte-tou-price")


@dataclasses.dataclass(frozen=True)
class SignalSet:
    """The bundle of signals a scheduling decision may consult."""
    background: Signal
    carbon: Signal
    price: Optional[Signal] = None

    def price_at(self, hour_of_day: float) -> float:
        return self.price.at(hour_of_day) if self.price is not None else 0.0

    def sample(self, grid: Sequence[float]
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Sample every signal on a grid of absolute hours.

        Returns `(background, carbon, price)` arrays of the grid's shape
        (price is all-zero when no price signal is set).  A convenience
        over `sample_signal`, which is the primitive the engines call
        per-signal (they carry cases' signals individually rather than
        as a SignalSet).
        """
        hours = np.asarray(grid, dtype=float)
        bg = sample_signal(self.background, hours)
        cf = sample_signal(self.carbon, hours)
        pr = (sample_signal(self.price, hours) if self.price is not None
              else np.zeros(hours.shape))
        return bg, cf, pr

    def is_periodic(self) -> bool:
        """True when every bundled signal repeats every 24 h."""
        return all(is_periodic_24h(s) for s in
                   (self.background, self.carbon, self.price)
                   if s is not None)


def default_signals(bands, carbon, price: Optional[Signal] = None) -> SignalSet:
    return SignalSet(background=background_signal(bands),
                     carbon=carbon_signal(carbon), price=price)


# ---------------------------------------------------------------------------
# Forecast-error models (receding-horizon MPC substrate).
#
# An MPC re-plan at hour `now_h` does not see the ground-truth trace; it
# sees a *forecast* of the remaining horizon.  A ForecastModel turns the
# ground truth into that per-re-plan view — seeded and stateless, so the
# same (truth, now_h, horizon_h) always yields the same forecast and a
# re-run of an MPC session is bit-reproducible.  The three bundled models
# bracket the forecast-quality axis from the West et al. carbon-shifting
# studies (arXiv:2503.13705, arXiv:2508.14625): `oracle` (perfect
# foresight — the open-loop upper bound), `day_ahead` (truth plus seeded
# multiplicative noise and optional bias), and `persistence` (yesterday's
# realized values repeated forward — the no-forecast baseline).
# ---------------------------------------------------------------------------

@runtime_checkable
class ForecastModel(Protocol):
    """Turns a ground-truth trace into a forecast of the remaining horizon."""

    name: str

    def forecast(self, truth, now_h: float, horizon_h: float) -> SignalEnsemble:
        """Forecast the window `[now_h, now_h + horizon_h]` of `truth`.

        Returns a `SignalEnsemble` (E >= 1 members) covering at least the
        requested window on the hourly grid.  Values at hours `<= now_h`
        are *observed* and must equal the realized truth; stochastic
        models must be deterministic in `(truth, now_h, horizon_h)` and
        their own seed.
        """
        ...


def _forecast_grid(truth, now_h: float, horizon_h: float):
    """The hourly grid a forecast is built on: integral hours from
    `floor(now_h)` through `now_h + horizon_h` (so the re-plan's sample
    grid, which is anchored at `floor(now_h)`, is fully covered)."""
    if horizon_h < 0:
        raise ValueError(f"horizon_h must be >= 0, got {horizon_h}")
    h0 = math.floor(now_h)
    n = max(1, math.ceil(now_h + horizon_h) - h0)
    return h0, np.arange(h0, h0 + n, dtype=float)


@dataclasses.dataclass(frozen=True)
class OracleForecast:
    """Perfect foresight: the forecast *is* the ground truth.

    The truth signal itself is returned as the single ensemble member
    (not a resampled copy), so an oracle-driven re-plan sees bitwise the
    same signal object as an open-loop optimize against the truth.
    """
    name: str = "oracle"

    def forecast(self, truth, now_h: float, horizon_h: float) -> SignalEnsemble:
        _forecast_grid(truth, now_h, horizon_h)   # validates horizon
        return SignalEnsemble((as_trace(truth),), name="oracle")


@dataclasses.dataclass(frozen=True)
class PersistenceForecast:
    """No-forecast baseline: the last observed period repeated forward.

    Future hours take the value realized exactly `lookback_h` (default 24,
    i.e. "same hour yesterday") before — iterated, so hour `now + 30`
    uses `now + 30 - 48` when a single lookback would still be in the
    future.  At the current hour the forecast equals the realized value
    (horizon-0 invariant), since only already-observed data is consulted.
    """
    lookback_h: float = 24.0
    name: str = "persistence"

    def __post_init__(self):
        if self.lookback_h <= 0:
            raise ValueError("lookback_h must be positive")

    def forecast(self, truth, now_h: float, horizon_h: float) -> SignalEnsemble:
        truth = as_trace(truth)
        h0, grid = _forecast_grid(truth, now_h, horizon_h)
        # Source hour per grid hour: observed hours pass through; future
        # hours step back whole lookback periods until at or before now.
        ahead = np.maximum(grid - now_h, 0.0)
        steps = np.ceil(ahead / self.lookback_h)
        src = grid - steps * self.lookback_h
        vals = sample_signal(truth, src)
        member = TraceSignal(tuple(float(v) for v in vals), start_hour=h0,
                             name=f"persistence@{now_h:g}h")
        return SignalEnsemble((member,), name="persistence")


@dataclasses.dataclass(frozen=True)
class DayAheadForecast:
    """Day-ahead-style forecast: truth plus seeded multiplicative error.

    Each member `m` sees `truth * (1 + bias + noise_sigma * eps)` with
    `eps ~ N(0, 1)` drawn from a generator seeded by
    `(seed, m, floor(now_h))` — stateless, so the same re-plan instant
    always produces the same forecast.  Hours at or before `now_h` are
    observed and pass through unperturbed.  With `noise_sigma == 0` and
    `bias == 0` the forecast values equal the oracle's.
    """
    noise_sigma: float = 0.1
    bias: float = 0.0
    n_members: int = 1
    seed: int = 0
    name: str = "day_ahead"

    def __post_init__(self):
        if self.noise_sigma < 0:
            raise ValueError("noise_sigma must be >= 0")
        if self.n_members < 1:
            raise ValueError("n_members must be >= 1")

    def forecast(self, truth, now_h: float, horizon_h: float) -> SignalEnsemble:
        truth = as_trace(truth)
        h0, grid = _forecast_grid(truth, now_h, horizon_h)
        base = sample_signal(truth, grid)
        future = grid > now_h
        members = []
        for m in range(self.n_members):
            vals = base.copy()
            if self.noise_sigma > 0.0 or self.bias != 0.0:
                rng = np.random.default_rng(
                    (int(self.seed), int(m), int(math.floor(now_h))))
                eps = rng.standard_normal(len(grid))
                factor = 1.0 + self.bias + self.noise_sigma * eps
                vals = np.where(future, base * factor, base)
                vals = np.maximum(vals, 1e-9)   # carbon intensity stays > 0
            members.append(TraceSignal(tuple(float(v) for v in vals),
                                       start_hour=h0,
                                       name=f"day_ahead[{m}]@{now_h:g}h"))
        return SignalEnsemble(tuple(members), name="day_ahead")


def oracle() -> OracleForecast:
    """Perfect-foresight forecast model (open-loop upper bound)."""
    return OracleForecast()


def persistence(lookback_h: float = 24.0) -> PersistenceForecast:
    """Persistence forecast model (same hour `lookback_h` ago)."""
    return PersistenceForecast(lookback_h=lookback_h)


def day_ahead(noise_sigma: float = 0.1, bias: float = 0.0,
              n_members: int = 1, seed: int = 0) -> DayAheadForecast:
    """Day-ahead forecast model (truth + seeded multiplicative error)."""
    return DayAheadForecast(noise_sigma=noise_sigma, bias=bias,
                            n_members=n_members, seed=seed)


def as_forecast(value) -> ForecastModel:
    """Coerce to a ForecastModel: pass through anything with a callable
    `forecast`, or map the names ``"oracle"`` / ``"persistence"`` /
    ``"day_ahead"`` to default-configured models."""
    if callable(getattr(value, "forecast", None)):
        return value
    if isinstance(value, str):
        factories = {"oracle": oracle, "persistence": persistence,
                     "day_ahead": day_ahead}
        if value in factories:
            return factories[value]()
        raise ValueError(
            f"unknown forecast model {value!r}; expected one of "
            f"{sorted(factories)} or a ForecastModel instance")
    raise TypeError(
        f"cannot interpret {type(value).__name__} as a ForecastModel; "
        "pass oracle()/persistence()/day_ahead(...) or a name string")
