"""Time-varying input signals (paper §2 instrumentation + stated future work).

A Signal is any time-of-day-varying scalar input the scheduler or the
simulator consumes: background office load, grid carbon intensity,
electricity price.  The paper hard-wires the first two (band levels in
`TimeBands.background`, an hourly multiplier in `GridCarbonModel`); this
module lifts them behind one interface so a live forecast feed — the
paper's "continuously updated regional carbon-intensity feeds" — can later
implement the same protocol without touching the simulator or the engine.

All bundled signals are periodic over 24 h and piecewise-constant per hour
(band boundaries fall on integer hours), which is what lets the vectorized
sweep engine (core/engine.py) evaluate them as 24-vectors.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Tuple, runtime_checkable


@runtime_checkable
class Signal(Protocol):
    """A scalar input varying with local time-of-day."""

    name: str

    def at(self, hour_of_day: float) -> float:
        """Value at the given local hour (any float; wraps mod 24)."""
        ...


@dataclasses.dataclass(frozen=True)
class ConstantSignal:
    """Flat signal (e.g. the paper's single DTE grid factor)."""
    value: float
    name: str = "constant"

    def at(self, hour_of_day: float) -> float:
        return self.value


@dataclasses.dataclass(frozen=True)
class HourlySignal:
    """24-slot piecewise-constant signal (one value per local hour)."""
    values: Tuple[float, ...]
    name: str = "hourly"

    def __post_init__(self):
        if len(self.values) != 24:
            raise ValueError(
                f"HourlySignal needs exactly 24 values, got {len(self.values)}")

    def at(self, hour_of_day: float) -> float:
        return self.values[int(hour_of_day) % 24]


@dataclasses.dataclass(frozen=True)
class BandSignal:
    """Signal defined per time band (e.g. background office load).

    `bands` is a TimeBands instance (duck-typed to avoid the import cycle);
    `levels` maps band name -> value.
    """
    bands: object
    levels: dict
    name: str = "band"

    def at(self, hour_of_day: float) -> float:
        return self.levels[self.bands.band_at(hour_of_day)]


def background_signal(bands) -> BandSignal:
    """The paper's contention model as a Signal: band -> background load."""
    from repro.core.policy import BANDS
    return BandSignal(bands, {b: bands.background(b) for b in BANDS},
                      name="background")


def sample_hourly(source) -> Tuple[float, ...]:
    """24 hourly samples from a GridCarbonModel or any Signal — the one
    place the hour grid is applied to a signal (engine, factories, and
    carbon_signal all build on this)."""
    at = getattr(source, "factor_at", None) or source.at
    return tuple(at(float(h)) for h in range(24))


def carbon_signal(carbon) -> Signal:
    """Grid carbon intensity (kg CO2e / kWh) as a Signal."""
    if getattr(carbon, "hourly_curve", None) is None:
        return ConstantSignal(carbon.factor_kg_per_kwh, name="carbon")
    return HourlySignal(sample_hourly(carbon), name="carbon")


# ---------------------------------------------------------------------------
# Electricity price (new input class; DTE-like time-of-use tariff).
# Off-peak 0.11 $/kWh, mid-day shoulder 0.15, on-peak 15-19 h at 0.21.
# ---------------------------------------------------------------------------
DTE_TOU_HOURLY: Tuple[float, ...] = (
    0.11, 0.11, 0.11, 0.11, 0.11, 0.11, 0.11, 0.15,
    0.15, 0.15, 0.15, 0.15, 0.15, 0.15, 0.15, 0.21,
    0.21, 0.21, 0.21, 0.21, 0.15, 0.15, 0.11, 0.11,
)

TOU_PRICE = HourlySignal(DTE_TOU_HOURLY, name="dte-tou-price")


@dataclasses.dataclass(frozen=True)
class SignalSet:
    """The bundle of signals a scheduling decision may consult."""
    background: Signal
    carbon: Signal
    price: Optional[Signal] = None

    def price_at(self, hour_of_day: float) -> float:
        return self.price.at(hour_of_day) if self.price is not None else 0.0


def default_signals(bands, carbon, price: Optional[Signal] = None) -> SignalSet:
    return SignalSet(background=background_signal(bands),
                     carbon=carbon_signal(carbon), price=price)
