"""The unified scheduling surface (Algorithm 1 line 6, generalized).

Everything that decides "how hard to work right now" — the six fixed
Figure-1 policies, the hourly carbon-aware factories, and any future
forecast-driven scheduler — implements one protocol:

    class Schedule(Protocol):
        name: str
        def decide(self, ctx: SchedulingContext) -> Decision

The context carries the local hour, the time band, and the current values
of every input Signal (background load, carbon intensity, price); the
decision carries worker intensity and orchestration batch size.  This
kills the `hasattr(policy, "intensity_at_hour")` duck typing that used to
be copy-pasted in both simulators and the controller.

Segmentation metadata: simulators and the vectorized engine need to know
when a schedule's decision can change.  `change_hours(schedule, bands)`
returns the sorted hour-of-day breakpoints (subset of [0, 24]); band
schedules change only at band edges, hourly schedules every hour, and
anything unknown conservatively every hour.  All bundled signals are
hourly-constant, so the hourly grid is always a safe refinement.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, Tuple, runtime_checkable


@dataclasses.dataclass(frozen=True)
class SchedulingContext:
    """Everything a schedule may consult for one decision."""
    hour_of_day: float           # local time, [0, 24)
    band: str                    # time band at this hour
    background: float            # background (office) load, [0, 1]
    carbon_factor: float         # grid intensity, kg CO2e / kWh
    price_usd_per_kwh: float = 0.0
    elapsed_h: float = 0.0       # hours since campaign start
    progress: float = 0.0        # fraction of the workload completed, [0, 1]


@dataclasses.dataclass(frozen=True)
class Decision:
    """One scheduling decision: how hard to work and at what granularity."""
    intensity: float             # worker intensity u in [0, 1]
    batch_size: int = 50         # orchestration batch size
    note: str = ""               # free-form provenance (dashboards/logs)


@runtime_checkable
class Schedule(Protocol):
    """Anything with a name that can turn a context into a decision."""

    name: str

    def decide(self, ctx: SchedulingContext) -> Decision:
        ...


# ---------------------------------------------------------------------------
# Segmentation metadata
# ---------------------------------------------------------------------------
HOURLY_GRID: Tuple[float, ...] = tuple(float(h) for h in range(25))


def change_hours(schedule, bands) -> Tuple[float, ...]:
    """Sorted hours in [0, 24] at which `schedule`'s decision may change.

    Schedules may implement `change_hours(bands)` themselves (band policies
    return the band edges); anything else is assumed hourly-constant, which
    is exact for every bundled signal and schedule.
    """
    fn = getattr(schedule, "change_hours", None)
    if callable(fn):
        return tuple(fn(bands))
    return HOURLY_GRID


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------
class FunctionSchedule:
    """Wrap a plain `ctx -> intensity` callable as a Schedule."""

    def __init__(self, name: str, fn: Callable[[SchedulingContext], float],
                 batch_size: int = 50):
        self.name = name
        self._fn = fn
        self.batch_size = batch_size

    def decide(self, ctx: SchedulingContext) -> Decision:
        return Decision(float(self._fn(ctx)), self.batch_size)


class _LegacyPolicyAdapter:
    """Back-compat shim for pre-Schedule duck-typed policy objects.

    Anything exposing the old `intensity_at(band)` (and optionally
    `intensity_at_hour(hour)` + `hourly_intensity`) surface keeps working;
    new code should subclass/implement Schedule directly.
    """

    def __init__(self, policy):
        self._policy = policy
        self.name = getattr(policy, "name", type(policy).__name__)
        self.batch_size = getattr(policy, "batch_size", 50)

    def decide(self, ctx: SchedulingContext) -> Decision:
        p = self._policy
        if hasattr(p, "intensity_at_hour") and getattr(p, "hourly_intensity", ()):
            u = p.intensity_at_hour(ctx.hour_of_day)
        else:
            u = p.intensity_at(ctx.band)
        return Decision(float(u), self.batch_size)

    def change_hours(self, bands) -> Tuple[float, ...]:
        p = self._policy
        if hasattr(p, "intensity_at_hour") and getattr(p, "hourly_intensity", ()):
            return HOURLY_GRID
        return bands.edges()


def as_schedule(obj) -> Schedule:
    """Coerce policies (old or new) into the Schedule protocol."""
    if hasattr(obj, "decide"):
        return obj
    if hasattr(obj, "intensity_at") or hasattr(obj, "intensity_at_hour"):
        return _LegacyPolicyAdapter(obj)
    raise TypeError(f"cannot interpret {obj!r} as a Schedule")
