"""The unified scheduling surface (Algorithm 1 line 6, generalized).

Everything that decides "how hard to work right now" — the six fixed
Figure-1 policies, the hourly carbon-aware factories, and any future
forecast-driven scheduler — implements one protocol:

    class Schedule(Protocol):
        name: str
        def decide(self, ctx: SchedulingContext) -> Decision

The context carries the local hour, the time band, and the current values
of every input Signal (background load, carbon intensity, price); the
decision carries worker intensity and orchestration batch size.  This
kills the `hasattr(policy, "intensity_at_hour")` duck typing that used to
be copy-pasted in both simulators and the controller.

Segmentation metadata: simulators and the vectorized engine need to know
when a schedule's decision can change.  `change_hours(schedule, bands)`
returns the sorted hour-of-day breakpoints (subset of [0, 24]); band
schedules change only at band edges, hourly schedules every hour, and
anything unknown conservatively every hour.  All bundled signals are
hourly-constant, so the hourly grid is always a safe refinement.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Protocol, Tuple, runtime_checkable

import numpy as np


@dataclasses.dataclass(frozen=True)
class SchedulingContext:
    """Everything a schedule may consult for one decision.

    The site-level fields describe the shared power envelope a fleet of
    concurrent campaigns runs under (core/fleet.py): `site_power_kw` is
    the total site draw (office + all campaigns) over the slot *entering*
    this decision, `site_headroom` the fraction of the site cap still
    free at that draw (1.0 when the site has no cap), and `n_active` the
    number of fleet campaigns with work remaining.  Standalone campaigns
    keep the defaults — a schedule written against them behaves
    identically with and without a fleet.  The site fields are exact in
    the sequential fleet oracle; the vectorized engines lower decisions
    to tables and do not feed live site state back into `decide()` (the
    cap coupling itself is physics, applied by the engine after
    decisions — see `model.site_throttle`).
    """
    hour_of_day: float           # local time, [0, 24)
    band: str                    # time band at this hour
    background: float            # background (office) load, [0, 1]
    carbon_factor: float         # grid intensity, kg CO2e / kWh
    price_usd_per_kwh: float = 0.0
    elapsed_h: float = 0.0       # hours since campaign start
    progress: float = 0.0        # fraction of the workload completed, [0, 1]
    deadline_h: float = 0.0      # campaign deadline in hours (0 = none)
    site_power_kw: float = 0.0   # site draw entering this slot (0 = unknown)
    site_headroom: float = 1.0   # free fraction of the site cap, [0, 1]
    n_active: int = 1            # fleet campaigns still running


@dataclasses.dataclass(frozen=True)
class Decision:
    """One scheduling decision: how hard to work and at what granularity."""
    intensity: float             # worker intensity u in [0, 1]
    batch_size: int = 50         # orchestration batch size
    note: str = ""               # free-form provenance (dashboards/logs)


@runtime_checkable
class Schedule(Protocol):
    """Anything with a name that can turn a context into a decision."""

    name: str

    def decide(self, ctx: SchedulingContext) -> Decision:
        ...


# ---------------------------------------------------------------------------
# Segmentation metadata
# ---------------------------------------------------------------------------
HOURLY_GRID: Tuple[float, ...] = tuple(float(h) for h in range(25))


def change_hours(schedule, bands) -> Tuple[float, ...]:
    """Sorted hours in [0, 24] at which `schedule`'s decision may change.

    Schedules may implement `change_hours(bands)` themselves (band policies
    return the band edges); anything else is assumed hourly-constant, which
    is exact for every bundled signal and schedule.
    """
    fn = getattr(schedule, "change_hours", None)
    if callable(fn):
        return tuple(fn(bands))
    return HOURLY_GRID


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------
class FunctionSchedule:
    """Wrap a plain `ctx -> intensity` callable as a Schedule."""

    def __init__(self, name: str, fn: Callable[[SchedulingContext], float],
                 batch_size: int = 50):
        self.name = name
        self._fn = fn
        self.batch_size = batch_size

    def decide(self, ctx: SchedulingContext) -> Decision:
        return Decision(float(self._fn(ctx)), self.batch_size)


@dataclasses.dataclass(frozen=True)
class DeadlineSchedule:
    """Pace-keeping deadline schedule (the related-work "deadline-aware
    shifting" pattern): run gently at `u_low` while ahead of the linear
    pace toward the deadline, ramp up to `u_high` as the campaign falls
    behind.

    The controller is proportional over a progress window of width
    `band` just ahead of the pace line: full boost at/behind pace, easing
    down to `u_low` once the campaign is `band` ahead — so feasible
    deadlines are met with a small margin rather than tracked from
    behind.  `band=0` degenerates to a bang-bang boost-when-behind
    switch, which is harsher on any discretized simulator — the
    proportional default is what the trace-grid engine's accuracy bar is
    pinned on.

    The deadline comes from the schedule's own `deadline_h` when given,
    else from `ctx.deadline_h` (so one schedule object can be swept
    against many deadlines via `Campaign.sweep(deadline_h=...)`).  With
    no deadline at all it runs flat-out at `u_high`.  Consults
    `ctx.progress`/`ctx.elapsed_h`, so it needs the sequential simulators
    or the trace-grid engine — the periodic 24-slot engine cannot
    represent it.

    Implements `decide_grid` (the vectorized decision protocol): engines
    may pass a SchedulingContext whose fields are broadcastable NumPy
    arrays and get the whole decision table back in one call, instead of
    sampling decide() once per (hour, progress-bucket) grid point.
    """
    deadline_h: float = 0.0
    u_low: float = 0.35
    u_high: float = 0.95
    band: float = 0.1
    batch_size: int = 50
    name: str = "deadline_pace"

    def _intensity(self, elapsed_h, progress, ctx_deadline_h):
        dl = self.deadline_h if self.deadline_h > 0.0 else ctx_deadline_h
        if dl <= 0.0:
            return np.broadcast_to(
                self.u_high, np.broadcast_shapes(np.shape(elapsed_h),
                                                 np.shape(progress)))
        pace = np.minimum(np.asarray(elapsed_h, dtype=float) / dl, 1.0)
        behind = pace - progress
        if self.band <= 0.0:
            return np.where(behind > 0.0, self.u_high, self.u_low)
        frac = np.clip(behind / self.band + 1.0, 0.0, 1.0)
        return self.u_low + (self.u_high - self.u_low) * frac

    def decide(self, ctx: SchedulingContext) -> Decision:
        return Decision(float(self._intensity(ctx.elapsed_h, ctx.progress,
                                              ctx.deadline_h)),
                        self.batch_size)

    def decide_grid(self, ctx: SchedulingContext):
        """(intensity, batch_size) arrays over a grid context."""
        u = self._intensity(ctx.elapsed_h, ctx.progress, ctx.deadline_h)
        return u, np.broadcast_to(float(self.batch_size), np.shape(u))


def deadline_schedule(deadline_h: float = 0.0, *, u_low: float = 0.35,
                      u_high: float = 0.95, band: float = 0.1,
                      batch_size: int = 50,
                      name: str = "") -> DeadlineSchedule:
    """A `DeadlineSchedule` with a readable default label."""
    label = name or (f"deadline_{deadline_h:g}h" if deadline_h
                     else "deadline_pace")
    return DeadlineSchedule(deadline_h, u_low, u_high, band, batch_size,
                            label)


def progress_ramp_schedule(u_start: float = 0.4, u_end: float = 0.9,
                           batch_size: int = 50,
                           name: str = "") -> FunctionSchedule:
    """Intensity ramping linearly with campaign progress — start gentle,
    finish hard.  Progress-aware, so trace-grid/sequential only."""

    def ramp(ctx: SchedulingContext) -> float:
        return u_start + (u_end - u_start) * min(max(ctx.progress, 0.0), 1.0)

    return FunctionSchedule(name or f"ramp_{u_start:g}_{u_end:g}", ramp,
                            batch_size)


def _sigmoid(z, xp=np):
    """Numerically stable logistic, polymorphic over the array namespace
    (tanh is bounded both directions, unlike the naive 1/(1+exp(-z)))."""
    return 0.5 * (xp.tanh(0.5 * z) + 1.0)


@dataclasses.dataclass(frozen=True)
class ParametricSchedule:
    """The optimizer's schedule family: one free intensity parameter per
    slot of the day, squashed through a sigmoid into [u_min, u_max].

    `logits[i]` controls the worker intensity over local hours
    `[24 i / n, 24 (i + 1) / n)` where `n = len(logits)`; the intensity is
    `u_min + (u_max - u_min) * sigmoid(logits[i])`, so every point of the
    parameter space is a feasible schedule and gradients never push
    intensities out of range.  `n` may exceed 24 for sub-hour resolution
    (48 -> half-hour slots); slot edges must align to a minute grid like
    band edges (n must divide a multiple of 24 up to 24*60).

    The family is deliberately *periodic and progress-free*: the decision
    depends on hour-of-day only, so it lowers to a decision table with no
    Python in the engines' hot loops.  `decide_grid` (the vectorized
    decision protocol) builds the whole table in one NumPy call;
    `core/engine_jax.py`'s `TraceObjective` consumes the same
    `u_from_logits` mapping inside jit/grad, which is what makes
    `core/optimize.py`'s gradient search possible.

    `from_intensities` inverts the squash (warm-starting the optimizer
    from a hand-written policy); `with_logits` rebinds parameters on an
    otherwise identical schedule (how the optimizer materializes its
    result).  A non-None `levels` snaps the materialized table to the
    nearest allowed intensity (exactly — membership tests against the
    level set hold; the squash cannot represent arbitrary values
    bit-exactly through a logit round trip), which is how the optimizer
    returns discrete decision tables.
    """
    logits: Tuple[float, ...]
    u_min: float = 0.05
    u_max: float = 1.0
    batch_size: int = 50
    name: str = "parametric"
    levels: Optional[Tuple[float, ...]] = None

    #: Contract flag for the trace engine's compiler: decisions depend on
    #: hour-of-day only (never elapsed/progress/carbon), so the decide_grid
    #: table may be lowered to one day-periodic block instead of being
    #: rebuilt per horizon chunk.  Custom decide_grid schedules may opt in
    #: by declaring the same attribute; without it they keep exact
    #: per-slot tables.
    periodic_decisions = True

    def __post_init__(self):
        n = len(self.logits)
        if n < 1:
            raise ValueError("ParametricSchedule needs at least one slot")
        if (24.0 * 60.0) % n:
            raise ValueError(
                f"n_slots={n} does not divide the day on a minute grid; "
                "use a divisor of 1440 (24, 48, 96, ...)")
        if not (0.0 <= self.u_min < self.u_max <= 1.0):
            raise ValueError(
                f"need 0 <= u_min < u_max <= 1, got ({self.u_min}, "
                f"{self.u_max})")
        # materialize the decision table once (frozen dataclass, so
        # decide() would otherwise recompute the sigmoid + level snap on
        # every sequential-simulator segment)
        u = self.u_from_logits(np.asarray(self.logits, dtype=float),
                               self.u_min, self.u_max, xp=np)
        if self.levels is not None:
            lv = np.asarray(self.levels, dtype=float)
            u = lv[np.argmin(np.abs(u[:, None] - lv[None, :]), axis=1)]
        object.__setattr__(self, "_table", u)

    # ---- parameter mapping (shared with the jitted objective) -------------
    @staticmethod
    def u_from_logits(logits, u_min: float = 0.05, u_max: float = 1.0,
                      xp=np):
        """logits -> intensities in [u_min, u_max]; works for NumPy *and*
        jnp arrays (the one definition the optimizer differentiates)."""
        return u_min + (u_max - u_min) * _sigmoid(logits, xp=xp)

    @classmethod
    def from_intensities(cls, intensities, *, u_min: float = 0.05,
                         u_max: float = 1.0, batch_size: int = 50,
                         name: str = "parametric") -> "ParametricSchedule":
        """Invert the squash: the ParametricSchedule whose table matches
        `intensities` (clipped into the open (u_min, u_max) interval)."""
        u = np.clip(np.asarray(intensities, dtype=float),
                    u_min + 1e-4 * (u_max - u_min),
                    u_max - 1e-4 * (u_max - u_min))
        frac = (u - u_min) / (u_max - u_min)
        return cls(tuple(float(v) for v in np.log(frac / (1.0 - frac))),
                   u_min=u_min, u_max=u_max, batch_size=batch_size,
                   name=name)

    def with_logits(self, logits, name: str = "") -> "ParametricSchedule":
        return dataclasses.replace(
            self, logits=tuple(float(v) for v in np.asarray(logits).ravel()),
            name=name or self.name)

    # ---- derived views ----------------------------------------------------
    @property
    def n_slots(self) -> int:
        return len(self.logits)

    def intensity_table(self) -> np.ndarray:
        """(n_slots,) intensities — the schedule as a decision table
        (snapped exactly onto `levels` when set)."""
        return self._table.copy()

    # ---- Schedule protocol ------------------------------------------------
    # Slot lookups add a half-ulp guard (+1e-9 slots) before flooring:
    # when 24/n_slots is not binary-representable (n_slots = 120, 240,
    # ...), a grid hour sitting exactly on a slot edge can compute as
    # 40.999999999999996 and truncate one slot low, breaking the 1e-9
    # engine-consistency contract with the sequential simulator.
    def decide(self, ctx: SchedulingContext) -> Decision:
        i = int((ctx.hour_of_day % 24.0) * self.n_slots / 24.0 + 1e-9)
        return Decision(float(self._table[min(i, self.n_slots - 1)]),
                        self.batch_size)

    def decide_grid(self, ctx: SchedulingContext):
        """Vectorized decision protocol: hour-of-day arrays in, the whole
        intensity table out (no Python in the engines' hot loops)."""
        hod = np.asarray(ctx.hour_of_day, dtype=float)
        idx = np.minimum(np.floor((hod % 24.0) * self.n_slots / 24.0 + 1e-9),
                         self.n_slots - 1).astype(int)
        u = self.intensity_table()[idx]
        return u, np.broadcast_to(float(self.batch_size), np.shape(u))

    def change_hours(self, bands) -> Tuple[float, ...]:
        """Slot edges: the engines refine their grid to align them (a
        48-slot schedule forces a half-hour trace grid)."""
        return tuple(24.0 * i / self.n_slots for i in range(self.n_slots + 1))


def parametric_schedule(n_slots: int = 24, *, init: float = 0.6,
                        u_min: float = 0.05, u_max: float = 1.0,
                        batch_size: int = 50,
                        name: str = "parametric") -> ParametricSchedule:
    """A flat ParametricSchedule at intensity `init` — the optimizer's
    default starting point."""
    return ParametricSchedule.from_intensities(
        np.full(n_slots, float(init)), u_min=u_min, u_max=u_max,
        batch_size=batch_size, name=name)


# ---------------------------------------------------------------------------
# Joint (fleet-level) scheduling
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CarbonGateSchedule:
    """Demand `u_high` while grid carbon is at or below `threshold`
    (kg CO2e/kWh), `u_low` above it — the per-member demand rule behind
    `carbon_gated_cap`: gating every member's demand on one shared
    carbon signal caps the whole fleet's draw in dirty hours.  Consults
    `ctx.carbon_factor`, so the trace compiler's probe classifies it
    carbon-dependent (per-member decision tables under an ensemble)."""
    threshold: float
    u_low: float = 0.15
    u_high: float = 0.95
    batch_size: int = 50
    name: str = "carbon_gate"

    def decide(self, ctx: SchedulingContext) -> Decision:
        u = self.u_high if ctx.carbon_factor <= self.threshold else self.u_low
        return Decision(float(u), self.batch_size)

    def decide_grid(self, ctx: SchedulingContext):
        u = np.where(np.asarray(ctx.carbon_factor) <= self.threshold,
                     self.u_high, self.u_low)
        u = np.broadcast_to(u, np.broadcast_shapes(np.shape(u),
                                                   np.shape(ctx.progress)))
        return u, np.broadcast_to(float(self.batch_size), np.shape(u))


@dataclasses.dataclass(frozen=True)
class AllocationSchedule:
    """A joint schedule: per-campaign intensities for a whole fleet.

    One `AllocationSchedule` covers M concurrent campaigns under a
    shared site (core/fleet.py).  It is two coupled halves:

      * **demand** — `members[m]` is campaign m's demand schedule (any
        ordinary `Schedule`; a single member broadcasts to every
        campaign).  `decide_joint(ctxs)` returns the demanded
        per-campaign decisions;
      * **allocation** — the realized intensities follow from the site's
        shared curtailment, `model.site_throttle`: when the demanded
        fleet draw exceeds the site headroom, every campaign is scaled
        by the same demand-proportional factor.  This is physics, not
        schedule code — the sequential fleet oracle and the grouped-lane
        engine both apply it after decisions, so a demand schedule runs
        identically under both.

    The bundled reference allocations compose existing demand families:
    `proportional_split` (flat equal demand — the cap splits headroom
    proportionally), `deadline_weighted_split` (per-member
    `DeadlineSchedule` pace-keepers — campaigns behind their deadline
    demand more and therefore win a larger share of a contended cap),
    and `carbon_gated_cap` (per-member `CarbonGateSchedule`s — the whole
    fleet's draw is gated on grid carbon).  `decide(ctx)` delegates to
    member 0 so an AllocationSchedule still satisfies the `Schedule`
    protocol (an M=1 fleet degenerates to a plain campaign).
    """
    members: Tuple[Schedule, ...]
    name: str = "allocation"

    def __post_init__(self):
        if len(self.members) < 1:
            raise ValueError("AllocationSchedule needs at least one member "
                             "demand schedule")

    def n_members(self) -> int:
        return len(self.members)

    def member_schedule(self, m: int) -> Schedule:
        """Campaign m's demand schedule (a single member broadcasts)."""
        if len(self.members) == 1:
            return self.members[0]
        return self.members[m]

    def for_fleet(self, n: int) -> Tuple[Schedule, ...]:
        """The M per-campaign demand schedules for an M-campaign fleet."""
        if len(self.members) not in (1, n):
            raise ValueError(
                f"AllocationSchedule {self.name!r} has {len(self.members)} "
                f"member schedules but the fleet has {n} campaigns; give "
                "one (broadcast) or exactly one per campaign")
        return tuple(self.member_schedule(m) for m in range(n))

    def decide(self, ctx: SchedulingContext) -> Decision:
        return self.members[0].decide(ctx)

    def decide_joint(self, ctxs) -> Tuple[Decision, ...]:
        """Demanded decisions for every campaign, one context each
        (contexts carry the site fields plus per-campaign progress/
        deadline).  Realized intensities are these demands scaled by the
        site curtailment factor — see `model.site_throttle`."""
        return tuple(self.member_schedule(m).decide(ctx)
                     for m, ctx in enumerate(ctxs))

    def change_hours(self, bands) -> Tuple[float, ...]:
        hs = set()
        for s in self.members:
            hs.update(change_hours(s, bands))
        return tuple(sorted(hs))


def proportional_split(u: float = 0.9, *, batch_size: int = 50,
                       name: str = "") -> AllocationSchedule:
    """Every campaign demands the same flat intensity; under a site cap
    the shared curtailment splits the headroom proportionally (equal
    demand -> equal share)."""
    from repro.core.policy import constant_schedule
    return AllocationSchedule((constant_schedule(u, batch_size=batch_size),),
                              name=name or f"proportional_{u:g}")


def deadline_weighted_split(deadlines_h, *, u_low: float = 0.35,
                            u_high: float = 0.95, band: float = 0.1,
                            batch_size: int = 50,
                            name: str = "") -> AllocationSchedule:
    """Per-campaign `DeadlineSchedule` pace-keepers: a campaign behind
    its own deadline pace demands more, so a contended cap is split in
    favour of the urgent campaigns (demand-proportional curtailment
    turns demand weights into allocation weights)."""
    members = tuple(deadline_schedule(float(d), u_low=u_low, u_high=u_high,
                                      band=band, batch_size=batch_size)
                    for d in deadlines_h)
    return AllocationSchedule(members, name=name or "deadline_weighted")


def carbon_gated_cap(threshold: float, *, u_low: float = 0.15,
                     u_high: float = 0.95, batch_size: int = 50,
                     name: str = "") -> AllocationSchedule:
    """Gate the whole fleet's demand on grid carbon: every campaign
    demands `u_high` in clean hours (carbon <= threshold) and `u_low`
    in dirty ones, capping the site's draw exactly when it is most
    carbon-expensive."""
    member = CarbonGateSchedule(float(threshold), u_low=u_low, u_high=u_high,
                                batch_size=batch_size)
    return AllocationSchedule((member,),
                              name=name or f"carbon_gate_{threshold:g}")


class _LegacyPolicyAdapter:
    """Back-compat shim for pre-Schedule duck-typed policy objects.

    Anything exposing the old `intensity_at(band)` (and optionally
    `intensity_at_hour(hour)` + `hourly_intensity`) surface keeps working;
    new code should subclass/implement Schedule directly.
    """

    def __init__(self, policy):
        self._policy = policy
        self.name = getattr(policy, "name", type(policy).__name__)
        self.batch_size = getattr(policy, "batch_size", 50)

    def decide(self, ctx: SchedulingContext) -> Decision:
        p = self._policy
        if hasattr(p, "intensity_at_hour") and getattr(p, "hourly_intensity", ()):
            u = p.intensity_at_hour(ctx.hour_of_day)
        else:
            u = p.intensity_at(ctx.band)
        return Decision(float(u), self.batch_size)

    def change_hours(self, bands) -> Tuple[float, ...]:
        p = self._policy
        if hasattr(p, "intensity_at_hour") and getattr(p, "hourly_intensity", ()):
            return HOURLY_GRID
        return bands.edges()


def dedupe_names(names) -> list:
    """Disambiguate duplicate labels with an indexed suffix (`name#1`,
    `name#2`, ...), so sweep result rows and dashboard tables keyed by
    name never silently collide."""
    seen: dict = {}
    out = []
    for n in names:
        if n in seen:
            seen[n] += 1
            out.append(f"{n}#{seen[n]}")
        else:
            seen[n] = 0
            out.append(n)
    return out


def as_schedule(obj) -> Schedule:
    """Coerce policies (old or new) into the Schedule protocol."""
    if hasattr(obj, "decide"):
        return obj
    if hasattr(obj, "intensity_at") or hasattr(obj, "intensity_at_hour"):
        return _LegacyPolicyAdapter(obj)
    raise TypeError(f"cannot interpret {obj!r} as a Schedule")
