"""CarinaController: execution-time control for the TPU training loop
(Algorithm 1, lines 6-8, with the knob mapping of DESIGN.md §2).

Per tracked unit (a training round of N steps) the controller:
  1. determines the local time phase (band) — simulated or wall clock;
  2. asks the Schedule for a decision (worker intensity) given the full
     SchedulingContext (band, background load, carbon intensity);
  3. maps intensity -> TPU knobs:
       * active dp replicas: floor(u * max_replicas), plus one extra
         duty-cycled replica whenever there is a fractional remainder
         (elastic width; a change triggers checkpoint + re-mesh in the
         training loop),
       * duty cycle: u / (replicas / max_replicas) — the fractional
         remainder of the last replica is realized as sleep between steps
         (priority-reduction analogue), so replicas * duty == u exactly;
  4. after execution records runtime / energy estimate / carbon into the
     RunTracker (roofline-mode energy when a compiled StepCost is known).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.carbon import GridCarbonModel
from repro.core.energy import ChipProfile, EnergyModel, StepCost
from repro.core.policy import BASELINE, TimeBands
from repro.core.schedule import SchedulingContext, as_schedule
from repro.core.tracker import RunTracker


@dataclasses.dataclass
class IntensityDecision:
    band: str
    intensity: float
    replicas: int            # active dp replicas
    duty: float              # in [0,1]: fraction of time stepping (sleep rest)


class SimClock:
    """Simulated campaign clock: hours advance as the loop reports runtime.
    Lets CPU-scale tests traverse day/night bands in seconds."""

    def __init__(self, start_hour: float = 9.0, speedup: float = 1.0):
        self.hours = start_hour
        self.speedup = speedup

    def advance_s(self, seconds: float):
        self.hours += self.speedup * seconds / 3600.0

    def hour_of_day(self) -> float:
        return self.hours % 24.0


class CarinaController:
    def __init__(self, policy=BASELINE, bands: TimeBands = TimeBands(),
                 tracker: Optional[RunTracker] = None,
                 max_replicas: int = 1, min_replicas: int = 1,
                 clock: Optional[SimClock] = None,
                 chip: ChipProfile = ChipProfile(),
                 step_cost: Optional[StepCost] = None,
                 carbon: Optional[GridCarbonModel] = None,
                 price=None):
        self.policy = policy                      # kept for introspection
        self.schedule = as_schedule(policy)
        self.bands = bands
        self.tracker = tracker
        self.max_replicas = max_replicas
        self.min_replicas = min_replicas
        self.clock = clock or SimClock()
        self.energy = EnergyModel(chip=chip)
        self.step_cost = step_cost
        self.carbon = carbon or (tracker.carbon if tracker is not None
                                 else GridCarbonModel())
        self.price = price                        # optional price Signal
        self.decisions = []

    # ---- Algorithm 1 lines 6-8 -------------------------------------------
    def decide(self) -> IntensityDecision:
        hour = self.clock.hour_of_day()
        band = self.bands.band_at(hour)
        ctx = SchedulingContext(
            hour_of_day=hour, band=band,
            background=self.bands.background(band),
            carbon_factor=self.carbon.factor_at(hour),
            price_usd_per_kwh=(self.price.at(hour)
                               if self.price is not None else 0.0))
        u = float(self.schedule.decide(ctx).intensity)
        # floor(u * max) full replicas; a fractional remainder adds one more
        # replica whose surplus capacity the duty cycle sleeps away, so
        # realized * duty == u (no part of u is silently dropped, which is
        # what round() did when it rounded down).
        want = u * self.max_replicas
        replicas = math.floor(want + 1e-9)
        if want - replicas > 1e-9:
            replicas += 1
        replicas = max(self.min_replicas, min(self.max_replicas, replicas))
        replicas = max(replicas, 1)
        realized = replicas / self.max_replicas
        duty = min(1.0, u / realized) if realized > 0 else 1.0
        d = IntensityDecision(band, u, replicas, duty)
        self.decisions.append(d)
        return d

    # ---- Algorithm 1 lines 10-11 -------------------------------------------
    def record_unit(self, decision: IntensityDecision, *, steps: int,
                    runtime_s: float, meta: Optional[dict] = None):
        self.clock.advance_s(runtime_s)
        if self.step_cost is not None:
            joules = steps * self.energy.step_energy_j(
                dataclasses.replace(self.step_cost,
                                    chips=self.step_cost.chips), decision.duty)
            # scale chips by active replica fraction
            joules *= decision.replicas / self.max_replicas
            kwh = joules / 3.6e6
        else:
            # runtime-mode fallback: machine profile at this intensity
            kwh = self.energy.runtime_energy_kwh(runtime_s, decision.intensity)
        if self.tracker is not None:
            self.tracker.record_unit(
                phase=decision.band, intensity=decision.intensity,
                runtime_s=runtime_s, energy_kwh=kwh,
                sim_time_h=self.clock.hours,
                meta=dict(meta or {}, steps=steps, replicas=decision.replicas,
                          duty=decision.duty))
        return kwh
