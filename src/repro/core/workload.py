"""Recurrent-workload descriptions (paper §2: "a sequence of tracked units,
where a unit may be a full run, a refresh batch, a wave, an epoch, or a
training round").

`OEMWorkload` models the paper's sheet-metal database-generation campaigns:
N scenarios executed in batches against worker-local engines, with per-batch
orchestration overhead (write inputs / trigger recalc / extract / store) and
resume/merge/verify bookkeeping.

`TrainingCampaign` is the TPU-side analogue: a recurring train/eval workload
whose unit is a training round of `steps_per_unit` steps.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.energy import StepCost


@dataclasses.dataclass(frozen=True)
class OEMWorkload:
    name: str
    n_scenarios: int
    rate_at_full: float           # scenarios/s at intensity 1.0, no contention
    batch_overhead_s: float       # per-batch orchestration time
    # measured baseline (for calibration/validation)
    measured_hours: Optional[float] = None
    measured_kwh: Optional[float] = None


# The two automotive OEM case studies (paper §3). rate_at_full is derived in
# core/simulator.calibrate_rate so that the measured runtime is matched
# exactly under the baseline policy.
OEM_CASE_1 = OEMWorkload("oem-case-1", 1_480_000, rate_at_full=0.0,
                         batch_overhead_s=2.0,
                         measured_hours=180.30, measured_kwh=48.67)
OEM_CASE_2 = OEMWorkload("oem-case-2", 3_660_000, rate_at_full=0.0,
                         batch_overhead_s=2.0,
                         measured_hours=274.75, measured_kwh=74.16)


@dataclasses.dataclass(frozen=True)
class TrainingCampaign:
    """Recurrent ML workload (scheduled retraining / eval / HPO wave)."""
    name: str
    arch: str
    total_steps: int
    steps_per_unit: int
    step_cost: Optional[StepCost] = None     # from the dry-run, when available
    step_seconds_hint: float = 1.0           # fallback if no compiled cost
