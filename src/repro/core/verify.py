"""Verification logic for tracked-unit logs (paper §2: the OEM workloads
use "resume, merge, and verification logic" — this is the verification
side: a JSONL unit log can be re-aggregated and checked for internal
consistency after crashes/restarts/merges).

Checks:
  1. schema: every record has the UnitRecord fields with sane types;
  2. monotonic unit indices (per producer) and non-negative quantities;
  3. carbon consistency: co2 == factor(hour) * energy within tolerance;
  4. summary consistency: an embedded summary line (if present) matches the
     re-aggregation of the unit records preceding it.

Returns a VerifyReport; `ok` is False with per-check messages otherwise.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional

from repro.core.carbon import GridCarbonModel


@dataclasses.dataclass
class VerifyReport:
    ok: bool
    n_units: int
    energy_kwh: float
    co2_kg: float
    errors: List[str]


REQUIRED = ("index", "phase", "intensity", "runtime_s", "energy_kwh",
            "co2_kg", "sim_time_h")


def verify_unit_log(path: str, carbon: Optional[GridCarbonModel] = None,
                    rtol: float = 1e-6) -> VerifyReport:
    carbon = carbon or GridCarbonModel()
    errors: List[str] = []
    units = []
    summary = None
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"line {ln}: bad json ({e})")
                continue
            if "summary" in rec:
                summary = rec["summary"]
                continue
            missing = [k for k in REQUIRED if k not in rec]
            if missing:
                errors.append(f"line {ln}: missing fields {missing}")
                continue
            if rec["runtime_s"] < 0 or rec["energy_kwh"] < 0:
                errors.append(f"line {ln}: negative quantities")
            want_co2 = carbon.co2_kg(rec["energy_kwh"],
                                     hour_of_day=rec["sim_time_h"] % 24.0)
            if abs(rec["co2_kg"] - want_co2) > rtol + rtol * abs(want_co2):
                errors.append(
                    f"line {ln}: carbon mismatch {rec['co2_kg']} vs {want_co2}")
            units.append(rec)

    for prev, cur in zip(units, units[1:]):
        if cur["index"] < prev["index"]:
            errors.append(f"unit {cur['index']}: non-monotonic index")

    e_tot = sum(u["energy_kwh"] for u in units)
    c_tot = sum(u["co2_kg"] for u in units)
    if summary is not None:
        if abs(summary.get("energy_kwh", 0.0) - e_tot) > 1e-6 + 1e-6 * e_tot:
            errors.append("summary energy does not match re-aggregation")
        if summary.get("units") != len(units):
            errors.append(f"summary units {summary.get('units')} != {len(units)}")
    return VerifyReport(not errors, len(units), e_tot, c_tot, errors)
