"""Local dashboard reporting (paper §2: "structured logs, summary metrics,
plots, and dashboard artifacts").

Emits a self-contained markdown dashboard + machine-readable JSON; a PNG
frontier plot is produced when matplotlib is importable (optional).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional, Sequence

from repro.core.simulator import SimResult
from repro.core.tracker import RunSummary


def _spark(values: Sequence[float], width: int = 48) -> str:
    if not values:
        return ""
    blocks = "▁▂▃▄▅▆▇█"
    lo, hi = min(values), max(values)
    rng = (hi - lo) or 1.0
    step = max(1, len(values) // width)
    vs = [values[i] for i in range(0, len(values), step)]
    return "".join(blocks[min(7, int(7 * (v - lo) / rng))] for v in vs)


def render_run_dashboard(summary: RunSummary, out_dir: str,
                         power_series: Optional[Sequence[float]] = None) -> str:
    os.makedirs(out_dir, exist_ok=True)
    lines = [
        f"# CARINA run dashboard — {summary.name}",
        "",
        f"| metric | value |",
        f"|---|---|",
        f"| tracked units | {summary.units} |",
        f"| runtime | {summary.runtime_h:.2f} h |",
        f"| energy load | {summary.energy_kwh:.3f} kWh |",
        f"| carbon burden | {summary.co2_kg:.3f} kg CO2e |",
        "",
        "## By phase",
        "",
        "| phase | units | runtime (h) | energy (kWh) | CO2e (kg) |",
        "|---|---|---|---|---|",
    ]
    for ph, d in sorted(summary.by_phase.items()):
        lines.append(f"| {ph} | {int(d['units'])} | {d['runtime_s']/3600:.2f} "
                     f"| {d['energy_kwh']:.3f} | {d['co2_kg']:.3f} |")
    if power_series:
        lines += ["", "## Power trace", "", "```", _spark(power_series), "```"]
    md = "\n".join(lines) + "\n"
    with open(os.path.join(out_dir, "dashboard.md"), "w") as f:
        f.write(md)
    with open(os.path.join(out_dir, "dashboard.json"), "w") as f:
        json.dump(dataclasses.asdict(summary), f, indent=2, sort_keys=True)
    return md


def render_frontier_dashboard(results: List[SimResult], out_dir: str,
                              title: str = "policy frontier") -> str:
    os.makedirs(out_dir, exist_ok=True)
    lines = [
        f"# CARINA {title}",
        "",
        "| policy | runtime (h) | energy (kWh) | CO2e (kg) | Δruntime | Δenergy |",
        "|---|---|---|---|---|---|",
    ]
    for r in results:
        lines.append(
            f"| {r.policy} | {r.runtime_h:.2f} | {r.energy_kwh:.2f} "
            f"| {r.co2_kg:.2f} | {r.runtime_delta_pct:+.2f}% "
            f"| {r.energy_delta_pct:+.2f}% |")
    md = "\n".join(lines) + "\n"
    with open(os.path.join(out_dir, "frontier.md"), "w") as f:
        f.write(md)
    with open(os.path.join(out_dir, "frontier.json"), "w") as f:
        json.dump([dataclasses.asdict(
            dataclasses.replace(r, summary=None)) for r in results],
            f, indent=2, sort_keys=True)
    try:  # optional plot
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
        fig, ax = plt.subplots(figsize=(6, 4))
        for r in results:
            ax.scatter(r.runtime_delta_pct, -r.energy_delta_pct, s=40)
            ax.annotate(r.policy.replace("peak_aware_", "pa_"),
                        (r.runtime_delta_pct, -r.energy_delta_pct), fontsize=7)
        ax.set_xlabel("runtime penalty (%)")
        ax.set_ylabel("energy savings (%)")
        ax.grid(alpha=0.3)
        ax.set_title(title)
        fig.tight_layout()
        fig.savefig(os.path.join(out_dir, "frontier.png"), dpi=120)
        plt.close(fig)
    except Exception:
        pass
    return md
