"""Local dashboard reporting (paper §2: "structured logs, summary metrics,
plots, and dashboard artifacts").

Emits a self-contained markdown dashboard + machine-readable JSON; a PNG
frontier plot is produced when matplotlib is importable (optional).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional, Sequence

from repro.core.simulator import SimResult
from repro.core.tracker import RunSummary


def _spark(values: Sequence[float], width: int = 48) -> str:
    if not values:
        return ""
    blocks = "▁▂▃▄▅▆▇█"
    lo, hi = min(values), max(values)
    rng = (hi - lo) or 1.0
    step = max(1, len(values) // width)
    vs = [values[i] for i in range(0, len(values), step)]
    return "".join(blocks[min(7, int(7 * (v - lo) / rng))] for v in vs)


def render_run_dashboard(summary: RunSummary, out_dir: str,
                         power_series: Optional[Sequence[float]] = None) -> str:
    os.makedirs(out_dir, exist_ok=True)
    lines = [
        f"# CARINA run dashboard — {summary.name}",
        "",
        f"| metric | value |",
        f"|---|---|",
        f"| tracked units | {summary.units} |",
        f"| runtime | {summary.runtime_h:.2f} h |",
        f"| energy load | {summary.energy_kwh:.3f} kWh |",
        f"| carbon burden | {summary.co2_kg:.3f} kg CO2e |",
        "",
        "## By phase",
        "",
        "| phase | units | runtime (h) | energy (kWh) | CO2e (kg) |",
        "|---|---|---|---|---|",
    ]
    for ph, d in sorted(summary.by_phase.items()):
        lines.append(f"| {ph} | {int(d['units'])} | {d['runtime_s']/3600:.2f} "
                     f"| {d['energy_kwh']:.3f} | {d['co2_kg']:.3f} |")
    if power_series:
        lines += ["", "## Power trace", "", "```", _spark(power_series), "```"]
    md = "\n".join(lines) + "\n"
    with open(os.path.join(out_dir, "dashboard.md"), "w") as f:
        f.write(md)
    with open(os.path.join(out_dir, "dashboard.json"), "w") as f:
        json.dump(dataclasses.asdict(summary), f, indent=2, sort_keys=True)
    return md


def _co2_cell(r: SimResult) -> str:
    """CO2 column cell: point value, or mean ±std with the q05–q95
    spread when the row carries ensemble stats."""
    s = r.co2_ensemble
    if s is None:
        return f"{r.co2_kg:.2f}"
    return (f"{s.mean:.2f} ±{s.std:.2f} "
            f"[{s.q05:.2f}…{s.q95:.2f}]")


def render_frontier_dashboard(results: List[SimResult], out_dir: str,
                              title: str = "policy frontier",
                              site_rollups=None) -> str:
    """Markdown + JSON (+ optional PNG) frontier table.

    Rows with `EnsembleStats` (carbon-ensemble sweeps) render the CO2
    column as mean ±std with the q05–q95 spread, and the PNG gains a
    CO2 whisker panel.  `site_rollups` is an optional list of
    `(label, SiteRollup)` pairs from fleet results — each gets a
    site-totals row (makespan, summed energy/CO2, peak site draw)
    appended under the per-campaign rows.
    """
    os.makedirs(out_dir, exist_ok=True)
    has_ens = any(r.co2_ensemble is not None for r in results)
    co2_head = "CO2e (kg, mean ±std [q05…q95])" if has_ens else "CO2e (kg)"
    lines = [
        f"# CARINA {title}",
        "",
        f"| policy | runtime (h) | energy (kWh) | {co2_head} "
        "| Δruntime | Δenergy |",
        "|---|---|---|---|---|---|",
    ]
    for r in results:
        lines.append(
            f"| {r.policy} | {r.runtime_h:.2f} | {r.energy_kwh:.2f} "
            f"| {_co2_cell(r)} | {r.runtime_delta_pct:+.2f}% "
            f"| {r.energy_delta_pct:+.2f}% |")
    if site_rollups:
        lines += [
            "",
            "## Site rollup",
            "",
            "| fleet case | campaigns | makespan (h) | energy (kWh) "
            f"| {co2_head} | peak draw (kW) |",
            "|---|---|---|---|---|---|",
        ]
        for label, s in site_rollups:
            s_ens = getattr(s, "co2_ensemble", None)
            co2 = (f"{s_ens.mean:.2f} ±{s_ens.std:.2f} "
                   f"[{s_ens.q05:.2f}…{s_ens.q95:.2f}]"
                   if s_ens is not None else f"{s.co2_kg:.2f}")
            peak = f"{s.peak_kw:.3f}" if s.peak_kw is not None else "—"
            lines.append(
                f"| {label} | {s.n_campaigns} | {s.runtime_h:.2f} "
                f"| {s.energy_kwh:.2f} | {co2} | {peak} |")
    md = "\n".join(lines) + "\n"
    with open(os.path.join(out_dir, "frontier.md"), "w") as f:
        f.write(md)
    payload = [dataclasses.asdict(dataclasses.replace(r, summary=None))
               for r in results]
    if site_rollups:
        payload = {"rows": payload,
                   "site_rollups": [dict(dataclasses.asdict(s), label=label)
                                    for label, s in site_rollups]}
    with open(os.path.join(out_dir, "frontier.json"), "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    try:  # optional plot
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
        if has_ens:
            fig, (ax, axc) = plt.subplots(
                1, 2, figsize=(10, 4),
                gridspec_kw={"width_ratios": [3, 2]})
        else:
            fig, ax = plt.subplots(figsize=(6, 4))
            axc = None
        for r in results:
            ax.scatter(r.runtime_delta_pct, -r.energy_delta_pct, s=40)
            ax.annotate(r.policy.replace("peak_aware_", "pa_"),
                        (r.runtime_delta_pct, -r.energy_delta_pct), fontsize=7)
        ax.set_xlabel("runtime penalty (%)")
        ax.set_ylabel("energy savings (%)")
        ax.grid(alpha=0.3)
        ax.set_title(title)
        if axc is not None:
            # CO2 whiskers: mean ±std box via errorbar, q05–q95 span as
            # thin whiskers, one row per policy
            rows = [r for r in results if r.co2_ensemble is not None]
            ys = range(len(rows))
            for y, r in zip(ys, rows):
                s = r.co2_ensemble
                axc.plot([s.q05, s.q95], [y, y], color="0.6", lw=1)
                axc.errorbar([s.mean], [y], xerr=[[s.std], [s.std]],
                             fmt="o", ms=4, capsize=3)
            axc.set_yticks(list(ys))
            axc.set_yticklabels([r.policy.replace("peak_aware_", "pa_")
                                 for r in rows], fontsize=7)
            axc.set_xlabel("CO2e (kg): mean ±std, q05–q95")
            axc.grid(alpha=0.3, axis="x")
        fig.tight_layout()
        fig.savefig(os.path.join(out_dir, "frontier.png"), dpi=120)
        plt.close(fig)
    except Exception:
        pass
    return md
