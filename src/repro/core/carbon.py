"""Grid carbon model: local emission factor + optional 24h intensity curve.

The paper translates energy to CO2e with a single local grid factor
(Detroit-area DTE).  The factor is not stated numerically but both case
studies imply it:  21.8 kg / 48.67 kWh = 33.2 kg / 74.16 kWh = 0.448 kg/kWh.

CARINA's conclusions call for "time-varying regional carbon-intensity
feeds" as future work; we implement that extension behind the same API
(hourly curve, disabled by default so the paper-faithful path is the
default).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

# kg CO2e per kWh, implied by the paper's OEM case studies (DTE, Detroit)
DTE_FACTOR = 0.448


@dataclasses.dataclass(frozen=True)
class GridCarbonModel:
    factor_kg_per_kwh: float = DTE_FACTOR
    # optional hourly multiplier (len 24, mean ~1.0); None = flat (paper mode)
    hourly_curve: Optional[Sequence[float]] = None
    # provenance of the emission factor (grid zone + data source), stamped
    # into RunTracker logs so calibration runs are self-describing; None
    # keeps the paper-faithful anonymous-factor default
    zone: Optional[str] = None
    source: Optional[str] = None

    def factor_at(self, hour_of_day: float) -> float:
        if self.hourly_curve is None:
            return self.factor_kg_per_kwh
        h = math.floor(hour_of_day) % 24   # floor: int() truncates negatives
        return self.factor_kg_per_kwh * self.hourly_curve[h]

    def co2_kg(self, kwh: float, hour_of_day: Optional[float] = None) -> float:
        if hour_of_day is None or self.hourly_curve is None:
            return kwh * self.factor_kg_per_kwh
        return kwh * self.factor_at(hour_of_day)


# A representative Midwest diurnal carbon-intensity shape (gas peakers on the
# evening ramp; baseload overnight).  Used only when explicitly enabled.
MIDWEST_HOURLY = (
    0.92, 0.90, 0.89, 0.88, 0.88, 0.90, 0.95, 1.00,
    1.03, 1.04, 1.05, 1.06, 1.07, 1.08, 1.10, 1.12,
    1.14, 1.15, 1.13, 1.10, 1.05, 1.00, 0.96, 0.94,
)
