"""Mesh-agnostic checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, step, meta
            arrays.npz          one entry per leaf (keypath-encoded names)
         <dir>/LATEST           atomic pointer file

Properties needed at 1000-node scale and implemented here at container scale:
  * atomic publication: write to step_N.tmp/, fsync, rename, then update
    LATEST — a reader never sees a torn checkpoint (crash-mid-save safe);
  * mesh-agnostic restore: arrays are saved as full logical arrays and
    re-placed with jax.device_put under the *restore-time* sharding — the
    elastic path (fail from 512 chips, resume on 256) is the same code;
  * keep-K retention + async save thread (training never blocks on I/O);
  * every record carries the CARINA run metadata so energy accounting
    survives restarts (the paper's resume/merge/verify logic, §2).

On a real multi-host pod, `np.asarray(leaf)` becomes a
per-shard gather via jax.experimental.multihost_utils; the manifest/commit
protocol is unchanged (process 0 commits).  Documented in DESIGN.md.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(directory: str, step: int, tree, meta: Optional[dict] = None,
                    keep: int = 3) -> str:
    """Blocking save. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(tree)
    arrays = {}
    manifest_entries = {}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if logical_dtype == "bfloat16":      # npz cannot round-trip ml_dtypes
            arr = arr.view(np.uint16)
        # npz keys cannot contain '/': encode
        enc = key.replace("/", "|")
        arrays[enc] = arr
        manifest_entries[key] = {"shape": list(arr.shape), "dtype": logical_dtype}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "meta": meta or {}, "entries": manifest_entries,
                "treedef": _treedef_repr(tree), "time": time.time()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST pointer
    ptr_tmp = os.path.join(directory, "LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    _retain(directory, keep)
    return final


def _treedef_repr(tree) -> str:
    return str(jax.tree.structure(tree))


def _retain(directory: str, keep: int):
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(directory, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(directory: str, like_tree, *, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, dict]:
    """Restore into the structure of `like_tree` (abstract or concrete).
    `shardings`: optional matching tree of NamedSharding for elastic
    re-placement on the current mesh.  Returns (tree, meta)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))

    import ml_dtypes
    entries = manifest.get("entries", {})
    flat_like = _flatten_with_paths(like_tree)
    leaves = []
    for key, like_leaf in flat_like:
        enc = key.replace("/", "|")
        if enc not in npz:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = npz[enc]
        saved_dtype = entries.get(key, {}).get("dtype", str(arr.dtype))
        if saved_dtype == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        want_dtype = like_leaf.dtype if hasattr(like_leaf, "dtype") else arr.dtype
        if str(want_dtype) == "bfloat16":
            arr = arr.astype(np.float32).astype(ml_dtypes.bfloat16) \
                if str(arr.dtype) != "bfloat16" else arr
        else:
            arr = arr.astype(want_dtype)
        leaves.append(arr)
    treedef = jax.tree.structure(like_tree)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, manifest.get("meta", {})


class AsyncCheckpointer:
    """Fire-and-forget background saves (single writer thread, queue depth 1:
    if a save is pending, the newest state wins)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: Optional[Tuple[int, Any, dict]] = None
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None
        self.errors: List[str] = []

    def submit(self, step: int, tree, meta: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        with self._lock:
            self._pending = (step, host_tree, meta or {})
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._drain, daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                if self._pending is None:
                    return
                step, tree, meta = self._pending
                self._pending = None
            try:
                save_checkpoint(self.directory, step, tree, meta, self.keep)
                self.last_saved = step
            except Exception as e:  # pragma: no cover
                self.errors.append(f"step {step}: {e}")

    def wait(self, timeout: float = 60.0):
        t = self._thread
        if t is not None:
            t.join(timeout)
