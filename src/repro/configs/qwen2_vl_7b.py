"""qwen2-vl-7b [vlm] — LM backbone with M-RoPE; vision frontend stubbed
(input_specs supplies precomputed patch embeddings). [arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    mlp_kind="swiglu",
    qkv_bias=True,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),  # temporal / height / width rotary sections
    rope_theta=1000000.0,
    n_vision_tokens=64,           # stub frontend: 64 patch embeddings replace leading tokens
    tie_embeddings=False,
)

SMOKE = smoke_variant(FULL, num_kv_heads=2)
CONFIG = FULL
