"""Config system for the repro framework.

Every assigned architecture is expressed as a `ModelConfig` (frozen dataclass).
Each arch module exposes:
    FULL    -- the exact published configuration (assignment block)
    SMOKE   -- a reduced same-family configuration for CPU tests
    CONFIG = FULL (registry entry)

Shapes (the four assigned LM input-shape cells) live in `SHAPES`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds used by models/transformer.py per-layer patterns.
ATTN = "attn"          # softmax attention (GQA/MQA; window>0 => local)
MLA = "mla"            # DeepSeek multi-head latent attention
MAMBA = "mamba"        # Mamba-1 selective SSM
RGLRU = "rglru"        # Griffin RG-LRU recurrent block
LOCAL_ATTN = "local"   # local (windowed) attention


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0              # routed experts
    num_shared_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0              # per-expert hidden dim
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    # layers that use MoE FFN: "all" | "all_but_first" (DeepSeek/Moonlight style)
    layer_mode: str = "all_but_first"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0              # 0 = full-rank q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                  # 0 => ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0                # 0 => d_model
    d_conv: int = 4
    block_width_multiplier: float = 1.0
    local_window: int = 2048          # window of the interleaved local-attn layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 => d_model // num_heads
    # --- attention details
    attention_kind: str = ATTN         # attn|mla|none
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_kind: str = "rope"            # rope|mrope|none|sinusoid
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # qwen2-vl temporal/h/w
    attn_logit_softcap: float = 0.0
    # --- per-layer block pattern, cycled over layers (temporal-mixing kind)
    block_pattern: Tuple[str, ...] = (ATTN,)
    # --- mlp
    mlp_kind: str = "swiglu"           # swiglu|gelu
    # --- sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # --- encoder-decoder (whisper)
    encdec: bool = False
    enc_layers: int = 0
    dec_layers: int = 0
    cross_kv_len: int = 1500           # stub encoder output length for decode cells
    dec_train_len: int = 512           # decoder text length for train/prefill cells
    # --- vlm
    n_vision_tokens: int = 0           # leading placeholder tokens fed by the stub frontend
    # --- embeddings / misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # --- runtime knobs (not architecture)
    remat: str = "none"                # none|dots|full
    use_scan: bool = True
    kernels: str = "auto"              # auto|xla|pallas  (auto: pallas on TPU only)
    blocked_xent: bool = False         # vocab-blocked CE (memory-term optimization)
    vocab_block: int = 8192
    # --- §Perf hillclimb knobs (see EXPERIMENTS.md §Perf)
    pad_heads_to_tp: bool = False      # head-padded TP attention (uneven heads)
    moe_expert_fsdp: bool = True       # False: experts sharded EP-only (no FSDP AG)
    decode_cache_seq_shard: bool = False  # shard decode KV cache seq over "model"
    decode_2d_tp: bool = False         # decode: 2D weight TP, batch replicated,
                                       # cache seq over (model, data) — activation
                                       # psums replace FSDP weight gathers

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def dt_rank(self) -> int:
        assert self.ssm is not None
        return self.ssm.dt_rank or -(-self.d_model // 16)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Temporal-mixing kind for each layer (pattern cycled)."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def layer_is_moe(self, i: int) -> bool:
        if self.moe is None:
            return False
        if self.moe.layer_mode == "all":
            return True
        return i > 0  # all_but_first

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (matches models/model.py init exactly)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        v = self.vocab_size

        def attn_params() -> int:
            n = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
            if self.qkv_bias:
                n += (nq + 2 * nkv) * hd
            return n

        def mla_params() -> int:
            assert self.mla is not None
            m = self.mla
            qdim = nq * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            n = d * qdim if m.q_lora_rank == 0 else d * m.q_lora_rank + m.q_lora_rank * qdim + m.q_lora_rank
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)          # down-proj (+rope k)
            n += m.kv_lora_rank                                     # kv layernorm
            n += m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)  # up-proj
            n += nq * m.v_head_dim * d                              # o proj
            return n

        def mamba_params() -> int:
            assert self.ssm is not None
            s = self.ssm
            di = s.expand * d
            n = d * 2 * di                      # in_proj
            n += di * s.d_conv + di             # conv1d + bias
            n += di * (self.dt_rank + 2 * s.d_state)  # x_proj
            n += self.dt_rank * di + di         # dt_proj
            n += di * s.d_state + di            # A_log, D
            n += di * d                         # out_proj
            return n

        def rglru_params() -> int:
            assert self.rglru is not None
            g = self.rglru
            w = g.lru_width or d
            n = 2 * d * w                       # x/gate branch in-proj
            n += w * g.d_conv + w               # conv1d
            n += 2 * w + 2 * w                  # RG-LRU input & recurrence gates (diag-ish per-channel) => use per-channel params
            n += w                              # a param
            n += w * d                          # out proj
            return n

        def dense_mlp(dff: int) -> int:
            if self.mlp_kind == "swiglu":
                return 3 * d * dff
            return 2 * d * dff + dff + d       # gelu w/ biases

        def moe_mlp() -> int:
            assert self.moe is not None
            m = self.moe
            n = d * m.num_experts               # router
            n += m.num_experts * 3 * d * m.d_ff_expert
            n += m.num_shared_experts * 3 * d * m.d_ff_expert
            return n

        total = v * d                            # embedding
        if not self.tie_embeddings:
            total += v * d
        total += d                               # final norm

        kinds = self.layer_kinds()
        n_layers = self.enc_layers + self.dec_layers if self.encdec else self.num_layers
        for i in range(self.num_layers):
            k = kinds[i]
            total += d                           # pre-mixer norm
            if k == ATTN or k == LOCAL_ATTN:
                total += attn_params()
            elif k == MLA:
                total += mla_params()
            elif k == MAMBA:
                total += mamba_params()
            elif k == RGLRU:
                total += rglru_params()
            # mlp (mamba blocks in falcon-mamba have no separate MLP)
            if k != MAMBA:
                total += d                       # pre-mlp norm
                total += moe_mlp() if self.layer_is_moe(i) else dense_mlp(self.d_ff)
        if self.encdec:
            # decoder layers: self-attn + cross-attn + mlp
            for _ in range(self.dec_layers):
                total += 2 * d + attn_params()          # self
                total += d + attn_params()              # cross (same shape)
                total += dense_mlp(self.d_ff)
            total += d                                  # decoder final norm
        _ = n_layers
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        inactive_per_layer = (m.num_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        n_moe_layers = sum(1 for i in range(self.num_layers) if self.layer_is_moe(i))
        return self.param_count() - n_moe_layers * inactive_per_layer


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode" | "long_decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "long_decode"),
}

# archs whose temporal mixing is sub-quadratic end-to-end (may run long_500k)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) dry-run cell is runnable; returns (ok, reason)."""
    if shape.kind == "long_decode" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "pure full-attention arch: 512k dense-KV decode not representable (DESIGN.md §4)"
    return True, ""


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config to CPU-test scale, preserving family structure."""
    kw = dict(
        num_layers=min(cfg.num_layers, len(cfg.block_pattern) + 1),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        use_scan=True,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2, d_ff_expert=32)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16,
                              qk_rope_head_dim=8, v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=8, d_conv=4, expand=2)
    if cfg.rglru is not None:
        kw["rglru"] = dataclasses.replace(cfg.rglru, lru_width=64, local_window=32)
    if cfg.encdec:
        kw["enc_layers"] = 2
        kw["dec_layers"] = 2
        kw["num_layers"] = 2
        kw["cross_kv_len"] = 24
        kw["dec_train_len"] = 16
    if cfg.n_vision_tokens:
        kw["n_vision_tokens"] = 8
    if cfg.rope_kind == "mrope":
        # sections must sum to head_dim//2 (reduced head_dim = 16)
        kw["mrope_sections"] = (2, 3, 3)
    kw["name"] = cfg.name + "-smoke"
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)


# populated by configs/__init__.py
REGISTRY: dict = {}


def flops_per_token_train(cfg: ModelConfig) -> float:
    """6 * N_active (the standard model-FLOPs estimate; attention extra ignored)."""
    return 6.0 * cfg.active_param_count()


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for a cell: 6*N*D for train; 2*N*D for inference shapes."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    tokens = shape.global_batch
    return 2.0 * n_act * tokens


def nice_int(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}P"
