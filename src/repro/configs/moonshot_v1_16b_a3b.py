"""moonshot-v1-16b-a3b [moe] — Moonlight (DeepSeek-V3-family): 64 routed + 2 shared, top-6.
[hf:moonshotai/Moonlight-16B-A3B]"""
from repro.configs.base import ModelConfig, MoEConfig, smoke_variant

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=11264,                 # dense FFN of layer 0 (DeepSeek-family first dense layer)
    vocab_size=163840,
    mlp_kind="swiglu",
    moe=MoEConfig(
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        d_ff_expert=1408,
        layer_mode="all_but_first",
    ),
    tie_embeddings=False,
)

SMOKE = smoke_variant(FULL, num_kv_heads=4)
CONFIG = FULL
