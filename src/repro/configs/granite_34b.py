"""granite-34b [dense] — llama-arch code model, MQA (kv=1). [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,           # MQA
    d_ff=24576,
    vocab_size=49152,
    mlp_kind="swiglu",
    tie_embeddings=True,
    qkv_bias=False,
)

SMOKE = smoke_variant(FULL, num_kv_heads=1)
CONFIG = FULL
