"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512; 64 routed + 2 shared, top-6.
[arXiv:2405.04434; hf]

Assignment note: the one-line spec says "MoE 64e top-6" while the descriptor
mentions "160 routed"; published V2-Lite is 64 routed + 2 shared (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig, smoke_variant

FULL = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,            # unused under MLA (latent KV); kept per assignment line
    d_ff=10944,                 # dense FFN of layer 0
    vocab_size=102400,
    attention_kind="mla",
    block_pattern=("mla",),
    mlp_kind="swiglu",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,          # V2-Lite: no q-lora
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        d_ff_expert=1408,
        layer_mode="all_but_first",
    ),
    tie_embeddings=False,
)

SMOKE = smoke_variant(FULL, num_kv_heads=4)
CONFIG = FULL
