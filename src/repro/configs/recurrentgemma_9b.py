"""recurrentgemma-9b [hybrid] — Griffin: RG-LRU + local attn 1:2, MQA kv=1, window 2048.
[arXiv:2402.19427]"""
from repro.configs.base import ModelConfig, RGLRUConfig, smoke_variant

FULL = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,             # MQA on the local-attention layers
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),   # 2 recurrent : 1 local-attn
    rglru=RGLRUConfig(lru_width=4096, d_conv=4, local_window=2048),
    mlp_kind="swiglu",
    attn_logit_softcap=0.0,
    tie_embeddings=True,
)

SMOKE = smoke_variant(FULL, num_kv_heads=1)
CONFIG = FULL
