"""llama3-405b [dense] — GQA kv=8, 128k vocab. [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    mlp_kind="swiglu",
    rope_theta=500000.0,
    tie_embeddings=False,
)

SMOKE = smoke_variant(FULL, num_kv_heads=2)
CONFIG = FULL
