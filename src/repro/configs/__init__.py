"""Architecture registry: the 10 assigned architectures + the CARINA OEM workload."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, MLAConfig, SSMConfig, RGLRUConfig,
    ShapeConfig, SHAPES, REGISTRY, cell_is_applicable, smoke_variant,
    model_flops, flops_per_token_train,
)

_ARCH_MODULES = {
    "granite-34b": "granite_34b",
    "qwen2.5-14b": "qwen2_5_14b",
    "llama3-405b": "llama3_405b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-small": "whisper_small",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.SMOKE if smoke else mod.FULL


def all_configs(smoke: bool = False):
    return {n: get_config(n, smoke=smoke) for n in ARCH_NAMES}


for _n in ARCH_NAMES:
    REGISTRY[_n] = _ARCH_MODULES[_n]
