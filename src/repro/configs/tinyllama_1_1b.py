"""tinyllama-1.1b [dense] — llama2-arch small, GQA kv=4. [arXiv:2401.02385; hf]"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    mlp_kind="swiglu",
    tie_embeddings=False,
)

SMOKE = smoke_variant(FULL, num_kv_heads=2)
CONFIG = FULL
