"""whisper-small [audio] — enc-dec transformer backbone; conv frontend stubbed
(input_specs supplies precomputed frame embeddings). [arXiv:2212.04356]

Convention (DESIGN.md §4): `num_layers` == encoder layers; seq_len in a shape
cell = encoder frame length (train/prefill) or decoder KV length (decode).
"""
from repro.configs.base import ModelConfig, smoke_variant

FULL = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,            # full MHA
    d_ff=3072,
    vocab_size=51865,
    encdec=True,
    enc_layers=12,
    dec_layers=12,
    cross_kv_len=1500,
    dec_train_len=512,
    mlp_kind="gelu",
    rope_kind="sinusoid",
    tie_embeddings=True,
)

SMOKE = smoke_variant(FULL, num_kv_heads=4)
CONFIG = FULL
