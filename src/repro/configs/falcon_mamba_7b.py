"""falcon-mamba-7b [ssm] — pure Mamba-1, attention-free. [arXiv:2410.05355]"""
from repro.configs.base import ModelConfig, SSMConfig, smoke_variant

FULL = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,                # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,                     # mamba blocks have no separate MLP
    vocab_size=65024,
    attention_kind="none",
    rope_kind="none",
    block_pattern=("mamba",),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=False,
)

SMOKE = smoke_variant(FULL)
CONFIG = FULL
