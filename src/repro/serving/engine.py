"""Batched serving engine: continuous batching over fixed decode slots with
per-request CARINA accounting.

Design (vLLM-lite, TPU-idiomatic: fixed shapes, no paging):
  * `slots` concurrent sequences share one (B, S_max) cache pytree;
  * admission runs a single-sequence prefill and writes its cache entries
    into the slot (per-leaf dynamic-update-slice);
  * every engine tick decodes ALL active slots in one batched decode_step
    (per-slot position indices — the vector-index decode path);
  * finished slots are freed and refilled from the queue;
  * each engine tick is a CARINA tracked unit: runtime + estimated energy
    (roofline mode when a StepCost is available) + carbon, accounted by a
    `ServingSession` (core/serve.py) in live mode — the session's carbon
    gate also throttles admissions, with queue-pressure override.

Supported families: attention (full), MLA, mamba, rglru-hybrid — i.e. every
assigned decoder arch; window-attention ring caches are filled from the
tail of the prefill KV (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, LOCAL_ATTN
from repro.models.model import Model
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S_prompt,) int32
    max_new: int = 16
    # filled by the engine
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_finish: float = 0.0


def _write_slot(cache, prefill_cache, slot: int, cfg: ModelConfig,
                prompt_len: int):
    """Merge a single-sequence prefill cache into batch cache at `slot`."""
    plan = T.layer_plan(cfg)
    new_cache = []
    for seg, seg_c, seg_p in zip(plan, cache, prefill_cache):
        seg_out = []
        for (kind, _), c, pc in zip(seg.pattern, seg_c, seg_p):
            upd = dict(c)
            if "k" in c:                       # attention KV
                s_cache = c["k"].shape[2]      # (L, B, S, kv, hd)
                for key in ("k", "v"):
                    src = pc[key]              # (L, 1, S_p, kv, hd)
                    if kind == LOCAL_ATTN or src.shape[2] > s_cache:
                        # ring/window: keep the last s_cache positions at
                        # slot j = pos % s_cache
                        take = min(s_cache, src.shape[2])
                        tail = src[:, :, src.shape[2] - take:]
                        pos = jnp.arange(src.shape[2] - take, src.shape[2])
                        dest = pos % s_cache
                        upd[key] = c[key].at[:, slot].set(
                            jnp.zeros_like(c[key][:, slot]).at[:, dest].set(
                                tail[:, 0]))
                    else:
                        upd[key] = c[key].at[:, slot, :src.shape[2]].set(src[:, 0])
            if "c_kv" in c:                    # MLA latent cache
                for key in ("c_kv", "k_rope"):
                    src = pc[key]
                    upd[key] = c[key].at[:, slot, :src.shape[2]].set(src[:, 0])
            if "ssm" in c:                     # mamba states
                upd["ssm"] = c["ssm"].at[:, slot].set(pc["ssm"][:, 0])
                upd["conv"] = c["conv"].at[:, slot].set(pc["conv"][:, 0])
            if "h" in c:                       # rglru state
                upd["h"] = c["h"].at[:, slot].set(pc["h"][:, 0])
                upd["conv"] = c["conv"].at[:, slot].set(pc["conv"][:, 0])
            seg_out.append(upd)
        new_cache.append(seg_out)
    return new_cache


class ServingEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 s_max: int = 256, session=None, eos_id: int = -1):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.slots = slots
        self.s_max = s_max
        # a core.serve.ServingSession in live mode: carbon-gated
        # admission + per-tick energy/CO2 accounting
        self.session = session
        self.eos_id = eos_id
        self.cache = model.cache_zeros(slots, s_max)
        self.lengths = np.zeros((slots,), np.int32)      # current position
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self._next_rid = 0
        self.completed: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 16) -> int:
        r = Request(self._next_rid, np.asarray(prompt, np.int32), max_new,
                    t_submit=time.monotonic())
        self._next_rid += 1
        self.queue.append(r)
        return r.rid

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            if (self.session is not None
                    and not self.session.gate_open(len(self.queue))):
                break                      # dirty hour: let the queue wait
            r = self.queue.pop(0)
            batch = {"tokens": jnp.asarray(r.prompt[None, :])}
            logits, pc = self._prefill(self.params, batch)
            self.cache = _write_slot(self.cache, pc, slot, self.cfg,
                                     len(r.prompt))
            first = int(jnp.argmax(logits[0]))
            r.generated.append(first)
            self.active[slot] = r
            self.lengths[slot] = len(r.prompt)

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """One engine iteration: admit + one batched decode step.
        Returns number of active slots."""
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        t0 = time.monotonic()
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in act:
            tokens[s, 0] = self.active[s].generated[-1]
        idx = jnp.asarray(self.lengths)
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens), idx)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s in act:
            r = self.active[s]
            r.generated.append(int(nxt[s]))
            self.lengths[s] += 1
            if (len(r.generated) >= r.max_new
                    or int(nxt[s]) == self.eos_id
                    or self.lengths[s] >= self.s_max - 1):
                r.done = True
                r.t_finish = time.monotonic()
                self.completed.append(r)
                self.active[s] = None
                self.lengths[s] = 0
        if self.session is not None:
            self.session.record_tick(time.monotonic() - t0,
                                     active=len(act), steps=1)
        return len(act)

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and all(a is None for a in self.active):
                break
            self.tick()
        return self.completed
