"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, S_frames, d) supplied by input_specs().
Norms are scale-only (RMS); positional encoding is sinusoidal (added).

Cache layout for decode: per decoder layer {self: {k,v}, cross: {k,v}} —
cross K/V are computed once (from encoder output) at prefill time.
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import ParamSpec, SpecTree
from repro.models.transformer import _stack_spec, _remat

F32 = jnp.float32


def _scan_layers(body, x, stacked, use_scan: bool, n: int):
    """lax.scan over stacked layer params, or a python loop when unrolled
    (cfg.use_scan=False — exact-costing depth pairs)."""
    if use_scan:
        x, ys = jax.lax.scan(body, x, stacked)
        return x, ys
    ys = []
    for i in range(n):
        pl = jax.tree.map(lambda t: t[i], stacked)
        x, y = body(x, pl)
        ys.append(y)
    ys = None if ys and ys[0] is None else (
        jax.tree.map(lambda *ts: jnp.stack(ts), *ys) if ys else None)
    return x, ys


# ---------------------------------------------------------------------------
def enc_block_spec(cfg: ModelConfig) -> SpecTree:
    return {"norm1": L.norm_spec(cfg.d_model), "attn": L.attn_spec(cfg),
            "norm2": L.norm_spec(cfg.d_model), "ffn": L.mlp_spec(cfg)}


def dec_block_spec(cfg: ModelConfig) -> SpecTree:
    d = cfg.d_model
    return {"norm1": L.norm_spec(d), "self_attn": L.attn_spec(cfg),
            "norm2": L.norm_spec(d), "cross_attn": L.attn_spec(cfg),
            "norm3": L.norm_spec(d), "ffn": L.mlp_spec(cfg)}


def encdec_spec(cfg: ModelConfig) -> SpecTree:
    d, v = cfg.d_model, cfg.vocab_size
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="normal"),
        "enc": _stack_spec(enc_block_spec(cfg), cfg.enc_layers),
        "enc_norm": L.norm_spec(d),
        "dec": _stack_spec(dec_block_spec(cfg), cfg.dec_layers),
        "dec_norm": L.norm_spec(d),
    }


# ---------------------------------------------------------------------------
def encode(frames: jax.Array, p: SpecTree, cfg: ModelConfig) -> jax.Array:
    """frames: (B, S, d) stub frame embeddings -> encoder states (B, S, d)."""
    x = frames + L.sinusoid_embedding(frames.shape[1], cfg.d_model).astype(frames.dtype)[None]

    def body(xc, pl):
        h = L.rms_norm(xc, pl["norm1"], cfg.norm_eps)
        o, _ = L.attn_block(h, pl["attn"], cfg, causal=False)
        xc = L.shard_batch(xc + o)
        h = L.rms_norm(xc, pl["norm2"], cfg.norm_eps)
        xc = L.shard_batch(xc + L.mlp_block(h, pl["ffn"], cfg))
        return xc, None

    x, _ = _scan_layers(_remat(body, cfg.remat), x, p["enc"], cfg.use_scan,
                        cfg.enc_layers)
    return L.rms_norm(x, p["enc_norm"], cfg.norm_eps)


def _dec_block(xc, pl, cfg, enc_kv, positions):
    h = L.rms_norm(xc, pl["norm1"], cfg.norm_eps)
    o, self_kv = L.attn_block(h, pl["self_attn"], cfg, causal=True, positions=positions)
    xc = L.shard_batch(xc + o)
    h = L.rms_norm(xc, pl["norm2"], cfg.norm_eps)
    o, _ = L.attn_block(h, pl["cross_attn"], cfg, cross_kv=enc_kv(pl))
    xc = xc + o
    h = L.rms_norm(xc, pl["norm3"], cfg.norm_eps)
    xc = L.shard_batch(xc + L.mlp_block(h, pl["ffn"], cfg))
    return xc, self_kv


def decode_train(tokens: jax.Array, enc_out: jax.Array, p: SpecTree,
                 cfg: ModelConfig) -> jax.Array:
    """Teacher-forced decoder pass -> logits (B, S_dec, V)."""
    x = L.shard_batch(p["embed"][tokens]
                      + L.sinusoid_embedding(tokens.shape[1], cfg.d_model
                                             ).astype(jnp.bfloat16)[None])

    def enc_kv(pl):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, pl["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, pl["cross_attn"]["wv"])
        return k, v

    def body(xc, pl):
        xc, _ = _dec_block(xc, pl, cfg, enc_kv, None)
        return xc, None

    x, _ = _scan_layers(_remat(body, cfg.remat), x, p["dec"], cfg.use_scan,
                        cfg.dec_layers)
    x = L.rms_norm(x, p["dec_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,vd->bsv", x, p["embed"])


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------
def encdec_cache_spec(cfg: ModelConfig, batch: int, s_max: int) -> SpecTree:
    hd = cfg.resolved_head_dim
    dt = jnp.bfloat16
    self_shp = (batch, s_max, cfg.num_kv_heads, hd)
    cross_shp = (batch, cfg.cross_kv_len, cfg.num_kv_heads, hd)
    ax = ("batch", None, "kv_heads", None)
    one = {
        "self_k": ParamSpec(self_shp, ax, init="zeros", dtype=dt),
        "self_v": ParamSpec(self_shp, ax, init="zeros", dtype=dt),
        "cross_k": ParamSpec(cross_shp, ax, init="zeros", dtype=dt),
        "cross_v": ParamSpec(cross_shp, ax, init="zeros", dtype=dt),
    }
    return _stack_spec(one, cfg.dec_layers)


def build_cross_cache(enc_out: jax.Array, p: SpecTree):
    """Precompute per-layer cross K/V from encoder output: (L, B, Skv, H, hd)."""
    def per_layer(pl):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, pl["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, pl["cross_attn"]["wv"])
        return k, v
    ks, vs = jax.vmap(per_layer, in_axes=(0,))(p["dec"])
    return ks, vs


def decode_step(token: jax.Array, cache: SpecTree, p: SpecTree, cfg: ModelConfig,
                index) -> Tuple[jax.Array, SpecTree]:
    """token: (B, 1) int32; index: scalar or (B,). Returns (logits (B,1,V), cache)."""
    b = token.shape[0]
    idx = L._norm_index(index, b)
    pos_emb = L.sinusoid_embedding(int(cache["self_k"].shape[2]), cfg.d_model)
    x = p["embed"][token] + pos_emb[idx][:, None, :].astype(jnp.bfloat16)

    def body(xc, xs):
        pl, c = xs
        h = L.rms_norm(xc, pl["norm1"], cfg.norm_eps)
        o, kc, vc = L.attn_decode(h, pl["self_attn"], cfg, c["self_k"], c["self_v"], index)
        xc = xc + o
        h = L.rms_norm(xc, pl["norm2"], cfg.norm_eps)
        o, _, _ = L.attn_decode(h, pl["cross_attn"], cfg, c["cross_k"], c["cross_v"],
                                index, cross=True)
        xc = xc + o
        h = L.rms_norm(xc, pl["norm3"], cfg.norm_eps)
        xc = xc + L.mlp_block(h, pl["ffn"], cfg)
        return xc, {"self_k": kc, "self_v": vc,
                    "cross_k": c["cross_k"], "cross_v": c["cross_v"]}

    if cfg.use_scan:
        x, new_cache = jax.lax.scan(body, x, (p["dec"], cache))
    else:
        outs = []
        for i in range(cfg.dec_layers):
            pl = jax.tree.map(lambda t: t[i], p["dec"])
            cl = jax.tree.map(lambda t: t[i], cache)
            x, nc = body(x, (pl, cl))
            outs.append(nc)
        new_cache = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
    x = L.rms_norm(x, p["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, p["embed"])
    return logits, new_cache
