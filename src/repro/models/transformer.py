"""Decoder-only LM assembly with per-layer block patterns.

A config's layers are grouped into *segments*: maximal runs of a repeating
(block-pattern x moe-flag) structure.  Each segment's parameters are stacked
with a leading `repeats` dim and applied with jax.lax.scan (small HLO even
for 126-layer models, which matters for 512-device AOT compiles).

Examples
  llama3-405b:         [(126, [(attn, dense)])]
  deepseek-v2-lite:    [(1, [(mla, dense)]), (26, [(mla, moe)])]
  recurrentgemma-9b:   [(12, [(rglru,·),(rglru,·),(local,·)]), (1, [(rglru,·),(rglru,·)])]
  falcon-mamba-7b:     [(64, [(mamba,·)])]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ATTN, MLA, MAMBA, RGLRU, LOCAL_ATTN)
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.param import ParamSpec, SpecTree, is_leaf

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Segment:
    repeats: int
    pattern: Tuple[Tuple[str, bool], ...]   # ((kind, is_moe), ...)


def layer_plan(cfg: ModelConfig) -> List[Segment]:
    per_layer = [(k, cfg.layer_is_moe(i)) for i, k in enumerate(cfg.layer_kinds())]
    plen = len(cfg.block_pattern)
    segs: List[Segment] = []
    i = 0
    n = len(per_layer)
    while i < n:
        # a pattern-aligned run starting at i
        pat = tuple(per_layer[i:i + plen])
        reps = 1
        j = i + len(pat)
        while j + len(pat) <= n and tuple(per_layer[j:j + len(pat)]) == pat:
            reps += 1
            j += len(pat)
        if len(pat) < plen:  # tail shorter than pattern
            segs.append(Segment(1, pat))
            i += len(pat)
            continue
        segs.append(Segment(reps, pat))
        i = j
    return segs


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
def _stack_spec(spec: SpecTree, n: int) -> SpecTree:
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, init=s.init,
                            scale=s.scale, dtype=s.dtype),
        spec, is_leaf=is_leaf)


def mixer_spec(cfg: ModelConfig, kind: str) -> SpecTree:
    if kind in (ATTN, LOCAL_ATTN):
        return L.attn_spec(cfg)
    if kind == MLA:
        return L.mla_spec(cfg)
    if kind == MAMBA:
        return SSM.mamba_spec(cfg)
    if kind == RGLRU:
        return SSM.rglru_spec(cfg)
    raise ValueError(kind)


def block_spec(cfg: ModelConfig, kind: str, is_moe: bool) -> SpecTree:
    d = cfg.d_model
    s: SpecTree = {"norm1": L.norm_spec(d), "mixer": mixer_spec(cfg, kind)}
    if kind != MAMBA:
        s["norm2"] = L.norm_spec(d)
        s["ffn"] = MOE.moe_spec(cfg) if is_moe else L.mlp_spec(cfg)
    return s


def segment_spec(cfg: ModelConfig, seg: Segment) -> SpecTree:
    per_pos = [block_spec(cfg, k, m) for (k, m) in seg.pattern]
    return {"blocks": [_stack_spec(s, seg.repeats) for s in per_pos]}


def lm_spec(cfg: ModelConfig) -> SpecTree:
    d, v = cfg.d_model, cfg.vocab_size
    s: SpecTree = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="normal"),
        "segments": [segment_spec(cfg, seg) for seg in layer_plan(cfg)],
        "final_norm": L.norm_spec(d),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((d, v), ("embed", "vocab"), init="scaled")
    return s


# ---------------------------------------------------------------------------
# Block application (full sequence: train / prefill)
# ---------------------------------------------------------------------------
def apply_block(x, p, cfg: ModelConfig, kind: str, is_moe: bool, *,
                causal: bool = True, positions=None, collect_cache: bool = False):
    """Returns (x, aux_loss, cache_entry_or_None)."""
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    cache = None
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.rglru.local_window if (kind == LOCAL_ATTN and cfg.rglru) else 0
        o, kv = L.attn_block(h, p["mixer"], cfg, causal=causal, window=window,
                             positions=positions)
        if collect_cache:
            cache = {"k": kv[0], "v": kv[1]}
    elif kind == MLA:
        o, ckv = L.mla_block(h, p["mixer"], cfg, causal=causal, positions=positions)
        if collect_cache:
            cache = {"c_kv": ckv[0], "k_rope": ckv[1]}
    elif kind == MAMBA:
        if collect_cache:
            o, (conv_s, ssm_s) = SSM.mamba_block(h, p["mixer"], cfg, return_state=True)
            cache = {"conv": conv_s, "ssm": ssm_s}
        else:
            o = SSM.mamba_block(h, p["mixer"], cfg)
    elif kind == RGLRU:
        if collect_cache:
            o, (conv_s, hh) = SSM.rglru_block(h, p["mixer"], cfg, return_state=True)
            cache = {"conv": conv_s, "h": hh}
        else:
            o = SSM.rglru_block(h, p["mixer"], cfg)
    else:
        raise ValueError(kind)
    x = L.shard_batch(x + o)
    aux = jnp.zeros((), F32)
    if kind != MAMBA:
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if is_moe:
            f, aux = MOE.moe_block(h2, p["ffn"], cfg)
        else:
            f = L.mlp_block(h2, p["ffn"], cfg)
        x = L.shard_batch(x + f)
    return x, aux, cache


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "full"


def apply_segments(x, params_segments, cfg: ModelConfig, *, causal=True,
                   positions=None, collect_cache=False):
    """Run all segments. Returns (x, total_aux, caches or None)."""
    plan = layer_plan(cfg)
    total_aux = jnp.zeros((), F32)
    caches: List[Any] = []
    for seg, seg_p in zip(plan, params_segments):
        def body(xc, p_slices, _seg=seg):
            aux = jnp.zeros((), F32)
            entries = []
            for pos_i, (kind, m) in enumerate(_seg.pattern):
                xc, a, ce = apply_block(xc, p_slices[pos_i], cfg, kind, m,
                                        causal=causal, positions=positions,
                                        collect_cache=collect_cache)
                aux = aux + a
                entries.append(ce)
            return xc, (aux, entries)

        body = _remat(body, cfg.remat)
        if cfg.use_scan:
            x, (auxs, entries) = jax.lax.scan(
                lambda c, p: body(c, p["blocks"]), x, seg_p)
            total_aux = total_aux + auxs.sum()
            caches.append(entries)          # each entry stacked (repeats, ...)
        else:
            seg_entries = None
            for r in range(seg.repeats):
                p_slices = jax.tree.map(lambda t: t[r], seg_p["blocks"])
                x, (a, entries) = body(x, p_slices)
                total_aux = total_aux + a
                if seg_entries is None:
                    seg_entries = [[e] for e in entries]
                else:
                    for lst, e in zip(seg_entries, entries):
                        lst.append(e)
            stacked = [None if es[0] is None else
                       jax.tree.map(lambda *ts: jnp.stack(ts), *es)
                       for es in (seg_entries or [])]
            caches.append(stacked)
    return x, total_aux, (caches if collect_cache else None)


# ---------------------------------------------------------------------------
# Decode-step application (single token, cache threading)
# ---------------------------------------------------------------------------
def apply_block_decode(x, p, cfg: ModelConfig, kind: str, is_moe: bool,
                       cache: dict, index):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.rglru.local_window if (kind == LOCAL_ATTN and cfg.rglru) else 0
        o, kc, vc = L.attn_decode(h, p["mixer"], cfg, cache["k"], cache["v"], index,
                                  window=window)
        cache = {"k": kc, "v": vc}
    elif kind == MLA:
        o, cc, krc = L.mla_decode(h, p["mixer"], cfg, cache["c_kv"], cache["k_rope"], index)
        cache = {"c_kv": cc, "k_rope": krc}
    elif kind == MAMBA:
        o, conv_s, ssm_s = SSM.mamba_decode(h, p["mixer"], cfg,
                                            cache["conv"], cache["ssm"])
        cache = {"conv": conv_s, "ssm": ssm_s}
    elif kind == RGLRU:
        o, conv_s, hh = SSM.rglru_decode(h, p["mixer"], cfg, cache["conv"], cache["h"])
        cache = {"conv": conv_s, "h": hh}
    else:
        raise ValueError(kind)
    x = L.shard_batch(x + o)
    if kind != MAMBA:
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if is_moe:
            f, _ = MOE.moe_block(h2, p["ffn"], cfg)
        else:
            f = L.mlp_block(h2, p["ffn"], cfg)
        x = L.shard_batch(x + f)
    return x, cache


def apply_segments_decode(x, params_segments, caches, cfg: ModelConfig, index):
    plan = layer_plan(cfg)
    new_caches = []
    for seg, seg_p, seg_c in zip(plan, params_segments, caches):
        def body(xc, slices, _seg=seg):
            p_slices, c_slices = slices
            new_entries = []
            for pos_i, (kind, m) in enumerate(_seg.pattern):
                xc, nc = apply_block_decode(xc, p_slices[pos_i], cfg, kind, m,
                                            c_slices[pos_i], index)
                new_entries.append(nc)
            return xc, new_entries

        if cfg.use_scan:
            x, new_seg_c = jax.lax.scan(
                lambda c, xs: body(c, (xs[0]["blocks"], xs[1])), x, (seg_p, seg_c))
        else:
            outs = []
            for r in range(seg.repeats):
                p_slices = jax.tree.map(lambda t: t[r], seg_p["blocks"])
                c_slices = jax.tree.map(lambda t: t[r], seg_c)
                x, nc = body(x, (p_slices, c_slices))
                outs.append(nc)
            new_seg_c = jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
        new_caches.append(new_seg_c)
    return x, new_caches


# ---------------------------------------------------------------------------
# Cache specs (ShapeDtypeStruct trees for the dry-run; zeros for real use)
# ---------------------------------------------------------------------------
def block_cache_spec(cfg: ModelConfig, kind: str, batch: int, s_max: int) -> SpecTree:
    hd = cfg.resolved_head_dim
    dt = jnp.bfloat16
    # §Perf knob: shard the decode KV cache's sequence dim over "model"
    # (sequence-parallel decode attention; GSPMD inserts the tiny distributed
    # softmax collectives).  Fixes cache replication when kv_heads doesn't
    # divide the model axis (e.g. 48GB/chip -> 3GB/chip for qwen2.5 decode).
    seq_ax = "kv_seq" if cfg.decode_cache_seq_shard else None
    if kind == ATTN:
        shp = (batch, s_max, cfg.num_kv_heads, hd)
        ax = ("batch", seq_ax, "kv_heads" if not cfg.decode_cache_seq_shard
              else None, None)
        return {"k": ParamSpec(shp, ax, init="zeros", dtype=dt),
                "v": ParamSpec(shp, ax, init="zeros", dtype=dt)}
    if kind == LOCAL_ATTN:
        w = min(cfg.rglru.local_window, s_max)
        shp = (batch, w, cfg.num_kv_heads, hd)
        ax = ("batch", None, "kv_heads", None)
        return {"k": ParamSpec(shp, ax, init="zeros", dtype=dt),
                "v": ParamSpec(shp, ax, init="zeros", dtype=dt)}
    if kind == MLA:
        m = cfg.mla
        return {"c_kv": ParamSpec((batch, s_max, m.kv_lora_rank),
                                  ("batch", None, None), init="zeros", dtype=dt),
                "k_rope": ParamSpec((batch, s_max, m.qk_rope_head_dim),
                                    ("batch", None, None), init="zeros", dtype=dt)}
    if kind == MAMBA:
        s = cfg.ssm
        di = s.expand * cfg.d_model
        return {"conv": ParamSpec((batch, s.d_conv - 1, di),
                                  ("batch", None, "ssm_inner"), init="zeros", dtype=dt),
                "ssm": ParamSpec((batch, di, s.d_state),
                                 ("batch", "ssm_inner", None), init="zeros",
                                 dtype=jnp.float32)}
    if kind == RGLRU:
        w = cfg.rglru.lru_width or cfg.d_model
        return {"conv": ParamSpec((batch, cfg.rglru.d_conv - 1, w),
                                  ("batch", None, "rnn"), init="zeros", dtype=dt),
                "h": ParamSpec((batch, w), ("batch", "rnn"), init="zeros",
                               dtype=jnp.float32)}
    raise ValueError(kind)


def cache_spec(cfg: ModelConfig, batch: int, s_max: int) -> List[Any]:
    segs = []
    for seg in layer_plan(cfg):
        segs.append([_stack_spec(block_cache_spec(cfg, k, batch, s_max), seg.repeats)
                     for (k, _) in seg.pattern])
    return segs
