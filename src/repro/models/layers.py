"""Core model layers: norms, rotary embeddings (RoPE / M-RoPE / sinusoid),
softmax attention (GQA/MQA, causal/bidir/windowed, chunked flash-style),
DeepSeek MLA (train expand path + absorbed decode path), and MLPs.

Everything is functional: params are plain pytrees declared via ParamSpec.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamSpec

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(F32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(F32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(F32) + bias.astype(F32)).astype(dt)


def norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), (None,), init="zeros")


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------
def rope_cos_sin(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int -> cos/sin (..., dim/2) in fp32."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=F32) / dim))
    ang = positions.astype(F32)[..., None] * inv_freq          # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    dt = x.dtype
    x = x.astype(F32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def mrope_cos_sin(positions3: jax.Array, dim: int, theta: float,
                  sections: Tuple[int, int, int]) -> Tuple[jax.Array, jax.Array]:
    """M-RoPE (Qwen2-VL): positions3 (3, B, S) -> cos/sin (B, S, dim/2).

    The dim/2 rotary frequencies are split into `sections` (t, h, w); each
    section rotates by its own position stream.  sum(sections) == dim//2.
    """
    assert sum(sections) == dim // 2, (sections, dim)
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=F32) / dim))  # (dim/2,)
    # section id per frequency
    sec_id = jnp.concatenate([jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    pos = positions3.astype(F32).transpose(1, 2, 0)[..., sec_id]   # (B, S, dim/2)
    ang = pos * inv_freq[None, None, :]
    return jnp.cos(ang), jnp.sin(ang)


def vlm_positions(batch: int, seq: int, n_vis: int, grid: Optional[int] = None) -> jax.Array:
    """Stub M-RoPE position ids: leading n_vis tokens form a sqrt-grid image,
    the rest are text with all three streams equal (temporal semantics)."""
    if grid is None:
        grid = max(int(math.sqrt(max(n_vis, 1))), 1)
    t = jnp.arange(seq, dtype=jnp.int32)
    is_vis = t < n_vis
    h = jnp.where(is_vis, (t // grid) % grid, t)
    w = jnp.where(is_vis, t % grid, t)
    tpos = jnp.where(is_vis, 0, t)
    p = jnp.stack([tpos, h, w])                                    # (3, S)
    return jnp.broadcast_to(p[:, None, :], (3, batch, seq))


def sinusoid_embedding(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=F32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=F32) * (-math.log(10000.0) / (d - 2 if d > 2 else 1)))
    ang = pos * div[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]


# ---------------------------------------------------------------------------
# Attention (chunked, flash-style memory behavior in pure XLA)
# ---------------------------------------------------------------------------
NEG_INF = -1e30

# Kernel dispatch: launchers call set_kernel_mode("auto"/"pallas"/"xla").
# On TPU with "auto", full-sequence attention routes to the Pallas flash
# kernel (kernels/ops.py); everywhere else the chunked XLA path below runs.
_KERNEL_MODE = "xla"


def set_kernel_mode(mode: str) -> None:
    global _KERNEL_MODE
    assert mode in ("auto", "xla", "pallas"), mode
    _KERNEL_MODE = mode


def kernel_mode() -> str:
    return _KERNEL_MODE


# Exact-costing mode (dry-run shallow compiles only): XLA cost analysis
# counts a scan/while body ONCE regardless of trip count, so for cost
# extraction every inner scan is replaced by a statically-unrolled or
# associative form: dense attention (no q-chunk scan), associative SSM
# scans, single-block CE.  Never enabled for real execution or for the
# full-model memory-analysis compile.
_EXACT_COSTING = False


def set_costing_mode(flag: bool) -> None:
    global _EXACT_COSTING
    _EXACT_COSTING = flag


def exact_costing() -> bool:
    return _EXACT_COSTING


# Activation sharding constraints (set by launchers when running under a
# mesh).  Without them GSPMD propagates the FSDP weight sharding into the
# residual stream (d_model over the dp axes, batch replicated) — every chip
# would then compute every sequence's attention.  `dp_axes` shards dim 0
# (batch); `sp_axis` optionally shards dim 1 (sequence parallelism).
_ACT_DP_AXES: tuple = ()     # ((name, size), ...)
_ACT_SP_AXIS: tuple = ()     # (name, size) or ()
_TP_AXIS: tuple = ()         # (name, size) or ()
_ACT_MODE: str = "batch"     # "batch": shard dim0 over dp | "feature": shard
                             # last dim over "data" (2D-TP decode plan)


def set_activation_sharding(mesh=None, sp: bool = False,
                            mode: str = "batch") -> None:
    """Configure from a Mesh (None disables)."""
    global _ACT_DP_AXES, _ACT_SP_AXIS, _TP_AXIS, _ACT_MODE
    _ACT_MODE = mode
    if mesh is None:
        _ACT_DP_AXES, _ACT_SP_AXIS, _TP_AXIS = (), (), ()
        return
    _ACT_DP_AXES = tuple((n, mesh.shape[n]) for n in ("pod", "data")
                         if n in mesh.axis_names)
    _ACT_SP_AXIS = ("model", mesh.shape["model"]) \
        if (sp and "model" in mesh.axis_names) else ()
    _TP_AXIS = ("model", mesh.shape["model"]) \
        if "model" in mesh.axis_names else ()


def shard_batch(x: jax.Array) -> jax.Array:
    """Constrain (B, S, ...) activations to batch-sharded (+ optional SP),
    or feature-sharded (last dim over "data") in 2D-TP decode mode."""
    if not _ACT_DP_AXES:
        return x
    from jax.sharding import PartitionSpec as P
    if _ACT_MODE == "feature":
        data = next((n for n, _ in _ACT_DP_AXES if n == "data"), None)
        sz = next((s for n, s in _ACT_DP_AXES if n == "data"), 1)
        if data is None or x.shape[-1] % sz != 0:
            return x
        return jax.lax.with_sharding_constraint(
            x, P(*([None] * (x.ndim - 1)), data))
    n = 1
    for _, s in _ACT_DP_AXES:
        n *= s
    names = tuple(a for a, _ in _ACT_DP_AXES)
    first = (names if len(names) > 1 else names[0]) if x.shape[0] % n == 0 else None
    rest = [None] * (x.ndim - 1)
    if _ACT_SP_AXIS and x.ndim >= 3 and x.shape[1] % _ACT_SP_AXIS[1] == 0:
        rest[0] = _ACT_SP_AXIS[0]
    if first is None and all(r is None for r in rest):
        return x
    return jax.lax.with_sharding_constraint(x, P(first, *rest))


def _pad_heads_tp(q, k, v):
    """Head-padded TP attention (§Perf optimization for archs whose head
    count does not divide the model axis, e.g. qwen2.5's 40 or qwen2-vl's 28
    heads on a 16-way axis): pad the Q/K/V *activations* (KV already
    broadcast to H) with zero heads up to a multiple of the TP size and
    constrain the head dim onto "model".  Padding is linear and sliced off
    after attention, so numerics and gradients of the real heads are
    untouched — but attention compute shards 16x instead of replicating.
    Returns (q, k, v, real_heads)."""
    h = q.shape[2]
    if not _TP_AXIS:
        return q, k, v, h
    name, tp = _TP_AXIS
    if h % tp == 0:
        return q, k, v, h
    h_pad = -(-h // tp) * tp
    from jax.sharding import PartitionSpec as P
    dp_n = 1
    for _, s in _ACT_DP_AXES:
        dp_n *= s
    dp = tuple(a for a, _ in _ACT_DP_AXES)
    first = (dp if len(dp) > 1 else dp[0]) \
        if (dp and q.shape[0] % dp_n == 0) else None

    def pad(t):
        t = jnp.pad(t, ((0, 0), (0, 0), (0, h_pad - t.shape[2]), (0, 0)))
        try:
            return jax.lax.with_sharding_constraint(t, P(first, None, name, None))
        except RuntimeError:   # no mesh in context (single-device tests)
            return t
    return pad(q), pad(k), pad(v), h


def _mask_bias(qpos: jax.Array, kpos: jax.Array, causal: bool, window: int) -> jax.Array:
    """(Sq, Sk) additive mask bias in fp32."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        ok &= qpos[:, None] - kpos[None, :] < window
    return jnp.where(ok, 0.0, NEG_INF).astype(F32)


def _attend_dense(q, k, v, qpos, kpos, causal, window, scale, softcap, kv_valid=None):
    """q: (B,Sq,H,D) k,v: (B,Sk,H,D) (kv pre-repeated to H) -> (B,Sq,H,D).

    KV heads are broadcast to the full H before this call: a (Hkv, G) split
    of the head dim would be unshardable under TP when Hkv < mesh model
    size (GSPMD would replicate the whole attention).  fp32 softmax."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=F32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = s + _mask_bias(qpos, kpos, causal, window)[None, None]
    if kv_valid is not None:  # (B, Sk) bool — decode cache validity
        s = s + jnp.where(kv_valid, 0.0, NEG_INF).astype(F32)[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def _attend_grouped(q, k, v, scale, softcap, kv_valid):
    """Grouped GQA attention WITHOUT broadcasting KV to H: q reshaped
    (B,Sq,Hkv,G,D).  Used for sharded-KV-cache decode where the head dim
    must stay replicated so the cache's seq sharding survives (a KV repeat
    to H would force GSPMD to reshard/gather the whole cache — §Perf)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=F32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if kv_valid is not None:
        s = s + jnp.where(kv_valid, 0.0, NEG_INF).astype(F32)[:, None, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, v.shape[-1])


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool, window: int = 0, scale: Optional[float] = None,
              softcap: float = 0.0, q_offset: int = 0,
              chunk_q: int = 1024, kv_valid: Optional[jax.Array] = None,
              pad_heads: bool = False, group_kv: bool = False) -> jax.Array:
    """GQA attention.  q: (B,Sq,Hq,D), k/v: (B,Sk,Hkv,D) -> (B,Sq,Hq,D).

    When Sq > chunk_q, queries are processed in chunks under jax.checkpoint:
    bounded memory (flash-attention behavior) with recompute-in-backward.
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]                      # may differ from d (MLA)
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv

    # Pallas fast path (TPU): plain causal/bidir GQA, no window/softcap/valid-mask
    if (_KERNEL_MODE != "xla" and window == 0 and softcap == 0.0 and kv_valid is None
            and q_offset == 0 and d == dv and sq > 1):
        from repro.kernels import ops as _ops
        if _ops.use_pallas(_KERNEL_MODE):
            return _ops.flash_attention(q, k, v, causal, scale,
                                        _KERNEL_MODE == "pallas"
                                        and jax.default_backend() != "tpu")

    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if group_kv and not causal and window == 0:
        return _attend_grouped(q, k, v, scale, softcap, kv_valid)
    if g > 1:  # broadcast KV heads (shardable-head form; see _attend_dense)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    real_h = hq
    if pad_heads:
        q, k, v, real_h = _pad_heads_tp(q, k, v)
        hq = q.shape[2]
    kpos = jnp.arange(sk, dtype=jnp.int32)

    if _EXACT_COSTING:
        chunk_q = max(chunk_q, sq)
    if sq <= chunk_q:
        qpos = q_offset + jnp.arange(sq, dtype=jnp.int32)
        o = _attend_dense(q, k, v, qpos, kpos, causal, window, scale, softcap, kv_valid)
        return o[:, :, :real_h]

    n_chunks = -(-sq // chunk_q)
    pad = n_chunks * chunk_q - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc_all = q.reshape(b, n_chunks, chunk_q, hq, d).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def body(carry, inp):
        ci, qc = inp
        qpos = q_offset + ci * chunk_q + jnp.arange(chunk_q, dtype=jnp.int32)
        oc = _attend_dense(qc, k, v, qpos, kpos, causal, window, scale, softcap, kv_valid)
        return carry, oc

    _, o = jax.lax.scan(body, 0, (jnp.arange(n_chunks), qc_all))
    o = o.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk_q, hq, dv)
    return o[:, :sq, :real_h]


# ---------------------------------------------------------------------------
# GQA attention block (params + apply)
# ---------------------------------------------------------------------------
def attn_spec(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    s = {
        "wq": ParamSpec((d, nq, hd), ("embed", "heads", None), init="scaled"),
        "wk": ParamSpec((d, nkv, hd), ("embed", "kv_heads", None), init="scaled"),
        "wv": ParamSpec((d, nkv, hd), ("embed", "kv_heads", None), init="scaled"),
        "wo": ParamSpec((nq, hd, d), ("heads", None, "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((nq, hd), ("heads", None), init="zeros")
        s["bk"] = ParamSpec((nkv, hd), ("kv_heads", None), init="zeros")
        s["bv"] = ParamSpec((nkv, hd), ("kv_heads", None), init="zeros")
    return s


def _qkv(x, p, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    return q, k, v


def _rope_for(cfg: ModelConfig, positions, hd: int, batch: int, seq: int):
    """cos/sin for this arch's rope kind; positions: (S,) or (3,B,S) or None."""
    if cfg.rope_kind == "none" or cfg.rope_kind == "sinusoid":
        return None
    if cfg.rope_kind == "mrope":
        if positions is None or positions.ndim == 1:
            positions = vlm_positions(batch, seq, cfg.n_vision_tokens)
            if positions.shape[2] != seq:  # offset decode handled by caller
                pass
        return mrope_cos_sin(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    if positions is None:
        positions = jnp.arange(seq, dtype=jnp.int32)
    return rope_cos_sin(positions, hd, cfg.rope_theta)


def attn_block(x, p, cfg: ModelConfig, *, causal: bool = False, window: int = 0,
               positions=None, cross_kv=None):
    """Full-sequence attention block (train/prefill). Returns (out, (k, v))."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"][None, None]
        k, v = cross_kv
        o = attention(q, k, v, causal=False, softcap=cfg.attn_logit_softcap)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), (k, v)
    q, k, v = _qkv(x, p, cfg)
    cs = _rope_for(cfg, positions, hd, b, s)
    if cs is not None:
        q = apply_rope(q, *cs)
        k = apply_rope(k, *cs)
    o = attention(q, k, v, causal=causal, window=window,
                  softcap=cfg.attn_logit_softcap,
                  pad_heads=cfg.pad_heads_to_tp)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), (k, v)


def _norm_index(index, b: int) -> jax.Array:
    """Normalize decode position index to (B,) int32 (scalar broadcasts)."""
    idx = jnp.asarray(index, jnp.int32)
    return jnp.broadcast_to(idx, (b,)) if idx.ndim == 0 else idx


def attn_decode(x, p, cfg: ModelConfig, k_cache, v_cache, index, *,
                window: int = 0, positions=None, cross: bool = False):
    """Single-token decode. x: (B,1,d). k/v_cache: (B,S,hkv,hd) (rope pre-applied
    at write time). index: scalar or (B,) per-slot position.
    Returns (out, k_cache, v_cache)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    s_max = k_cache.shape[1]
    if cross:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"][None, None]
        valid = jnp.ones((b, s_max), bool)
        o = attention(q, k_cache, v_cache, causal=False, kv_valid=valid,
                      softcap=cfg.attn_logit_softcap)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), k_cache, v_cache
    idx = _norm_index(index, b)                                  # (B,)
    q, k, v = _qkv(x, p, cfg)
    if cfg.rope_kind in ("rope", "mrope"):
        if cfg.rope_kind == "mrope":
            pos3 = jnp.broadcast_to(idx[None, :, None], (3, b, 1)).astype(jnp.int32)
            cs = mrope_cos_sin(pos3, hd, cfg.rope_theta, cfg.mrope_sections)
        else:
            cs = rope_cos_sin(idx[:, None], hd, cfg.rope_theta)  # (B,1,hd/2)
        q = apply_rope(q, *cs)
        k = apply_rope(k, *cs)
    slot = idx % s_max if window > 0 else idx
    if cfg.decode_cache_seq_shard or cfg.decode_2d_tp:
        # masked elementwise write: a scatter into the sharded seq dim would
        # make GSPMD all-gather the whole cache per layer (§Perf cell 2)
        mask = (jnp.arange(s_max, dtype=jnp.int32)[None, :] == slot[:, None]
                )[..., None, None]                       # (B,S,1,1)
        k_cache = jnp.where(mask, k[:, 0:1], k_cache)
        v_cache = jnp.where(mask, v[:, 0:1], v_cache)
    else:
        rows = jnp.arange(b)
        k_cache = k_cache.at[rows, slot].set(k[:, 0])
        v_cache = v_cache.at[rows, slot].set(v[:, 0])
    kpos_slots = jnp.arange(s_max, dtype=jnp.int32)[None, :]     # (1,S)
    idx_c = idx[:, None]
    if window > 0:
        # ring buffer: slot j holds absolute position idx - ((idx - j) mod s_max)
        abs_pos = idx_c - ((idx_c - kpos_slots) % s_max)
        valid = (abs_pos >= 0) & (abs_pos <= idx_c) & (idx_c - abs_pos < window)
    else:
        valid = kpos_slots <= idx_c
    o = attention(q, k_cache, v_cache, causal=False, kv_valid=valid,
                  softcap=cfg.attn_logit_softcap,
                  group_kv=cfg.decode_cache_seq_shard or cfg.decode_2d_tp)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------
def mla_spec(cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    return {
        "wq": ParamSpec((d, h, m.qk_nope_head_dim + m.qk_rope_head_dim),
                        ("embed", "heads", None), init="scaled"),
        "w_dkv": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("embed", None), init="scaled"),
        "kv_norm": norm_spec(m.kv_lora_rank),
        "w_uk": ParamSpec((m.kv_lora_rank, h, m.qk_nope_head_dim),
                          (None, "heads", None), init="scaled"),
        "w_uv": ParamSpec((m.kv_lora_rank, h, m.v_head_dim),
                          (None, "heads", None), init="scaled"),
        "wo": ParamSpec((h, m.v_head_dim, d), ("heads", None, "embed"), init="scaled"),
    }


def mla_block(x, p, cfg: ModelConfig, *, causal: bool = True, positions=None):
    """Train/prefill MLA: expand latent to per-head K/V.  Returns (out, cache_kv)
    where cache_kv = (c_kv, k_rope) for the decode path."""
    m = cfg.mla
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    cs = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, *cs)
    k_rope = apply_rope(k_rope[:, :, None, :], *cs)          # (B,S,1,rope)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.qk_rope_head_dim,))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    o = attention(qf, k, v, causal=causal, scale=scale)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_decode(x, p, cfg: ModelConfig, c_cache, kr_cache, index):
    """Absorbed-projection MLA decode: attention runs in the latent space
    (per-head K/V are never materialized over the 32k cache).
    c_cache: (B,S,lora), kr_cache: (B,S,rope)."""
    m = cfg.mla
    b = x.shape[0]
    s_max = c_cache.shape[1]
    idx = _norm_index(index, b)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])                  # (B,1,H,nope+rope)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_new, kr_new = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_new = rms_norm(c_new, p["kv_norm"], cfg.norm_eps)
    cs = rope_cos_sin(idx[:, None], m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, *cs)
    kr_new = apply_rope(kr_new[:, :, None, :], *cs)[:, :, 0, :]
    rows = jnp.arange(b)
    c_cache = c_cache.at[rows, idx].set(c_new[:, 0])
    kr_cache = kr_cache.at[rows, idx].set(kr_new[:, 0])
    # absorb W_uk into q: q_lat (B,1,H,lora)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat, c_cache, preferred_element_type=F32)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, kr_cache, preferred_element_type=F32)
    scores = (s_lat + s_rope) * scale
    valid = jnp.arange(s_max, dtype=jnp.int32)[None, :] <= idx[:, None]
    scores = scores + jnp.where(valid, 0.0, NEG_INF).astype(F32)[:, None, None, :]
    prob = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhst,btr->bshr", prob, c_cache)        # (B,1,H,lora)
    o = jnp.einsum("bshr,rhk->bshk", ctx_lat, p["w_uv"])         # (B,1,H,v)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, c_cache, kr_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_spec(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        return {
            "wi_gate": ParamSpec((d, f), ("embed", "mlp"), init="scaled"),
            "wi_up": ParamSpec((d, f), ("embed", "mlp"), init="scaled"),
            "wo": ParamSpec((f, d), ("mlp", "embed"), init="scaled"),
        }
    return {  # gelu (whisper)
        "wi": ParamSpec((d, f), ("embed", "mlp"), init="scaled"),
        "bi": ParamSpec((f,), ("mlp",), init="zeros"),
        "wo": ParamSpec((f, d), ("mlp", "embed"), init="scaled"),
        "bo": ParamSpec((d,), (None,), init="zeros"),
    }


def mlp_block(x, p, cfg: ModelConfig):
    if cfg.mlp_kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
        h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
        return jnp.einsum("bsf,fd->bsd", h, p["wo"])
    h = jnp.einsum("bsd,df->bsf", x, p["wi"]) + p["bi"]
    h = jax.nn.gelu(h.astype(F32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"]) + p["bo"]
