"""Mixture-of-Experts FFN (DeepSeek/Moonlight family: shared + routed top-k).

Dispatch is capacity-based scatter into per-expert buffers, computed
**per batch row**:

  * routing, intra-expert positions (cumsum) and scatter/gather are all
    independent per batch element, so under GSPMD with batch sharded over
    the dp axes every dispatch op stays device-local (no cross-shard
    cumsum/scatter traffic);
  * the expert dim of the (B, E, C, d) buffers carries the "experts"
    logical axis => expert parallelism over the "model" mesh axis;
  * per-row capacity C = ceil(cf * k * S / E); tokens over capacity are
    dropped (GShard semantics) — the residual connection keeps them intact;
  * fully differentiable (scatter-add fwd, gather bwd and vice versa).

A Switch-style auxiliary load-balancing loss is returned for training.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamSpec
from repro.models.layers import F32


def moe_spec(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, fe = cfg.d_model, m.d_ff_expert
    # §Perf knob: with moe_expert_fsdp=False the expert weights are sharded
    # over experts (EP) ONLY — no FSDP dim, so no per-layer all-gather of the
    # full expert bank (the dominant collective in the MoE train baseline).
    emb = "embed" if cfg.moe_expert_fsdp else None
    s = {
        "router": ParamSpec((d, m.num_experts), ("embed", "experts"), init="scaled",
                            dtype=jnp.float32),
        "w_gate": ParamSpec((m.num_experts, d, fe), ("experts", emb, "expert_mlp"),
                            init="scaled"),
        "w_up": ParamSpec((m.num_experts, d, fe), ("experts", emb, "expert_mlp"),
                          init="scaled"),
        "w_down": ParamSpec((m.num_experts, fe, d), ("experts", "expert_mlp", emb),
                            init="scaled"),
    }
    if m.num_shared_experts:
        fs = m.num_shared_experts * fe
        s["shared"] = {
            "wi_gate": ParamSpec((d, fs), ("embed", "mlp"), init="scaled"),
            "wi_up": ParamSpec((d, fs), ("embed", "mlp"), init="scaled"),
            "wo": ParamSpec((fs, d), ("mlp", "embed"), init="scaled"),
        }
    return s


def moe_capacity(cfg: ModelConfig, seq: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * m.top_k * seq / m.num_experts)
    return max(c, m.top_k)


def _dispatch_row(flat_e, slot, src, num_experts, cap):
    """One batch row: scatter (S*k, d) token copies into (E, C+1, d)."""
    buf = jnp.zeros((num_experts, cap + 1, src.shape[-1]), src.dtype)
    return buf.at[flat_e, slot].add(src)


def _gather_row(out_buf, flat_e, slot):
    return out_buf[flat_e, slot]


def moe_block(x: jax.Array, p: dict, cfg: ModelConfig,
              capacity: int = 0) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    k = m.top_k
    c = capacity or moe_capacity(cfg, s)

    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"].astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (B,S,E) fp32
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e f_e * P_e  (global means)
    me = probs.mean((0, 1))
    ce = jax.nn.one_hot(expert_idx[..., 0], m.num_experts, dtype=F32).mean((0, 1))
    aux = m.num_experts * jnp.sum(me * ce) * m.aux_loss_weight

    # per-row intra-expert positions
    flat_e = expert_idx.reshape(b, s * k)                        # (B, S*k)
    eo = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)  # (B, S*k, E)
    pos = jnp.cumsum(eo, axis=1) - 1
    pos_in_e = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = pos_in_e < c
    slot = jnp.where(keep, pos_in_e, c)                          # overflow slot = c

    src = jnp.repeat(x.reshape(b, s, 1, d), k, axis=2).reshape(b, s * k, d)
    buf = jax.vmap(_dispatch_row, in_axes=(0, 0, 0, None, None))(
        flat_e, slot, src, m.num_experts, c)                     # (B, E, C+1, d)

    # expert SwiGLU: (B,E,C,d) x (E,d,f)
    bufc = buf[:, :, :c]
    g = jnp.einsum("becd,edf->becf", bufc, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", bufc, p["w_up"])
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    out_buf = jnp.pad(out_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))

    gathered = jax.vmap(_gather_row)(out_buf, flat_e, slot)      # (B, S*k, d)
    w = (gate_vals.reshape(b, s * k) * keep.astype(F32)).astype(x.dtype)
    y = (gathered * w[..., None]).reshape(b, s, k, d).sum(axis=2)

    if m.num_shared_experts:
        sp = p["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sp["wi_gate"])
        u = jnp.einsum("bsd,df->bsf", x, sp["wi_up"])
        y = y + jnp.einsum("bsf,fd->bsd",
                           jax.nn.silu(g.astype(F32)).astype(x.dtype) * u, sp["wo"])

    return y, aux
