"""Cross-entropy losses.

`blocked_cross_entropy` is the memory-term optimization (EXPERIMENTS.md §Perf):
for 150k-vocab models the (B, S, V) logits tensor is the single largest
activation in training (e.g. qwen2.5 train_4k: 1M tokens x 152k vocab x 2B
= 319 GB global).  We instead scan over vocab blocks maintaining a running
(max, sumexp, label_logit); the full logits never exist.  jax.checkpoint on
the block body keeps backward memory equally bounded (recompute per block).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """logits (..., V) any float dtype; labels (...) int32.
    Returns (mean_nll fp32, accuracy fp32)."""
    logits = logits.astype(F32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    acc = (jnp.argmax(logits, axis=-1) == labels).astype(F32)
    if mask is None:
        return nll.mean(), acc.mean()
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom, (acc * mask).sum() / denom


def blocked_cross_entropy(x: jax.Array, emb: jax.Array, labels: jax.Array,
                          block: int = 8192,
                          mask: jax.Array | None = None,
                          transpose_emb: bool = False) -> Tuple[jax.Array, jax.Array]:
    """CE of logits = x @ emb^T without materializing them.

    x: (T, d) final hidden states; emb: (V, d) (or (d, V) with transpose_emb);
    labels: (T,).  Returns (mean_nll, max-logit-match accuracy proxy).
    """
    if transpose_emb:
        emb = emb.T                                    # (V, d) view
    v, d = emb.shape
    t = x.shape[0]
    n_blocks = -(-v // block)
    pad = n_blocks * block - v
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0)))
    embb = emb.reshape(n_blocks, block, d)

    @jax.checkpoint
    def body(carry, inp):
        m, s, ll, amax_val, amax_idx = carry
        bi, e_blk = inp
        logits = jnp.einsum("td,kd->tk", x, e_blk, preferred_element_type=F32)
        base = bi * block
        col = jnp.arange(block, dtype=jnp.int32)[None, :] + base
        valid = col < v
        logits = jnp.where(valid, logits, -jnp.inf)
        blk_max = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m, blk_max)
        s = s * jnp.exp(m - new_m) + jnp.sum(jnp.exp(logits - new_m[:, None]), axis=-1)
        # label logit if the label falls in this block
        in_blk = (labels >= base) & (labels < base + block)
        idx = jnp.clip(labels - base, 0, block - 1)
        cand = jnp.take_along_axis(logits, idx[:, None], axis=-1)[:, 0]
        ll = jnp.where(in_blk, cand, ll)
        # running argmax for accuracy
        blk_arg = jnp.argmax(logits, axis=-1) + base
        better = blk_max > amax_val
        amax_val = jnp.where(better, blk_max, amax_val)
        amax_idx = jnp.where(better, blk_arg, amax_idx)
        return (new_m, s, ll, amax_val, amax_idx), None

    init = (jnp.full((t,), -jnp.inf, F32), jnp.zeros((t,), F32),
            jnp.full((t,), -jnp.inf, F32), jnp.full((t,), -jnp.inf, F32),
            jnp.zeros((t,), jnp.int32))
    (m, s, ll, _, amax_idx), _ = jax.lax.scan(
        body, init, (jnp.arange(n_blocks), embb))
    nll = m + jnp.log(s) - ll
    acc = (amax_idx == labels).astype(F32)
    if mask is None:
        return nll.mean(), acc.mean()
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom, (acc * mask).sum() / denom
