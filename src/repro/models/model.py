"""Unified model API over all 10 assigned architectures.

    model = build_model(cfg)
    spec  = model.spec()                    # ParamSpec tree (single source of truth)
    params = model.init(key)                # materialized (smoke / real runs)
    aspec  = model.abstract_params()        # ShapeDtypeStruct tree (dry-run)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, cache, tokens, index)
    cspec  = model.cache_abstract(batch, s_max)

Batch dict keys by family:
    LM/MoE/SSM/hybrid: tokens (B,S) int32
    vlm:               tokens (B,S) + vision_embeds (B, n_vis, d)
    audio (whisper):   frames (B,S,d) + tokens (B, dec_len)
All train batches also carry labels (same shape as tokens).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import transformer as T
from repro.models import param as P
from repro.models.loss import blocked_cross_entropy, cross_entropy

F32 = jnp.float32


def _shift_labels(tokens):
    """next-token labels (last position predicts a pad; masked out)."""
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate([jnp.ones_like(tokens[:, 1:], F32),
                            jnp.zeros_like(tokens[:, :1], F32)], axis=1)
    return labels, mask


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- params ------------------------------------------------------------
    def spec(self) -> P.SpecTree:
        if self.cfg.encdec:
            return ED.encdec_spec(self.cfg)
        return T.lm_spec(self.cfg)

    def init(self, key: jax.Array):
        return P.init_params(self.spec(), key)

    def abstract_params(self):
        return P.abstract_params(self.spec())

    def logical_axes(self):
        return P.logical_axes(self.spec())

    def param_count(self) -> int:
        return P.param_count(self.spec())

    # -- embedding / head ----------------------------------------------------
    def _embed(self, params, batch) -> Tuple[jax.Array, Optional[jax.Array]]:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        positions = None
        if cfg.family == "vlm":
            b, s = tokens.shape
            ve = batch["vision_embeds"].astype(x.dtype)      # (B, n_vis, d)
            n_vis = ve.shape[1]
            pad = jnp.zeros((b, s - n_vis, ve.shape[-1]), x.dtype)
            ve_full = jnp.concatenate([ve, pad], axis=1)
            is_vis = (jnp.arange(s) < n_vis)[None, :, None]
            x = jnp.where(is_vis, ve_full, x)
            positions = L.vlm_positions(b, s, n_vis)
        return L.shard_batch(x), positions

    def _head(self, params, x) -> jax.Array:
        if self.cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])

    # -- training loss -------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        if cfg.encdec:
            enc_out = ED.encode(batch["frames"], params, cfg)
            logits = ED.decode_train(batch["tokens"], enc_out, params, cfg)
            labels, mask = _shift_labels(batch["tokens"])
            nll, acc = cross_entropy(logits, labels, mask)
            return nll, {"nll": nll, "acc": acc, "aux": jnp.zeros((), F32)}

        x, positions = self._embed(params, batch)
        x, aux, _ = T.apply_segments(x, params["segments"], cfg,
                                     causal=True, positions=positions)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        labels, mask = _shift_labels(batch["tokens"])
        if cfg.blocked_xent:
            b, s, d = x.shape
            emb = params["embed"] if cfg.tie_embeddings else params["lm_head"]
            vb = cfg.vocab_size if L.exact_costing() else cfg.vocab_block
            nll, acc = blocked_cross_entropy(
                x.reshape(b * s, d), emb, labels.reshape(-1),
                block=vb, mask=mask.reshape(-1),
                transpose_emb=not cfg.tie_embeddings)
        else:
            logits = self._head(params, x)
            nll, acc = cross_entropy(logits, labels, mask)
        loss = nll + aux
        return loss, {"nll": nll, "acc": acc, "aux": aux}

    # -- inference: prefill ----------------------------------------------------
    def prefill(self, params, batch) -> Tuple[jax.Array, Any]:
        """Full-prompt pass. Returns (last-position logits (B,V), cache)."""
        cfg = self.cfg
        if cfg.encdec:
            enc_out = ED.encode(batch["frames"], params, cfg)
            ck, cv = ED.build_cross_cache(enc_out, params)
            dec_tokens = batch["tokens"]
            s_max = batch.get("s_max", dec_tokens.shape[1])
            logits = ED.decode_train(dec_tokens, enc_out, params, cfg)
            # build self-attn cache for subsequent decode (filled up to dec len)
            b = dec_tokens.shape[0]
            cspec = ED.encdec_cache_spec(cfg, b, s_max)
            cache = P.init_params(cspec, jax.random.PRNGKey(0))
            cache = dict(cache)
            cache["cross_k"], cache["cross_v"] = ck, cv
            return logits[:, -1], cache
        x, positions = self._embed(params, batch)
        x, _, caches = T.apply_segments(x, params["segments"], cfg, causal=True,
                                        positions=positions, collect_cache=True)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x[:, -1:])[:, 0]
        return logits, caches

    # -- inference: single-token decode ----------------------------------------
    def decode_step(self, params, cache, tokens, index) -> Tuple[jax.Array, Any]:
        """tokens: (B,1) int32; index: scalar int32 (current position).
        Returns (logits (B,1,V), new cache)."""
        cfg = self.cfg
        if cfg.encdec:
            return ED.decode_step(tokens, cache, params, cfg, index)
        x = params["embed"][tokens]
        if cfg.family == "vlm":
            pass  # decode tokens are text; M-RoPE handled inside attn_decode
        x, cache = T.apply_segments_decode(x, params["segments"], cache, cfg, index)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._head(params, x), cache

    # -- caches -----------------------------------------------------------------
    def cache_spec(self, batch: int, s_max: int):
        if self.cfg.encdec:
            return ED.encdec_cache_spec(self.cfg, batch, s_max)
        return T.cache_spec(self.cfg, batch, s_max)

    def cache_abstract(self, batch: int, s_max: int):
        return P.abstract_params(self.cache_spec(batch, s_max))

    def cache_zeros(self, batch: int, s_max: int):
        return P.init_params(self.cache_spec(batch, s_max), jax.random.PRNGKey(0))

    def cache_logical_axes(self, batch: int, s_max: int):
        return P.logical_axes(self.cache_spec(batch, s_max))


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
