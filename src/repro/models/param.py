"""Parameter declaration system.

A model is described once as a nested dict of `ParamSpec`s (shape + logical
axes + initializer). From that single source of truth we derive:

  * `init_params(spec, key)`        -- materialized arrays (smoke tests, real runs)
  * `abstract_params(spec)`         -- jax.ShapeDtypeStruct tree (dry-run: NO allocation)
  * `logical_axes(spec)`            -- tree of logical-axis tuples (sharding rules)
  * `param_count(spec)`             -- exact parameter count

Logical axis names are mapped to mesh axes by distributed/sharding.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis name per dim (None = replicated)
    init: str = "normal"                     # normal|zeros|ones|scaled|uniform_conv|a_log
    scale: float = 0.02
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


SpecTree = Dict[str, Any]  # nested dict of ParamSpec


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    shape, dtype = spec.shape, spec.dtype
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if spec.init == "scaled":  # fan-in scaled
        fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
        s = 1.0 / math.sqrt(fan_in)
        return (s * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if spec.init == "a_log":   # mamba A_log init: log(1..d_state) broadcast
        d_state = shape[-1]
        a = jnp.tile(jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32)), shape[:-1] + (1,))
        return a.astype(dtype)
    if spec.init == "dt_bias":  # mamba dt bias: softplus-inverse of uniform [1e-3, 1e-1]
        u = jax.random.uniform(key, shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    if spec.init == "lru_a":    # RG-LRU Lambda init so a in [0.9, 0.999]
        u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
        # a = exp(-c*softplus(L)*r); store L s.t. softplus(L) = -log(u)/c (c=8, r~1)
        target = -jnp.log(u) / 8.0
        return jnp.log(jnp.expm1(jnp.maximum(target, 1e-8))).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def is_leaf(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec: SpecTree, key: jax.Array):
    leaves, treedef = jax.tree.flatten(spec, is_leaf=is_leaf)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(spec: SpecTree):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec, is_leaf=is_leaf)


def logical_axes(spec: SpecTree):
    return jax.tree.map(lambda s: s.axes, spec, is_leaf=is_leaf)


def param_count(spec: SpecTree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(spec, is_leaf=is_leaf))


def param_bytes(spec: SpecTree) -> int:
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in jax.tree.leaves(spec, is_leaf=is_leaf))
