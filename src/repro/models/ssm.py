"""State-space / linear-recurrence blocks: Mamba-1 selective SSM and
Griffin RG-LRU, sharing one chunked diagonal-recurrence scan.

Memory discipline: a naive Mamba scan materializes (B, S, d_inner, N)
decay/input tensors (17 GB at our train_4k shapes).  We instead scan over
time *chunks*; the chunk body is jax.checkpoint'ed so only the inter-chunk
carried state (B, d_inner, N) is stored per chunk — the per-step tensors
exist transiently inside one chunk (fwd and recomputed bwd).  This is the
same tiling the Pallas ssm_scan kernel uses on TPU (kernels/ssm_scan.py).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamSpec
from repro.models.layers import F32

DEFAULT_CHUNK = 64


# ---------------------------------------------------------------------------
# chunked diagonal linear recurrence: h_t = a_t * h_{t-1} + b_t
# a, b: (B, S, ...state dims...) ; returns h for every t (same shape) + final h
# ---------------------------------------------------------------------------
def assoc_diag_scan(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Associative-scan formulation (exact-costing mode: statically unrolled
    log-depth combine graph, so XLA cost analysis counts it fully)."""
    def comb(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])
    a_, hs = jax.lax.associative_scan(comb, (a.astype(F32), b.astype(F32)), axis=1)
    del a_
    return hs, hs[:, -1]


def chunked_diag_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None,
                      chunk: int = DEFAULT_CHUNK) -> Tuple[jax.Array, jax.Array]:
    from repro.models import layers as _L
    if _L.exact_costing() and h0 is None:
        return assoc_diag_scan(a, b)
    B, S = a.shape[0], a.shape[1]
    state_shape = a.shape[2:]
    if h0 is None:
        h0 = jnp.zeros((B,) + state_shape, F32)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * len(state_shape), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad)) + ((0, 0),) * len(state_shape))
    ac = jnp.moveaxis(a.reshape((B, n_chunks, chunk) + state_shape), 1, 0)
    bc = jnp.moveaxis(b.reshape((B, n_chunks, chunk) + state_shape), 1, 0)

    @jax.checkpoint
    def chunk_body(h, inp):
        a_c, b_c = inp                                   # (B, chunk, ...)

        def step(hh, xs):
            at, bt = xs
            hh = at.astype(F32) * hh + bt.astype(F32)
            return hh, hh

        h, hs = jax.lax.scan(step, h, (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(b_c, 1, 0)))
        return h, jnp.moveaxis(hs, 0, 1)                 # back to (B, chunk, ...)

    h_final, hs = jax.lax.scan(chunk_body, h0, (ac, bc))
    hs = jnp.moveaxis(hs, 0, 1).reshape((B, n_chunks * chunk) + state_shape)
    return hs[:, :S], h_final


# ---------------------------------------------------------------------------
# causal depthwise conv1d (k small), + single-step update for decode
# ---------------------------------------------------------------------------
def causal_conv1d(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """x: (B, S, C), w: (C, K) depthwise, causal."""
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # sum_j x[t-K+1+j] * w[:, j]
    out = jnp.zeros_like(x, dtype=F32)
    for j in range(k):
        out = out + xp[:, j:j + x.shape[1]].astype(F32) * w[:, j].astype(F32)[None, None]
    return (out + bias.astype(F32)).astype(x.dtype)


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                bias: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x_t: (B, C); conv_state: (B, K-1, C) past inputs. Returns (y_t, new_state)."""
    k = w.shape[1]
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,ck->bc", window.astype(F32), w.astype(F32)) + bias.astype(F32)
    return y.astype(x_t.dtype), window[:, -(k - 1):] if k > 1 else conv_state


# ---------------------------------------------------------------------------
# Mamba-1 block
# ---------------------------------------------------------------------------
def mamba_spec(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dtr = cfg.dt_rank
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ssm_inner"), init="scaled"),
        "conv_w": ParamSpec((di, s.d_conv), ("ssm_inner", None), init="scaled"),
        "conv_b": ParamSpec((di,), ("ssm_inner",), init="zeros"),
        "x_proj": ParamSpec((di, dtr + 2 * s.d_state), ("ssm_inner", None), init="scaled"),
        "dt_proj": ParamSpec((dtr, di), (None, "ssm_inner"), init="scaled"),
        "dt_bias": ParamSpec((di,), ("ssm_inner",), init="dt_bias", dtype=jnp.float32),
        "A_log": ParamSpec((di, s.d_state), ("ssm_inner", None), init="a_log", dtype=jnp.float32),
        "D": ParamSpec((di,), ("ssm_inner",), init="ones", dtype=jnp.float32),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed"), init="scaled"),
    }


def _mamba_abc(xc, p, cfg):
    """Shared projections: xc (B,T,di) -> dt (B,T,di) fp32, Bm, Cm (B,T,N)."""
    s = cfg.ssm
    dtr = cfg.dt_rank
    xdbc = jnp.einsum("btd,dk->btk", xc, p["x_proj"])
    dt_r, Bm, Cm = jnp.split(xdbc, [dtr, dtr + s.d_state], axis=-1)
    dt = jnp.einsum("btr,rd->btd", dt_r, p["dt_proj"]).astype(F32) + p["dt_bias"]
    dt = jax.nn.softplus(dt)
    return dt, Bm, Cm


def _mamba_chunk_scan(dt, Bm, Cm, xc, A, chunk: int):
    """Fused selective scan. dt (B,S,di) fp32; Bm/Cm (B,S,N); xc (B,S,di).
    Decay/input tensors (B,chunk,di,N) only ever exist for ONE chunk
    (checkpointed body) — never (B,S,di,N).  Returns (y (B,S,di) fp32, h_final)."""
    B, S, di = dt.shape
    N = Bm.shape[-1]
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S

    def prep(t, fill=0.0):
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2),
                        constant_values=fill)
        t = t.reshape((B, n_chunks, chunk) + t.shape[2:])
        return jnp.moveaxis(t, 1, 0)                     # (n_chunks, B, chunk, ...)

    from repro.models import layers as _L
    if _L.exact_costing():
        # exact-costing mode: materialized associative form (count-correct)
        a = jnp.exp(dt[..., None] * A[None, None])       # (B,S,di,N)
        bmat = (dt * xc.astype(F32))[..., None] * Bm.astype(F32)[:, :, None, :]
        hs, h_final = assoc_diag_scan(a, bmat)
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cm.astype(F32))
        return y, h_final

    xs = (prep(dt), prep(Bm), prep(Cm), prep(xc))

    @jax.checkpoint
    def body(h, inp):
        dt_c, B_c, C_c, x_c = inp                        # (B, chunk, ...)
        a = jnp.exp(dt_c[..., None] * A[None, None])     # (B, chunk, di, N)
        b = (dt_c * x_c.astype(F32))[..., None] * B_c.astype(F32)[:, :, None, :]

        def step(hh, s_in):
            at, bt, ct = s_in                            # (B,di,N),(B,di,N),(B,N)
            hh = at * hh + bt
            yt = jnp.einsum("bdn,bn->bd", hh, ct)
            return hh, yt

        h, y_c = jax.lax.scan(
            step, h, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0),
                      jnp.moveaxis(C_c.astype(F32), 1, 0)))
        return h, jnp.moveaxis(y_c, 0, 1)                # (B, chunk, di)

    h0 = jnp.zeros((B, di, N), F32)
    h_final, ys = jax.lax.scan(body, h0, xs)
    ys = jnp.moveaxis(ys, 0, 1).reshape(B, n_chunks * chunk, di)
    return ys[:, :S], h_final


def mamba_block(x: jax.Array, p: dict, cfg: ModelConfig,
                chunk: int = DEFAULT_CHUNK, return_state: bool = False):
    """x: (B, S, d) -> (B, S, d) [, (conv_state, ssm_state)]."""
    s = cfg.ssm
    xz = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    xc_pre, z = jnp.split(xz, 2, axis=-1)                # (B,S,di) each
    xc = causal_conv1d(xc_pre, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(F32)).astype(x.dtype)
    dt, Bm, Cm = _mamba_abc(xc, p, cfg)
    A = -jnp.exp(p["A_log"])                             # (di, N) fp32
    y, h_final = _mamba_chunk_scan(dt, Bm, Cm, xc, A, chunk)  # (B,S,di) fp32
    y = y + p["D"][None, None] * xc.astype(F32)
    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    if return_state:
        conv_state = xc_pre[:, -(s.d_conv - 1):]         # raw pre-conv tail
        return out, (conv_state, h_final)
    return out


def mamba_decode(x_t: jax.Array, p: dict, cfg: ModelConfig,
                 conv_state: jax.Array, ssm_state: jax.Array):
    """Single token. x_t: (B,1,d); conv_state (B,K-1,di); ssm_state (B,di,N) fp32.
    Returns (y (B,1,d), conv_state, ssm_state)."""
    xz = jnp.einsum("bsd,dk->bsk", x_t, p["in_proj"])[:, 0]
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = conv1d_step(xc, conv_state, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(F32)).astype(x_t.dtype)
    dt, Bm, Cm = _mamba_abc(xc[:, None], p, cfg)
    dt, Bm, Cm = dt[:, 0], Bm[:, 0], Cm[:, 0]            # (B,di) fp32, (B,N), (B,N)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A[None])                 # (B,di,N)
    bmat = (dt * xc.astype(F32))[..., None] * Bm.astype(F32)[:, None, :]
    ssm_state = a * ssm_state + bmat
    y = jnp.einsum("bdn,bn->bd", ssm_state, Cm.astype(F32))
    y = y + p["D"][None] * xc.astype(F32)
    y = (y * jax.nn.silu(z.astype(F32))).astype(x_t.dtype)
    return jnp.einsum("bk,kd->bd", y, p["out_proj"])[:, None], conv_state, ssm_state


# ---------------------------------------------------------------------------
# Griffin RG-LRU block (recurrentgemma)
# ---------------------------------------------------------------------------
def rglru_spec(cfg: ModelConfig) -> dict:
    g = cfg.rglru
    d = cfg.d_model
    w = g.lru_width or d
    return {
        "in_x": ParamSpec((d, w), ("embed", "rnn"), init="scaled"),
        "in_gate": ParamSpec((d, w), ("embed", "rnn"), init="scaled"),
        "conv_w": ParamSpec((w, g.d_conv), ("rnn", None), init="scaled"),
        "conv_b": ParamSpec((w,), ("rnn",), init="zeros"),
        "gate_i_w": ParamSpec((w,), ("rnn",), init="zeros", dtype=jnp.float32),
        "gate_i_b": ParamSpec((w,), ("rnn",), init="zeros", dtype=jnp.float32),
        "gate_r_w": ParamSpec((w,), ("rnn",), init="zeros", dtype=jnp.float32),
        "gate_r_b": ParamSpec((w,), ("rnn",), init="zeros", dtype=jnp.float32),
        "a_param": ParamSpec((w,), ("rnn",), init="lru_a", dtype=jnp.float32),
        "out": ParamSpec((w, d), ("rnn", "embed"), init="scaled"),
    }


_LRU_C = 8.0


def _rglru_gates(xc, p):
    """xc fp32 (..., w) -> (log_a, gated_in) fp32 (per-channel diagonal gates;
    DESIGN.md notes this simplification of Griffin's block-diagonal gates)."""
    i_gate = jax.nn.sigmoid(xc * p["gate_i_w"] + p["gate_i_b"])
    r_gate = jax.nn.sigmoid(xc * p["gate_r_w"] + p["gate_r_b"])
    log_a = -_LRU_C * r_gate * jax.nn.softplus(p["a_param"])
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) multiplier on the gated input (Griffin eq. 4)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * i_gate * xc


def rglru_block(x: jax.Array, p: dict, cfg: ModelConfig,
                chunk: int = DEFAULT_CHUNK, return_state: bool = False):
    """x: (B,S,d) -> (B,S,d) [, (conv_state, h_final)]. Griffin recurrent block:
    two branches (gate via GELU; x via conv1d + RG-LRU), merged, projected."""
    xb_pre = jnp.einsum("bsd,dw->bsw", x, p["in_x"])
    gb = jnp.einsum("bsd,dw->bsw", x, p["in_gate"])
    xb = causal_conv1d(xb_pre, p["conv_w"], p["conv_b"])
    a, b = _rglru_gates(xb.astype(F32), p)
    hs, h_final = chunked_diag_scan(a, b, chunk=chunk)   # (B,S,w) fp32
    y = hs * jax.nn.gelu(gb.astype(F32))
    out = jnp.einsum("bsw,wd->bsd", y.astype(x.dtype), p["out"])
    if return_state:
        conv_state = xb_pre[:, -(cfg.rglru.d_conv - 1):]
        return out, (conv_state, h_final)
    return out


def rglru_decode(x_t: jax.Array, p: dict, cfg: ModelConfig,
                 conv_state: jax.Array, h: jax.Array):
    """x_t: (B,1,d); conv_state (B,K-1,w); h (B,w) fp32."""
    xb = jnp.einsum("bsd,dw->bsw", x_t, p["in_x"])[:, 0]
    gb = jnp.einsum("bsd,dw->bsw", x_t, p["in_gate"])[:, 0]
    xb, conv_state = conv1d_step(xb, conv_state, p["conv_w"], p["conv_b"])
    a, b = _rglru_gates(xb.astype(F32), p)
    h = a * h + b
    y = h * jax.nn.gelu(gb.astype(F32))
    return jnp.einsum("bw,wd->bd", y.astype(x_t.dtype), p["out"])[:, None], conv_state, h
